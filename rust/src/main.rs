//! `accumkrr` — CLI launcher for the accumulation-sketch KRR framework.
//!
//! ```text
//! accumkrr bench <fig1|fig2|fig3|fig4|fig5|thm8|cost|adaptive|sampling|cluster|serve|tiles>
//!          [--replicates N] [--n-max N] [--seed S] [--csv PATH] [--full]
//!          [--streamed] [--smoke]  # smoke: CI-sized serve load test
//! accumkrr train --name M --dataset rqa --n 2000 --sketch accum --m 4
//!          [--d D] [--lambda L] [--bandwidth B] [--seed S] [--save PATH]
//!          [--precision f64|f32]  # f32: single-precision Gram assembly
//!          [--sampling uniform|leverage|poisson]  # informed row draws
//! accumkrr train --sketch adaptive [--m-max M] [--rel-tol T]  # adaptive m
//!          [--refine-after-m R]  # refine draw probs between terms
//! accumkrr train --data-path X.bin --data-kind file --data-dim P --data-y Y.bin
//!          # out-of-core: stream X off disk (f64 LE row-major file or
//!          # shard directory via --data-kind shards), never resident
//! accumkrr cluster --dataset moons --n 600 --k 2
//!          [--method operator|sketched|adaptive] [--d D] [--m M]
//!          [--m-max M] [--rel-tol T] [--bandwidth B] [--seed S]
//!          [--k-max K]  # sweep k in 2..=K, pick by eigengap
//!          [--data-path P --data-kind file|shards --data-dim D]  # out-of-core
//! accumkrr serve [--addr 127.0.0.1:7878] [--max-batch N] [--max-wait-ms T]
//!          [--fixed-wait]       # disable the adaptive batching wait
//!          [--max-inflight N] [--high-water BYTES] [--workers N]
//! accumkrr client [op] [--addr 127.0.0.1:7878] [--model M] [--x JSON]
//!          [--json REQ]         # full request object, overrides op flags
//!          [--legacy]           # newline-JSON instead of framed
//!          [--retries N] [--backoff-ms T] [--seed S]  # retry policy
//!          [--deadline-ms T]    # per-request deadline (server-enforced)
//! accumkrr info [--artifacts DIR]
//! accumkrr gen-data --dataset rqa --n 1000 --out data.csv [--seed S]
//! ```

// Same rationale as the lib.rs crate-level allows: keep the CI
// `clippy -D warnings` gate about correctness, not CLI-plumbing style.
#![allow(unknown_lints)]
#![allow(clippy::uninlined_format_args, clippy::too_many_arguments)]

use accumkrr::bench::{self, BenchOpts};
use accumkrr::coordinator::state::{model_to_json, ModelStore, TrainRequest};
use accumkrr::coordinator::{serve, ServerConfig};
use accumkrr::util::cli::Args;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let code = match args.positional.first().map(|s| s.as_str()) {
        Some("bench") => cmd_bench(&args),
        Some("train") => cmd_train(&args),
        Some("cv") => cmd_cv(&args),
        Some("kpca") => cmd_kpca(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("serve") => cmd_serve(&args),
        Some("client") => cmd_client(&args),
        Some("info") => cmd_info(&args),
        Some("gen-data") => cmd_gen_data(&args),
        _ => {
            eprintln!(
                "usage: accumkrr <bench|train|cv|kpca|cluster|serve|client|info|gen-data> [flags]"
            );
            eprintln!("       see module docs / README for flags");
            2
        }
    };
    std::process::exit(code);
}

fn bench_opts(args: &Args) -> BenchOpts {
    // precedence: built-in defaults < --config file < explicit flags
    let cfg = args
        .flags
        .get("config")
        .map(|p| accumkrr::util::config::Config::load(p).expect("config file"))
        .unwrap_or_default();
    let defaults = BenchOpts::default();
    BenchOpts {
        replicates: args.usize_or(
            "replicates",
            cfg.usize_or("bench", "replicates", defaults.replicates),
        ),
        n_max: args.usize_or("n-max", cfg.usize_or("bench", "n_max", defaults.n_max)),
        seed: args.usize_or("seed", cfg.usize_or("bench", "seed", defaults.seed as usize)) as u64,
        csv: args
            .flags
            .get("csv")
            .cloned()
            .or_else(|| cfg.get("bench", "csv").and_then(|v| v.as_str().map(String::from))),
        full: args.has("full") || cfg.bool_or("bench", "full", false),
        streamed: args.has("streamed") || cfg.bool_or("bench", "streamed", false),
        smoke: args.has("smoke") || cfg.bool_or("bench", "smoke", false),
    }
}

fn cmd_bench(args: &Args) -> i32 {
    let Some(id) = args.positional.get(1) else {
        eprintln!("bench: missing figure id");
        return 2;
    };
    let opts = bench_opts(args);
    match bench::run(id, &opts) {
        Ok(rows) => {
            bench::print_table(&format!("{id} (replicates={})", opts.replicates), &rows, &opts.csv);
            0
        }
        Err(e) => {
            eprintln!("bench: {e}");
            2
        }
    }
}

/// Shared `--data-*` flags → an out-of-core `DataSpec`: `--data-path P`
/// activates the file-backed route (`--data-kind file|shards`,
/// `--data-dim D` for flat files, `--data-y Y` for train targets);
/// absent, jobs use the named dataset generators.
fn data_spec_from_args(args: &Args) -> Option<accumkrr::coordinator::DataSpec> {
    let path = args.flags.get("data-path")?.clone();
    Some(accumkrr::coordinator::DataSpec {
        kind: args.str_or("data-kind", "file").to_string(),
        path,
        dim: args.usize_or("data-dim", 0),
        y_path: args.flags.get("data-y").cloned(),
    })
}

fn cmd_train(args: &Args) -> i32 {
    let (kind, mut adaptive) = match accumkrr::coordinator::state::parse_sketch_spec(
        args.str_or("sketch", "accum"),
        args.usize_or("m", 4),
        args.usize_or("m-max", 64),
        args.f64_or("rel-tol", 1e-3),
    ) {
        Ok(spec) => spec,
        Err(e) => {
            eprintln!("train: {e}");
            return 2;
        }
    };
    // --refine-after-m R: adaptive fits estimate leverage from the cached
    // support columns once R terms accumulated and draw later terms from
    // it (0 disables — the draw stream stays bit-identical)
    if let Some(a) = adaptive.as_mut() {
        a.refine_after_m = args.usize_or("refine-after-m", 0);
    }
    let precision = match accumkrr::linalg::Precision::parse(args.str_or("precision", "f64")) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("train: {e}");
            return 2;
        }
    };
    let sampling = match accumkrr::coordinator::SamplingSpec::parse(
        args.str_or("sampling", "uniform"),
    ) {
        Ok(sp) => sp,
        Err(e) => {
            eprintln!("train: {e}");
            return 2;
        }
    };
    let req = TrainRequest {
        name: args.str_or("name", "default").to_string(),
        dataset: args.str_or("dataset", "bimodal").to_string(),
        n: args.usize_or("n", 1000),
        kind,
        d: args.usize_or("d", 0),
        lambda: args.f64_or("lambda", 0.0),
        bandwidth: args.f64_or("bandwidth", 0.0),
        seed: args.usize_or("seed", 1) as u64,
        adaptive,
        precision,
        sampling,
        data: data_spec_from_args(args),
    };
    let store = ModelStore::new();
    match store.train(&req) {
        Ok(meta) => {
            println!(
                "trained {:?}: n={} sketch={} landmarks={} train_mse={:.6} train_secs={:.3}",
                req.name,
                meta.n_train,
                meta.sketch,
                meta.model.num_landmarks(),
                meta.train_mse,
                meta.train_secs
            );
            let rep = *meta.model.report();
            if rep.rounds > 0 {
                println!(
                    "adaptive: chose m={} in {} rounds ({} rank updates, {} refactors, {} kernel evals)",
                    rep.m, rep.rounds, rep.rank_updates, rep.refactors, rep.kernel_evals
                );
            }
            if meta.sampling != "uniform" || meta.d_stat > 0.0 {
                println!("sampling: {} (d_stat={:.2})", meta.sampling, meta.d_stat);
            }
            if rep.refine_round > 0 {
                println!("refined draw probabilities at round {}", rep.refine_round);
            }
            if let Some(path) = args.flags.get("save") {
                let j = model_to_json(&meta.model);
                if let Err(e) = std::fs::write(path, j.to_string()) {
                    eprintln!("save failed: {e}");
                    return 1;
                }
                println!("model saved to {path}");
            }
            0
        }
        Err(e) => {
            eprintln!("train: {e}");
            1
        }
    }
}

fn cmd_cv(args: &Args) -> i32 {
    use accumkrr::rng::Pcg64;
    let mut rng = Pcg64::seed(args.usize_or("seed", 1) as u64);
    let n = args.usize_or("n", 1000);
    let dataset = args.str_or("dataset", "bimodal");
    let (mut ds, dx, _) = match accumkrr::coordinator::state::dataset_for(dataset, n, 0.0, &mut rng)
    {
        Ok(v) => v,
        Err(e) => {
            eprintln!("cv: {e}");
            return 1;
        }
    };
    accumkrr::data::normalize_features(&mut ds.x);
    let d = args.usize_or("d", accumkrr::coordinator::state::paper_d(n, dx));
    let m = args.usize_or("m", 4);
    let builder = accumkrr::sketch::SketchBuilder::new(
        accumkrr::sketch::SketchKind::Accumulation { m },
    );
    let lambdas = [1e-5, 1e-4, 1e-3, 1e-2, 1e-1];
    let bandwidths = [0.25, 0.5, 1.0, 2.0, 4.0];
    let res = accumkrr::krr::cv_select(
        accumkrr::kernels::Kernel::gaussian,
        &ds.x,
        &ds.y,
        &lambdas,
        &bandwidths,
        &builder,
        d,
        args.usize_or("folds", 5),
        &mut rng,
    );
    println!("cv grid ({} points):", res.grid.len());
    for (lam, bw, err) in &res.grid {
        println!("  lambda={lam:<8.1e} bw={bw:<6} cv_err={err:.6}");
    }
    println!(
        "selected: lambda={:.1e} bandwidth={} (cv error {:.6})",
        res.lambda, res.bandwidth, res.cv_error
    );
    0
}

fn cmd_kpca(args: &Args) -> i32 {
    use accumkrr::rng::Pcg64;
    let mut rng = Pcg64::seed(args.usize_or("seed", 1) as u64);
    let n = args.usize_or("n", 500);
    let dataset = args.str_or("dataset", "bimodal");
    let (mut ds, dx, kern) =
        match accumkrr::coordinator::state::dataset_for(dataset, n, args.f64_or("bandwidth", 0.0), &mut rng) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("kpca: {e}");
                return 1;
            }
        };
    accumkrr::data::normalize_features(&mut ds.x);
    let d = args.usize_or("d", accumkrr::coordinator::state::paper_d(n, dx) * 2);
    let m = args.usize_or("m", 4);
    let r = args.usize_or("r", 8);
    let s = accumkrr::sketch::SketchBuilder::new(accumkrr::sketch::SketchKind::Accumulation { m })
        .build(ds.n(), d, &mut rng);
    match accumkrr::krr::sketched_kpca(&kern, &ds.x, &s, r) {
        Some(res) => {
            println!("sketched kernel PCA on {dataset} (n={n}, d={d}, m={m}):");
            for (j, lam) in res.eigenvalues.iter().enumerate() {
                println!("  component {j}: eigenvalue {lam:.6}");
            }
            0
        }
        None => {
            eprintln!("kpca: factorisation failed");
            1
        }
    }
}

fn cmd_cluster(args: &Args) -> i32 {
    use accumkrr::coordinator::state::run_cluster_job;
    use accumkrr::coordinator::ClusterRequest;
    let defaults = ClusterRequest::default();
    let req = ClusterRequest {
        dataset: args.str_or("dataset", &defaults.dataset).to_string(),
        n: args.usize_or("n", defaults.n),
        k: args.usize_or("k", defaults.k),
        k_max: args.usize_or("k-max", defaults.k_max),
        method: args.str_or("method", &defaults.method).to_string(),
        d: args.usize_or("d", defaults.d),
        m: args.usize_or("m", defaults.m),
        m_max: args.usize_or("m-max", defaults.m_max),
        rel_tol: args.f64_or("rel-tol", defaults.rel_tol),
        bandwidth: args.f64_or("bandwidth", defaults.bandwidth),
        seed: args.usize_or("seed", defaults.seed as usize) as u64,
        data: data_spec_from_args(args),
    };
    match run_cluster_job(&req) {
        Ok(j) => {
            let g = |k: &str| j.get(k).cloned();
            println!(
                "clustered {} (n={}): k={} method={} secs={:.3}",
                req.dataset,
                // the reply's n is authoritative (file-backed sources
                // carry their own row count)
                g("n").and_then(|v| v.as_usize()).unwrap_or(req.n),
                g("k").and_then(|v| v.as_usize()).unwrap_or(0),
                req.method,
                g("secs").and_then(|v| v.as_f64()).unwrap_or(0.0),
            );
            if let Some(sizes) = g("sizes") {
                println!("cluster sizes: {sizes}");
            }
            if let Some(ev) = g("eigenvalues") {
                println!("bottom Laplacian eigenvalues: {ev}");
            }
            if let Some(m) = g("chosen_m").and_then(|v| v.as_usize()) {
                println!("adaptive: chose m={m}");
            }
            if let Some(ari) = g("ari_vs_truth").and_then(|v| v.as_f64()) {
                println!("ARI vs ground truth: {ari:.4}");
            }
            if let Some(sweep) = g("sweep").and_then(|v| v.as_arr().map(|a| a.to_vec())) {
                println!("k sweep (eigengap model selection):");
                for row in &sweep {
                    println!(
                        "  k={} inertia={:.5} eigengap={:.5}",
                        row.get("k").and_then(|v| v.as_usize()).unwrap_or(0),
                        row.get("inertia").and_then(|v| v.as_f64()).unwrap_or(0.0),
                        row.get("eigengap").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    );
                }
            }
            if let Some(path) = args.flags.get("save") {
                if let Err(e) = std::fs::write(path, j.to_string()) {
                    eprintln!("save failed: {e}");
                    return 1;
                }
                println!("full reply saved to {path}");
            }
            0
        }
        Err(e) => {
            eprintln!("cluster: {e}");
            1
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let defaults = ServerConfig::default();
    let cfg = ServerConfig {
        addr: args.str_or("addr", "127.0.0.1:7878").to_string(),
        batcher: accumkrr::coordinator::BatcherConfig {
            max_batch: args.usize_or("max-batch", defaults.batcher.max_batch),
            max_wait: std::time::Duration::from_secs_f64(
                args.f64_or("max-wait-ms", 2.0).max(0.0) / 1e3,
            ),
            adaptive: !args.has("fixed-wait"),
        },
        max_inflight: args.usize_or("max-inflight", defaults.max_inflight),
        high_water_bytes: args.usize_or("high-water", defaults.high_water_bytes),
        workers: args.usize_or("workers", defaults.workers).max(1),
    };
    let store = Arc::new(ModelStore::new());
    println!(
        "accumkrr serving on {} (framed + newline JSON; send {{\"op\":\"shutdown\"}} to stop)",
        cfg.addr
    );
    match serve(store, cfg, true) {
        Ok(_) => 0,
        Err(e) => {
            eprintln!("serve: {e}");
            1
        }
    }
}

/// One-shot client for the serving plane: build (or take via `--json`) a
/// request and send it through the retrying [`Client`] — framed by
/// default, newline-JSON with `--legacy`; idempotent ops are retried
/// with exponential backoff (`--retries`, `--backoff-ms`). The reply
/// prints on stdout; retry/err_code telemetry goes to stderr.
fn cmd_client(args: &Args) -> i32 {
    use accumkrr::coordinator::{Client, ClientConfig};
    use accumkrr::util::json::Json;
    let req = if let Some(raw) = args.flags.get("json") {
        match Json::parse(raw) {
            Ok(j) => j,
            Err(e) => {
                eprintln!("client: bad --json: {e}");
                return 2;
            }
        }
    } else {
        let op = args.positional.get(1).map(|s| s.as_str()).unwrap_or("ping");
        let mut fields = vec![("method", Json::from(op))];
        if let Some(m) = args.flags.get("model") {
            fields.push(("model", Json::from(m.as_str())));
        }
        if let Some(x) = args.flags.get("x") {
            match Json::parse(x) {
                Ok(j) => fields.push(("x", j)),
                Err(e) => {
                    eprintln!("client: bad --x: {e}");
                    return 2;
                }
            }
        }
        if let Some(ms) = args.flags.get("deadline-ms").and_then(|v| v.parse::<usize>().ok()) {
            fields.push(("deadline_ms", Json::from(ms)));
        }
        Json::obj(fields)
    };
    let mut client = Client::new(ClientConfig {
        addr: args.str_or("addr", "127.0.0.1:7878").to_string(),
        retries: args.usize_or("retries", 2) as u32,
        backoff: std::time::Duration::from_millis(args.usize_or("backoff-ms", 50) as u64),
        seed: args.usize_or("seed", 1) as u64,
        legacy: args.has("legacy"),
    });
    match client.call(&req) {
        Ok(reply) => {
            println!("{reply}");
            let (attempts, retries) = client.stats();
            if retries > 0 {
                eprintln!("client: {attempts} attempts ({retries} retries)");
            }
            if !client.err_code_tally().is_empty() {
                let tally: Vec<String> = client
                    .err_code_tally()
                    .iter()
                    .map(|(code, n)| format!("{code}={n}"))
                    .collect();
                eprintln!("client: err_codes {}", tally.join(" "));
            }
            0
        }
        Err(e) => {
            eprintln!("client: {e}");
            1
        }
    }
}

#[cfg(feature = "xla")]
fn cmd_info(args: &Args) -> i32 {
    let dir = args.str_or("artifacts", "artifacts");
    println!("host: {}", accumkrr::runtime::HostStamp::detect());
    match accumkrr::runtime::ModelRuntime::open(dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifacts in {dir}:");
            for a in &rt.manifest().artifacts {
                println!(
                    "  {:40} entry={:17} kernel={:9} n={} p={} d={} m={} b={}",
                    a.name, a.entry, a.kernel, a.n, a.p, a.d, a.m, a.b
                );
            }
            0
        }
        Err(e) => {
            eprintln!("info: {e} (run `make artifacts` first?)");
            1
        }
    }
}

/// Without the `xla` feature there is no PJRT engine, but the manifest
/// and the host/dispatch stamp are still useful diagnostics.
#[cfg(not(feature = "xla"))]
fn cmd_info(args: &Args) -> i32 {
    let dir = args.str_or("artifacts", "artifacts");
    println!("host: {}", accumkrr::runtime::HostStamp::detect());
    println!("PJRT platform: disabled (build with `--features xla`)");
    match accumkrr::runtime::Manifest::load(dir) {
        Ok(man) => {
            println!("artifacts in {dir}:");
            for a in &man.artifacts {
                println!(
                    "  {:40} entry={:17} kernel={:9} n={} p={} d={} m={} b={}",
                    a.name, a.entry, a.kernel, a.n, a.p, a.d, a.m, a.b
                );
            }
            0
        }
        Err(e) => {
            eprintln!("info: {e} (run `make artifacts` first?)");
            1
        }
    }
}

fn cmd_gen_data(args: &Args) -> i32 {
    use accumkrr::rng::Pcg64;
    let n = args.usize_or("n", 1000);
    let name = args.str_or("dataset", "rqa");
    let out = args.str_or("out", "data.csv");
    let mut rng = Pcg64::seed(args.usize_or("seed", 1) as u64);
    let result = accumkrr::coordinator::state::dataset_for(name, n, 0.0, &mut rng);
    match result {
        Ok((ds, _, _)) => {
            let mut text = String::new();
            let p = ds.x.cols();
            let header: Vec<String> = (0..p).map(|j| format!("f{j}")).collect();
            text.push_str(&header.join(","));
            text.push_str(",y\n");
            for i in 0..ds.n() {
                let mut fields: Vec<String> =
                    ds.x.row(i).iter().map(|v| format!("{v}")).collect();
                fields.push(format!("{}", ds.y[i]));
                text.push_str(&fields.join(","));
                text.push('\n');
            }
            if let Err(e) = std::fs::write(out, text) {
                eprintln!("gen-data: {e}");
                return 1;
            }
            println!("wrote {n} rows of {name} to {out}");
            0
        }
        Err(e) => {
            eprintln!("gen-data: {e}");
            1
        }
    }
}
