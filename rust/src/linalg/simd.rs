//! SIMD micro-kernel dispatch: explicit AVX2+FMA (x86_64) and NEON
//! (aarch64) inner kernels behind one-time runtime feature detection,
//! with the portable scalar kernel as the always-correct fallback.
//!
//! Everything that is hot *and* vectorisable funnels through here:
//!
//! * the `MR×NR` GEMM micro-kernel consumed by the packed driver in
//!   [`super::gemm`] (packing layout unchanged — the dispatch swaps only
//!   the register-tile arithmetic, so all four product variants get the
//!   vector kernel for free);
//! * the lane-parallel `exp` used by the batched kernel map
//!   ([`crate::kernels::Kernel::map_sq_dist`]), in f64 (4-wide) and f32
//!   (8-wide) flavours.
//!
//! **Dispatch model.** [`active`] answers "which kernel?" from, in
//! order: a thread-local override (see [`with_kernel`]), then a
//! process-wide `OnceLock` initialised on first use from the
//! `ACCUMKRR_FORCE_SCALAR` env var and `is_x86_feature_detected!`. Hot
//! entry points sample the dispatch **once on the calling thread** and
//! pass the choice into their worker closures, so a scoped override
//! covers the whole parallel computation and a worker thread can never
//! disagree with its coordinator mid-product.
//!
//! **Determinism contract (per selected kernel).** For a fixed
//! [`KernelImpl`], every result is bitwise independent of thread count
//! and tile size — the FMA tile accumulates in the same fixed order the
//! scalar kernel does, and the lane-parallel `exp` pushes slice tails
//! through the same vector routine via a padded lane buffer, so each
//! element's value is independent of its position in the slice. *Across*
//! kernels, FMA contraction means AVX2/NEON results differ from scalar
//! by accumulated ulps; tests compare dispatches with tight relative
//! tolerances, never bitwise. DESIGN.md §8 spells out the policy.

use std::cell::Cell;
use std::sync::OnceLock;

/// Micro-tile rows: the accumulator holds `MR×NR` partial sums in
/// registers (shared with the packed driver in [`super::gemm`]).
pub(crate) const MR: usize = 4;
/// Micro-tile columns: two 4-lane f64 vectors per accumulator row.
pub(crate) const NR: usize = 8;

/// Which inner micro-kernel implementation the dispatch selected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelImpl {
    /// Portable Rust fallback (always correct on every target).
    Scalar,
    /// AVX2 + FMA 4×8 register tile (x86_64, runtime-detected).
    Avx2,
    /// NEON 4×8 register tile (aarch64 — a baseline feature there).
    Neon,
}

impl KernelImpl {
    /// Stable name recorded in bench output and host stamps.
    pub fn name(self) -> &'static str {
        match self {
            KernelImpl::Scalar => "scalar",
            KernelImpl::Avx2 => "avx2",
            KernelImpl::Neon => "neon",
        }
    }
}

/// Numeric accumulation policy for the kernel-assembly and sketch-apply
/// hot paths. The `d×d` solve side (`chol`, pencil, eig) always runs in
/// f64 regardless of this knob — mixed precision buys lane width on the
/// `O(n·tile)` assembly work, not on the conditioning-sensitive solves.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// Assemble and accumulate in f64 (default; all bitwise contracts).
    #[default]
    F64,
    /// Assemble kernel tiles and accumulate `K·B` rows in f32, widening
    /// to f64 once per output element. Accuracy bounds are quantified in
    /// EXPERIMENTS.md §Mixed-precision.
    F32,
}

impl Precision {
    /// Stable name used in job schemas and bench output.
    pub fn name(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }

    /// Parse a job-schema / CLI spelling.
    pub fn parse(s: &str) -> Result<Precision, String> {
        match s {
            "f64" | "F64" | "double" => Ok(Precision::F64),
            "f32" | "F32" | "single" => Ok(Precision::F32),
            other => Err(format!("precision: expected f32 or f64, got {other:?}")),
        }
    }
}

static DETECTED: OnceLock<KernelImpl> = OnceLock::new();

thread_local! {
    /// Scoped dispatch override (tests, bench uplift runs). Thread-local
    /// on purpose: a global toggle would race against concurrently
    /// running tests that rely on the ambient dispatch.
    static OVERRIDE: Cell<Option<KernelImpl>> = const { Cell::new(None) };
}

fn force_scalar_env() -> bool {
    match std::env::var("ACCUMKRR_FORCE_SCALAR") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    }
}

#[cfg(target_arch = "x86_64")]
fn detect_arch() -> KernelImpl {
    if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma") {
        KernelImpl::Avx2
    } else {
        KernelImpl::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_arch() -> KernelImpl {
    // NEON is baseline on every aarch64 target std supports.
    KernelImpl::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_arch() -> KernelImpl {
    KernelImpl::Scalar
}

fn detect() -> KernelImpl {
    if force_scalar_env() {
        KernelImpl::Scalar
    } else {
        detect_arch()
    }
}

/// The micro-kernel implementation in effect on this thread: a scoped
/// [`with_kernel`] override if present, else the process-wide detection
/// (`ACCUMKRR_FORCE_SCALAR=1` pins the fallback; cached in a `OnceLock`).
pub fn active() -> KernelImpl {
    if let Some(k) = OVERRIDE.with(|c| c.get()) {
        return k;
    }
    *DETECTED.get_or_init(detect)
}

/// Name of the dispatch in effect (`"scalar"` / `"avx2"` / `"neon"`).
pub fn kernel_name() -> &'static str {
    active().name()
}

/// CPU feature set the detection probed, for provenance stamps
/// (`runtime::HostStamp`): what the *hardware* offers, independent of
/// any override pinning the dispatch below it.
pub fn detected_features() -> String {
    detected_features_impl()
}

#[cfg(target_arch = "x86_64")]
fn detected_features_impl() -> String {
    let mut feats = vec!["sse2"];
    if std::arch::is_x86_feature_detected!("avx") {
        feats.push("avx");
    }
    if std::arch::is_x86_feature_detected!("avx2") {
        feats.push("avx2");
    }
    if std::arch::is_x86_feature_detected!("fma") {
        feats.push("fma");
    }
    feats.join("+")
}

#[cfg(target_arch = "aarch64")]
fn detected_features_impl() -> String {
    "neon".to_string()
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detected_features_impl() -> String {
    "portable".to_string()
}

/// Run `f` with the dispatch pinned to `k` on this thread, restoring the
/// previous state afterwards (also on panic). Entry points sample the
/// dispatch once on the calling thread and propagate it into their
/// worker closures, so the override covers whole parallel computations
/// started inside `f`. This is the in-process companion to the
/// `ACCUMKRR_FORCE_SCALAR` env pin: tests and the bench's uplift rows
/// use it to run the same computation under two dispatches.
pub fn with_kernel<R>(k: KernelImpl, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<KernelImpl>);
    impl Drop for Restore {
        fn drop(&mut self) {
            OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(OVERRIDE.with(|c| c.replace(Some(k))));
    f()
}

// ---------------------------------------------------------------------
// GEMM micro-kernel
// ---------------------------------------------------------------------

/// The register-blocked heart of the packed GEMM driver:
/// `acc[r][t] += Σ_p a[p·MR+r] · b[p·NR+t]`, dispatched per `imp`. Both
/// operands arrive packed and zero-padded (see [`super::gemm`]), so
/// every implementation runs branch-free at fixed trip counts.
#[inline(always)]
pub(crate) fn micro_kernel(
    imp: KernelImpl,
    kc: usize,
    a: &[f64],
    b: &[f64],
    acc: &mut [[f64; NR]; MR],
) {
    match imp {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `imp` is Avx2 only when runtime detection saw avx2+fma
        // on this CPU; the packed operands satisfy the length contract
        // (`a ≥ kc·MR`, `b ≥ kc·NR`) asserted inside.
        KernelImpl::Avx2 => unsafe { avx2::micro_kernel_4x8(kc, a, b, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is a baseline aarch64 feature; same length contract.
        KernelImpl::Neon => unsafe { neon::micro_kernel_4x8(kc, a, b, acc) },
        _ => micro_kernel_scalar(kc, a, b, acc),
    }
}

/// Portable micro-kernel (the pre-dispatch implementation, unchanged —
/// the scalar baseline every SIMD kernel is tested against). LLVM
/// autovectorises the fixed-width `t` loop on targets with vector units
/// enabled at compile time.
#[inline(always)]
fn micro_kernel_scalar(kc: usize, a: &[f64], b: &[f64], acc: &mut [[f64; NR]; MR]) {
    for p in 0..kc {
        let av = &a[p * MR..(p + 1) * MR];
        let bv = &b[p * NR..(p + 1) * NR];
        for r in 0..MR {
            let ar = av[r];
            for (cv, bt) in acc[r].iter_mut().zip(bv.iter()) {
                *cv += ar * *bt;
            }
        }
    }
}

// ---------------------------------------------------------------------
// Lane-parallel exp
// ---------------------------------------------------------------------

/// `xs[i] = exp(xs[i])` elementwise, dispatched per `imp`. Under SIMD
/// dispatch each element's result is independent of its position in the
/// slice (tails run through the same vector routine via a padded lane
/// buffer) — the property the bitwise symmetric-vs-rectangular assembly
/// test relies on, since the two paths map differently-aligned row
/// suffixes.
pub(crate) fn map_exp(imp: KernelImpl, xs: &mut [f64]) {
    match imp {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 implies runtime-detected avx2+fma.
        KernelImpl::Avx2 => unsafe { avx2::map_exp(xs) },
        _ => {
            for v in xs.iter_mut() {
                *v = exp_fast(*v);
            }
        }
    }
}

/// f32 twin of [`map_exp`] for the mixed-precision assembly path
/// (8 lanes per AVX2 vector).
pub(crate) fn map_exp_f32(imp: KernelImpl, xs: &mut [f32]) {
    match imp {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 implies runtime-detected avx2+fma.
        KernelImpl::Avx2 => unsafe { avx2::map_exp_f32(xs) },
        _ => {
            for v in xs.iter_mut() {
                *v = exp_fast_f32(*v);
            }
        }
    }
}

/// Branch-light scalar `exp` (moved here from `kernels::functions` when
/// the dispatch layer grew a vector twin): Cody–Waite range reduction
/// (`x = n·ln2 + r`, `|r| ≤ ln2/2`) followed by a degree-12
/// Taylor–Horner polynomial and an exact power-of-two scale via exponent
/// bits. No division and no libm call. Accurate to a few ulp for
/// `x ∈ [−708, 709]` (the truncation tail `r¹³/13!` is below 2e-16
/// relative); saturates to `0`/`∞` outside.
#[inline]
pub(crate) fn exp_fast(x: f64) -> f64 {
    if x < -708.0 {
        return 0.0;
    }
    if x > 709.0 {
        return f64::INFINITY;
    }
    let n = (x * std::f64::consts::LOG2_E).round();
    let r = (x - n * LN2_HI) - n * LN2_LO;
    let mut p = 1.0 / 479_001_600.0; // 1/12!
    p = p * r + 1.0 / 39_916_800.0; // 1/11!
    p = p * r + 1.0 / 3_628_800.0; // 1/10!
    p = p * r + 1.0 / 362_880.0; // 1/9!
    p = p * r + 1.0 / 40_320.0; // 1/8!
    p = p * r + 1.0 / 5_040.0; // 1/7!
    p = p * r + 1.0 / 720.0; // 1/6!
    p = p * r + 1.0 / 120.0; // 1/5!
    p = p * r + 1.0 / 24.0; // 1/4!
    p = p * r + 1.0 / 6.0; // 1/3!
    p = p * r + 0.5; // 1/2!
    p = p * r + 1.0; // 1/1!
    p = p * r + 1.0; // 1/0!
    // 2ⁿ exactly, through the exponent field (n ∈ [−1022, 1023] here)
    let scale = f64::from_bits(((n as i64 + 1023) as u64) << 52);
    p * scale
}

const LN2_HI: f64 = 6.931_471_803_691_238_164_90e-1;
const LN2_LO: f64 = 1.908_214_929_270_587_700_02e-10;

/// f32 scalar `exp` for the mixed-precision path: same structure as
/// [`exp_fast`] with a degree-7 polynomial (truncation `r⁸/8!` ≈ 5e-9 at
/// `|r| ≤ ln2/2`, below f32 eps) and f32 Cody–Waite constants. Max
/// relative error ≈ 9e-8 (< 1 ulp) over `[−87, 88]`; saturates outside.
#[inline]
pub(crate) fn exp_fast_f32(x: f32) -> f32 {
    if x < -87.0 {
        return 0.0;
    }
    if x > 88.0 {
        return f32::INFINITY;
    }
    let n = (x * std::f32::consts::LOG2_E).round();
    let r = (x - n * LN2_HI_F32) - n * LN2_LO_F32;
    let mut p = 1.0 / 5_040.0f32; // 1/7!
    p = p * r + 1.0 / 720.0; // 1/6!
    p = p * r + 1.0 / 120.0; // 1/5!
    p = p * r + 1.0 / 24.0; // 1/4!
    p = p * r + 1.0 / 6.0; // 1/3!
    p = p * r + 0.5; // 1/2!
    p = p * r + 1.0; // 1/1!
    p = p * r + 1.0; // 1/0!
    // 2ⁿ via the exponent field (n ∈ [−126, 127] inside the guards)
    let scale = f32::from_bits(((n as i32 + 127) as u32) << 23);
    p * scale
}

const LN2_HI_F32: f32 = 0.693_359_375; // 355/512, exact in f32
const LN2_LO_F32: f32 = -2.121_944_4e-4;

// ---------------------------------------------------------------------
// AVX2 + FMA implementations (x86_64)
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{LN2_HI, LN2_HI_F32, LN2_LO, LN2_LO_F32, MR, NR};
    use std::arch::x86_64::*;

    /// 4×8 f64 register tile: 8 accumulator vectors (4 rows × 2 lanes of
    /// 4), one broadcast per packed A element, FMA into the tile. The
    /// accumulation order per element (`p` ascending) matches the scalar
    /// kernel; only FMA contraction separates the two numerically.
    ///
    /// # Safety
    /// Caller must have runtime-verified `avx2` and `fma`, and pass
    /// packed panels with `a.len() ≥ kc·MR`, `b.len() ≥ kc·NR`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn micro_kernel_4x8(
        kc: usize,
        a: &[f64],
        b: &[f64],
        acc: &mut [[f64; NR]; MR],
    ) {
        debug_assert!(a.len() >= kc * MR && b.len() >= kc * NR);
        // SAFETY: all pointer offsets are in-bounds by the length
        // contract above; loadu/storeu tolerate any alignment.
        unsafe {
            let mut acc_v = [[_mm256_setzero_pd(); 2]; MR];
            for r in 0..MR {
                acc_v[r][0] = _mm256_loadu_pd(acc[r].as_ptr());
                acc_v[r][1] = _mm256_loadu_pd(acc[r].as_ptr().add(4));
            }
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            for p in 0..kc {
                let b0 = _mm256_loadu_pd(bp.add(p * NR));
                let b1 = _mm256_loadu_pd(bp.add(p * NR + 4));
                let arow = ap.add(p * MR);
                for r in 0..MR {
                    let av = _mm256_set1_pd(*arow.add(r));
                    acc_v[r][0] = _mm256_fmadd_pd(av, b0, acc_v[r][0]);
                    acc_v[r][1] = _mm256_fmadd_pd(av, b1, acc_v[r][1]);
                }
            }
            for r in 0..MR {
                _mm256_storeu_pd(acc[r].as_mut_ptr(), acc_v[r][0]);
                _mm256_storeu_pd(acc[r].as_mut_ptr().add(4), acc_v[r][1]);
            }
        }
    }

    /// 4-lane f64 `exp`: the scalar Cody–Waite/Horner pipeline verbatim,
    /// with the float→int n conversion done by the `1.5·2⁵²` magic-add
    /// bit trick (AVX2 has no packed f64→i64 convert) and saturation
    /// applied by mask blends against the *unclamped* input, matching
    /// the scalar guards exactly.
    ///
    /// # Safety
    /// Requires runtime-verified `avx2` and `fma`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn exp4(x: __m256d) -> __m256d {
        // SAFETY: pure register arithmetic; no memory access.
        unsafe {
            let lo_mask = _mm256_cmp_pd::<_CMP_LT_OQ>(x, _mm256_set1_pd(-708.0));
            let hi_mask = _mm256_cmp_pd::<_CMP_GT_OQ>(x, _mm256_set1_pd(709.0));
            // clamp so n/scale stay in range on saturated lanes (their
            // value is overwritten by the blends below)
            let xc = _mm256_max_pd(
                _mm256_set1_pd(-708.0),
                _mm256_min_pd(x, _mm256_set1_pd(709.0)),
            );
            let n = _mm256_round_pd::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(
                _mm256_mul_pd(xc, _mm256_set1_pd(std::f64::consts::LOG2_E)),
            );
            let r = _mm256_fnmadd_pd(
                n,
                _mm256_set1_pd(LN2_LO),
                _mm256_fnmadd_pd(n, _mm256_set1_pd(LN2_HI), xc),
            );
            let mut p = _mm256_set1_pd(1.0 / 479_001_600.0);
            p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(1.0 / 39_916_800.0));
            p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(1.0 / 3_628_800.0));
            p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(1.0 / 362_880.0));
            p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(1.0 / 40_320.0));
            p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(1.0 / 5_040.0));
            p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(1.0 / 720.0));
            p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(1.0 / 120.0));
            p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(1.0 / 24.0));
            p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(1.0 / 6.0));
            p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(0.5));
            p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(1.0));
            p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(1.0));
            // 2ⁿ: bits(n + 1.5·2⁵²) − bits(1.5·2⁵²) recovers n as i64
            // (exact for |n| < 2⁵¹), then (n + 1023) << 52 is the scale.
            const SHIFT: f64 = 6_755_399_441_055_744.0;
            let nbits = _mm256_castpd_si256(_mm256_add_pd(n, _mm256_set1_pd(SHIFT)));
            let nint = _mm256_sub_epi64(nbits, _mm256_castpd_si256(_mm256_set1_pd(SHIFT)));
            let scale = _mm256_castsi256_pd(_mm256_slli_epi64::<52>(_mm256_add_epi64(
                nint,
                _mm256_set1_epi64x(1023),
            )));
            let y = _mm256_mul_pd(p, scale);
            let y = _mm256_andnot_pd(lo_mask, y);
            _mm256_blendv_pd(y, _mm256_set1_pd(f64::INFINITY), hi_mask)
        }
    }

    /// Apply [`exp4`] over a slice. The tail (`len % 4`) runs through the
    /// same vector routine via a padded lane buffer so every element's
    /// result is independent of its position and of the slice length.
    ///
    /// # Safety
    /// Requires runtime-verified `avx2` and `fma`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn map_exp(xs: &mut [f64]) {
        // SAFETY: chunk pointers come from `chunks_exact_mut(4)`, so
        // every 4-lane load/store is in-bounds; the tail goes through a
        // stack buffer of exactly 4 lanes.
        unsafe {
            let mut chunks = xs.chunks_exact_mut(4);
            for c in &mut chunks {
                let v = _mm256_loadu_pd(c.as_ptr());
                _mm256_storeu_pd(c.as_mut_ptr(), exp4(v));
            }
            let rem = chunks.into_remainder();
            if !rem.is_empty() {
                let mut buf = [0.0f64; 4];
                buf[..rem.len()].copy_from_slice(rem);
                let v = _mm256_loadu_pd(buf.as_ptr());
                _mm256_storeu_pd(buf.as_mut_ptr(), exp4(v));
                rem.copy_from_slice(&buf[..rem.len()]);
            }
        }
    }

    /// 8-lane f32 `exp`: degree-7 Horner; here AVX2's native packed
    /// f32→i32 convert replaces the f64 magic-add trick.
    ///
    /// # Safety
    /// Requires runtime-verified `avx2` and `fma`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn exp8_f32(x: __m256) -> __m256 {
        // SAFETY: pure register arithmetic; no memory access.
        unsafe {
            let lo_mask = _mm256_cmp_ps::<_CMP_LT_OQ>(x, _mm256_set1_ps(-87.0));
            let hi_mask = _mm256_cmp_ps::<_CMP_GT_OQ>(x, _mm256_set1_ps(88.0));
            let xc = _mm256_max_ps(
                _mm256_set1_ps(-87.0),
                _mm256_min_ps(x, _mm256_set1_ps(88.0)),
            );
            let n = _mm256_round_ps::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(
                _mm256_mul_ps(xc, _mm256_set1_ps(std::f32::consts::LOG2_E)),
            );
            let r = _mm256_fnmadd_ps(
                n,
                _mm256_set1_ps(LN2_LO_F32),
                _mm256_fnmadd_ps(n, _mm256_set1_ps(LN2_HI_F32), xc),
            );
            let mut p = _mm256_set1_ps(1.0 / 5_040.0);
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0 / 720.0));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0 / 120.0));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0 / 24.0));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0 / 6.0));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(0.5));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0));
            // n is already integral, so the nearest-even convert is exact
            let nint = _mm256_cvtps_epi32(n);
            let scale = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(
                nint,
                _mm256_set1_epi32(127),
            )));
            let y = _mm256_mul_ps(p, scale);
            let y = _mm256_andnot_ps(lo_mask, y);
            _mm256_blendv_ps(y, _mm256_set1_ps(f32::INFINITY), hi_mask)
        }
    }

    /// Apply [`exp8_f32`] over a slice with the same padded-tail
    /// discipline as [`map_exp`].
    ///
    /// # Safety
    /// Requires runtime-verified `avx2` and `fma`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn map_exp_f32(xs: &mut [f32]) {
        // SAFETY: same bounds argument as `map_exp`, with 8-lane chunks.
        unsafe {
            let mut chunks = xs.chunks_exact_mut(8);
            for c in &mut chunks {
                let v = _mm256_loadu_ps(c.as_ptr());
                _mm256_storeu_ps(c.as_mut_ptr(), exp8_f32(v));
            }
            let rem = chunks.into_remainder();
            if !rem.is_empty() {
                let mut buf = [0.0f32; 8];
                buf[..rem.len()].copy_from_slice(rem);
                let v = _mm256_loadu_ps(buf.as_ptr());
                _mm256_storeu_ps(buf.as_mut_ptr(), exp8_f32(v));
                rem.copy_from_slice(&buf[..rem.len()]);
            }
        }
    }
}

// ---------------------------------------------------------------------
// NEON implementation (aarch64)
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{MR, NR};
    use std::arch::aarch64::*;

    /// 4×8 f64 register tile on 2-lane NEON vectors: 16 accumulators +
    /// 4 B vectors fit the 32-register file. Same fixed accumulation
    /// order as the scalar kernel, FMA-contracted.
    ///
    /// # Safety
    /// NEON is baseline on aarch64; caller passes packed panels with
    /// `a.len() ≥ kc·MR`, `b.len() ≥ kc·NR`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn micro_kernel_4x8(
        kc: usize,
        a: &[f64],
        b: &[f64],
        acc: &mut [[f64; NR]; MR],
    ) {
        debug_assert!(a.len() >= kc * MR && b.len() >= kc * NR);
        // SAFETY: all pointer offsets are in-bounds by the length
        // contract above.
        unsafe {
            let mut acc_v = [[vdupq_n_f64(0.0); 4]; MR];
            for r in 0..MR {
                for t in 0..4 {
                    acc_v[r][t] = vld1q_f64(acc[r].as_ptr().add(2 * t));
                }
            }
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            for p in 0..kc {
                let brow = bp.add(p * NR);
                let bv = [
                    vld1q_f64(brow),
                    vld1q_f64(brow.add(2)),
                    vld1q_f64(brow.add(4)),
                    vld1q_f64(brow.add(6)),
                ];
                let arow = ap.add(p * MR);
                for r in 0..MR {
                    let av = vdupq_n_f64(*arow.add(r));
                    for t in 0..4 {
                        acc_v[r][t] = vfmaq_f64(acc_v[r][t], av, bv[t]);
                    }
                }
            }
            for r in 0..MR {
                for t in 0..4 {
                    vst1q_f64(acc[r].as_mut_ptr().add(2 * t), acc_v[r][t]);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn dispatch_names_and_override() {
        assert!(["scalar", "avx2", "neon"].contains(&kernel_name()));
        let ambient = active();
        with_kernel(KernelImpl::Scalar, || {
            assert_eq!(active(), KernelImpl::Scalar);
            assert_eq!(kernel_name(), "scalar");
        });
        assert_eq!(active(), ambient, "override must restore");
        assert!(!detected_features().is_empty());
    }

    #[test]
    fn precision_parse_roundtrip() {
        assert_eq!(Precision::parse("f32"), Ok(Precision::F32));
        assert_eq!(Precision::parse("double"), Ok(Precision::F64));
        assert!(Precision::parse("f16").is_err());
        assert_eq!(Precision::default().name(), "f64");
    }

    /// The dispatched micro-kernel agrees with the scalar one on random
    /// packed panels to FMA-contraction tolerance (bitwise when the
    /// ambient dispatch *is* scalar).
    #[test]
    fn micro_kernel_dispatch_matches_scalar() {
        let mut r = Pcg64::seed(0xD15);
        for &kc in &[1usize, 2, 7, 64, 256] {
            let a: Vec<f64> = (0..kc * MR).map(|_| r.normal()).collect();
            let b: Vec<f64> = (0..kc * NR).map(|_| r.normal()).collect();
            let mut want = [[0.25f64; NR]; MR];
            micro_kernel_scalar(kc, &a, &b, &mut want);
            let mut got = [[0.25f64; NR]; MR];
            micro_kernel(active(), kc, &a, &b, &mut got);
            for rr in 0..MR {
                for t in 0..NR {
                    let (w, g) = (want[rr][t], got[rr][t]);
                    assert!(
                        (w - g).abs() <= 1e-12 * (1.0 + w.abs()),
                        "kc={kc} [{rr}][{t}]: scalar {w} vs dispatch {g}"
                    );
                }
            }
        }
    }

    /// Lane-parallel exp vs the scalar reference over the full reduction
    /// range, including both saturation regimes (exact 0/∞ agreement).
    #[test]
    fn map_exp_matches_scalar_over_reduction_range() {
        let mut xs: Vec<f64> = Vec::new();
        let mut x = -740.0;
        while x < 60.0 {
            xs.push(x);
            x += 0.193;
        }
        xs.extend_from_slice(&[-1e9, -708.0, -708.0001, 709.0, 709.0001, 1e9, 0.0]);
        let mut got = xs.clone();
        map_exp(active(), &mut got);
        for (&xi, &gi) in xs.iter().zip(got.iter()) {
            let want = exp_fast(xi);
            if want == 0.0 || want.is_infinite() {
                assert_eq!(gi, want, "saturation at {xi}");
            } else {
                let rel = ((gi - want) / want).abs();
                assert!(rel < 1e-12, "x={xi}: {gi} vs {want} (rel {rel})");
            }
        }
    }

    /// Each element's value is independent of its position in the slice:
    /// mapping one element at a time reproduces the batch map bitwise.
    /// (This is what keeps the symmetric assembly fast path — which maps
    /// row *suffixes* — bitwise equal to rectangular assembly.)
    #[test]
    fn map_exp_is_position_independent() {
        let xs: Vec<f64> = (0..23).map(|i| -0.37 * i as f64).collect();
        let mut batch = xs.clone();
        map_exp(active(), &mut batch);
        for (i, &xi) in xs.iter().enumerate() {
            let mut one = [xi];
            map_exp(active(), &mut one);
            assert_eq!(one[0].to_bits(), batch[i].to_bits(), "element {i}");
        }
        // and for every suffix offset (the symmetric path maps krow[i..])
        for off in 0..xs.len() {
            let mut suffix = xs[off..].to_vec();
            map_exp(active(), &mut suffix);
            for (k, v) in suffix.iter().enumerate() {
                assert_eq!(v.to_bits(), batch[off + k].to_bits(), "offset {off}+{k}");
            }
        }
    }

    #[test]
    fn exp_f32_accuracy_and_saturation() {
        let mut worst = 0.0f64;
        let mut x = -87.0f32;
        while x < 88.0 {
            let got = exp_fast_f32(x) as f64;
            let want = (x as f64).exp();
            worst = worst.max(((got - want) / want).abs());
            x += 0.0137;
        }
        assert!(worst < 2e-7, "f32 exp relative error {worst}");
        assert_eq!(exp_fast_f32(-100.0), 0.0);
        assert_eq!(exp_fast_f32(100.0), f32::INFINITY);
        assert_eq!(exp_fast_f32(0.0), 1.0);
    }

    #[test]
    fn map_exp_f32_matches_scalar_and_positions() {
        let xs: Vec<f32> = (0..37).map(|i| -0.61 * i as f32 + 3.0).collect();
        let mut batch = xs.clone();
        map_exp_f32(active(), &mut batch);
        for (i, &xi) in xs.iter().enumerate() {
            let mut one = [xi];
            map_exp_f32(active(), &mut one);
            assert_eq!(one[0].to_bits(), batch[i].to_bits(), "element {i}");
            let want = exp_fast_f32(xi) as f64;
            let rel = ((batch[i] as f64 - want) / want.max(1e-30)).abs();
            assert!(rel < 2e-7, "x={xi}: {} vs {want}", batch[i]);
        }
    }
}
