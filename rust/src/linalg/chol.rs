//! Cholesky factorisation and SPD solves.
//!
//! The KRR training paths solve `(K + nλI) α = Y` (exact estimator) and
//! `(SᵀK²S + nλ SᵀKS) θ = SᵀKY` (sketched estimator, paper eq. 3); both
//! matrices are symmetric positive-definite. We factor `A = L·Lᵀ` in place
//! and back-substitute.

use super::Matrix;

/// Lower-triangular Cholesky factor `L` with `A = L·Lᵀ`.
#[derive(Clone, Debug)]
pub struct CholFactor {
    l: Matrix,
}

/// Factor a symmetric positive-definite matrix. Returns `None` when a pivot
/// is non-positive (matrix not PD to working precision) — callers either
/// bump the ridge or surface the failure.
pub fn chol_factor(a: &Matrix) -> Option<CholFactor> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "chol: square required");
    let mut l = a.clone();
    for j in 0..n {
        // diagonal
        let mut d = l[(j, j)];
        for p in 0..j {
            let v = l[(j, p)];
            d -= v * v;
        }
        if d <= 0.0 || !d.is_finite() {
            return None;
        }
        let dj = d.sqrt();
        l[(j, j)] = dj;
        let inv = 1.0 / dj;
        // column below the diagonal. Rows i and j are both contiguous in
        // row-major storage; 4 accumulators break the FMA reduction
        // dependency chain (§Perf: ~2.5 → ~4 gflop/s on the 256 case).
        let (head, tail) = l.data_mut().split_at_mut((j + 1) * n);
        let jrow = &head[j * n..j * n + j];
        for (off, trow) in tail.chunks_mut(n).enumerate() {
            let i = j + 1 + off;
            let _ = i;
            let irow = &trow[..j];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
            let mut p = 0;
            while p + 4 <= j {
                s0 += irow[p] * jrow[p];
                s1 += irow[p + 1] * jrow[p + 1];
                s2 += irow[p + 2] * jrow[p + 2];
                s3 += irow[p + 3] * jrow[p + 3];
                p += 4;
            }
            let mut s = s0 + s1 + s2 + s3;
            while p < j {
                s += irow[p] * jrow[p];
                p += 1;
            }
            trow[j] = (trow[j] - s) * inv;
        }
    }
    // zero the strict upper triangle so `l` is exactly L
    for i in 0..n {
        for j in (i + 1)..n {
            l[(i, j)] = 0.0;
        }
    }
    Some(CholFactor { l })
}

impl CholFactor {
    /// Order of the factor.
    pub fn n(&self) -> usize {
        self.l.rows()
    }

    /// Access the lower-triangular factor.
    pub fn l(&self) -> &Matrix {
        &self.l
    }

    /// Solve `A x = b` via forward + backward substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(b.len(), n);
        // forward: L y = b
        let mut y = b.to_vec();
        for i in 0..n {
            let row = self.l.row(i);
            let mut s = y[i];
            for p in 0..i {
                s -= row[p] * y[p];
            }
            y[i] = s / row[i];
        }
        // backward: Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = y[i];
            for p in (i + 1)..n {
                s -= self.l[(p, i)] * y[p];
            }
            y[i] = s / self.l[(i, i)];
        }
        y
    }

    /// Solve with a matrix right-hand side (column-wise).
    pub fn solve_mat(&self, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(b.rows(), b.cols());
        for j in 0..b.cols() {
            let col = b.col(j);
            let x = self.solve(&col);
            for i in 0..b.rows() {
                out[(i, j)] = x[i];
            }
        }
        out
    }

    /// log-determinant of `A` (twice the log-det of L) — used by diagnostics.
    pub fn logdet(&self) -> f64 {
        (0..self.n()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// `A⁻¹` explicitly (only for small diagnostic matrices).
    pub fn inverse(&self) -> Matrix {
        self.solve_mat(&Matrix::eye(self.n()))
    }
}

/// One-shot SPD solve.
pub fn chol_solve(a: &Matrix, b: &[f64]) -> Option<Vec<f64>> {
    chol_factor(a).map(|f| f.solve(b))
}

/// One-shot SPD solve with matrix RHS.
pub fn chol_solve_many(a: &Matrix, b: &Matrix) -> Option<Matrix> {
    chol_factor(a).map(|f| f.solve_mat(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, syrk_at_a};
    use crate::rng::Pcg64;

    fn random_spd(r: &mut Pcg64, n: usize) -> Matrix {
        let a = Matrix::from_fn(n + 3, n, |_, _| r.normal());
        let mut g = syrk_at_a(&a);
        g.add_diag(0.5); // well-conditioned
        g
    }

    #[test]
    fn factor_reconstructs() {
        let mut r = Pcg64::seed(31);
        let a = random_spd(&mut r, 12);
        let f = chol_factor(&a).unwrap();
        let rec = matmul(f.l(), &f.l().transpose());
        for i in 0..12 {
            for j in 0..12 {
                assert!((rec[(i, j)] - a[(i, j)]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn solve_matches_direct() {
        let mut r = Pcg64::seed(32);
        let a = random_spd(&mut r, 20);
        let b: Vec<f64> = (0..20).map(|_| r.normal()).collect();
        let x = chol_solve(&a, &b).unwrap();
        let back = a.matvec(&x);
        for (u, v) in back.iter().zip(b.iter()) {
            assert!((u - v).abs() < 1e-8, "{u} vs {v}");
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(chol_factor(&a).is_none());
    }

    #[test]
    fn solve_mat_multiple_rhs() {
        let mut r = Pcg64::seed(33);
        let a = random_spd(&mut r, 8);
        let b = Matrix::from_fn(8, 3, |_, _| r.normal());
        let x = chol_solve_many(&a, &b).unwrap();
        let back = matmul(&a, &x);
        for i in 0..8 {
            for j in 0..3 {
                assert!((back[(i, j)] - b[(i, j)]).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn logdet_identity_zero() {
        let f = chol_factor(&Matrix::eye(5)).unwrap();
        assert!(f.logdet().abs() < 1e-12);
    }

    #[test]
    fn inverse_times_a_is_identity() {
        let mut r = Pcg64::seed(34);
        let a = random_spd(&mut r, 6);
        let inv = chol_factor(&a).unwrap().inverse();
        let id = matmul(&a, &inv);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((id[(i, j)] - want).abs() < 1e-8);
            }
        }
    }
}
