//! Cholesky factorisation and SPD solves.
//!
//! The KRR training paths solve `(K + nλI) α = Y` (exact estimator) and
//! `(SᵀK²S + nλ SᵀKS) θ = SᵀKY` (sketched estimator, paper eq. 3); both
//! matrices are symmetric positive-definite. We factor `A = L·Lᵀ` in place
//! and back-substitute.

use super::Matrix;

/// Lower-triangular Cholesky factor `L` with `A = L·Lᵀ`.
#[derive(Clone, Debug)]
pub struct CholFactor {
    l: Matrix,
}

/// Factor a symmetric positive-definite matrix. Returns `None` when a pivot
/// is non-positive (matrix not PD to working precision) — callers either
/// bump the ridge or surface the failure.
pub fn chol_factor(a: &Matrix) -> Option<CholFactor> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "chol: square required");
    let mut l = a.clone();
    for j in 0..n {
        // diagonal
        let mut d = l[(j, j)];
        for p in 0..j {
            let v = l[(j, p)];
            d -= v * v;
        }
        if d <= 0.0 || !d.is_finite() {
            return None;
        }
        let dj = d.sqrt();
        l[(j, j)] = dj;
        let inv = 1.0 / dj;
        // column below the diagonal. Rows i and j are both contiguous in
        // row-major storage; 4 accumulators break the FMA reduction
        // dependency chain (§Perf: ~2.5 → ~4 gflop/s on the 256 case).
        let (head, tail) = l.data_mut().split_at_mut((j + 1) * n);
        let jrow = &head[j * n..j * n + j];
        for (off, trow) in tail.chunks_mut(n).enumerate() {
            let i = j + 1 + off;
            let _ = i;
            let irow = &trow[..j];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
            let mut p = 0;
            while p + 4 <= j {
                s0 += irow[p] * jrow[p];
                s1 += irow[p + 1] * jrow[p + 1];
                s2 += irow[p + 2] * jrow[p + 2];
                s3 += irow[p + 3] * jrow[p + 3];
                p += 4;
            }
            let mut s = s0 + s1 + s2 + s3;
            while p < j {
                s += irow[p] * jrow[p];
                p += 1;
            }
            trow[j] = (trow[j] - s) * inv;
        }
    }
    // zero the strict upper triangle so `l` is exactly L
    for i in 0..n {
        for j in (i + 1)..n {
            l[(i, j)] = 0.0;
        }
    }
    Some(CholFactor { l })
}

impl CholFactor {
    /// Order of the factor.
    pub fn n(&self) -> usize {
        self.l.rows()
    }

    /// Access the lower-triangular factor.
    pub fn l(&self) -> &Matrix {
        &self.l
    }

    /// Solve `A x = b` via forward + backward substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(b.len(), n);
        // forward: L y = b
        let mut y = b.to_vec();
        for i in 0..n {
            let row = self.l.row(i);
            let mut s = y[i];
            for p in 0..i {
                s -= row[p] * y[p];
            }
            y[i] = s / row[i];
        }
        // backward: Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = y[i];
            for p in (i + 1)..n {
                s -= self.l[(p, i)] * y[p];
            }
            y[i] = s / self.l[(i, i)];
        }
        y
    }

    /// Solve with a matrix right-hand side (column-wise).
    pub fn solve_mat(&self, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(b.rows(), b.cols());
        for j in 0..b.cols() {
            let col = b.col(j);
            let x = self.solve(&col);
            for i in 0..b.rows() {
                out[(i, j)] = x[i];
            }
        }
        out
    }

    /// log-determinant of `A` (twice the log-det of L) — used by diagnostics.
    pub fn logdet(&self) -> f64 {
        (0..self.n()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// `A⁻¹` explicitly (only for small diagnostic matrices).
    pub fn inverse(&self) -> Matrix {
        self.solve_mat(&Matrix::eye(self.n()))
    }

    /// Diagonal of `A⁻¹` without forming the inverse: with `A = LLᵀ`,
    /// `(A⁻¹)ᵢᵢ = eᵢᵀL⁻ᵀL⁻¹eᵢ = ‖L⁻¹eᵢ‖²`, one *forward* solve per
    /// column. The solve for `eᵢ` starts at row `i` (everything above is
    /// zero), so the total is `O(n³/6)` — a third of the
    /// [`inverse`](Self::inverse)-then-read-the-diagonal route's forward
    /// + backward sweeps — and the working set is one n-vector instead of
    /// a second n×n matrix. This is what ridge leverage scores consume
    /// (`leverage::exact_scores`).
    pub fn inv_diag(&self) -> Vec<f64> {
        let n = self.n();
        let mut out = vec![0.0; n];
        let mut z = vec![0.0; n];
        for i in 0..n {
            // forward solve L z = eᵢ; z[j] = 0 for j < i by triangularity
            z[i] = 1.0 / self.l[(i, i)];
            let mut s2 = z[i] * z[i];
            for r in (i + 1)..n {
                let row = self.l.row(r);
                let mut s = 0.0;
                for (lv, zv) in row[i..r].iter().zip(z[i..r].iter()) {
                    s -= lv * zv;
                }
                let zr = s / row[r];
                z[r] = zr;
                s2 += zr * zr;
            }
            out[i] = s2;
        }
        out
    }

    /// Scale the factored matrix: `A → α²·A` via `L → α·L`. The
    /// incremental accumulation engine uses this when appending a sketch
    /// term rescales all earlier terms by `α = √(m/m′) < 1`.
    pub fn scale(&mut self, alpha: f64) {
        assert!(alpha > 0.0 && alpha.is_finite(), "chol scale: alpha > 0");
        for v in self.l.data_mut().iter_mut() {
            *v *= alpha;
        }
    }

    /// Givens-style update sweep for `A → A + work·workᵀ`, starting at
    /// column `start` (entries of `work` before `start` must be zero).
    fn update_from(&mut self, work: &mut [f64], start: usize) {
        let n = self.n();
        for k in start..n {
            let wk = work[k];
            if wk == 0.0 {
                // rotation is the identity; nothing to fold
                continue;
            }
            let lkk = self.l[(k, k)];
            let r = (lkk * lkk + wk * wk).sqrt();
            let c = r / lkk;
            let s = wk / lkk;
            self.l[(k, k)] = r;
            for i in (k + 1)..n {
                let lik = (self.l[(i, k)] + s * work[i]) / c;
                work[i] = c * work[i] - s * lik;
                self.l[(i, k)] = lik;
            }
        }
    }

    /// Rank-1 update `A → A + v·vᵀ` in `O(n²)` (LINPACK `dchud`-style
    /// sweep) — always succeeds: adding a PSD term preserves
    /// positive-definiteness.
    pub fn rank1_update(&mut self, v: &[f64]) {
        assert_eq!(v.len(), self.n(), "rank1_update: dim");
        let mut work = v.to_vec();
        self.update_from(&mut work, 0);
    }

    /// Rank-1 downdate `A → A − v·vᵀ` in `O(n²)` (hyperbolic-rotation
    /// sweep). Returns `false` — leaving the factor *unchanged* — when the
    /// downdated matrix is not positive-definite to working precision;
    /// callers fall back to re-factorisation (or reject the downdate).
    pub fn rank1_downdate(&mut self, v: &[f64]) -> bool {
        // Chaos seam: an injected failure reports "not PD" without
        // touching the factor — exactly the contract of a real
        // precision-loss failure, so callers' recovery ladders
        // (diag_update retry, jitter refactorisation) are exercised
        // end to end by tests/chaos.rs.
        if crate::util::fault::hit("chol.downdate") {
            return false;
        }
        let n = self.n();
        assert_eq!(v.len(), n, "rank1_downdate: dim");
        let backup = self.l.clone();
        let mut work = v.to_vec();
        for k in 0..n {
            let wk = work[k];
            if wk == 0.0 {
                continue;
            }
            let lkk = self.l[(k, k)];
            let d2 = lkk * lkk - wk * wk;
            if d2 <= 0.0 || !d2.is_finite() {
                self.l = backup;
                return false;
            }
            let r = d2.sqrt();
            let c = r / lkk;
            let s = wk / lkk;
            self.l[(k, k)] = r;
            for i in (k + 1)..n {
                let lik = (self.l[(i, k)] - s * work[i]) / c;
                work[i] = c * work[i] - s * lik;
                self.l[(i, k)] = lik;
            }
        }
        true
    }

    /// Rank-k update/downdate `A → A + Σᵢ σᵢ·vᵢvᵢᵀ` with `σᵢ ∈ {+1, −1}`
    /// (`vᵢ` = columns of `cols`; a zero `σᵢ` skips its column). Updates
    /// are applied before downdates so every intermediate matrix stays PD
    /// whenever the final one is (each intermediate equals the final
    /// matrix plus a PSD sum of the remaining downdates). Returns `false`
    /// — restoring the original factor — if a downdate still loses
    /// positive-definiteness (the final matrix itself is not PD to working
    /// precision); callers then re-factorise with jitter.
    pub fn rank_update(&mut self, cols: &Matrix, sigma: &[f64]) -> bool {
        let n = self.n();
        assert_eq!(cols.rows(), n, "rank_update: rows");
        assert_eq!(cols.cols(), sigma.len(), "rank_update: sigma len");
        let backup = self.l.clone();
        for (j, &s) in sigma.iter().enumerate() {
            if s > 0.0 {
                self.rank1_update(&cols.col(j));
            }
        }
        for (j, &s) in sigma.iter().enumerate() {
            if s < 0.0 && !self.rank1_downdate(&cols.col(j)) {
                self.l = backup;
                return false;
            }
        }
        true
    }

    /// Diagonal jitter update `A → A + ε·I` applied directly to the factor
    /// (n sparse rank-1 updates with `√ε·eₖ`, each starting its sweep at
    /// `k`). Costs `O(n³/3)` — same order as re-factorising — but needs
    /// only `L`: the adaptive KRR loop uses it when a rank-update's
    /// downdates lose positive-definiteness by a numerical hair, bumping
    /// the factored system and retrying before paying for a rebuild of
    /// `A` and a fresh factorisation.
    pub fn diag_update(&mut self, eps: f64) {
        assert!(eps >= 0.0 && eps.is_finite(), "diag_update: eps >= 0");
        if eps == 0.0 {
            return;
        }
        let n = self.n();
        let se = eps.sqrt();
        let mut work = vec![0.0; n];
        for k in 0..n {
            for w in work.iter_mut() {
                *w = 0.0;
            }
            work[k] = se;
            self.update_from(&mut work, k);
        }
    }
}

/// One-shot SPD solve.
pub fn chol_solve(a: &Matrix, b: &[f64]) -> Option<Vec<f64>> {
    chol_factor(a).map(|f| f.solve(b))
}

/// One-shot SPD solve with matrix RHS.
pub fn chol_solve_many(a: &Matrix, b: &Matrix) -> Option<Matrix> {
    chol_factor(a).map(|f| f.solve_mat(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, syrk_at_a};
    use crate::rng::Pcg64;

    fn random_spd(r: &mut Pcg64, n: usize) -> Matrix {
        let a = Matrix::from_fn(n + 3, n, |_, _| r.normal());
        let mut g = syrk_at_a(&a);
        g.add_diag(0.5); // well-conditioned
        g
    }

    #[test]
    fn factor_reconstructs() {
        let mut r = Pcg64::seed(31);
        let a = random_spd(&mut r, 12);
        let f = chol_factor(&a).unwrap();
        let rec = matmul(f.l(), &f.l().transpose());
        for i in 0..12 {
            for j in 0..12 {
                assert!((rec[(i, j)] - a[(i, j)]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn solve_matches_direct() {
        let mut r = Pcg64::seed(32);
        let a = random_spd(&mut r, 20);
        let b: Vec<f64> = (0..20).map(|_| r.normal()).collect();
        let x = chol_solve(&a, &b).unwrap();
        let back = a.matvec(&x);
        for (u, v) in back.iter().zip(b.iter()) {
            assert!((u - v).abs() < 1e-8, "{u} vs {v}");
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(chol_factor(&a).is_none());
    }

    #[test]
    fn solve_mat_multiple_rhs() {
        let mut r = Pcg64::seed(33);
        let a = random_spd(&mut r, 8);
        let b = Matrix::from_fn(8, 3, |_, _| r.normal());
        let x = chol_solve_many(&a, &b).unwrap();
        let back = matmul(&a, &x);
        for i in 0..8 {
            for j in 0..3 {
                assert!((back[(i, j)] - b[(i, j)]).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn logdet_identity_zero() {
        let f = chol_factor(&Matrix::eye(5)).unwrap();
        assert!(f.logdet().abs() < 1e-12);
    }

    fn outer(v: &[f64]) -> Matrix {
        Matrix::from_fn(v.len(), v.len(), |i, j| v[i] * v[j])
    }

    fn assert_factors_close(a: &CholFactor, b: &CholFactor, tol: f64, what: &str) {
        assert_eq!(a.n(), b.n());
        for i in 0..a.n() {
            for j in 0..=i {
                assert!(
                    (a.l()[(i, j)] - b.l()[(i, j)]).abs() < tol,
                    "{what} ({i},{j}): {} vs {}",
                    a.l()[(i, j)],
                    b.l()[(i, j)]
                );
            }
        }
    }

    /// Property: rank-1 update matches full re-factorisation of `A + vvᵀ`
    /// (the Cholesky factor of a PD matrix is unique, so factors compare
    /// entrywise).
    #[test]
    fn rank1_update_matches_refactorisation() {
        for seed in 0..8u64 {
            let mut r = Pcg64::seed(0xc401 + seed);
            let n = 4 + (seed as usize % 9);
            let a = random_spd(&mut r, n);
            let v: Vec<f64> = (0..n).map(|_| r.normal()).collect();
            let mut up = chol_factor(&a).unwrap();
            up.rank1_update(&v);
            let mut plus = a.clone();
            plus.axpy(1.0, &outer(&v));
            let re = chol_factor(&plus).unwrap();
            assert_factors_close(&up, &re, 1e-8, "rank1 update");
        }
    }

    /// Property: downdating the update recovers the original factor.
    #[test]
    fn rank1_downdate_matches_refactorisation() {
        for seed in 0..8u64 {
            let mut r = Pcg64::seed(0xc402 + seed);
            let n = 4 + (seed as usize % 9);
            let a = random_spd(&mut r, n);
            let v: Vec<f64> = (0..n).map(|_| r.normal()).collect();
            let mut plus = a.clone();
            plus.axpy(1.0, &outer(&v));
            let mut down = chol_factor(&plus).unwrap();
            assert!(down.rank1_downdate(&v), "downdate must succeed");
            let re = chol_factor(&a).unwrap();
            assert_factors_close(&down, &re, 1e-7, "rank1 downdate");
        }
    }

    #[test]
    fn failed_downdate_leaves_factor_unchanged() {
        let mut r = Pcg64::seed(0xc403);
        let a = random_spd(&mut r, 7);
        let mut f = chol_factor(&a).unwrap();
        let before = f.l().clone();
        // v far too large: A − vvᵀ is indefinite
        let v: Vec<f64> = (0..7).map(|_| 100.0 + r.uniform()).collect();
        assert!(!f.rank1_downdate(&v));
        assert_eq!(f.l().data(), before.data(), "factor must be restored");
        // and the factor still solves the original system
        let b: Vec<f64> = (0..7).map(|_| r.normal()).collect();
        let x = f.solve(&b);
        let back = a.matvec(&x);
        for (u, w) in back.iter().zip(b.iter()) {
            assert!((u - w).abs() < 1e-8);
        }
    }

    /// Property: mixed rank-k up/down-date matches re-factorisation of
    /// `A + Σ σᵢvᵢvᵢᵀ`.
    #[test]
    fn rank_k_update_matches_refactorisation() {
        for seed in 0..6u64 {
            let mut r = Pcg64::seed(0xc404 + seed);
            let n = 6 + (seed as usize % 5);
            let k = 3;
            let a = random_spd(&mut r, n);
            // keep downdate vectors small so the result stays PD
            let cols = Matrix::from_fn(n, k, |_, j| r.normal() * if j == 1 { 0.05 } else { 1.0 });
            let sigma = [1.0, -1.0, 1.0];
            let mut target = a.clone();
            for (j, &s) in sigma.iter().enumerate() {
                target.axpy(s, &outer(&cols.col(j)));
            }
            let mut f = chol_factor(&a).unwrap();
            assert!(f.rank_update(&cols, &sigma), "rank-k must succeed");
            let re = chol_factor(&target).unwrap();
            assert_factors_close(&f, &re, 1e-7, "rank-k update");
        }
    }

    #[test]
    fn rank_update_zero_sigma_skips_column() {
        let mut r = Pcg64::seed(0xc407);
        let a = random_spd(&mut r, 6);
        let cols = Matrix::from_fn(6, 2, |_, _| r.normal());
        let mut f = chol_factor(&a).unwrap();
        // σ = 0 must be a no-op for its column, not a downdate
        assert!(f.rank_update(&cols, &[1.0, 0.0]));
        let mut target = a.clone();
        target.axpy(1.0, &outer(&cols.col(0)));
        let re = chol_factor(&target).unwrap();
        assert_factors_close(&f, &re, 1e-8, "zero sigma skip");
    }

    #[test]
    fn scale_matches_scaled_refactorisation() {
        let mut r = Pcg64::seed(0xc405);
        let a = random_spd(&mut r, 9);
        let mut f = chol_factor(&a).unwrap();
        f.scale(2.0);
        let mut a4 = a.clone();
        a4.scale(4.0);
        let re = chol_factor(&a4).unwrap();
        assert_factors_close(&f, &re, 1e-9, "scale");
    }

    /// The jitter-bump path: `diag_update(ε)` equals re-factorising
    /// `A + ε·I`.
    #[test]
    fn diag_update_matches_add_diag_refactorisation() {
        for seed in 0..4u64 {
            let mut r = Pcg64::seed(0xc406 + seed);
            let n = 5 + seed as usize;
            let a = random_spd(&mut r, n);
            let mut f = chol_factor(&a).unwrap();
            f.diag_update(0.37);
            let mut bumped = a.clone();
            bumped.add_diag(0.37);
            let re = chol_factor(&bumped).unwrap();
            assert_factors_close(&f, &re, 1e-8, "diag update");
        }
    }

    /// `inv_diag` agrees with the explicit inverse's diagonal (the route
    /// it replaces in `leverage::exact_scores`).
    #[test]
    fn inv_diag_matches_explicit_inverse() {
        for seed in 0..4u64 {
            let mut r = Pcg64::seed(0xd1a6 + seed);
            let n = 5 + 3 * seed as usize;
            let a = random_spd(&mut r, n);
            let f = chol_factor(&a).unwrap();
            let inv = f.inverse();
            let d = f.inv_diag();
            assert_eq!(d.len(), n);
            for i in 0..n {
                assert!(
                    (d[i] - inv[(i, i)]).abs() < 1e-10 * (1.0 + inv[(i, i)].abs()),
                    "diag {i}: {} vs {}",
                    d[i],
                    inv[(i, i)]
                );
            }
        }
    }

    #[test]
    fn inverse_times_a_is_identity() {
        let mut r = Pcg64::seed(34);
        let a = random_spd(&mut r, 6);
        let inv = chol_factor(&a).unwrap().inverse();
        let id = matmul(&a, &inv);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((id[(i, j)] - want).abs() < 1e-8);
            }
        }
    }
}
