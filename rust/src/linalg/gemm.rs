//! Packed micro-kernel GEMM core.
//!
//! All four dense products — `A·B`, `A·Bᵀ`, `Aᵀ·B` and the SYRK `Aᵀ·A` —
//! dispatch through one register-blocked driver: an `MR×NR` accumulator
//! tile held in locals, the B operand packed once into contiguous `kc×NR`
//! strips, the A panel packed per row-panel task into `MR×kc` strips, and
//! `MC`/`KC`/`NC` cache blocking around the micro-kernel. This is the L3
//! hot path for `K·S_dense`, `SᵀK²S`, the radial kernel-assembly cross
//! term (`kernels::matrix::cross_kernel`) and the partial eigensolver
//! ([`crate::linalg::partial_eigh`]); the sparse accumulation path lives
//! in `sketch::apply`. Before/after medians for the packed rewrite are
//! recorded in EXPERIMENTS.md §Perf (measured by `bench::hotpath`).
//!
//! The micro-kernel itself lives in [`super::simd`]: the packed `MR×kc`
//! / `kc×NR` strip layout produced here is exactly what the AVX2/NEON
//! 4×8 kernels consume, so runtime dispatch swaps the innermost loop
//! without touching the packing or blocking. The dispatch is sampled
//! once per GEMM call on the calling thread and passed into the pool
//! workers by value.
//!
//! Determinism: every element of C is produced inside exactly one
//! row-panel chunk, and within a chunk the loop structure (`kc` blocks
//! outer, micro-tiles inner, `p` ascending inside the micro-kernel) is
//! fixed. Chunk boundaries depend only on the `MC` constant, never on the
//! worker count, so **all** variants are bitwise independent of the
//! thread count — the contract the `at_b`/`syrk` callers rely on. The
//! bitwise guarantee holds *per selected kernel*: scalar and vector
//! kernels contract FMAs differently, so cross-dispatch comparisons are
//! tolerance-based (see `simd` module docs).

use super::simd::{self, MR, NR};
use super::Matrix;
use crate::pool;

/// Row-panel height a single task works on (the `mc` of the blocking
/// scheme; also the parallel split unit, so it must not depend on the
/// worker count).
const MC: usize = 64;
/// k-blocking: one packed `KC×NR` B strip plus the `MC×KC` A panel stay
/// cache-resident while a row panel sweeps its tiles.
const KC: usize = 256;
/// Column blocking inside a task: bounds the active packed-B window to
/// `KC×NC` (L2-sized) while the panel's tiles stream over it.
const NC: usize = 512;
/// Below this `m·n·k` the packing + tile plumbing costs more than it
/// saves; a plain serial i-k-j loop wins (rank-1-ish updates in
/// `IncrementalGram` hit this constantly).
const SMALL_FLOPS: usize = 8192;

/// `C = A · B`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul: inner dims");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let (ad, bd) = (a.data(), b.data());
    gemm_packed(m, k, n, |i, p| ad[i * k + p], |p, j| bd[p * n + j], false)
}

/// `C = A · Bᵀ` (`a`: m×k, `b`: n×k) without materialising the transpose.
pub fn matmul_a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt: inner dims");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let (ad, bd) = (a.data(), b.data());
    gemm_packed(m, k, n, |i, p| ad[i * k + p], |p, j| bd[j * k + p], false)
}

/// **Row-stable** `C = A · Bᵀ`: row `i` of the result is bitwise a
/// function of row `i` of `A` and all of `B` only — independent of how
/// many *other* rows ride along in the same call. The plain variants
/// don't promise this: [`gemm_packed`] routes tiny products
/// (`m·n·k ≤ SMALL_FLOPS`) to a serial i-k-j loop whose accumulation
/// order differs from the packed micro-kernel, so the same row computed
/// in a 1-row call and a 64-row call could differ in the last ulp. This
/// variant always takes the packed path (whose per-row outputs are
/// position-independent: MR strips are zero-padded, the micro-kernel
/// accumulates each lane separately with a fixed `p`-ascending order),
/// which is the serving-plane contract — a prediction must not change
/// with the batch it happened to be coalesced into.
pub fn matmul_a_bt_rowstable(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt_rowstable: inner dims");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    if m == 0 || n == 0 || k == 0 {
        return Matrix::zeros(m, n);
    }
    let (ad, bd) = (a.data(), b.data());
    gemm_packed_full(m, k, n, |i, p| ad[i * k + p], |p, j| bd[j * k + p], false)
}

/// `C = Aᵀ · B` (`a`: k×m, `b`: k×n) without materialising the transpose.
/// Results are bitwise independent of the thread count (see module docs).
pub fn matmul_at_b(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b: inner dims");
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let (ad, bd) = (a.data(), b.data());
    gemm_packed(m, k, n, |i, p| ad[p * m + i], |p, j| bd[p * n + j], false)
}

/// `C = Aᵀ · A` (symmetric rank-k update), computing only micro-tiles that
/// touch the upper triangle and mirroring below the diagonal afterwards
/// with a cache-blocked transposed copy (no scalar `c[(i,j)]` sweep).
/// Used for `SᵀK²S = (KS)ᵀ(KS)`. Bitwise independent of the thread count.
pub fn syrk_at_a(a: &Matrix) -> Matrix {
    let (k, n) = (a.rows(), a.cols());
    let ad = a.data();
    let mut c = gemm_packed(n, k, n, |i, p| ad[p * n + i], |p, j| ad[p * n + j], true);
    mirror_lower_from_upper(&mut c);
    c
}

/// `C = A · Aᵀ` (row-Gram SYRK), upper micro-tiles + mirror — same
/// discipline as [`syrk_at_a`], for the other orientation. This is the
/// symmetric kernel-assembly fast path: `cross_kernel(k, x, x)` feeds its
/// `−2·X·Xᵀ` cross term through it at half the GEMM cost.
pub fn syrk_a_at(a: &Matrix) -> Matrix {
    let mut c = syrk_a_at_upper(a);
    mirror_lower_from_upper(&mut c);
    c
}

/// Upper-triangle-only `A · Aᵀ`: micro-tiles entirely below the diagonal
/// are left zero (tiles straddling it are computed in full). The square
/// kernel-assembly path maps the kernel over `j ≥ i` only and mirrors
/// *after* the transcendental pass, halving that dominant cost — hence
/// the mirror is deferred to the caller.
pub(crate) fn syrk_a_at_upper(a: &Matrix) -> Matrix {
    let (m, k) = (a.rows(), a.cols());
    let ad = a.data();
    gemm_packed(m, k, m, |i, p| ad[i * k + p], |p, j| ad[j * k + p], true)
}

/// The shared packed driver: `C[m×n] += Σ_p a_at(i,p)·b_at(p,j)` with the
/// operands described by index closures (monomorphised per variant, so
/// packing compiles to direct loads). `upper_only` skips micro-tiles that
/// lie entirely below the diagonal (SYRK); the caller mirrors.
fn gemm_packed<FA, FB>(
    m: usize,
    k: usize,
    n: usize,
    a_at: FA,
    b_at: FB,
    upper_only: bool,
) -> Matrix
where
    FA: Fn(usize, usize) -> f64 + Sync,
    FB: Fn(usize, usize) -> f64 + Sync,
{
    if m == 0 || n == 0 || k == 0 {
        return Matrix::zeros(m, n);
    }
    if m * n * k <= SMALL_FLOPS {
        return gemm_small(m, k, n, &a_at, &b_at, upper_only);
    }
    gemm_packed_full(m, k, n, a_at, b_at, upper_only)
}

/// The packed body proper — no small-product shortcut, so the code path
/// (and therefore the per-row accumulation order) is the same at every
/// `m`. Callers guarantee non-zero dims. [`matmul_a_bt_rowstable`] calls
/// this directly; everything else goes through [`gemm_packed`].
fn gemm_packed_full<FA, FB>(
    m: usize,
    k: usize,
    n: usize,
    a_at: FA,
    b_at: FB,
    upper_only: bool,
) -> Matrix
where
    FA: Fn(usize, usize) -> f64 + Sync,
    FB: Fn(usize, usize) -> f64 + Sync,
{
    let n_strips = (n + NR - 1) / NR;
    let n_pad = n_strips * NR;
    // Pack all of B once: per KC block, NR-column strips, k-major inside a
    // strip (NR contiguous values per k step, zero-padded tail columns).
    // Strip s of block kk starts at kk·n_pad + s·kc·NR.
    let mut bpack = vec![0.0f64; k * n_pad];
    {
        let b_at = &b_at;
        pool::scope_chunks(&mut bpack, KC * n_pad, |kb, block| {
            let kk = kb * KC;
            let kc = block.len() / n_pad;
            for s in 0..n_strips {
                let j0 = s * NR;
                let jn = NR.min(n - j0);
                let strip = &mut block[s * kc * NR..(s + 1) * kc * NR];
                for p in 0..kc {
                    let dst = &mut strip[p * NR..(p + 1) * NR];
                    for t in 0..jn {
                        dst[t] = b_at(kk + p, j0 + t);
                    }
                }
            }
        });
    }
    let mut c = Matrix::zeros(m, n);
    let cdat = c.data_mut();
    let a_at = &a_at;
    let bpack = &bpack;
    // Sample the micro-kernel dispatch ONCE on the calling thread (pool
    // workers are fresh threads where a scoped `with_kernel` override
    // would not be visible) and pass it into every worker by value.
    let imp = simd::active();
    pool::scope_chunks(cdat, MC * n, |panel_idx, chunk| {
        let r0 = panel_idx * MC;
        let rows = chunk.len() / n;
        let row_strips = (rows + MR - 1) / MR;
        let mut apack = vec![0.0f64; row_strips * MR * KC.min(k)];
        let mut kk = 0usize;
        while kk < k {
            let kc = KC.min(k - kk);
            // pack the A panel: MR-row strips, k-major inside a strip
            // (MR contiguous values per k step, zero-padded tail rows)
            for rs in 0..row_strips {
                let i0 = rs * MR;
                let rn = MR.min(rows - i0);
                let strip = &mut apack[rs * MR * kc..(rs + 1) * MR * kc];
                for p in 0..kc {
                    let dst = &mut strip[p * MR..(p + 1) * MR];
                    for r in 0..rn {
                        dst[r] = a_at(r0 + i0 + r, kk + p);
                    }
                    for d in dst[rn..].iter_mut() {
                        *d = 0.0;
                    }
                }
            }
            let bblock = &bpack[kk * n_pad..kk * n_pad + kc * n_pad];
            let mut jj = 0usize;
            while jj < n_pad {
                let jend = (jj + NC).min(n_pad);
                for rs in 0..row_strips {
                    let i0 = rs * MR;
                    let rn = MR.min(rows - i0);
                    let gi = r0 + i0; // global top row of this tile
                    let astrip = &apack[rs * MR * kc..(rs + 1) * MR * kc];
                    let mut s = jj / NR;
                    while s * NR < jend {
                        let j0 = s * NR;
                        if upper_only && j0 + NR <= gi {
                            // tile entirely below the diagonal: the mirror
                            // pass fills it from the transpose
                            s += 1;
                            continue;
                        }
                        let bstrip = &bblock[s * kc * NR..(s + 1) * kc * NR];
                        let mut acc = [[0.0f64; NR]; MR];
                        simd::micro_kernel(imp, kc, astrip, bstrip, &mut acc);
                        let jn = NR.min(n - j0);
                        for r in 0..rn {
                            let base = (i0 + r) * n + j0;
                            let crow = &mut chunk[base..base + jn];
                            for (cv, av) in crow.iter_mut().zip(acc[r][..jn].iter()) {
                                *cv += *av;
                            }
                        }
                        s += 1;
                    }
                }
                jj = jend;
            }
            kk += kc;
        }
    });
    c
}

/// Serial i-k-j fallback for tiny products where packing overhead loses.
/// Always scalar (no micro-kernel involved), so tiny products are bitwise
/// identical under every dispatch mode.
fn gemm_small<FA, FB>(
    m: usize,
    k: usize,
    n: usize,
    a_at: &FA,
    b_at: &FB,
    upper_only: bool,
) -> Matrix
where
    FA: Fn(usize, usize) -> f64,
    FB: Fn(usize, usize) -> f64,
{
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let row = c.row_mut(i);
        let j0 = if upper_only { i.min(n) } else { 0 };
        for p in 0..k {
            let av = a_at(i, p);
            if av == 0.0 {
                continue;
            }
            for (j, cv) in row.iter_mut().enumerate().skip(j0) {
                *cv += av * b_at(p, j);
            }
        }
    }
    c
}

/// Mirror the strict upper triangle into the lower one with a cache-blocked
/// transposed copy on the raw buffer — `TB×TB` blocks keep both the source
/// rows and the destination rows resident, unlike a whole-matrix column
/// sweep. Shared by the SYRK variants and the symmetric kernel-assembly
/// fast path (`kernels::matrix::cross_kernel` on `a is b`).
pub(crate) fn mirror_lower_from_upper(c: &mut Matrix) {
    let n = c.rows();
    const TB: usize = 48;
    let d = c.data_mut();
    let mut bi = 0;
    while bi < n {
        let iend = (bi + TB).min(n);
        let mut bj = 0;
        while bj <= bi {
            let jend = (bj + TB).min(n);
            for i in bi..iend {
                let jmax = jend.min(i);
                for j in bj..jmax {
                    d[i * n + j] = d[j * n + i];
                }
            }
            bj += TB;
        }
        bi += TB;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn randm(r: &mut Pcg64, rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| r.normal())
    }

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for p in 0..a.cols() {
                    s += a[(i, p)] * b[(p, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    fn close(a: &Matrix, b: &Matrix, tol: f64) -> bool {
        a.rows() == b.rows()
            && a.cols() == b.cols()
            && a.data()
                .iter()
                .zip(b.data().iter())
                .all(|(x, y)| (x - y).abs() < tol)
    }

    #[test]
    fn matmul_matches_naive_various_shapes() {
        let mut r = Pcg64::seed(21);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 9, 13), (70, 33, 70), (128, 64, 5)] {
            let a = randm(&mut r, m, k);
            let b = randm(&mut r, k, n);
            assert!(close(&matmul(&a, &b), &naive(&a, &b), 1e-9), "{m}x{k}x{n}");
        }
    }

    /// Micro-kernel edge shapes: m < MR, n < NR, k < 4, 1×1, tall-skinny,
    /// KC- and MC-boundary crossings — every variant against the naive
    /// reference.
    #[test]
    fn edge_shapes_all_variants_match_naive() {
        let mut r = Pcg64::seed(25);
        for &(m, k, n) in &[
            (1, 1, 1),    // degenerate
            (3, 2, 5),    // m < MR, k < 4
            (9, 3, 7),    // n < NR, k < 4
            (5, 4, 8),    // exact NR boundary, MR+1 rows
            (4, 300, 9),  // crosses KC = 256, ragged columns
            (3, 2000, 2), // packed path with m < MR AND n < NR tails
            (66, 2, 70),  // packed path with k < 4
            (130, 70, 7), // packed path with n < NR, crosses MC
            (200, 3, 2),  // tall-skinny, tiny k (serial small path)
            (6, 70, 130), // wide, ragged strip tail
            (65, 33, 9),  // crosses the MC row-panel boundary
        ] {
            let a = randm(&mut r, m, k);
            let b = randm(&mut r, k, n);
            assert!(
                close(&matmul(&a, &b), &naive(&a, &b), 1e-9),
                "matmul {m}x{k}x{n}"
            );
            let bt_src = randm(&mut r, n, k);
            assert!(
                close(
                    &matmul_a_bt(&a, &bt_src),
                    &naive(&a, &bt_src.transpose()),
                    1e-9
                ),
                "a_bt {m}x{k}x{n}"
            );
            let at_src = randm(&mut r, k, m);
            assert!(
                close(
                    &matmul_at_b(&at_src, &b),
                    &naive(&at_src.transpose(), &b),
                    1e-9
                ),
                "at_b {m}x{k}x{n}"
            );
            let sy_src = randm(&mut r, k, n);
            let sy = syrk_at_a(&sy_src);
            assert!(
                close(&sy, &naive(&sy_src.transpose(), &sy_src), 1e-9),
                "syrk {k}x{n}"
            );
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(sy[(i, j)], sy[(j, i)], "syrk symmetry {k}x{n}");
                }
            }
        }
    }

    #[test]
    fn at_b_matches() {
        let mut r = Pcg64::seed(22);
        let a = randm(&mut r, 31, 7);
        let b = randm(&mut r, 31, 11);
        assert!(close(&matmul_at_b(&a, &b), &naive(&a.transpose(), &b), 1e-9));
    }

    #[test]
    fn a_bt_matches() {
        let mut r = Pcg64::seed(23);
        let a = randm(&mut r, 13, 9);
        let b = randm(&mut r, 17, 9);
        assert!(close(&matmul_a_bt(&a, &b), &naive(&a, &b.transpose()), 1e-9));
    }

    #[test]
    fn syrk_matches_and_symmetric() {
        let mut r = Pcg64::seed(24);
        let a = randm(&mut r, 40, 12);
        let c = syrk_at_a(&a);
        assert!(close(&c, &naive(&a.transpose(), &a), 1e-9));
        for i in 0..12 {
            for j in 0..12 {
                assert_eq!(c[(i, j)], c[(j, i)]);
            }
        }
    }

    /// `syrk_a_at` matches `A·Aᵀ` via the general path, and its upper
    /// triangle is **bitwise** what `matmul_a_bt(a, a)` produces — the
    /// contract the symmetric kernel-assembly fast path relies on
    /// (skipping below-diagonal tiles must not perturb the kept ones).
    #[test]
    fn syrk_a_at_matches_general_product_bitwise_on_upper() {
        let mut r = Pcg64::seed(26);
        for &(m, k) in &[(1usize, 1usize), (7, 3), (40, 12), (130, 5), (150, 70)] {
            let a = randm(&mut r, m, k);
            let full = matmul_a_bt(&a, &a);
            let sy = syrk_a_at(&a);
            for i in 0..m {
                for j in 0..m {
                    assert_eq!(sy[(i, j)], sy[(j, i)], "symmetry {m}x{k}");
                    if j >= i {
                        assert_eq!(sy[(i, j)], full[(i, j)], "upper bitwise {m}x{k}");
                    }
                }
            }
        }
    }

    #[test]
    fn empty_dims() {
        let a = Matrix::zeros(0, 3);
        let b = Matrix::zeros(3, 4);
        let c = matmul(&a, &b);
        assert_eq!((c.rows(), c.cols()), (0, 4));
        let atb = matmul_at_b(&a, &Matrix::zeros(0, 4));
        assert_eq!((atb.rows(), atb.cols()), (3, 4));
        let s = syrk_at_a(&Matrix::zeros(0, 3));
        assert_eq!((s.rows(), s.cols()), (3, 3));
    }

    /// Scalar vs whatever this host detects (AVX2/NEON, or scalar again):
    /// all four variants over micro-kernel edge shapes — m,n,k sweeping
    /// 1, MR−1, MR, NR+1 and 97 (crosses no blocking boundary evenly).
    /// Scalar and FMA kernels round differently, so this is a tight
    /// relative comparison, **not** bitwise (see `simd` module docs); on
    /// a scalar-only host both runs take the same path and the check is
    /// trivially exact.
    #[test]
    fn scalar_and_simd_dispatch_agree_on_edge_shapes() {
        use super::simd::{with_kernel, KernelImpl};
        let mut r = Pcg64::seed(27);
        let dims = [1usize, MR - 1, MR, NR + 1, 97];
        let rel_close = |x: &Matrix, y: &Matrix| {
            x.data()
                .iter()
                .zip(y.data().iter())
                .all(|(a, b)| (a - b).abs() <= 1e-12 * (1.0 + a.abs().max(b.abs())))
        };
        for &m in &dims {
            for &k in &dims {
                for &n in &dims {
                    let a = randm(&mut r, m, k);
                    let b = randm(&mut r, k, n);
                    let bt = randm(&mut r, n, k);
                    let at = randm(&mut r, k, m);
                    let sc = with_kernel(KernelImpl::Scalar, || {
                        (
                            matmul(&a, &b),
                            matmul_a_bt(&a, &bt),
                            matmul_at_b(&at, &b),
                            syrk_at_a(&b),
                        )
                    });
                    let vc = (
                        matmul(&a, &b),
                        matmul_a_bt(&a, &bt),
                        matmul_at_b(&at, &b),
                        syrk_at_a(&b),
                    );
                    assert!(rel_close(&sc.0, &vc.0), "matmul {m}x{k}x{n}");
                    assert!(rel_close(&sc.1, &vc.1), "a_bt {m}x{k}x{n}");
                    assert!(rel_close(&sc.2, &vc.2), "at_b {m}x{k}x{n}");
                    assert!(rel_close(&sc.3, &vc.3), "syrk {k}x{n}");
                }
            }
        }
    }

    /// The serving contract: a single row pushed through
    /// `matmul_a_bt_rowstable` alone is **bitwise** equal to that row of
    /// the full-batch product, under both dispatch modes and regardless
    /// of which batch position the row occupies. (The plain `matmul_a_bt`
    /// has no such promise — tiny products take the serial shortcut.)
    #[test]
    fn rowstable_a_bt_is_bitwise_batch_invariant() {
        use super::simd::{active, with_kernel, KernelImpl};
        let mut r = Pcg64::seed(0x9003);
        // n·k small enough that a 1-row call would hit SMALL_FLOPS in the
        // plain variant — exactly the case the rowstable path exists for.
        let b = randm(&mut r, 12, 10);
        let batch = randm(&mut r, 37, 10);
        for imp in [KernelImpl::Scalar, active()] {
            with_kernel(imp, || {
                let full = matmul_a_bt_rowstable(&batch, &b);
                for i in [0usize, 1, 5, 36] {
                    let one = Matrix::from_fn(1, 10, |_, j| batch[(i, j)]);
                    let solo = matmul_a_bt_rowstable(&one, &b);
                    for j in 0..12 {
                        assert_eq!(
                            solo[(0, j)].to_bits(),
                            full[(i, j)].to_bits(),
                            "row {i} col {j} under {imp:?}"
                        );
                    }
                }
                // and it agrees numerically with the reference product
                let reference = naive(&batch, &b.transpose());
                assert!(close(&full, &reference, 1e-9), "{imp:?}");
            });
        }
    }

    /// Every element of C is produced inside one fixed-boundary row-panel
    /// chunk, so the parallel split is bitwise identical to the serial
    /// path — for the packed paths of all four variants, under **both**
    /// dispatch modes (forced scalar and whatever this host detects).
    #[test]
    fn at_b_and_syrk_parallel_match_serial_exactly() {
        use super::simd::{active, with_kernel, KernelImpl};
        use crate::pool;
        let _guard = pool::TEST_THREADS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        for imp in [KernelImpl::Scalar, active()] {
            with_kernel(imp, || {
                let mut r = Pcg64::seed(0x9002);
                // > MC output rows so the pool actually splits
                let a = randm(&mut r, 150, 70);
                let b = randm(&mut r, 150, 33);
                let big = randm(&mut r, 90, 130);
                let wide = randm(&mut r, 130, 80);
                let before = pool::num_threads();
                pool::set_num_threads(1);
                let atb_serial = matmul_at_b(&a, &b);
                let syrk_serial = syrk_at_a(&big);
                let mm_serial = matmul(&big, &wide);
                let abt_serial = matmul_a_bt(&big, &wide.transpose());
                pool::set_num_threads(4);
                let atb_par = matmul_at_b(&a, &b);
                let syrk_par = syrk_at_a(&big);
                let mm_par = matmul(&big, &wide);
                let abt_par = matmul_a_bt(&big, &wide.transpose());
                pool::set_num_threads(before);
                assert_eq!(atb_serial.data(), atb_par.data(), "{imp:?}");
                assert_eq!(syrk_serial.data(), syrk_par.data(), "{imp:?}");
                assert_eq!(mm_serial.data(), mm_par.data(), "{imp:?}");
                assert_eq!(abt_serial.data(), abt_par.data(), "{imp:?}");
            });
        }
    }
}
