//! Blocked matrix multiplication kernels.
//!
//! Cache-blocked, `i-k-j` loop order (row-major friendly: the inner loop
//! streams both B's row and C's row), with an optional thread-pool split
//! over row panels. This is the L3 hot path for `K·S_dense`, `SᵀK²S` and
//! the Gaussian-sketch baseline; the sparse accumulation path lives in
//! `sketch::apply`.

use super::Matrix;
use crate::pool;

/// Row-panel height a single task works on. 64 rows × (k ≤ a few thousand)
/// keeps the A-panel in L2 while C stays write-streamed.
const PANEL: usize = 64;
/// k-blocking: the B block of `KB × cols` must stay cache-resident.
const KBLOCK: usize = 256;

/// `C = A · B`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul: inner dims");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    let bdat = b.data();
    let adat = a.data();
    // split C's rows into panels, execute panels on the pool
    let cdat = c.data_mut();
    pool::scope_chunks(cdat, n * PANEL, |panel_idx, chunk| {
        let r0 = panel_idx * PANEL;
        for kk in (0..k).step_by(KBLOCK) {
            let kend = (kk + KBLOCK).min(k);
            for (local_i, crow) in chunk.chunks_mut(n).enumerate() {
                let i = r0 + local_i;
                let arow = &adat[i * k..(i + 1) * k];
                // 4-way k-unroll: one pass over crow consumes four B rows,
                // quartering the C-row read/write traffic (§Perf: 6.7 →
                // see EXPERIMENTS.md for the measured delta).
                let mut p = kk;
                while p + 4 <= kend {
                    let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
                    let b0 = &bdat[p * n..p * n + n];
                    let b1 = &bdat[(p + 1) * n..(p + 1) * n + n];
                    let b2 = &bdat[(p + 2) * n..(p + 2) * n + n];
                    let b3 = &bdat[(p + 3) * n..(p + 3) * n + n];
                    for j in 0..n {
                        crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                    }
                    p += 4;
                }
                while p < kend {
                    let aval = arow[p];
                    if aval != 0.0 {
                        let brow = &bdat[p * n..(p + 1) * n];
                        for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                            *cv += aval * bv;
                        }
                    }
                    p += 1;
                }
            }
        }
    });
    c
}

/// `C = Aᵀ · B` without materialising the transpose, parallelised over
/// row panels of `C`. Each panel streams the rows of `A` and `B` once
/// (p-major inner order), so the per-element accumulation order is
/// identical to the serial loop — results are bitwise independent of the
/// thread count.
pub fn matmul_at_b(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b: inner dims");
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let adat = a.data();
    let bdat = b.data();
    let cdat = c.data_mut();
    pool::scope_chunks(cdat, n * PANEL, |panel_idx, chunk| {
        let r0 = panel_idx * PANEL;
        let rows = chunk.len() / n;
        // C[i,:] += A[p,i] * B[p,:] — stream rows of A and B together.
        for p in 0..k {
            let arow = &adat[p * m..(p + 1) * m];
            let brow = &bdat[p * n..(p + 1) * n];
            for (local_i, crow) in chunk.chunks_mut(n).enumerate().take(rows) {
                let aval = arow[r0 + local_i];
                if aval == 0.0 {
                    continue;
                }
                for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += aval * bv;
                }
            }
        }
    });
    c
}

/// `C = A · Bᵀ` (dot-product form; B's rows are contiguous).
pub fn matmul_a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt: inner dims");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let mut c = Matrix::zeros(m, n);
    let n_cols = n;
    let adat = a.data();
    let bdat = b.data();
    let cdat = c.data_mut();
    pool::scope_chunks(cdat, n_cols * PANEL, |panel_idx, chunk| {
        let r0 = panel_idx * PANEL;
        for (local_i, crow) in chunk.chunks_mut(n_cols).enumerate() {
            let i = r0 + local_i;
            let arow = &adat[i * k..(i + 1) * k];
            for (j, cv) in crow.iter_mut().enumerate() {
                let brow = &bdat[j * k..(j + 1) * k];
                let mut s = 0.0;
                for (x, y) in arow.iter().zip(brow.iter()) {
                    s += x * y;
                }
                *cv = s;
            }
        }
    });
    c
}

/// `C = Aᵀ · A` (symmetric rank-k update), computing only the upper
/// triangle and mirroring, parallelised over row panels of `C`. Used for
/// `SᵀK²S = (KS)ᵀ(KS)`. The p-major accumulation order matches the serial
/// loop exactly, so results are bitwise independent of the thread count.
pub fn syrk_at_a(a: &Matrix) -> Matrix {
    let (k, n) = (a.rows(), a.cols());
    let mut c = Matrix::zeros(n, n);
    if n == 0 || k == 0 {
        return c;
    }
    let adat = a.data();
    let cdat = c.data_mut();
    pool::scope_chunks(cdat, n * PANEL, |panel_idx, chunk| {
        let r0 = panel_idx * PANEL;
        let rows = chunk.len() / n;
        for p in 0..k {
            let row = &adat[p * n..(p + 1) * n];
            for (local_i, crow) in chunk.chunks_mut(n).enumerate().take(rows) {
                let i = r0 + local_i;
                let v = row[i];
                if v == 0.0 {
                    continue;
                }
                for j in i..n {
                    crow[j] += v * row[j];
                }
            }
        }
    });
    // mirror
    for i in 0..n {
        for j in (i + 1)..n {
            let v = c[(i, j)];
            c[(j, i)] = v;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn randm(r: &mut Pcg64, rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| r.normal())
    }

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for p in 0..a.cols() {
                    s += a[(i, p)] * b[(p, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    fn close(a: &Matrix, b: &Matrix, tol: f64) -> bool {
        a.rows() == b.rows()
            && a.cols() == b.cols()
            && a.data()
                .iter()
                .zip(b.data().iter())
                .all(|(x, y)| (x - y).abs() < tol)
    }

    #[test]
    fn matmul_matches_naive_various_shapes() {
        let mut r = Pcg64::seed(21);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 9, 13), (70, 33, 70), (128, 64, 5)] {
            let a = randm(&mut r, m, k);
            let b = randm(&mut r, k, n);
            assert!(close(&matmul(&a, &b), &naive(&a, &b), 1e-9), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn at_b_matches() {
        let mut r = Pcg64::seed(22);
        let a = randm(&mut r, 31, 7);
        let b = randm(&mut r, 31, 11);
        assert!(close(&matmul_at_b(&a, &b), &naive(&a.transpose(), &b), 1e-9));
    }

    #[test]
    fn a_bt_matches() {
        let mut r = Pcg64::seed(23);
        let a = randm(&mut r, 13, 9);
        let b = randm(&mut r, 17, 9);
        assert!(close(&matmul_a_bt(&a, &b), &naive(&a, &b.transpose()), 1e-9));
    }

    #[test]
    fn syrk_matches_and_symmetric() {
        let mut r = Pcg64::seed(24);
        let a = randm(&mut r, 40, 12);
        let c = syrk_at_a(&a);
        assert!(close(&c, &naive(&a.transpose(), &a), 1e-9));
        for i in 0..12 {
            for j in 0..12 {
                assert_eq!(c[(i, j)], c[(j, i)]);
            }
        }
    }

    #[test]
    fn empty_dims() {
        let a = Matrix::zeros(0, 3);
        let b = Matrix::zeros(3, 4);
        let c = matmul(&a, &b);
        assert_eq!((c.rows(), c.cols()), (0, 4));
        let atb = matmul_at_b(&a, &Matrix::zeros(0, 4));
        assert_eq!((atb.rows(), atb.cols()), (3, 4));
        let s = syrk_at_a(&Matrix::zeros(0, 3));
        assert_eq!((s.rows(), s.cols()), (3, 3));
    }

    /// The p-major accumulation order makes the parallel row-panel split
    /// bitwise identical to the serial path.
    #[test]
    fn at_b_and_syrk_parallel_match_serial_exactly() {
        use crate::pool;
        let _guard = pool::TEST_THREADS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let mut r = Pcg64::seed(0x9002);
        // > PANEL output rows so the pool actually splits
        let a = randm(&mut r, 150, 70);
        let b = randm(&mut r, 150, 33);
        let big = randm(&mut r, 90, 130);
        let before = pool::num_threads();
        pool::set_num_threads(1);
        let atb_serial = matmul_at_b(&a, &b);
        let syrk_serial = syrk_at_a(&big);
        pool::set_num_threads(4);
        let atb_par = matmul_at_b(&a, &b);
        let syrk_par = syrk_at_a(&big);
        pool::set_num_threads(before);
        assert_eq!(atb_serial.data(), atb_par.data());
        assert_eq!(syrk_serial.data(), syrk_par.data());
    }
}
