//! Symmetric eigendecomposition: Householder tridiagonalisation followed by
//! implicit-shift QL with eigenvector accumulation (Numerical-Recipes-style
//! `tred2`/`tqli` scheme, re-derived for row-major storage).
//!
//! This is the backbone of the paper's *diagnostics*: the K-satisfiability
//! check (Definition 3) needs `U₁`, `Σ` of the empirical kernel matrix, the
//! incoherence `M` (Theorem 8) needs `Ψ_δ = [Σ(Σ+nδI)]^{-1/2} Uᵀ`, and the
//! statistical dimension is a spectral sum. It is *not* on the training hot
//! path (KRR solves go through Cholesky).

use super::Matrix;

/// Result of [`eigh`]: `a = V · diag(w) · Vᵀ`, eigenvalues ascending.
#[derive(Clone, Debug)]
pub struct EighResult {
    /// Eigenvalues in ascending order.
    pub w: Vec<f64>,
    /// Orthonormal eigenvectors; column `j` pairs with `w[j]`.
    pub v: Matrix,
}

impl EighResult {
    /// Eigenvalues in descending order with matching eigenvector columns
    /// (the paper's convention σ₁ ≥ σ₂ ≥ …).
    pub fn descending(&self) -> (Vec<f64>, Matrix) {
        let n = self.w.len();
        let mut w = vec![0.0; n];
        let mut v = Matrix::zeros(n, n);
        for j in 0..n {
            let src = n - 1 - j;
            w[j] = self.w[src];
            for i in 0..n {
                v[(i, j)] = self.v[(i, src)];
            }
        }
        (w, v)
    }
}

/// Eigendecomposition of a symmetric matrix. Input asymmetry beyond
/// round-off is the caller's bug (use `Matrix::symmetrize`).
pub fn eigh(a: &Matrix) -> EighResult {
    let n = a.rows();
    assert_eq!(n, a.cols(), "eigh: square required");
    if n == 0 {
        return EighResult {
            w: vec![],
            v: Matrix::zeros(0, 0),
        };
    }
    let mut z = a.clone();
    let mut d = vec![0.0; n]; // diagonal
    let mut e = vec![0.0; n]; // sub-diagonal
    tred2(&mut z, &mut d, &mut e);
    tqli(&mut d, &mut e, &mut z);
    // sort ascending, permuting eigenvector columns
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| d[i].partial_cmp(&d[j]).unwrap());
    let w: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    let mut v = Matrix::zeros(n, n);
    for (jnew, &jold) in order.iter().enumerate() {
        for i in 0..n {
            v[(i, jnew)] = z[(i, jold)];
        }
    }
    EighResult { w, v }
}

/// Householder reduction to tridiagonal form; `z` is overwritten with the
/// accumulated orthogonal transform Q (so the original A = Q·T·Qᵀ).
fn tred2(z: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += z[(i, k)].abs();
            }
            if scale == 0.0 {
                e[i] = z[(i, l)];
            } else {
                for k in 0..=l {
                    z[(i, k)] /= scale;
                    h += z[(i, k)] * z[(i, k)];
                }
                let mut f = z[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[(i, l)] = f - g;
                f = 0.0;
                for j in 0..=l {
                    z[(j, i)] = z[(i, j)] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z[(j, k)] * z[(i, k)];
                    }
                    for k in (j + 1)..=l {
                        g += z[(k, j)] * z[(i, k)];
                    }
                    e[j] = g / h;
                    f += e[j] * z[(i, j)];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = z[(i, j)];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        let upd = f * e[k] + g * z[(i, k)];
                        z[(j, k)] -= upd;
                    }
                }
            }
        } else {
            e[i] = z[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += z[(i, k)] * z[(k, j)];
                }
                for k in 0..i {
                    let upd = g * z[(k, i)];
                    z[(k, j)] -= upd;
                }
            }
        }
        d[i] = z[(i, i)];
        z[(i, i)] = 1.0;
        for j in 0..i {
            z[(j, i)] = 0.0;
            z[(i, j)] = 0.0;
        }
    }
}

/// Implicit-shift QL iteration on the tridiagonal (d, e), accumulating the
/// rotations into `z`.
fn tqli(d: &mut [f64], e: &mut [f64], z: &mut Matrix) {
    let n = d.len();
    if n <= 1 {
        return;
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // find a small sub-diagonal element to split
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter < 100, "eigh: QL failed to converge");
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sign_r = if g >= 0.0 { r.abs() } else { -r.abs() };
            g = d[m] - d[l] + e[l] / (g + sign_r);
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            let mut underflow = false;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    // recover from underflow (Numerical Recipes tqli)
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    underflow = true;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // accumulate rotation
                for k in 0..n {
                    f = z[(k, i + 1)];
                    z[(k, i + 1)] = s * z[(k, i)] + c * f;
                    z[(k, i)] = c * z[(k, i)] - s * f;
                }
            }
            if underflow {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, syrk_at_a};
    use crate::rng::Pcg64;

    fn random_sym(r: &mut Pcg64, n: usize) -> Matrix {
        let mut a = Matrix::from_fn(n, n, |_, _| r.normal());
        let at = a.transpose();
        a.axpy(1.0, &at);
        a.scale(0.5);
        a
    }

    fn check_decomposition(a: &Matrix, res: &EighResult, tol: f64) {
        let n = a.rows();
        // A v_j = w_j v_j
        for j in 0..n {
            let vj = res.v.col(j);
            let av = a.matvec(&vj);
            for i in 0..n {
                assert!(
                    (av[i] - res.w[j] * vj[i]).abs() < tol,
                    "eigpair {j}: {} vs {}",
                    av[i],
                    res.w[j] * vj[i]
                );
            }
        }
        // VᵀV = I
        let vtv = matmul(&res.v.transpose(), &res.v);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vtv[(i, j)] - want).abs() < tol);
            }
        }
    }

    #[test]
    fn diagonal_matrix() {
        let a = Matrix::from_vec(3, 3, vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0]);
        let res = eigh(&a);
        assert!((res.w[0] - 1.0).abs() < 1e-12);
        assert!((res.w[1] - 2.0).abs() < 1e-12);
        assert!((res.w[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] → eigenvalues 1 and 3
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let res = eigh(&a);
        assert!((res.w[0] - 1.0).abs() < 1e-12);
        assert!((res.w[1] - 3.0).abs() < 1e-12);
        check_decomposition(&a, &res, 1e-10);
    }

    #[test]
    fn random_symmetric_various_sizes() {
        let mut r = Pcg64::seed(41);
        for &n in &[1usize, 2, 5, 10, 30] {
            let a = random_sym(&mut r, n);
            let res = eigh(&a);
            check_decomposition(&a, &res, 1e-8);
            // ascending order
            for j in 1..n {
                assert!(res.w[j] >= res.w[j - 1] - 1e-12);
            }
        }
    }

    #[test]
    fn psd_gram_matrix_nonnegative_spectrum() {
        let mut r = Pcg64::seed(42);
        let b = Matrix::from_fn(15, 8, |_, _| r.normal());
        let g = syrk_at_a(&b); // PSD, rank 8
        let res = eigh(&g);
        assert!(res.w.iter().all(|&w| w > -1e-9));
        check_decomposition(&g, &res, 1e-7);
    }

    #[test]
    fn descending_helper() {
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let res = eigh(&a);
        let (w, v) = res.descending();
        assert!((w[0] - 3.0).abs() < 1e-12);
        assert!((w[1] - 1.0).abs() < 1e-12);
        // first descending column is an eigenvector for 3
        let av = a.matvec(&v.col(0));
        for i in 0..2 {
            assert!((av[i] - 3.0 * v[(i, 0)]).abs() < 1e-10);
        }
    }

    #[test]
    fn trace_preserved() {
        let mut r = Pcg64::seed(43);
        let a = random_sym(&mut r, 20);
        let res = eigh(&a);
        let tr: f64 = (0..20).map(|i| a[(i, i)]).sum();
        let ws: f64 = res.w.iter().sum();
        assert!((tr - ws).abs() < 1e-8);
    }
}
