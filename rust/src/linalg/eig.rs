//! Symmetric eigendecomposition: Householder tridiagonalisation followed by
//! implicit-shift QL with eigenvector accumulation (Numerical-Recipes-style
//! `tred2`/`tqli` scheme, re-derived for row-major storage), plus a
//! **partial** top-k solver ([`partial_eigh`]) — blocked subspace iteration
//! with Rayleigh–Ritz extraction, powered by the packed GEMM core.
//!
//! This is the backbone of the paper's *diagnostics*: the K-satisfiability
//! check (Definition 3) needs `U₁`, `Σ` of the empirical kernel matrix, the
//! incoherence `M` (Theorem 8) needs `Ψ_δ = [Σ(Σ+nδI)]^{-1/2} Uᵀ`, and the
//! statistical dimension is a spectral sum. The spectral *applications*
//! (KPCA, kernel k-means, the top-distortion side of K-satisfiability)
//! consume only the leading eigenpairs — they route through
//! [`partial_eigh`], which costs `O(n²·b)` per iteration instead of the
//! dense solver's `O(n³)`. Neither is on the training hot path (KRR solves
//! go through Cholesky).

use super::gemm::{matmul, matmul_at_b};
use super::Matrix;
use crate::rng::Pcg64;

/// Result of [`eigh`]: `a = V · diag(w) · Vᵀ`, eigenvalues ascending.
#[derive(Clone, Debug)]
pub struct EighResult {
    /// Eigenvalues in ascending order.
    pub w: Vec<f64>,
    /// Orthonormal eigenvectors; column `j` pairs with `w[j]`.
    pub v: Matrix,
}

impl EighResult {
    /// Eigenvalues in descending order with matching eigenvector columns
    /// (the paper's convention σ₁ ≥ σ₂ ≥ …).
    pub fn descending(&self) -> (Vec<f64>, Matrix) {
        let n = self.w.len();
        let mut w = vec![0.0; n];
        let mut v = Matrix::zeros(n, n);
        for j in 0..n {
            let src = n - 1 - j;
            w[j] = self.w[src];
            for i in 0..n {
                v[(i, j)] = self.v[(i, src)];
            }
        }
        (w, v)
    }
}

/// Eigendecomposition of a symmetric matrix. Input asymmetry beyond
/// round-off is the caller's bug (use `Matrix::symmetrize`).
pub fn eigh(a: &Matrix) -> EighResult {
    let n = a.rows();
    assert_eq!(n, a.cols(), "eigh: square required");
    if n == 0 {
        return EighResult {
            w: vec![],
            v: Matrix::zeros(0, 0),
        };
    }
    let mut z = a.clone();
    let mut d = vec![0.0; n]; // diagonal
    let mut e = vec![0.0; n]; // sub-diagonal
    tred2(&mut z, &mut d, &mut e);
    tqli(&mut d, &mut e, &mut z);
    // sort ascending, permuting eigenvector columns
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| d[i].partial_cmp(&d[j]).unwrap());
    let w: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    let mut v = Matrix::zeros(n, n);
    for (jnew, &jold) in order.iter().enumerate() {
        for i in 0..n {
            v[(i, jnew)] = z[(i, jold)];
        }
    }
    EighResult { w, v }
}

/// Householder reduction to tridiagonal form; `z` is overwritten with the
/// accumulated orthogonal transform Q (so the original A = Q·T·Qᵀ).
fn tred2(z: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += z[(i, k)].abs();
            }
            if scale == 0.0 {
                e[i] = z[(i, l)];
            } else {
                for k in 0..=l {
                    z[(i, k)] /= scale;
                    h += z[(i, k)] * z[(i, k)];
                }
                let mut f = z[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[(i, l)] = f - g;
                f = 0.0;
                for j in 0..=l {
                    z[(j, i)] = z[(i, j)] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z[(j, k)] * z[(i, k)];
                    }
                    for k in (j + 1)..=l {
                        g += z[(k, j)] * z[(i, k)];
                    }
                    e[j] = g / h;
                    f += e[j] * z[(i, j)];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = z[(i, j)];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        let upd = f * e[k] + g * z[(i, k)];
                        z[(j, k)] -= upd;
                    }
                }
            }
        } else {
            e[i] = z[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += z[(i, k)] * z[(k, j)];
                }
                for k in 0..i {
                    let upd = g * z[(k, i)];
                    z[(k, j)] -= upd;
                }
            }
        }
        d[i] = z[(i, i)];
        z[(i, i)] = 1.0;
        for j in 0..i {
            z[(j, i)] = 0.0;
            z[(i, j)] = 0.0;
        }
    }
}

/// Implicit-shift QL iteration on the tridiagonal (d, e), accumulating the
/// rotations into `z`.
fn tqli(d: &mut [f64], e: &mut [f64], z: &mut Matrix) {
    let n = d.len();
    if n <= 1 {
        return;
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // find a small sub-diagonal element to split
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter < 100, "eigh: QL failed to converge");
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sign_r = if g >= 0.0 { r.abs() } else { -r.abs() };
            g = d[m] - d[l] + e[l] / (g + sign_r);
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            let mut underflow = false;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    // recover from underflow (Numerical Recipes tqli)
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    underflow = true;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // accumulate rotation
                for k in 0..n {
                    f = z[(k, i + 1)];
                    z[(k, i + 1)] = s * z[(k, i)] + c * f;
                    z[(k, i)] = c * z[(k, i)] - s * f;
                }
            }
            if underflow {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
}

/// A symmetric linear operator the partial eigensolver can drive without
/// a materialised matrix. Subspace iteration only ever needs `A·B`
/// products against a thin block, so an implicit operator (e.g. the
/// row-tiled Gram operator in `kernels::operator`) plugs in with `O(n·b)`
/// working memory; [`materialize`](SymOp::materialize) backs the dense
/// fallbacks (small n, oversized block, stalled iteration), which are the
/// only places the full matrix is ever formed.
pub trait SymOp {
    /// Operator order `n` (the matrix is `n×n`).
    fn dim(&self) -> usize;

    /// `A · B` for an `n×b` block.
    fn apply(&self, b: &Matrix) -> Matrix;

    /// Dense materialisation for the full-`eigh` fallback paths.
    fn materialize(&self) -> Matrix;
}

/// A dense symmetric matrix is trivially a [`SymOp`].
impl SymOp for Matrix {
    fn dim(&self) -> usize {
        self.rows()
    }

    fn apply(&self, b: &Matrix) -> Matrix {
        matmul(self, b)
    }

    fn materialize(&self) -> Matrix {
        self.clone()
    }
}

/// Result of [`partial_eigh`]: the top-`k` eigenpairs, **descending**
/// (the paper's σ₁ ≥ σ₂ ≥ … convention, unlike [`eigh`]'s ascending `w`).
#[derive(Clone, Debug)]
pub struct PartialEigh {
    /// Top eigenvalues, descending (λ₁ ≥ … ≥ λ_k).
    pub w: Vec<f64>,
    /// Matching orthonormal eigenvectors (`n×k`); column `j` pairs with
    /// `w[j]`.
    pub v: Matrix,
    /// Whether a full dense decomposition was computed under the hood
    /// (small-n / large-k / stall fallbacks) — see [`Self::is_complete`].
    complete: bool,
}

impl PartialEigh {
    /// `true` when the returned pairs came from a **full dense**
    /// decomposition (the small-n, large-block or stalled-iteration
    /// fallback): the spectrum below the returned `k` pairs was resolved
    /// too (then discarded), so a caller growing `k` adaptively should
    /// jump straight to its final size rather than re-pay the dense
    /// solver once per enlargement.
    pub fn is_complete(&self) -> bool {
        self.complete
    }
}

/// Below this order the dense `tred2`/`tqli` solver wins outright, so the
/// partial solver falls back to it (the decision rule is re-derived in
/// DESIGN.md §4.2).
const PARTIAL_MIN_N: usize = 96;
/// Subspace-iteration cap; the residual test stops far earlier on the
/// gapped spectra kernel matrices have.
const PARTIAL_MAX_ITERS: usize = 300;
/// Per-pair convergence: ‖A·xⱼ − λⱼxⱼ‖ ≤ tol·max|λ|.
const PARTIAL_RES_TOL: f64 = 1e-11;
/// Iterations without a 0.7× residual contraction before the iteration is
/// declared stalled (clustered spectrum) and the dense solver takes over —
/// a contraction slower than `0.7^(1/12) ≈ 0.97` per step would need
/// hundreds of iterations anyway, at which point `eigh` is cheaper.
const PARTIAL_STALL_ITERS: usize = 12;

/// Top-`k` eigenpairs of a symmetric matrix by blocked subspace iteration
/// with Rayleigh–Ritz extraction.
///
/// Each iteration applies `A` to an orthonormal `n×b` block
/// (`b = k + clamp(k/2, 4, 16)` oversampled directions), solves the small
/// `b×b` Ritz problem with the dense [`eigh`], and stops once every
/// returned pair's residual `‖A·xⱼ − λⱼxⱼ‖` drops below `1e-11·max|λ|` —
/// the convergence rate is `(λ_{b+1}/λ_k)` per iteration, so the
/// oversampled directions buy the gap. Intended for (near-)PSD inputs
/// (kernel matrices, Ritz pencils), where top-by-magnitude and
/// top-by-value coincide. Falls back to the full dense solver when `n`
/// is small, when `k` is a large fraction of `n`, **or when the
/// iteration stalls** (clustered spectrum near λ_k) — the result is
/// always converged, never a silent approximation. Deterministic (fixed
/// internal seed) and bitwise independent of the thread count (the GEMMs
/// it is built on are).
pub fn partial_eigh(a: &Matrix, k: usize) -> PartialEigh {
    assert_eq!(a.rows(), a.cols(), "partial_eigh: square required");
    partial_eigh_op_warm(a, k, None)
}

/// [`partial_eigh`] over any [`SymOp`] — the entry point for implicit
/// operators (streamed kernel Grams) that must never materialise `n×n`.
pub fn partial_eigh_op<O: SymOp>(a: &O, k: usize) -> PartialEigh {
    partial_eigh_op_warm(a, k, None)
}

/// [`partial_eigh_op`] with an optional warm-start basis: up to `block`
/// leading columns of `warm` seed the iteration (remaining directions are
/// filled randomly). Used by block-growing consumers (`stats::ksat`) so
/// each enlargement resumes from the previous round's Ritz vectors
/// instead of rediscovering them from a cold random block.
pub fn partial_eigh_op_warm<O: SymOp>(a: &O, k: usize, warm: Option<&Matrix>) -> PartialEigh {
    let n = a.dim();
    let k = k.min(n);
    if k == 0 {
        return PartialEigh {
            w: Vec::new(),
            v: Matrix::zeros(n, 0),
            complete: false,
        };
    }
    let block = (k + (k / 2).clamp(4, 16)).min(n);
    if n <= PARTIAL_MIN_N || 2 * block >= n {
        let (w, v) = eigh(&a.materialize()).descending();
        return PartialEigh {
            w: w[..k].to_vec(),
            v: v.slice(0, n, 0, k),
            complete: true,
        };
    }
    let mut rng = Pcg64::seed(0x9a57_11a1);
    let mut v = Matrix::from_fn(n, block, |_, _| rng.normal());
    if let Some(wm) = warm {
        assert_eq!(wm.rows(), n, "partial_eigh: warm basis row count");
        for j in 0..wm.cols().min(block) {
            for i in 0..n {
                v[(i, j)] = wm[(i, j)];
            }
        }
    }
    orthonormalize_cols(&mut v, &mut rng);
    let mut w = vec![0.0; k];
    let mut x = Matrix::zeros(n, k);
    let mut converged = false;
    let mut best_resid = f64::INFINITY;
    let mut stalled = 0usize;
    for _iter in 0..PARTIAL_MAX_ITERS {
        let av = a.apply(&v);
        let mut small = matmul_at_b(&v, &av);
        small.symmetrize();
        let (ritz, q) = eigh(&small).descending();
        let xs = matmul(&v, &q); // Ritz vectors (orthonormal)
        let axs = matmul(&av, &q); // A · Ritz vectors
        w.copy_from_slice(&ritz[..k]);
        x = xs.slice(0, n, 0, k);
        let scale = ritz.iter().fold(0.0f64, |m, &r| m.max(r.abs())).max(1e-300);
        let mut worst = 0.0f64;
        for j in 0..k {
            let mut s = 0.0;
            for i in 0..n {
                let resid = axs[(i, j)] - ritz[j] * xs[(i, j)];
                s += resid * resid;
            }
            worst = worst.max(s.sqrt());
        }
        if worst <= PARTIAL_RES_TOL * scale {
            converged = true;
            break;
        }
        if worst < 0.7 * best_resid {
            best_resid = worst;
            stalled = 0;
        } else {
            stalled += 1;
            if stalled >= PARTIAL_STALL_ITERS {
                break; // clustered spectrum: contraction has stalled
            }
        }
        // next subspace: one power step (A applied to the Ritz basis)
        v = axs;
        orthonormalize_cols(&mut v, &mut rng);
    }
    if converged {
        return PartialEigh {
            w,
            v: x,
            complete: false,
        };
    }
    // Stalled or out of iterations: pay for the dense solver rather than
    // hand back silently-unconverged pairs.
    let (wf, vf) = eigh(&a.materialize()).descending();
    PartialEigh {
        w: wf[..k].to_vec(),
        v: vf.slice(0, n, 0, k),
        complete: true,
    }
}

/// Orthonormalise the columns of `v` in place by twice-iterated modified
/// Gram–Schmidt (worked on the transpose so every column is a contiguous
/// row). Columns that cancel to numerically zero are re-seeded from `rng`
/// and re-orthogonalised, so the result always has full column rank.
fn orthonormalize_cols(v: &mut Matrix, rng: &mut Pcg64) {
    let (n, b) = (v.rows(), v.cols());
    if n == 0 || b == 0 {
        return;
    }
    let mut t = v.transpose(); // b×n: columns become contiguous rows
    for j in 0..b {
        let mut attempts = 0;
        loop {
            let before: f64 = t.row(j).iter().map(|x| x * x).sum::<f64>().sqrt();
            for _pass in 0..2 {
                for p in 0..j {
                    let (head, tail) = t.data_mut().split_at_mut(j * n);
                    let rp = &head[p * n..(p + 1) * n];
                    let rj = &mut tail[..n];
                    let mut dot = 0.0;
                    for (xp, xj) in rp.iter().zip(rj.iter()) {
                        dot += xp * xj;
                    }
                    for (xp, xj) in rp.iter().zip(rj.iter_mut()) {
                        *xj -= dot * xp;
                    }
                }
            }
            let nrm: f64 = t.row(j).iter().map(|x| x * x).sum::<f64>().sqrt();
            // Degeneracy must be judged *relative* to the entering norm: a
            // column exactly dependent on earlier ones cancels to rounding
            // noise that can still be ≫ 0 absolutely — and that noise may
            // point straight back along an existing column, so normalising
            // it would silently duplicate a direction.
            if nrm > 1e-10 * before.max(1e-300) && nrm > 1e-150 {
                let inv = 1.0 / nrm;
                for xj in t.row_mut(j).iter_mut() {
                    *xj *= inv;
                }
                break;
            }
            attempts += 1;
            assert!(attempts < 64, "orthonormalize_cols: degenerate basis");
            for xj in t.row_mut(j).iter_mut() {
                *xj = rng.normal();
            }
        }
    }
    *v = t.transpose();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_a_bt, syrk_at_a};
    use crate::rng::Pcg64;

    fn random_sym(r: &mut Pcg64, n: usize) -> Matrix {
        let mut a = Matrix::from_fn(n, n, |_, _| r.normal());
        let at = a.transpose();
        a.axpy(1.0, &at);
        a.scale(0.5);
        a
    }

    fn check_decomposition(a: &Matrix, res: &EighResult, tol: f64) {
        let n = a.rows();
        // A v_j = w_j v_j
        for j in 0..n {
            let vj = res.v.col(j);
            let av = a.matvec(&vj);
            for i in 0..n {
                assert!(
                    (av[i] - res.w[j] * vj[i]).abs() < tol,
                    "eigpair {j}: {} vs {}",
                    av[i],
                    res.w[j] * vj[i]
                );
            }
        }
        // VᵀV = I
        let vtv = matmul(&res.v.transpose(), &res.v);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vtv[(i, j)] - want).abs() < tol);
            }
        }
    }

    #[test]
    fn diagonal_matrix() {
        let a = Matrix::from_vec(3, 3, vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0]);
        let res = eigh(&a);
        assert!((res.w[0] - 1.0).abs() < 1e-12);
        assert!((res.w[1] - 2.0).abs() < 1e-12);
        assert!((res.w[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] → eigenvalues 1 and 3
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let res = eigh(&a);
        assert!((res.w[0] - 1.0).abs() < 1e-12);
        assert!((res.w[1] - 3.0).abs() < 1e-12);
        check_decomposition(&a, &res, 1e-10);
    }

    #[test]
    fn random_symmetric_various_sizes() {
        let mut r = Pcg64::seed(41);
        for &n in &[1usize, 2, 5, 10, 30] {
            let a = random_sym(&mut r, n);
            let res = eigh(&a);
            check_decomposition(&a, &res, 1e-8);
            // ascending order
            for j in 1..n {
                assert!(res.w[j] >= res.w[j - 1] - 1e-12);
            }
        }
    }

    #[test]
    fn psd_gram_matrix_nonnegative_spectrum() {
        let mut r = Pcg64::seed(42);
        let b = Matrix::from_fn(15, 8, |_, _| r.normal());
        let g = syrk_at_a(&b); // PSD, rank 8
        let res = eigh(&g);
        assert!(res.w.iter().all(|&w| w > -1e-9));
        check_decomposition(&g, &res, 1e-7);
    }

    #[test]
    fn descending_helper() {
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let res = eigh(&a);
        let (w, v) = res.descending();
        assert!((w[0] - 3.0).abs() < 1e-12);
        assert!((w[1] - 1.0).abs() < 1e-12);
        // first descending column is an eigenvector for 3
        let av = a.matvec(&v.col(0));
        for i in 0..2 {
            assert!((av[i] - 3.0 * v[(i, 0)]).abs() < 1e-10);
        }
    }

    #[test]
    fn trace_preserved() {
        let mut r = Pcg64::seed(43);
        let a = random_sym(&mut r, 20);
        let res = eigh(&a);
        let tr: f64 = (0..20).map(|i| a[(i, i)]).sum();
        let ws: f64 = res.w.iter().sum();
        assert!((tr - ws).abs() < 1e-8);
    }

    /// SPD matrix with a *known* well-gapped spectrum (built from an
    /// exactly orthonormal eigenbasis): the partial solver must recover
    /// the top-k values to 1e-8 and the eigenvectors to subspace angle
    /// well inside 1e-6.
    #[test]
    fn partial_matches_known_spectrum_large_n() {
        let mut r = Pcg64::seed(0xbead);
        let n = 160;
        let basis = eigh(&random_sym(&mut r, n)).v; // orthonormal n×n
        // descending spectrum: geometric head, tiny flat-ish tail — the
        // gap beyond the oversampled block drives fast convergence
        let lam: Vec<f64> = (0..n)
            .map(|j| {
                if j < 24 {
                    0.8f64.powi(j as i32)
                } else {
                    1e-4 * 0.99f64.powi(j as i32)
                }
            })
            .collect();
        let mut vd = basis.clone();
        for j in 0..n {
            for i in 0..n {
                vd[(i, j)] *= lam[j];
            }
        }
        let mut a = matmul_a_bt(&vd, &basis); // Σⱼ λⱼ vⱼvⱼᵀ
        a.symmetrize();
        let k = 10;
        let pe = partial_eigh(&a, k);
        assert_eq!(pe.w.len(), k);
        assert_eq!((pe.v.rows(), pe.v.cols()), (n, k));
        for j in 0..k {
            assert!(
                (pe.w[j] - lam[j]).abs() < 1e-8 * lam[0],
                "eigval {j}: {} vs {}",
                pe.w[j],
                lam[j]
            );
            // well-separated values ⇒ per-vector cosine must be ±1
            let mut dot = 0.0;
            for i in 0..n {
                dot += pe.v[(i, j)] * basis[(i, j)];
            }
            assert!(
                dot.abs() > 1.0 - 1e-8,
                "eigvec {j}: |cos| = {}",
                dot.abs()
            );
        }
        // returned block is orthonormal
        let g = matmul(&pe.v.transpose(), &pe.v);
        for i in 0..k {
            for j in 0..k {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((g[(i, j)] - want).abs() < 1e-9, "VᵀV ({i},{j})");
            }
        }
    }

    /// A clustered leading spectrum (30 near-equal top eigenvalues)
    /// stalls subspace iteration; the solver must detect the stall and
    /// fall back to the dense path instead of returning silently
    /// unconverged pairs.
    #[test]
    fn partial_clustered_spectrum_falls_back_exactly() {
        let mut r = Pcg64::seed(0xc1a5);
        let n = 120;
        let basis = eigh(&random_sym(&mut r, n)).v;
        let lam: Vec<f64> = (0..n)
            .map(|j| {
                if j < 30 {
                    1.0 - j as f64 * 1e-4
                } else {
                    0.5 * 0.9f64.powi(j as i32)
                }
            })
            .collect();
        let mut vd = basis.clone();
        for j in 0..n {
            for i in 0..n {
                vd[(i, j)] *= lam[j];
            }
        }
        let mut a = matmul_a_bt(&vd, &basis);
        a.symmetrize();
        let (wf, _) = eigh(&a).descending();
        let pe = partial_eigh(&a, 8);
        for j in 0..8 {
            assert!(
                (pe.w[j] - wf[j]).abs() < 1e-9,
                "clustered eig {j}: {} vs {}",
                pe.w[j],
                wf[j]
            );
        }
    }

    /// Small-n / large-k inputs take the dense fallback and agree with
    /// `eigh` exactly.
    #[test]
    fn partial_fallback_matches_full() {
        let mut r = Pcg64::seed(0xfa11);
        let a = random_sym(&mut r, 30);
        let (wf, vf) = eigh(&a).descending();
        let pe = partial_eigh(&a, 7);
        for j in 0..7 {
            assert_eq!(pe.w[j], wf[j]);
            for i in 0..30 {
                assert_eq!(pe.v[(i, j)], vf[(i, j)]);
            }
        }
    }

    #[test]
    fn partial_degenerate_requests() {
        let mut r = Pcg64::seed(0xdead);
        let a = random_sym(&mut r, 12);
        let none = partial_eigh(&a, 0);
        assert!(none.w.is_empty());
        assert_eq!((none.v.rows(), none.v.cols()), (12, 0));
        // k > n clamps to n and matches the full solver
        let all = partial_eigh(&a, 40);
        let (wf, _) = eigh(&a).descending();
        assert_eq!(all.w.len(), 12);
        for j in 0..12 {
            assert!((all.w[j] - wf[j]).abs() < 1e-10);
        }
    }

    /// PSD Gram matrix (the shape kernel-spectrum consumers feed in):
    /// partial top-k values match the dense solver.
    #[test]
    fn partial_matches_full_on_gram() {
        let mut r = Pcg64::seed(0x96a3);
        // geometric column scaling gives the Gram a gapped spectrum (a
        // raw Wishart's edge eigenvalues are too closely spaced for a
        // tight-tolerance comparison)
        let b = Matrix::from_fn(200, 120, |_, j| r.normal() * 0.85f64.powi(j as i32));
        let mut g = syrk_at_a(&b);
        g.scale(1.0 / 200.0);
        g.symmetrize();
        let (wf, _) = eigh(&g).descending();
        let pe = partial_eigh(&g, 6);
        for j in 0..6 {
            assert!(
                (pe.w[j] - wf[j]).abs() < 1e-8 * wf[0].max(1.0),
                "gram eig {j}: {} vs {}",
                pe.w[j],
                wf[j]
            );
        }
    }
}
