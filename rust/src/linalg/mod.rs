//! Dense linear-algebra substrate (no external BLAS/LAPACK in this image).
//!
//! Everything the sketched-KRR stack needs: a row-major [`Matrix`], a
//! packed-micro-kernel GEMM/SYRK core ([`gemm`] — one register-blocked
//! kernel behind all four product variants), Cholesky factorisation and
//! SPD solves ([`chol`]), triangular solves, a symmetric
//! eigendecomposition (Householder tridiagonalisation + implicit-shift
//! QL, [`eig`]) used by the K-satisfiability / incoherence diagnostics, a
//! partial top-k eigensolver ([`partial_eigh`] — blocked subspace
//! iteration for the spectral application paths, driveable by implicit
//! operators through the [`SymOp`] trait), and operator-norm estimation
//! by power iteration ([`norms`]).
//!
//! The GEMM micro-kernel and the transcendental kernel map are routed
//! through [`simd`] — a one-time runtime dispatch over explicit
//! AVX2+FMA / NEON implementations with a portable scalar fallback
//! ([`kernel_name`] reports the selection, [`with_kernel`] pins it for a
//! scope, `ACCUMKRR_FORCE_SCALAR=1` pins the fallback process-wide).

mod chol;
mod eig;
mod gemm;
mod matrix;
mod norms;
pub(crate) mod simd;

pub use chol::{chol_factor, chol_solve, chol_solve_many, CholFactor};
pub use eig::{
    eigh, partial_eigh, partial_eigh_op, partial_eigh_op_warm, EighResult, PartialEigh, SymOp,
};
pub(crate) use gemm::{mirror_lower_from_upper, syrk_a_at_upper};
pub use gemm::{matmul, matmul_at_b, matmul_a_bt, matmul_a_bt_rowstable, syrk_a_at, syrk_at_a};
pub use matrix::Matrix;
pub use norms::{fro_norm, op_norm, op_norm_rect};
pub use simd::{detected_features, kernel_name, with_kernel, KernelImpl, Precision};
