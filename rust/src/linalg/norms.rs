//! Matrix norms: Frobenius, and operator (spectral) norm by power iteration
//! on `AᵀA`. The K-satisfiability conditions (paper Definition 3) are
//! operator-norm bounds on `U₁ᵀSSᵀU₁ − I` and `SᵀU₂Σ₂^{1/2}`; power
//! iteration avoids a full SVD of those rectangular matrices.

use super::Matrix;
use crate::rng::Pcg64;

/// Frobenius norm.
pub fn fro_norm(a: &Matrix) -> f64 {
    a.data().iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Spectral norm of a square symmetric matrix by power iteration.
pub fn op_norm(a: &Matrix, iters: usize) -> f64 {
    assert_eq!(a.rows(), a.cols());
    let n = a.rows();
    if n == 0 {
        return 0.0;
    }
    let mut rng = Pcg64::seed(0x5eed);
    let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    normalize(&mut v);
    let mut lam = 0.0;
    for _ in 0..iters {
        let mut w = a.matvec(&v);
        lam = norm2(&w);
        if lam == 0.0 {
            return 0.0;
        }
        normalize(&mut w);
        v = w;
    }
    // for symmetric A, |λ_max| is the operator norm; power iteration on A
    // converges to the dominant-magnitude eigenvalue
    lam
}

/// Spectral norm of a rectangular matrix: power iteration on the Gram
/// operator `v ↦ Aᵀ(Av)` (never materialises `AᵀA`).
pub fn op_norm_rect(a: &Matrix, iters: usize) -> f64 {
    let (r, c) = (a.rows(), a.cols());
    if r == 0 || c == 0 {
        return 0.0;
    }
    let mut rng = Pcg64::seed(0x5eed2);
    let mut v: Vec<f64> = (0..c).map(|_| rng.normal()).collect();
    normalize(&mut v);
    let mut s2 = 0.0;
    for _ in 0..iters {
        let av = a.matvec(&v);
        let mut w = a.matvec_t(&av);
        s2 = norm2(&w);
        if s2 == 0.0 {
            return 0.0;
        }
        normalize(&mut w);
        v = w;
    }
    s2.sqrt()
}

fn norm2(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

fn normalize(v: &mut [f64]) {
    let n = norm2(v);
    if n > 0.0 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigh;
    use crate::rng::Pcg64;

    #[test]
    fn fro_of_identity() {
        assert!((fro_norm(&Matrix::eye(4)) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn opnorm_diagonal() {
        let a = Matrix::from_vec(3, 3, vec![1.0, 0.0, 0.0, 0.0, -5.0, 0.0, 0.0, 0.0, 2.0]);
        let n = op_norm(&a, 200);
        assert!((n - 5.0).abs() < 1e-6, "n={n}");
    }

    #[test]
    fn opnorm_rect_matches_eig_of_gram() {
        let mut r = Pcg64::seed(51);
        let a = Matrix::from_fn(12, 5, |_, _| r.normal());
        let got = op_norm_rect(&a, 300);
        let gram = crate::linalg::gemm::matmul_at_b(&a, &a);
        let want = eigh(&gram).w.last().unwrap().max(0.0).sqrt();
        assert!((got - want).abs() < 1e-6 * want.max(1.0), "{got} vs {want}");
    }

    #[test]
    fn zero_matrix() {
        let a = Matrix::zeros(4, 3);
        assert_eq!(op_norm_rect(&a, 50), 0.0);
        assert_eq!(fro_norm(&a), 0.0);
    }
}
