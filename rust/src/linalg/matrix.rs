//! Row-major dense matrix of `f64`.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major matrix. The single owning container used throughout the
/// library; views are expressed as (`&Matrix`, row/col ranges) at call sites
/// of the blocked kernels.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zeros `rows × cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Identity matrix of order `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "from_vec: size mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Column vector from a slice.
    pub fn col_vec(v: &[f64]) -> Self {
        Matrix::from_vec(v.len(), 1, v.to_vec())
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Raw row-major data.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw row-major data.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// `self += alpha * other` (same shape).
    pub fn axpy(&mut self, alpha: f64, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// Scale every element.
    pub fn scale(&mut self, alpha: f64) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Add `alpha` to the diagonal (ridge shift `K + nλI`).
    pub fn add_diag(&mut self, alpha: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += alpha;
        }
    }

    /// Matrix–vector product `self · v`.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols);
        let mut out = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut s = 0.0;
            for (a, b) in row.iter().zip(v.iter()) {
                s += a * b;
            }
            out[i] = s;
        }
        out
    }

    /// Transposed matrix–vector product `selfᵀ · v`.
    pub fn matvec_t(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            let vi = v[i];
            for (o, a) in out.iter_mut().zip(row.iter()) {
                *o += vi * a;
            }
        }
        out
    }

    /// Sub-matrix copy `self[r0..r1, c0..c1]`.
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        for i in r0..r1 {
            out.row_mut(i - r0).copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Symmetrise in place: `A ← (A + Aᵀ)/2` (guards eigensolvers against
    /// round-off asymmetry in products like `SᵀKS`).
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    /// Max absolute element (used by tests).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_shape_and_index() {
        let mut m = Matrix::zeros(2, 3);
        m[(1, 2)] = 5.0;
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m[(0, 0)], 0.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 10 + j) as f64);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose()[(2, 1)], m[(1, 2)]);
    }

    #[test]
    fn matvec_matches_manual() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        assert_eq!(m.matvec_t(&[1.0, 1.0]), vec![4.0, 6.0]);
    }

    #[test]
    fn slice_copies_block() {
        let m = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let s = m.slice(1, 3, 2, 4);
        assert_eq!(s.rows(), 2);
        assert_eq!(s[(0, 0)], m[(1, 2)]);
        assert_eq!(s[(1, 1)], m[(2, 3)]);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Matrix::eye(2);
        let b = Matrix::eye(2);
        a.axpy(2.0, &b);
        a.scale(0.5);
        assert_eq!(a[(0, 0)], 1.5);
        assert_eq!(a[(0, 1)], 0.0);
    }

    #[test]
    fn add_diag_ridge() {
        let mut a = Matrix::zeros(3, 3);
        a.add_diag(2.5);
        assert_eq!(a[(1, 1)], 2.5);
        assert_eq!(a[(0, 1)], 0.0);
    }

    #[test]
    fn symmetrize_averages() {
        let mut a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 4.0, 1.0]);
        a.symmetrize();
        assert_eq!(a[(0, 1)], 3.0);
        assert_eq!(a[(1, 0)], 3.0);
    }
}
