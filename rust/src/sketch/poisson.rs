//! Poisson-sampled sub-sampling sketch — per-row independent inclusion as
//! an alternative to the paper's with-replacement column draws.
//!
//! With-replacement sampling ([`AccumSketch`](super::AccumSketch) /
//! [`SketchKind::Nystrom`](super::SketchKind)) draws `d` i.i.d. columns, so
//! a high-probability row can be picked twice while another is missed.
//! Poisson sampling (Wang, Zou & Wang, arXiv:2205.08588) instead includes
//! each row `i` *independently* with probability `πᵢ = min(1, d·pᵢ)` and
//! reweights the surviving rows by `1/√πᵢ`:
//!
//! ```text
//!   E[SSᵀ] = Σᵢ πᵢ · (1/πᵢ) eᵢeᵢᵀ = Iₙ     (exactly, not just per column)
//! ```
//!
//! The column count is random with mean `Σᵢ πᵢ ≤ d` — rows whose inclusion
//! probability saturates at 1 enter deterministically with unit weight, so
//! on a concentrated leverage profile the sketch degrades gracefully into
//! an exact sub-matrix selection.
//!
//! **Determinism contract** (the Poisson analogue of grow-1→m): the sketch
//! caches one uniform `uᵢ` per row, drawn in a single pass of exactly `n`
//! [`Pcg64::uniform`] calls. Row `i` is included at target dimension `d`
//! iff `uᵢ < πᵢ(d)`. Because `πᵢ(d)` is non-decreasing in `d`, the supports
//! are *nested* as `d` grows, and [`PoissonSketch::grow_to`] rematerialises
//! from the cached uniforms without touching the RNG — a sketch grown
//! `d₀ → d` is bit-identical to a one-shot draw at `d` from the same RNG
//! stream.

use super::{Sampling, Sketch, SketchOps, SparseSketch};
use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// A growable Poisson-sampled sketch over `n` points with target (expected)
/// dimension `d_target`.
#[derive(Clone, Debug)]
pub struct PoissonSketch {
    n: usize,
    d_target: usize,
    /// Base probabilities `pᵢ` (normalised; uniform = `1/n`).
    probs: Vec<f64>,
    /// One cached uniform per row; inclusion at dimension `d` is
    /// `u[i] < min(1, d·probs[i])`, so growing `d` only moves thresholds.
    u: Vec<f64>,
    /// Materialised sparse view at the current `d_target`.
    sparse: SparseSketch,
}

impl PoissonSketch {
    /// Draw a Poisson sketch at target dimension `d_target` over the base
    /// distribution of `sampling` (any variant: uniform, a leverage table,
    /// or [`Sampling::Poisson`] carrying its table). Consumes exactly `n`
    /// uniforms from `rng`, independent of `d_target`.
    pub fn draw(n: usize, d_target: usize, sampling: &Sampling, rng: &mut Pcg64) -> PoissonSketch {
        assert!(n > 0 && d_target > 0, "poisson sketch: empty dims");
        let probs: Vec<f64> = (0..n).map(|i| sampling.prob(i, n)).collect();
        let u: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let mut sk = PoissonSketch {
            n,
            d_target,
            probs,
            u,
            sparse: SparseSketch::new(n, Vec::new()),
        };
        sk.materialise();
        sk
    }

    /// Grow the target dimension (no-op if already at or beyond it).
    /// Deterministic: rematerialises from the cached per-row uniforms, so
    /// the result is bit-identical to a one-shot [`draw`](Self::draw) at
    /// the new dimension, and the support only ever gains rows.
    pub fn grow_to(&mut self, d_target: usize) {
        if d_target <= self.d_target {
            return;
        }
        self.d_target = d_target;
        self.materialise();
    }

    fn materialise(&mut self) {
        let d = self.d_target as f64;
        let mut cols = Vec::new();
        for i in 0..self.n {
            let pi = (d * self.probs[i]).min(1.0);
            if self.u[i] < pi {
                // π = 1 rows carry exactly unit weight (1/√1), so the
                // saturated regime is an unweighted row selection
                cols.push(vec![(i, 1.0 / pi.sqrt())]);
            }
        }
        self.sparse = SparseSketch::new(self.n, cols);
    }

    /// Target (expected) dimension `d` the inclusion probabilities use.
    pub fn d_target(&self) -> usize {
        self.d_target
    }

    /// Expected realised dimension `Σᵢ min(1, d·pᵢ)` (`≤ d_target`, with
    /// equality iff no probability saturates).
    pub fn expected_dim(&self) -> f64 {
        let d = self.d_target as f64;
        self.probs.iter().map(|&p| (d * p).min(1.0)).sum()
    }

    /// The materialised sparse sketch (one column per included row, in row
    /// order).
    pub fn sparse(&self) -> &SparseSketch {
        &self.sparse
    }

    /// Clone into the [`Sketch`] enum (for APIs taking any sketch).
    pub fn as_sketch(&self) -> Sketch {
        Sketch::Sparse(self.sparse.clone())
    }
}

impl SketchOps for PoissonSketch {
    fn n(&self) -> usize {
        self.n
    }

    /// Realised dimension (number of included rows) — random, mean
    /// [`expected_dim`](Self::expected_dim).
    fn d(&self) -> usize {
        self.sparse.d()
    }

    fn nnz(&self) -> usize {
        self.sparse.nnz()
    }

    fn to_dense(&self) -> Matrix {
        self.sparse.to_dense()
    }

    fn st_mat(&self, b: &Matrix) -> Matrix {
        self.sparse.st_mat(b)
    }

    fn st_vec(&self, v: &[f64]) -> Vec<f64> {
        self.sparse.st_vec(v)
    }

    fn s_vec(&self, w: &[f64]) -> Vec<f64> {
        self.sparse.s_vec(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::AliasTable;

    /// Grow-in-d determinism: a sketch grown d₀ → d bit-matches a one-shot
    /// draw at d from the same RNG stream, and both consume exactly n
    /// uniforms.
    #[test]
    fn grown_poisson_bit_matches_one_shot() {
        let n = 100;
        let weights: Vec<f64> = (0..n).map(|i| 1.0 + (i % 9) as f64).collect();
        let sampling = Sampling::Poisson(AliasTable::new(&weights));
        let mut rng_grow = Pcg64::seed(0x9015);
        let mut rng_shot = Pcg64::seed(0x9015);
        let mut grown = PoissonSketch::draw(n, 4, &sampling, &mut rng_grow);
        grown.grow_to(12);
        grown.grow_to(24);
        let shot = PoissonSketch::draw(n, 24, &sampling, &mut rng_shot);
        assert_eq!(grown.d(), shot.d(), "realised dims");
        for j in 0..shot.d() {
            let a = grown.sparse().col(j);
            let b = shot.col(j);
            assert_eq!(a.len(), 1);
            assert_eq!(a[0].0, b[0].0, "col {j} row");
            assert_eq!(a[0].1.to_bits(), b[0].1.to_bits(), "col {j} weight bits");
        }
        // identical stream positions: both consumed exactly n uniforms
        assert_eq!(rng_grow.next_u64(), rng_shot.next_u64());
    }

    /// Supports are nested in d (the coupling that makes grow deterministic).
    #[test]
    fn poisson_supports_are_nested_in_d() {
        let n = 64;
        let sampling = Sampling::Uniform;
        let mut rng = Pcg64::seed(0x2b);
        let mut sk = PoissonSketch::draw(n, 4, &sampling, &mut rng);
        let small: Vec<usize> = sk.sparse().support();
        sk.grow_to(16);
        let big: Vec<usize> = sk.sparse().support();
        assert!(small.iter().all(|r| big.contains(r)), "support must nest");
        assert!(big.len() >= small.len());
    }

    /// `E[SSᵀ] = Iₙ` unbiasedness (seeded Monte Carlo, pinned tolerance).
    /// Small n relative to d keeps every πᵢ strictly inside (0, 1) so the
    /// test exercises the random regime rather than saturated selection.
    #[test]
    fn poisson_expectation_is_identity() {
        let (n, d, reps) = (6, 3, 4000);
        let mut rng = Pcg64::seed(0xbeef);
        let sampling = Sampling::Uniform; // πᵢ = 3/6 = 1/2 per row
        let mut acc = Matrix::zeros(n, n);
        for _ in 0..reps {
            let s = PoissonSketch::draw(n, d, &sampling, &mut rng).to_dense();
            let sst = crate::linalg::matmul_a_bt(&s, &s);
            for i in 0..n {
                for j in 0..n {
                    acc[(i, j)] += sst[(i, j)] / reps as f64;
                }
            }
        }
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (acc[(i, j)] - want).abs() < 0.1,
                    "E[SSᵀ][{i},{j}] = {} (want {want})",
                    acc[(i, j)]
                );
            }
        }
    }

    /// Saturated rows (πᵢ = 1) enter deterministically with unit weight.
    #[test]
    fn saturated_rows_included_with_unit_weight() {
        let n = 10;
        // all mass on rows 0 and 1 → at d = 4, π₀ = π₁ = 1, rest 0
        let mut weights = vec![0.0; n];
        weights[0] = 1.0;
        weights[1] = 1.0;
        let sampling = Sampling::Poisson(AliasTable::new(&weights));
        let mut rng = Pcg64::seed(7);
        let sk = PoissonSketch::draw(n, 4, &sampling, &mut rng);
        assert_eq!(sk.d(), 2);
        let support = sk.sparse().support();
        assert_eq!(support, vec![0, 1]);
        for j in 0..2 {
            assert_eq!(sk.sparse().col(j)[0].1.to_bits(), 1.0f64.to_bits());
        }
        assert!((sk.expected_dim() - 2.0).abs() < 1e-12);
    }
}
