//! Sketch application — forming `KS`, `SᵀKS`, `SᵀK²S` and `SᵀKY` without
//! ever materialising the full `n×n` kernel matrix for sparse sketches.
//!
//! This is the paper's §3.3 efficiency argument made concrete:
//!
//! * sparse `S` with support `U` (|U| ≤ m·d): `KS` needs only the kernel
//!   columns `K[:, U]` — `O(n·|U|)` kernel evaluations + `O(n·nnz)` flops —
//!   then `SᵀKS = Sᵀ(KS)` is another `O(nnz·d)`;
//! * dense `S` (Gaussian/Rademacher): the full `K` and an `O(n²d)` GEMM are
//!   unavoidable, which is exactly the gap the paper's Figures 1/3 show.

use super::{Sketch, SparseSketch};
use crate::kernels::{cross_kernel, kernel_matrix, Kernel};
use crate::linalg::{matmul, syrk_at_a, Matrix};

/// All sketched quantities the KRR solvers need, with the cost model used
/// to produce them.
#[derive(Clone, Debug)]
pub struct SketchedGram {
    /// `K S` (n×d).
    pub ks: Matrix,
    /// `Sᵀ K S` (d×d, symmetrised).
    pub stks: Matrix,
    /// `Sᵀ K² S = (KS)ᵀ(KS)` (d×d).
    pub stk2s: Matrix,
    /// Number of kernel evaluations actually performed (cost diagnostic;
    /// the bench harness reports it next to wall-clock).
    pub kernel_evals: usize,
}

/// Compute `K[:, support]` for a sparse sketch and fold the per-column
/// weights to get `KS` directly: column `j` of `KS` is
/// `Σ_{(i,w)∈col j} w · K[:, i]`.
pub fn sketch_kernel_cols(kernel: &Kernel, x: &Matrix, s: &SparseSketch) -> (Matrix, usize) {
    let n = x.rows();
    let support = s.support();
    let landmarks = crate::kernels::gather_rows(x, &support);
    let kcols = cross_kernel(kernel, x, &landmarks); // n × |U|
    // position map for the fold
    let mut pos = std::collections::HashMap::with_capacity(support.len());
    for (p, &i) in support.iter().enumerate() {
        pos.insert(i, p);
    }
    let mut ks = Matrix::zeros(n, s.d());
    for (j, col) in (0..s.d()).map(|j| (j, s.col(j))) {
        for &(i, w) in col {
            let src = pos[&i];
            for r in 0..n {
                ks[(r, j)] += w * kcols[(r, src)];
            }
        }
    }
    (ks, n * support.len())
}

/// Form every Gram quantity for the given sketch.
///
/// `k_full`: pass a precomputed `K` to share it across sketches in a sweep
/// (the bench harness does this for dense baselines); `None` lets sparse
/// sketches use the column fast path and dense sketches build `K` once.
pub fn sketch_gram(
    kernel: &Kernel,
    x: &Matrix,
    sketch: &Sketch,
    k_full: Option<&Matrix>,
) -> SketchedGram {
    let n = x.rows();
    let (ks, kernel_evals) = match (sketch, k_full) {
        (Sketch::Sparse(sp), None) => sketch_kernel_cols(kernel, x, sp),
        (Sketch::Sparse(sp), Some(k)) => {
            // K given: KS is a sparse column-combination, zero kernel evals.
            let mut ks = Matrix::zeros(n, sp.d());
            for j in 0..sp.d() {
                for &(i, w) in sp.col(j) {
                    let kcol_i = k.row(i); // K symmetric: row i = column i
                    for r in 0..n {
                        ks[(r, j)] += w * kcol_i[r];
                    }
                }
            }
            (ks, 0)
        }
        (Sketch::Dense(s), maybe_k) => {
            let owned;
            let k = match maybe_k {
                Some(k) => k,
                None => {
                    owned = kernel_matrix(kernel, x);
                    &owned
                }
            };
            (matmul(k, s), if maybe_k.is_some() { 0 } else { n * n })
        }
    };
    let mut stks = sketch.st_mat(&ks);
    stks.symmetrize();
    let stk2s = syrk_at_a(&ks);
    SketchedGram {
        ks,
        stks,
        stk2s,
        kernel_evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul_at_b;
    use crate::rng::Pcg64;
    use crate::sketch::{SketchBuilder, SketchKind};

    fn setup(n: usize) -> (Kernel, Matrix, Pcg64) {
        let mut rng = Pcg64::seed(91);
        let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
        (Kernel::gaussian(1.0), x, rng)
    }

    fn assert_close(a: &Matrix, b: &Matrix, tol: f64, what: &str) {
        assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
        for i in 0..a.rows() {
            for j in 0..a.cols() {
                assert!(
                    (a[(i, j)] - b[(i, j)]).abs() < tol,
                    "{what} ({i},{j}): {} vs {}",
                    a[(i, j)],
                    b[(i, j)]
                );
            }
        }
    }

    #[test]
    fn sparse_fast_path_matches_dense_math() {
        let (kernel, x, mut rng) = setup(40);
        let k = kernel_matrix(&kernel, &x);
        for kind in [
            SketchKind::Nystrom,
            SketchKind::Accumulation { m: 5 },
            SketchKind::VerySparse { sparsity: Some(4.0) },
        ] {
            let s = SketchBuilder::new(kind.clone()).build(40, 7, &mut rng);
            let g = sketch_gram(&kernel, &x, &s, None);
            let sd = s.to_dense();
            let ks_ref = matmul(&k, &sd);
            assert_close(&g.ks, &ks_ref, 1e-9, &format!("KS {}", kind.name()));
            let stks_ref = matmul_at_b(&sd, &ks_ref);
            assert_close(&g.stks, &stks_ref, 1e-9, "StKS");
            let stk2s_ref = matmul_at_b(&ks_ref, &ks_ref);
            assert_close(&g.stk2s, &stk2s_ref, 1e-9, "StK2S");
        }
    }

    #[test]
    fn precomputed_k_path_matches() {
        let (kernel, x, mut rng) = setup(25);
        let k = kernel_matrix(&kernel, &x);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 3 }).build(25, 6, &mut rng);
        let with_k = sketch_gram(&kernel, &x, &s, Some(&k));
        let without = sketch_gram(&kernel, &x, &s, None);
        assert_close(&with_k.ks, &without.ks, 1e-9, "KS");
        assert_eq!(with_k.kernel_evals, 0);
        assert!(without.kernel_evals > 0);
    }

    #[test]
    fn dense_sketch_gram() {
        let (kernel, x, mut rng) = setup(20);
        let s = SketchBuilder::new(SketchKind::Gaussian).build(20, 5, &mut rng);
        let g = sketch_gram(&kernel, &x, &s, None);
        assert_eq!((g.ks.rows(), g.ks.cols()), (20, 5));
        assert_eq!(g.kernel_evals, 400);
        // symmetry of StKS
        for i in 0..5 {
            for j in 0..5 {
                assert_eq!(g.stks[(i, j)], g.stks[(j, i)]);
            }
        }
    }

    #[test]
    fn kernel_evals_scale_with_support_not_n_squared() {
        let (kernel, x, mut rng) = setup(60);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 2 }).build(60, 4, &mut rng);
        let g = sketch_gram(&kernel, &x, &s, None);
        // support ≤ m·d = 8 → evals ≤ 60·8 ≪ 60²
        assert!(g.kernel_evals <= 60 * 8);
    }
}
