//! Sketch application — forming `KS`, `SᵀKS`, `SᵀK²S` and `SᵀKY` without
//! **ever** materialising the full `n×n` kernel matrix.
//!
//! This is the paper's §3.3 efficiency argument made concrete, routed
//! through the row-tiled [`GramOperator`]:
//!
//! * sparse `S` with support `U` (|U| ≤ m·d): `KS` needs only the kernel
//!   columns `K[:, U]` — `O(n·|U|)` kernel evaluations + `O(n·nnz)` flops —
//!   then `SᵀKS = Sᵀ(KS)` is another `O(nnz·d)`;
//! * dense `S` (Gaussian/Rademacher): the `O(n²d)` arithmetic is
//!   unavoidable (the gap the paper's Figures 1/3 show), but the operator
//!   streams `K[tile, :]·S` so peak memory stays `O(tile·n + n·d)` — the
//!   full `K` only ever exists when a caller explicitly shares one across
//!   a sweep via `k_full`.
//!
//! All dense products here (`K·S`, the SYRK for `SᵀK²S`, the thin
//! incremental-update GEMMs) run on the packed micro-kernel core in
//! `linalg::gemm`; tiny per-append products fall into its serial
//! small-matrix path, so `IncrementalGram::sync` pays no packing overhead
//! on single-term growth.

use super::{AccumSketch, Sketch, SketchOps, SparseSketch};
use crate::data::{TileCache, TileSource};
use crate::kernels::{GramOperator, Kernel};
use crate::linalg::{chol_factor, matmul, matmul_at_b, syrk_at_a, Matrix, Precision};
use crate::util::CodedError;
use std::collections::HashMap;

/// All sketched quantities the KRR solvers need, with the cost model used
/// to produce them.
#[derive(Clone, Debug)]
pub struct SketchedGram {
    /// `K S` (n×d).
    pub ks: Matrix,
    /// `Sᵀ K S` (d×d, symmetrised).
    pub stks: Matrix,
    /// `Sᵀ K² S = (KS)ᵀ(KS)` (d×d).
    pub stk2s: Matrix,
    /// Number of kernel evaluations actually performed (cost diagnostic;
    /// the bench harness reports it next to wall-clock).
    pub kernel_evals: usize,
}

/// Compute `K[:, support]` for a sparse sketch and fold the per-column
/// weights to get `KS` directly: column `j` of `KS` is
/// `Σ_{(i,w)∈col j} w · K[:, i]`. Thin wrapper over the operator's
/// support-column path. Panics on a tile-source read failure (in-memory
/// sources cannot fail).
pub fn sketch_kernel_cols(kernel: &Kernel, x: &dyn TileSource, s: &SparseSketch) -> (Matrix, usize) {
    GramOperator::new(*kernel, x)
        .try_ks_sparse(s)
        .expect("sketch kernel cols: tile source read failed")
}

/// Form every Gram quantity for the given sketch.
///
/// `k_full`: pass a precomputed `K` to share it across sketches in a sweep
/// (the bench harness does this for dense baselines); `None` streams
/// everything through a [`GramOperator`] — the column fast path for sparse
/// sketches, row tiles for dense ones — so **no** `n×n` matrix is ever
/// allocated.
pub fn sketch_gram(
    kernel: &Kernel,
    x: &dyn TileSource,
    sketch: &Sketch,
    k_full: Option<&Matrix>,
) -> SketchedGram {
    let Some(k) = k_full else {
        return sketch_gram_streamed(&GramOperator::new(*kernel, x), sketch);
    };
    let n = x.rows();
    let (ks, kernel_evals) = match sketch {
        Sketch::Sparse(sp) => {
            // K given: KS is a sparse column-combination, zero kernel evals.
            let mut ks = Matrix::zeros(n, sp.d());
            for j in 0..sp.d() {
                for &(i, w) in sp.col(j) {
                    let kcol_i = k.row(i); // K symmetric: row i = column i
                    for r in 0..n {
                        ks[(r, j)] += w * kcol_i[r];
                    }
                }
            }
            (ks, 0)
        }
        Sketch::Dense(s) => (matmul(k, s), 0),
    };
    let mut stks = sketch.st_mat(&ks);
    stks.symmetrize();
    let stk2s = syrk_at_a(&ks);
    SketchedGram {
        ks,
        stks,
        stk2s,
        kernel_evals,
    }
}

/// [`sketch_gram`] with an explicit accumulation [`Precision`]. `F64`
/// (and any non-streamed call, i.e. `k_full` given) is exactly
/// [`sketch_gram`]; `F32` streams through a single-precision
/// [`GramOperator`] — f32 panel assembly and `K·S` accumulation, one
/// widen per entry — while the `d×d` Grams handed to the solvers stay
/// f64. The precision knob reaches here from
/// [`SketchedKrr::fit_with`](crate::krr::SketchedKrr::fit_with) and the
/// coordinator job schema's `precision` field.
pub fn sketch_gram_with(
    kernel: &Kernel,
    x: &dyn TileSource,
    sketch: &Sketch,
    k_full: Option<&Matrix>,
    precision: Precision,
) -> SketchedGram {
    try_sketch_gram_with(kernel, x, sketch, k_full, precision)
        .expect("sketch gram: tile source read failed")
}

/// Fallible [`sketch_gram_with`] — the route fit paths take so a failed
/// tile-source read (real or injected through the `io.read` seam)
/// surfaces as a [`CodedError`] instead of a panic.
pub fn try_sketch_gram_with(
    kernel: &Kernel,
    x: &dyn TileSource,
    sketch: &Sketch,
    k_full: Option<&Matrix>,
    precision: Precision,
) -> Result<SketchedGram, CodedError> {
    if k_full.is_none() {
        let op = GramOperator::new(*kernel, x).with_precision(precision);
        return try_sketch_gram_streamed(&op, sketch);
    }
    Ok(sketch_gram(kernel, x, sketch, k_full))
}

/// [`sketch_gram`] against an existing [`GramOperator`] (callers that
/// stream several sketched computations over one dataset build the
/// operator once). Peak memory `O(tile·n + n·d)`.
pub fn sketch_gram_streamed(op: &GramOperator, sketch: &Sketch) -> SketchedGram {
    try_sketch_gram_streamed(op, sketch).expect("sketch gram: tile source read failed")
}

/// Fallible [`sketch_gram_streamed`].
pub fn try_sketch_gram_streamed(
    op: &GramOperator,
    sketch: &Sketch,
) -> Result<SketchedGram, CodedError> {
    let (ks, kernel_evals) = op.try_ks(sketch)?;
    let stks = op.stks(sketch, &ks);
    let stk2s = op.stk2s(&ks);
    Ok(SketchedGram {
        ks,
        stks,
        stk2s,
        kernel_evals,
    })
}

/// The factored form of one accumulation step's effect on the solver
/// matrix `A = SᵀK²S + nλ·SᵀKS`, produced by [`IncrementalGram::sync`].
///
/// With `S_new = α·S_old + T` (T = the appended terms, α = `√(m/m′)` the
/// rescaling of earlier terms) and `δ` distinct support rows in `T`,
///
/// ```text
///   A_new = α²·A_old + Σ_u (g_u c_uᵀ + c_u g_uᵀ) + C·(G_UU + nλ·K_UU)·Cᵀ
/// ```
///
/// where `c_u` is column `u` of `C` (the new-term weight pattern),
/// `g_u = a_u + nλ·b_u` with `a_u = (α·KS_old)ᵀ k_u` and `b_u` the
/// `u`-th support row of `α·KS_old`. [`AppendDelta::factor_update`] turns
/// this into `3δ` signed rank-1 vectors for
/// [`CholFactor::rank_update`](crate::linalg::CholFactor::rank_update), so
/// the `d×d` factor is *updated* (`O(δ·d²)`) instead of re-factorised
/// (`O(d³)`) — a win whenever the appended support is small relative to
/// `d` (single-term growth at small n, or concentrated weighted sampling).
#[derive(Clone, Debug)]
pub struct AppendDelta {
    /// Rescaling `α = √(m_old/m_new)` applied to the previous Grams
    /// (0 when the sketch was empty before the append).
    pub alpha: f64,
    /// `d×δ` new-term weight pattern: `C[j, u] = Σ_t w_{t,j}·[row = u]`.
    pub c: Matrix,
    /// `d×δ`: `a_u = (α·KS_old)ᵀ·k_u` per distinct support row.
    pub a_cols: Matrix,
    /// `δ×d`: support rows of `α·KS_old`.
    pub b_rows: Matrix,
    /// `δ×δ` kernel-column Gram `k_uᵀ k_v` (= `[K²]_{uv}`).
    pub guu: Matrix,
    /// `δ×δ` kernel values `K(x_u, x_v)`.
    pub kuu: Matrix,
}

impl AppendDelta {
    /// Number of distinct support rows `δ` the append touched.
    pub fn support_len(&self) -> usize {
        self.c.cols()
    }

    /// Number of signed rank-1 vectors [`factor_update`](Self::factor_update)
    /// produces (`3δ`) — callers compare `rank() · d²` against the
    /// `d³/3` re-factorisation cost to pick a strategy.
    pub fn rank(&self) -> usize {
        3 * self.support_len()
    }

    /// Signed rank-1 vectors `(columns, σ)` such that
    /// `A_new = α²·A_old + Σᵢ σᵢ vᵢvᵢᵀ` for the ridge level `nl = n·λ`.
    /// Returns `None` when the small `δ×δ` PSD block fails to factor
    /// (numerically rank-deficient batch — duplicate support rows); the
    /// caller falls back to re-factorisation from the exact Grams.
    pub fn factor_update(&self, nl: f64) -> Option<(Matrix, Vec<f64>)> {
        let d = self.c.rows();
        let k = self.support_len();
        // PSD block W = G_UU + nλ·K_UU = M·Mᵀ
        let mut w = self.guu.clone();
        w.axpy(nl, &self.kuu);
        w.symmetrize();
        let m = chol_factor(&w)?;
        let cm = matmul(&self.c, m.l()); // d×δ, C·M
        let inv_sqrt2 = 1.0 / 2f64.sqrt();
        let mut cols = Matrix::zeros(d, 3 * k);
        let mut sigma = vec![1.0; 3 * k];
        for u in 0..k {
            for i in 0..d {
                let g = self.a_cols[(i, u)] + nl * self.b_rows[(u, i)];
                let c = self.c[(i, u)];
                // g cᵀ + c gᵀ = ½[(g+c)(g+c)ᵀ − (g−c)(g−c)ᵀ]
                cols[(i, 3 * u)] = (g + c) * inv_sqrt2;
                cols[(i, 3 * u + 1)] = (g - c) * inv_sqrt2;
                cols[(i, 3 * u + 2)] = cm[(i, u)];
            }
            sigma[3 * u + 1] = -1.0;
        }
        Some((cols, sigma))
    }
}

/// Incrementally accumulated sketched Grams: the engine behind
/// [`SketchedKrr::fit_adaptive`](crate::krr::SketchedKrr::fit_adaptive).
///
/// Where [`sketch_gram`] rebuilds `KS`, `SᵀKS`, `SᵀK²S` from scratch for
/// every sketch, this struct *grows* them as terms are appended to an
/// [`AccumSketch`]:
///
/// * kernel columns are cached per support row in a [`TileCache`] — the
///   support columns of the accumulated sketch are **pinned** (the
///   solver's live working set; never evicted), while opportunistic
///   columns (seeded landmark panels) stay evictable under the cache's
///   byte budget (`ACCUMKRR_TILE_CACHE_MB`, DESIGN.md §12) — so
///   appending terms costs kernel evaluations only at support points
///   not already resident;
/// * `KS` and `SᵀKS` are updated in `O(n·d)` / `O(δ·d²)` per append
///   (δ = distinct support rows appended);
/// * `SᵀK²S` is updated with two thin GEMMs against the `n×δ` panel of
///   appended kernel columns — `O(n·d·δ)`, versus the `O(n·d²)` SYRK plus
///   `O(n·m·d)` re-fold a rebuild pays.
///
/// The matching [`AppendDelta`] additionally lets the solver up/down-date
/// its Cholesky factor instead of re-factorising.
#[derive(Clone, Debug)]
pub struct IncrementalGram {
    kernel: Kernel,
    n: usize,
    d: usize,
    m_done: usize,
    /// Budgeted cache of kernel columns `K[:, u]`, keyed by support row;
    /// sketch-support columns are pinned, seeded ones evictable.
    kcols: TileCache,
    ks: Matrix,
    stks: Matrix,
    stk2s: Matrix,
    kernel_evals: usize,
}

impl IncrementalGram {
    /// Empty accumulator for an `n×d` sketch under `kernel`. The column
    /// cache takes its byte budget from `ACCUMKRR_TILE_CACHE_MB`
    /// ([`TileCache::from_env`]); see
    /// [`set_cache_budget`](Self::set_cache_budget) for the explicit
    /// override.
    pub fn new(kernel: Kernel, n: usize, d: usize) -> IncrementalGram {
        IncrementalGram {
            kernel,
            n,
            d,
            m_done: 0,
            kcols: TileCache::from_env(),
            ks: Matrix::zeros(n, d),
            stks: Matrix::zeros(d, d),
            stk2s: Matrix::zeros(d, d),
            kernel_evals: 0,
        }
    }

    /// Override the column-cache byte budget (tests and embedders; the
    /// default comes from the environment). Shrinking evicts unpinned
    /// columns immediately — pinned support columns always stay.
    pub fn set_cache_budget(&mut self, bytes: usize) {
        self.kcols.set_budget(bytes);
    }

    /// The support-column cache (inspection: residency, budget, pins).
    pub fn cache(&self) -> &TileCache {
        &self.kcols
    }

    /// Terms folded in so far.
    pub fn m(&self) -> usize {
        self.m_done
    }

    /// Current `K·S` (n×d).
    pub fn ks(&self) -> &Matrix {
        &self.ks
    }

    /// Current `Sᵀ·K·S` (d×d).
    pub fn stks(&self) -> &Matrix {
        &self.stks
    }

    /// Current `Sᵀ·K²·S` (d×d).
    pub fn stk2s(&self) -> &Matrix {
        &self.stk2s
    }

    /// Kernel evaluations performed so far (only new support rows cost).
    pub fn kernel_evals(&self) -> usize {
        self.kernel_evals
    }

    /// Right-hand side `SᵀKY = (KS)ᵀy` at the current `m` — `O(n·d)`.
    pub fn rhs(&self, y: &[f64]) -> Vec<f64> {
        self.ks.matvec_t(y)
    }

    /// Support rows whose kernel columns are currently cached, sorted.
    pub fn cached_rows(&self) -> Vec<usize> {
        self.kcols.cached_rows()
    }

    /// Seed the kernel-column cache with already-computed columns (e.g. the
    /// final-round landmark panel of
    /// [`bless`](crate::leverage::bless) — column `c` of `panel` must be
    /// `K[:, rows[c]]`). The evaluations were paid by the producer, so
    /// [`kernel_evals`](Self::kernel_evals) is *not* incremented; a
    /// subsequent [`sync`](Self::sync) whose support hits these rows costs
    /// zero new kernel evaluations. Seeded columns are **unpinned** —
    /// they are an opportunistic prefetch, evictable under the cache
    /// budget (a later `sync` that needs an evicted one just recomputes
    /// and pins it).
    pub fn seed_columns(&mut self, rows: &[usize], panel: &Matrix) {
        assert_eq!(panel.rows(), self.n, "seed_columns: panel row count");
        assert_eq!(panel.cols(), rows.len(), "seed_columns: panel columns");
        for (c, &row) in rows.iter().enumerate() {
            assert!(row < self.n, "seed_columns: row out of range");
            if !self.kcols.contains(row) {
                self.kcols.insert(row, panel.col(c), false);
            }
        }
    }

    /// Estimate ridge leverage scores from the support columns already in
    /// the cache — the between-term probability refinement of
    /// [`fit_adaptive`](crate::krr::SketchedKrr::fit_adaptive).
    ///
    /// With cached support `J` (|J| = s), this is one round of the BLESS
    /// Nyström resolvent ([`bless`](crate::leverage::bless)) at the target
    /// λ: `ℓ̂ᵢ = (kᵢᵢ − k_{iJ}(K_{JJ} + sλI)⁻¹k_{Ji}) / (nλ)`, clamped to
    /// `[1e-12, 1]`. Every `k_{iJ}` entry reads the cache, so the only new
    /// kernel work is the diagonal (`n` evaluations, counted) — the
    /// landmark-panel cost `bless` would pay is amortised into the terms
    /// already folded. With `J = [n]` the estimate is exact. `O(n·s²)`
    /// flops; never materialises anything `n×n`. Returns `None` when the
    /// cache is empty or λ ≤ 0. If the cache evicted some seeded columns
    /// under budget pressure, `J` is just smaller — a coarser but still
    /// valid Nyström estimate. Panics on a tile-source read failure
    /// (in-memory sources cannot fail); see
    /// [`try_estimate_leverage`](Self::try_estimate_leverage).
    pub fn estimate_leverage(&mut self, x: &dyn TileSource, lambda: f64) -> Option<Vec<f64>> {
        self.try_estimate_leverage(x, lambda)
            .expect("incremental gram: tile source read failed")
    }

    /// Fallible core of [`estimate_leverage`](Self::estimate_leverage):
    /// a diagonal read off a file-backed source surfaces as `Err` instead
    /// of panicking. Nothing is mutated before the fallible read, so an
    /// error leaves the accumulator (and its cache) exactly as it was.
    pub fn try_estimate_leverage(
        &mut self,
        x: &dyn TileSource,
        lambda: f64,
    ) -> Result<Option<Vec<f64>>, CodedError> {
        let j = self.cached_rows();
        if j.is_empty() || !(lambda > 0.0) {
            return Ok(None);
        }
        let s = j.len();
        let col = |row: usize| self.kcols.get(row).expect("cached_rows listed this row");
        let mut a = Matrix::from_fn(s, s, |u, v| col(j[v])[j[u]]);
        a.symmetrize();
        a.add_diag(s as f64 * lambda);
        let fac = match chol_factor(&a) {
            Some(f) => f,
            None => {
                a.add_diag(1e-8);
                match chol_factor(&a) {
                    Some(f) => f,
                    None => return Ok(None),
                }
            }
        };
        let diag = GramOperator::new(self.kernel, x).try_diag()?;
        self.kernel_evals += self.n;
        let nl = self.n as f64 * lambda;
        let mut ki = vec![0.0; s];
        let mut scores = Vec::with_capacity(self.n);
        for i in 0..self.n {
            for (v, &row) in j.iter().enumerate() {
                ki[v] = self.kcols.get(row).expect("cached_rows listed this row")[i];
            }
            let sol = fac.solve(&ki);
            let reduced: f64 = ki.iter().zip(sol.iter()).map(|(a, b)| a * b).sum();
            scores.push(((diag[i] - reduced).max(0.0) / nl).clamp(1e-12, 1.0));
        }
        Ok(Some(scores))
    }

    /// Snapshot into the one-shot [`SketchedGram`] shape the solvers take.
    pub fn snapshot(&self) -> SketchedGram {
        SketchedGram {
            ks: self.ks.clone(),
            stks: self.stks.clone(),
            stk2s: self.stk2s.clone(),
            kernel_evals: self.kernel_evals,
        }
    }

    /// Fold every term the sketch has grown past this accumulator's count
    /// into the Grams. Returns `None` when the sketch has no new terms,
    /// otherwise the [`AppendDelta`] describing the step for the solver.
    /// Panics on a tile-source read failure (in-memory sources cannot
    /// fail); see [`try_sync`](Self::try_sync).
    pub fn sync(&mut self, x: &dyn TileSource, sketch: &AccumSketch) -> Option<AppendDelta> {
        self.try_sync(x, sketch)
            .expect("incremental gram: tile source read failed")
    }

    /// Fallible core of [`sync`](Self::sync): a kernel-column read off a
    /// file-backed source surfaces as `Err` instead of panicking. The
    /// fallible read happens **before** any state mutation (cache inserts,
    /// Gram rescale, `m_done`), so an error leaves the accumulator
    /// untouched — a retry after the fault clears folds the same terms.
    ///
    /// Cache discipline: the batch's support columns are inserted (or
    /// re-marked) **pinned** — they are the sketch's live support, read
    /// again on every later append and by
    /// [`estimate_leverage`](Self::estimate_leverage), and must not be
    /// evicted mid-update. Pinned bytes may exceed the budget; only the
    /// evictable (seeded) columns compete for what remains.
    pub fn try_sync(
        &mut self,
        x: &dyn TileSource,
        sketch: &AccumSketch,
    ) -> Result<Option<AppendDelta>, CodedError> {
        assert_eq!(x.rows(), self.n, "incremental gram: n mismatch");
        assert_eq!(SketchOps::n(sketch), self.n, "incremental gram: sketch n");
        assert_eq!(SketchOps::d(sketch), self.d, "incremental gram: sketch d");
        let m_new = sketch.m();
        if m_new <= self.m_done {
            return Ok(None);
        }
        let m_old = self.m_done;
        let alpha = ((m_old as f64) / (m_new as f64)).sqrt();

        // gather batch entries (weights already at the final-m scaling)
        // and the distinct support rows, in first-appearance order
        let mut rows: Vec<usize> = Vec::new();
        let mut pos: HashMap<usize, usize> = HashMap::new();
        let mut entries: Vec<(usize, usize, f64)> = Vec::new();
        for t in m_old..m_new {
            for (col, row, w) in sketch.term_entries(t) {
                if !pos.contains_key(&row) {
                    pos.insert(row, rows.len());
                    rows.push(row);
                }
                entries.push((col, row, w));
            }
        }
        let delta_k = rows.len();

        // cache kernel columns for rows not seen before — streamed off the
        // operator's gathered-column path (tile-assembled, never touches a
        // dense K); pinned bytes are `O(n·|support|)`, support ≤ m·d ≪ n.
        // This read is the only fallible step: it runs before any mutation.
        let missing: Vec<usize> = rows
            .iter()
            .copied()
            .filter(|r| !self.kcols.contains(*r))
            .collect();
        if !missing.is_empty() {
            let op = GramOperator::new(self.kernel, x);
            let fresh = op.try_columns(&missing)?; // n × |missing|
            for (c, &row) in missing.iter().enumerate() {
                self.kcols.insert(row, fresh.col(c), true);
            }
            self.kernel_evals += self.n * missing.len();
        }
        // promote already-cached batch rows (seeded or from earlier terms)
        // to pinned: they are live support from here on
        for &row in &rows {
            self.kcols.pin(row);
        }

        // C (d×δ): per-column weight against each distinct support row
        let mut c = Matrix::zeros(self.d, delta_k);
        for &(col, row, w) in &entries {
            c[(col, pos[&row])] += w;
        }
        // Kb (n×δ): cached kernel columns of the batch support
        let mut kb = Matrix::zeros(self.n, delta_k);
        for (u, row) in rows.iter().enumerate() {
            let kcol = self.kcols.get(*row).expect("batch support pinned above");
            for i in 0..self.n {
                kb[(i, u)] = kcol[i];
            }
        }

        // rescale earlier terms: S_old → α·S_old
        self.ks.scale(alpha);
        self.stks.scale(alpha * alpha);
        self.stk2s.scale(alpha * alpha);

        // P = α·KS_old pieces the update formulas share
        let a_cols = matmul_at_b(&self.ks, &kb); // d×δ : Pᵀ·k_u
        let b_rows = Matrix::from_fn(delta_k, self.d, |u, j| self.ks[(rows[u], j)]);
        let guu = syrk_at_a(&kb); // δ×δ : k_uᵀ k_v (symmetric — triangle + mirror)
        let kuu = Matrix::from_fn(delta_k, delta_k, |u, v| {
            self.kcols.get(rows[v]).expect("batch support pinned above")[rows[u]]
        });

        let ct = c.transpose();
        let kt = matmul(&kb, &ct); // n×d : K·T

        // SᵀK²S ← α²·old + Pᵀkt + (Pᵀkt)ᵀ + C·G_UU·Cᵀ
        let cross = matmul(&a_cols, &ct);
        self.stk2s.axpy(1.0, &cross);
        self.stk2s.axpy(1.0, &cross.transpose());
        self.stk2s.axpy(1.0, &matmul(&matmul(&c, &guu), &ct));
        self.stk2s.symmetrize();

        // SᵀKS ← α²·old + C·b_rows + (C·b_rows)ᵀ + C·K_UU·Cᵀ
        let cb = matmul(&c, &b_rows);
        self.stks.axpy(1.0, &cb);
        self.stks.axpy(1.0, &cb.transpose());
        self.stks.axpy(1.0, &matmul(&matmul(&c, &kuu), &ct));
        self.stks.symmetrize();

        // KS ← α·old + K·T
        self.ks.axpy(1.0, &kt);

        self.m_done = m_new;
        Ok(Some(AppendDelta {
            alpha,
            c,
            a_cols,
            b_rows,
            guu,
            kuu,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::kernel_matrix;
    use crate::linalg::matmul_at_b;
    use crate::rng::Pcg64;
    use crate::sketch::{SketchBuilder, SketchKind};

    fn setup(n: usize) -> (Kernel, Matrix, Pcg64) {
        let mut rng = Pcg64::seed(91);
        let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
        (Kernel::gaussian(1.0), x, rng)
    }

    fn assert_close(a: &Matrix, b: &Matrix, tol: f64, what: &str) {
        assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
        for i in 0..a.rows() {
            for j in 0..a.cols() {
                assert!(
                    (a[(i, j)] - b[(i, j)]).abs() < tol,
                    "{what} ({i},{j}): {} vs {}",
                    a[(i, j)],
                    b[(i, j)]
                );
            }
        }
    }

    #[test]
    fn sparse_fast_path_matches_dense_math() {
        let (kernel, x, mut rng) = setup(40);
        let k = kernel_matrix(&kernel, &x);
        for kind in [
            SketchKind::Nystrom,
            SketchKind::Accumulation { m: 5 },
            SketchKind::VerySparse { sparsity: Some(4.0) },
        ] {
            let s = SketchBuilder::new(kind.clone()).build(40, 7, &mut rng);
            let g = sketch_gram(&kernel, &x, &s, None);
            let sd = s.to_dense();
            let ks_ref = matmul(&k, &sd);
            assert_close(&g.ks, &ks_ref, 1e-9, &format!("KS {}", kind.name()));
            let stks_ref = matmul_at_b(&sd, &ks_ref);
            assert_close(&g.stks, &stks_ref, 1e-9, "StKS");
            let stk2s_ref = matmul_at_b(&ks_ref, &ks_ref);
            assert_close(&g.stk2s, &stk2s_ref, 1e-9, "StK2S");
        }
    }

    #[test]
    fn precomputed_k_path_matches() {
        let (kernel, x, mut rng) = setup(25);
        let k = kernel_matrix(&kernel, &x);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 3 }).build(25, 6, &mut rng);
        let with_k = sketch_gram(&kernel, &x, &s, Some(&k));
        let without = sketch_gram(&kernel, &x, &s, None);
        assert_close(&with_k.ks, &without.ks, 1e-9, "KS");
        assert_eq!(with_k.kernel_evals, 0);
        assert!(without.kernel_evals > 0);
    }

    #[test]
    fn dense_sketch_gram() {
        let (kernel, x, mut rng) = setup(20);
        let s = SketchBuilder::new(SketchKind::Gaussian).build(20, 5, &mut rng);
        let g = sketch_gram(&kernel, &x, &s, None);
        assert_eq!((g.ks.rows(), g.ks.cols()), (20, 5));
        assert_eq!(g.kernel_evals, 400);
        // symmetry of StKS
        for i in 0..5 {
            for j in 0..5 {
                assert_eq!(g.stks[(i, j)], g.stks[(j, i)]);
            }
        }
    }

    #[test]
    fn kernel_evals_scale_with_support_not_n_squared() {
        let (kernel, x, mut rng) = setup(60);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 2 }).build(60, 4, &mut rng);
        let g = sketch_gram(&kernel, &x, &s, None);
        // support ≤ m·d = 8 → evals ≤ 60·8 ≪ 60²
        assert!(g.kernel_evals <= 60 * 8);
    }

    /// Tentpole contract: growing term by term accumulates the same Grams
    /// a one-shot rebuild computes (the underlying sketches bit-match, so
    /// the Grams agree to accumulation round-off).
    #[test]
    fn incremental_gram_matches_one_shot_rebuild() {
        let (kernel, x, rng) = setup(50);
        let d = 6;
        let mut grow_rng = rng.clone();
        let mut acc = crate::sketch::AccumSketch::new(50, d);
        let mut inc = IncrementalGram::new(kernel, 50, d);
        for m in [1usize, 2, 4, 7] {
            acc.grow_to(m, &mut grow_rng);
            let delta = inc.sync(&x, &acc).expect("new terms");
            assert!(delta.support_len() >= 1);
            // one-shot from the same stream position the growth started at
            let mut shot_rng = rng.clone();
            let shot =
                SketchBuilder::new(SketchKind::Accumulation { m }).build(50, d, &mut shot_rng);
            let g = sketch_gram(&kernel, &x, &shot, None);
            assert_close(&inc.snapshot().ks, &g.ks, 1e-8, &format!("KS m={m}"));
            assert_close(&inc.snapshot().stks, &g.stks, 1e-8, &format!("StKS m={m}"));
            assert_close(&inc.snapshot().stk2s, &g.stk2s, 1e-8, &format!("StK2S m={m}"));
        }
        // second sync with no growth is a no-op
        assert!(inc.sync(&x, &acc).is_none());
    }

    /// Kernel columns are cached: re-sampled support rows cost no new
    /// kernel evaluations (weighted sampling concentrated on 3 rows).
    #[test]
    fn incremental_gram_caches_kernel_columns() {
        let (kernel, x, mut rng) = setup(40);
        let mut weights = vec![0.0; 40];
        weights[3] = 1.0;
        weights[17] = 1.0;
        weights[29] = 1.0;
        let table = crate::rng::AliasTable::new(&weights);
        let d = 8;
        let mut acc = crate::sketch::AccumSketch::new(40, d)
            .with_sampling(crate::sketch::Sampling::Weighted(table));
        let mut inc = IncrementalGram::new(kernel, 40, d);
        acc.grow_to(1, &mut rng);
        let _ = inc.sync(&x, &acc);
        let evals_after_first = inc.kernel_evals();
        assert!(evals_after_first <= 40 * 3);
        acc.grow_to(6, &mut rng);
        let _ = inc.sync(&x, &acc);
        // support cannot exceed the 3 weighted rows → no new evals
        assert_eq!(inc.kernel_evals(), evals_after_first);
    }

    /// Pre-seeded columns (the BLESS landmark-panel reuse path) make a
    /// sync whose support hits them cost zero kernel evaluations.
    #[test]
    fn seeded_columns_make_sync_free() {
        let (kernel, x, mut rng) = setup(40);
        let rows = [3usize, 17, 29];
        let panel = GramOperator::new(kernel, &x).columns(&rows);
        let mut weights = vec![0.0; 40];
        for &r in &rows {
            weights[r] = 1.0;
        }
        let d = 8;
        let mut acc = crate::sketch::AccumSketch::new(40, d)
            .with_sampling(crate::sketch::Sampling::Weighted(crate::rng::AliasTable::new(
                &weights,
            )));
        let mut inc = IncrementalGram::new(kernel, 40, d);
        inc.seed_columns(&rows, &panel);
        assert_eq!(inc.cached_rows(), rows.to_vec());
        assert_eq!(inc.kernel_evals(), 0, "seeding is free for the consumer");
        acc.grow_to(4, &mut rng);
        let _ = inc.sync(&x, &acc);
        assert_eq!(inc.kernel_evals(), 0, "support ⊆ seeded rows → no evals");
        // and the Grams are identical to an unseeded rebuild
        let g = sketch_gram(&kernel, &x, &acc.as_sketch(), None);
        assert_close(&inc.snapshot().stks, &g.stks, 1e-9, "StKS seeded");
    }

    /// With the full kernel in the cache, the refinement estimator reduces
    /// to the exact ridge leverage scores (the J = [n] identity).
    #[test]
    fn estimate_leverage_exact_at_full_support() {
        let (kernel, x, _) = setup(24);
        let k = kernel_matrix(&kernel, &x);
        let lambda = 1e-2;
        let all: Vec<usize> = (0..24).collect();
        let mut inc = IncrementalGram::new(kernel, 24, 4);
        inc.seed_columns(&all, &k);
        let got = inc.estimate_leverage(&x, lambda).expect("cache non-empty");
        let want = crate::leverage::exact_scores(&k, lambda);
        for i in 0..24 {
            assert!(
                (got[i] - want[i]).abs() < 1e-8,
                "score {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
        // only the diagonal was newly evaluated
        assert_eq!(inc.kernel_evals(), 24);
        // empty cache refuses
        let mut empty = IncrementalGram::new(kernel, 24, 4);
        assert!(empty.estimate_leverage(&x, lambda).is_none());
    }

    /// `AppendDelta::factor_update` reproduces the dense solver-matrix
    /// step: `A_new = α²·A_old + Σ σᵢ vᵢvᵢᵀ`.
    #[test]
    fn append_delta_factors_the_solver_update() {
        let (kernel, x, mut rng) = setup(35);
        let d = 5;
        let nl = 0.7;
        let mut acc = crate::sketch::AccumSketch::new(35, d);
        let mut inc = IncrementalGram::new(kernel, 35, d);
        let mut a_old = Matrix::zeros(d, d);
        for m in [1usize, 3, 5] {
            acc.grow_to(m, &mut rng);
            let delta = inc.sync(&x, &acc).unwrap();
            let (cols, sigma) = delta.factor_update(nl).expect("PD small block");
            let mut a_step = a_old.clone();
            a_step.scale(delta.alpha * delta.alpha);
            for (j, &s) in sigma.iter().enumerate() {
                let v = cols.col(j);
                for i in 0..d {
                    for jj in 0..d {
                        a_step[(i, jj)] += s * v[i] * v[jj];
                    }
                }
            }
            let mut a_new = inc.stk2s().clone();
            a_new.axpy(nl, inc.stks());
            assert_close(&a_step, &a_new, 1e-7, &format!("A update m={m}"));
            a_old = a_new;
        }
    }
}
