//! Growable accumulation sketch — the paper's `S = Σ_{i=1}^{m} S₍ᵢ₎` as a
//! *runtime* object instead of a constructor parameter.
//!
//! [`AccumSketch`] stores the raw draws of every sub-sampling term
//! `S₍ᵢ₎` (per column: a sampled row index and a Rademacher sign) and
//! materialises the accumulated sketch at the *current* term count `m`.
//! Because each entry's weight `r/√(d·m·p)` depends on `m`, appending a
//! term implicitly rescales all earlier terms by `√(m/(m+1))`; storing the
//! m-free draw `(index, sign)` and recomputing the weight on
//! materialisation makes that rescaling exact — growing a sketch from 1 to
//! `m` terms is **bit-identical** to a one-shot
//! [`SketchKind::Accumulation { m }`](super::SketchKind) build from the
//! same RNG stream (both consume draws in term-major order: for each term,
//! for each column, index then sign).
//!
//! This is the substrate of the incremental accumulation engine: the
//! adaptive KRR loop ([`crate::krr::SketchedKrr::fit_adaptive`]) appends
//! terms until a stopping rule fires, and
//! [`IncrementalGram`](super::IncrementalGram) folds each appended term
//! into the sketched Gram matrices without a rebuild.

use super::{Sampling, Sketch, SketchOps, SparseSketch};
use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// One raw sub-sampling draw: sampled row index, Rademacher sign, and the
/// probability the row had *at draw time* (the `1/√(d·m·p)` rescaling is
/// applied at materialisation time, where `m` is known). Storing `p` with
/// the draw keeps earlier terms correctly scaled when the sampling
/// distribution is refined between terms ([`AccumSketch::set_sampling`]):
/// each term is unbiased under its own draw distribution, so the
/// accumulated `E[SSᵀ] = Iₙ` survives the switch.
type RawEntry = (usize, f64, f64);

/// A growable accumulation sketch `S = Σ_{i=1}^{m} S₍ᵢ₎` over `n` points
/// with projection dimension `d`.
#[derive(Clone, Debug)]
pub struct AccumSketch {
    n: usize,
    d: usize,
    sampling: Sampling,
    signed: bool,
    /// `terms[i][j]` = (row index, sign, draw-time probability) of term
    /// `i`'s single non-zero in column `j`.
    terms: Vec<Vec<RawEntry>>,
    /// Materialised sparse view at the current `m` (kept in sync by the
    /// grow operations).
    sparse: SparseSketch,
}

impl AccumSketch {
    /// Empty sketch (`m = 0`) with uniform sampling.
    pub fn new(n: usize, d: usize) -> AccumSketch {
        assert!(n > 0 && d > 0, "accum sketch: empty dims");
        AccumSketch {
            n,
            d,
            sampling: Sampling::Uniform,
            signed: true,
            terms: Vec::new(),
            sparse: SparseSketch::new(n, vec![Vec::new(); d]),
        }
    }

    /// Override the sampling distribution (e.g. leverage scores).
    pub fn with_sampling(mut self, sampling: Sampling) -> AccumSketch {
        assert!(self.terms.is_empty(), "set sampling before growing");
        assert!(
            !matches!(sampling, Sampling::Poisson(_)),
            "accum sketch: Poisson is a per-row inclusion scheme, not a \
             per-column draw — build it via SketchBuilder / PoissonSketch"
        );
        self.sampling = sampling;
        self
    }

    /// Switch the sampling distribution *mid-growth* (the between-term
    /// probability refinement of
    /// [`fit_adaptive`](crate::krr::SketchedKrr::fit_adaptive)). Only
    /// future draws use the new distribution; already-appended terms keep
    /// the probabilities they were drawn under (stored per entry), so their
    /// weights — and the sketch's unbiasedness — are unaffected.
    pub fn set_sampling(&mut self, sampling: Sampling) {
        assert!(
            !matches!(sampling, Sampling::Poisson(_)),
            "accum sketch: Poisson is a per-row inclusion scheme, not a \
             per-column draw — build it via SketchBuilder / PoissonSketch"
        );
        self.sampling = sampling;
    }

    /// Disable the Rademacher signs (classical Nyström at `m = 1`).
    pub fn unsigned(mut self) -> AccumSketch {
        assert!(self.terms.is_empty(), "set signedness before growing");
        self.signed = false;
        self
    }

    /// Number of accumulated terms `m` so far.
    pub fn m(&self) -> usize {
        self.terms.len()
    }

    /// Sampling distribution used for the draws.
    pub fn sampling(&self) -> &Sampling {
        &self.sampling
    }

    /// Stable name for manifests / bench output (`accum_m{m}`), consistent
    /// with [`SketchKind::Accumulation`](super::SketchKind).
    pub fn name(&self) -> String {
        format!("accum_m{}", self.m())
    }

    /// Append one sub-sampling term `S₍ᵢ₎`, drawing `d` (index, sign)
    /// pairs from `rng` in column order — exactly the draws a one-shot
    /// build consumes for its `i`-th term.
    pub fn append_term(&mut self, rng: &mut Pcg64) {
        self.push_raw_term(rng);
        self.rebuild();
    }

    /// Grow to `m` terms (no-op if already at or beyond `m`). Equivalent
    /// to calling [`append_term`](Self::append_term) in a loop but only
    /// materialises once.
    pub fn grow_to(&mut self, m: usize, rng: &mut Pcg64) {
        if m <= self.terms.len() {
            return;
        }
        while self.terms.len() < m {
            self.push_raw_term(rng);
        }
        self.rebuild();
    }

    fn push_raw_term(&mut self, rng: &mut Pcg64) {
        let mut term = Vec::with_capacity(self.d);
        for _ in 0..self.d {
            let (j, p) = match &self.sampling {
                Sampling::Uniform => {
                    let j = rng.below(self.n as u64) as usize;
                    (j, 1.0 / self.n as f64)
                }
                Sampling::Weighted(t) => {
                    let j = t.sample(rng);
                    (j, t.p(j))
                }
                Sampling::Poisson(_) => {
                    unreachable!("rejected by with_sampling/set_sampling")
                }
            };
            let r = if self.signed { rng.rademacher() } else { 1.0 };
            term.push((j, r, p));
        }
        self.terms.push(term);
    }

    /// Entries of term `i` at the *current* scaling: `(column, row,
    /// weight)` with `weight = sign/√(d·m·p_row)`, `p_row` being the
    /// probability stored at draw time. Consumed by
    /// [`IncrementalGram`](super::IncrementalGram) when folding appended
    /// terms into the Gram matrices.
    pub fn term_entries(&self, i: usize) -> Vec<(usize, usize, f64)> {
        let dm = (self.d * self.m()) as f64;
        self.terms[i]
            .iter()
            .enumerate()
            .map(|(col, &(row, sign, p))| (col, row, sign / (dm * p).sqrt()))
            .collect()
    }

    /// Rebuild the materialised sparse view at the current `m`. Weights
    /// use the same expression as the one-shot builder
    /// (`sign / √((d·m)·p)`) with the draw-time `p`, so grown and one-shot
    /// sketches bit-match.
    fn rebuild(&mut self) {
        let m = self.terms.len();
        let dm = (self.d * m) as f64;
        let mut cols: Vec<Vec<(usize, f64)>> = vec![Vec::with_capacity(m); self.d];
        for term in &self.terms {
            for (col, &(row, sign, p)) in term.iter().enumerate() {
                cols[col].push((row, sign / (dm * p).sqrt()));
            }
        }
        self.sparse = SparseSketch::new(self.n, cols);
    }

    /// The materialised sparse sketch at the current `m`.
    pub fn sparse(&self) -> &SparseSketch {
        &self.sparse
    }

    /// Clone into the [`Sketch`] enum (for APIs taking any sketch).
    pub fn as_sketch(&self) -> Sketch {
        Sketch::Sparse(self.sparse.clone())
    }
}

impl SketchOps for AccumSketch {
    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn nnz(&self) -> usize {
        self.sparse.nnz()
    }

    fn to_dense(&self) -> Matrix {
        self.sparse.to_dense()
    }

    fn st_mat(&self, b: &Matrix) -> Matrix {
        self.sparse.st_mat(b)
    }

    fn st_vec(&self, v: &[f64]) -> Vec<f64> {
        self.sparse.st_vec(v)
    }

    fn s_vec(&self, w: &[f64]) -> Vec<f64> {
        self.sparse.s_vec(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::{SketchBuilder, SketchKind};

    /// The tentpole determinism contract: growing 1 → m bit-matches a
    /// one-shot `Accumulation { m }` build from the same RNG stream.
    #[test]
    fn grown_sketch_bit_matches_one_shot() {
        let (n, d, m) = (120, 9, 8);
        let mut rng_grow = Pcg64::seed(0x51de);
        let mut rng_shot = Pcg64::seed(0x51de);
        let mut acc = AccumSketch::new(n, d);
        for _ in 0..m {
            acc.append_term(&mut rng_grow);
        }
        let shot = SketchBuilder::new(SketchKind::Accumulation { m }).build(n, d, &mut rng_shot);
        let Sketch::Sparse(shot) = shot else {
            panic!("accumulation builds sparse")
        };
        assert_eq!(acc.m(), m);
        for j in 0..d {
            let a = acc.sparse().col(j);
            let b = shot.col(j);
            assert_eq!(a.len(), b.len(), "col {j} nnz");
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.0, y.0, "col {j} index");
                assert_eq!(x.1.to_bits(), y.1.to_bits(), "col {j} weight bits");
            }
        }
        // and the RNG streams are in the same position afterwards
        assert_eq!(rng_grow.next_u64(), rng_shot.next_u64());
    }

    #[test]
    fn grow_to_matches_append_loop() {
        let mut r1 = Pcg64::seed(77);
        let mut r2 = Pcg64::seed(77);
        let mut a = AccumSketch::new(50, 6);
        let mut b = AccumSketch::new(50, 6);
        a.grow_to(5, &mut r1);
        for _ in 0..5 {
            b.append_term(&mut r2);
        }
        assert_eq!(a.sparse().nnz(), b.sparse().nnz());
        for j in 0..6 {
            assert_eq!(a.sparse().col(j), b.sparse().col(j));
        }
    }

    #[test]
    fn rescaling_shrinks_earlier_terms() {
        let mut rng = Pcg64::seed(3);
        let mut acc = AccumSketch::new(40, 4);
        acc.append_term(&mut rng);
        let w1 = acc.sparse().col(0)[0].1.abs();
        acc.append_term(&mut rng);
        let w2 = acc.sparse().col(0)[0].1.abs();
        // same raw draw, rescaled by √(1/2)
        assert!((w2 - w1 / 2f64.sqrt()).abs() < 1e-12, "{w2} vs {w1}/√2");
    }

    #[test]
    fn term_entries_match_materialised_columns() {
        let mut rng = Pcg64::seed(4);
        let mut acc = AccumSketch::new(30, 5);
        acc.grow_to(3, &mut rng);
        for i in 0..3 {
            for (col, row, w) in acc.term_entries(i) {
                let &(r, wv) = &acc.sparse().col(col)[i];
                assert_eq!(r, row);
                assert_eq!(wv.to_bits(), w.to_bits());
            }
        }
    }

    /// Same contract for *weighted* draws: growing 1 → m with a leverage-
    /// style table bit-matches the one-shot weighted build from the same
    /// RNG stream (draws stay term-major; the alias table consumes the
    /// same two u64s per index either way).
    #[test]
    fn weighted_grown_sketch_bit_matches_one_shot() {
        let (n, d, m) = (80, 7, 6);
        let weights: Vec<f64> = (0..n).map(|i| 1.0 + (i % 13) as f64).collect();
        let table = crate::rng::AliasTable::new(&weights);
        let mut rng_grow = Pcg64::seed(0x1e7a);
        let mut rng_shot = Pcg64::seed(0x1e7a);
        let mut acc = AccumSketch::new(n, d).with_sampling(Sampling::Weighted(table.clone()));
        for _ in 0..m {
            acc.append_term(&mut rng_grow);
        }
        let shot = SketchBuilder::new(SketchKind::Accumulation { m })
            .with_sampling(Sampling::Weighted(table))
            .build(n, d, &mut rng_shot);
        let Sketch::Sparse(shot) = shot else {
            panic!("accumulation builds sparse")
        };
        for j in 0..d {
            let a = acc.sparse().col(j);
            let b = shot.col(j);
            assert_eq!(a.len(), b.len(), "col {j} nnz");
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.0, y.0, "col {j} index");
                assert_eq!(x.1.to_bits(), y.1.to_bits(), "col {j} weight bits");
            }
        }
        assert_eq!(rng_grow.next_u64(), rng_shot.next_u64());
    }

    /// Refining the distribution mid-growth must leave already-drawn terms
    /// bit-untouched (their weights come from the stored draw-time
    /// probabilities, modulo the √(m_old/m_new) accumulation rescale).
    #[test]
    fn set_sampling_preserves_earlier_term_weights() {
        let (n, d) = (60, 5);
        let mut rng = Pcg64::seed(0xbe5);
        let mut acc = AccumSketch::new(n, d);
        acc.grow_to(2, &mut rng);
        let before: Vec<Vec<(usize, f64)>> = (0..d).map(|j| acc.sparse().col(j).to_vec()).collect();
        let weights: Vec<f64> = (0..n).map(|i| ((i * 7) % 11 + 1) as f64).collect();
        acc.set_sampling(Sampling::Weighted(crate::rng::AliasTable::new(&weights)));
        acc.grow_to(4, &mut rng);
        // the first two entries of every column are the original draws,
        // rescaled exactly by √(2/4)
        let alpha = (2.0f64 / 4.0).sqrt();
        for j in 0..d {
            let after = acc.sparse().col(j);
            assert_eq!(after.len(), 4);
            for (t, &(row, w)) in before[j].iter().enumerate() {
                assert_eq!(after[t].0, row, "col {j} term {t} row");
                assert!(
                    (after[t].1 - w * alpha).abs() < 1e-12 * w.abs().max(1.0),
                    "col {j} term {t} weight: {} vs {}",
                    after[t].1,
                    w * alpha
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "Poisson")]
    fn accum_sketch_rejects_poisson_sampling() {
        let table = crate::rng::AliasTable::new(&[1.0; 8]);
        let _ = AccumSketch::new(8, 2).with_sampling(Sampling::Poisson(table));
    }

    #[test]
    fn empty_sketch_has_zero_terms() {
        let acc = AccumSketch::new(10, 3);
        assert_eq!(acc.m(), 0);
        assert_eq!(acc.nnz(), 0);
        assert_eq!(acc.name(), "accum_m0");
    }

    #[test]
    fn sketch_ops_delegate_to_sparse_view() {
        let mut rng = Pcg64::seed(5);
        let mut acc = AccumSketch::new(25, 4);
        acc.grow_to(2, &mut rng);
        let dense = acc.to_dense();
        let v: Vec<f64> = (0..25).map(|i| i as f64).collect();
        let via_acc = acc.st_vec(&v);
        let via_dense = dense.matvec_t(&v);
        for (a, b) in via_acc.iter().zip(via_dense.iter()) {
            assert!((a - b).abs() < 1e-10);
        }
        assert_eq!(SketchOps::n(&acc), 25);
        assert_eq!(SketchOps::d(&acc), 4);
    }
}
