//! Two further classical sketches for completeness of the framework
//! comparison: the Subsampled Randomized Hadamard Transform (SRHT) and
//! CountSketch (sparse Johnson–Lindenstrauss).
//!
//! * **SRHT**: `S = √(n/d)·D·H·Pᵀ/√n` columns — here materialised as a
//!   dense n×d matrix `(1/√d)·D H[:, idx]` with `H` the Walsh–Hadamard
//!   matrix (power-of-two padded), `D` random signs, `idx` sampled columns.
//!   Sub-Gaussian-like rows with `E[SSᵀ] = I`; the classical "fast JL"
//!   baseline.
//! * **CountSketch**: every *row* i is assigned one random column `h(i)`
//!   with sign `s(i)` — exactly one non-zero per row, `E[SSᵀ] = I`. Its
//!   transpose-apply is `O(n)`; unlike sub-sampling sketches it never
//!   drops rows, but it collides them.
//!
//! Both integrate with [`super::Sketch`] so every bench/diagnostic in the
//! crate (K-satisfiability, cost ablations, KRR fits) can run over them.

use super::sparse::SparseSketch;
use super::Sketch;
#[cfg(test)]
use super::SketchOps;
use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// Next power of two ≥ x.
fn next_pow2(x: usize) -> usize {
    let mut p = 1;
    while p < x {
        p <<= 1;
    }
    p
}

/// In-place Walsh–Hadamard transform of a power-of-two-length vector
/// (unnormalised).
pub fn fwht(v: &mut [f64]) {
    let n = v.len();
    debug_assert!(n.is_power_of_two());
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let (a, b) = (v[j], v[j + h]);
                v[j] = a + b;
                v[j + h] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

/// Draw an SRHT sketch as a dense n×d matrix.
///
/// Construction: pad to N = 2^k, apply signs `D`, pick `d` random
/// Hadamard columns, scale by `1/√(d·N/n)`·(1/√n)… normalised so that
/// `E[s sᵀ] = Iₙ/d` per column (matching every other sketch here).
pub fn srht(n: usize, d: usize, rng: &mut Pcg64) -> Sketch {
    let big_n = next_pow2(n);
    // column c of (D·H) is D ⊙ H[:, c]; we build d of them.
    let signs: Vec<f64> = (0..n).map(|_| rng.rademacher()).collect();
    let cols: Vec<usize> = (0..d).map(|_| rng.below(big_n as u64) as usize).collect();
    let mut s = Matrix::zeros(n, d);
    // H[i, c] = (−1)^{popcount(i & c)}; entries ±1/√d give E[s sᵀ] = Iₙ/d
    // per column (matching every other construction in this crate)
    let scale = 1.0 / (d as f64).sqrt();
    for i in 0..n {
        let si = signs[i] * scale;
        let row = s.row_mut(i);
        for (j, &c) in cols.iter().enumerate() {
            let h = if ((i & c).count_ones() & 1) == 0 { 1.0 } else { -1.0 };
            row[j] = si * h;
        }
    }
    Sketch::Dense(s)
}

/// Draw a CountSketch as a sparse n×d matrix (one non-zero per *row*).
pub fn countsketch(n: usize, d: usize, rng: &mut Pcg64) -> Sketch {
    let mut cols: Vec<Vec<(usize, f64)>> = vec![Vec::new(); d];
    for i in 0..n {
        let j = rng.below(d as u64) as usize;
        cols[j].push((i, rng.rademacher()));
    }
    Sketch::Sparse(SparseSketch::new(n, cols))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul_a_bt;

    #[test]
    fn fwht_matches_definition() {
        let mut v = vec![1.0, 0.0, 0.0, 0.0];
        fwht(&mut v);
        assert_eq!(v, vec![1.0, 1.0, 1.0, 1.0]);
        let mut w = vec![1.0, 2.0, 3.0, 4.0];
        fwht(&mut w);
        // H4 * [1,2,3,4] = [10, -2, -4, 0]
        assert_eq!(w, vec![10.0, -2.0, -4.0, 0.0]);
    }

    #[test]
    fn fwht_self_inverse_up_to_n() {
        let mut rng = Pcg64::seed(0x5a);
        let orig: Vec<f64> = (0..16).map(|_| rng.normal()).collect();
        let mut v = orig.clone();
        fwht(&mut v);
        fwht(&mut v);
        for (a, b) in v.iter().zip(orig.iter()) {
            assert!((a / 16.0 - b).abs() < 1e-12);
        }
    }

    #[test]
    fn srht_expectation_identity() {
        let mut rng = Pcg64::seed(0x5b);
        let n = 6;
        let reps = 3000;
        let mut acc = Matrix::zeros(n, n);
        for _ in 0..reps {
            let Sketch::Dense(s) = srht(n, 24, &mut rng) else { panic!() };
            let sst = matmul_a_bt(&s, &s);
            acc.axpy(1.0 / reps as f64, &sst);
        }
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (acc[(i, j)] - want).abs() < 0.15,
                    "({i},{j}) = {}",
                    acc[(i, j)]
                );
            }
        }
    }

    #[test]
    fn countsketch_one_nnz_per_row() {
        let mut rng = Pcg64::seed(0x5c);
        let s = countsketch(50, 8, &mut rng);
        assert_eq!(s.nnz(), 50);
        let dense = s.to_dense();
        for i in 0..50 {
            let nnz = (0..8).filter(|&j| dense[(i, j)] != 0.0).count();
            assert_eq!(nnz, 1, "row {i}");
            let val: f64 = (0..8).map(|j| dense[(i, j)].abs()).sum();
            assert!((val - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn countsketch_expectation_identity() {
        let mut rng = Pcg64::seed(0x5d);
        let n = 5;
        let reps = 4000;
        let mut acc = Matrix::zeros(n, n);
        for _ in 0..reps {
            let s = countsketch(n, 16, &mut rng).to_dense();
            let sst = matmul_a_bt(&s, &s);
            acc.axpy(1.0 / reps as f64, &sst);
        }
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((acc[(i, j)] - want).abs() < 0.1, "({i},{j})");
            }
        }
    }

    #[test]
    fn both_work_in_sketched_krr() {
        use crate::kernels::Kernel;
        use crate::krr::SketchedKrr;
        let mut rng = Pcg64::seed(0x5e);
        let n = 60;
        let x = Matrix::from_fn(n, 2, |_, _| rng.uniform());
        let y: Vec<f64> = (0..n).map(|i| (4.0 * x[(i, 0)]).sin()).collect();
        for s in [srht(n, 20, &mut rng), countsketch(n, 20, &mut rng)] {
            let m = SketchedKrr::fit(Kernel::gaussian(0.4), &x, &y, &s, 1e-4, None)
                .expect("fit with srht/countsketch");
            let mse = crate::stats::mse(m.fitted(), &y);
            assert!(mse < 0.3, "mse {mse}");
        }
    }
}
