//! Sketch constructions — Algorithm 1 of the paper plus every baseline the
//! evaluation compares against.

use super::sparse::SparseSketch;
use super::{AccumSketch, PoissonSketch, Sampling, Sketch};
use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// Which sketch construction to use.
#[derive(Clone, Debug, PartialEq)]
pub enum SketchKind {
    /// Classical Nyström: one sub-sampling matrix, *without* random signs
    /// (the signs cancel in `K_S` anyway — paper §3.1 — but plain Nyström is
    /// the conventional baseline form).
    Nystrom,
    /// The paper's Algorithm 1: accumulation of `m` rescaled, randomly
    /// signed sub-sampling matrices. `m = 1` is a randomly-signed
    /// sub-sampling sketch.
    Accumulation {
        /// Number of accumulated sub-sampling matrices.
        m: usize,
    },
    /// Dense Gaussian sketch, entries `N(0, 1/d)` — the `m = ∞` extreme.
    Gaussian,
    /// Dense Rademacher sketch, entries `±1/√d` (sub-Gaussian baseline).
    Rademacher,
    /// Very sparse random projection (Li, Hastie & Church 2006): entries
    /// `√(s/d)·{+1 w.p. 1/2s, 0 w.p. 1−1/s, −1 w.p. 1/2s}`. The canonical
    /// choice `s = √n` is applied when `sparsity` is `None`.
    VerySparse {
        /// `s` parameter; `None` → `√n`.
        sparsity: Option<f64>,
    },
}

impl SketchKind {
    /// Stable name for manifests / bench output. Parameterised kinds
    /// include their parameter (`accum_m4`, `verysparse_s20`) so bench
    /// manifests distinguish sweep settings.
    pub fn name(&self) -> String {
        match self {
            SketchKind::Nystrom => "nystrom".into(),
            SketchKind::Accumulation { m } => format!("accum_m{m}"),
            SketchKind::Gaussian => "gaussian".into(),
            SketchKind::Rademacher => "rademacher".into(),
            SketchKind::VerySparse { sparsity: Some(s) } => {
                if s.fract() == 0.0 {
                    format!("verysparse_s{}", *s as u64)
                } else {
                    format!("verysparse_s{s}")
                }
            }
            // s defaults to √n, which is unknown until build time
            SketchKind::VerySparse { sparsity: None } => "verysparse_sauto".into(),
        }
    }
}

/// Configured sketch factory: kind + sampling distribution.
#[derive(Clone, Debug)]
pub struct SketchBuilder {
    kind: SketchKind,
    sampling: Sampling,
}

impl SketchBuilder {
    /// Builder with uniform sampling (the paper's default).
    pub fn new(kind: SketchKind) -> Self {
        SketchBuilder {
            kind,
            sampling: Sampling::Uniform,
        }
    }

    /// Override the sampling distribution (e.g. leverage scores, or
    /// [`Sampling::Poisson`] to switch the sub-sampling kinds to per-row
    /// independent inclusion).
    pub fn with_sampling(mut self, sampling: Sampling) -> Self {
        self.sampling = sampling;
        self
    }

    /// The configured kind.
    pub fn kind(&self) -> &SketchKind {
        &self.kind
    }

    /// The configured sampling distribution.
    pub fn sampling(&self) -> &Sampling {
        &self.sampling
    }

    /// Draw a sketch `S ∈ ℝ^{n×d}`.
    ///
    /// Sub-sampling kinds (Nyström / accumulation) are built by growing an
    /// [`AccumSketch`] term by term, so a one-shot `Accumulation { m }`
    /// build is *defined* to bit-match a sketch grown 1 → m from the same
    /// RNG stream (draws are consumed term-major: for each term, for each
    /// column, index then sign).
    /// [`Sampling::Poisson`] routes the sub-sampling kinds (Nyström /
    /// accumulation) to a [`PoissonSketch`] instead: one independent
    /// inclusion pass at target dimension `d` (Poisson replaces *both* the
    /// column draws and the accumulation count, so `m` does not apply and
    /// `Accumulation` is rejected — grow the expected dimension via
    /// [`PoissonSketch::grow_to`] instead). Dense kinds ignore the sampling
    /// distribution as before.
    pub fn build(&self, n: usize, d: usize, rng: &mut Pcg64) -> Sketch {
        assert!(n > 0 && d > 0, "sketch: empty dims");
        match &self.kind {
            SketchKind::Nystrom => {
                if matches!(self.sampling, Sampling::Poisson(_)) {
                    return PoissonSketch::draw(n, d, &self.sampling, rng).as_sketch();
                }
                let mut acc = AccumSketch::new(n, d)
                    .with_sampling(self.sampling.clone())
                    .unsigned();
                acc.grow_to(1, rng);
                acc.as_sketch()
            }
            SketchKind::Accumulation { m } => {
                assert!(*m >= 1, "accumulation: m >= 1");
                assert!(
                    !matches!(self.sampling, Sampling::Poisson(_)),
                    "poisson sampling is a one-shot inclusion scheme: use \
                     SketchKind::Nystrom (or PoissonSketch directly) and grow d, not m"
                );
                let mut acc = AccumSketch::new(n, d).with_sampling(self.sampling.clone());
                acc.grow_to(*m, rng);
                acc.as_sketch()
            }
            SketchKind::Gaussian => {
                let scale = 1.0 / (d as f64).sqrt();
                Sketch::Dense(Matrix::from_fn(n, d, |_, _| rng.normal() * scale))
            }
            SketchKind::Rademacher => {
                let scale = 1.0 / (d as f64).sqrt();
                Sketch::Dense(Matrix::from_fn(n, d, |_, _| rng.rademacher() * scale))
            }
            SketchKind::VerySparse { sparsity } => {
                let s = sparsity.unwrap_or_else(|| (n as f64).sqrt()).max(1.0);
                let mag = (s / d as f64).sqrt();
                let p_nonzero = 1.0 / s;
                let mut cols = Vec::with_capacity(d);
                for _ in 0..d {
                    let mut col = Vec::new();
                    for i in 0..n {
                        let u = rng.uniform();
                        if u < p_nonzero {
                            let sign = if u < p_nonzero * 0.5 { 1.0 } else { -1.0 };
                            col.push((i, sign * mag));
                        }
                    }
                    cols.push(col);
                }
                Sketch::Sparse(SparseSketch::new(n, cols))
            }
        }
    }

    /// Start an empty growable accumulation sketch with this builder's
    /// sampling distribution — the entry point of the adaptive-m loop,
    /// which appends terms until a stopping rule fires instead of fixing
    /// `m` up front.
    pub fn grower(&self, n: usize, d: usize) -> AccumSketch {
        AccumSketch::new(n, d).with_sampling(self.sampling.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_a_bt};
    use crate::rng::AliasTable;
    use crate::sketch::SketchOps;

    /// E[S Sᵀ] = I/… : every construction is normalised so each column has
    /// E[s sᵀ] = Iₙ/d, hence E[S Sᵀ] = Iₙ. Check empirically.
    fn empirical_ssT_close_to_identity(kind: SketchKind, n: usize, d: usize, reps: usize, tol: f64) {
        let mut rng = Pcg64::seed(0xbeef);
        let builder = SketchBuilder::new(kind);
        let mut acc = Matrix::zeros(n, n);
        for _ in 0..reps {
            let s = builder.build(n, d, &mut rng).to_dense();
            let sst = matmul_a_bt(&s, &s);
            acc.axpy(1.0 / reps as f64, &sst);
        }
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (acc[(i, j)] - want).abs() < tol,
                    "({i},{j}) = {} want {want}",
                    acc[(i, j)]
                );
            }
        }
    }

    /// Same check for an arbitrary builder (non-uniform sampling included —
    /// the `1/√(d·m·pᵢ)` rescale must make *any* base distribution
    /// unbiased).
    fn empirical_ssT_for_builder(builder: SketchBuilder, n: usize, d: usize, reps: usize, tol: f64) {
        let mut rng = Pcg64::seed(0xbeef);
        let mut acc = Matrix::zeros(n, n);
        for _ in 0..reps {
            let s = builder.build(n, d, &mut rng).to_dense();
            let sst = matmul_a_bt(&s, &s);
            acc.axpy(1.0 / reps as f64, &sst);
        }
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (acc[(i, j)] - want).abs() < tol,
                    "({i},{j}) = {} want {want}",
                    acc[(i, j)]
                );
            }
        }
    }

    #[test]
    fn nystrom_expectation_identity() {
        empirical_ssT_close_to_identity(SketchKind::Nystrom, 6, 40, 4000, 0.15);
    }

    /// E[SSᵀ] = I for *weighted* accumulation draws (the leverage-fed
    /// scheme): skewed base probabilities, seeded Monte Carlo, pinned
    /// tolerance.
    #[test]
    fn weighted_accumulation_expectation_identity() {
        let table = AliasTable::new(&[1.0, 2.0, 3.0, 4.0, 5.0, 9.0]);
        empirical_ssT_for_builder(
            SketchBuilder::new(SketchKind::Accumulation { m: 4 })
                .with_sampling(Sampling::Weighted(table)),
            6,
            40,
            6000,
            0.15,
        );
    }

    /// E[SSᵀ] = I for Poisson inclusion over a skewed base distribution
    /// (small d/n keeps every πᵢ < 1 so the random regime is exercised).
    #[test]
    fn poisson_expectation_identity() {
        let table = AliasTable::new(&[1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
        empirical_ssT_for_builder(
            SketchBuilder::new(SketchKind::Nystrom).with_sampling(Sampling::Poisson(table)),
            6,
            2,
            6000,
            0.15,
        );
    }

    #[test]
    fn poisson_builder_routes_to_poisson_sketch() {
        let n = 50;
        let mut rng = Pcg64::seed(0x90);
        let b = SketchBuilder::new(SketchKind::Nystrom)
            .with_sampling(Sampling::Poisson(AliasTable::uniform(n)));
        let s = b.build(n, 10, &mut rng);
        let Sketch::Sparse(sp) = &s else {
            panic!("poisson builds sparse")
        };
        // every column is a single row with weight 1/√π, π = 10/50
        let want = (50.0f64 / 10.0).sqrt();
        for j in 0..sp.d() {
            assert_eq!(sp.col(j).len(), 1);
            assert!((sp.col(j)[0].1 - want).abs() < 1e-12);
        }
    }

    #[test]
    fn accumulation_expectation_identity() {
        empirical_ssT_close_to_identity(SketchKind::Accumulation { m: 4 }, 6, 40, 4000, 0.15);
    }

    #[test]
    fn gaussian_expectation_identity() {
        empirical_ssT_close_to_identity(SketchKind::Gaussian, 6, 40, 2000, 0.15);
    }

    #[test]
    fn verysparse_expectation_identity() {
        empirical_ssT_close_to_identity(
            SketchKind::VerySparse { sparsity: Some(3.0) },
            6,
            40,
            4000,
            0.15,
        );
    }

    #[test]
    fn names_include_parameters() {
        assert_eq!(SketchKind::Accumulation { m: 8 }.name(), "accum_m8");
        assert_eq!(
            SketchKind::VerySparse { sparsity: Some(20.0) }.name(),
            "verysparse_s20"
        );
        assert_eq!(
            SketchKind::VerySparse { sparsity: Some(2.5) }.name(),
            "verysparse_s2.5"
        );
        assert_eq!(SketchKind::VerySparse { sparsity: None }.name(), "verysparse_sauto");
    }

    #[test]
    fn nystrom_has_one_nnz_per_column() {
        let mut rng = Pcg64::seed(81);
        let s = SketchBuilder::new(SketchKind::Nystrom).build(100, 12, &mut rng);
        assert_eq!(s.nnz(), 12);
        if let Sketch::Sparse(sp) = &s {
            for j in 0..12 {
                assert_eq!(sp.col(j).len(), 1);
                // uniform scaling: 1/√(d·1·(1/n)) = √(n/d)
                let w = sp.col(j)[0].1;
                assert!((w - (100.0f64 / 12.0).sqrt()).abs() < 1e-12);
            }
        } else {
            panic!("expected sparse");
        }
    }

    #[test]
    fn accumulation_has_m_nnz_per_column_with_signs() {
        let mut rng = Pcg64::seed(82);
        let m = 7;
        let s = SketchBuilder::new(SketchKind::Accumulation { m }).build(200, 9, &mut rng);
        assert_eq!(s.nnz(), 9 * m);
        if let Sketch::Sparse(sp) = &s {
            let expect = (200.0f64 / (9.0 * m as f64)).sqrt();
            let mut saw_neg = false;
            for j in 0..9 {
                for &(_, w) in sp.col(j) {
                    assert!((w.abs() - expect).abs() < 1e-12);
                    saw_neg |= w < 0.0;
                }
            }
            assert!(saw_neg, "random signs should produce some negatives");
        }
    }

    #[test]
    fn weighted_sampling_rescales_by_prob() {
        let mut rng = Pcg64::seed(83);
        let n = 5;
        let weights = vec![1.0, 2.0, 3.0, 4.0, 10.0];
        let table = AliasTable::new(&weights);
        let b = SketchBuilder::new(SketchKind::Nystrom)
            .with_sampling(Sampling::Weighted(table.clone()));
        let s = b.build(n, 50, &mut rng);
        if let Sketch::Sparse(sp) = &s {
            for j in 0..50 {
                let (i, w) = sp.col(j)[0];
                let want = 1.0 / (50.0 * table.p(i)).sqrt();
                assert!((w - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn signs_cancel_in_gram() {
        // SᵀKS with K = I: accumulation sketch gram must be PSD regardless
        // of signs.
        let mut rng = Pcg64::seed(84);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 3 })
            .build(30, 6, &mut rng)
            .to_dense();
        let gram = matmul(&s.transpose(), &s);
        let eig = crate::linalg::eigh(&gram);
        assert!(eig.w.iter().all(|&w| w > -1e-10));
    }

    #[test]
    fn verysparse_default_density_about_sqrt_n() {
        let mut rng = Pcg64::seed(85);
        let n = 400; // s = 20 → E[nnz per column] = n/s = 20
        let s = SketchBuilder::new(SketchKind::VerySparse { sparsity: None })
            .build(n, 30, &mut rng);
        let per_col = s.nnz() as f64 / 30.0;
        assert!((per_col - 20.0).abs() < 6.0, "per_col={per_col}");
    }
}
