//! Localized (block-diagonal) sketching — Srinivasa, Davenport & Romberg
//! (2020), the distributed/streaming-oriented alternative the paper's
//! introduction contrasts with (§1: "localized sketching assumes the data
//! is partitioned in advance").
//!
//! The data is split into `B` contiguous blocks; block `b` of size `n_b`
//! gets its own small sub-sketch `S_b ∈ ℝ^{n_b × d_b}` (Gaussian or
//! signed-subsample), and `S = blockdiag(S₁, …, S_B)` with
//! `Σ d_b = d`. Each block's sketch only touches that block's rows — the
//! property that makes it distributable, and also what costs it accuracy
//! when the information is not evenly spread across blocks (exactly the
//! paper's incoherence story).

use super::sparse::SparseSketch;
use super::Sketch;
#[cfg(test)]
use super::SketchOps;
use crate::rng::Pcg64;

/// Block-local sketch type.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LocalKind {
    /// Dense Gaussian entries within each block (stored sparsely: the
    /// block pattern keeps column nnz = block size).
    Gaussian,
    /// Signed sub-sampling within each block.
    Subsample,
}

/// Draw a localized block-diagonal sketch over `blocks` contiguous data
/// partitions. The projection dimension d is split proportionally to block
/// sizes (at least 1 column per block).
pub fn localized(
    n: usize,
    d: usize,
    blocks: usize,
    kind: LocalKind,
    rng: &mut Pcg64,
) -> Sketch {
    assert!(blocks >= 1 && blocks <= n && d >= blocks, "localized: need d ≥ blocks ≤ n");
    // contiguous block boundaries
    let base = n / blocks;
    let rem = n % blocks;
    let mut cols: Vec<Vec<(usize, f64)>> = Vec::with_capacity(d);
    let mut start = 0usize;
    let mut d_used = 0usize;
    for b in 0..blocks {
        let nb = base + usize::from(b < rem);
        // proportional share of d (last block takes the remainder)
        let db = if b + 1 == blocks {
            d - d_used
        } else {
            ((d as f64 * nb as f64 / n as f64).round() as usize).clamp(1, d - d_used - (blocks - b - 1))
        };
        d_used += db;
        for _ in 0..db {
            let col = match kind {
                LocalKind::Gaussian => {
                    // entries N(0, 1/d_b) within the block: the block's d_b
                    // columns give E[S_b S_bᵀ] = I_{n_b}, so the block
                    // diagonal satisfies E[SSᵀ] = Iₙ like every other
                    // construction in this crate.
                    (start..start + nb)
                        .map(|i| (i, rng.normal() / (db as f64).sqrt()))
                        .collect::<Vec<_>>()
                }
                LocalKind::Subsample => {
                    let j = start + rng.below(nb as u64) as usize;
                    let w = rng.rademacher() * (nb as f64 / db as f64).sqrt();
                    vec![(j, w)]
                }
            };
            cols.push(col);
        }
        start += nb;
    }
    Sketch::Sparse(SparseSketch::new(n, cols))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_a_bt, Matrix};

    #[test]
    fn block_structure_respected() {
        let mut rng = Pcg64::seed(0x10c);
        let s = localized(40, 8, 4, LocalKind::Gaussian, &mut rng);
        let dense = s.to_dense();
        // columns 0..2 only touch rows 0..10, etc. (4 blocks of 10, 2 cols each)
        for j in 0..8 {
            let block = j / 2;
            for i in 0..40 {
                if i / 10 != block {
                    assert_eq!(dense[(i, j)], 0.0, "({i},{j}) outside block {block}");
                }
            }
        }
    }

    #[test]
    fn subsample_kind_one_nnz_per_column() {
        let mut rng = Pcg64::seed(0x10d);
        let s = localized(60, 12, 3, LocalKind::Subsample, &mut rng);
        assert_eq!(s.nnz(), 12);
    }

    #[test]
    fn expectation_identity_blockwise() {
        // E[SSᵀ] = I for the block-diagonal Gaussian variant
        let mut rng = Pcg64::seed(0x10e);
        let n = 8;
        let reps = 3000;
        let mut acc = Matrix::zeros(n, n);
        for _ in 0..reps {
            let d = s_dense(&mut rng);
            let sst = matmul_a_bt(&d, &d);
            acc.axpy(1.0 / reps as f64, &sst);
        }
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((acc[(i, j)] - want).abs() < 0.15, "({i},{j}) = {}", acc[(i, j)]);
            }
        }
    }

    fn s_dense(rng: &mut Pcg64) -> Matrix {
        let s = localized(8, 4, 2, LocalKind::Gaussian, rng);
        s.to_dense()
    }

    #[test]
    fn works_in_sketched_krr_but_suffers_on_unbalanced_blocks() {
        use crate::kernels::{kernel_matrix, Kernel};
        use crate::krr::{KrrModel, SketchedKrr};
        use crate::sketch::{SketchBuilder, SketchKind};
        use crate::stats::in_sample_sq_error;
        // all the signal mass in the first block: localized must spend
        // columns on the uninformative second block, accumulation may not
        let mut rng = Pcg64::seed(0x10f);
        let n = 160;
        let x = Matrix::from_fn(n, 1, |i, _| {
            if i < 80 {
                rng.uniform() // informative half
            } else {
                10.0 + 0.001 * rng.uniform() // nearly-constant half
            }
        });
        let y: Vec<f64> = (0..n).map(|i| (5.0 * x[(i, 0)]).sin()).collect();
        let kern = Kernel::gaussian(0.3);
        let lam = 1e-4;
        let k = kernel_matrix(&kern, &x);
        let exact = KrrModel::fit_with_k(kern, &x, &k, &y, lam).unwrap();
        let reps = 10;
        let mean_err = |make: &mut dyn FnMut(&mut Pcg64) -> Sketch| -> f64 {
            let mut rng = Pcg64::seed(0x110);
            (0..reps)
                .map(|_| {
                    let s = make(&mut rng);
                    let m = SketchedKrr::fit(kern, &x, &y, &s, lam, Some(&k)).unwrap();
                    in_sample_sq_error(m.fitted(), exact.fitted())
                })
                .sum::<f64>()
                / reps as f64
        };
        let e_local = mean_err(&mut |r| localized(n, 16, 2, LocalKind::Gaussian, r));
        let e_accum = mean_err(&mut |r| {
            SketchBuilder::new(SketchKind::Accumulation { m: 8 }).build(n, 16, r)
        });
        assert!(e_local.is_finite() && e_accum.is_finite());
        // accumulation adapts its budget to where the spectrum lives
        assert!(
            e_accum < 2.0 * e_local + 1e-9,
            "accum {e_accum} should be competitive with localized {e_local}"
        );
    }
}
