//! Sketching matrices — the paper's core contribution, organised around an
//! **incremental accumulation engine**.
//!
//! A sketching matrix `S ∈ ℝ^{n×d}` approximates the KRR problem through
//! `K_S = KS (SᵀKS)⁻¹ SᵀK`. This module implements the paper's unified
//! framework (Algorithm 1): `S` is the accumulation of `m` rescaled,
//! randomly-signed sub-sampling matrices with i.i.d. columns,
//!
//! ```text
//!   S = Σ_{i=1}^{m} S₍ᵢ₎,   S₍ᵢ₎[:, j] = (r_j / √(d·m·p_{n_j})) e_{n_j}
//! ```
//!
//! which recovers the Nyström method at `m = 1` and a sub-Gaussian sketch as
//! `m → ∞`. All constructions are normalised so every column satisfies
//! `E[s sᵀ] = Iₙ/d`, hence `E[S Sᵀ] = Iₙ`.
//!
//! The module is built from three pieces (see `DESIGN.md` §2 for the data
//! flow):
//!
//! * **[`SketchOps`]** — the operations every sketch representation
//!   supports (`SᵀB`, `Sᵀv`, `Sw`, densification, shape). Implemented by
//!   [`SparseSketch`] (per-column COO), dense [`Matrix`] sketches
//!   (Gaussian / Rademacher baselines), the [`Sketch`] enum that unifies
//!   them, and [`AccumSketch`]. Generic code dispatches through the trait
//!   instead of matching on the enum at every call site.
//! * **[`AccumSketch`]** — a *growable* accumulation sketch: terms are
//!   appended one at a time (with the `1/√(d·m·p)` rescaling of earlier
//!   terms applied exactly), so the right `m` can be discovered at runtime
//!   instead of fixed up front. Growing 1 → m bit-matches a one-shot
//!   [`SketchKind::Accumulation`] build from the same RNG stream.
//! * **[`IncrementalGram`]** — accumulates the sketched Gram quantities
//!   `KS`, `SᵀKS`, `SᵀK²S` term by term (caching kernel columns, so each
//!   appended term costs `O(n·d)` plus kernel evaluations only at *new*
//!   support points), and hands the solver a factored low-rank delta for
//!   Cholesky up/down-dating.
//!
//! Sparse sketches are stored in a per-column COO layout ([`SparseSketch`])
//! so application costs `O(n·m·d)` (paper §3.3) instead of the dense
//! `O(n²d)`; dense sketches ([`Matrix`]) cover the Gaussian / Rademacher
//! baselines the paper compares against.

mod accum;
mod amm;
mod apply;
mod build;
mod localized;
mod poisson;
mod sparse;
mod srht;

pub use accum::AccumSketch;
pub use amm::{amm_rel_error, approx_matmul};
pub use apply::{
    sketch_gram, sketch_gram_streamed, sketch_gram_with, sketch_kernel_cols,
    try_sketch_gram_streamed, try_sketch_gram_with, AppendDelta, IncrementalGram, SketchedGram,
};
pub use build::{SketchBuilder, SketchKind};
pub use localized::{localized, LocalKind};
pub use poisson::PoissonSketch;
pub use sparse::SparseSketch;
pub use srht::{countsketch, fwht, srht};

use crate::linalg::Matrix;
use crate::rng::AliasTable;

/// Sampling distribution `P` for sub-sampling-based sketches.
#[derive(Clone, Debug)]
pub enum Sampling {
    /// `p_i = 1/n` (the classical Nyström choice).
    Uniform,
    /// Arbitrary `p_i` (e.g. statistical leverage scores), drawn *with
    /// replacement*: each sketch column samples one index from the table.
    /// The table also retains the normalised probabilities needed for the
    /// `1/√(dmpᵢ)` rescaling.
    Weighted(AliasTable),
    /// Poisson sampling over the base distribution `p_i` (Wang, Zou & Wang,
    /// arXiv:2205.08588): instead of `d` with-replacement column draws, row
    /// `i` is included *independently* with probability
    /// `πᵢ = min(1, d·pᵢ)` and reweighted by `1/√πᵢ`, so `E[SSᵀ] = Iₙ`
    /// holds exactly and the column count is random with mean `≤ d`.
    /// Materialised by [`PoissonSketch`] (one cached uniform per row, so
    /// growing the target dimension is deterministic and nested); the
    /// per-column draw machinery of [`AccumSketch`] does not apply.
    Poisson(AliasTable),
}

impl Sampling {
    /// Probability of index `i` under the (base) distribution over `n`
    /// points. For [`Sampling::Poisson`] this is the base `p_i`, not the
    /// inclusion probability — see [`Sampling::inclusion_prob`].
    pub fn prob(&self, i: usize, n: usize) -> f64 {
        match self {
            Sampling::Uniform => 1.0 / n as f64,
            Sampling::Weighted(t) | Sampling::Poisson(t) => t.p(i),
        }
    }

    /// Poisson inclusion probability `πᵢ = min(1, d·pᵢ)` of row `i` at
    /// target dimension `d`. Defined for every variant (any base
    /// distribution can be Poisson-sampled); [`PoissonSketch`] uses this to
    /// threshold its cached per-row uniforms.
    pub fn inclusion_prob(&self, i: usize, n: usize, d: usize) -> f64 {
        (d as f64 * self.prob(i, n)).min(1.0)
    }
}

/// The operations every sketch representation supports. Code that only
/// needs to *apply* a sketch takes `&impl SketchOps` (or dispatches through
/// [`Sketch`]) instead of matching on the storage enum — new
/// representations ([`AccumSketch`], future streaming variants) plug in by
/// implementing this trait.
pub trait SketchOps {
    /// Number of data points `n`.
    fn n(&self) -> usize;

    /// Projection dimension `d`.
    fn d(&self) -> usize;

    /// Total non-zeros (density diagnostic; `≈ m·d` for accumulation
    /// sketches, `n·d` for dense ones).
    fn nnz(&self) -> usize;

    /// Dense `n×d` materialisation (diagnostics / K-satisfiability checks;
    /// never on the training path for sparse sketches).
    fn to_dense(&self) -> Matrix;

    /// `Sᵀ B` for a tall `n×c` matrix `B`, in `O(nnz·c)` for sparse.
    fn st_mat(&self, b: &Matrix) -> Matrix;

    /// `Sᵀ v` for an n-vector.
    fn st_vec(&self, v: &[f64]) -> Vec<f64>;

    /// `S w` for a d-vector (maps sketch coefficients back to data space).
    fn s_vec(&self, w: &[f64]) -> Vec<f64>;
}

/// Dense `n×d` sketches (Gaussian / Rademacher baselines) are plain
/// matrices; the trait impl gives them the same application API as the
/// sparse constructions.
impl SketchOps for Matrix {
    fn n(&self) -> usize {
        self.rows()
    }

    fn d(&self) -> usize {
        self.cols()
    }

    fn nnz(&self) -> usize {
        self.data().iter().filter(|&&x| x != 0.0).count()
    }

    fn to_dense(&self) -> Matrix {
        self.clone()
    }

    fn st_mat(&self, b: &Matrix) -> Matrix {
        crate::linalg::matmul_at_b(self, b)
    }

    fn st_vec(&self, v: &[f64]) -> Vec<f64> {
        self.matvec_t(v)
    }

    fn s_vec(&self, w: &[f64]) -> Vec<f64> {
        self.matvec(w)
    }
}

/// A materialised sketching matrix.
#[derive(Clone, Debug)]
pub enum Sketch {
    /// Per-column sparse (sub-sampling / accumulation / very-sparse RP).
    Sparse(SparseSketch),
    /// Dense `n×d` (Gaussian / Rademacher).
    Dense(Matrix),
}

/// The enum dispatches each operation to its variant's [`SketchOps`] impl —
/// the single `match` in the library, instead of one per method per call
/// site.
impl SketchOps for Sketch {
    fn n(&self) -> usize {
        match self {
            Sketch::Sparse(s) => s.n(),
            Sketch::Dense(m) => SketchOps::n(m),
        }
    }

    fn d(&self) -> usize {
        match self {
            Sketch::Sparse(s) => s.d(),
            Sketch::Dense(m) => SketchOps::d(m),
        }
    }

    fn nnz(&self) -> usize {
        match self {
            Sketch::Sparse(s) => s.nnz(),
            Sketch::Dense(m) => SketchOps::nnz(m),
        }
    }

    fn to_dense(&self) -> Matrix {
        match self {
            Sketch::Sparse(s) => s.to_dense(),
            Sketch::Dense(m) => m.clone(),
        }
    }

    fn st_mat(&self, b: &Matrix) -> Matrix {
        match self {
            Sketch::Sparse(s) => s.st_mat(b),
            Sketch::Dense(m) => SketchOps::st_mat(m, b),
        }
    }

    fn st_vec(&self, v: &[f64]) -> Vec<f64> {
        match self {
            Sketch::Sparse(s) => s.st_vec(v),
            Sketch::Dense(m) => m.matvec_t(v),
        }
    }

    fn s_vec(&self, w: &[f64]) -> Vec<f64> {
        match self {
            Sketch::Sparse(s) => s.s_vec(w),
            Sketch::Dense(m) => m.matvec(w),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn dense_and_sparse_agree_through_common_api() {
        let mut rng = Pcg64::seed(71);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 3 })
            .build(50, 8, &mut rng);
        let dense = s.to_dense();
        let b = Matrix::from_fn(50, 4, |_, _| 1.0);
        let via_sparse = s.st_mat(&b);
        let via_dense = crate::linalg::matmul_at_b(&dense, &b);
        for i in 0..8 {
            for j in 0..4 {
                assert!((via_sparse[(i, j)] - via_dense[(i, j)]).abs() < 1e-12);
            }
        }
        let v: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let sv = s.st_vec(&v);
        let dv = dense.matvec_t(&v);
        for (a, b) in sv.iter().zip(dv.iter()) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn s_vec_roundtrip_dimension() {
        let mut rng = Pcg64::seed(72);
        let s = SketchBuilder::new(SketchKind::Gaussian).build(20, 5, &mut rng);
        let w = vec![1.0; 5];
        assert_eq!(s.s_vec(&w).len(), 20);
    }

    #[test]
    fn trait_object_dispatch_works() {
        let mut rng = Pcg64::seed(73);
        let sparse = SketchBuilder::new(SketchKind::Nystrom).build(30, 5, &mut rng);
        let dense = SketchBuilder::new(SketchKind::Gaussian).build(30, 5, &mut rng);
        let sketches: Vec<&dyn SketchOps> = vec![&sparse, &dense];
        for s in sketches {
            assert_eq!(s.n(), 30);
            assert_eq!(s.d(), 5);
            assert_eq!(s.st_vec(&vec![1.0; 30]).len(), 5);
        }
    }
}
