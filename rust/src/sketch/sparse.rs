//! Per-column COO storage for sparse sketching matrices.
//!
//! Column `j` holds `(row, weight)` pairs; an accumulation sketch has
//! exactly `m` pairs per column (duplicates kept — they are statistically
//! distinct draws and merging is a measurable but optional optimisation
//! performed by [`SparseSketch::merged`]).

use super::SketchOps;
use crate::linalg::Matrix;

/// Sparse n×d sketching matrix, column-major COO.
#[derive(Clone, Debug)]
pub struct SparseSketch {
    n: usize,
    /// `cols[j]` = non-zeros of column j as (row index, weight).
    cols: Vec<Vec<(usize, f64)>>,
}

impl SparseSketch {
    /// Construct from raw per-column entries.
    pub fn new(n: usize, cols: Vec<Vec<(usize, f64)>>) -> Self {
        debug_assert!(cols
            .iter()
            .all(|c| c.iter().all(|&(i, w)| i < n && w.is_finite())));
        SparseSketch { n, cols }
    }

    /// Data-space dimension `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Projection dimension `d`.
    pub fn d(&self) -> usize {
        self.cols.len()
    }

    /// Total stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.cols.iter().map(|c| c.len()).sum()
    }

    /// Entries of column `j`.
    pub fn col(&self, j: usize) -> &[(usize, f64)] {
        &self.cols[j]
    }

    /// Sorted, deduplicated list of all sampled row indices (the sketch's
    /// *support*). `|support| ≤ nnz ≤ m·d`; kernel evaluation against the
    /// support is what makes the accumulation method `O(n·md)`.
    pub fn support(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = self
            .cols
            .iter()
            .flat_map(|c| c.iter().map(|&(i, _)| i))
            .collect();
        idx.sort_unstable();
        idx.dedup();
        idx
    }

    /// Same sketch with duplicate rows inside each column merged (weights
    /// summed). Semantically identical; reduces nnz when `m` draws collide.
    pub fn merged(&self) -> SparseSketch {
        let cols = self
            .cols
            .iter()
            .map(|c| {
                let mut c = c.clone();
                c.sort_unstable_by_key(|&(i, _)| i);
                let mut out: Vec<(usize, f64)> = Vec::with_capacity(c.len());
                for (i, w) in c {
                    match out.last_mut() {
                        Some((li, lw)) if *li == i => *lw += w,
                        _ => out.push((i, w)),
                    }
                }
                out.retain(|&(_, w)| w != 0.0);
                out
            })
            .collect();
        SparseSketch { n: self.n, cols }
    }

    /// Dense materialisation (diagnostics only).
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.n, self.d());
        for (j, col) in self.cols.iter().enumerate() {
            for &(i, w) in col {
                m[(i, j)] += w;
            }
        }
        m
    }

    /// `Sᵀ B` for `B ∈ ℝ^{n×c}`: row `j` of the result is
    /// `Σ_{(i,w)∈col j} w · B[i, :]` — `O(nnz · c)`.
    pub fn st_mat(&self, b: &Matrix) -> Matrix {
        assert_eq!(b.rows(), self.n, "st_mat: row mismatch");
        let c = b.cols();
        let mut out = Matrix::zeros(self.d(), c);
        for (j, col) in self.cols.iter().enumerate() {
            let orow = out.row_mut(j);
            for &(i, w) in col {
                let brow = b.row(i);
                for (o, x) in orow.iter_mut().zip(brow.iter()) {
                    *o += w * x;
                }
            }
        }
        out
    }

    /// `Sᵀ v` — `O(nnz)`.
    pub fn st_vec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.n);
        self.cols
            .iter()
            .map(|col| col.iter().map(|&(i, w)| w * v[i]).sum())
            .collect()
    }

    /// `S w` — scatter `O(nnz)`.
    pub fn s_vec(&self, w: &[f64]) -> Vec<f64> {
        assert_eq!(w.len(), self.d());
        let mut out = vec![0.0; self.n];
        for (j, col) in self.cols.iter().enumerate() {
            let wj = w[j];
            for &(i, wt) in col {
                out[i] += wt * wj;
            }
        }
        out
    }

    /// Fold the sketch into *landmark weights*: for each support point `u`,
    /// `beta[u] = Σ_{(j,t): idx=u} coeff[j] · w[j,t]`. Returns
    /// `(support, beta)` — this is how a trained sketched-KRR model predicts
    /// with at most `|support|` kernel evaluations per query (paper §3.3).
    pub fn landmark_weights(&self, coeff: &[f64]) -> (Vec<usize>, Vec<f64>) {
        assert_eq!(coeff.len(), self.d());
        let support = self.support();
        // map row index → dense position
        let mut pos = std::collections::HashMap::with_capacity(support.len());
        for (p, &i) in support.iter().enumerate() {
            pos.insert(i, p);
        }
        let mut beta = vec![0.0; support.len()];
        for (j, col) in self.cols.iter().enumerate() {
            for &(i, w) in col {
                beta[pos[&i]] += coeff[j] * w;
            }
        }
        (support, beta)
    }
}

/// Trait impl delegates to the inherent methods (which stay public — the
/// COO-specific extras like [`SparseSketch::support`] and
/// [`SparseSketch::landmark_weights`] have no dense counterpart).
impl SketchOps for SparseSketch {
    fn n(&self) -> usize {
        SparseSketch::n(self)
    }

    fn d(&self) -> usize {
        SparseSketch::d(self)
    }

    fn nnz(&self) -> usize {
        SparseSketch::nnz(self)
    }

    fn to_dense(&self) -> Matrix {
        SparseSketch::to_dense(self)
    }

    fn st_mat(&self, b: &Matrix) -> Matrix {
        SparseSketch::st_mat(self, b)
    }

    fn st_vec(&self, v: &[f64]) -> Vec<f64> {
        SparseSketch::st_vec(self, v)
    }

    fn s_vec(&self, w: &[f64]) -> Vec<f64> {
        SparseSketch::s_vec(self, w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> SparseSketch {
        // n=4, d=2; col0 = 2·e0 + 1·e2, col1 = −1·e2
        SparseSketch::new(4, vec![vec![(0, 2.0), (2, 1.0)], vec![(2, -1.0)]])
    }

    #[test]
    fn dims_and_nnz() {
        let s = toy();
        assert_eq!((s.n(), s.d(), s.nnz()), (4, 2, 3));
        assert_eq!(s.support(), vec![0, 2]);
    }

    #[test]
    fn to_dense_matches_definition() {
        let d = toy().to_dense();
        assert_eq!(d[(0, 0)], 2.0);
        assert_eq!(d[(2, 0)], 1.0);
        assert_eq!(d[(2, 1)], -1.0);
        assert_eq!(d[(1, 0)], 0.0);
    }

    #[test]
    fn st_vec_and_s_vec() {
        let s = toy();
        let v = [1.0, 10.0, 100.0, 1000.0];
        assert_eq!(s.st_vec(&v), vec![102.0, -100.0]);
        let w = [1.0, 2.0];
        assert_eq!(s.s_vec(&w), vec![2.0, 0.0, -1.0, 0.0]);
    }

    #[test]
    fn st_mat_matches_dense() {
        let s = toy();
        let b = Matrix::from_fn(4, 3, |i, j| (i * 3 + j) as f64);
        let got = s.st_mat(&b);
        let want = crate::linalg::matmul_at_b(&s.to_dense(), &b);
        for i in 0..2 {
            for j in 0..3 {
                assert_eq!(got[(i, j)], want[(i, j)]);
            }
        }
    }

    #[test]
    fn merged_combines_duplicates() {
        let s = SparseSketch::new(3, vec![vec![(1, 0.5), (1, 0.25), (0, 1.0)]]);
        let m = s.merged();
        assert_eq!(m.nnz(), 2);
        let d = m.to_dense();
        assert_eq!(d[(1, 0)], 0.75);
        assert_eq!(d[(0, 0)], 1.0);
    }

    #[test]
    fn merged_drops_cancelled_entries() {
        let s = SparseSketch::new(2, vec![vec![(0, 1.0), (0, -1.0)]]);
        assert_eq!(s.merged().nnz(), 0);
    }

    #[test]
    fn landmark_weights_fold() {
        let s = toy();
        let (support, beta) = s.landmark_weights(&[3.0, 5.0]);
        assert_eq!(support, vec![0, 2]);
        // beta[0] = 3·2 = 6 ; beta[2] = 3·1 + 5·(−1) = −2
        assert_eq!(beta, vec![6.0, -2.0]);
    }
}
