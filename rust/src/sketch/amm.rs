//! Approximate matrix multiplication (AMM) with accumulation sketches —
//! the paper's §5 future-work direction, implemented as an extension.
//!
//! For conformable `A ∈ ℝ^{k×n}`, `B ∈ ℝ^{n×c}`, any sketch from this
//! crate gives the unbiased estimator `A·B ≈ (A S)(Sᵀ B)` (every
//! construction satisfies `E[S Sᵀ] = Iₙ`). For a sparse accumulation
//! sketch the cost is `O((k + c)·nnz + k·d·c)` versus the exact
//! `O(k·n·c)` — the same m/d trade-off as in KRR: m controls the variance
//! contributed by high-incoherence rows, d the overall rank budget.

use super::{Sketch, SketchOps};
use crate::linalg::{matmul, Matrix};

/// `A·B ≈ (A S)(Sᵀ B)` through the sketch.
pub fn approx_matmul(a: &Matrix, b: &Matrix, sketch: &Sketch) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "amm: inner dims");
    assert_eq!(sketch.n(), a.cols(), "amm: sketch dim");
    // A S  = (Sᵀ Aᵀ)ᵀ — reuse the sparse-fast st_mat path
    let at = a.transpose();
    let sta_t = sketch.st_mat(&at); // d × k
    let a_s = sta_t.transpose(); // k × d
    let stb = sketch.st_mat(b); // d × c
    matmul(&a_s, &stb)
}

/// Relative Frobenius error `‖AB − (AS)(SᵀB)‖_F / ‖AB‖_F` (diagnostic used
/// by the extension bench).
pub fn amm_rel_error(a: &Matrix, b: &Matrix, sketch: &Sketch) -> f64 {
    let exact = matmul(a, b);
    let approx = approx_matmul(a, b, sketch);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in approx.data().iter().zip(exact.data().iter()) {
        num += (x - y) * (x - y);
        den += y * y;
    }
    (num / den.max(1e-300)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::sketch::{SketchBuilder, SketchKind};

    #[test]
    fn amm_unbiased_in_expectation() {
        let mut rng = Pcg64::seed(0xa33);
        let n = 40;
        let a = Matrix::from_fn(6, n, |_, _| rng.normal());
        let b = Matrix::from_fn(n, 5, |_, _| rng.normal());
        let exact = matmul(&a, &b);
        let reps = 3000;
        let mut acc = Matrix::zeros(6, 5);
        let builder = SketchBuilder::new(SketchKind::Accumulation { m: 3 });
        for _ in 0..reps {
            let s = builder.build(n, 12, &mut rng);
            acc.axpy(1.0 / reps as f64, &approx_matmul(&a, &b, &s));
        }
        for i in 0..6 {
            for j in 0..5 {
                assert!(
                    (acc[(i, j)] - exact[(i, j)]).abs() < 0.7,
                    "({i},{j}): {} vs {}",
                    acc[(i, j)],
                    exact[(i, j)]
                );
            }
        }
    }

    #[test]
    fn error_decreases_with_d() {
        let mut rng = Pcg64::seed(0xa34);
        let n = 120;
        let a = Matrix::from_fn(10, n, |_, _| rng.normal());
        let b = Matrix::from_fn(n, 8, |_, _| rng.normal());
        let mean_err = |d: usize| -> f64 {
            let mut rng = Pcg64::seed(0xa35);
            let builder = SketchBuilder::new(SketchKind::Accumulation { m: 4 });
            (0..20)
                .map(|_| amm_rel_error(&a, &b, &builder.build(n, d, &mut rng)))
                .sum::<f64>()
                / 20.0
        };
        let e_small = mean_err(8);
        let e_large = mean_err(64);
        assert!(
            e_large < e_small * 0.7,
            "d=64 err {e_large} should beat d=8 err {e_small}"
        );
    }

    #[test]
    fn m_does_not_change_the_order_of_amm_error_on_isotropic_data() {
        // Unlike sketched KRR (where the signed cross-terms cancel inside
        // the quadratic forms of eq. 3), plain AMM keeps the m(m−1)
        // zero-mean cross products A[:,i]B[i',:] per column, so at fixed d
        // the error is of the same order for every m — the benefit of
        // accumulation in AMM is unbiasedness + sparsity, not variance
        // reduction. Documented here as a guard against regressions.
        let mut rng = Pcg64::seed(0xa36);
        let n = 200;
        let a = Matrix::from_fn(4, n, |_, _| rng.normal());
        let b = Matrix::from_fn(n, 4, |_, _| rng.normal());
        let mean_err = |m: usize| -> f64 {
            let mut rng = Pcg64::seed(0xa37);
            let builder = SketchBuilder::new(SketchKind::Accumulation { m });
            (0..40)
                .map(|_| amm_rel_error(&a, &b, &builder.build(n, 10, &mut rng)))
                .sum::<f64>()
                / 40.0
        };
        let e1 = mean_err(1);
        let e8 = mean_err(8);
        assert!(
            e8 < 2.5 * e1 && e1 < 2.5 * e8,
            "same order expected: m=1 {e1} vs m=8 {e8}"
        );
    }
}
