//! Minimal data-parallel substrate (no `rayon` in the offline image).
//!
//! Built on `std::thread::scope`. Two primitives cover every parallel site
//! in the library:
//!
//! * [`scope_chunks`] — split a mutable slice into fixed-size chunks and run
//!   a closure per chunk (GEMM row panels, kernel-matrix row tiles).
//! * [`parallel_map`] — map a closure over an index range collecting results
//!   (experiment replicates in the coordinator's job scheduler).
//!
//! A third, long-lived primitive serves the coordinator rather than the
//! math kernels: [`TaskPool`], a fixed set of worker threads draining a
//! queue of boxed jobs, used to keep slow ops (train, cluster) off the
//! reactor thread without spawning a thread per request.
//!
//! The worker count defaults to `std::thread::available_parallelism()` and
//! can be pinned with `ACCUMKRR_THREADS` (the bench harness pins 1 for
//! stable timings).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

static CACHED: AtomicUsize = AtomicUsize::new(0);

/// Serialises tests that mutate the process-global worker count via
/// [`set_num_threads`] — without it, concurrently running tests race on
/// the shared setting and a "serial" baseline can silently run parallel.
#[cfg(test)]
pub(crate) static TEST_THREADS_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Number of worker threads to use.
pub fn num_threads() -> usize {
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("ACCUMKRR_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        });
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Override the worker count (tests exercise the multi-threaded path on
/// single-core CI; the bench harness pins 1 for stable timings).
pub fn set_num_threads(n: usize) {
    CACHED.store(n.max(1), Ordering::Relaxed);
}

/// Split `data` into consecutive chunks of at most `chunk_len` elements and
/// invoke `f(chunk_index, chunk)` for each, distributing chunks over worker
/// threads. Falls back to a plain serial loop when one worker suffices
/// (avoids thread-spawn overhead on the 1-core bench machine).
pub fn scope_chunks<T: Send, F>(data: &mut [T], chunk_len: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    let chunk_len = chunk_len.max(1);
    let nthreads = num_threads();
    if nthreads <= 1 || data.len() <= chunk_len {
        for (idx, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(idx, chunk);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk_len).enumerate().collect();
    // hand ownership of each chunk to exactly one worker via an atomic cursor
    let cells: Vec<std::sync::Mutex<Option<(usize, &mut [T])>>> =
        chunks.into_iter().map(|c| std::sync::Mutex::new(Some(c))).collect();
    std::thread::scope(|s| {
        for _ in 0..nthreads.min(cells.len()) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                if let Some((idx, chunk)) = cells[i].lock().unwrap().take() {
                    f(idx, chunk);
                }
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, preserving order of results.
pub fn parallel_map<R: Send, F>(n: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let nthreads = num_threads();
    if nthreads <= 1 || n == 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    scope_chunks(&mut out, 1, |i, slot| {
        slot[0] = Some(f(i));
    });
    out.into_iter().map(|r| r.unwrap()).collect()
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size pool of long-lived worker threads draining a shared job
/// queue. Unlike [`scope_chunks`] (scoped, borrows the caller's stack),
/// `TaskPool` jobs are `'static` and outlive the submitting call — the
/// shape the serving plane needs for train/cluster ops that must not
/// block the reactor. A panicking job is caught and does not take its
/// worker down.
pub struct TaskPool {
    tx: Mutex<Option<Sender<Job>>>,
    workers: Vec<JoinHandle<()>>,
}

impl TaskPool {
    /// Spawn `workers` (min 1) threads waiting on the queue.
    pub fn new(workers: usize) -> TaskPool {
        let (tx, rx) = channel::<Job>();
        let rx: Arc<Mutex<Receiver<Job>>> = Arc::new(Mutex::new(rx));
        let workers = (0..workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    let job = match rx.lock().unwrap_or_else(|e| e.into_inner()).recv() {
                        Ok(job) => job,
                        Err(_) => break, // sender dropped: shutdown
                    };
                    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job()));
                })
            })
            .collect();
        TaskPool { tx: Mutex::new(Some(tx)), workers }
    }

    /// Enqueue a job. Returns `false` if the pool has been shut down.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) -> bool {
        match &*self.tx.lock().unwrap_or_else(|e| e.into_inner()) {
            Some(tx) => tx.send(Box::new(f)).is_ok(),
            None => false,
        }
    }

    /// Stop accepting jobs, finish the queue, and join every worker.
    pub fn shutdown(&mut self) {
        self.tx.lock().unwrap_or_else(|e| e.into_inner()).take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for TaskPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_all_elements_once() {
        let mut v = vec![0u32; 1000];
        scope_chunks(&mut v, 37, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn chunk_indices_correct() {
        let mut v = vec![0usize; 100];
        scope_chunks(&mut v, 10, |idx, chunk| {
            for x in chunk {
                *x = idx;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i / 10);
        }
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(57, |i| i * i);
        assert_eq!(out, (0..57).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_inputs() {
        let mut v: Vec<u8> = vec![];
        scope_chunks(&mut v, 4, |_, _| panic!("no chunks expected"));
        assert!(parallel_map(0, |i| i).is_empty());
    }

    #[test]
    fn multithreaded_path_covers_all_chunks() {
        let _guard = TEST_THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        // force >1 workers even on a 1-core box, then restore
        let before = num_threads();
        set_num_threads(4);
        let mut v = vec![0u32; 5000];
        scope_chunks(&mut v, 13, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
        let out = parallel_map(100, |i| i * 3);
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>());
        set_num_threads(before);
    }

    #[test]
    fn task_pool_runs_all_jobs_and_survives_panics() {
        use std::sync::atomic::AtomicU64;
        let pool = TaskPool::new(3);
        let count = Arc::new(AtomicU64::new(0));
        assert!(pool.submit(|| panic!("worker must survive this")));
        for _ in 0..50 {
            let c = Arc::clone(&count);
            assert!(pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            }));
        }
        let mut pool = pool;
        pool.shutdown(); // drains the queue and joins
        assert_eq!(count.load(Ordering::SeqCst), 50);
        assert!(!pool.submit(|| {}), "submit after shutdown must fail");
    }

    #[test]
    fn multithreaded_gemm_matches_serial() {
        use crate::linalg::{matmul, Matrix};
        use crate::rng::Pcg64;
        let _guard = TEST_THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut r = Pcg64::seed(0x9001);
        let a = Matrix::from_fn(130, 40, |_, _| r.normal());
        let b = Matrix::from_fn(40, 50, |_, _| r.normal());
        let before = num_threads();
        set_num_threads(1);
        let serial = matmul(&a, &b);
        set_num_threads(3);
        let parallel = matmul(&a, &b);
        set_num_threads(before);
        assert_eq!(serial.data(), parallel.data());
    }
}
