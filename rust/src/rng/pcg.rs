//! PCG64 (PCG XSL RR 128/64) — O'Neill's permuted congruential generator.
//!
//! 128-bit LCG state with an xor-shift + random-rotate output permutation.
//! Chosen for statistical quality, tiny state, and trivially reproducible
//! streams (every experiment in the bench harness is seeded).

/// PCG XSL RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream constant fixed).
    pub fn seed(seed: u64) -> Self {
        Self::seed_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Create a generator with an explicit stream; distinct streams are
    /// statistically independent (used to give each worker its own RNG).
    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut g = Pcg64 {
            state: 0,
            inc,
        };
        g.state = g.state.wrapping_mul(MULT).wrapping_add(g.inc);
        g.state = g.state.wrapping_add(seed as u128);
        g.state = g.state.wrapping_mul(MULT).wrapping_add(g.inc);
        g.next_u64();
        g
    }

    /// Derive a child generator (for per-replicate / per-worker streams).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let s = self.next_u64() ^ tag.rotate_left(17);
        Pcg64::seed_stream(s, self.next_u64() | 1)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::seed_stream(1, 1);
        let mut b = Pcg64::seed_stream(1, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_children_independent() {
        let mut root = Pcg64::seed(9);
        let mut c1 = root.split(0);
        let mut c2 = root.split(1);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn no_short_cycle() {
        let mut g = Pcg64::seed(5);
        let first = g.next_u64();
        for _ in 0..10_000 {
            assert_ne!(g.next_u64(), 0u64.wrapping_sub(1) ^ first ^ first.wrapping_add(1), "sanity");
        }
        // the real check: 10k outputs contain no immediate repetition
        let mut prev = g.next_u64();
        for _ in 0..10_000 {
            let x = g.next_u64();
            assert_ne!(x, prev);
            prev = x;
        }
    }
}
