//! Walker/Vose alias method for O(1) sampling from a fixed discrete
//! distribution.
//!
//! The leverage-score Nyström sampler draws `m·d` indices from an n-point
//! non-uniform distribution; a linear scan per draw would be `O(n·m·d)`.
//! The alias table costs `O(n)` to build and `O(1)` per draw.

use super::Pcg64;

/// Preprocessed alias table over `n` outcomes.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<usize>,
    /// Normalised probabilities (kept for the rescaling 1/√(d·m·pᵢ) used by
    /// sub-sampling sketches).
    p: Vec<f64>,
}

impl AliasTable {
    /// Build from (unnormalised, non-negative) weights. Panics if all
    /// weights are zero or any is negative/NaN.
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0);
        let total: f64 = weights.iter().sum();
        assert!(
            total > 0.0 && weights.iter().all(|w| w.is_finite() && *w >= 0.0),
            "alias: invalid weights"
        );
        let p: Vec<f64> = weights.iter().map(|w| w / total).collect();
        let mut scaled: Vec<f64> = p.iter().map(|q| q * n as f64).collect();
        let mut small: Vec<usize> = Vec::with_capacity(n);
        let mut large: Vec<usize> = Vec::with_capacity(n);
        for (i, &s) in scaled.iter().enumerate() {
            if s < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        let mut prob = vec![1.0; n];
        let mut alias: Vec<usize> = (0..n).collect();
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s] = scaled[s];
            alias[s] = l;
            scaled[l] = (scaled[l] + scaled[s]) - 1.0;
            if scaled[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // leftovers are numerically 1.0
        AliasTable { prob, alias, p }
    }

    /// Number of outcomes.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// True if the table is over zero outcomes (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Normalised probability of outcome `i`.
    #[inline]
    pub fn p(&self, i: usize) -> f64 {
        self.p[i]
    }

    /// Draw one outcome.
    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let n = self.prob.len();
        let i = rng.below(n as u64) as usize;
        if rng.uniform() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }

    /// Uniform table over `n` outcomes (the classical Nyström sampler).
    pub fn uniform(n: usize) -> Self {
        AliasTable::new(&vec![1.0; n])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_weights_empirically() {
        let w = [0.5, 2.0, 0.0, 1.5];
        let t = AliasTable::new(&w);
        let mut rng = Pcg64::seed(11);
        let mut counts = [0usize; 4];
        let n = 200_000;
        for _ in 0..n {
            counts[t.sample(&mut rng)] += 1;
        }
        assert_eq!(counts[2], 0);
        let total: f64 = w.iter().sum();
        for i in [0usize, 1, 3] {
            let emp = counts[i] as f64 / n as f64;
            let want = w[i] / total;
            assert!((emp - want).abs() < 0.01, "i={i} emp={emp} want={want}");
        }
    }

    #[test]
    fn normalised_probs_accessible() {
        let t = AliasTable::new(&[1.0, 3.0]);
        assert!((t.p(0) - 0.25).abs() < 1e-12);
        assert!((t.p(1) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn uniform_table() {
        let t = AliasTable::uniform(7);
        assert_eq!(t.len(), 7);
        for i in 0..7 {
            assert!((t.p(i) - 1.0 / 7.0).abs() < 1e-12);
        }
    }

    #[test]
    fn single_outcome() {
        let t = AliasTable::new(&[42.0]);
        let mut rng = Pcg64::seed(1);
        for _ in 0..10 {
            assert_eq!(t.sample(&mut rng), 0);
        }
    }
}
