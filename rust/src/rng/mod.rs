//! Random number generation substrate.
//!
//! The offline build has no `rand` crate, so we implement the PRNG stack the
//! library needs: a PCG64 generator ([`Pcg64`]), normal variates
//! (Box–Muller), Rademacher signs, categorical sampling (linear and
//! alias-method for the weighted Nyström / leverage-score samplers), and
//! Fisher–Yates shuffling.

mod alias;
mod pcg;

pub use alias::AliasTable;
pub use pcg::Pcg64;

impl Pcg64 {
    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // Take the top 53 bits of a 64-bit draw.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection to avoid modulo
    /// bias).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (we burn the second variate for
    /// simplicity; profiled as irrelevant next to the GEMM hot paths).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 0.0 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Rademacher sign: ±1 with equal probability.
    #[inline]
    pub fn rademacher(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// One draw from a discrete distribution given by (unnormalised)
    /// weights, by linear scan. Use [`AliasTable`] for repeated draws.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical: all-zero weights");
        let mut u = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_in_range_and_varies() {
        let mut r = Pcg64::seed(1);
        let xs: Vec<f64> = (0..1000).map(|_| r.uniform()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Pcg64::seed(2);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.below(3) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed(3);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn rademacher_balanced() {
        let mut r = Pcg64::seed(4);
        let s: f64 = (0..10_000).map(|_| r.rademacher()).sum();
        assert!(s.abs() < 300.0);
    }

    #[test]
    fn sample_without_replacement_distinct() {
        let mut r = Pcg64::seed(5);
        let s = r.sample_without_replacement(100, 30);
        assert_eq!(s.len(), 30);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::seed(6);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed(7);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
