//! # accumkrr
//!
//! Production-grade reproduction of *"Accumulation of Sub-Sampling Matrices
//! with Applications to Statistical Computation"* (Chen & Yang, 2021):
//! a unified framework for random sketches in kernel ridge regression (KRR)
//! in which the sketching matrix `S ∈ ℝ^{n×d}` is the accumulation of `m`
//! rescaled, randomly-signed sub-sampling matrices (paper Algorithm 1).
//!
//! * `m = 1`  → the classical Nyström method (sub-sampling sketch).
//! * `m → ∞` → sub-Gaussian (Gaussian) sketching, by the CLT.
//! * medium `m` → the paper's contribution: accuracy close to Gaussian
//!   sketching at close to Nyström cost, because
//!   `KS = Σᵢ K S₍ᵢ₎` costs `O(nmd)` rather than `O(n²d)`.
//!
//! Since optimal sampling probabilities are rarely available in practice,
//! the right `m` is data-dependent — so accumulation is implemented as the
//! system's **incremental runtime loop**, not just a constructor
//! parameter: [`sketch::AccumSketch`] grows term by term (bit-matching a
//! one-shot build from the same RNG stream), [`sketch::IncrementalGram`]
//! folds each term into `KS`/`SᵀKS`/`SᵀK²S` without a rebuild,
//! [`linalg::CholFactor`] supports rank up/down-dates of the `d×d` solve,
//! and [`krr::SketchedKrr::fit_adaptive`] grows `m` until a
//! [`stats::StoppingRule`] fires.
//!
//! The memory side of the same argument is the **tiled Gram-operator
//! pipeline** ([`kernels::GramOperator`], DESIGN.md §5): training and
//! diagnostic paths stream `K` as `tile×n` row panels instead of
//! materialising it, so peak memory is `O(tile·n + n·d)` — and with the
//! out-of-core [`data::TileSource`] backends (one f64 file or a shard
//! directory, DESIGN.md §12) `X` itself leaves residency too, while
//! every result stays bitwise identical to the in-memory run. The one
//! documented exception is the partial eigensolver's dense fallback
//! (small n, oversized block, or a stalled/clustered spectrum), which
//! assembles `K` rather than return unconverged pairs — observable via
//! `kernels::assembly_guard`, and test-pinned off on the default paths.
//!
//! The abstract's *other* headline application — eigendecomposition in
//! spectral clustering — is the [`cluster`] subsystem: a
//! [`cluster::LaplacianOperator`] keeps the normalized graph Laplacian
//! implicit over the streamed Gram operator (degrees in one pass,
//! bottom-k eigenvectors via the `2I − L_sym` shift trick), with the
//! embedding computed either by operator iteration or from an
//! accumulation-sketched `d×d` pencil whose term count `m` is again
//! chosen at runtime by a [`stats::StoppingRule`].
//!
//! The hot paths themselves are explicitly vectorized (DESIGN.md §8):
//! `linalg::simd` selects an AVX2+FMA / NEON / scalar micro-kernel once
//! at runtime (`ACCUMKRR_FORCE_SCALAR=1` pins the fallback) and feeds
//! the packed GEMM driver and the radial kernel map, while an opt-in
//! [`linalg::Precision`] knob runs the `O(n²)` assembly side in f32 —
//! every `d×d` solve stays f64. Determinism is preserved *per selected
//! kernel*: bitwise tile/thread invariance holds under each dispatch.
//!
//! The crate is organised in three layers (README.md has the map):
//!
//! * **Substrates** (built entirely from scratch — the default build has
//!   **zero** external dependencies; the optional `xla` feature pulls the
//!   in-tree PJRT stub crate): [`rng`], [`linalg`], [`pool`], [`util`].
//! * **Core statistical library**: [`kernels`], [`sketch`], [`leverage`],
//!   [`krr`], [`cluster`], [`stats`], [`data`].
//! * **System layer**: [`runtime`] (PJRT execution of AOT-compiled JAX/Pallas
//!   artifacts), [`coordinator`] (experiment scheduler, prediction server
//!   with adaptive-fit and spectral-clustering job kinds, dynamic
//!   batcher), [`bench`] (paper figure regeneration plus the
//!   adaptive-vs-refit and streamed-vs-dense clustering comparisons).
//!
//! See `DESIGN.md` (repo root) for the full inventory, the incremental
//! accumulation data flow, and the per-experiment index.

// Documentation is part of the CI contract: a cross-reference that stops
// resolving is a build failure, not a silent rot (`cargo doc --no-deps`
// runs in CI with the same lint as an error).
#![deny(rustdoc::broken_intra_doc_links)]

// The numerical substrate deliberately writes index-blocked loops
// (triangular sweeps, register tiles, in-place panels) and long argument
// lists on the blocked kernels; these style lints fight that idiom and
// are allowed crate-wide so the CI `clippy -D warnings` gate stays about
// correctness, not loop aesthetics.
#![allow(unknown_lints)]
#![allow(
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::type_complexity,
    clippy::len_without_is_empty,
    clippy::new_without_default,
    clippy::excessive_precision,
    clippy::approx_constant,
    clippy::uninlined_format_args,
    clippy::manual_div_ceil,
    clippy::needless_lifetimes,
    clippy::comparison_chain
)]

pub mod bench;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod kernels;
pub mod krr;
pub mod leverage;
pub mod linalg;
pub mod pool;
pub mod rng;
pub mod runtime;
pub mod sketch;
pub mod stats;
pub mod util;

pub use cluster::{LaplacianOperator, SpectralClustering};
pub use data::{F64File, ShardedFile, TileSource};
pub use kernels::{GramOperator, Kernel};
pub use krr::{AdaptiveOptions, KrrModel, SketchedKrr};
pub use linalg::{Matrix, Precision};
pub use rng::Pcg64;
pub use sketch::{AccumSketch, Sketch, SketchKind, SketchOps};
