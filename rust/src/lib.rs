//! # accumkrr
//!
//! Production-grade reproduction of *"Accumulation of Sub-Sampling Matrices
//! with Applications to Statistical Computation"* (Chen & Yang, 2021):
//! a unified framework for random sketches in kernel ridge regression (KRR)
//! in which the sketching matrix `S ∈ ℝ^{n×d}` is the accumulation of `m`
//! rescaled, randomly-signed sub-sampling matrices (paper Algorithm 1).
//!
//! * `m = 1`  → the classical Nyström method (sub-sampling sketch).
//! * `m → ∞` → sub-Gaussian (Gaussian) sketching, by the CLT.
//! * medium `m` → the paper's contribution: accuracy close to Gaussian
//!   sketching at close to Nyström cost, because
//!   `KS = Σᵢ K S₍ᵢ₎` costs `O(nmd)` rather than `O(n²d)`.
//!
//! Since optimal sampling probabilities are rarely available in practice,
//! the right `m` is data-dependent — so accumulation is implemented as the
//! system's **incremental runtime loop**, not just a constructor
//! parameter: [`sketch::AccumSketch`] grows term by term (bit-matching a
//! one-shot build from the same RNG stream), [`sketch::IncrementalGram`]
//! folds each term into `KS`/`SᵀKS`/`SᵀK²S` without a rebuild,
//! [`linalg::CholFactor`] supports rank up/down-dates of the `d×d` solve,
//! and [`krr::SketchedKrr::fit_adaptive`] grows `m` until a
//! [`stats::StoppingRule`] fires.
//!
//! The crate is organised in three layers:
//!
//! * **Substrates** (built from scratch — the offline image only ships the
//!   `xla` and `anyhow` crates): [`rng`], [`linalg`], [`pool`], [`util`].
//! * **Core statistical library**: [`kernels`], [`sketch`], [`leverage`],
//!   [`krr`], [`stats`], [`data`].
//! * **System layer**: [`runtime`] (PJRT execution of AOT-compiled JAX/Pallas
//!   artifacts), [`coordinator`] (experiment scheduler, prediction server
//!   with an adaptive-fit job kind, dynamic batcher), [`bench`] (paper
//!   figure regeneration plus the adaptive-vs-refit comparison).
//!
//! See `DESIGN.md` (repo root) for the full inventory, the incremental
//! accumulation data flow, and the per-experiment index.

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod kernels;
pub mod krr;
pub mod leverage;
pub mod linalg;
pub mod pool;
pub mod rng;
pub mod runtime;
pub mod sketch;
pub mod stats;
pub mod util;

pub use kernels::Kernel;
pub use krr::{AdaptiveOptions, KrrModel, SketchedKrr};
pub use linalg::Matrix;
pub use rng::Pcg64;
pub use sketch::{AccumSketch, Sketch, SketchKind, SketchOps};
