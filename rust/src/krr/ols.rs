//! Sketched ridge / least squares on **raw features** — no kernel.
//!
//! The accumulation + sampling machinery is kernel-agnostic: for the plain
//! ridge problem `min_β ‖Xβ − y‖² + nλ‖β‖²` (the setting of
//! arXiv:2204.04776), sketch-and-solve compresses the `n×p` design to
//! `Z = SᵀX` (d×p) and `z_y = Sᵀy`, then solves the p×p normal equations
//!
//! ```text
//!   (ZᵀZ + nλI_p) β̂ = Zᵀ z_y
//! ```
//!
//! Since every sketch here satisfies `E[SSᵀ] = Iₙ`, `ZᵀZ` and `Zᵀz_y` are
//! unbiased for `XᵀX` and `Xᵀy`, and β̂ → the exact ridge solution as the
//! sketch concentrates (m → ∞ for accumulation, d → n for Poisson). The
//! informed-probability source in this setting is [`feature_leverage`] —
//! the ridge leverage `ℓᵢ = xᵢᵀ(XᵀX + nλI)⁻¹xᵢ` of each design row,
//! `O(np²)` — playing the role [`crate::leverage::bless`] plays for
//! kernels: rows that dominate the spectrum get sampled, rows in the bulk
//! do not.

use super::sketched::factor_with_jitter;
use crate::linalg::{syrk_at_a, Matrix};
use crate::sketch::{Sketch, SketchOps};
use crate::util::timer::Timer;

/// Cost/telemetry of one sketched-OLS fit.
#[derive(Clone, Copy, Debug, Default)]
pub struct OlsReport {
    /// Sketch dimension d (realised, for Poisson sketches).
    pub d: usize,
    /// Sketch non-zeros.
    pub nnz: usize,
    /// Ridge bump retries needed for PD-ness (0 in healthy runs).
    pub jitter_bumps: u32,
    /// Seconds forming `SᵀX`, `Sᵀy` and the p×p Gram.
    pub sketch_secs: f64,
    /// Seconds in the p×p factorisation + solve.
    pub solve_secs: f64,
}

/// Trained sketched ridge/least-squares model on raw features.
#[derive(Clone, Debug)]
pub struct SketchedOls {
    beta: Vec<f64>,
    fitted: Vec<f64>,
    report: OlsReport,
}

impl SketchedOls {
    /// Coefficients β̂ (one per feature).
    pub fn beta(&self) -> &[f64] {
        &self.beta
    }

    /// In-sample fitted values `Xβ̂`.
    pub fn fitted(&self) -> &[f64] {
        &self.fitted
    }

    /// Fit telemetry.
    pub fn report(&self) -> &OlsReport {
        &self.report
    }

    /// Predict at query rows: `x_q · β̂`.
    pub fn predict(&self, xq: &Matrix) -> Vec<f64> {
        xq.matvec(&self.beta)
    }
}

/// Exact ridge solution `(XᵀX + nλI)⁻¹Xᵀy` — the small-p reference the
/// sketched estimator converges to. `None` if the (jittered) normal
/// equations cannot be factored.
pub fn ridge_exact(x: &Matrix, y: &[f64], lambda: f64) -> Option<Vec<f64>> {
    let n = x.rows();
    assert_eq!(y.len(), n, "ridge: |y| != n");
    let mut a = syrk_at_a(x);
    a.add_diag(n as f64 * lambda);
    let (fac, _) = factor_with_jitter(&mut a)?;
    Some(fac.solve(&x.matvec_t(y)))
}

/// Ridge leverage scores of the design rows:
/// `ℓᵢ = xᵢᵀ(XᵀX + nλI)⁻¹xᵢ ∈ [0, 1)` — the informed sampling
/// probabilities (`pᵢ ∝ ℓᵢ`) for raw-feature sketching, costing `O(np²)`
/// (one p×p factorisation + a triangular solve per row). Their sum is the
/// ridge effective dimension `Σⱼ σⱼ/(σⱼ + nλ)` over the eigenvalues of
/// `XᵀX`.
pub fn feature_leverage(x: &Matrix, lambda: f64) -> Vec<f64> {
    let n = x.rows();
    let mut a = syrk_at_a(x);
    a.add_diag(n as f64 * lambda);
    let (fac, _) = factor_with_jitter(&mut a).expect("XᵀX + nλI is PD for λ > 0");
    (0..n)
        .map(|i| {
            let xi = x.row(i);
            let sol = fac.solve(xi);
            let l: f64 = xi.iter().zip(sol.iter()).map(|(a, b)| a * b).sum();
            l.clamp(1e-12, 1.0)
        })
        .collect()
}

/// Sketch-and-solve ridge on raw features. Takes any [`Sketch`] built by
/// [`SketchBuilder`](crate::sketch::SketchBuilder) — uniform or
/// leverage-weighted accumulation, Poisson inclusion, dense baselines —
/// and solves the compressed normal equations. `None` if the (jittered)
/// p×p system cannot be factored.
pub fn sketched_ols(x: &Matrix, y: &[f64], sketch: &Sketch, lambda: f64) -> Option<SketchedOls> {
    let n = x.rows();
    assert_eq!(y.len(), n, "sketched ols: |y| != n");
    assert_eq!(sketch.n(), n, "sketched ols: sketch n mismatch");
    let mut t = Timer::start();
    let z = sketch.st_mat(x); // d×p
    let zy = sketch.st_vec(y); // d
    let mut a = syrk_at_a(&z); // p×p
    a.add_diag(n as f64 * lambda);
    let rhs = z.matvec_t(&zy); // p
    let sketch_secs = t.lap();
    let (fac, jitter_bumps) = factor_with_jitter(&mut a)?;
    let beta = fac.solve(&rhs);
    let solve_secs = t.lap();
    let fitted = x.matvec(&beta);
    Some(SketchedOls {
        beta,
        fitted,
        report: OlsReport {
            d: sketch.d(),
            nnz: sketch.nnz(),
            jitter_bumps,
            sketch_secs,
            solve_secs,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{AliasTable, Pcg64};
    use crate::sketch::{Sampling, SketchBuilder, SketchKind, SparseSketch};

    /// Skewed design: a diffuse bulk plus a few far, high-leverage rows —
    /// the regime where informed sampling pays.
    fn skewed_design(n_bulk: usize, n_far: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Pcg64::seed(seed);
        let n = n_bulk + n_far;
        let p = 4;
        let x = Matrix::from_fn(n, p, |i, j| {
            if i < n_bulk {
                0.3 * rng.normal()
            } else if j == i % p {
                // far rows: one dominant direction per row
                6.0 + rng.normal()
            } else {
                0.1 * rng.normal()
            }
        });
        let beta_true = [1.0, -2.0, 0.5, 3.0];
        let y: Vec<f64> = (0..n)
            .map(|i| {
                let xi = x.row(i);
                xi.iter().zip(beta_true.iter()).map(|(a, b)| a * b).sum::<f64>()
                    + 0.05 * rng.normal()
            })
            .collect();
        (x, y)
    }

    fn rel_err(beta: &[f64], reference: &[f64]) -> f64 {
        let num: f64 = beta
            .iter()
            .zip(reference.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        let den: f64 = reference.iter().map(|b| b * b).sum::<f64>().sqrt();
        num / den.max(1e-300)
    }

    /// The identity sketch (S = Iₙ) makes the compressed normal equations
    /// *equal* the exact ones — sketched OLS must recover exact ridge.
    #[test]
    fn identity_sketch_recovers_exact_ridge() {
        let (x, y) = skewed_design(30, 3, 201);
        let n = x.rows();
        let lam = 1e-3;
        let cols: Vec<Vec<(usize, f64)>> = (0..n).map(|j| vec![(j, 1.0)]).collect();
        let s = Sketch::Sparse(SparseSketch::new(n, cols));
        let got = sketched_ols(&x, &y, &s, lam).unwrap();
        let want = ridge_exact(&x, &y, lam).unwrap();
        for (a, b) in got.beta().iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-10, "{a} vs {b}");
        }
        // fitted and predict agree
        let p = got.predict(&x);
        for (a, b) in p.iter().zip(got.fitted().iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    /// Accumulation error shrinks toward the exact solution as m grows
    /// (medians over seeds, like the KRR analogue).
    #[test]
    fn ols_error_decreases_with_m() {
        let (x, y) = skewed_design(80, 4, 202);
        let lam = 1e-3;
        let exact = ridge_exact(&x, &y, lam).unwrap();
        let err = |m: usize, seed: u64| -> f64 {
            let mut rng = Pcg64::seed(seed);
            let mut total = 0.0;
            let reps = 5;
            for _ in 0..reps {
                let s = SketchBuilder::new(SketchKind::Accumulation { m })
                    .build(x.rows(), 12, &mut rng);
                total += rel_err(sketched_ols(&x, &y, &s, lam).unwrap().beta(), &exact);
            }
            total / reps as f64
        };
        let median = |m: usize| -> f64 {
            let mut v: Vec<f64> = [7u64, 19, 41, 83, 131].iter().map(|&s| err(m, s)).collect();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[v.len() / 2]
        };
        let e1 = median(1);
        let e16 = median(16);
        assert!(e16 < e1, "m=16 median err {e16} should beat m=1 {e1}");
    }

    /// Σᵢ ℓᵢ equals the ridge effective dimension Σⱼ σⱼ/(σⱼ + nλ) over the
    /// eigenvalues of XᵀX (an exact trace identity — deterministic check).
    #[test]
    fn feature_leverage_sums_to_effective_dimension() {
        let (x, _) = skewed_design(25, 3, 203);
        let n = x.rows() as f64;
        let lam = 1e-2;
        let scores = feature_leverage(&x, lam);
        assert!(scores.iter().all(|&l| (0.0..=1.0).contains(&l)));
        let got: f64 = scores.iter().sum();
        let eig = crate::linalg::eigh(&syrk_at_a(&x));
        let want: f64 = eig.w.iter().map(|&s| s.max(0.0) / (s.max(0.0) + n * lam)).sum();
        assert!((got - want).abs() < 1e-8, "{got} vs {want}");
    }

    /// Far rows dominate the leverage profile, and feeding that profile
    /// back as sampling probabilities beats uniform at equal d (medians
    /// over seeds — the raw-feature version of the informed-sampling win).
    #[test]
    fn leverage_informed_sampling_beats_uniform() {
        let (x, y) = skewed_design(120, 4, 204);
        let n = x.rows();
        let lam = 1e-3;
        let exact = ridge_exact(&x, &y, lam).unwrap();
        let scores = feature_leverage(&x, lam);
        let bulk_mean: f64 = scores[..120].iter().sum::<f64>() / 120.0;
        let far_mean: f64 = scores[120..].iter().sum::<f64>() / 4.0;
        assert!(far_mean > 10.0 * bulk_mean, "{far_mean} vs {bulk_mean}");
        let err = |sampling: Sampling, seed: u64| -> f64 {
            let mut rng = Pcg64::seed(seed);
            let mut total = 0.0;
            let reps = 3;
            for _ in 0..reps {
                let s = SketchBuilder::new(SketchKind::Accumulation { m: 4 })
                    .with_sampling(sampling.clone())
                    .build(n, 10, &mut rng);
                total += rel_err(sketched_ols(&x, &y, &s, lam).unwrap().beta(), &exact);
            }
            total / reps as f64
        };
        let median = |sampling: &Sampling| -> f64 {
            let mut v: Vec<f64> = [7u64, 19, 41, 83, 131]
                .iter()
                .map(|&s| err(sampling.clone(), s))
                .collect();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[v.len() / 2]
        };
        let informed = Sampling::Weighted(AliasTable::new(&scores));
        let e_unif = median(&Sampling::Uniform);
        let e_info = median(&informed);
        assert!(
            e_info < e_unif,
            "informed median err {e_info} should beat uniform {e_unif}"
        );
    }

    /// Poisson sketches drop straight into the OLS path (variable column
    /// count is fine for SᵀX).
    #[test]
    fn poisson_sketch_works_for_ols() {
        let (x, y) = skewed_design(60, 3, 205);
        let n = x.rows();
        let lam = 1e-3;
        let scores = feature_leverage(&x, lam);
        let mut rng = Pcg64::seed(206);
        let s = SketchBuilder::new(SketchKind::Nystrom)
            .with_sampling(Sampling::Poisson(AliasTable::new(&scores)))
            .build(n, 20, &mut rng);
        let fit = sketched_ols(&x, &y, &s, lam).unwrap();
        assert!(fit.beta().iter().all(|v| v.is_finite()));
        assert_eq!(fit.beta().len(), 4);
        assert!(fit.report().d > 0);
    }
}
