//! Falkon (Rudi, Carratino & Rosasco, 2017) — the state-of-the-art Nyström
//! variant the paper compares against in Figure 5 / §D.3.
//!
//! Falkon solves the sketched KRR system iteratively:
//!
//! ```text
//!   (SᵀK²S + nλ SᵀKS) θ = SᵀKY
//! ```
//!
//! by conjugate gradients with the Nyström preconditioner
//! `P = T⁻¹ A⁻¹` where `T = chol(SᵀKS)` and
//! `A = chol(T Tᵀ / d + nλ I)`, plus early stopping. The original paper
//! fixes `S` to a column sub-sampling matrix; following §3.3 we generalise
//! to any [`Sketch`] from this crate (accumulation sketches included) —
//! the preconditioner only needs the `d×d` Grams.

use crate::kernels::{gather_rows, Kernel};
use crate::linalg::{chol_factor, CholFactor, Matrix};
use crate::sketch::{sketch_gram, Sketch, SketchOps};

/// Falkon solver options.
#[derive(Clone, Copy, Debug)]
pub struct FalkonOptions {
    /// Maximum CG iterations (early stopping bound; Falkon's analysis needs
    /// only `O(log n)`).
    pub max_iters: usize,
    /// Relative residual tolerance for early stopping.
    pub tol: f64,
}

impl Default for FalkonOptions {
    fn default() -> Self {
        FalkonOptions {
            max_iters: 20,
            tol: 1e-8,
        }
    }
}

/// Falkon fit result.
#[derive(Clone, Debug)]
pub struct FalkonResult {
    /// θ solving the sketched system (coefficients in sketch space).
    pub theta: Vec<f64>,
    /// In-sample fitted values `KSθ`.
    pub fitted: Vec<f64>,
    /// Landmark rows + folded weights (same prediction form as
    /// [`crate::krr::SketchedKrr`]).
    pub landmarks: Matrix,
    /// Folded landmark weights.
    pub beta: Vec<f64>,
    /// CG iterations actually run.
    pub iters: usize,
    /// Final relative residual.
    pub residual: f64,
    /// Kernel evaluations performed.
    pub kernel_evals: usize,
}

impl FalkonResult {
    /// Predict at query rows.
    pub fn predict(&self, kernel: &Kernel, xq: &Matrix) -> Vec<f64> {
        let kq = crate::kernels::cross_kernel(kernel, xq, &self.landmarks);
        kq.matvec(&self.beta)
    }
}

/// Run Falkon-style preconditioned CG for sketched KRR.
pub fn falkon(
    kernel: Kernel,
    x: &Matrix,
    y: &[f64],
    sketch: &Sketch,
    lambda: f64,
    opts: FalkonOptions,
    k_full: Option<&Matrix>,
) -> Option<FalkonResult> {
    let n = x.rows();
    assert_eq!(y.len(), n);
    let gram = sketch_gram(&kernel, x, sketch, k_full);
    let d = sketch.d();
    let nl = n as f64 * lambda;

    // Preconditioner factors. With G = SᵀKS = L·Lᵀ and E[SSᵀ] = I, the
    // system operator is H = SᵀK²S + nλG ≈ G² + nλG = L(LᵀL + nλI)Lᵀ, so
    // M⁻¹ = L⁻ᵀ (LᵀL + nλI)⁻¹ L⁻¹ — two triangular solves plus one small
    // SPD solve per CG step. Jitter like the sketched direct solver.
    let t_fac = factor_with_jitter(&gram.stks)?;
    let tl = t_fac.l();
    let mut a = crate::linalg::matmul_at_b(tl, tl);
    a.add_diag(nl);
    let a_fac = factor_with_jitter(&a)?;

    // System operator: H θ = (SᵀK²S + nλ SᵀKS) θ.
    let mut h = gram.stk2s.clone();
    h.axpy(nl, &gram.stks);
    h.symmetrize();

    // rhs
    let b = gram.ks.matvec_t(y);

    // M⁻¹ r = L⁻ᵀ (LᵀL + nλI)⁻¹ L⁻¹ r (SPD by construction).
    let apply_minv = |r: &[f64]| -> Vec<f64> {
        let z1 = forward_sub(t_fac.l(), r);
        let z2 = a_fac.solve(&z1);
        backward_sub_t(t_fac.l(), &z2)
    };

    let mut theta = vec![0.0; d];
    let mut r = b.clone(); // residual (θ₀ = 0)
    let b_norm = norm2(&b).max(1e-300);
    let mut z = apply_minv(&r);
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    let mut iters = 0;
    let mut residual = norm2(&r) / b_norm;
    for _ in 0..opts.max_iters {
        if residual < opts.tol {
            break;
        }
        iters += 1;
        let hp = h.matvec(&p);
        let php = dot(&p, &hp);
        if php <= 0.0 || !php.is_finite() {
            break; // numerical breakdown: keep the current iterate
        }
        let alpha = rz / php;
        for i in 0..d {
            theta[i] += alpha * p[i];
            r[i] -= alpha * hp[i];
        }
        residual = norm2(&r) / b_norm;
        z = apply_minv(&r);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        for i in 0..d {
            p[i] = z[i] + beta * p[i];
        }
    }

    let fitted = gram.ks.matvec(&theta);
    let (landmarks, beta) = match sketch {
        Sketch::Sparse(sp) => {
            let (support, beta) = sp.landmark_weights(&theta);
            (gather_rows(x, &support), beta)
        }
        Sketch::Dense(_) => (x.clone(), sketch.s_vec(&theta)),
    };
    Some(FalkonResult {
        theta,
        fitted,
        landmarks,
        beta,
        iters,
        residual,
        kernel_evals: gram.kernel_evals,
    })
}

fn factor_with_jitter(m: &Matrix) -> Option<CholFactor> {
    let mut a = m.clone();
    let scale = (0..a.rows()).map(|i| a[(i, i)]).fold(0.0f64, f64::max).max(1e-300);
    for bump in 0..9 {
        if let Some(f) = chol_factor(&a) {
            return Some(f);
        }
        a.add_diag(scale * 1e-12 * 10f64.powi(bump));
    }
    None
}

/// Solve `L y = r` (L lower-triangular).
fn forward_sub(l: &Matrix, r: &[f64]) -> Vec<f64> {
    let n = l.rows();
    let mut y = r.to_vec();
    for i in 0..n {
        let row = l.row(i);
        let mut s = y[i];
        for p in 0..i {
            s -= row[p] * y[p];
        }
        y[i] = s / row[i];
    }
    y
}

/// Solve `Lᵀ x = r`.
fn backward_sub_t(l: &Matrix, r: &[f64]) -> Vec<f64> {
    let n = l.rows();
    let mut x = r.to_vec();
    for i in (0..n).rev() {
        let mut s = x[i];
        for p in (i + 1)..n {
            s -= l[(p, i)] * x[p];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::krr::SketchedKrr;
    use crate::rng::Pcg64;
    use crate::sketch::{SketchBuilder, SketchKind};

    fn toy(n: usize, seed: u64) -> (Matrix, Vec<f64>, Kernel, f64) {
        let mut rng = Pcg64::seed(seed);
        let x = Matrix::from_fn(n, 2, |_, _| rng.uniform());
        let y: Vec<f64> = (0..n)
            .map(|i| (4.0 * x[(i, 0)]).cos() + 0.05 * rng.normal())
            .collect();
        (x, y, Kernel::gaussian(0.5), 1e-3)
    }

    #[test]
    fn falkon_matches_direct_sketched_solution() {
        let (x, y, kern, lam) = toy(80, 121);
        let mut rng = Pcg64::seed(122);
        for kind in [SketchKind::Nystrom, SketchKind::Accumulation { m: 4 }] {
            let s = SketchBuilder::new(kind).build(80, 12, &mut rng);
            let direct = SketchedKrr::fit(kern, &x, &y, &s, lam, None).unwrap();
            let fk = falkon(kern, &x, &y, &s, lam, FalkonOptions { max_iters: 200, tol: 1e-12 }, None)
                .unwrap();
            for (a, b) in fk.theta.iter().zip(direct.theta().iter()) {
                assert!((a - b).abs() < 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn early_stopping_caps_iterations() {
        let (x, y, kern, lam) = toy(60, 123);
        let mut rng = Pcg64::seed(124);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 2 }).build(60, 8, &mut rng);
        let fk = falkon(kern, &x, &y, &s, lam, FalkonOptions { max_iters: 3, tol: 0.0 }, None)
            .unwrap();
        assert_eq!(fk.iters, 3);
    }

    #[test]
    fn preconditioner_converges_fast() {
        // the whole point of Falkon: few iterations to tight residual
        let (x, y, kern, lam) = toy(100, 125);
        let mut rng = Pcg64::seed(126);
        let s = SketchBuilder::new(SketchKind::Nystrom).build(100, 15, &mut rng);
        let fk = falkon(kern, &x, &y, &s, lam, FalkonOptions::default(), None).unwrap();
        assert!(fk.residual < 1e-6, "residual={}", fk.residual);
        assert!(fk.iters <= 20);
    }

    #[test]
    fn predict_works() {
        let (x, y, kern, lam) = toy(50, 127);
        let mut rng = Pcg64::seed(128);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 3 }).build(50, 10, &mut rng);
        let fk = falkon(kern, &x, &y, &s, lam, FalkonOptions::default(), None).unwrap();
        let p = fk.predict(&kern, &x);
        for (a, b) in p.iter().zip(fk.fitted.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
