//! Exact kernel ridge regression (paper eq. 2).

use crate::kernels::{cross_kernel, kernel_matrix, Kernel};
use crate::linalg::{chol_factor, Matrix};

/// Trained exact-KRR model: `f̂(x) = Σᵢ αᵢ k(x, xᵢ)`.
#[derive(Clone, Debug)]
pub struct KrrModel {
    kernel: Kernel,
    x_train: Matrix,
    alpha: Vec<f64>,
    fitted: Vec<f64>,
}

impl KrrModel {
    /// Fit by solving `(K + nλI) α = Y` with Cholesky. Returns `None` if
    /// the shifted kernel matrix is not PD at working precision (λ ≤ 0 or
    /// catastrophically scaled inputs).
    pub fn fit(kernel: Kernel, x: &Matrix, y: &[f64], lambda: f64) -> Option<KrrModel> {
        let n = x.rows();
        assert_eq!(y.len(), n, "krr: |y| != n");
        let mut a = kernel_matrix(&kernel, x);
        let fitted_from = a.clone();
        a.add_diag(n as f64 * lambda);
        let fac = chol_factor(&a)?;
        let alpha = fac.solve(y);
        let fitted = fitted_from.matvec(&alpha);
        Some(KrrModel {
            kernel,
            x_train: x.clone(),
            alpha,
            fitted,
        })
    }

    /// Fit when `K` is already available (bench sweeps share it).
    pub fn fit_with_k(
        kernel: Kernel,
        x: &Matrix,
        k: &Matrix,
        y: &[f64],
        lambda: f64,
    ) -> Option<KrrModel> {
        let n = x.rows();
        let mut a = k.clone();
        a.add_diag(n as f64 * lambda);
        let fac = chol_factor(&a)?;
        let alpha = fac.solve(y);
        let fitted = k.matvec(&alpha);
        Some(KrrModel {
            kernel,
            x_train: x.clone(),
            alpha,
            fitted,
        })
    }

    /// In-sample fitted values `f̂(xᵢ)` (used by the approximation-error
    /// experiments of Figure 1/2).
    pub fn fitted(&self) -> &[f64] {
        &self.fitted
    }

    /// Representer coefficients α.
    pub fn alpha(&self) -> &[f64] {
        &self.alpha
    }

    /// Predict at query rows.
    pub fn predict(&self, xq: &Matrix) -> Vec<f64> {
        let kq = cross_kernel(&self.kernel, xq, &self.x_train);
        kq.matvec(&self.alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    /// KRR with tiny λ interpolates smooth noiseless data.
    #[test]
    fn interpolates_noiseless_data() {
        let mut rng = Pcg64::seed(101);
        let n = 40;
        let x = Matrix::from_fn(n, 1, |_, _| rng.uniform() * 2.0 - 1.0);
        let y: Vec<f64> = (0..n).map(|i| (2.0 * x[(i, 0)]).sin()).collect();
        let model = KrrModel::fit(Kernel::gaussian(0.5), &x, &y, 1e-10 / n as f64).unwrap();
        for (f, t) in model.fitted().iter().zip(y.iter()) {
            assert!((f - t).abs() < 1e-4, "{f} vs {t}");
        }
        // predict at train points matches fitted
        let p = model.predict(&x);
        for (a, b) in p.iter().zip(model.fitted().iter()) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn ridge_shrinks_towards_zero() {
        let mut rng = Pcg64::seed(102);
        let n = 30;
        let x = Matrix::from_fn(n, 2, |_, _| rng.normal());
        let y: Vec<f64> = (0..n).map(|_| rng.normal() + 3.0).collect();
        let small = KrrModel::fit(Kernel::gaussian(1.0), &x, &y, 1e-6).unwrap();
        let large = KrrModel::fit(Kernel::gaussian(1.0), &x, &y, 100.0).unwrap();
        let norm = |v: &[f64]| v.iter().map(|a| a * a).sum::<f64>();
        assert!(norm(large.fitted()) < norm(small.fitted()));
        // heavy ridge pushes fitted values towards 0
        assert!(norm(large.fitted()) < 0.5 * norm(&y));
    }

    #[test]
    fn fit_with_k_matches_fit() {
        let mut rng = Pcg64::seed(103);
        let x = Matrix::from_fn(20, 2, |_, _| rng.normal());
        let y: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        let kern = Kernel::matern(1.5, 1.0);
        let k = kernel_matrix(&kern, &x);
        let a = KrrModel::fit(kern, &x, &y, 0.01).unwrap();
        let b = KrrModel::fit_with_k(kern, &x, &k, &y, 0.01).unwrap();
        for (u, v) in a.alpha().iter().zip(b.alpha().iter()) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn rejects_nonpositive_lambda_degeneracy() {
        // duplicate points + λ = 0 → singular K, factorisation must fail
        let x = Matrix::from_vec(2, 1, vec![0.5, 0.5]);
        let y = vec![1.0, -1.0];
        assert!(KrrModel::fit(Kernel::gaussian(1.0), &x, &y, 0.0).is_none());
    }
}
