//! Sketched kernel k-means — the paper's §5 future work ("how the
//! approximation error translates when the new sketching method is
//! utilized to approximate some classical machine learning models, such as
//! k-means and PCA").
//!
//! Kernel k-means in the sketched feature space: the sketched KPCA scores
//! (`krr::sketched_kpca`) embed the data into `ℝ^r` where ordinary Lloyd
//! iterations run in `O(n·r·k)` per step — the kernel matrix is never
//! materialised (KPCA's Grams stream through the row-tiled
//! `kernels::GramOperator`, `O(tile·n + n·d)` peak memory), and the d×d
//! spectral step inherits KPCA's partial-eigensolver routing
//! (`linalg::partial_eigh`) since only the top-r pairs are embedded.

use crate::kernels::Kernel;
use crate::krr::sketched_kpca;
use crate::linalg::Matrix;
use crate::rng::Pcg64;
use crate::sketch::Sketch;

/// Result of sketched kernel k-means.
#[derive(Clone, Debug)]
pub struct KernelKmeans {
    /// Cluster assignment per point.
    pub labels: Vec<usize>,
    /// Final within-cluster sum of squares in the embedded space.
    pub inertia: f64,
    /// Lloyd iterations run.
    pub iters: usize,
}

/// Run sketched kernel k-means with `k` clusters on the top-`r` sketched
/// kernel principal components.
pub fn kernel_kmeans(
    kernel: &Kernel,
    x: &Matrix,
    sketch: &Sketch,
    k: usize,
    r: usize,
    max_iters: usize,
    rng: &mut Pcg64,
) -> Option<KernelKmeans> {
    let n = x.rows();
    assert!(k >= 1 && k <= n);
    let kpca = sketched_kpca(kernel, x, sketch, r)?;
    // weight components by √λ so distances approximate kernel-space ones
    let mut emb = kpca.components.clone();
    for j in 0..emb.cols() {
        let w = kpca.eigenvalues[j].max(0.0).sqrt();
        for i in 0..n {
            emb[(i, j)] *= w;
        }
    }
    Some(lloyd(&emb, k, max_iters, rng))
}

/// Plain Lloyd iterations with k-means++-style seeding.
pub fn lloyd(emb: &Matrix, k: usize, max_iters: usize, rng: &mut Pcg64) -> KernelKmeans {
    let (n, p) = (emb.rows(), emb.cols());
    // k-means++ seeding
    let mut centers = Matrix::zeros(k, p);
    let first = rng.below(n as u64) as usize;
    centers.row_mut(0).copy_from_slice(emb.row(first));
    let mut dist2: Vec<f64> = (0..n).map(|i| sqd(emb.row(i), centers.row(0))).collect();
    for c in 1..k {
        let idx = rng.categorical(&dist2.iter().map(|&d| d.max(1e-12)).collect::<Vec<_>>());
        centers.row_mut(c).copy_from_slice(emb.row(idx));
        for i in 0..n {
            dist2[i] = dist2[i].min(sqd(emb.row(i), centers.row(c)));
        }
    }

    let mut labels = vec![0usize; n];
    let mut iters = 0;
    for it in 0..max_iters {
        iters = it + 1;
        // assign
        let mut changed = false;
        for i in 0..n {
            let row = emb.row(i);
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..k {
                let d = sqd(row, centers.row(c));
                if d < best.0 {
                    best = (d, c);
                }
            }
            if labels[i] != best.1 {
                labels[i] = best.1;
                changed = true;
            }
        }
        if !changed && it > 0 {
            break;
        }
        // update
        let mut counts = vec![0usize; k];
        let mut sums = Matrix::zeros(k, p);
        for i in 0..n {
            counts[labels[i]] += 1;
            let row = emb.row(i);
            let srow = sums.row_mut(labels[i]);
            for (s, v) in srow.iter_mut().zip(row.iter()) {
                *s += v;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                let inv = 1.0 / counts[c] as f64;
                let crow = centers.row_mut(c);
                let srow = sums.row(c);
                for (cv, sv) in crow.iter_mut().zip(srow.iter()) {
                    *cv = sv * inv;
                }
            } else {
                // re-seed an empty cluster at the farthest point
                let far = (0..n)
                    .max_by(|&a, &b| {
                        sqd(emb.row(a), centers.row(labels[a]))
                            .partial_cmp(&sqd(emb.row(b), centers.row(labels[b])))
                            .unwrap()
                    })
                    .unwrap();
                centers.row_mut(c).copy_from_slice(emb.row(far));
            }
        }
    }
    let inertia = (0..n).map(|i| sqd(emb.row(i), centers.row(labels[i]))).sum();
    KernelKmeans {
        labels,
        inertia,
        iters,
    }
}

fn sqd(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::{SketchBuilder, SketchKind};

    /// Two well-separated nonlinear clusters (concentric rings) that plain
    /// Euclidean k-means cannot split but kernel k-means can.
    fn rings(n_per: usize, rng: &mut Pcg64) -> (Matrix, Vec<usize>) {
        let n = 2 * n_per;
        let mut x = Matrix::zeros(n, 2);
        let mut truth = vec![0usize; n];
        for i in 0..n {
            let r = if i < n_per { 0.3 } else { 2.0 };
            truth[i] = (i >= n_per) as usize;
            let a = rng.uniform() * std::f64::consts::TAU;
            x[(i, 0)] = r * a.cos() + 0.03 * rng.normal();
            x[(i, 1)] = r * a.sin() + 0.03 * rng.normal();
        }
        (x, truth)
    }

    fn agreement(labels: &[usize], truth: &[usize]) -> f64 {
        let n = labels.len();
        let same: usize = labels
            .iter()
            .zip(truth.iter())
            .filter(|(a, b)| a == b)
            .count();
        (same.max(n - same)) as f64 / n as f64
    }

    #[test]
    fn separates_rings_with_accumulation_sketch() {
        let mut rng = Pcg64::seed(0xabc);
        let (x, truth) = rings(60, &mut rng);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(120, 30, &mut rng);
        let res = kernel_kmeans(&Kernel::gaussian(0.4), &x, &s, 2, 6, 50, &mut rng).unwrap();
        let acc = agreement(&res.labels, &truth);
        assert!(acc > 0.9, "ring separation accuracy {acc}");
        assert!(res.inertia.is_finite());
    }

    #[test]
    fn lloyd_converges_and_labels_in_range() {
        let mut rng = Pcg64::seed(0xbcd);
        let emb = Matrix::from_fn(40, 2, |i, _| if i < 20 { 0.0 } else { 5.0 });
        let res = lloyd(&emb, 2, 100, &mut rng);
        assert!(res.iters < 100);
        assert!(res.labels.iter().all(|&l| l < 2));
        // perfect split ⇒ inertia 0
        assert!(res.inertia < 1e-12, "inertia {}", res.inertia);
    }

    #[test]
    fn single_cluster_degenerate() {
        let mut rng = Pcg64::seed(0xcde);
        let emb = Matrix::from_fn(10, 2, |_, _| rng.normal());
        let res = lloyd(&emb, 1, 10, &mut rng);
        assert!(res.labels.iter().all(|&l| l == 0));
    }
}
