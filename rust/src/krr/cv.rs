//! k-fold cross-validation for sketched-KRR hyperparameters.
//!
//! The paper selects kernel bandwidth and λ "by cross validation" (§4.1,
//! §D.1/D.2); this module makes that step part of the framework: grid
//! search over (λ, bandwidth) with k-fold CV, fitting the *sketched*
//! estimator in each fold so model selection costs `O(k·n·d²)` rather than
//! the exact `O(k·n³)`.

use crate::kernels::Kernel;
use crate::krr::SketchedKrr;
use crate::linalg::Matrix;
use crate::rng::Pcg64;
use crate::sketch::SketchBuilder;
use crate::stats::test_error;

/// Result of a CV grid search.
#[derive(Clone, Debug)]
pub struct CvResult {
    /// Winning λ.
    pub lambda: f64,
    /// Winning bandwidth.
    pub bandwidth: f64,
    /// CV error of the winner.
    pub cv_error: f64,
    /// Full grid: (λ, bandwidth, mean CV error).
    pub grid: Vec<(f64, f64, f64)>,
}

/// k-fold CV over a (λ × bandwidth) grid for a given kernel family
/// (bandwidth is substituted into `kernel_of(bw)`).
#[allow(clippy::too_many_arguments)]
pub fn cv_select(
    kernel_of: impl Fn(f64) -> Kernel,
    x: &Matrix,
    y: &[f64],
    lambdas: &[f64],
    bandwidths: &[f64],
    sketch_builder: &SketchBuilder,
    d: usize,
    folds: usize,
    rng: &mut Pcg64,
) -> CvResult {
    let n = x.rows();
    assert!(folds >= 2 && n >= 2 * folds, "cv: need ≥ 2 folds and data");
    // one shuffled fold assignment shared across the grid (paired design)
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);

    let mut grid = Vec::new();
    let mut best: Option<(f64, f64, f64)> = None;
    for &bw in bandwidths {
        let kern = kernel_of(bw);
        for &lam in lambdas {
            let mut err_sum = 0.0;
            let mut err_count = 0usize;
            for f in 0..folds {
                // fold f = validation
                let val_idx: Vec<usize> = order
                    .iter()
                    .enumerate()
                    .filter(|(pos, _)| pos % folds == f)
                    .map(|(_, &i)| i)
                    .collect();
                let train_idx: Vec<usize> = order
                    .iter()
                    .enumerate()
                    .filter(|(pos, _)| pos % folds != f)
                    .map(|(_, &i)| i)
                    .collect();
                let take = |idx: &[usize]| -> (Matrix, Vec<f64>) {
                    let mut xm = Matrix::zeros(idx.len(), x.cols());
                    let mut ym = vec![0.0; idx.len()];
                    for (dst, &src) in idx.iter().enumerate() {
                        xm.row_mut(dst).copy_from_slice(x.row(src));
                        ym[dst] = y[src];
                    }
                    (xm, ym)
                };
                let (xt, yt) = take(&train_idx);
                let (xv, yv) = take(&val_idx);
                let sketch = sketch_builder.build(xt.rows(), d.min(xt.rows()), rng);
                if let Some(model) = SketchedKrr::fit(kern, &xt, &yt, &sketch, lam, None) {
                    err_sum += test_error(&model.predict(&xv), &yv);
                    err_count += 1;
                }
            }
            if err_count == 0 {
                continue;
            }
            let mean = err_sum / err_count as f64;
            grid.push((lam, bw, mean));
            if best.map(|(_, _, e)| mean < e).unwrap_or(true) {
                best = Some((lam, bw, mean));
            }
        }
    }
    let (lambda, bandwidth, cv_error) = best.expect("cv: every grid point failed");
    CvResult {
        lambda,
        bandwidth,
        cv_error,
        grid,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::SketchKind;

    /// CV must reject a wildly wrong bandwidth and an absurd λ.
    #[test]
    fn cv_picks_sane_hyperparameters() {
        let mut rng = Pcg64::seed(0xcf1);
        let n = 240;
        let x = Matrix::from_fn(n, 1, |_, _| rng.uniform() * 3.0);
        let y: Vec<f64> = (0..n)
            .map(|i| (2.0 * x[(i, 0)]).sin() + 0.1 * rng.normal())
            .collect();
        let builder = SketchBuilder::new(SketchKind::Accumulation { m: 4 });
        let res = cv_select(
            Kernel::gaussian,
            &x,
            &y,
            &[1e-5, 1e-1, 100.0],
            &[0.5, 50.0],
            &builder,
            24,
            4,
            &mut rng,
        );
        assert_eq!(res.grid.len(), 6);
        assert!(res.bandwidth < 50.0, "picked bw {}", res.bandwidth);
        assert!(res.lambda < 100.0, "picked λ {}", res.lambda);
        // the winner's CV error beats the flat-function error (variance of y)
        let var = {
            let m = y.iter().sum::<f64>() / n as f64;
            y.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / n as f64
        };
        assert!(res.cv_error < var, "cv {} vs var {var}", res.cv_error);
    }

    #[test]
    #[should_panic(expected = "cv: need")]
    fn cv_rejects_tiny_data() {
        let mut rng = Pcg64::seed(1);
        let x = Matrix::zeros(3, 1);
        let y = vec![0.0; 3];
        let builder = SketchBuilder::new(SketchKind::Nystrom);
        let _ = cv_select(
            Kernel::gaussian,
            &x,
            &y,
            &[0.1],
            &[1.0],
            &builder,
            2,
            3,
            &mut rng,
        );
    }
}
