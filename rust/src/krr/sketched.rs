//! Sketched kernel ridge regression (paper eq. 3).

use crate::kernels::{cross_kernel, gather_rows, Kernel};
use crate::linalg::{chol_factor, Matrix};
use crate::sketch::{sketch_gram, Sketch};
use crate::util::timer::Timer;

/// Trained sketched-KRR model.
///
/// Training solves `(SᵀK²S + nλ SᵀKS) θ = SᵀKY` (d×d system). Prediction
/// folds `Sθ` into *landmark weights* over the sketch support: for a sparse
/// accumulation sketch, `f̂_S(x) = Σ_u β_u k(x, x_u)` over at most `m·d`
/// support points (paper §3.3); for dense sketches the support is all of X.
#[derive(Clone, Debug)]
pub struct SketchedKrr {
    kernel: Kernel,
    /// Landmark feature rows (support points of the sketch).
    landmarks: Matrix,
    /// Folded weights β (one per landmark row).
    beta: Vec<f64>,
    /// Solution of the d×d system.
    theta: Vec<f64>,
    /// In-sample fitted values `(KSθ)ᵢ`.
    fitted: Vec<f64>,
    report: SketchedKrrReport,
}

/// Cost/telemetry of one sketched fit — consumed by the bench harness and
/// the coordinator's metrics endpoint.
#[derive(Clone, Copy, Debug, Default)]
pub struct SketchedKrrReport {
    /// Kernel evaluations performed while forming the sketched Grams.
    pub kernel_evals: usize,
    /// Seconds forming `KS`, `SᵀKS`, `SᵀK²S`.
    pub gram_secs: f64,
    /// Seconds in the d×d Cholesky solve.
    pub solve_secs: f64,
    /// Projection dimension d.
    pub d: usize,
    /// Sketch non-zeros (density `m·d` for accumulation).
    pub nnz: usize,
    /// Ridge bump retries needed for PD-ness (0 in healthy runs).
    pub jitter_bumps: u32,
}

impl SketchedKrr {
    /// Fit the sketched estimator. `k_full` optionally shares a precomputed
    /// kernel matrix across fits (bench sweeps).
    pub fn fit(
        kernel: Kernel,
        x: &Matrix,
        y: &[f64],
        sketch: &Sketch,
        lambda: f64,
        k_full: Option<&Matrix>,
    ) -> Option<SketchedKrr> {
        let n = x.rows();
        assert_eq!(y.len(), n, "sketched krr: |y| != n");
        let mut t = Timer::start();
        let gram = sketch_gram(&kernel, x, sketch, k_full);
        let gram_secs = t.lap();

        // A = SᵀK²S + nλ·SᵀKS ; rhs = SᵀKY = (KS)ᵀ y
        let nl = n as f64 * lambda;
        let mut a = gram.stk2s.clone();
        a.axpy(nl, &gram.stks);
        a.symmetrize();
        let rhs = gram.ks.matvec_t(y);

        // PD can fail when sampled columns collide (rank-deficient SᵀKS);
        // bump the diagonal by escalating jitter like production KRR
        // libraries do, and record it.
        let mut jitter_bumps = 0;
        let scale = (0..a.rows()).map(|i| a[(i, i)]).fold(0.0f64, f64::max).max(1e-300);
        let fac = loop {
            match chol_factor(&a) {
                Some(f) => break f,
                None => {
                    jitter_bumps += 1;
                    if jitter_bumps > 8 {
                        return None;
                    }
                    a.add_diag(scale * 1e-12 * 10f64.powi(jitter_bumps as i32));
                }
            }
        };
        let theta = fac.solve(&rhs);
        let solve_secs = t.lap();

        let fitted = gram.ks.matvec(&theta);

        // fold Sθ into landmark weights
        let (landmarks, beta) = match sketch {
            Sketch::Sparse(sp) => {
                let (support, beta) = sp.landmark_weights(&theta);
                (gather_rows(x, &support), beta)
            }
            Sketch::Dense(_) => (x.clone(), sketch.s_vec(&theta)),
        };

        Some(SketchedKrr {
            kernel,
            landmarks,
            beta,
            theta,
            fitted,
            report: SketchedKrrReport {
                kernel_evals: gram.kernel_evals,
                gram_secs,
                solve_secs,
                d: sketch.d(),
                nnz: sketch.nnz(),
                jitter_bumps,
            },
        })
    }

    /// In-sample fitted values `f̂_S(xᵢ)`.
    pub fn fitted(&self) -> &[f64] {
        &self.fitted
    }

    /// θ, the d-dimensional solution.
    pub fn theta(&self) -> &[f64] {
        &self.theta
    }

    /// Landmark count (≤ m·d for accumulation sketches).
    pub fn num_landmarks(&self) -> usize {
        self.landmarks.rows()
    }

    /// Fit telemetry.
    pub fn report(&self) -> &SketchedKrrReport {
        &self.report
    }

    /// Landmark rows (sketch support points).
    pub fn landmarks(&self) -> &Matrix {
        &self.landmarks
    }

    /// Folded landmark weights β.
    pub fn beta(&self) -> &[f64] {
        &self.beta
    }

    /// Kernel used by this model.
    pub fn kernel(&self) -> &Kernel {
        &self.kernel
    }

    /// Rebuild a predict-only model from persisted parts (the coordinator's
    /// model store round-trips landmarks + β as JSON).
    pub fn from_parts(kernel: Kernel, landmarks: Matrix, beta: Vec<f64>) -> SketchedKrr {
        assert_eq!(landmarks.rows(), beta.len());
        SketchedKrr {
            kernel,
            landmarks,
            beta,
            theta: Vec::new(),
            fitted: Vec::new(),
            report: SketchedKrrReport::default(),
        }
    }

    /// Predict at query rows: `O(|landmarks|)` kernel evals per query.
    pub fn predict(&self, xq: &Matrix) -> Vec<f64> {
        let kq = cross_kernel(&self.kernel, xq, &self.landmarks);
        kq.matvec(&self.beta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::krr::KrrModel;
    use crate::rng::Pcg64;
    use crate::sketch::{SketchBuilder, SketchKind};

    fn toy_problem(n: usize, seed: u64) -> (Matrix, Vec<f64>, Kernel, f64) {
        let mut rng = Pcg64::seed(seed);
        let x = Matrix::from_fn(n, 2, |_, _| rng.uniform());
        let y: Vec<f64> = (0..n)
            .map(|i| (3.0 * x[(i, 0)]).sin() + 0.1 * rng.normal())
            .collect();
        (x, y, Kernel::gaussian(0.4), 1e-3)
    }

    #[test]
    fn full_rank_sketch_recovers_exact_krr() {
        // d = n with an invertible (Gaussian) sketch ⇒ K_S = K, so the
        // sketched estimator equals the exact one.
        let (x, y, kern, lam) = toy_problem(25, 111);
        let mut rng = Pcg64::seed(112);
        let s = SketchBuilder::new(SketchKind::Gaussian).build(25, 25, &mut rng);
        let skrr = SketchedKrr::fit(kern, &x, &y, &s, lam, None).unwrap();
        let exact = KrrModel::fit(kern, &x, &y, lam).unwrap();
        for (a, b) in skrr.fitted().iter().zip(exact.fitted().iter()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn approximation_error_decreases_with_m() {
        // the paper's core claim, in miniature: on *high-incoherence*
        // (bimodal, unbalanced) data, accumulation error at m = 16 is much
        // lower than Nyström (m = 1) at the same d, averaged over draws.
        // (On low-incoherence data the two match — that is also the theory.)
        let mut rng = Pcg64::seed(113);
        let cfg = crate::data::BimodalConfig {
            n: 150,
            gamma: 0.5,
            ..Default::default()
        };
        let (x, y, _) = crate::data::bimodal(&cfg, &mut rng);
        let kern = Kernel::gaussian(0.5);
        let lam = 1e-3;
        let exact = KrrModel::fit(kern, &x, &y, lam).unwrap();
        let err = |m: usize, seed: u64| -> f64 {
            let mut rng = Pcg64::seed(seed);
            let mut total = 0.0;
            let reps = 15;
            for _ in 0..reps {
                let s = SketchBuilder::new(SketchKind::Accumulation { m }).build(150, 10, &mut rng);
                let skrr = SketchedKrr::fit(kern, &x, &y, &s, lam, None).unwrap();
                total += crate::stats::in_sample_sq_error(skrr.fitted(), exact.fitted());
            }
            total / reps as f64
        };
        let e1 = err(1, 7);
        let e16 = err(16, 7);
        assert!(
            e16 < e1 * 0.8,
            "accumulation should beat Nyström: m=1 err {e1} vs m=16 err {e16}"
        );
    }

    #[test]
    fn predict_consistent_with_fitted() {
        let (x, y, kern, lam) = toy_problem(60, 114);
        let mut rng = Pcg64::seed(115);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(60, 10, &mut rng);
        let skrr = SketchedKrr::fit(kern, &x, &y, &s, lam, None).unwrap();
        let p = skrr.predict(&x);
        for (a, b) in p.iter().zip(skrr.fitted().iter()) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
        assert!(skrr.num_landmarks() <= 40);
    }

    #[test]
    fn shared_k_matches_fast_path() {
        let (x, y, kern, lam) = toy_problem(50, 116);
        let k = crate::kernels::kernel_matrix(&kern, &x);
        let mut rng1 = Pcg64::seed(117);
        let mut rng2 = Pcg64::seed(117);
        let s1 = SketchBuilder::new(SketchKind::Accumulation { m: 3 }).build(50, 9, &mut rng1);
        let s2 = SketchBuilder::new(SketchKind::Accumulation { m: 3 }).build(50, 9, &mut rng2);
        let a = SketchedKrr::fit(kern, &x, &y, &s1, lam, None).unwrap();
        let b = SketchedKrr::fit(kern, &x, &y, &s2, lam, Some(&k)).unwrap();
        for (u, v) in a.theta().iter().zip(b.theta().iter()) {
            assert!((u - v).abs() < 1e-7);
        }
    }

    #[test]
    fn report_populated() {
        let (x, y, kern, lam) = toy_problem(40, 118);
        let mut rng = Pcg64::seed(119);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 2 }).build(40, 6, &mut rng);
        let r = *SketchedKrr::fit(kern, &x, &y, &s, lam, None).unwrap().report();
        assert_eq!(r.d, 6);
        assert_eq!(r.nnz, 12);
        assert!(r.kernel_evals > 0 && r.kernel_evals <= 40 * 12);
    }
}
