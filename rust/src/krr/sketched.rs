//! Sketched kernel ridge regression (paper eq. 3), with both a one-shot
//! fit and the adaptive-m incremental fit that grows the accumulation
//! sketch at runtime.

use crate::data::{gather_rows_source, load_all, TileSource};
use crate::kernels::{cross_kernel_rowstable, Kernel};
use crate::leverage::{stat_dim_from_scores, BlessResult};
use crate::linalg::{chol_factor, CholFactor, Matrix, Precision};
use crate::rng::{AliasTable, Pcg64};
use crate::sketch::{
    try_sketch_gram_with, IncrementalGram, Sampling, Sketch, SketchBuilder, SketchOps,
};
use crate::stats::{amm_error_proxy, rel_change, StoppingRule};
use crate::util::timer::Timer;
use crate::util::CodedError;

/// Trained sketched-KRR model.
///
/// Training solves `(SᵀK²S + nλ SᵀKS) θ = SᵀKY` (d×d system). Prediction
/// folds `Sθ` into *landmark weights* over the sketch support: for a sparse
/// accumulation sketch, `f̂_S(x) = Σ_u β_u k(x, x_u)` over at most `m·d`
/// support points (paper §3.3); for dense sketches the support is all of X.
#[derive(Clone, Debug)]
pub struct SketchedKrr {
    kernel: Kernel,
    /// Landmark feature rows (support points of the sketch).
    landmarks: Matrix,
    /// Folded weights β (one per landmark row).
    beta: Vec<f64>,
    /// Solution of the d×d system.
    theta: Vec<f64>,
    /// In-sample fitted values `(KSθ)ᵢ`.
    fitted: Vec<f64>,
    report: SketchedKrrReport,
}

/// Cost/telemetry of one sketched fit — consumed by the bench harness and
/// the coordinator's metrics endpoint.
#[derive(Clone, Copy, Debug, Default)]
pub struct SketchedKrrReport {
    /// Kernel evaluations performed while forming the sketched Grams.
    pub kernel_evals: usize,
    /// Seconds forming `KS`, `SᵀKS`, `SᵀK²S`.
    pub gram_secs: f64,
    /// Seconds in the d×d solve (factorisations + rank updates + triangular
    /// solves).
    pub solve_secs: f64,
    /// Projection dimension d.
    pub d: usize,
    /// Sketch non-zeros (density `m·d` for accumulation).
    pub nnz: usize,
    /// Ridge bump retries needed for PD-ness (0 in healthy runs).
    pub jitter_bumps: u32,
    /// Accumulated terms `m` (adaptive fits; 0 when unknown/not adaptive).
    pub m: usize,
    /// Adaptive rounds run (0 for one-shot fits).
    pub rounds: usize,
    /// Rounds solved by Cholesky rank up/down-date instead of
    /// re-factorisation.
    pub rank_updates: u32,
    /// Rounds that (re)factorised the d×d system.
    pub refactors: u32,
    /// Statistical dimension `Σᵢ ℓ̂ᵢ` of the refined leverage estimate
    /// (0.0 when no refinement ran / no scores were computed).
    pub d_stat: f64,
    /// 1-based adaptive round after which the sampling distribution was
    /// refined to estimated leverage scores (0 = never refined).
    pub refine_round: usize,
}

/// Knobs of [`SketchedKrr::fit_adaptive`].
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveOptions {
    /// Terms in the first round.
    pub m0: usize,
    /// Hard cap on accumulated terms.
    pub m_max: usize,
    /// Geometric growth factor of the m-schedule (each round grows to
    /// `max(m+1, ⌈m·growth⌉)`, capped at `m_max`).
    pub growth: f64,
    /// Stop when the relative θ-change stays below this for `patience`
    /// consecutive rounds (negative disables the criterion — the loop
    /// then runs to `m_max` or the AMM threshold).
    pub rel_tol: f64,
    /// Consecutive quiet rounds required by the relative-change criterion.
    pub patience: usize,
    /// Optional AMM-error threshold: stop once
    /// [`amm_error_proxy`](crate::stats::amm_error_proxy)`(n, d, m)` falls
    /// below it.
    pub amm_tol: Option<f64>,
    /// Max [`AppendDelta::rank`](crate::sketch::AppendDelta::rank)
    /// admitted to the Cholesky rank-update path; `None` picks by cost
    /// (update wins when `9·rank ≤ d`). `Some(usize::MAX)` forces the
    /// update path (tests / benches).
    pub rank_update_limit: Option<usize>,
    /// Between-term probability refinement: once the sketch holds at least
    /// this many terms, estimate leverage scores from the support columns
    /// already cached in [`IncrementalGram`]
    /// ([`estimate_leverage`](IncrementalGram::estimate_leverage) — only
    /// the kernel diagonal is newly evaluated) and switch the remaining
    /// draws to `pᵢ ∝ ℓ̂ᵢ`. `0` disables refinement (the default — the
    /// uniform path stays bit-identical to its pre-refinement behaviour);
    /// `1` refines after the first round, the recommended setting.
    pub refine_after_m: usize,
}

impl Default for AdaptiveOptions {
    fn default() -> Self {
        AdaptiveOptions {
            m0: 1,
            m_max: 64,
            growth: 2.0,
            rel_tol: 1e-3,
            patience: 1,
            amm_tol: None,
            rank_update_limit: None,
            refine_after_m: 0,
        }
    }
}

/// One round of the adaptive loop (telemetry trace).
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveRound {
    /// Accumulated terms after this round.
    pub m: usize,
    /// Relative θ-change vs the previous round (∞ on the first).
    pub rel_change: f64,
    /// Whether the round re-factorised (vs rank-updated) the d×d system.
    pub refactored: bool,
    /// Whether this round's appended terms were drawn from the *refined*
    /// (estimated-leverage) distribution — `false` until the round after
    /// the switch-over recorded in
    /// [`SketchedKrrReport::refine_round`].
    pub refined: bool,
    /// Wall-clock seconds of the round (gram growth + solve).
    pub secs: f64,
}

/// Factor `a`, escalating a diagonal jitter bump on failure like
/// production KRR libraries do (sampled columns can collide, leaving
/// `SᵀKS` rank-deficient). Returns the factor and the bumps applied, or
/// `None` after 8 failed escalations. `a` is mutated by the bumps.
pub(crate) fn factor_with_jitter(a: &mut Matrix) -> Option<(CholFactor, u32)> {
    let mut jitter_bumps = 0u32;
    let scale = (0..a.rows())
        .map(|i| a[(i, i)])
        .fold(0.0f64, f64::max)
        .max(1e-300);
    loop {
        match chol_factor(a) {
            Some(f) => return Some((f, jitter_bumps)),
            None => {
                jitter_bumps += 1;
                if jitter_bumps > 8 {
                    return None;
                }
                a.add_diag(scale * 1e-12 * 10f64.powi(jitter_bumps as i32));
            }
        }
    }
}

impl SketchedKrr {
    /// Assemble the trained model from a solved system: fitted values from
    /// `KSθ`, prediction weights by folding `Sθ` into the sketch support.
    /// Sparse sketches gather only their support rows off the source (one
    /// tile read per landmark — the out-of-core path stays `O(|support|·p)`
    /// resident); dense sketches support *every* row, so the model loads
    /// all of `X` — dense baselines are documented as not out-of-core.
    fn finish(
        kernel: Kernel,
        x: &dyn TileSource,
        sketch: &Sketch,
        ks: &Matrix,
        theta: Vec<f64>,
        report: SketchedKrrReport,
    ) -> Result<SketchedKrr, CodedError> {
        let fitted = ks.matvec(&theta);
        let (landmarks, beta) = match sketch {
            Sketch::Sparse(sp) => {
                let (support, beta) = sp.landmark_weights(&theta);
                (gather_rows_source(x, &support)?, beta)
            }
            Sketch::Dense(_) => (load_all(x)?, sketch.s_vec(&theta)),
        };
        Ok(SketchedKrr {
            kernel,
            landmarks,
            beta,
            theta,
            fitted,
            report,
        })
    }

    /// Fit the sketched estimator. With `k_full = None` (the production
    /// path) every Gram quantity streams through the row-tiled
    /// [`GramOperator`](crate::kernels::GramOperator) — no `n×n`
    /// allocation for sparse *or* dense sketches, peak memory
    /// `O(tile·n + n·d)`. `k_full` optionally shares a precomputed kernel
    /// matrix across fits (bench sweeps that amortise one assembly).
    pub fn fit(
        kernel: Kernel,
        x: &dyn TileSource,
        y: &[f64],
        sketch: &Sketch,
        lambda: f64,
        k_full: Option<&Matrix>,
    ) -> Option<SketchedKrr> {
        Self::fit_with(kernel, x, y, sketch, lambda, k_full, Precision::F64)
    }

    /// Fallible [`fit`](Self::fit): a failed tile-source read (real, or
    /// injected through the `io.read` fault seam) surfaces as a
    /// [`CodedError`] instead of a panic. `Ok(None)` still means the
    /// sketched system could not be factored.
    pub fn try_fit(
        kernel: Kernel,
        x: &dyn TileSource,
        y: &[f64],
        sketch: &Sketch,
        lambda: f64,
        k_full: Option<&Matrix>,
    ) -> Result<Option<SketchedKrr>, CodedError> {
        Self::try_fit_with(kernel, x, y, sketch, lambda, k_full, Precision::F64)
    }

    /// [`SketchedKrr::fit`] with an explicit Gram-accumulation
    /// [`Precision`]. `F32` assembles kernel panels and accumulates `K·S`
    /// in single precision (the `exp`-bound hot path runs the 8-lane f32
    /// kernel map under AVX2 dispatch) and widens once per Gram entry;
    /// the `d×d` system, its Cholesky factorisation and every solve stay
    /// f64, so θ degrades only through the Gram entries (~1e-7 relative
    /// each — end-to-end bounds gated in EXPERIMENTS.md §Mixed-precision).
    /// The adaptive fit ([`SketchedKrr::fit_adaptive`]) intentionally has
    /// no precision knob: its incremental rank-update identities assume
    /// the Grams are exact in f64.
    pub fn fit_with(
        kernel: Kernel,
        x: &dyn TileSource,
        y: &[f64],
        sketch: &Sketch,
        lambda: f64,
        k_full: Option<&Matrix>,
        precision: Precision,
    ) -> Option<SketchedKrr> {
        Self::try_fit_with(kernel, x, y, sketch, lambda, k_full, precision)
            .expect("sketched krr: tile source read failed")
    }

    /// Fallible [`fit_with`](Self::fit_with) — the core every fit wrapper
    /// routes through.
    pub fn try_fit_with(
        kernel: Kernel,
        x: &dyn TileSource,
        y: &[f64],
        sketch: &Sketch,
        lambda: f64,
        k_full: Option<&Matrix>,
        precision: Precision,
    ) -> Result<Option<SketchedKrr>, CodedError> {
        let n = x.rows();
        assert_eq!(y.len(), n, "sketched krr: |y| != n");
        let mut t = Timer::start();
        let gram = try_sketch_gram_with(&kernel, x, sketch, k_full, precision)?;
        let gram_secs = t.lap();

        // A = SᵀK²S + nλ·SᵀKS ; rhs = SᵀKY = (KS)ᵀ y
        let nl = n as f64 * lambda;
        let mut a = gram.stk2s.clone();
        a.axpy(nl, &gram.stks);
        a.symmetrize();
        let rhs = gram.ks.matvec_t(y);
        let Some((fac, jitter_bumps)) = factor_with_jitter(&mut a) else {
            return Ok(None);
        };
        let theta = fac.solve(&rhs);
        let solve_secs = t.lap();

        let report = SketchedKrrReport {
            kernel_evals: gram.kernel_evals,
            gram_secs,
            solve_secs,
            d: sketch.d(),
            nnz: sketch.nnz(),
            jitter_bumps,
            ..Default::default()
        };
        Ok(Some(SketchedKrr::finish(
            kernel, x, sketch, &gram.ks, theta, report,
        )?))
    }

    /// Fit with an **adaptively grown** accumulation sketch: starting from
    /// `m0` terms, each round appends terms (geometric schedule), folds
    /// them into the Grams incrementally ([`IncrementalGram`] — kernel
    /// evaluations only at new support points), updates the d×d Cholesky
    /// factor by rank up/down-date when the append is low-rank enough (and
    /// re-factorises otherwise), and stops when the
    /// [`StoppingRule`](crate::stats::StoppingRule) fires or `m_max` is
    /// reached.
    ///
    /// Only the *sampling distribution* of `builder` is used — the number
    /// of terms is what this function discovers (reported in
    /// [`SketchedKrrReport::m`]).
    ///
    /// Determinism contract: with the stopping criteria disabled
    /// (`rel_tol < 0`, no `amm_tol`), growing to `m_max` consumes exactly
    /// the RNG draws of a one-shot `Accumulation { m: m_max }` build, the
    /// grown sketch bit-matches it, and θ agrees to solver round-off.
    pub fn fit_adaptive(
        kernel: Kernel,
        x: &dyn TileSource,
        y: &[f64],
        builder: &SketchBuilder,
        d: usize,
        lambda: f64,
        opts: &AdaptiveOptions,
        rng: &mut Pcg64,
    ) -> Option<(SketchedKrr, Vec<AdaptiveRound>)> {
        Self::fit_adaptive_warm(kernel, x, y, builder, d, lambda, opts, rng, None)
    }

    /// Fallible [`fit_adaptive`](Self::fit_adaptive): a failed tile-source
    /// read surfaces as a [`CodedError`]; the incremental state is local to
    /// the call, so nothing is poisoned — retrying the fit after the fault
    /// clears recomputes every column.
    pub fn try_fit_adaptive(
        kernel: Kernel,
        x: &dyn TileSource,
        y: &[f64],
        builder: &SketchBuilder,
        d: usize,
        lambda: f64,
        opts: &AdaptiveOptions,
        rng: &mut Pcg64,
    ) -> Result<Option<(SketchedKrr, Vec<AdaptiveRound>)>, CodedError> {
        Self::try_fit_adaptive_warm(kernel, x, y, builder, d, lambda, opts, rng, None)
    }

    /// [`fit_adaptive`](Self::fit_adaptive) warm-started from a
    /// [`bless`](crate::leverage::bless) run on the same data: the
    /// landmark panel `bless` already evaluated is seeded into
    /// [`IncrementalGram`]'s support-column cache
    /// ([`seed_columns`](IncrementalGram::seed_columns)), so any sketch
    /// support that lands on a landmark row — the common case when
    /// `builder` samples from
    /// [`sampling_table`](crate::leverage::BlessResult::sampling_table) —
    /// costs zero new kernel column evaluations.
    pub fn fit_adaptive_warm(
        kernel: Kernel,
        x: &dyn TileSource,
        y: &[f64],
        builder: &SketchBuilder,
        d: usize,
        lambda: f64,
        opts: &AdaptiveOptions,
        rng: &mut Pcg64,
        warm: Option<&BlessResult>,
    ) -> Option<(SketchedKrr, Vec<AdaptiveRound>)> {
        Self::try_fit_adaptive_warm(kernel, x, y, builder, d, lambda, opts, rng, warm)
            .expect("sketched krr: tile source read failed")
    }

    /// Fallible [`fit_adaptive_warm`](Self::fit_adaptive_warm) — the core
    /// the adaptive wrappers route through.
    pub fn try_fit_adaptive_warm(
        kernel: Kernel,
        x: &dyn TileSource,
        y: &[f64],
        builder: &SketchBuilder,
        d: usize,
        lambda: f64,
        opts: &AdaptiveOptions,
        rng: &mut Pcg64,
        warm: Option<&BlessResult>,
    ) -> Result<Option<(SketchedKrr, Vec<AdaptiveRound>)>, CodedError> {
        let n = x.rows();
        assert_eq!(y.len(), n, "adaptive krr: |y| != n");
        assert!(d >= 1 && opts.m_max >= 1, "adaptive krr: d, m_max >= 1");
        let nl = n as f64 * lambda;

        let mut acc = builder.grower(n, d);
        let mut inc = IncrementalGram::new(kernel, n, d);
        if let Some(b) = warm {
            inc.seed_columns(&b.landmarks, &b.panel);
        }
        let mut rule = StoppingRule::new(opts.rel_tol, opts.patience);
        if let Some(t) = opts.amm_tol {
            rule = rule.with_amm_tol(t);
        }
        let mut fac: Option<CholFactor> = None;
        let mut theta: Vec<f64> = Vec::new();
        let mut trace: Vec<AdaptiveRound> = Vec::new();
        let (mut gram_secs, mut solve_secs) = (0.0, 0.0);
        let (mut rank_updates, mut refactors, mut jitter_bumps) = (0u32, 0u32, 0u32);
        let mut refined = false;
        let mut refine_round = 0usize;
        let mut d_stat = 0.0f64;
        let mut m_target = opts.m0.max(1).min(opts.m_max);
        loop {
            let drew_refined = refined;
            let mut t = Timer::start();
            acc.grow_to(m_target, rng);
            let delta = inc
                .try_sync(x, &acc)?
                .expect("adaptive krr: sketch must grow");
            let g_secs = t.lap();
            gram_secs += g_secs;

            // rank-update the factor when the appended support is small
            // enough for 3δ rank-1 sweeps to beat a d³/3 re-factorisation
            let admit = opts.rank_update_limit.unwrap_or(d / 9);
            let mut updated = false;
            if delta.rank() <= admit {
                if let Some(f) = fac.as_mut() {
                    if let Some((cols, sigma)) = delta.factor_update(nl) {
                        f.scale(delta.alpha);
                        if f.rank_update(&cols, &sigma) {
                            updated = true;
                            rank_updates += 1;
                        } else {
                            // downdates lost PD by a numerical hair: bump
                            // the factored system by a tiny ridge and retry
                            // once before paying for a full rebuild
                            let diag_scale = (0..f.n())
                                .map(|i| {
                                    let l = f.l()[(i, i)];
                                    l * l
                                })
                                .fold(0.0f64, f64::max)
                                .max(1e-300);
                            f.diag_update(diag_scale * 1e-10);
                            if f.rank_update(&cols, &sigma) {
                                updated = true;
                                rank_updates += 1;
                                jitter_bumps += 1;
                            }
                        }
                    }
                }
            }
            if !updated {
                let mut a = inc.stk2s().clone();
                a.axpy(nl, inc.stks());
                a.symmetrize();
                let Some((f, bumps)) = factor_with_jitter(&mut a) else {
                    return Ok(None);
                };
                jitter_bumps += bumps;
                fac = Some(f);
                refactors += 1;
            }
            let rhs = inc.rhs(y);
            let new_theta = fac.as_ref().expect("factor present").solve(&rhs);
            let s_secs = t.lap();
            solve_secs += s_secs;

            let change = if theta.is_empty() {
                f64::INFINITY
            } else {
                rel_change(&theta, &new_theta)
            };
            theta = new_theta;
            let m = acc.m();
            trace.push(AdaptiveRound {
                m,
                rel_change: change,
                refactored: !updated,
                refined: drew_refined,
                secs: g_secs + s_secs,
            });
            if rule.observe(m, change, amm_error_proxy(n, d, m)) || m >= opts.m_max {
                break;
            }
            // between-term probability refinement: the support columns the
            // early uniform terms already cached double as BLESS landmarks
            // — estimate leverage from them (only the kernel diagonal is
            // newly evaluated) and let every later term draw `pᵢ ∝ ℓ̂ᵢ`.
            // Consumes no sketch RNG, so the uniform path (refine_after_m
            // = 0) is untouched draw for draw.
            if !refined && opts.refine_after_m > 0 && m >= opts.refine_after_m {
                if let Some(scores) = inc.try_estimate_leverage(x, lambda)? {
                    d_stat = stat_dim_from_scores(&scores);
                    acc.set_sampling(Sampling::Weighted(AliasTable::new(&scores)));
                    refined = true;
                    refine_round = trace.len();
                }
            }
            m_target = ((m as f64 * opts.growth).ceil() as usize)
                .max(m + 1)
                .min(opts.m_max);
        }

        let report = SketchedKrrReport {
            kernel_evals: inc.kernel_evals(),
            gram_secs,
            solve_secs,
            d,
            nnz: SketchOps::nnz(&acc),
            jitter_bumps,
            m: acc.m(),
            rounds: trace.len(),
            rank_updates,
            refactors,
            d_stat,
            refine_round,
        };
        let sketch = acc.as_sketch();
        let model = SketchedKrr::finish(kernel, x, &sketch, inc.ks(), theta, report)?;
        Ok(Some((model, trace)))
    }

    /// In-sample fitted values `f̂_S(xᵢ)`.
    pub fn fitted(&self) -> &[f64] {
        &self.fitted
    }

    /// θ, the d-dimensional solution.
    pub fn theta(&self) -> &[f64] {
        &self.theta
    }

    /// Landmark count (≤ m·d for accumulation sketches).
    pub fn num_landmarks(&self) -> usize {
        self.landmarks.rows()
    }

    /// Fit telemetry.
    pub fn report(&self) -> &SketchedKrrReport {
        &self.report
    }

    /// Landmark rows (sketch support points).
    pub fn landmarks(&self) -> &Matrix {
        &self.landmarks
    }

    /// Folded landmark weights β.
    pub fn beta(&self) -> &[f64] {
        &self.beta
    }

    /// Kernel used by this model.
    pub fn kernel(&self) -> &Kernel {
        &self.kernel
    }

    /// Rebuild a predict-only model from persisted parts (the coordinator's
    /// model store round-trips landmarks + β as JSON).
    pub fn from_parts(kernel: Kernel, landmarks: Matrix, beta: Vec<f64>) -> SketchedKrr {
        assert_eq!(landmarks.rows(), beta.len());
        SketchedKrr {
            kernel,
            landmarks,
            beta,
            theta: Vec::new(),
            fitted: Vec::new(),
            report: SketchedKrrReport::default(),
        }
    }

    /// Predict at query rows: `O(|landmarks|)` kernel evals per query.
    ///
    /// Assembly goes through the **row-stable** route
    /// ([`cross_kernel_rowstable`]): each prediction is bitwise a
    /// function of its own query row and the model only, never of the
    /// other rows in `xq`. The serving plane's micro-batcher relies on
    /// this — coalescing requests into one GEMM must not change anyone's
    /// answer (`matvec` is per-output-row independent, so the contract
    /// survives the final product too).
    pub fn predict(&self, xq: &Matrix) -> Vec<f64> {
        let kq = cross_kernel_rowstable(&self.kernel, xq, &self.landmarks);
        kq.matvec(&self.beta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::krr::KrrModel;
    use crate::rng::Pcg64;
    use crate::sketch::{SketchBuilder, SketchKind};

    fn toy_problem(n: usize, seed: u64) -> (Matrix, Vec<f64>, Kernel, f64) {
        let mut rng = Pcg64::seed(seed);
        let x = Matrix::from_fn(n, 2, |_, _| rng.uniform());
        let y: Vec<f64> = (0..n)
            .map(|i| (3.0 * x[(i, 0)]).sin() + 0.1 * rng.normal())
            .collect();
        (x, y, Kernel::gaussian(0.4), 1e-3)
    }

    #[test]
    fn full_rank_sketch_recovers_exact_krr() {
        // d = n with an invertible (Gaussian) sketch ⇒ K_S = K, so the
        // sketched estimator equals the exact one.
        let (x, y, kern, lam) = toy_problem(25, 111);
        let mut rng = Pcg64::seed(112);
        let s = SketchBuilder::new(SketchKind::Gaussian).build(25, 25, &mut rng);
        let skrr = SketchedKrr::fit(kern, &x, &y, &s, lam, None).unwrap();
        let exact = KrrModel::fit(kern, &x, &y, lam).unwrap();
        for (a, b) in skrr.fitted().iter().zip(exact.fitted().iter()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    /// The paper's core claim, in miniature: on *high-incoherence*
    /// (bimodal, unbalanced) data, accumulation error at m = 16 is much
    /// lower than Nyström (m = 1) at the same d. (On low-incoherence data
    /// the two match — that is also the theory.) A single seed can flip
    /// the ordering — this test spent a long time `#[ignore]`d for exactly
    /// that — so the assertion compares **medians over independent
    /// seeds**, each seed's value itself a small replicate average: a
    /// failure now needs a majority of seeds to invert the ordering, not
    /// one unlucky draw.
    #[test]
    fn approximation_error_decreases_with_m() {
        let mut rng = Pcg64::seed(113);
        let cfg = crate::data::BimodalConfig {
            n: 150,
            gamma: 0.5,
            ..Default::default()
        };
        let (x, y, _) = crate::data::bimodal(&cfg, &mut rng);
        let kern = Kernel::gaussian(0.5);
        let lam = 1e-3;
        let exact = KrrModel::fit(kern, &x, &y, lam).unwrap();
        let err = |m: usize, seed: u64| -> f64 {
            let mut rng = Pcg64::seed(seed);
            let mut total = 0.0;
            let reps = 5;
            for _ in 0..reps {
                let s = SketchBuilder::new(SketchKind::Accumulation { m }).build(150, 10, &mut rng);
                let skrr = SketchedKrr::fit(kern, &x, &y, &s, lam, None).unwrap();
                total += crate::stats::in_sample_sq_error(skrr.fitted(), exact.fitted());
            }
            total / reps as f64
        };
        let median = |m: usize| -> f64 {
            let mut vals: Vec<f64> = [7u64, 19, 41, 83, 131].iter().map(|&s| err(m, s)).collect();
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals[vals.len() / 2]
        };
        let e1 = median(1);
        let e16 = median(16);
        assert!(
            e16 < e1 * 0.8,
            "accumulation should beat Nyström: m=1 median err {e1} vs m=16 median err {e16}"
        );
    }

    #[test]
    fn predict_consistent_with_fitted() {
        let (x, y, kern, lam) = toy_problem(60, 114);
        let mut rng = Pcg64::seed(115);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(60, 10, &mut rng);
        let skrr = SketchedKrr::fit(kern, &x, &y, &s, lam, None).unwrap();
        let p = skrr.predict(&x);
        for (a, b) in p.iter().zip(skrr.fitted().iter()) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
        assert!(skrr.num_landmarks() <= 40);
    }

    /// End-to-end accuracy bound for the mixed-precision path: an F32 fit
    /// tracks the F64 fit on θ and the fitted values to well inside the
    /// paper's statistical error scale (the Gram entries each carry
    /// ~1e-7 relative noise; the f64 d×d solve does not amplify it beyond
    /// the system's modest conditioning). Also pins that F64 through
    /// `fit_with` is exactly `fit`.
    #[test]
    fn f32_precision_fit_tracks_f64_fit() {
        let (x, y, kern, lam) = toy_problem(200, 130);
        let mut rng = Pcg64::seed(131);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(200, 12, &mut rng);
        let f64_fit = SketchedKrr::fit(kern, &x, &y, &s, lam, None).unwrap();
        let same = SketchedKrr::fit_with(kern, &x, &y, &s, lam, None, Precision::F64).unwrap();
        assert_eq!(f64_fit.theta(), same.theta(), "F64 fit_with == fit");
        let f32_fit = SketchedKrr::fit_with(kern, &x, &y, &s, lam, None, Precision::F32).unwrap();
        let theta_scale = f64_fit
            .theta()
            .iter()
            .fold(0.0f64, |m, v| m.max(v.abs()));
        for (a, b) in f32_fit.theta().iter().zip(f64_fit.theta().iter()) {
            assert!(
                (a - b).abs() < 1e-3 * (1.0 + theta_scale),
                "theta {a} vs {b}"
            );
        }
        for (a, b) in f32_fit.fitted().iter().zip(f64_fit.fitted().iter()) {
            assert!((a - b).abs() < 1e-3, "fitted {a} vs {b}");
        }
    }

    #[test]
    fn shared_k_matches_fast_path() {
        let (x, y, kern, lam) = toy_problem(50, 116);
        let k = crate::kernels::kernel_matrix(&kern, &x);
        let mut rng1 = Pcg64::seed(117);
        let mut rng2 = Pcg64::seed(117);
        let s1 = SketchBuilder::new(SketchKind::Accumulation { m: 3 }).build(50, 9, &mut rng1);
        let s2 = SketchBuilder::new(SketchKind::Accumulation { m: 3 }).build(50, 9, &mut rng2);
        let a = SketchedKrr::fit(kern, &x, &y, &s1, lam, None).unwrap();
        let b = SketchedKrr::fit(kern, &x, &y, &s2, lam, Some(&k)).unwrap();
        for (u, v) in a.theta().iter().zip(b.theta().iter()) {
            assert!((u - v).abs() < 1e-7);
        }
    }

    #[test]
    fn report_populated() {
        let (x, y, kern, lam) = toy_problem(40, 118);
        let mut rng = Pcg64::seed(119);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 2 }).build(40, 6, &mut rng);
        let r = *SketchedKrr::fit(kern, &x, &y, &s, lam, None).unwrap().report();
        assert_eq!(r.d, 6);
        assert_eq!(r.nnz, 12);
        assert!(r.kernel_evals > 0 && r.kernel_evals <= 40 * 12);
        assert_eq!(r.rounds, 0, "one-shot fit has no adaptive rounds");
    }

    /// Tentpole acceptance: with the stopping rule disabled, the adaptive
    /// fit grown 1 → m_max produces a bit-identical sketch (checked via
    /// landmark count + RNG stream position) and a θ that agrees with a
    /// one-shot `Accumulation { m_max }` fit from the same seed.
    #[test]
    fn adaptive_growth_matches_one_shot_accumulation() {
        let (x, y, kern, lam) = toy_problem(80, 120);
        let (d, m_max) = (10, 8);
        let opts = AdaptiveOptions {
            m0: 1,
            m_max,
            growth: 2.0,
            rel_tol: -1.0, // disabled: run to m_max
            patience: 1,
            amm_tol: None,
            rank_update_limit: None,
            refine_after_m: 0,
        };
        let builder = SketchBuilder::new(SketchKind::Accumulation { m: m_max });
        let mut rng_a = Pcg64::seed(121);
        let (model, trace) =
            SketchedKrr::fit_adaptive(kern, &x, &y, &builder, d, lam, &opts, &mut rng_a).unwrap();
        assert_eq!(model.report().m, m_max);
        assert_eq!(trace.len(), 4, "schedule 1,2,4,8");
        assert_eq!(trace.last().unwrap().m, m_max);

        let mut rng_b = Pcg64::seed(121);
        let s = builder.build(80, d, &mut rng_b);
        let shot = SketchedKrr::fit(kern, &x, &y, &s, lam, None).unwrap();
        // same RNG draws were consumed → streams line up afterwards
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
        assert_eq!(model.num_landmarks(), shot.num_landmarks());
        for (a, b) in model.theta().iter().zip(shot.theta().iter()) {
            let tol = 1e-8 * b.abs().max(1.0);
            assert!((a - b).abs() < tol, "theta {a} vs {b}");
        }
        for (a, b) in model.fitted().iter().zip(shot.fitted().iter()) {
            assert!((a - b).abs() < 1e-7, "fitted {a} vs {b}");
        }
    }

    /// The adaptive loop stops before m_max once θ stabilises.
    #[test]
    fn adaptive_stops_early_on_loose_tolerance() {
        let (x, y, kern, lam) = toy_problem(100, 122);
        let opts = AdaptiveOptions {
            m_max: 64,
            rel_tol: 0.5, // very loose → converges in few rounds
            ..Default::default()
        };
        let builder = SketchBuilder::new(SketchKind::Accumulation { m: 1 });
        let mut rng = Pcg64::seed(123);
        let (model, trace) =
            SketchedKrr::fit_adaptive(kern, &x, &y, &builder, 8, lam, &opts, &mut rng).unwrap();
        assert!(model.report().m < 64, "chosen m = {}", model.report().m);
        assert_eq!(model.report().rounds, trace.len());
        assert!(model.report().refactors >= 1);
        // the model still predicts coherently
        let p = model.predict(&x);
        for (a, b) in p.iter().zip(model.fitted().iter()) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    /// Forcing the rank-update solver path yields the same θ as the
    /// refactor-every-round path — the up/down-date algebra is exact.
    #[test]
    fn adaptive_rank_update_path_matches_refactor_path() {
        let (x, y, kern, lam) = toy_problem(70, 124);
        let (d, m_max) = (9, 8);
        let base = AdaptiveOptions {
            m_max,
            rel_tol: -1.0,
            ..Default::default()
        };
        let forced = AdaptiveOptions {
            rank_update_limit: Some(usize::MAX),
            ..base
        };
        let never = AdaptiveOptions {
            rank_update_limit: Some(0),
            ..base
        };
        let builder = SketchBuilder::new(SketchKind::Accumulation { m: 1 });
        let mut rng1 = Pcg64::seed(125);
        let mut rng2 = Pcg64::seed(125);
        let (a, _) =
            SketchedKrr::fit_adaptive(kern, &x, &y, &builder, d, lam, &forced, &mut rng1).unwrap();
        let (b, _) =
            SketchedKrr::fit_adaptive(kern, &x, &y, &builder, d, lam, &never, &mut rng2).unwrap();
        assert!(
            a.report().rank_updates >= 1,
            "forced path must rank-update at least once (got {:?})",
            a.report()
        );
        assert_eq!(b.report().rank_updates, 0);
        for (u, v) in a.theta().iter().zip(b.theta().iter()) {
            let tol = 1e-6 * v.abs().max(1.0);
            assert!((u - v).abs() < tol, "theta {u} vs {v}");
        }
    }

    /// Between-term refinement: with `refine_after_m = 1` the loop
    /// switches to estimated-leverage draws after the first round and
    /// records the switch-over in the report and the trace.
    #[test]
    fn adaptive_refinement_switches_distribution_and_reports_it() {
        let (x, y, kern, lam) = toy_problem(80, 128);
        let (d, m_max) = (10, 8);
        let opts = AdaptiveOptions {
            m_max,
            rel_tol: -1.0, // run to m_max so every round is observed
            refine_after_m: 1,
            ..Default::default()
        };
        let builder = SketchBuilder::new(SketchKind::Accumulation { m: 1 });
        let mut rng = Pcg64::seed(129);
        let (model, trace) =
            SketchedKrr::fit_adaptive(kern, &x, &y, &builder, d, lam, &opts, &mut rng).unwrap();
        let rep = model.report();
        assert_eq!(rep.refine_round, 1, "switch after the first round");
        assert!(rep.d_stat > 0.0, "d_stat from the refined scores");
        assert!(!trace[0].refined, "round 1 drew uniform");
        assert!(
            trace[1..].iter().all(|r| r.refined),
            "all later rounds drew refined"
        );
        assert_eq!(rep.m, m_max);
        assert!(model.fitted().iter().all(|v| v.is_finite()));
        // refinement itself must not consume sketch RNG: the uniform terms
        // of an unrefined run from the same seed bit-match round 1
        let mut rng_u = Pcg64::seed(129);
        let uniform_opts = AdaptiveOptions {
            m_max,
            rel_tol: -1.0,
            ..Default::default()
        };
        let (model_u, _) = SketchedKrr::fit_adaptive(
            kern,
            &x,
            &y,
            &builder,
            d,
            lam,
            &uniform_opts,
            &mut rng_u,
        )
        .unwrap();
        assert_eq!(model_u.report().refine_round, 0);
        assert_eq!(model_u.report().d_stat, 0.0);
    }

    /// The refinement path stays streamed: estimating leverage from cached
    /// support columns must never assemble an n×n kernel matrix.
    #[test]
    fn adaptive_refinement_never_materialises_n_by_n() {
        let (x, y, kern, lam) = toy_problem(90, 138);
        let opts = AdaptiveOptions {
            m_max: 8,
            rel_tol: -1.0,
            refine_after_m: 1,
            ..Default::default()
        };
        let builder = SketchBuilder::new(SketchKind::Accumulation { m: 1 });
        let mut rng = Pcg64::seed(139);
        crate::kernels::assembly_guard::reset();
        let (model, _) =
            SketchedKrr::fit_adaptive(kern, &x, &y, &builder, 9, lam, &opts, &mut rng).unwrap();
        assert!(model.report().refine_round >= 1);
        let max_sq = crate::kernels::assembly_guard::max_square();
        assert!(
            max_sq < 90,
            "refinement assembled a {max_sq}×{max_sq} square kernel block"
        );
    }

    /// BLESS panel reuse: a warm-started fit whose sampling is the bless
    /// table restricted to landmark rows pays zero kernel *column*
    /// evaluations — every support column is already seeded.
    #[test]
    fn warm_start_reuses_bless_landmark_panel() {
        let (x, y, kern, lam) = toy_problem(70, 140);
        let mut lev_rng = Pcg64::seed(141);
        let bl = crate::leverage::bless(&kern, &x, lam, 12, 2.0, &mut lev_rng);
        assert!(!bl.landmarks.is_empty());
        // concentrate all sampling mass on the landmark rows so the sketch
        // support is provably a subset of the seeded columns
        let mut weights = vec![0.0; 70];
        for &r in &bl.landmarks {
            weights[r] = bl.scores[r].max(1e-12);
        }
        let builder = SketchBuilder::new(SketchKind::Accumulation { m: 1 })
            .with_sampling(Sampling::Weighted(AliasTable::new(&weights)));
        let opts = AdaptiveOptions {
            m_max: 4,
            rel_tol: -1.0,
            ..Default::default()
        };
        let mut rng = Pcg64::seed(142);
        let (model, _) = SketchedKrr::fit_adaptive_warm(
            kern,
            &x,
            &y,
            &builder,
            8,
            lam,
            &opts,
            &mut rng,
            Some(&bl),
        )
        .unwrap();
        assert_eq!(
            model.report().kernel_evals,
            0,
            "support ⊆ landmarks → all columns reused from the bless panel"
        );
        // the same fit without the warm start pays for its support columns
        let mut rng2 = Pcg64::seed(142);
        let (cold, _) =
            SketchedKrr::fit_adaptive(kern, &x, &y, &builder, 8, lam, &opts, &mut rng2).unwrap();
        assert!(cold.report().kernel_evals > 0);
        // and the models agree: seeding changes cost, not math
        for (a, b) in model.theta().iter().zip(cold.theta().iter()) {
            assert!((a - b).abs() < 1e-9 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    /// The jitter-bump path, deterministically: `A = vvᵀ` with
    /// power-of-two entries makes every elimination exact, so the second
    /// pivot is *exactly* zero and the first factorisation is guaranteed
    /// to fail — the escalating diagonal bump must rescue it.
    #[test]
    fn factor_with_jitter_rescues_exactly_singular_system() {
        let v = [1.0, 2.0, 4.0, 8.0];
        let mut a = Matrix::from_fn(4, 4, |i, j| v[i] * v[j]);
        assert!(chol_factor(&a).is_none(), "rank-1 matrix must fail plain chol");
        let (f, bumps) = factor_with_jitter(&mut a).expect("jitter should rescue");
        assert!(bumps > 0);
        // the bumped system solves consistently for an in-range rhs
        let x = f.solve(&v);
        let back = a.matvec(&x);
        for (u, w) in back.iter().zip(v.iter()) {
            assert!((u - w).abs() < 1e-6, "{u} vs {w}");
        }
    }

    /// End-to-end: d > n gives a rank-deficient sketched system; the fit
    /// must survive (via jitter bumps when the zero pivots surface as
    /// non-positive) and produce finite predictions.
    #[test]
    fn rank_deficient_fit_survives() {
        let (x, y, kern, lam) = toy_problem(10, 126);
        let mut rng = Pcg64::seed(127);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 1 }).build(10, 40, &mut rng);
        let skrr = SketchedKrr::fit(kern, &x, &y, &s, lam, None).expect("fit should survive");
        assert!(skrr.fitted().iter().all(|v| v.is_finite()));
        assert!(skrr.predict(&x).iter().all(|v| v.is_finite()));
    }
}
