//! Kernel ridge regression estimators.
//!
//! * [`KrrModel`] — the exact estimator `f̂(x) = k(x,X)(K + nλI)⁻¹Y`
//!   (paper eq. 2), `O(n³)`.
//! * [`SketchedKrr`] — the sketched estimator
//!   `f̂_S(x) = k(x,X) S (SᵀK²S + nλ SᵀKS)⁻¹ SᵀKY` (paper eq. 3), `O(nd²)`
//!   once the sketch Grams are formed. [`SketchedKrr::fit_adaptive`] grows
//!   the accumulation sketch at runtime (incremental Grams + rank-updated
//!   Cholesky) until a [`StoppingRule`](crate::stats::StoppingRule) picks
//!   the data-dependent `m`.
//! * [`falkon`] — the Falkon baseline (Rudi et al. 2017): preconditioned
//!   conjugate gradients with early stopping, generalised to take any
//!   sketch from this crate (paper §3.3 discusses exactly this pairing).
//! * [`sketched_ols`] — sketched ridge/least-squares on the *raw features*
//!   (no kernel, the setting of arXiv:2204.04776), reusing the same
//!   accumulation + sampling machinery on `SᵀX`.

mod cv;
mod exact;
mod falkon;
mod kkmeans;
mod kpca;
mod ols;
mod sketched;

pub use cv::{cv_select, CvResult};
pub use exact::KrrModel;
pub use falkon::{falkon, FalkonOptions, FalkonResult};
pub use kkmeans::{kernel_kmeans, lloyd, KernelKmeans};
pub(crate) use kpca::kpca_from_gram;
pub use kpca::{sketched_kpca, SketchedKpca};
pub use ols::{feature_leverage, ridge_exact, sketched_ols, OlsReport, SketchedOls};
pub use sketched::{AdaptiveOptions, AdaptiveRound, SketchedKrr, SketchedKrrReport};
