//! Sketched kernel PCA — the paper's §5 future-work direction ("how the
//! approximation error translates when the new sketching method is
//! utilized to approximate some classical machine learning models, such as
//! k-means and PCA"), implemented as an extension.
//!
//! Nyström-style KPCA generalised to any sketch: the top-r eigenpairs of
//! the sketched operator `K_S = KS (SᵀKS)⁻¹ SᵀK` are recovered from the
//! d×d pencil. With `C = KS` and `W = SᵀKS = LLᵀ`, the non-zero spectrum
//! of `C W⁻¹ Cᵀ / n` equals that of `(L⁻¹ Cᵀ C L⁻ᵀ)/n`, a d×d symmetric
//! eigenproblem; eigenvectors lift back as `V = C L⁻ᵀ Q Λ^{-1/2}/√n`.

use crate::data::TileSource;
use crate::kernels::Kernel;
use crate::linalg::{chol_factor, matmul, partial_eigh, Matrix};
use crate::sketch::{sketch_gram, Sketch, SketchOps, SketchedGram};

/// Result of sketched kernel PCA.
#[derive(Clone, Debug)]
pub struct SketchedKpca {
    /// Top eigenvalues of `K_S/n`, descending.
    pub eigenvalues: Vec<f64>,
    /// Matching orthonormal component scores (n×r): column j is the j-th
    /// kernel principal direction evaluated at the training points.
    pub components: Matrix,
}

/// Compute the top-`r` sketched kernel principal components. The Grams
/// stream through the row-tiled Gram operator (`sketch_gram` with no
/// shared K), so the `n×n` kernel matrix is never materialised; the
/// spectral work happens on the `d×d` pencil. `x` is any
/// [`TileSource`] — an in-memory matrix, or one of the out-of-core
/// file backends (DESIGN.md §12) when `X` itself should not be
/// resident either.
pub fn sketched_kpca(
    kernel: &Kernel,
    x: &dyn TileSource,
    sketch: &Sketch,
    r: usize,
) -> Option<SketchedKpca> {
    let gram = sketch_gram(kernel, x, sketch, None);
    kpca_from_gram(&gram, sketch.d(), x.rows(), r)
}

/// The d×d pencil + lift, from already-formed sketched Grams (separated
/// so tests can pin the streamed and dense-K gram routes to the same
/// spectrum). Crate-visible because the pencil is operator-agnostic: the
/// spectral-clustering path (`cluster::spectral`) feeds it Grams formed
/// over the normalized affinity `N = D^{-1/2} K D^{-1/2}` instead of `K`
/// and gets the sketched *Laplacian* embedding from the identical
/// `L⁻¹(SᵀA²S)L⁻ᵀ` factorisation.
pub(crate) fn kpca_from_gram(
    gram: &SketchedGram,
    d: usize,
    n: usize,
    r: usize,
) -> Option<SketchedKpca> {
    let r = r.min(d);
    // W = SᵀKS = LLᵀ (jitter if columns collided)
    let mut w = gram.stks.clone();
    let scale = (0..d).map(|i| w[(i, i)]).fold(0.0f64, f64::max).max(1e-300);
    let l = loop {
        match chol_factor(&w) {
            Some(f) => break f,
            None => {
                w.add_diag(scale * 1e-10);
                if w[(0, 0)] > scale * 2.0 {
                    return None;
                }
            }
        }
    };
    // M = L⁻¹ (CᵀC) L⁻ᵀ / n  (d×d, symmetric PSD); CᵀC = SᵀK²S is
    // already formed in the gram
    // solve L Z = CᵀC, then L Y = Zᵀ → Y = L⁻¹ (CᵀC) L⁻ᵀ
    let z = forward_sub_mat(l.l(), &gram.stk2s);
    let y = forward_sub_mat(l.l(), &z.transpose());
    let mut m = y;
    m.scale(1.0 / n as f64);
    m.symmetrize();
    // only the top-r pairs of the d×d pencil are consumed: the partial
    // eigensolver takes over for large d (it falls back to the full dense
    // solver below its small-n cutoff — see DESIGN.md §4.2)
    let pe = partial_eigh(&m, r);
    let (vals, q) = (pe.w, pe.v);
    // lift: V = C L⁻ᵀ Q Λ^{-1/2} / √n
    let linv_t_q = back_sub_t_mat(l.l(), &q); // L⁻ᵀ Q
    let mut v = matmul(&gram.ks, &linv_t_q);
    for j in 0..r {
        let lam = vals[j].max(0.0);
        let denom = (lam * n as f64).sqrt();
        let scale = if denom > 1e-12 { 1.0 / denom } else { 0.0 };
        for i in 0..n {
            v[(i, j)] *= scale;
        }
    }
    Some(SketchedKpca {
        eigenvalues: vals[..r].to_vec(),
        components: v,
    })
}

/// Solve `L X = B` column-wise for lower-triangular L.
fn forward_sub_mat(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    let mut x = b.clone();
    for col in 0..b.cols() {
        for i in 0..n {
            let mut s = x[(i, col)];
            for p in 0..i {
                s -= l[(i, p)] * x[(p, col)];
            }
            x[(i, col)] = s / l[(i, i)];
        }
    }
    x
}

/// Solve `Lᵀ X = B` column-wise.
fn back_sub_t_mat(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    let mut x = b.clone();
    for col in 0..b.cols() {
        for i in (0..n).rev() {
            let mut s = x[(i, col)];
            for p in (i + 1)..n {
                s -= l[(p, i)] * x[(p, col)];
            }
            x[(i, col)] = s / l[(i, i)];
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::kernel_matrix;
    use crate::rng::Pcg64;
    use crate::sketch::{SketchBuilder, SketchKind};
    use crate::stats::SpectralView;

    #[test]
    fn full_sketch_recovers_exact_spectrum() {
        let mut rng = Pcg64::seed(0xca);
        let n = 30;
        let x = Matrix::from_fn(n, 2, |_, _| rng.uniform());
        let kern = Kernel::gaussian(0.7);
        // identity sketch (d = n): K_S = K exactly
        let s = Sketch::Dense(Matrix::eye(n));
        let kpca = sketched_kpca(&kern, &x, &s, 5).unwrap();
        let k = kernel_matrix(&kern, &x);
        let view = SpectralView::new(&k);
        for j in 0..5 {
            assert!(
                (kpca.eigenvalues[j] - view.sigma[j]).abs() < 1e-6 * (1.0 + view.sigma[j]),
                "eig {j}: {} vs {}",
                kpca.eigenvalues[j],
                view.sigma[j]
            );
        }
    }

    /// Same exactness contract as `full_sketch_recovers_exact_spectrum`,
    /// but at a pencil size (d = n = 120 > the dense-fallback cutoff)
    /// where the partial eigensolver actually engages.
    #[test]
    fn partial_pencil_matches_exact_spectrum_large_d() {
        let mut rng = Pcg64::seed(0xce);
        let n = 120;
        let x = Matrix::from_fn(n, 2, |_, _| rng.uniform());
        let kern = Kernel::gaussian(0.7);
        let s = Sketch::Dense(Matrix::eye(n));
        let kpca = sketched_kpca(&kern, &x, &s, 5).unwrap();
        let k = kernel_matrix(&kern, &x);
        let view = SpectralView::new(&k);
        for j in 0..5 {
            assert!(
                (kpca.eigenvalues[j] - view.sigma[j]).abs() < 1e-6 * (1.0 + view.sigma[j]),
                "eig {j}: {} vs {}",
                kpca.eigenvalues[j],
                view.sigma[j]
            );
        }
        let g = matmul(&kpca.components.transpose(), &kpca.components);
        for i in 0..5 {
            for j in 0..5 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((g[(i, j)] - want).abs() < 1e-6, "({i},{j}) = {}", g[(i, j)]);
            }
        }
    }

    #[test]
    fn components_orthonormal() {
        let mut rng = Pcg64::seed(0xcb);
        let n = 60;
        let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
        let kern = Kernel::gaussian(1.0);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(n, 20, &mut rng);
        let kpca = sketched_kpca(&kern, &x, &s, 4).unwrap();
        let g = matmul(&kpca.components.transpose(), &kpca.components);
        for i in 0..4 {
            for j in 0..4 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (g[(i, j)] - want).abs() < 1e-6,
                    "({i},{j}) = {}",
                    g[(i, j)]
                );
            }
        }
    }

    /// The streamed-gram pencil and the dense-K-gram pencil resolve the
    /// same spectrum and (up to sign) the same components — the operator
    /// route changes memory, not results.
    #[test]
    fn streamed_pencil_matches_dense_k_pencil() {
        let mut rng = Pcg64::seed(0xcf);
        let n = 70;
        let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
        let kern = Kernel::gaussian(1.0);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(n, 16, &mut rng);
        let streamed = sketch_gram(&kern, &x, &s, None);
        let k = kernel_matrix(&kern, &x);
        let dense = sketch_gram(&kern, &x, &s, Some(&k));
        let r = 4;
        let a = kpca_from_gram(&streamed, 16, n, r).unwrap();
        let b = kpca_from_gram(&dense, 16, n, r).unwrap();
        for j in 0..r {
            assert!(
                (a.eigenvalues[j] - b.eigenvalues[j]).abs()
                    < 1e-9 * (1.0 + b.eigenvalues[j].abs()),
                "pencil eig {j}: {} vs {}",
                a.eigenvalues[j],
                b.eigenvalues[j]
            );
            let mut dot = 0.0;
            for i in 0..n {
                dot += a.components[(i, j)] * b.components[(i, j)];
            }
            assert!(dot.abs() > 1.0 - 1e-7, "component {j}: |cos| = {}", dot.abs());
        }
    }

    #[test]
    fn accumulation_beats_nystrom_on_incoherent_top_eigenvalue() {
        // incoherent two-cluster data: uniform Nyström often misses the
        // minority eigendirection entirely; m=8 accumulation keeps it.
        let mut rng = Pcg64::seed(0xcc);
        let n = 160;
        let x = Matrix::from_fn(n, 2, |i, _| {
            if i < n - 3 {
                2.0 * rng.uniform()
            } else {
                30.0 + 0.02 * rng.uniform()
            }
        });
        let kern = Kernel::gaussian(1.0);
        let k = kernel_matrix(&kern, &x);
        let view = SpectralView::new(&k);
        let top5: f64 = view.sigma[..5].iter().sum();
        let recovered = |kind: SketchKind| -> f64 {
            let mut rng = Pcg64::seed(0xcd);
            let reps = 12;
            (0..reps)
                .map(|_| {
                    let s = SketchBuilder::new(kind.clone()).build(n, 16, &mut rng);
                    sketched_kpca(&kern, &x, &s, 5)
                        .map(|r| r.eigenvalues.iter().sum::<f64>())
                        .unwrap_or(0.0)
                })
                .sum::<f64>()
                / reps as f64
        };
        let nys = recovered(SketchKind::Nystrom);
        let acc = recovered(SketchKind::Accumulation { m: 8 });
        assert!(
            acc > nys,
            "accumulation should capture more top spectrum: {acc} vs {nys} (exact {top5})"
        );
    }
}
