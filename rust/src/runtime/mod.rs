//! PJRT runtime: load AOT-compiled HLO artifacts (built once by
//! `python -m compile.aot`) and execute them from the Rust hot path.
//! Python never runs at request time.
//!
//! * [`Engine`] wraps `xla::PjRtClient` (CPU) and compiles HLO **text**
//!   artifacts (`artifacts/*.hlo.txt`). Text, not serialized protos: jax ≥
//!   0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//!   the text parser reassigns ids.
//! * [`Manifest`] / [`ArtifactSpec`] mirror `artifacts/manifest.json`.
//! * [`ModelRuntime`] is the typed facade: pad a request to the nearest
//!   shape bucket, convert `f64 → f32`, execute, unpad.

mod client;
mod manifest;
mod model_runtime;

pub use client::{
    literal_f32, literal_i32, literal_scalar, literal_to_f64, Engine, LoadedArtifact,
};
pub use manifest::{ArtifactSpec, Manifest};
pub use model_runtime::{FitOutput, ModelRuntime};
