//! PJRT runtime: load AOT-compiled HLO artifacts (built once by
//! `python -m compile.aot`) and execute them from the Rust hot path.
//! Python never runs at request time.
//!
//! The execution half is gated behind the `xla` cargo feature so the
//! default build resolves and compiles fully offline (the feature's
//! dependency is the in-tree type stub under `third_party/xla-stub`;
//! swap it for the real bindings to run artifacts):
//!
//! * `Engine` (feature `xla`) wraps `xla::PjRtClient` (CPU) and compiles
//!   HLO **text** artifacts (`artifacts/*.hlo.txt`). Text, not serialized
//!   protos: jax ≥ 0.5 emits 64-bit instruction ids that xla_extension
//!   0.5.1 rejects; the text parser reassigns ids.
//! * [`Manifest`] / [`ArtifactSpec`] mirror `artifacts/manifest.json` and
//!   are always available (pure JSON, no runtime dependency), as is
//!   [`HostStamp`] — the shared arch/CPU-feature provenance record that
//!   bench output and `accumkrr info` both embed.
//! * `ModelRuntime` (feature `xla`) is the typed facade: pad a request to
//!   the nearest shape bucket, convert `f64 → f32`, execute, unpad.

mod manifest;

#[cfg(feature = "xla")]
mod client;
#[cfg(feature = "xla")]
mod model_runtime;

pub use manifest::{ArtifactSpec, HostStamp, Manifest};

#[cfg(feature = "xla")]
pub use client::{
    literal_f32, literal_i32, literal_scalar, literal_to_f64, Engine, LoadedArtifact,
};
#[cfg(feature = "xla")]
pub use model_runtime::{FitOutput, ModelRuntime};

/// Runtime-layer error. A plain string wrapper: the runtime layer used to
/// lean on `anyhow`, but keeping the crate dependency-free (offline
/// builds, no registry) is worth more than error-chain ergonomics here.
#[derive(Debug)]
pub struct RuntimeError(String);

impl RuntimeError {
    /// Wrap a message.
    pub fn new(msg: impl Into<String>) -> RuntimeError {
        RuntimeError(msg.into())
    }
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

#[cfg(feature = "xla")]
impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> RuntimeError {
        RuntimeError(format!("xla: {e}"))
    }
}

/// Result alias used across the runtime layer.
pub type Result<T> = std::result::Result<T, RuntimeError>;
