//! Artifact manifest (`artifacts/manifest.json`) written by
//! `python -m compile.aot`, plus [`HostStamp`] — the one shared
//! formatter for "which machine/kernel produced this artifact".

use crate::util::json::Json;

/// Provenance stamp for persisted artifacts (`BENCH_hotpath.json`,
/// `accumkrr info`, saved models): compile-target arch, the micro-kernel
/// dispatch selected at runtime, and the CPU features that selection was
/// based on. One implementation so every artifact formats the same
/// fields the same way, instead of each writer rolling its own arch
/// string.
#[derive(Clone, Debug, PartialEq)]
pub struct HostStamp {
    /// Compile-target architecture (`x86_64`, `aarch64`, …).
    pub arch: String,
    /// Micro-kernel dispatch in effect (`scalar` / `avx2` / `neon`).
    pub kernel: String,
    /// CPU feature set the dispatch layer detected (e.g. `avx2+fma`).
    pub cpu_features: String,
}

impl HostStamp {
    /// Probe the current host/dispatch state.
    pub fn detect() -> HostStamp {
        HostStamp {
            arch: std::env::consts::ARCH.to_string(),
            kernel: crate::linalg::kernel_name().to_string(),
            cpu_features: crate::linalg::detected_features(),
        }
    }

    /// JSON object with `arch` / `kernel` / `cpu_features` fields.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arch", Json::Str(self.arch.clone())),
            ("kernel", Json::Str(self.kernel.clone())),
            ("cpu_features", Json::Str(self.cpu_features.clone())),
        ])
    }
}

impl std::fmt::Display for HostStamp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{} ({})", self.arch, self.kernel, self.cpu_features)
    }
}

/// One artifact's metadata: entry point + static shape bucket.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    /// Manifest name (also the file stem).
    pub name: String,
    /// HLO file name relative to the artifact dir.
    pub file: String,
    /// Entry point: `fit_sketched`, `predict_sketched`, `fit_exact`.
    pub entry: String,
    /// Kernel family baked into the artifact (`gaussian`, `matern32`, …).
    pub kernel: String,
    /// Training rows (fit buckets).
    pub n: usize,
    /// Feature dimension.
    pub p: usize,
    /// Projection dimension (sketched buckets).
    pub d: usize,
    /// Accumulation parameter (sketched buckets).
    pub m: usize,
    /// Query batch (predict buckets).
    pub b: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// All artifact specs.
    pub artifacts: Vec<ArtifactSpec>,
    /// Directory the files live in.
    pub dir: String,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &str) -> Result<Manifest, String> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| format!("{path}: {e}"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON.
    pub fn parse(text: &str, dir: &str) -> Result<Manifest, String> {
        let j = Json::parse(text)?;
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or("manifest: missing artifacts array")?;
        let field = |o: &Json, k: &str| -> usize {
            o.get(k).and_then(|v| v.as_usize()).unwrap_or(0)
        };
        let sfield = |o: &Json, k: &str| -> Result<String, String> {
            o.get(k)
                .and_then(|v| v.as_str())
                .map(|s| s.to_string())
                .ok_or_else(|| format!("manifest: artifact missing {k}"))
        };
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            artifacts.push(ArtifactSpec {
                name: sfield(a, "name")?,
                file: sfield(a, "file")?,
                entry: sfield(a, "entry")?,
                kernel: sfield(a, "kernel")?,
                n: field(a, "n"),
                p: field(a, "p"),
                d: field(a, "d"),
                m: field(a, "m"),
                b: field(a, "b"),
            });
        }
        Ok(Manifest {
            artifacts,
            dir: dir.to_string(),
        })
    }

    /// Smallest fit bucket that fits `(kernel, n, p, d, m)` (padding up).
    pub fn find_fit(&self, kernel: &str, n: usize, p: usize, d: usize, m: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.entry == "fit_sketched"
                    && a.kernel == kernel
                    && a.n >= n
                    && a.p == p
                    && a.d >= d
                    && a.m >= m
            })
            .min_by_key(|a| (a.n, a.d, a.m))
    }

    /// Smallest predict bucket that fits `(kernel, batch, p, d, m)`.
    pub fn find_predict(&self, kernel: &str, b: usize, p: usize, d: usize, m: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.entry == "predict_sketched"
                    && a.kernel == kernel
                    && a.b >= b
                    && a.p == p
                    && a.d >= d
                    && a.m >= m
            })
            .min_by_key(|a| (a.b, a.d, a.m))
    }

    /// Full path of an artifact file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> String {
        format!("{}/{}", self.dir, spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"version":1,"artifacts":[
      {"name":"fit_gaussian_n512_p3_d32_m4","file":"f1.hlo.txt","entry":"fit_sketched","kernel":"gaussian","n":512,"p":3,"d":32,"m":4},
      {"name":"fit_gaussian_n1024_p3_d48_m4","file":"f2.hlo.txt","entry":"fit_sketched","kernel":"gaussian","n":1024,"p":3,"d":48,"m":4},
      {"name":"predict_gaussian_b64_p3_d32_m4","file":"p1.hlo.txt","entry":"predict_sketched","kernel":"gaussian","b":64,"p":3,"d":32,"m":4}
    ]}"#;

    #[test]
    fn parses_specs() {
        let m = Manifest::parse(SAMPLE, "arts").unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.artifacts[0].n, 512);
        assert_eq!(m.path_of(&m.artifacts[0]), "arts/f1.hlo.txt");
    }

    #[test]
    fn bucket_selection_prefers_smallest_fit() {
        let m = Manifest::parse(SAMPLE, ".").unwrap();
        let b = m.find_fit("gaussian", 300, 3, 20, 4).unwrap();
        assert_eq!(b.n, 512);
        let b2 = m.find_fit("gaussian", 600, 3, 20, 4).unwrap();
        assert_eq!(b2.n, 1024);
        assert!(m.find_fit("gaussian", 2000, 3, 20, 4).is_none());
        assert!(m.find_fit("matern32", 300, 3, 20, 4).is_none());
    }

    #[test]
    fn predict_bucket() {
        let m = Manifest::parse(SAMPLE, ".").unwrap();
        assert!(m.find_predict("gaussian", 64, 3, 32, 4).is_some());
        assert!(m.find_predict("gaussian", 65, 3, 32, 4).is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}", ".").is_err());
        assert!(Manifest::parse("{\"artifacts\":[{\"name\":\"x\"}]}", ".").is_err());
    }

    /// The stamp records the compile-target arch and a kernel name the
    /// dispatch layer actually owns, and serialises all three fields.
    #[test]
    fn host_stamp_reflects_dispatch() {
        let stamp = HostStamp::detect();
        assert_eq!(stamp.arch, std::env::consts::ARCH);
        assert!(["scalar", "avx2", "neon"].contains(&stamp.kernel.as_str()));
        let j = stamp.to_json();
        assert_eq!(
            j.get("kernel").and_then(|v| v.as_str()),
            Some(stamp.kernel.as_str())
        );
        assert_eq!(
            j.get("arch").and_then(|v| v.as_str()),
            Some(stamp.arch.as_str())
        );
        assert!(j.get("cpu_features").is_some());
        assert!(format!("{stamp}").contains(&stamp.kernel));
    }
}
