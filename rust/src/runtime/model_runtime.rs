//! Typed facade over the compiled artifacts: shape-bucket padding, dtype
//! conversion, execution, unpadding.
//!
//! Padding semantics (tested in `rust/tests/pjrt_roundtrip.rs`):
//!
//! * **fit**: extra data rows are placed at the *mean of the real rows*
//!   with `y = 0` — they contribute kernel mass but the sketch never
//!   samples them (all idx point at real rows), so `KS` rows for padding
//!   are computed-but-ignored; extra sketch columns get `w = 0` (their θ
//!   entries are driven to 0 by the jittered system) — padding rows appear
//!   far away so their kernel columns are ≈0. In practice we pad features
//!   at a large sentinel offset so padding is *kernel-invisible*.
//! * **predict**: extra query rows are sentinel rows whose outputs are
//!   dropped; extra (d, m) slots carry `w = 0`.

use super::client::{literal_f32, literal_i32, literal_scalar, literal_to_f64, Engine, LoadedArtifact};
use super::manifest::{ArtifactSpec, Manifest};
use super::{Result, RuntimeError};
use crate::linalg::Matrix;
use crate::sketch::SparseSketch;
use std::collections::HashMap;
use std::sync::Mutex;

/// Feature-space sentinel for padding rows: far from any normalised data,
/// so every radial kernel value against real rows underflows to ~0.
const PAD_SENTINEL: f64 = 1.0e3;

/// Output of a PJRT fit call.
#[derive(Clone, Debug)]
pub struct FitOutput {
    /// θ (d entries, unpadded).
    pub theta: Vec<f64>,
    /// In-sample fitted values (n entries, unpadded).
    pub fitted: Vec<f64>,
    /// Which artifact served the call.
    pub artifact: String,
}

/// Engine + manifest + compiled-artifact cache.
pub struct ModelRuntime {
    engine: Engine,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<LoadedArtifact>>>,
}

impl ModelRuntime {
    /// Open the artifact directory (compiles lazily, caches per artifact).
    pub fn open(dir: &str) -> Result<ModelRuntime> {
        let manifest = Manifest::load(dir).map_err(RuntimeError::new)?;
        Ok(ModelRuntime {
            engine: Engine::cpu()?,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Platform description.
    pub fn platform(&self) -> String {
        self.engine.platform()
    }

    fn compiled(&self, spec: &ArtifactSpec) -> Result<std::sync::Arc<LoadedArtifact>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(a) = cache.get(&spec.name) {
            return Ok(a.clone());
        }
        let path = self.manifest.path_of(spec);
        let loaded = std::sync::Arc::new(self.engine.load_hlo_text(&path, &spec.name)?);
        cache.insert(spec.name.clone(), loaded.clone());
        Ok(loaded)
    }

    /// Sketched KRR fit through the AOT artifact.
    ///
    /// `sketch` must be a sparse sketch whose columns each hold ≤ bucket-m
    /// entries (accumulation sketches by construction).
    pub fn fit_sketched(
        &self,
        kernel_name: &str,
        x: &Matrix,
        y: &[f64],
        sketch: &SparseSketch,
        lambda: f64,
        bandwidth: f64,
    ) -> Result<FitOutput> {
        let (n, p) = (x.rows(), x.cols());
        let d = sketch.d();
        let m_max = (0..d).map(|j| sketch.col(j).len()).max().unwrap_or(1);
        let spec = self
            .manifest
            .find_fit(kernel_name, n, p, d, m_max)
            .ok_or_else(|| {
                RuntimeError::new(format!(
                    "no fit bucket for kernel={kernel_name} n={n} p={p} d={d} m={m_max}"
                ))
            })?
            .clone();
        let exe = self.compiled(&spec)?;

        // pad features: real rows then sentinel rows
        let mut xp = vec![0.0f64; spec.n * spec.p];
        for i in 0..n {
            xp[i * spec.p..i * spec.p + p].copy_from_slice(x.row(i));
        }
        for i in n..spec.n {
            for j in 0..spec.p {
                xp[i * spec.p + j] = PAD_SENTINEL + (i as f64);
            }
        }
        let mut yp = vec![0.0f64; spec.n];
        yp[..n].copy_from_slice(y);

        // pad sketch to (spec.d, spec.m): idx 0 with w = 0 is inert
        let mut idx = vec![0i32; spec.d * spec.m];
        let mut w = vec![0.0f64; spec.d * spec.m];
        for j in 0..d {
            for (t, &(row, weight)) in sketch.col(j).iter().enumerate() {
                idx[j * spec.m + t] = row as i32;
                w[j * spec.m + t] = weight;
            }
        }

        let inputs = vec![
            literal_f32(&xp, &[spec.n as i64, spec.p as i64])?,
            literal_f32(&yp, &[spec.n as i64])?,
            literal_i32(&idx, &[spec.d as i64, spec.m as i64])?,
            literal_f32(&w, &[spec.d as i64, spec.m as i64])?,
            literal_scalar(lambda * n as f64 / spec.n as f64), // rescale nλ: artifact multiplies by bucket n
            literal_scalar(bandwidth),
        ];
        let out = exe.execute(&inputs)?;
        if out.len() != 2 {
            return Err(RuntimeError::new(format!(
                "fit artifact returned {} outputs",
                out.len()
            )));
        }
        let theta_full = literal_to_f64(&out[0])?;
        let fitted_full = literal_to_f64(&out[1])?;
        Ok(FitOutput {
            theta: theta_full[..d].to_vec(),
            fitted: fitted_full[..n].to_vec(),
            artifact: spec.name.clone(),
        })
    }

    /// Exact KRR fit through the AOT `fit_exact` artifact (small-n buckets;
    /// the approximation-error experiments' reference line).
    pub fn fit_exact(
        &self,
        kernel_name: &str,
        x: &Matrix,
        y: &[f64],
        lambda: f64,
        bandwidth: f64,
    ) -> Result<FitOutput> {
        let (n, p) = (x.rows(), x.cols());
        let spec = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.entry == "fit_exact" && a.kernel == kernel_name && a.n >= n && a.p == p)
            .min_by_key(|a| a.n)
            .ok_or_else(|| {
                RuntimeError::new(format!("no exact bucket for kernel={kernel_name} n={n} p={p}"))
            })?
            .clone();
        let exe = self.compiled(&spec)?;
        let mut xp = vec![0.0f64; spec.n * spec.p];
        for i in 0..n {
            xp[i * spec.p..i * spec.p + p].copy_from_slice(x.row(i));
        }
        for i in n..spec.n {
            for j in 0..spec.p {
                xp[i * spec.p + j] = PAD_SENTINEL + i as f64;
            }
        }
        let mut yp = vec![0.0f64; spec.n];
        yp[..n].copy_from_slice(y);
        let inputs = vec![
            literal_f32(&xp, &[spec.n as i64, spec.p as i64])?,
            literal_f32(&yp, &[spec.n as i64])?,
            literal_scalar(lambda * n as f64 / spec.n as f64),
            literal_scalar(bandwidth),
        ];
        let out = exe.execute(&inputs)?;
        let alpha = literal_to_f64(&out[0])?;
        let fitted = literal_to_f64(&out[1])?;
        Ok(FitOutput {
            theta: alpha[..n].to_vec(),
            fitted: fitted[..n].to_vec(),
            artifact: spec.name.clone(),
        })
    }

    /// Batched prediction through the AOT artifact.
    ///
    /// `support`: (d, m, p) sampled support points flattened per sketch
    /// column; `w` the matching weights; `theta` from a fit.
    pub fn predict_sketched(
        &self,
        kernel_name: &str,
        xq: &Matrix,
        support: &[Matrix], // one (m_j, p) matrix per sketch column
        w: &[Vec<f64>],
        theta: &[f64],
        bandwidth: f64,
    ) -> Result<Vec<f64>> {
        let (b, p) = (xq.rows(), xq.cols());
        let d = theta.len();
        let m_max = w.iter().map(|c| c.len()).max().unwrap_or(1);
        let spec = self
            .manifest
            .find_predict(kernel_name, b, p, d, m_max)
            .ok_or_else(|| {
                RuntimeError::new(format!(
                    "no predict bucket for kernel={kernel_name} b={b} p={p} d={d} m={m_max}"
                ))
            })?
            .clone();
        let exe = self.compiled(&spec)?;

        let mut xqp = vec![0.0f64; spec.b * spec.p];
        for i in 0..b {
            xqp[i * spec.p..i * spec.p + p].copy_from_slice(xq.row(i));
        }
        for i in b..spec.b {
            for j in 0..spec.p {
                xqp[i * spec.p + j] = PAD_SENTINEL + i as f64;
            }
        }

        // support points (spec.d, spec.m, spec.p); w = 0 slots are inert
        let mut xs = vec![PAD_SENTINEL; spec.d * spec.m * spec.p];
        let mut wp = vec![0.0f64; spec.d * spec.m];
        let mut thetap = vec![0.0f64; spec.d];
        thetap[..d].copy_from_slice(theta);
        for j in 0..d {
            for t in 0..w[j].len() {
                wp[j * spec.m + t] = w[j][t];
                let base = (j * spec.m + t) * spec.p;
                xs[base..base + p].copy_from_slice(support[j].row(t));
            }
        }

        let inputs = vec![
            literal_f32(&xqp, &[spec.b as i64, spec.p as i64])?,
            literal_f32(&xs, &[spec.d as i64, spec.m as i64, spec.p as i64])?,
            literal_f32(&wp, &[spec.d as i64, spec.m as i64])?,
            literal_f32(&thetap, &[spec.d as i64])?,
            literal_scalar(bandwidth),
        ];
        let out = exe.execute(&inputs)?;
        let yq = literal_to_f64(&out[0])?;
        Ok(yq[..b].to_vec())
    }
}
