//! Thin PJRT wrapper over the `xla` crate (in-tree stub by default; see
//! `runtime` module docs for how to point it at the real bindings).

use super::{Result, RuntimeError};

/// A PJRT CPU client plus the artifacts compiled on it.
pub struct Engine {
    client: xla::PjRtClient,
}

/// One compiled executable.
pub struct LoadedArtifact {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact name (manifest key), for diagnostics.
    pub name: String,
}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| RuntimeError::new(format!("creating PJRT CPU client: {e}")))?;
        Ok(Engine { client })
    }

    /// Platform string (diagnostics / `accumkrr info`).
    pub fn platform(&self) -> String {
        format!(
            "{} ({} devices)",
            self.client.platform_name(),
            self.client.device_count()
        )
    }

    /// Load and compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: &str, name: &str) -> Result<LoadedArtifact> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| RuntimeError::new(format!("parsing HLO text {path}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| RuntimeError::new(format!("compiling {name}: {e}")))?;
        Ok(LoadedArtifact {
            exe,
            name: name.to_string(),
        })
    }
}

impl LoadedArtifact {
    /// Execute with literal inputs; returns the flattened output tuple
    /// (aot.py lowers with `return_tuple=True`).
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| RuntimeError::new(format!("executing {}: {e}", self.name)))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| RuntimeError::new(format!("device → host transfer: {e}")))?;
        Ok(lit.to_tuple()?)
    }
}

/// Build an `f32` literal of the given shape from `f64` data (row-major).
pub fn literal_f32(data: &[f64], dims: &[i64]) -> Result<xla::Literal> {
    let f32s: Vec<f32> = data.iter().map(|&x| x as f32).collect();
    Ok(xla::Literal::vec1(&f32s).reshape(dims)?)
}

/// Build an `i32` literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Scalar f32 literal.
pub fn literal_scalar(x: f64) -> xla::Literal {
    xla::Literal::scalar(x as f32)
}

/// Extract an f32 literal into `f64`s.
pub fn literal_to_f64(lit: &xla::Literal) -> Result<Vec<f64>> {
    Ok(lit.to_vec::<f32>()?.into_iter().map(|x| x as f64).collect())
}
