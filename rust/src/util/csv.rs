//! Minimal CSV reader/writer for numeric regression datasets.
//!
//! `data::loader` uses this to ingest the real UCI files (RQA/CASP/GAS) when
//! they are dropped into `data/`; the bench harness uses the writer to dump
//! figure series for plotting.

use crate::linalg::Matrix;

/// Line-by-line numeric accumulator shared by the in-memory and
/// streaming parse entries: one flat value buffer (no per-row `Vec`s, no
/// second copy of the text), identical row/col error context either way.
struct NumericAccum {
    data: Vec<f64>,
    width: Option<usize>,
    nrows: usize,
}

impl NumericAccum {
    fn new() -> NumericAccum {
        NumericAccum { data: Vec::new(), width: None, nrows: 0 }
    }

    /// Parse one physical line (0-based `lineno` for error context).
    /// Blank lines are skipped; field and raggedness errors abort the
    /// whole parse, so no cleanup of partially pushed values is needed.
    fn push_line(&mut self, line: &str, lineno: usize) -> Result<(), String> {
        let line = line.trim();
        if line.is_empty() {
            return Ok(());
        }
        let start = self.data.len();
        for (col, f) in line.split(',').enumerate() {
            let v = f.trim().parse::<f64>().map_err(|_| {
                format!("line {} col {}: not a number: {f:?}", lineno + 1, col + 1)
            })?;
            self.data.push(v);
        }
        let w = self.data.len() - start;
        match self.width {
            Some(ww) if w != ww => {
                return Err(format!("line {}: ragged row ({w} vs {ww})", lineno + 1))
            }
            None => self.width = Some(w),
            _ => {}
        }
        self.nrows += 1;
        Ok(())
    }

    fn finish(self) -> Result<Matrix, String> {
        let w = self.width.ok_or("empty csv")?;
        Ok(Matrix::from_vec(self.nrows, w, self.data))
    }
}

/// Parse numeric CSV text into a matrix. `skip_header` drops the first
/// line; non-numeric fields are an error (with row/col context).
pub fn parse_numeric(text: &str, skip_header: bool) -> Result<Matrix, String> {
    let mut acc = NumericAccum::new();
    for (lineno, line) in text.lines().enumerate() {
        if lineno == 0 && skip_header {
            continue;
        }
        acc.push_line(line, lineno)?;
    }
    acc.finish()
}

/// Streaming variant of [`parse_numeric`]: reads one line at a time from
/// a `BufRead` into a reused buffer, so ingesting a multi-gigabyte file
/// never holds the raw text — only the parsed values — in memory. Same
/// grammar and error messages; read failures carry line context.
pub fn parse_numeric_reader<R: std::io::BufRead>(
    mut reader: R,
    skip_header: bool,
) -> Result<Matrix, String> {
    let mut acc = NumericAccum::new();
    let mut buf = String::new();
    let mut lineno = 0usize;
    loop {
        buf.clear();
        let nread = reader
            .read_line(&mut buf)
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        if nread == 0 {
            break;
        }
        if !(lineno == 0 && skip_header) {
            acc.push_line(&buf, lineno)?;
        }
        lineno += 1;
    }
    acc.finish()
}

/// Write a header + rows of f64 columns as CSV.
pub fn write_csv(path: &str, header: &[&str], rows: &[Vec<f64>]) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", header.join(","))?;
    for r in rows {
        let line: Vec<String> = r.iter().map(|x| format!("{x}")).collect();
        writeln!(f, "{}", line.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_with_header() {
        let m = parse_numeric("a,b\n1,2\n3.5,-4\n", true).unwrap();
        assert_eq!((m.rows(), m.cols()), (2, 2));
        assert_eq!(m[(1, 0)], 3.5);
        assert_eq!(m[(1, 1)], -4.0);
    }

    #[test]
    fn rejects_ragged_and_text() {
        assert!(parse_numeric("1,2\n3\n", false).is_err());
        assert!(parse_numeric("1,x\n", false).is_err());
        assert!(parse_numeric("", false).is_err());
    }

    #[test]
    fn skips_blank_lines() {
        let m = parse_numeric("1,2\n\n3,4\n", false).unwrap();
        assert_eq!(m.rows(), 2);
    }

    #[test]
    fn reader_matches_in_memory_parse_including_errors() {
        for (text, skip) in [
            ("a,b\n1,2\n3.5,-4\n", true),
            ("1,2\n\n3,4", false),
            ("1,2\n3\n", false),
            ("1,x\n", false),
            ("", false),
            ("h\n", true),
        ] {
            let mem = parse_numeric(text, skip);
            let rdr = parse_numeric_reader(text.as_bytes(), skip);
            match (mem, rdr) {
                (Ok(a), Ok(b)) => assert_eq!(a.data(), b.data(), "{text:?}"),
                (Err(a), Err(b)) => assert_eq!(a, b, "{text:?}"),
                (a, b) => panic!("divergence on {text:?}: {a:?} vs {b:?}"),
            }
        }
    }
}
