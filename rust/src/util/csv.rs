//! Minimal CSV reader/writer for numeric regression datasets.
//!
//! `data::loader` uses this to ingest the real UCI files (RQA/CASP/GAS) when
//! they are dropped into `data/`; the bench harness uses the writer to dump
//! figure series for plotting.

use crate::linalg::Matrix;

/// Parse numeric CSV text into a matrix. `skip_header` drops the first
/// line; non-numeric fields are an error (with row/col context).
pub fn parse_numeric(text: &str, skip_header: bool) -> Result<Matrix, String> {
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut width = None;
    for (lineno, line) in text.lines().enumerate() {
        if lineno == 0 && skip_header {
            continue;
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let vals: Result<Vec<f64>, String> = line
            .split(',')
            .enumerate()
            .map(|(col, f)| {
                f.trim()
                    .parse::<f64>()
                    .map_err(|_| format!("line {} col {}: not a number: {f:?}", lineno + 1, col + 1))
            })
            .collect();
        let vals = vals?;
        if let Some(w) = width {
            if vals.len() != w {
                return Err(format!("line {}: ragged row ({} vs {w})", lineno + 1, vals.len()));
            }
        } else {
            width = Some(vals.len());
        }
        rows.push(vals);
    }
    let w = width.ok_or("empty csv")?;
    let mut m = Matrix::zeros(rows.len(), w);
    for (i, r) in rows.iter().enumerate() {
        m.row_mut(i).copy_from_slice(r);
    }
    Ok(m)
}

/// Write a header + rows of f64 columns as CSV.
pub fn write_csv(path: &str, header: &[&str], rows: &[Vec<f64>]) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", header.join(","))?;
    for r in rows {
        let line: Vec<String> = r.iter().map(|x| format!("{x}")).collect();
        writeln!(f, "{}", line.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_with_header() {
        let m = parse_numeric("a,b\n1,2\n3.5,-4\n", true).unwrap();
        assert_eq!((m.rows(), m.cols()), (2, 2));
        assert_eq!(m[(1, 0)], 3.5);
        assert_eq!(m[(1, 1)], -4.0);
    }

    #[test]
    fn rejects_ragged_and_text() {
        assert!(parse_numeric("1,2\n3\n", false).is_err());
        assert!(parse_numeric("1,x\n", false).is_err());
        assert!(parse_numeric("", false).is_err());
    }

    #[test]
    fn skips_blank_lines() {
        let m = parse_numeric("1,2\n\n3,4\n", false).unwrap();
        assert_eq!(m.rows(), 2);
    }
}
