//! Leveled stderr logging with a global level set by `ACCUMKRR_LOG`
//! (`error|warn|info|debug`, default `info`).

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != u8::MAX {
        return v;
    }
    let parsed = match std::env::var("ACCUMKRR_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") => 1,
        Ok("debug") => 3,
        _ => 2,
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override the global level programmatically (tests, CLI `--quiet`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Emit a log line if `l` is enabled.
pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if (l as u8) <= level() {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {target}: {msg}");
    }
}

/// `info!`-style macros.
#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target, format_args!($($arg)*))
    };
}
/// Warning log.
#[macro_export]
macro_rules! warnlog {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target, format_args!($($arg)*))
    };
}
/// Debug log.
#[macro_export]
macro_rules! debuglog {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Debug);
        set_level(Level::Error);
        log(Level::Debug, "t", format_args!("suppressed"));
        set_level(Level::Info);
    }
}
