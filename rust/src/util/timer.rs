//! Wall-clock timing helpers used by the bench harness and the coordinator's
//! metrics.

use std::time::Instant;

/// A running wall-clock timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start now.
    pub fn start() -> Timer {
        Timer {
            start: Instant::now(),
        }
    }

    /// Seconds elapsed.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds elapsed.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    /// Restart and return the lap time in seconds.
    pub fn lap(&mut self) -> f64 {
        let t = self.secs();
        self.start = Instant::now();
        t
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.secs())
}

/// Robust summary of repeated timings (median + IQR are what the bench
/// harness reports; means are unstable on a shared 1-core box).
#[derive(Clone, Copy, Debug, Default)]
pub struct TimingStats {
    pub median: f64,
    pub p25: f64,
    pub p75: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

/// Compute [`TimingStats`] from raw samples.
pub fn timing_stats(samples: &[f64]) -> TimingStats {
    if samples.is_empty() {
        return TimingStats::default();
    }
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| -> f64 {
        let idx = p * (s.len() - 1) as f64;
        let lo = idx.floor() as usize;
        let hi = idx.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (idx - lo as f64) * (s[hi] - s[lo])
        }
    };
    TimingStats {
        median: q(0.5),
        p25: q(0.25),
        p75: q(0.75),
        min: s[0],
        max: *s.last().unwrap(),
        n: s.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.secs() >= 0.004);
    }

    #[test]
    fn timed_returns_result() {
        let (x, secs) = timed(|| 41 + 1);
        assert_eq!(x, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn stats_quartiles() {
        let s = timing_stats(&[4.0, 1.0, 3.0, 2.0, 5.0]);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p25, 2.0);
        assert_eq!(s.p75, 4.0);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn stats_empty() {
        assert_eq!(timing_stats(&[]).n, 0);
    }
}
