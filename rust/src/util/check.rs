//! Property-testing mini-framework (no `proptest` in the offline image).
//!
//! A property is a closure over a [`Gen`] that panics on violation. The
//! runner executes it across `cases` seeds; on failure it re-runs the same
//! seed with shrunk size parameters to report the smallest configuration
//! that still fails. Used by the coordinator/sketch/linalg property suites
//! (e.g. "`E[S Sᵀ]` scaling holds for every (n, d, m, distribution)").

use crate::rng::Pcg64;

/// Randomised input generator handed to properties.
pub struct Gen {
    rng: Pcg64,
    /// Current size budget; shrinking lowers it.
    pub size: usize,
}

impl Gen {
    /// Integer in `[lo, hi]` scaled into the current size budget.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        let hi = hi.min(lo + self.size);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.uniform() * (hi - lo)
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// Bool with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.uniform() < p
    }

    /// Choose uniformly from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// Vector of normals.
    pub fn normals(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.rng.normal()).collect()
    }

    /// Positive weights (bounded away from zero).
    pub fn weights(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| 0.05 + self.rng.uniform()).collect()
    }

    /// Access the raw RNG (seeding library objects under test).
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `prop` for `cases` random cases. On panic, retries the failing seed
/// at smaller sizes and reports the smallest failing size.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    for case in 0..cases {
        let seed = 0xacc0_0000 + case as u64;
        let run = |size: usize| -> Result<(), String> {
            let result = std::panic::catch_unwind(|| {
                let mut g = Gen {
                    rng: Pcg64::seed(seed),
                    size,
                };
                prop(&mut g);
            });
            result.map_err(|e| {
                e.downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<panic>".into())
            })
        };
        if let Err(full_msg) = run(64) {
            // shrink: find smallest failing size budget
            let mut smallest = (64usize, full_msg);
            let mut size = 32;
            while size >= 1 {
                match run(size) {
                    Err(m) => {
                        smallest = (size, m);
                        size /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (seed={seed:#x}, shrunk size={}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("ints in range", 20, |g| {
            let x = g.int(3, 10);
            assert!((3..=10).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        check("always fails", 1, |g| {
            let n = g.int(1, 50);
            assert!(n == usize::MAX, "n={n} is never MAX");
        });
    }

    #[test]
    fn generator_helpers_sane() {
        check("helpers", 10, |g| {
            assert!((0.0..1.0).contains(&g.f64(0.0, 1.0)));
            let w = g.weights(5);
            assert!(w.iter().all(|&x| x >= 0.05));
            let c = *g.choose(&[1, 2, 3]);
            assert!((1..=3).contains(&c));
        });
    }
}
