//! Small-infrastructure substrate: JSON, config, CLI parsing, timing,
//! logging, CSV, deterministic fault injection, a structured error
//! taxonomy, and a property-testing mini-framework. All hand-rolled —
//! the offline image ships no serde/clap/proptest.

pub mod check;
pub mod cli;
pub mod config;
pub mod csv;
pub mod error;
pub mod fault;
pub mod json;
pub mod log;
pub mod mem;
pub mod timer;

pub use error::{CodedError, ErrorKind};
pub use json::Json;
pub use timer::Timer;
