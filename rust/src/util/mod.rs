//! Small-infrastructure substrate: JSON, config, CLI parsing, timing,
//! logging, CSV, and a property-testing mini-framework. All hand-rolled —
//! the offline image ships no serde/clap/proptest.

pub mod check;
pub mod cli;
pub mod config;
pub mod csv;
pub mod json;
pub mod log;
pub mod mem;
pub mod timer;

pub use json::Json;
pub use timer::Timer;
