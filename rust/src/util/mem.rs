//! Process-memory introspection for the bench harness (no external
//! crates: reads the procfs status file directly).

/// Peak resident set size of the current process in bytes (`VmHWM` from
/// `/proc/self/status`). Linux-only; returns `None` elsewhere or when the
/// field is missing. Note the semantics: a **monotone high-water mark**
/// for the whole process — later measurements can only grow, so per-case
/// bench readings record the trajectory, not an isolated footprint (the
/// hard "streamed code never allocates n×n" guarantee is test-enforced by
/// `kernels::assembly_guard` instead).
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Test-only counting allocator: counts heap allocations made **by the
/// current thread** so hot-path tests can assert allocation budgets
/// (e.g. the batcher flush path must not allocate per row). Installed as
/// the global allocator only under `cfg(test)`, so release binaries use
/// the system allocator untouched.
#[cfg(test)]
pub mod alloc_count {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::cell::Cell;

    // const-initialised Cell: no lazy-init allocation, no Drop — safe to
    // touch from inside the allocator itself without TLS re-entry.
    thread_local! {
        static COUNT: Cell<u64> = const { Cell::new(0) };
    }

    /// Forwards to [`System`], bumping a per-thread counter on `alloc`
    /// and `realloc` (frees are not counted: the budget of interest is
    /// new allocations).
    pub struct CountingAlloc;

    // SAFETY: pure pass-through to the system allocator; the counter
    // side effect cannot affect allocation correctness.
    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            let _ = COUNT.try_with(|c| c.set(c.get() + 1));
            System.alloc(layout)
        }
        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }
        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            let _ = COUNT.try_with(|c| c.set(c.get() + 1));
            System.realloc(ptr, layout, new_size)
        }
    }

    #[global_allocator]
    static GLOBAL: CountingAlloc = CountingAlloc;

    /// Allocations made by the calling thread so far (monotone).
    pub fn on_thread() -> u64 {
        COUNT.try_with(|c| c.get()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_allocator_sees_thread_allocations() {
        let before = alloc_count::on_thread();
        let v: Vec<u64> = Vec::with_capacity(1024);
        let after = alloc_count::on_thread();
        assert!(after > before, "Vec::with_capacity must register");
        drop(v);
    }

    #[test]
    fn peak_rss_readable_on_linux() {
        // non-Linux (or sandboxed procfs): None is the documented result
        if let Some(b) = peak_rss_bytes() {
            // a live test process has touched well over a page
            assert!(b > 4096, "implausible peak RSS {b}");
        }
    }
}
