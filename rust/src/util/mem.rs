//! Process-memory introspection for the bench harness (no external
//! crates: reads the procfs status file directly).

/// Peak resident set size of the current process in bytes (`VmHWM` from
/// `/proc/self/status`). Linux-only; returns `None` elsewhere or when the
/// field is missing. Note the semantics: a **monotone high-water mark**
/// for the whole process — later measurements can only grow, so per-case
/// bench readings record the trajectory, not an isolated footprint (the
/// hard "streamed code never allocates n×n" guarantee is test-enforced by
/// `kernels::assembly_guard` instead).
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_rss_readable_on_linux() {
        // non-Linux (or sandboxed procfs): None is the documented result
        if let Some(b) = peak_rss_bytes() {
            // a live test process has touched well over a page
            assert!(b > 4096, "implausible peak RSS {b}");
        }
    }
}
