//! TOML-subset config parser for experiment and service configuration.
//!
//! Supports the subset every config in `configs/` uses: `[section]` headers,
//! `key = value` with string / integer / float / bool / homogeneous-array
//! values, `#` comments. Dotted keys and nested tables are intentionally
//! out of scope.

use std::collections::BTreeMap;

/// A parsed configuration: `section → key → value`. Keys outside any
/// section live under the empty-string section.
#[derive(Clone, Debug, Default)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

/// A configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    /// Numeric view (ints widen to float).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }
    /// Integer view.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// String view.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Bool view.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Array view.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
}

impl Config {
    /// Parse a config document.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim().to_string();
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
            cfg.sections.entry(section.clone()).or_default().insert(key, val);
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Config::parse(&text)
    }

    /// Look up `section.key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|m| m.get(key))
    }

    /// `section.key` as f64 with default.
    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    /// `section.key` as usize with default.
    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key)
            .and_then(|v| v.as_i64())
            .map(|i| i.max(0) as usize)
            .unwrap_or(default)
    }

    /// `section.key` as str with default.
    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    /// `section.key` as bool with default.
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Section names present.
    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"').and_then(|t| t.strip_suffix('"')) {
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|t| t.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Arr(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "fig2"
replicates = 30

[sweep]
n = [1000, 2000, 4000, 8000]
m = [1, 4, 16]
gamma = 0.6
use_pjrt = false
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("", "name", "?"), "fig2");
        assert_eq!(c.usize_or("", "replicates", 0), 30);
        assert_eq!(c.f64_or("sweep", "gamma", 0.0), 0.6);
        assert!(!c.bool_or("sweep", "use_pjrt", true));
        let ns = c.get("sweep", "n").unwrap().as_arr().unwrap();
        assert_eq!(ns.len(), 4);
        assert_eq!(ns[3].as_i64(), Some(8000));
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("x", "y", 7), 7);
        assert_eq!(c.f64_or("x", "y", 1.5), 1.5);
    }

    #[test]
    fn comment_inside_string_preserved() {
        let c = Config::parse("k = \"a#b\"").unwrap();
        assert_eq!(c.str_or("", "k", ""), "a#b");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("just words").is_err());
        assert!(Config::parse("k = ").is_err());
    }
}
