//! Minimal JSON value type, parser and serializer.
//!
//! Used for (a) the artifact manifest written by `python/compile/aot.py`,
//! (b) the coordinator's line-delimited TCP protocol, and (c) bench-harness
//! result dumps. Supports the full JSON grammar except `\u` surrogate pairs
//! beyond the BMP (not needed by any producer in this repo — still parsed,
//! just unpaired surrogates are replaced).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64 if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// As usize if numeric and integral.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// As str if string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As array if array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// As bool if boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Convenience constructor for objects.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Convenience constructor for numeric arrays.
    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Interpret `self` as a rectangular array-of-numeric-arrays and
    /// flatten it row-major into one buffer, returning
    /// `(flat, rows, dim)`. `None` if `self` is not an array, is empty,
    /// is ragged, has a zero-width row, or contains a non-numeric
    /// entry. This is the zero-copy-per-row ingestion path for predict
    /// payloads: one allocation for the whole batch, no intermediate
    /// `Vec<Vec<f64>>`.
    pub fn as_flat_rows(&self) -> Option<(Vec<f64>, usize, usize)> {
        let rows = self.as_arr()?;
        let first = rows.first()?.as_arr()?;
        let dim = first.len();
        if dim == 0 {
            return None;
        }
        let mut flat = Vec::with_capacity(rows.len() * dim);
        for row in rows {
            let row = row.as_arr()?;
            if row.len() != dim {
                return None;
            }
            for v in row {
                flat.push(v.as_f64()?);
            }
        }
        Some((flat, rows.len(), dim))
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "bad utf8")?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"n":1000,"d":25,"name":"fit_n1000","w":[0.5,-1,2.25],"ok":true}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn escapes_on_output() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn flat_rows_accepts_rectangular_numeric_input() {
        let j = Json::parse("[[1,2,3],[4,5,6]]").unwrap();
        let (flat, rows, dim) = j.as_flat_rows().unwrap();
        assert_eq!((rows, dim), (2, 3));
        assert_eq!(flat, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn flat_rows_rejects_bad_shapes() {
        assert!(Json::parse("[]").unwrap().as_flat_rows().is_none(), "empty");
        assert!(Json::parse("[[]]").unwrap().as_flat_rows().is_none(), "zero-dim row");
        assert!(Json::parse("[[1,2],[3]]").unwrap().as_flat_rows().is_none(), "ragged");
        assert!(Json::parse("[[1,\"x\"]]").unwrap().as_flat_rows().is_none(), "non-numeric");
        assert!(Json::parse("[1,2]").unwrap().as_flat_rows().is_none(), "not nested");
        assert!(Json::parse("3").unwrap().as_flat_rows().is_none(), "not an array");
    }
}
