//! Deterministic fault injection for chaos testing.
//!
//! A registry of *named fault points* compiled into the serving plane's
//! failure-prone seams (`io.read`, `io.write`, `frame.decode`,
//! `worker.panic`, `chol.downdate`, `batcher.flush`). Each site asks
//! [`hit`] whether its fault fires *this* time; when the registry is
//! disarmed (the default) that is a `Once` check plus one relaxed load
//! and no branch into the slow path, so hot paths pay nothing.
//!
//! Arming is deterministic and seeded, never wall-clock dependent:
//!
//! * **Env var** — `ACCUMKRR_FAULTS="io.read=every:7,chol.downdate=nth:1"`
//!   parsed once on first use. This is how CI's chaos legs arm the matrix.
//! * **Scoped override** — [`scoped`] swaps the armed set for a guard's
//!   lifetime while holding a global lock, so chaos tests serialize
//!   instead of trampling each other's triggers. [`locked`] grabs the
//!   same lock without changing the armed set, for tests that exercise
//!   whatever the environment armed.
//!
//! Trigger grammar per point: `nth:K` (fire exactly once, on the K-th
//! hit), `every:K` (fire on every K-th hit), `prob:P[:SEED]` (fire with
//! probability P, derived deterministically from the seed and the hit
//! index — no global RNG state, so a given hit sequence always fires the
//! same way).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, Once, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Every fault point compiled into the codebase. Specs naming a point
/// outside this list are rejected, so typos surface instead of silently
/// never firing.
pub const KNOWN_POINTS: &[&str] = &[
    "io.read",
    "io.write",
    "frame.decode",
    "worker.panic",
    "chol.downdate",
    "batcher.flush",
];

/// When a fault point fires, relative to its per-point hit counter.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Trigger {
    /// Fire exactly once, on the k-th hit (1-based).
    Nth(u64),
    /// Fire on every k-th hit.
    Every(u64),
    /// Fire with probability `p` per hit, drawn deterministically from
    /// the seed and the hit index.
    Prob(f64, u64),
}

impl Trigger {
    /// Does this trigger fire on (1-based) hit number `n`?
    fn fires(self, n: u64) -> bool {
        match self {
            Trigger::Nth(k) => n == k,
            Trigger::Every(k) => n % k == 0,
            Trigger::Prob(p, seed) => ((mix(seed, n) >> 11) as f64) / (1u64 << 53) as f64 < p,
        }
    }
}

struct Point {
    trigger: Trigger,
    hits: AtomicU64,
    fired: AtomicU64,
}

impl Point {
    fn new(trigger: Trigger) -> Point {
        Point {
            trigger,
            hits: AtomicU64::new(0),
            fired: AtomicU64::new(0),
        }
    }
}

static ARMED: AtomicBool = AtomicBool::new(false);
static FIRED_TOTAL: AtomicU64 = AtomicU64::new(0);
static ENV_INIT: Once = Once::new();
static POINTS: RwLock<BTreeMap<String, Point>> = RwLock::new(BTreeMap::new());
/// Serializes scoped overrides — and therefore the chaos tests that
/// arm them.
static SCOPE_LOCK: Mutex<()> = Mutex::new(());

fn read_points() -> RwLockReadGuard<'static, BTreeMap<String, Point>> {
    POINTS.read().unwrap_or_else(|e| e.into_inner())
}

fn write_points() -> RwLockWriteGuard<'static, BTreeMap<String, Point>> {
    POINTS.write().unwrap_or_else(|e| e.into_inner())
}

fn init_env() {
    ENV_INIT.call_once(|| {
        let spec = match std::env::var("ACCUMKRR_FAULTS") {
            Ok(s) if !s.trim().is_empty() => s,
            _ => return,
        };
        match parse_spec(&spec) {
            Ok(parsed) => {
                let mut pts = write_points();
                for (name, trigger) in parsed {
                    pts.insert(name, Point::new(trigger));
                }
                if !pts.is_empty() {
                    ARMED.store(true, Ordering::SeqCst);
                }
            }
            Err(e) => eprintln!("ACCUMKRR_FAULTS ignored: {e}"),
        }
    });
}

/// Parse a comma-separated fault spec: `point=mode:arg[:seed]` entries.
pub fn parse_spec(spec: &str) -> Result<Vec<(String, Trigger)>, String> {
    let mut out = Vec::new();
    for entry in spec.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (name, rule) = entry
            .split_once('=')
            .ok_or_else(|| format!("missing '=' in fault entry {entry:?}"))?;
        let name = name.trim();
        if !KNOWN_POINTS.contains(&name) {
            return Err(format!("unknown fault point {name:?}"));
        }
        let mut parts = rule.trim().split(':');
        let mode = parts.next().unwrap_or("");
        let trigger = match mode {
            "nth" | "every" => {
                let k: u64 = parts
                    .next()
                    .ok_or_else(|| format!("{mode} needs a count in {entry:?}"))?
                    .parse()
                    .map_err(|_| format!("bad count in {entry:?}"))?;
                if k == 0 {
                    return Err(format!("count must be >= 1 in {entry:?}"));
                }
                if mode == "nth" {
                    Trigger::Nth(k)
                } else {
                    Trigger::Every(k)
                }
            }
            "prob" => {
                let p: f64 = parts
                    .next()
                    .ok_or_else(|| format!("prob needs a probability in {entry:?}"))?
                    .parse()
                    .map_err(|_| format!("bad probability in {entry:?}"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("probability out of [0,1] in {entry:?}"));
                }
                let seed = match parts.next() {
                    Some(s) => s.parse().map_err(|_| format!("bad seed in {entry:?}"))?,
                    None => 0x5eed,
                };
                Trigger::Prob(p, seed)
            }
            other => return Err(format!("unknown trigger mode {other:?} in {entry:?}")),
        };
        if parts.next().is_some() {
            return Err(format!("trailing fields in {entry:?}"));
        }
        out.push((name.to_string(), trigger));
    }
    Ok(out)
}

/// splitmix64-style finalizer: decorrelates (seed, hit-index) pairs for
/// the `prob` trigger without any shared RNG state.
fn mix(seed: u64, n: u64) -> u64 {
    let mut z = seed ^ n.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Should the named fault point fire on this hit? Near-free when the
/// registry is disarmed.
#[inline]
pub fn hit(name: &str) -> bool {
    init_env();
    if !ARMED.load(Ordering::Relaxed) {
        return false;
    }
    hit_armed(name)
}

#[cold]
fn hit_armed(name: &str) -> bool {
    let pts = read_points();
    let Some(p) = pts.get(name) else { return false };
    let n = p.hits.fetch_add(1, Ordering::Relaxed) + 1;
    let fire = p.trigger.fires(n);
    if fire {
        p.fired.fetch_add(1, Ordering::Relaxed);
        FIRED_TOTAL.fetch_add(1, Ordering::Relaxed);
    }
    fire
}

/// Times `name` has fired under the *current* registry (scoped overrides
/// start from zero; the global total does not reset).
pub fn fired(name: &str) -> u64 {
    read_points().get(name).map_or(0, |p| p.fired.load(Ordering::Relaxed))
}

/// Times `name` has been evaluated under the current registry.
pub fn hits(name: &str) -> u64 {
    read_points().get(name).map_or(0, |p| p.hits.load(Ordering::Relaxed))
}

/// Total fires across all points since process start — monotone even
/// across scoped overrides; feeds the `faults_injected` serving metric.
pub fn fired_total() -> u64 {
    FIRED_TOTAL.load(Ordering::Relaxed)
}

/// Arm `spec` for the guard's lifetime, restoring the previous registry
/// (typically the env-armed one, or nothing) on drop. Holds the global
/// scope lock so concurrent chaos tests serialize; an empty spec disarms
/// every point within the scope.
///
/// # Panics
/// On a malformed spec — scoped arming is test-side, so fail loudly.
pub fn scoped(spec: &str) -> FaultGuard {
    let lock = SCOPE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    init_env();
    let mut fresh = BTreeMap::new();
    for (name, trigger) in parse_spec(spec).expect("bad fault spec") {
        fresh.insert(name, Point::new(trigger));
    }
    let armed = !fresh.is_empty();
    let saved = std::mem::replace(&mut *write_points(), fresh);
    let saved_armed = ARMED.swap(armed, Ordering::SeqCst);
    FaultGuard {
        saved: Some(saved),
        saved_armed,
        _lock: lock,
    }
}

/// Hold the chaos-test scope lock *without* changing the armed set — for
/// tests that exercise whatever the environment armed (the CI fault
/// matrix legs).
pub fn locked() -> FaultGuard {
    let lock = SCOPE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    init_env();
    FaultGuard {
        saved: None,
        saved_armed: ARMED.load(Ordering::SeqCst),
        _lock: lock,
    }
}

/// RAII restore for [`scoped`] / [`locked`].
pub struct FaultGuard {
    saved: Option<BTreeMap<String, Point>>,
    saved_armed: bool,
    _lock: MutexGuard<'static, ()>,
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        ARMED.store(false, Ordering::SeqCst);
        if let Some(saved) = self.saved.take() {
            *write_points() = saved;
        }
        ARMED.store(self.saved_armed, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Trigger evaluation and spec parsing are tested purely here; the
    // global registry (arming, counters, scoping) is exercised by
    // tests/chaos.rs, which owns the scope lock in its own process so
    // unit tests elsewhere in this binary never see injected faults.

    #[test]
    fn parse_accepts_all_modes() {
        let spec = "io.read=every:7, chol.downdate=nth:1,worker.panic=prob:0.25:99";
        let parsed = parse_spec(spec).unwrap();
        assert_eq!(
            parsed,
            vec![
                ("io.read".to_string(), Trigger::Every(7)),
                ("chol.downdate".to_string(), Trigger::Nth(1)),
                ("worker.panic".to_string(), Trigger::Prob(0.25, 99)),
            ]
        );
        assert_eq!(parse_spec("").unwrap(), vec![]);
    }

    #[test]
    fn parse_rejects_malformed_entries() {
        for bad in [
            "io.read",               // no '='
            "nope.nope=nth:1",       // unknown point
            "io.read=nth:0",         // zero count
            "io.read=nth:x",         // non-numeric
            "io.read=prob:1.5",      // p out of range
            "io.read=sometimes:3",   // unknown mode
            "io.read=every:3:4:5",   // trailing fields
        ] {
            assert!(parse_spec(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn trigger_semantics() {
        let fires = |t: Trigger| (1..=12u64).filter(|&n| t.fires(n)).collect::<Vec<_>>();
        assert_eq!(fires(Trigger::Nth(3)), vec![3]);
        assert_eq!(fires(Trigger::Every(4)), vec![4, 8, 12]);
        // prob is deterministic in (seed, n) and roughly calibrated
        let a = fires(Trigger::Prob(0.5, 7));
        let b = fires(Trigger::Prob(0.5, 7));
        assert_eq!(a, b);
        let n_fired = (1..=10_000u64).filter(|&n| Trigger::Prob(0.3, 11).fires(n)).count();
        assert!((2_500..3_500).contains(&n_fired), "p=0.3 fired {n_fired}/10000");
        assert_eq!(fires(Trigger::Prob(0.0, 1)), vec![]);
        assert_eq!(fires(Trigger::Prob(1.0, 1)).len(), 12);
    }

    #[test]
    fn known_points_cover_the_documented_seams() {
        let want = [
            "io.read",
            "io.write",
            "frame.decode",
            "worker.panic",
            "chol.downdate",
            "batcher.flush",
        ];
        for p in want {
            assert!(KNOWN_POINTS.contains(&p));
        }
    }
}
