//! Tiny CLI argument parser (no `clap` offline): positional subcommand plus
//! `--flag value` / `--flag` options.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional arguments in order (after the binary name).
    pub positional: Vec<String>,
    /// `--key value` or bare `--key` (value `""`).
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw args (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // value form: next token unless it is another flag
                    let takes_value = it.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                    let v = if takes_value {
                        it.next().unwrap()
                    } else {
                        String::new()
                    };
                    out.flags.insert(name.to_string(), v);
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process args.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Flag as string.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flags.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    /// Flag as usize.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Flag as f64.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.flags.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Flag present?
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["bench", "fig2", "--n", "4000", "--verbose", "--out=res.json"]);
        assert_eq!(a.positional, vec!["bench", "fig2"]);
        assert_eq!(a.usize_or("n", 0), 4000);
        assert!(a.has("verbose"));
        assert_eq!(a.str_or("out", ""), "res.json");
    }

    #[test]
    fn flag_before_flag_has_empty_value() {
        let a = parse(&["--a", "--b", "1"]);
        assert_eq!(a.str_or("a", "x"), "");
        assert_eq!(a.usize_or("b", 0), 1);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.f64_or("lam", 0.25), 0.25);
        assert!(a.positional.is_empty());
    }
}
