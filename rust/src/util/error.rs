//! Structured error taxonomy for the serving plane.
//!
//! Every failure that crosses the serving boundary is classified into a
//! small, stable set of [`ErrorKind`]s and surfaced to framed-protocol
//! clients as an `err_code` field in the reply envelope. The codes are
//! the contract: human-readable `error` messages may be reworded, but a
//! client routing on `err_code` ("retry on `overloaded`, give up on
//! `invalid_input`") never breaks. Legacy newline-JSON replies predate
//! the taxonomy and stay byte-identical — the reactor strips `err_code`
//! before legacy encoding.

use std::fmt;

/// Stable failure classes, ordered roughly by who is at fault: the
/// request, the load, the clock, the model, the math, or us.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ErrorKind {
    /// The request itself is unusable: malformed JSON, non-finite
    /// features, dimension mismatch, unknown fields or bounds.
    InvalidInput,
    /// The plane shed the request under backpressure; safe to retry.
    Overloaded,
    /// The request's `deadline_ms` expired before an answer was
    /// produced; no compute was spent past the deadline.
    DeadlineExceeded,
    /// The target model is quarantined after a worker panic; retrain to
    /// heal it.
    ModelUnhealthy,
    /// A numeric routine failed beyond its recovery ladder (e.g. a
    /// Cholesky factorization that jitter could not rescue).
    NumericFailure,
    /// Everything else: handler panics, injected faults, bugs.
    Internal,
}

impl ErrorKind {
    /// The wire code — the stable string clients switch on.
    pub fn code(self) -> &'static str {
        match self {
            ErrorKind::InvalidInput => "invalid_input",
            ErrorKind::Overloaded => "overloaded",
            ErrorKind::DeadlineExceeded => "deadline_exceeded",
            ErrorKind::ModelUnhealthy => "model_unhealthy",
            ErrorKind::NumericFailure => "numeric_failure",
            ErrorKind::Internal => "internal",
        }
    }

    /// Inverse of [`ErrorKind::code`]; `None` for unknown strings.
    pub fn from_code(code: &str) -> Option<ErrorKind> {
        ALL.iter().copied().find(|k| k.code() == code)
    }
}

/// Every kind, in taxonomy order — handy for exhaustive metrics tables.
pub const ALL: &[ErrorKind] = &[
    ErrorKind::InvalidInput,
    ErrorKind::Overloaded,
    ErrorKind::DeadlineExceeded,
    ErrorKind::ModelUnhealthy,
    ErrorKind::NumericFailure,
    ErrorKind::Internal,
];

/// A classified error: a stable kind plus a human-readable message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CodedError {
    /// Which failure class this is — drives `err_code` on the wire.
    pub kind: ErrorKind,
    /// Human-readable detail; not part of the stable contract.
    pub msg: String,
}

impl CodedError {
    /// New error of the given kind.
    pub fn new(kind: ErrorKind, msg: impl Into<String>) -> CodedError {
        CodedError { kind, msg: msg.into() }
    }

    /// Shorthand for [`ErrorKind::InvalidInput`].
    pub fn invalid_input(msg: impl Into<String>) -> CodedError {
        CodedError::new(ErrorKind::InvalidInput, msg)
    }

    /// Shorthand for [`ErrorKind::Overloaded`].
    pub fn overloaded() -> CodedError {
        CodedError::new(ErrorKind::Overloaded, "overloaded")
    }

    /// Shorthand for [`ErrorKind::DeadlineExceeded`].
    pub fn deadline_exceeded() -> CodedError {
        CodedError::new(ErrorKind::DeadlineExceeded, "deadline exceeded")
    }

    /// Shorthand for [`ErrorKind::ModelUnhealthy`].
    pub fn model_unhealthy(model: &str) -> CodedError {
        CodedError::new(
            ErrorKind::ModelUnhealthy,
            format!("model '{model}' is quarantined after a worker panic; retrain to heal"),
        )
    }

    /// Shorthand for [`ErrorKind::NumericFailure`].
    pub fn numeric(msg: impl Into<String>) -> CodedError {
        CodedError::new(ErrorKind::NumericFailure, msg)
    }

    /// Shorthand for [`ErrorKind::Internal`].
    pub fn internal(msg: impl Into<String>) -> CodedError {
        CodedError::new(ErrorKind::Internal, msg)
    }

    /// The wire code for this error's kind.
    pub fn code(&self) -> &'static str {
        self.kind.code()
    }
}

impl fmt::Display for CodedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for CodedError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_round_trip_and_stay_stable() {
        let want = [
            "invalid_input",
            "overloaded",
            "deadline_exceeded",
            "model_unhealthy",
            "numeric_failure",
            "internal",
        ];
        assert_eq!(ALL.len(), want.len());
        for (k, w) in ALL.iter().zip(want) {
            assert_eq!(k.code(), w);
            assert_eq!(ErrorKind::from_code(w), Some(*k));
        }
        assert_eq!(ErrorKind::from_code("nope"), None);
    }

    #[test]
    fn display_is_the_message() {
        let e = CodedError::invalid_input("x[0][2] is not finite");
        assert_eq!(e.to_string(), "x[0][2] is not finite");
        assert_eq!(e.code(), "invalid_input");
    }
}
