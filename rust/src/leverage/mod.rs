//! Ridge leverage scores — exact and BLESS-style approximate.
//!
//! The statistical leverage score of sample `i` (paper §2.2) is
//! `ℓᵢ = (K(K + nλIₙ)⁻¹)ᵢᵢ`; sampling landmarks with `pᵢ ∝ ℓᵢ` makes the
//! incoherence `M` collapse to `d_stat` (paper Theorem 8 remark), which is
//! why the leverage-based Nyström method is a baseline in Figures 3–5.
//! Exact scores cost `O(n³)`; [`bless`] implements a bottom-up approximate
//! sampler in the spirit of BLESS (Rudi et al., 2018): leverage scores are
//! estimated through a growing landmark set while the regularisation is
//! annealed down to the target λ.

use crate::data::TileSource;
use crate::kernels::{GramOperator, Kernel};
use crate::linalg::{chol_factor, Matrix};
use crate::rng::{AliasTable, Pcg64};
use crate::util::CodedError;

/// Exact ridge leverage scores `ℓᵢ = (K(K+nλI)⁻¹)ᵢᵢ = 1 − nλ·[(K+nλI)⁻¹]ᵢᵢ`.
///
/// Exactness is inherently dense (the caller owns the `n×n` K — this is
/// the small-n reference, [`bless`] is the scalable route), but the
/// diagonal of the resolvent comes from triangular solves on the Cholesky
/// factor (`CholFactor::inv_diag`: `(A⁻¹)ᵢᵢ = ‖L⁻¹eᵢ‖²`) — no explicit
/// inverse, which used to cost a second `n×n` allocation and a
/// GEMM-sized extra pass of back-substitutions.
pub fn exact_scores(k: &Matrix, lambda: f64) -> Vec<f64> {
    let n = k.rows();
    let nl = n as f64 * lambda;
    let mut a = k.clone();
    a.add_diag(nl);
    let fac = chol_factor(&a).expect("K + nλI must be PD for λ > 0");
    let diag = fac.inv_diag();
    (0..n).map(|i| (1.0 - nl * diag[i]).clamp(0.0, 1.0)).collect()
}

/// Statistical dimension `d_stat = Σᵢ ℓᵢ` — the theoretical lower bound on
/// the projection dimension (paper §2.2).
pub fn stat_dim_from_scores(scores: &[f64]) -> f64 {
    scores.iter().sum()
}

/// Result of the BLESS-style approximate leverage-score computation.
#[derive(Clone, Debug)]
pub struct BlessResult {
    /// Approximate leverage scores (same indexing as the data).
    pub scores: Vec<f64>,
    /// Landmark set used in the final round.
    pub landmarks: Vec<usize>,
    /// The final round's `n×s` kernel panel `K[:, landmarks]` (column `v`
    /// ↔ `landmarks[v]`). These columns are already paid for in
    /// [`kernel_evals`](Self::kernel_evals); a follow-up fit on the same
    /// data seeds them into
    /// [`IncrementalGram`](crate::sketch::IncrementalGram) via
    /// [`seed_columns`](crate::sketch::IncrementalGram::seed_columns) so
    /// landmark columns are never re-evaluated.
    pub panel: Matrix,
    /// Kernel evaluations performed (cost diagnostic).
    pub kernel_evals: usize,
}

impl BlessResult {
    /// Sampling distribution `pᵢ ∝ ℓ̂ᵢ` as an alias table.
    pub fn sampling_table(&self) -> AliasTable {
        AliasTable::new(&self.scores)
    }
}

/// Bottom-up approximate ridge leverage scores.
///
/// Rounds `h = 0,1,…` anneal `λ_h = λ_0 / q^h` down to the target `λ`
/// (`λ_0` chosen so the first round is easy: `λ_0 = 1`). Each round:
///
/// 1. sample a landmark set `J_h` (size `≤ q_size·d_target`) from the
///    previous round's score estimates,
/// 2. estimate all n scores against those landmarks via the Nyström
///    resolvent `ℓ̂ᵢ = (1/nλ_h)·(kᵢᵢ − k_{iJ}(K_{JJ} + nλ_h D)⁻¹ k_{Ji})`
///    with `D = diag(1/(s·p_J))` correcting for the sampling,
///
/// which costs `O(n·|J|² )` per round instead of `O(n³)` total.
///
/// `x` is any [`TileSource`]; panics on a tile-source read failure
/// (in-memory sources cannot fail) — see [`try_bless`] for the fallible
/// route the coordinator's file-backed jobs take.
pub fn bless(
    kernel: &Kernel,
    x: &dyn TileSource,
    lambda: f64,
    d_target: usize,
    oversample: f64,
    rng: &mut Pcg64,
) -> BlessResult {
    try_bless(kernel, x, lambda, d_target, oversample, rng)
        .expect("bless: tile source read failed")
}

/// Fallible [`bless`]: a failed read off a file-backed source (real, or
/// injected through the `io.read` fault seam) surfaces as a
/// [`CodedError`] instead of a panic. The RNG may have consumed draws
/// for the round that failed; rerun with a fresh seed position.
pub fn try_bless(
    kernel: &Kernel,
    x: &dyn TileSource,
    lambda: f64,
    d_target: usize,
    oversample: f64,
    rng: &mut Pcg64,
) -> Result<BlessResult, CodedError> {
    let n = x.rows();
    assert!(n > 0 && lambda > 0.0);
    // every kernel quantity streams off the Gram operator: the full n×n
    // matrix is never assembled, only n×s landmark panels
    let op = GramOperator::new(*kernel, x);
    let diag = op.try_diag()?;
    let mut kernel_evals = 0usize;

    // initial estimates: uniform
    let mut scores = vec![1.0; n];
    #[allow(unused_assignments)]
    let mut landmarks: Vec<usize> = Vec::new();
    #[allow(unused_assignments)]
    let mut panel = Matrix::zeros(0, 0);

    // anneal λ_h geometrically from 1.0 down to the target
    let q = 2.0;
    let mut lam_h = 1.0f64.max(lambda);
    loop {
        lam_h = (lam_h / q).max(lambda);
        // sample landmark set from current scores
        let size = ((oversample * d_target as f64).ceil() as usize).clamp(4, n);
        let table = AliasTable::new(&scores);
        let mut set: Vec<usize> = (0..size).map(|_| table.sample(rng)).collect();
        set.sort_unstable();
        set.dedup();
        let j = set;
        let s = j.len();

        // Nyström resolvent over the subset: A = K_JJ + s·λ_h·I. With
        // J = [n] this reduces to the exact identity ℓᵢ = (1/nλ)(kᵢᵢ −
        // kᵢ(K+nλI)⁻¹kᵢ); with |J| = s the sλ_h shift keeps the per-subset
        // regularisation proportional to its size (BLESS's rescaling).
        // One streamed n×s panel serves both: K_JJ is its rows at J (the
        // s² landmark-vs-landmark evals the old subset assembly re-paid).
        let kxj = op.try_columns(&j)?; // n × s
        kernel_evals += n * s;
        let mut a = Matrix::from_fn(s, s, |u, v| kxj[(j[u], v)]);
        a.add_diag(s as f64 * lam_h);
        let fac = match chol_factor(&a) {
            Some(f) => f,
            None => {
                let mut aj = a;
                aj.add_diag(1e-8);
                chol_factor(&aj).expect("bless: jittered factor")
            }
        };

        // estimate scores for all points
        let mut new_scores = vec![0.0; n];
        for i in 0..n {
            let ki = kxj.row(i);
            let sol = fac.solve(ki);
            let reduced: f64 = ki.iter().zip(sol.iter()).map(|(a, b)| a * b).sum();
            let resid = (diag[i] - reduced).max(0.0);
            new_scores[i] = (resid / (n as f64 * lam_h)).clamp(1e-12, 1.0);
        }
        scores = new_scores;
        landmarks = j;
        panel = kxj;

        if lam_h <= lambda {
            break;
        }
    }

    Ok(BlessResult {
        scores,
        landmarks,
        panel,
        kernel_evals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::kernel_matrix;
    use crate::rng::Pcg64;

    /// Two-cluster data where the paper's §3.2 failure case lives: a small
    /// dense cluster far from a large one. The dense far cluster's points
    /// must carry outsized leverage.
    fn clustered(n_big: usize, n_small: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seed(seed);
        Matrix::from_fn(n_big + n_small, 2, |i, _| {
            if i < n_big {
                rng.uniform() // big diffuse cluster in [0,1]
            } else {
                8.0 + 0.01 * rng.uniform() // tiny tight far cluster
            }
        })
    }

    #[test]
    fn exact_scores_in_unit_interval_and_sum_to_statdim() {
        let x = clustered(30, 5, 131);
        let k = kernel_matrix(&Kernel::gaussian(0.5), &x);
        let lam = 1e-3;
        let scores = exact_scores(&k, lam);
        assert!(scores.iter().all(|&s| (0.0..=1.0).contains(&s)));
        // d_stat = Σ σᵢ/(σᵢ+λ) over eigenvalues of K/n
        let eig = crate::linalg::eigh(&k);
        let n = x.rows() as f64;
        let want: f64 = eig.w.iter().map(|&w| {
            let s = (w / n).max(0.0);
            s / (s + lam)
        }).sum();
        let got = stat_dim_from_scores(&scores);
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }

    /// The triangular-solve route (`CholFactor::inv_diag`) produces the
    /// same scores as the explicit-inverse formula it replaced.
    #[test]
    fn exact_scores_match_explicit_inverse_route() {
        let x = clustered(25, 4, 137);
        let k = kernel_matrix(&Kernel::gaussian(0.5), &x);
        let lam = 1e-3;
        let got = exact_scores(&k, lam);
        let n = k.rows();
        let nl = n as f64 * lam;
        let mut a = k.clone();
        a.add_diag(nl);
        let inv = crate::linalg::chol_factor(&a).unwrap().inverse();
        for i in 0..n {
            let want = (1.0 - nl * inv[(i, i)]).clamp(0.0, 1.0);
            assert!(
                (got[i] - want).abs() < 1e-10,
                "score {i}: {} vs {}",
                got[i],
                want
            );
        }
    }

    #[test]
    fn far_cluster_points_have_high_leverage() {
        let x = clustered(60, 3, 132);
        let k = kernel_matrix(&Kernel::gaussian(0.4), &x);
        let scores = exact_scores(&k, 1e-4);
        let big_mean: f64 = scores[..60].iter().sum::<f64>() / 60.0;
        let small_mean: f64 = scores[60..].iter().sum::<f64>() / 3.0;
        assert!(
            small_mean > big_mean,
            "small far cluster should be high-leverage: {small_mean} vs {big_mean}"
        );
    }

    #[test]
    fn bless_correlates_with_exact() {
        let x = clustered(50, 5, 133);
        let kern = Kernel::gaussian(0.5);
        let k = kernel_matrix(&kern, &x);
        let lam = 1e-3;
        let exact = exact_scores(&k, lam);
        let mut rng = Pcg64::seed(134);
        let approx = bless(&kern, &x, lam, 15, 3.0, &mut rng);
        // rank correlation proxy: the top-5 exact points should rank highly
        // in the approximation on average
        let mut order: Vec<usize> = (0..55).collect();
        order.sort_by(|&a, &b| approx.scores[b].partial_cmp(&approx.scores[a]).unwrap());
        let rank_of = |i: usize| order.iter().position(|&j| j == i).unwrap();
        let mut top_exact: Vec<usize> = (0..55).collect();
        top_exact.sort_by(|&a, &b| exact[b].partial_cmp(&exact[a]).unwrap());
        let mean_rank: f64 =
            top_exact[..5].iter().map(|&i| rank_of(i) as f64).sum::<f64>() / 5.0;
        assert!(mean_rank < 22.0, "top exact-leverage points rank {mean_rank} on average");
        assert!(approx.kernel_evals < 55 * 55 * 12);
    }

    /// The returned panel is the final round's `K[:, landmarks]` — the
    /// reusable columns a follow-up `IncrementalGram` seeds its cache with.
    #[test]
    fn bless_panel_matches_kernel_columns() {
        let x = clustered(24, 3, 139);
        let kern = Kernel::gaussian(0.6);
        let mut rng = Pcg64::seed(140);
        let r = bless(&kern, &x, 1e-2, 8, 2.0, &mut rng);
        assert_eq!(r.panel.rows(), 27);
        assert_eq!(r.panel.cols(), r.landmarks.len());
        let k = kernel_matrix(&kern, &x);
        for (v, &row) in r.landmarks.iter().enumerate() {
            for i in 0..27 {
                assert!(
                    (r.panel[(i, v)] - k[(i, row)]).abs() < 1e-10,
                    "panel col {v} (landmark {row}) row {i}"
                );
            }
        }
    }

    #[test]
    fn bless_sampling_table_usable() {
        let x = clustered(20, 2, 135);
        let mut rng = Pcg64::seed(136);
        let r = bless(&Kernel::gaussian(0.6), &x, 1e-2, 6, 2.0, &mut rng);
        let t = r.sampling_table();
        assert_eq!(t.len(), 22);
        let i = t.sample(&mut rng);
        assert!(i < 22);
        assert!(!r.landmarks.is_empty());
    }
}
