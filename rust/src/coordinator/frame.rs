//! Wire framing for the serving plane (protocol v2).
//!
//! A v2 message is a **length-prefixed JSON frame**: a 4-byte big-endian
//! payload length followed by that many bytes of UTF-8 JSON. Frames never
//! exceed [`MAX_FRAME`] (8 MiB), so the high byte of a valid length
//! prefix is always `0x00` — which is also how the server tells protocols
//! apart: the first byte of a connection is sniffed ([`sniff`]), `0x00`
//! selects framed mode, `{` or leading whitespace selects the legacy
//! newline-delimited JSON protocol (v1, unchanged for old clients), and
//! anything else is rejected. A connection keeps its sniffed mode for its
//! whole lifetime.
//!
//! Framing exists so the reactor can multiplex: requests carry an `"id"`
//! and framed replies may arrive out of submission order (the reply
//! echoes the id), whereas legacy-mode replies are always released in
//! request order. [`Decoder`] is the incremental parser both modes share
//! on the server side; [`write_frame`]/[`read_frame`] are the blocking
//! client-side helpers the CLI, the load-generator bench and the tests
//! speak the protocol with.

use crate::util::json::Json;
use std::io::{self, Read, Write};

/// Hard cap on a single frame's payload (8 MiB — a predict batch of
/// ~130k rows of 8 features; anything larger should be chunked by the
/// client). Kept below `2^24` so valid length prefixes always start with
/// a zero byte (the protocol-sniffing invariant).
pub const MAX_FRAME: usize = 8 << 20;

/// Header size: 4-byte big-endian payload length.
pub const HEADER: usize = 4;

/// Which protocol a connection speaks (decided once per connection by
/// [`sniff`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Wire {
    /// v2: length-prefixed JSON frames, multiplexed via request ids.
    Framed,
    /// v1: newline-delimited JSON, replies strictly in request order.
    Legacy,
}

/// Decode errors the incremental [`Decoder`] can hit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// Declared payload length exceeds [`MAX_FRAME`]; the stream cannot
    /// be resynchronised, so the connection must close after the error
    /// reply.
    Oversized(usize),
}

/// Classify a connection by its first byte. `None` → unknown protocol
/// (reject the connection with an error).
pub fn sniff(first: u8) -> Option<Wire> {
    match first {
        0x00 => Some(Wire::Framed),
        b'{' | b' ' | b'\t' | b'\r' | b'\n' => Some(Wire::Legacy),
        _ => None,
    }
}

/// Wrap a payload in the 4-byte big-endian length header.
pub fn encode_frame(payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() <= MAX_FRAME, "frame exceeds MAX_FRAME");
    let mut out = Vec::with_capacity(HEADER + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    out.extend_from_slice(payload);
    out
}

/// Encode a JSON value as one framed message.
pub fn frame_msg(j: &Json) -> Vec<u8> {
    encode_frame(j.to_string().as_bytes())
}

/// Encode a JSON value as one legacy newline-terminated line.
pub fn legacy_msg(j: &Json) -> Vec<u8> {
    let mut out = j.to_string().into_bytes();
    out.push(b'\n');
    out
}

/// Write one framed request/reply (blocking client side).
pub fn write_frame<W: Write>(w: &mut W, j: &Json) -> io::Result<()> {
    w.write_all(&frame_msg(j))?;
    w.flush()
}

/// Read one framed message (blocking client side). `InvalidData` on an
/// oversized header or malformed JSON payload; `UnexpectedEof` on a
/// half-written frame.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Json> {
    let mut hdr = [0u8; HEADER];
    r.read_exact(&mut hdr)?;
    let len = u32::from_be_bytes(hdr) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds MAX_FRAME"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    let text = std::str::from_utf8(&payload)
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "frame payload not UTF-8"))?;
    Json::parse(text).map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))
}

/// Incremental receive buffer shared by both protocols: bytes go in via
/// [`push`](Decoder::push), complete frames or lines come out. Consumed
/// bytes are reclaimed lazily ([`compact`](Decoder::compact) runs
/// internally once the dead prefix outgrows the live tail).
#[derive(Default)]
pub struct Decoder {
    buf: Vec<u8>,
    pos: usize,
}

impl Decoder {
    /// Empty decoder.
    pub fn new() -> Decoder {
        Decoder::default()
    }

    /// Append received bytes.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Unconsumed bytes currently buffered.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn compact(&mut self) {
        if self.pos > 0 && self.pos >= self.buf.len().max(4096) / 2 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }

    /// Next complete frame payload, if one is fully buffered.
    pub fn next_frame(&mut self) -> Result<Option<Vec<u8>>, FrameError> {
        let avail = self.buffered();
        if avail < HEADER {
            return Ok(None);
        }
        let hdr = &self.buf[self.pos..self.pos + HEADER];
        let len = u32::from_be_bytes([hdr[0], hdr[1], hdr[2], hdr[3]]) as usize;
        if len > MAX_FRAME {
            return Err(FrameError::Oversized(len));
        }
        if avail < HEADER + len {
            return Ok(None);
        }
        let start = self.pos + HEADER;
        let payload = self.buf[start..start + len].to_vec();
        self.pos = start + len;
        self.compact();
        Ok(Some(payload))
    }

    /// Next complete newline-terminated line (legacy mode), without the
    /// terminator. Non-UTF-8 bytes are replaced, surfacing later as a
    /// JSON parse error rather than a connection kill.
    pub fn next_line(&mut self) -> Option<String> {
        let rel = self.buf[self.pos..].iter().position(|&b| b == b'\n')?;
        let end = self.pos + rel;
        let line = String::from_utf8_lossy(&self.buf[self.pos..end])
            .trim_end_matches('\r')
            .to_string();
        self.pos = end + 1;
        self.compact();
        Some(line)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_through_decoder() {
        let j = Json::obj(vec![("method", Json::Str("ping".into())), ("id", Json::from(7usize))]);
        let bytes = frame_msg(&j);
        assert_eq!(bytes[0], 0x00, "length high byte must be the sniff byte");
        let mut d = Decoder::new();
        d.push(&bytes);
        let payload = d.next_frame().unwrap().unwrap();
        assert_eq!(Json::parse(std::str::from_utf8(&payload).unwrap()).unwrap(), j);
        assert_eq!(d.next_frame().unwrap(), None);
    }

    #[test]
    fn decoder_handles_byte_at_a_time_and_pipelined_frames() {
        let a = frame_msg(&Json::obj(vec![("id", Json::from(1usize))]));
        let b = frame_msg(&Json::obj(vec![("id", Json::from(2usize))]));
        let mut stream = a.clone();
        stream.extend_from_slice(&b);
        let mut d = Decoder::new();
        let mut got = Vec::new();
        for byte in stream {
            d.push(&[byte]);
            while let Some(p) = d.next_frame().unwrap() {
                got.push(p);
            }
        }
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], a[HEADER..].to_vec());
        assert_eq!(got[1], b[HEADER..].to_vec());
    }

    #[test]
    fn oversized_header_is_an_error() {
        let mut d = Decoder::new();
        d.push(&((MAX_FRAME as u32 + 1).to_be_bytes()));
        assert_eq!(d.next_frame(), Err(FrameError::Oversized(MAX_FRAME + 1)));
    }

    #[test]
    fn half_frame_stays_pending() {
        let bytes = frame_msg(&Json::obj(vec![("id", Json::from(3usize))]));
        let mut d = Decoder::new();
        d.push(&bytes[..bytes.len() - 1]);
        assert_eq!(d.next_frame().unwrap(), None);
        d.push(&bytes[bytes.len() - 1..]);
        assert!(d.next_frame().unwrap().is_some());
    }

    #[test]
    fn legacy_lines_split_correctly() {
        let mut d = Decoder::new();
        d.push(b"{\"op\":\"ping\"}\r\n{\"op\":");
        assert_eq!(d.next_line().as_deref(), Some("{\"op\":\"ping\"}"));
        assert_eq!(d.next_line(), None);
        d.push(b"\"metrics\"}\n");
        assert_eq!(d.next_line().as_deref(), Some("{\"op\":\"metrics\"}"));
    }

    #[test]
    fn sniff_table() {
        assert_eq!(sniff(0x00), Some(Wire::Framed));
        assert_eq!(sniff(b'{'), Some(Wire::Legacy));
        assert_eq!(sniff(b' '), Some(Wire::Legacy));
        assert_eq!(sniff(b'\n'), Some(Wire::Legacy));
        assert_eq!(sniff(b'G'), None, "HTTP and other junk is rejected");
        assert_eq!(sniff(0x01), None, "oversized first header byte is rejected");
    }

    /// Property: a framed byte stream decodes to the same messages no
    /// matter how it is chunked. Chunk sizes are drawn seeded from
    /// 1..=9 bytes, so splits land inside the 4-byte header (and inside
    /// payloads, and across frame boundaries) many times per trial; the
    /// decode must equal one-shot delivery exactly, in order.
    #[test]
    fn random_chunking_decodes_like_one_shot() {
        use crate::rng::Pcg64;
        let msgs: Vec<Json> = (0..6usize)
            .map(|i| {
                Json::obj(vec![
                    ("id", Json::from(i)),
                    ("method", Json::Str("predict".into())),
                    ("x", Json::nums(&vec![0.25 * i as f64; i * 7 + 1])),
                ])
            })
            .collect();
        let stream: Vec<u8> = msgs.iter().flat_map(frame_msg).collect();
        let mut d = Decoder::new();
        d.push(&stream);
        let mut want = Vec::new();
        while let Some(p) = d.next_frame().unwrap() {
            want.push(p);
        }
        assert_eq!(want.len(), msgs.len(), "reference decode must see every frame");
        let mut rng = Pcg64::seed(0xC0FFEE);
        for trial in 0..64 {
            let mut d = Decoder::new();
            let mut got = Vec::new();
            let mut pos = 0;
            while pos < stream.len() {
                let max = (stream.len() - pos).min(1 + rng.below(9) as usize);
                let take = 1 + rng.below(max as u64) as usize;
                d.push(&stream[pos..pos + take]);
                pos += take;
                while let Some(p) = d.next_frame().unwrap() {
                    got.push(p);
                }
            }
            assert_eq!(got, want, "trial {trial} diverged from one-shot decode");
        }
    }

    #[test]
    fn blocking_helpers_roundtrip() {
        let j = Json::obj(vec![("ok", Json::Bool(true)), ("y", Json::nums(&[1.5, -2.0]))]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &j).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap(), j);
    }
}
