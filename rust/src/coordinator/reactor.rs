//! Single-threaded readiness loop driving every serving connection.
//!
//! Pure-`std` event loop (DESIGN.md §9): the listener and all accepted
//! sockets run non-blocking, and one reactor thread sweeps them —
//! accept burst, completion drain, per-connection reads → parse →
//! dispatch, write flush — then parks briefly on the completion channel
//! when a sweep found no work. The `mpsc` completion channel doubles as
//! the wake mechanism: worker threads (batcher, task pool) finish a
//! request by sending [`Done::Reply`], which both delivers the bytes and
//! wakes the reactor. Without `epoll`/`kqueue` (no `libc` in the
//! zero-dep substrate) idle wakeups are bounded by the park interval:
//! 200 µs with open connections, 5 ms when idle — a latency floor that
//! disappears under load, when the sweep always finds work and never
//! parks.
//!
//! **Backpressure** is per connection and enforced at parse time: a
//! request that would push `inflight` past `max_inflight`, or that
//! arrives while the outbound queue holds more than `high_water_bytes`
//! of unread replies, is shed immediately with
//! `{"ok":false,"err":"overloaded"}` (and a `shed` counter tick) instead
//! of being dispatched. Reply bytes queue in a per-connection
//! [`VecDeque`] and are written opportunistically; a slow reader
//! therefore fills its own queue and starts shedding without affecting
//! any other connection.
//!
//! **Ordering:** every parsed request gets a per-connection sequence
//! number and completes exactly once (dispatch reply, parse error, or
//! shed). Legacy-mode replies are parked and released strictly in
//! sequence order (newline clients have no ids to match on); framed
//! replies are released the moment they complete — the id does the
//! matching.

use crate::coordinator::frame::{legacy_msg, sniff, Decoder, FrameError, Wire};
use crate::coordinator::metrics::ServingMetrics;
use crate::util::json::Json;
use crate::util::ErrorKind;
use std::collections::{BTreeMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// Reactor-level limits (the server config carries user-facing knobs).
#[derive(Clone, Copy, Debug)]
pub(crate) struct ReactorConfig {
    /// Max requests in flight per connection before shedding.
    pub max_inflight: usize,
    /// Max queued outbound bytes per connection before shedding.
    pub high_water_bytes: usize,
}

/// Messages into the reactor: a finished request's reply bytes, or a
/// bare wakeup (shutdown nudge).
pub(crate) enum Done {
    Reply {
        conn: usize,
        gen: u64,
        seq: u64,
        bytes: Vec<u8>,
    },
    Wake,
}

/// One-shot reply channel handed to the router per request. Dropping it
/// without sending leaks the sequence slot on a legacy connection, so
/// routers must guarantee exactly-once delivery (the coordinator router
/// wraps handlers in `catch_unwind` for this reason).
pub(crate) struct ReplySink {
    tx: Sender<Done>,
    conn: usize,
    gen: u64,
    seq: u64,
    mode: Wire,
    id: Json,
    method: String,
    deadline: Option<Instant>,
}

impl ReplySink {
    /// Absolute deadline derived from the request's `deadline_ms` field,
    /// if the client sent one. Handlers and the batcher consult it so an
    /// expired request is answered `deadline_exceeded` instead of doing
    /// (and then discarding) the work.
    pub fn deadline(&self) -> Option<Instant> {
        self.deadline
    }

    /// Encode the reply for this connection's protocol and deliver it to
    /// the reactor (also wakes it).
    pub fn send(self, reply: Json) {
        let bytes = encode_reply(self.mode, &self.id, &self.method, reply);
        let _ = self.tx.send(Done::Reply {
            conn: self.conn,
            gen: self.gen,
            seq: self.seq,
            bytes,
        });
    }
}

/// Request dispatcher plugged into the reactor (implemented by
/// `server::CoordRouter`; a trait so protocol tests can stub it).
pub(crate) trait Router: Send + Sync + 'static {
    /// Handle one parsed request; must eventually call `sink.send`
    /// exactly once (synchronously or from another thread).
    fn route(&self, req: Json, sink: ReplySink);
    /// Cooperative shutdown flag: when set, the reactor stops accepting
    /// and reading, drains outstanding work briefly, and exits.
    fn stop_flag(&self) -> &AtomicBool;
    /// Shared counters (shed / frame errors are ticked by the reactor).
    fn metrics(&self) -> &ServingMetrics;
}

/// Envelope guarantee for framed replies: inject the echoed `id` and
/// `method`, default `ok` to `true` when the handler didn't set it,
/// mirror `err`/`error` both ways so clients can rely on either key, and
/// guarantee every failed reply carries a machine-stable `err_code`
/// (defaulting to `internal` when the handler set none). Legacy replies
/// stay byte-identical to the v1 protocol: the taxonomy postdates v1, so
/// `err_code` is stripped before newline encoding.
pub(crate) fn encode_reply(mode: Wire, id: &Json, method: &str, mut reply: Json) -> Vec<u8> {
    match mode {
        Wire::Legacy => {
            if let Json::Obj(m) = &mut reply {
                m.remove("err_code");
            }
            legacy_msg(&reply)
        }
        Wire::Framed => {
            if let Json::Obj(m) = &mut reply {
                if !matches!(id, Json::Null) {
                    m.insert("id".into(), id.clone());
                }
                if !method.is_empty() && !m.contains_key("method") {
                    m.insert("method".into(), Json::Str(method.to_string()));
                }
                if !m.contains_key("ok") {
                    m.insert("ok".into(), Json::Bool(true));
                }
                if let Some(e) = m.get("error").cloned() {
                    m.entry("err".to_string()).or_insert(e);
                } else if let Some(e) = m.get("err").cloned() {
                    m.entry("error".to_string()).or_insert(e);
                }
                if matches!(m.get("ok"), Some(Json::Bool(false))) && !m.contains_key("err_code") {
                    m.insert(
                        "err_code".into(),
                        Json::Str(ErrorKind::Internal.code().to_string()),
                    );
                }
            }
            crate::coordinator::frame::frame_msg(&reply)
        }
    }
}

fn err_reply(kind: ErrorKind, msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("err_code", Json::Str(kind.code().to_string())),
        ("error", Json::Str(msg.to_string())),
    ])
}

/// The canonical load-shed reply; carries both error keys explicitly so
/// even legacy clients (no envelope injection) see `"err":"overloaded"`.
fn overloaded_reply() -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("err", Json::Str("overloaded".into())),
        ("err_code", Json::Str(ErrorKind::Overloaded.code().to_string())),
        ("error", Json::Str("overloaded".into())),
    ])
}

struct Conn {
    stream: TcpStream,
    gen: u64,
    mode: Option<Wire>,
    dec: Decoder,
    wq: VecDeque<Vec<u8>>,
    /// Bytes of `wq.front()` already written.
    wfront: usize,
    /// Total unwritten outbound bytes (the backpressure signal).
    wbytes: usize,
    next_seq: u64,
    /// Legacy ordering: next sequence number eligible for release.
    release_next: u64,
    /// Legacy replies completed out of order, keyed by sequence.
    parked: BTreeMap<u64, Vec<u8>>,
    inflight: usize,
    /// Half-closed: no more reads; freed once fully drained.
    closing: bool,
    /// Unrecoverable; freed on the next sweep.
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream, gen: u64) -> Conn {
        Conn {
            stream,
            gen,
            mode: None,
            dec: Decoder::new(),
            wq: VecDeque::new(),
            wfront: 0,
            wbytes: 0,
            next_seq: 0,
            release_next: 0,
            parked: BTreeMap::new(),
            inflight: 0,
            closing: false,
            dead: false,
        }
    }

    fn enqueue(&mut self, bytes: Vec<u8>) {
        self.wbytes += bytes.len();
        self.wq.push_back(bytes);
    }

    /// A request finished: account it and release what's releasable.
    fn complete(&mut self, seq: u64, bytes: Vec<u8>) {
        self.inflight = self.inflight.saturating_sub(1);
        match self.mode {
            Some(Wire::Legacy) => {
                self.parked.insert(seq, bytes);
                while let Some(b) = self.parked.remove(&self.release_next) {
                    self.enqueue(b);
                    self.release_next += 1;
                }
            }
            _ => self.enqueue(bytes),
        }
    }

    /// Write until the socket pushes back. Returns `true` on progress.
    fn flush_writes(&mut self) -> bool {
        // Chaos seam: an injected write fault behaves like a broken pipe
        // — this connection dies, every other connection keeps serving.
        if !self.wq.is_empty() && crate::util::fault::hit("io.write") {
            self.dead = true;
            return false;
        }
        let mut progressed = false;
        while let Some(front) = self.wq.front() {
            match self.stream.write(&front[self.wfront..]) {
                Ok(0) => {
                    self.dead = true;
                    return progressed;
                }
                Ok(n) => {
                    progressed = true;
                    self.wfront += n;
                    self.wbytes -= n;
                    if self.wfront == front.len() {
                        self.wq.pop_front();
                        self.wfront = 0;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return progressed,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    return progressed;
                }
            }
        }
        progressed
    }

    /// Drained and done: nothing queued, nothing parked, nothing inflight.
    fn drained(&self) -> bool {
        self.wq.is_empty() && self.parked.is_empty() && self.inflight == 0
    }
}

/// Spawn the reactor thread over a bound (blocking-mode) listener.
/// Returns the completion/wake sender and the join handle; the thread
/// exits once the router's stop flag is set and the grace drain ends.
pub(crate) fn spawn<R: Router>(
    listener: TcpListener,
    router: std::sync::Arc<R>,
    cfg: ReactorConfig,
) -> std::io::Result<(Sender<Done>, std::thread::JoinHandle<()>)> {
    listener.set_nonblocking(true)?;
    let (tx, rx) = channel::<Done>();
    let tx2 = tx.clone();
    let handle = std::thread::Builder::new()
        .name("accumkrr-reactor".into())
        .spawn(move || run(listener, router, cfg, tx2, rx))
        .expect("spawn reactor thread");
    Ok((tx, handle))
}

fn run<R: Router>(
    listener: TcpListener,
    router: std::sync::Arc<R>,
    cfg: ReactorConfig,
    tx: Sender<Done>,
    rx: Receiver<Done>,
) {
    let mut conns: Vec<Option<Conn>> = Vec::new();
    let mut gens: Vec<u64> = Vec::new();
    let mut buf = vec![0u8; 16 * 1024];
    loop {
        let stopping = router.stop_flag().load(Ordering::SeqCst);
        let mut activity = false;

        if !stopping {
            // accept burst (bounded so a connect flood can't starve IO)
            for _ in 0..64 {
                match listener.accept() {
                    Ok((s, _)) => {
                        let _ = s.set_nodelay(true);
                        if s.set_nonblocking(true).is_err() {
                            continue;
                        }
                        activity = true;
                        let slot = conns.iter().position(|c| c.is_none());
                        match slot {
                            Some(i) => conns[i] = Some(Conn::new(s, gens[i])),
                            None => {
                                conns.push(Some(Conn::new(s, 0)));
                                gens.push(0);
                            }
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(_) => break,
                }
            }
        }

        // drain finished work
        while let Ok(done) = rx.try_recv() {
            activity = true;
            apply_done(&mut conns, done);
        }

        // per-connection IO sweep
        for idx in 0..conns.len() {
            let Some(conn) = conns[idx].as_mut() else {
                continue;
            };
            if !stopping && !conn.closing && !conn.dead {
                // bounded read burst per tick per connection
                'reads: for _ in 0..4 {
                    match conn.stream.read(&mut buf) {
                        Ok(0) => {
                            conn.closing = true;
                            break 'reads;
                        }
                        Ok(n) => {
                            activity = true;
                            // Chaos seam: an injected read fault acts as
                            // a mid-request connection reset.
                            if crate::util::fault::hit("io.read") {
                                conn.dead = true;
                                break 'reads;
                            }
                            if conn.mode.is_none() {
                                match sniff(buf[0]) {
                                    Some(m) => conn.mode = Some(m),
                                    None => {
                                        router
                                            .metrics()
                                            .frame_errors
                                            .fetch_add(1, Ordering::Relaxed);
                                        router.metrics().tick_err_code("invalid_input");
                                        // legacy encoding strips err_code
                                        conn.enqueue(encode_reply(
                                            Wire::Legacy,
                                            &Json::Null,
                                            "",
                                            err_reply(
                                                ErrorKind::InvalidInput,
                                                "unknown protocol (expected framed or newline \
                                                 JSON)",
                                            ),
                                        ));
                                        conn.closing = true;
                                        break 'reads;
                                    }
                                }
                            }
                            conn.dec.push(&buf[..n]);
                            parse_available(conn, idx, router.as_ref(), &tx, &cfg);
                            if conn.closing || conn.dead {
                                break 'reads;
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break 'reads,
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                        Err(_) => {
                            conn.dead = true;
                            break 'reads;
                        }
                    }
                }
            }
            if conn.flush_writes() {
                activity = true;
            }
            if conn.dead || (conn.closing && conn.drained()) {
                gens[idx] = conn.gen + 1;
                conns[idx] = None;
            }
        }

        if stopping {
            grace_drain(&mut conns, &rx);
            return;
        }

        if !activity {
            let open = conns.iter().filter(|c| c.is_some()).count();
            let park = if open > 0 {
                Duration::from_micros(200)
            } else {
                Duration::from_millis(5)
            };
            match rx.recv_timeout(park) {
                Ok(done) => apply_done(&mut conns, done),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => return,
            }
        }
    }
}

/// Post-shutdown drain: deliver already-inflight replies (the shutdown
/// ack itself among them) and flush sockets, bounded at 250 ms so
/// shutdown latency stays deterministic even with a slow op in flight.
fn grace_drain(conns: &mut [Option<Conn>], rx: &Receiver<Done>) {
    let deadline = Instant::now() + Duration::from_millis(250);
    loop {
        while let Ok(done) = rx.try_recv() {
            apply_done_slice(conns, done);
        }
        let mut pending = false;
        for conn in conns.iter_mut().flatten() {
            conn.flush_writes();
            if !conn.dead && !conn.drained() {
                pending = true;
            }
        }
        if !pending || Instant::now() >= deadline {
            return;
        }
        match rx.recv_timeout(Duration::from_millis(2)) {
            Ok(done) => apply_done_slice(conns, done),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                // no more completions can arrive; flush what's queued
                for conn in conns.iter_mut().flatten() {
                    conn.flush_writes();
                }
                return;
            }
        }
    }
}

fn apply_done(conns: &mut Vec<Option<Conn>>, done: Done) {
    apply_done_slice(conns.as_mut_slice(), done);
}

fn apply_done_slice(conns: &mut [Option<Conn>], done: Done) {
    if let Done::Reply {
        conn,
        gen,
        seq,
        bytes,
    } = done
    {
        if let Some(Some(c)) = conns.get_mut(conn) {
            if c.gen == gen && !c.dead {
                c.complete(seq, bytes);
            }
        }
    }
}

/// Pull every complete message out of the connection's decoder and start
/// (or summarily answer) a request for each.
fn parse_available<R: Router>(
    conn: &mut Conn,
    idx: usize,
    router: &R,
    tx: &Sender<Done>,
    cfg: &ReactorConfig,
) {
    loop {
        if conn.closing || conn.dead {
            return;
        }
        match conn.mode {
            Some(Wire::Legacy) => match conn.dec.next_line() {
                Some(line) => {
                    if line.trim().is_empty() {
                        continue;
                    }
                    begin_request(conn, idx, router, tx, cfg, &line);
                }
                None => return,
            },
            Some(Wire::Framed) => match conn.dec.next_frame() {
                Ok(Some(payload)) => {
                    // Chaos seam: an injected decode fault corrupts this
                    // one frame — structured reply, connection survives.
                    if crate::util::fault::hit("frame.decode") {
                        router.metrics().frame_errors.fetch_add(1, Ordering::Relaxed);
                        router.metrics().tick_err_code("invalid_input");
                        let reply =
                            err_reply(ErrorKind::InvalidInput, "injected fault: frame.decode");
                        conn.enqueue(encode_reply(Wire::Framed, &Json::Null, "", reply));
                        continue;
                    }
                    let text = String::from_utf8_lossy(&payload).into_owned();
                    begin_request(conn, idx, router, tx, cfg, &text);
                }
                Ok(None) => return,
                Err(FrameError::Oversized(len)) => {
                    // unrecoverable: the stream can't be resynchronised
                    router.metrics().frame_errors.fetch_add(1, Ordering::Relaxed);
                    router.metrics().tick_err_code("invalid_input");
                    let reply = err_reply(
                        ErrorKind::InvalidInput,
                        &format!(
                            "frame of {len} bytes exceeds limit of {} bytes",
                            crate::coordinator::frame::MAX_FRAME
                        ),
                    );
                    let bytes = encode_reply(Wire::Framed, &Json::Null, "", reply);
                    conn.enqueue(bytes);
                    conn.closing = true;
                    return;
                }
            },
            None => return,
        }
    }
}

fn begin_request<R: Router>(
    conn: &mut Conn,
    idx: usize,
    router: &R,
    tx: &Sender<Done>,
    cfg: &ReactorConfig,
    text: &str,
) {
    let mode = conn.mode.expect("mode sniffed before parsing");
    let seq = conn.next_seq;
    conn.next_seq += 1;
    conn.inflight += 1;
    let parsed = Json::parse(text);
    let (id, method) = match &parsed {
        Ok(j) => (
            j.get("id").cloned().unwrap_or(Json::Null),
            j.get("method")
                .or_else(|| j.get("op"))
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
        ),
        Err(_) => (Json::Null, String::new()),
    };
    match parsed {
        Err(e) => {
            router.metrics().frame_errors.fetch_add(1, Ordering::Relaxed);
            router.metrics().tick_err_code("invalid_input");
            let reply = err_reply(ErrorKind::InvalidInput, &format!("bad json: {e}"));
            let bytes = encode_reply(mode, &id, &method, reply);
            conn.complete(seq, bytes);
        }
        Ok(req) => {
            let overloaded =
                conn.inflight > cfg.max_inflight || conn.wbytes > cfg.high_water_bytes;
            if overloaded {
                router.metrics().shed.fetch_add(1, Ordering::Relaxed);
                router.metrics().tick_err_code("overloaded");
                let bytes = encode_reply(mode, &id, &method, overloaded_reply());
                conn.complete(seq, bytes);
            } else {
                let deadline = req
                    .get("deadline_ms")
                    .and_then(Json::as_usize)
                    .map(|ms| Instant::now() + Duration::from_millis(ms as u64));
                router.route(
                    req,
                    ReplySink {
                        tx: tx.clone(),
                        conn: idx,
                        gen: conn.gen,
                        seq,
                        mode,
                        id,
                        method,
                        deadline,
                    },
                );
            }
        }
    }
}
