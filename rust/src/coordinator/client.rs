//! Retrying TCP client for the serving plane.
//!
//! Wraps one connection to an `accumkrr serve` instance with the retry
//! discipline a production caller needs: bounded attempts, exponential
//! backoff with seeded jitter (deterministic under test), reconnect on
//! transport errors, and a running tally of `err_code`s seen so callers
//! can report shed vs deadline vs fault rejections separately.
//!
//! Retries are **idempotent-only**: `ping`, `predict`, `models`,
//! `metrics`, and `cluster` are safe to resend (they mutate nothing), so
//! a transport error or an `overloaded` shed triggers a backed-off
//! retry. `train` and `shutdown` are never resent — a lost reply does
//! not prove the op did not run, and double-submitting a multi-second
//! fit is worse than surfacing the error.

use crate::coordinator::frame::{read_frame, write_frame};
use crate::rng::Pcg64;
use crate::util::json::Json;
use crate::util::CodedError;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Ops the client will resend after a transport error or shed. Everything
/// else gets exactly one attempt.
const IDEMPOTENT_OPS: &[&str] = &["ping", "predict", "models", "metrics", "cluster"];

/// Client configuration; see [`Client::new`].
#[derive(Clone, Debug)]
pub struct ClientConfig {
    /// Server address, e.g. `127.0.0.1:7878`.
    pub addr: String,
    /// Extra attempts after the first (so `retries: 2` → ≤ 3 sends).
    pub retries: u32,
    /// Base backoff before the first retry; doubles each further retry,
    /// with up to +50% seeded jitter so synchronized clients desynchronize.
    pub backoff: Duration,
    /// Seed for the jitter stream (deterministic tests).
    pub seed: u64,
    /// Speak v1 newline JSON instead of framed v2.
    pub legacy: bool,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            addr: "127.0.0.1:7878".into(),
            retries: 2,
            backoff: Duration::from_millis(50),
            seed: 1,
            legacy: false,
        }
    }
}

/// A lazily-connected retrying client. Not thread-safe by design (one
/// connection, one request in flight); clone the config and build one
/// per thread for concurrent load.
pub struct Client {
    cfg: ClientConfig,
    conn: Option<TcpStream>,
    rng: Pcg64,
    err_codes: BTreeMap<String, u64>,
    attempts: u64,
    retries: u64,
}

impl Client {
    /// Build a client; no I/O happens until the first [`call`](Client::call).
    pub fn new(cfg: ClientConfig) -> Client {
        let seed = cfg.seed;
        Client {
            cfg,
            conn: None,
            rng: Pcg64::seed(seed),
            err_codes: BTreeMap::new(),
            attempts: 0,
            retries: 0,
        }
    }

    /// Every `err_code` observed in failed replies, with counts. Legacy
    /// replies carry no code; their failures tally under `"unknown"`.
    pub fn err_code_tally(&self) -> &BTreeMap<String, u64> {
        &self.err_codes
    }

    /// `(total sends, of which retries)` — observability for the bench.
    pub fn stats(&self) -> (u64, u64) {
        (self.attempts, self.retries)
    }

    fn ensure_conn(&mut self) -> std::io::Result<&mut TcpStream> {
        if self.conn.is_none() {
            let s = TcpStream::connect(&self.cfg.addr)?;
            let _ = s.set_nodelay(true);
            self.conn = Some(s);
        }
        Ok(self.conn.as_mut().expect("connection just established"))
    }

    /// One send + one reply on the current connection; any I/O error
    /// tears the connection down so the next attempt reconnects.
    fn send_once(&mut self, req: &Json) -> std::io::Result<Json> {
        let legacy = self.cfg.legacy;
        let result = (|| {
            let conn = self.ensure_conn()?;
            if legacy {
                conn.write_all(format!("{req}\n").as_bytes())?;
                conn.flush()?;
                let mut line = String::new();
                let mut reader = BufReader::new(conn.try_clone()?);
                if reader.read_line(&mut line)? == 0 {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "server closed the connection",
                    ));
                }
                Json::parse(&line).map_err(|e| {
                    std::io::Error::new(std::io::ErrorKind::InvalidData, format!("bad reply: {e}"))
                })
            } else {
                write_frame(conn, req)?;
                read_frame(conn)
            }
        })();
        if result.is_err() {
            self.conn = None;
        }
        result
    }

    fn backoff_for(&mut self, attempt: u32) -> Duration {
        let exp = self.cfg.backoff.saturating_mul(1u32 << attempt.min(16));
        exp.mul_f64(1.0 + 0.5 * self.rng.uniform())
    }

    fn tally_reply(&mut self, reply: &Json) {
        if reply.get("ok").and_then(|v| v.as_bool()) == Some(false) {
            let code = reply
                .get("err_code")
                .and_then(|c| c.as_str())
                .unwrap_or("unknown")
                .to_string();
            *self.err_codes.entry(code).or_insert(0) += 1;
        }
    }

    /// Send one request and return the server's reply. `Err` means the
    /// transport failed (after retries, if the op was idempotent);
    /// application-level failures come back as `Ok` replies with
    /// `ok:false` plus `err_code` — inspect, don't unwrap.
    pub fn call(&mut self, req: &Json) -> Result<Json, CodedError> {
        let op = req
            .get("method")
            .or_else(|| req.get("op"))
            .and_then(|o| o.as_str())
            .unwrap_or("")
            .to_string();
        let retryable = IDEMPOTENT_OPS.contains(&op.as_str());
        let mut attempt = 0u32;
        loop {
            self.attempts += 1;
            match self.send_once(req) {
                Ok(reply) => {
                    self.tally_reply(&reply);
                    let shed = reply.get("err_code").and_then(|c| c.as_str())
                        == Some("overloaded")
                        || reply.get("err").and_then(|e| e.as_str()) == Some("overloaded");
                    if shed && retryable && attempt < self.cfg.retries {
                        let wait = self.backoff_for(attempt);
                        std::thread::sleep(wait);
                        attempt += 1;
                        self.retries += 1;
                        continue;
                    }
                    return Ok(reply);
                }
                Err(e) => {
                    if retryable && attempt < self.cfg.retries {
                        let wait = self.backoff_for(attempt);
                        std::thread::sleep(wait);
                        attempt += 1;
                        self.retries += 1;
                        continue;
                    }
                    return Err(CodedError::internal(format!(
                        "transport to {} failed after {} attempt(s): {e}",
                        self.cfg.addr,
                        attempt + 1
                    )));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ModelStore, ServerConfig, ServerHandle};
    use std::sync::Arc;

    fn local_server() -> ServerHandle {
        ServerHandle::start(
            Arc::new(ModelStore::new()),
            ServerConfig {
                addr: "127.0.0.1:0".into(),
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn ping_roundtrips_on_both_protocols() {
        let server = local_server();
        for legacy in [false, true] {
            let mut c = Client::new(ClientConfig {
                addr: server.addr().to_string(),
                legacy,
                ..Default::default()
            });
            let reply = c.call(&Json::obj(vec![("op", Json::Str("ping".into()))])).unwrap();
            assert_eq!(reply.get("pong"), Some(&Json::Bool(true)), "legacy={legacy}");
            assert_eq!(c.stats(), (1, 0), "no retries on a healthy call");
        }
        server.stop();
    }

    #[test]
    fn application_errors_are_tallied_not_retried() {
        let server = local_server();
        let mut c = Client::new(ClientConfig {
            addr: server.addr().to_string(),
            ..Default::default()
        });
        let reply = c
            .call(&Json::obj(vec![
                ("method", Json::Str("predict".into())),
                ("model", Json::Str("absent".into())),
                ("x", Json::Arr(vec![Json::nums(&[0.0, 0.0])])),
            ]))
            .unwrap();
        assert_eq!(reply.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(
            reply.get("err_code").and_then(|c| c.as_str()),
            Some("invalid_input"),
            "{reply}"
        );
        assert_eq!(c.err_code_tally().get("invalid_input"), Some(&1));
        assert_eq!(c.stats(), (1, 0), "invalid_input must not be retried");
        server.stop();
    }

    #[test]
    fn transport_failure_retries_then_reports() {
        // bind-then-drop: the port is real but nobody is listening
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut c = Client::new(ClientConfig {
            addr: dead,
            retries: 2,
            backoff: Duration::from_millis(1),
            ..Default::default()
        });
        let err = c.call(&Json::obj(vec![("op", Json::Str("ping".into()))])).unwrap_err();
        assert!(err.msg.contains("3 attempt(s)"), "{}", err.msg);
        assert_eq!(c.stats(), (3, 2));
        // non-idempotent ops get exactly one attempt
        let before = c.stats().0;
        let _ = c.call(&Json::obj(vec![("op", Json::Str("train".into()))]));
        assert_eq!(c.stats().0, before + 1);
    }
}
