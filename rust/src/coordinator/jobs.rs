//! Experiment job scheduler.
//!
//! Every figure in the paper is a sweep: settings × replicates, each
//! replicate an independent seeded run. The scheduler fans jobs out over a
//! worker pool (bounded by `pool::num_threads`), gives each job its own
//! PCG stream (derived from the root seed + job index, so results are
//! reproducible regardless of scheduling order), and collects results in
//! submission order.

use crate::pool;
use crate::rng::Pcg64;

/// One point in a sweep: setting index × replicate index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SweepPoint {
    /// Index into the settings list.
    pub setting: usize,
    /// Replicate number within the setting.
    pub replicate: usize,
}

/// Scheduler configured with a root seed.
#[derive(Clone, Debug)]
pub struct JobScheduler {
    root_seed: u64,
}

impl JobScheduler {
    /// New scheduler; all job RNGs derive from `root_seed`.
    pub fn new(root_seed: u64) -> JobScheduler {
        JobScheduler { root_seed }
    }

    /// RNG for a given sweep point — stable under parallel scheduling.
    pub fn rng_for(&self, pt: SweepPoint) -> Pcg64 {
        Pcg64::seed_stream(
            self.root_seed ^ (pt.setting as u64).wrapping_mul(0x9e3779b97f4a7c15),
            0x100 + pt.replicate as u64,
        )
    }

    /// Run `f` over `settings × replicates` in parallel; results arrive
    /// grouped per setting, in replicate order.
    pub fn run_sweep<R: Send, F>(&self, n_settings: usize, replicates: usize, f: F) -> Vec<Vec<R>>
    where
        F: Fn(SweepPoint, &mut Pcg64) -> R + Sync,
    {
        let total = n_settings * replicates;
        let flat = pool::parallel_map(total, |i| {
            let pt = SweepPoint {
                setting: i / replicates,
                replicate: i % replicates,
            };
            let mut rng = self.rng_for(pt);
            f(pt, &mut rng)
        });
        let mut out: Vec<Vec<R>> = (0..n_settings).map(|_| Vec::with_capacity(replicates)).collect();
        for (i, r) in flat.into_iter().enumerate() {
            out[i / replicates].push(r);
        }
        out
    }

    /// Mean and standard error over replicate values (the paper reports
    /// 30-replicate averages with standard-error bars).
    pub fn mean_stderr(values: &[f64]) -> (f64, f64) {
        let n = values.len();
        if n == 0 {
            return (f64::NAN, f64::NAN);
        }
        let mean = values.iter().sum::<f64>() / n as f64;
        if n == 1 {
            return (mean, 0.0);
        }
        let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / (n - 1) as f64;
        (mean, (var / n as f64).sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_shape_and_order() {
        let s = JobScheduler::new(42);
        let out = s.run_sweep(3, 4, |pt, _| (pt.setting, pt.replicate));
        assert_eq!(out.len(), 3);
        for (si, group) in out.iter().enumerate() {
            assert_eq!(group.len(), 4);
            for (ri, &(gs, gr)) in group.iter().enumerate() {
                assert_eq!((gs, gr), (si, ri));
            }
        }
    }

    #[test]
    fn rng_streams_reproducible_and_distinct() {
        let s = JobScheduler::new(7);
        let a1 = s
            .rng_for(SweepPoint { setting: 1, replicate: 2 })
            .next_u64();
        let a2 = s
            .rng_for(SweepPoint { setting: 1, replicate: 2 })
            .next_u64();
        assert_eq!(a1, a2);
        let b = s
            .rng_for(SweepPoint { setting: 1, replicate: 3 })
            .next_u64();
        let c = s
            .rng_for(SweepPoint { setting: 2, replicate: 2 })
            .next_u64();
        assert_ne!(a1, b);
        assert_ne!(a1, c);
    }

    #[test]
    fn sweep_results_deterministic() {
        let run = || {
            JobScheduler::new(3).run_sweep(2, 3, |_, rng| rng.uniform())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn mean_stderr_basic() {
        let (m, se) = JobScheduler::mean_stderr(&[1.0, 3.0]);
        assert_eq!(m, 2.0);
        assert!((se - 1.0).abs() < 1e-12); // var = 2, se = √(2/2) = 1
        let (m1, se1) = JobScheduler::mean_stderr(&[5.0]);
        assert_eq!((m1, se1), (5.0, 0.0));
    }
}
