//! Model store: named trained models with JSON persistence.

use crate::data::{
    load_all, normalize_features, read_f64_vec, Dataset, F64File, ShardedFile, TileSource,
};
use crate::kernels::{kernel_matrix, Kernel};
use crate::krr::{AdaptiveOptions, SketchedKrr};
use crate::leverage::{exact_scores, stat_dim_from_scores, try_bless, BlessResult};
use crate::linalg::{Matrix, Precision};
use crate::rng::{AliasTable, Pcg64};
use crate::sketch::{Sampling, SketchBuilder, SketchKind};
use crate::util::json::Json;
use crate::util::CodedError;
use std::collections::{HashMap, HashSet};
use std::sync::{Arc, RwLock};

/// A trained model plus the metadata clients query.
#[derive(Clone, Debug)]
pub struct StoredModel {
    /// The predict-ready model.
    pub model: Arc<SketchedKrr>,
    /// Training rows used.
    pub n_train: usize,
    /// Wall-clock training seconds.
    pub train_secs: f64,
    /// Sketch descriptor (`accum_m4`, `nystrom`, …).
    pub sketch: String,
    /// In-sample MSE at train time.
    pub train_mse: f64,
    /// Row-sampling scheme the sketch was drawn with
    /// (`uniform` | `leverage` | `poisson`).
    pub sampling: String,
    /// Statistical dimension `Σᵢ ℓᵢ` of the leverage profile used
    /// (0 for uniform sampling — no profile was estimated).
    pub d_stat: f64,
}

/// Row-sampling scheme for the sketch draw — the coordinator-level knob
/// over [`Sampling`]: `uniform` is the classical accumulation draw,
/// `leverage` feeds ridge-leverage scores (exact for small `n`,
/// [`bless`](crate::leverage::bless) beyond) into the per-term draw
/// probabilities, `poisson`
/// turns the same profile into independent per-row inclusion
/// (Nyström-shaped, one-shot).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SamplingSpec {
    /// Uniform row draws — bit-identical to the pre-knob coordinator.
    #[default]
    Uniform,
    /// Leverage-weighted draws (exact scores for `n ≤ 512`, BLESS above).
    Leverage,
    /// Poisson inclusion with leverage-informed `πᵢ = min(1, d·pᵢ)`.
    Poisson,
}

impl SamplingSpec {
    /// Parse the wire/CLI name.
    pub fn parse(name: &str) -> Result<SamplingSpec, String> {
        match name {
            "uniform" => Ok(SamplingSpec::Uniform),
            "leverage" => Ok(SamplingSpec::Leverage),
            "poisson" => Ok(SamplingSpec::Poisson),
            other => Err(format!("unknown sampling {other:?} (uniform|leverage|poisson)")),
        }
    }

    /// Wire/CLI name (inverse of [`parse`](Self::parse)).
    pub fn name(&self) -> &'static str {
        match self {
            SamplingSpec::Uniform => "uniform",
            SamplingSpec::Leverage => "leverage",
            SamplingSpec::Poisson => "poisson",
        }
    }
}

/// Out-of-core dataset reference carried by `train`/`cluster` requests:
/// instead of naming a generator, the client points at feature rows
/// already on disk in one of the [`TileSource`] storage formats
/// (DESIGN.md §12). The whole job then streams `tile×p` panels off the
/// file — `X` is never fully resident — and produces results bitwise
/// identical to the same rows trained in memory.
#[derive(Clone, Debug, PartialEq)]
pub struct DataSpec {
    /// Backend: `file` (one little-endian f64 row-major file, opened as
    /// [`F64File`]) or `shards` (a directory with a `manifest.json`,
    /// opened as [`ShardedFile`]).
    pub kind: String,
    /// Path of the file (kind `file`) or shard directory (kind `shards`).
    pub path: String,
    /// Features per row. Required for `file` (the flat file carries no
    /// geometry); ignored for `shards` (the manifest records it).
    pub dim: usize,
    /// Optional little-endian f64 file of training targets, length `n`.
    /// Required by `train` jobs, unused by `cluster`.
    pub y_path: Option<String>,
}

impl DataSpec {
    /// Open the referenced backend. Malformed specs (unknown kind, bad
    /// path, geometry mismatch) are `invalid_input` protocol errors.
    pub fn open(&self) -> Result<Box<dyn TileSource>, CodedError> {
        match self.kind.as_str() {
            "file" => Ok(Box::new(F64File::open(&self.path, self.dim)?)),
            "shards" => Ok(Box::new(ShardedFile::open(&self.path)?)),
            other => Err(CodedError::invalid_input(format!(
                "data: unknown kind {other:?} (file|shards)"
            ))),
        }
    }
}

/// Parse the optional `data` object of a train/cluster request body:
/// `{"kind": "file"|"shards", "path": ..., "dim": p, "y": ...}`.
/// Shared by the TCP ops and the CLI so both surfaces accept identical
/// specs. Absent field → `Ok(None)` (the request names a dataset
/// instead).
pub fn parse_data_spec(j: &Json) -> Result<Option<DataSpec>, String> {
    let Some(obj) = j.get("data") else {
        return Ok(None);
    };
    let kind = obj
        .get("kind")
        .and_then(|v| v.as_str())
        .ok_or("data.kind missing (file|shards)")?
        .to_string();
    let path = obj
        .get("path")
        .and_then(|v| v.as_str())
        .ok_or("data.path missing")?
        .to_string();
    let dim = obj.get("dim").and_then(|v| v.as_usize()).unwrap_or(0);
    let y_path = obj.get("y").and_then(|v| v.as_str()).map(str::to_string);
    Ok(Some(DataSpec {
        kind,
        path,
        dim,
        y_path,
    }))
}

/// Parameters of a `train` request (server op or CLI).
#[derive(Clone, Debug)]
pub struct TrainRequest {
    /// Model name to store under.
    pub name: String,
    /// Dataset: `rqa` / `casp` / `gas` / `bimodal`.
    pub dataset: String,
    /// Rows to train on.
    pub n: usize,
    /// Sketch kind.
    pub kind: SketchKind,
    /// Projection dimension (0 → paper schedule `⌊1.5·n^{dX/(3+2dX)}⌋`).
    pub d: usize,
    /// Ridge λ (0 → paper schedule `0.9·n^{−(3+dX)/(3+2dX)}`).
    pub lambda: f64,
    /// Kernel bandwidth.
    pub bandwidth: f64,
    /// RNG seed.
    pub seed: u64,
    /// Adaptive-m training: grow the accumulation sketch until the
    /// stopping rule fires instead of building `kind` with a fixed `m`
    /// (the kind's sampling distribution still applies). The chosen `m`
    /// is reported through the stored model's
    /// [`SketchedKrrReport`](crate::krr::SketchedKrrReport).
    pub adaptive: Option<AdaptiveOptions>,
    /// Gram-accumulation precision for one-shot fits (`F32` assembles and
    /// accumulates the sketched Grams in single precision; all `d×d`
    /// solves stay f64). Ignored by adaptive training, which is
    /// f64-only — its incremental rank-update identities assume exact
    /// f64 Grams.
    pub precision: Precision,
    /// Row-sampling scheme (`uniform` keeps the draw stream bit-identical
    /// to requests made before the knob existed — the leverage estimator
    /// runs on a *derived* RNG, never the sketch RNG).
    pub sampling: SamplingSpec,
    /// Out-of-core dataset reference. When set, `dataset`/`n` are ignored
    /// and the job streams X off disk (a `y` target file is required);
    /// the kernel is Matérn-3/2 at `bandwidth` (default 1.0), matching
    /// the CSV fallback of [`dataset_for`].
    pub data: Option<DataSpec>,
}

/// Shards in the model registry. Power of two; 16 is plenty — the shard
/// count only needs to exceed the number of threads that might touch
/// the store at once (batcher + task-pool workers).
const STORE_SHARDS: usize = 16;

/// Thread-safe named model registry, sharded by name hash so the
/// batcher's per-group `get` on the serving hot path never contends
/// with a concurrent `train` writing a different model.
///
/// Every lock access is poison-tolerant (`into_inner` on a poisoned
/// guard): a panic elsewhere while a guard was held must not cascade
/// into killing every thread that later touches the store — the store's
/// invariant is per-entry (a `StoredModel` is immutable once inserted),
/// so a poisoned lock carries no torn state.
pub struct ModelStore {
    shards: Vec<RwLock<HashMap<String, StoredModel>>>,
    /// Names quarantined after a worker panic during train/predict —
    /// requests against them answer `model_unhealthy` instead of
    /// retry-and-panic loops. A successful retrain (or re-`put`) heals.
    quarantined: RwLock<HashSet<String>>,
}

impl Default for ModelStore {
    fn default() -> Self {
        ModelStore {
            shards: (0..STORE_SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
            quarantined: RwLock::new(HashSet::new()),
        }
    }
}

/// FNV-1a over the model name — tiny, deterministic, no `RandomState`
/// allocation per lookup.
fn shard_of(name: &str) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h as usize) & (STORE_SHARDS - 1)
}

impl ModelStore {
    /// Empty store.
    pub fn new() -> ModelStore {
        ModelStore::default()
    }

    /// Insert/replace a model. Storing a model heals any standing
    /// quarantine on the name — whatever is now in the slot is freshly
    /// trained and healthy.
    pub fn put(&self, name: &str, m: StoredModel) {
        self.shards[shard_of(name)]
            .write()
            .unwrap_or_else(|e| e.into_inner())
            .insert(name.to_string(), m);
        self.heal(name);
    }

    /// Fetch a model by name.
    pub fn get(&self, name: &str) -> Option<StoredModel> {
        self.shards[shard_of(name)]
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .get(name)
            .cloned()
    }

    /// Quarantine a name after a worker panic touched its model: until a
    /// retrain heals it, requests answer `model_unhealthy`.
    pub fn quarantine(&self, name: &str) {
        self.quarantined
            .write()
            .unwrap_or_else(|e| e.into_inner())
            .insert(name.to_string());
    }

    /// Lift a quarantine (successful retrain / re-`put`).
    pub fn heal(&self, name: &str) {
        self.quarantined
            .write()
            .unwrap_or_else(|e| e.into_inner())
            .remove(name);
    }

    /// Is the name currently quarantined?
    pub fn is_quarantined(&self, name: &str) -> bool {
        self.quarantined
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .contains(name)
    }

    /// Names + summary metadata of all models (sorted by name — shard
    /// order is hash order, clients expect something stable).
    pub fn list(&self) -> Vec<(String, usize, f64, String)> {
        let mut out: Vec<(String, usize, f64, String)> = self
            .shards
            .iter()
            .flat_map(|s| {
                s.read()
                    .unwrap_or_else(|e| e.into_inner())
                    .iter()
                    .map(|(k, v)| (k.clone(), v.n_train, v.train_secs, v.sketch.clone()))
                    .collect::<Vec<_>>()
            })
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Train a model per the request and store it. Returns the stored
    /// metadata. This is the coordinator's end-to-end training path.
    /// Malformed requests come back as `invalid_input`; solver failures
    /// past the jitter ladder as `numeric_failure` — never a panic.
    pub fn train(&self, req: &TrainRequest) -> Result<StoredModel, CodedError> {
        validate_train_request(req)?;
        let mut rng = Pcg64::seed(req.seed);
        // Resolve the training rows: a named/generated dataset (features
        // normalized, fully resident) or an out-of-core `data` spec,
        // where X stays on disk and every Gram pass streams row tiles
        // through the [`TileSource`] (DESIGN.md §12). File-backed rows
        // are consumed as stored — writers pre-normalize.
        let (src, y, dx, kernel): (Box<dyn TileSource>, Vec<f64>, usize, Kernel) =
            if let Some(spec) = &req.data {
                let src = spec.open()?;
                let y_path = spec.y_path.as_deref().ok_or_else(|| {
                    CodedError::invalid_input("train: data spec needs a y target file")
                })?;
                let y = read_f64_vec(y_path)?;
                if y.len() != src.rows() {
                    return Err(CodedError::invalid_input(format!(
                        "train: y file has {} targets but data has {} rows",
                        y.len(),
                        src.rows()
                    )));
                }
                let dx = src.dim();
                let bw = if req.bandwidth > 0.0 { req.bandwidth } else { 1.0 };
                (src, y, dx, Kernel::matern(1.5, bw))
            } else {
                let (mut ds, dx, kernel) =
                    dataset_for(&req.dataset, req.n, req.bandwidth, &mut rng)
                        .map_err(CodedError::invalid_input)?;
                normalize_features(&mut ds.x);
                (Box::new(ds.x), ds.y, dx, kernel)
            };
        let x: &dyn TileSource = src.as_ref();
        let n = x.rows();
        if n == 0 {
            return Err(CodedError::invalid_input("train: dataset has no rows"));
        }
        let d = if req.d > 0 {
            req.d
        } else {
            paper_d(n, dx)
        };
        if d > n {
            return Err(CodedError::invalid_input(format!(
                "train: d={d} exceeds n={n} training rows"
            )));
        }
        let lambda = if req.lambda > 0.0 {
            req.lambda
        } else {
            paper_lambda(n, dx)
        };
        let t = crate::util::Timer::start();
        // Informed sampling: resolve the per-row probability profile
        // *before* any sketch draw, on a derived RNG — the sketch RNG
        // stream is untouched, so a uniform request trains a model
        // bit-identical to the pre-knob coordinator.
        let (sampling, warm, mut d_stat) = resolve_sampling(req, &kernel, x, d, lambda)?;
        let (model, sketch_name) = if let Some(aopts) = &req.adaptive {
            let builder = SketchBuilder::new(req.kind.clone()).with_sampling(sampling);
            let (model, _trace) = SketchedKrr::try_fit_adaptive_warm(
                kernel, x, &y, &builder, d, lambda, aopts, &mut rng, warm.as_ref(),
            )?
            .ok_or_else(|| CodedError::numeric("adaptive sketched fit failed (singular system)"))?;
            // between-term refinement estimates its own profile mid-fit;
            // that estimate supersedes any draw-time one
            if model.report().refine_round > 0 {
                d_stat = model.report().d_stat;
            }
            let name = match req.sampling {
                SamplingSpec::Uniform => format!("adaptive_m{}", model.report().m),
                _ => format!("adaptive_lev_m{}", model.report().m),
            };
            (model, name)
        } else {
            let sketch = SketchBuilder::new(req.kind.clone())
                .with_sampling(sampling)
                .build(n, d, &mut rng);
            let model =
                SketchedKrr::try_fit_with(kernel, x, &y, &sketch, lambda, None, req.precision)?
                    .ok_or_else(|| CodedError::numeric("sketched fit failed (singular system)"))?;
            let name = match req.sampling {
                SamplingSpec::Uniform => req.kind.name(),
                SamplingSpec::Leverage => format!("{}_lev", req.kind.name()),
                SamplingSpec::Poisson => "poisson".to_string(),
            };
            (model, name)
        };
        let train_secs = t.secs();
        let train_mse = crate::stats::mse(model.fitted(), &y);
        let stored = StoredModel {
            model: Arc::new(model),
            n_train: n,
            train_secs,
            sketch: sketch_name,
            train_mse,
            sampling: req.sampling.name().to_string(),
            d_stat,
        };
        self.put(&req.name, stored.clone());
        Ok(stored)
    }
}

/// Largest `n` for which leverage scores come from the exact `O(n³)`
/// ridge identity; beyond it the streaming BLESS estimator takes over
/// (never assembling `n×n`).
const EXACT_LEVERAGE_N: usize = 512;

/// Salt XORed into the request seed for the leverage estimator's derived
/// RNG, keeping the sketch draw stream independent of whether (and how)
/// a profile was estimated.
const LEVERAGE_SEED_SALT: u64 = 0x1e7e_4a9e_5eed_0b1e;

/// Resolve a [`SamplingSpec`] into the concrete [`Sampling`] distribution
/// plus (for BLESS) the warm-start landmark panel and the profile's
/// statistical dimension. Uniform costs nothing and touches no RNG.
fn resolve_sampling(
    req: &TrainRequest,
    kernel: &Kernel,
    x: &dyn TileSource,
    d: usize,
    lambda: f64,
) -> Result<(Sampling, Option<BlessResult>, f64), CodedError> {
    if req.sampling == SamplingSpec::Uniform {
        return Ok((Sampling::Uniform, None, 0.0));
    }
    let n = x.rows();
    let (table, warm, d_stat) = if n <= EXACT_LEVERAGE_N {
        // small n: materialise the rows once — the exact identity needs
        // the full n×n kernel matrix anyway
        let xm = load_all(x)?;
        let scores = exact_scores(&kernel_matrix(kernel, &xm), lambda);
        let ds = stat_dim_from_scores(&scores);
        (AliasTable::new(&scores), None, ds)
    } else {
        let mut lrng = Pcg64::seed(req.seed ^ LEVERAGE_SEED_SALT);
        let b = try_bless(kernel, x, lambda, d, 2.0, &mut lrng)?;
        let ds = stat_dim_from_scores(&b.scores);
        (b.sampling_table(), Some(b), ds)
    };
    let sampling = match req.sampling {
        SamplingSpec::Uniform => unreachable!("handled above"),
        SamplingSpec::Leverage => Sampling::Weighted(table),
        SamplingSpec::Poisson => Sampling::Poisson(table),
    };
    Ok((sampling, warm, d_stat))
}

/// Bounds-check a train request before any compute is spent — every
/// rejection here is an `invalid_input`, never a worker-killing panic.
fn validate_train_request(req: &TrainRequest) -> Result<(), CodedError> {
    if req.name.is_empty() {
        return Err(CodedError::invalid_input("train: model name is empty"));
    }
    // with an out-of-core data spec the row count comes from the file,
    // not the request
    if req.n == 0 && req.data.is_none() {
        return Err(CodedError::invalid_input("train: n must be >= 1"));
    }
    if !req.lambda.is_finite() || req.lambda < 0.0 {
        return Err(CodedError::invalid_input(format!(
            "train: lambda must be finite and >= 0, got {}",
            req.lambda
        )));
    }
    if !req.bandwidth.is_finite() || req.bandwidth < 0.0 {
        return Err(CodedError::invalid_input(format!(
            "train: bandwidth must be finite and >= 0, got {}",
            req.bandwidth
        )));
    }
    // Poisson is a one-shot per-row inclusion scheme: it has no notion of
    // accumulated terms, so it composes with the Nyström shape only and
    // never with adaptive-m growth
    if req.sampling == SamplingSpec::Poisson {
        if req.adaptive.is_some() {
            return Err(CodedError::invalid_input(
                "train: poisson sampling is one-shot — it cannot grow adaptively \
                 (use sampling=leverage with the adaptive kind)",
            ));
        }
        if !matches!(req.kind, SketchKind::Nystrom) {
            return Err(CodedError::invalid_input(format!(
                "train: poisson sampling requires the nystrom sketch kind, got {}",
                req.kind.name()
            )));
        }
    }
    // leverage weights only steer row-sampling sketches; the dense
    // projections (gaussian/rademacher/verysparse) ignore a row profile
    if req.sampling == SamplingSpec::Leverage
        && !matches!(
            req.kind,
            SketchKind::Nystrom | SketchKind::Accumulation { .. }
        )
    {
        return Err(CodedError::invalid_input(format!(
            "train: leverage sampling applies to row-sampling sketches \
             (nystrom/accum/adaptive), got {}",
            req.kind.name()
        )));
    }
    Ok(())
}

/// Parse a sketch spec name (`nystrom` | `gaussian` | `rademacher` |
/// `verysparse` | `accum` | `adaptive`) into the kind plus adaptive
/// options. Shared by the TCP `train` op and the CLI so both surfaces
/// train identical models from identical arguments: `m` configures
/// fixed-m accumulation, `m_max`/`rel_tol` the adaptive kind.
pub fn parse_sketch_spec(
    name: &str,
    m: usize,
    m_max: usize,
    rel_tol: f64,
) -> Result<(SketchKind, Option<AdaptiveOptions>), String> {
    match name {
        "nystrom" => Ok((SketchKind::Nystrom, None)),
        "gaussian" => Ok((SketchKind::Gaussian, None)),
        "rademacher" => Ok((SketchKind::Rademacher, None)),
        "verysparse" => Ok((SketchKind::VerySparse { sparsity: None }, None)),
        "accum" => Ok((SketchKind::Accumulation { m: m.max(1) }, None)),
        // the adaptive job kind: m is discovered at runtime, bounded by
        // m_max, with the relative-change stopping tolerance rel_tol
        "adaptive" => Ok((
            SketchKind::Accumulation { m: 1 },
            Some(AdaptiveOptions {
                m_max: m_max.max(1),
                rel_tol,
                ..Default::default()
            }),
        )),
        other => Err(format!("unknown sketch {other:?}")),
    }
}

/// Paper's projection-dimension schedule `⌊1.5·n^{dX/(3+2dX)}⌋` (§4.2/D.3).
pub fn paper_d(n: usize, dx: usize) -> usize {
    ((1.5 * (n as f64).powf(dx as f64 / (3.0 + 2.0 * dx as f64))).floor() as usize).max(2)
}

/// Paper's ridge schedule `0.9·n^{−(3+dX)/(3+2dX)}` (§D.3).
pub fn paper_lambda(n: usize, dx: usize) -> f64 {
    0.9 * (n as f64).powf(-(3.0 + dx as f64) / (3.0 + 2.0 * dx as f64))
}

/// Resolve a dataset name into data + feature count + default kernel.
pub fn dataset_for(
    name: &str,
    n: usize,
    bandwidth: f64,
    rng: &mut Pcg64,
) -> Result<(Dataset, usize, Kernel), String> {
    let bw = |default: f64| if bandwidth > 0.0 { bandwidth } else { default };
    match name {
        "rqa" => {
            let s = crate::data::rqa_sim(n, rng);
            Ok((Dataset { x: s.x, y: s.y }, 4, Kernel::matern(1.5, bw(1.0))))
        }
        "casp" => {
            let s = crate::data::casp_sim(n, rng);
            Ok((Dataset { x: s.x, y: s.y }, 9, Kernel::matern(1.5, bw(1.0))))
        }
        "gas" => {
            let s = crate::data::gas_sim(n, rng);
            Ok((Dataset { x: s.x, y: s.y }, 10, Kernel::matern(1.5, bw(1.0))))
        }
        "bimodal" => {
            let cfg = crate::data::BimodalConfig {
                n,
                ..Default::default()
            };
            let (x, y, _) = crate::data::bimodal(&cfg, rng);
            // paper Fig. 2: Gaussian kernel, bw = 1.5 n^{-1/7}
            Ok((
                Dataset { x, y },
                3,
                Kernel::gaussian(bw(1.5 * (n as f64).powf(-1.0 / 7.0))),
            ))
        }
        other => {
            // fall back to a CSV file path (real UCI data dropped in)
            if std::path::Path::new(other).exists() {
                let mut ds = crate::data::load_csv_dataset(other, true)?;
                ds.shuffle(rng);
                let ds = ds.head(n);
                let dx = ds.x.cols();
                Ok((ds, dx, Kernel::matern(1.5, bw(1.0))))
            } else {
                Err(format!("unknown dataset {other:?}"))
            }
        }
    }
}

/// Parameters of a `cluster` job (server op or CLI) — the
/// spectral-clustering workload (`crate::cluster`) as a coordinator job
/// kind. Stateless: unlike `train`, nothing is stored, the reply carries
/// the labels.
#[derive(Clone, Debug)]
pub struct ClusterRequest {
    /// Dataset: the labelled generators `blobs` / `moons` / `rings`
    /// (ARI against the ground truth is reported) or any regression
    /// dataset name/CSV accepted by [`dataset_for`] (features only).
    pub dataset: String,
    /// Number of points.
    pub n: usize,
    /// Number of clusters (ignored when `k_max` triggers a sweep).
    pub k: usize,
    /// When ≥ 2: embed once at `k_max + 1` dimensions, run the per-k
    /// k-means sweep over `k ∈ 2..=k_max` through the
    /// [`JobScheduler`](super::jobs::JobScheduler), and pick `k` by the
    /// largest Laplacian eigengap.
    pub k_max: usize,
    /// Embedding route: `operator` | `sketched` | `adaptive`.
    pub method: String,
    /// Sketch width (0 → `max(4k, 32)` capped at `n`).
    pub d: usize,
    /// Accumulated terms for `sketched`.
    pub m: usize,
    /// Term cap for `adaptive`.
    pub m_max: usize,
    /// Subspace-change stopping tolerance for `adaptive`.
    pub rel_tol: f64,
    /// Kernel bandwidth (0 → per-dataset default).
    pub bandwidth: f64,
    /// RNG seed (data generation + sketch draws).
    pub seed: u64,
    /// Out-of-core dataset reference. When set, `dataset`/`n` are ignored
    /// and the whole spectral fit streams X off disk with a Gaussian
    /// kernel at `bandwidth` (default 1.5); no ground truth is known, so
    /// the reply carries no `ari_vs_truth`.
    pub data: Option<DataSpec>,
}

impl Default for ClusterRequest {
    fn default() -> Self {
        ClusterRequest {
            dataset: "blobs".into(),
            n: 600,
            k: 2,
            k_max: 0,
            method: "operator".into(),
            d: 0,
            m: 4,
            m_max: 16,
            rel_tol: 5e-2,
            bandwidth: 0.0,
            seed: 1,
            data: None,
        }
    }
}

/// Resolve a clustering dataset: `(features, ground truth if known,
/// default kernel)`. The labelled 2-D generators get clustering-tuned
/// bandwidth defaults; any other name falls through to [`dataset_for`]
/// (features only, normalized like the training path).
pub fn cluster_dataset_for(
    name: &str,
    n: usize,
    k: usize,
    bandwidth: f64,
    rng: &mut Pcg64,
) -> Result<(Matrix, Option<Vec<usize>>, Kernel), String> {
    let bw = |default: f64| if bandwidth > 0.0 { bandwidth } else { default };
    match name {
        "blobs" => {
            let (x, t) = crate::data::blobs(n, k.max(2), 6.0, 0.3, rng);
            Ok((x, Some(t), Kernel::gaussian(bw(1.5))))
        }
        "moons" => {
            // bandwidth must sit below the ≈0.3 inter-moon gap: 0.15
            // cleanly separates (ARI 1.0); 0.25 already bridges the moons
            let (x, t) = crate::data::two_moons(n, 0.06, rng);
            Ok((x, Some(t), Kernel::gaussian(bw(0.15))))
        }
        "rings" => {
            let radii = [0.5, 2.0, 3.5];
            let kk = k.clamp(2, radii.len());
            let (x, t) = crate::data::rings(n, &radii[..kk], 0.05, rng);
            Ok((x, Some(t), Kernel::gaussian(bw(0.35))))
        }
        other => {
            let (mut ds, _, kern) = dataset_for(other, n, bandwidth, rng)?;
            crate::data::normalize_features(&mut ds.x);
            Ok((ds.x, None, kern))
        }
    }
}

/// Parse a `cluster` method spec into an embedding route. Shared by the
/// TCP op and the CLI, like [`parse_sketch_spec`].
pub fn parse_cluster_method(
    name: &str,
    d: usize,
    m: usize,
    m_max: usize,
    rel_tol: f64,
) -> Result<crate::cluster::EmbedMethod, String> {
    use crate::cluster::EmbedMethod;
    match name {
        "operator" => Ok(EmbedMethod::Operator),
        "sketched" => Ok(EmbedMethod::Sketched { d, m: m.max(1) }),
        "adaptive" => Ok(EmbedMethod::Adaptive {
            d,
            m_max: m_max.max(1),
            rel_tol,
        }),
        other => Err(format!("unknown cluster method {other:?}")),
    }
}

/// Run a `cluster` job end to end: generate the dataset, fit the
/// spectral clustering (`k_max` ≥ 2 additionally embeds at `k_max + 1`,
/// fans the per-k k-means sweep out through the
/// [`JobScheduler`](super::jobs::JobScheduler), and picks `k` at the
/// largest eigengap), and encode the JSON reply documented in the
/// `coordinator` module docs.
pub fn run_cluster_job(req: &ClusterRequest) -> Result<Json, CodedError> {
    use crate::cluster::{
        adjusted_rand_index, cluster_sizes, lloyd_kmeans, row_normalize, SpectralClustering,
        SpectralOptions,
    };
    if req.n == 0 && req.data.is_none() {
        return Err(CodedError::invalid_input("cluster: n must be >= 1"));
    }
    if !req.bandwidth.is_finite() || req.bandwidth < 0.0 {
        return Err(CodedError::invalid_input(format!(
            "cluster: bandwidth must be finite and >= 0, got {}",
            req.bandwidth
        )));
    }
    let sweep = req.k_max >= 2;
    let fit_k = if sweep { 2 } else { req.k };
    let mut rng = Pcg64::seed(req.seed);
    // data generation always uses the requested k (the "true" cluster
    // count for labelled generators); k_max only bounds the search
    let gen_k = req.k.max(2);
    // an out-of-core `data` spec clusters rows already on disk: the fit
    // streams tiles through the TileSource (DESIGN.md §12), bitwise
    // identical to the same rows clustered in memory
    let (x, truth, kernel): (Box<dyn TileSource>, Option<Vec<usize>>, Kernel) =
        if let Some(spec) = &req.data {
            let bw = if req.bandwidth > 0.0 { req.bandwidth } else { 1.5 };
            (spec.open()?, None, Kernel::gaussian(bw))
        } else {
            let (x, truth, kernel) =
                cluster_dataset_for(&req.dataset, req.n, gen_k, req.bandwidth, &mut rng)
                    .map_err(CodedError::invalid_input)?;
            (Box::new(x), truth, kernel)
        };
    // validate against the *actual* row count — CSV datasets may hold
    // fewer rows than requested (dataset_for truncates), file-backed
    // sources carry their own count, and a bad k or k_max must surface
    // as a protocol error, not a panic that kills the connection thread
    let n = x.rows();
    if fit_k < 1 || fit_k > n {
        return Err(CodedError::invalid_input(format!(
            "cluster: need 1 <= k <= n, got k={fit_k} n={n}"
        )));
    }
    if sweep && req.k_max > n {
        return Err(CodedError::invalid_input(format!(
            "cluster: k_max {} exceeds n={n}",
            req.k_max
        )));
    }
    let embed_dim = if sweep { (req.k_max + 1).min(n) } else { 0 };
    let want_r = if sweep { embed_dim } else { fit_k };
    let d = if req.d > 0 {
        req.d.max(want_r).min(n)
    } else {
        crate::cluster::default_sketch_width(gen_k, want_r, n)
    };
    let method = parse_cluster_method(&req.method, d, req.m, req.m_max, req.rel_tol)
        .map_err(CodedError::invalid_input)?;
    let opts = SpectralOptions {
        k: fit_k,
        embed_dim,
        method,
        // the job's labels always come from the explicit rounding below
        // (uniform across sweep and fixed-k paths), so the fit's own
        // k-means is capped at a single pass instead of a full solve
        kmeans_iters: 1,
        ..Default::default()
    };
    let t = crate::util::Timer::start();
    let fit = SpectralClustering::fit(kernel, x.as_ref(), &opts, &mut rng)
        .ok_or_else(|| CodedError::numeric("cluster: sketched pencil factorisation failed"))?;
    // model selection: per-k Lloyd sweep through the job scheduler +
    // eigengap choice on the bottom Laplacian spectrum
    let (final_k, sweep_rows) = if sweep {
        let sched = super::jobs::JobScheduler::new(req.seed);
        let emb = &fit.embedding;
        let per_k = sched.run_sweep(req.k_max - 1, 1, |pt, _rng| {
            let kk = pt.setting + 2;
            let pts = row_normalize(emb, kk.min(emb.cols()));
            let km = lloyd_kmeans(&pts, kk, 100);
            (kk, km.inertia)
        });
        let ev = &fit.eigenvalues;
        let mut best = (f64::NEG_INFINITY, 2usize);
        let mut rows = Vec::new();
        for group in &per_k {
            let (kk, inertia) = group[0];
            // eigengap λ_{k+1} − λ_k (0-based: ev[kk] − ev[kk−1])
            let gap = if kk < ev.len() {
                ev[kk] - ev[kk - 1]
            } else {
                0.0
            };
            if gap > best.0 {
                best = (gap, kk);
            }
            rows.push(Json::obj(vec![
                ("k", Json::from(kk)),
                ("inertia", Json::Num(inertia)),
                ("eigengap", Json::Num(gap)),
            ]));
        }
        (best.1, Some(rows))
    } else {
        (fit_k, None)
    };
    let pts = row_normalize(&fit.embedding, final_k.min(fit.embedding.cols()));
    let km = lloyd_kmeans(&pts, final_k, 100);
    let secs = t.secs();
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("dataset", Json::Str(req.dataset.clone())),
        ("n", Json::from(n)),
        ("k", Json::from(final_k)),
        ("method", Json::Str(req.method.clone())),
        ("secs", Json::Num(secs)),
        ("inertia", Json::Num(km.inertia)),
        ("eigenvalues", Json::nums(&fit.eigenvalues)),
        (
            "sizes",
            Json::Arr(
                cluster_sizes(&km.labels, final_k)
                    .into_iter()
                    .map(Json::from)
                    .collect(),
            ),
        ),
        (
            "labels",
            Json::Arr(km.labels.iter().map(|&l| Json::from(l)).collect()),
        ),
    ];
    if let Some(m) = fit.chosen_m {
        fields.push(("chosen_m", Json::from(m)));
    }
    if let Some(t) = &truth {
        fields.push(("ari_vs_truth", Json::Num(adjusted_rand_index(&km.labels, t))));
    }
    if let Some(rows) = sweep_rows {
        fields.push(("sweep", Json::Arr(rows)));
    }
    Ok(Json::obj(fields))
}

/// Serialise a model (landmarks + β + kernel) to JSON for persistence.
pub fn model_to_json(m: &SketchedKrr) -> Json {
    let l = m.landmarks();
    Json::obj(vec![
        ("kernel", Json::from(m.kernel().name())),
        ("bandwidth", Json::Num(m.kernel().bandwidth)),
        ("rows", Json::from(l.rows())),
        ("cols", Json::from(l.cols())),
        ("landmarks", Json::nums(l.data())),
        ("beta", Json::nums(m.beta())),
    ])
}

/// Rebuild a predict-only model from [`model_to_json`] output.
pub fn model_from_json(j: &Json) -> Result<SketchedKrr, String> {
    let name = j.get("kernel").and_then(|v| v.as_str()).ok_or("missing kernel")?;
    let bw = j.get("bandwidth").and_then(|v| v.as_f64()).ok_or("missing bandwidth")?;
    let kernel = match name {
        "gaussian" => Kernel::gaussian(bw),
        "matern12" => Kernel::matern(0.5, bw),
        "matern32" => Kernel::matern(1.5, bw),
        "matern52" => Kernel::matern(2.5, bw),
        "laplacian" => Kernel::laplacian(bw),
        other => return Err(format!("unknown kernel {other}")),
    };
    let rows = j.get("rows").and_then(|v| v.as_usize()).ok_or("rows")?;
    let cols = j.get("cols").and_then(|v| v.as_usize()).ok_or("cols")?;
    let land: Vec<f64> = j
        .get("landmarks")
        .and_then(|v| v.as_arr())
        .ok_or("landmarks")?
        .iter()
        .filter_map(|x| x.as_f64())
        .collect();
    let beta: Vec<f64> = j
        .get("beta")
        .and_then(|v| v.as_arr())
        .ok_or("beta")?
        .iter()
        .filter_map(|x| x.as_f64())
        .collect();
    if land.len() != rows * cols || beta.len() != rows {
        return Err("model json: size mismatch".into());
    }
    Ok(SketchedKrr::from_parts(
        kernel,
        Matrix::from_vec(rows, cols, land),
        beta,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn train_and_fetch() {
        let store = ModelStore::new();
        let req = TrainRequest {
            name: "m1".into(),
            dataset: "bimodal".into(),
            n: 200,
            kind: SketchKind::Accumulation { m: 4 },
            d: 12,
            lambda: 1e-3,
            bandwidth: 0.0,
            seed: 3,
            adaptive: None,
            precision: Precision::F64,
            sampling: SamplingSpec::Uniform,
            data: None,
        };
        let meta = store.train(&req).unwrap();
        assert_eq!(meta.n_train, 200);
        assert!(meta.train_mse.is_finite());
        let got = store.get("m1").unwrap();
        assert_eq!(got.sketch, "accum_m4");
        assert_eq!(store.list().len(), 1);
    }

    #[test]
    fn adaptive_train_reports_chosen_m() {
        let store = ModelStore::new();
        let req = TrainRequest {
            name: "ad".into(),
            dataset: "bimodal".into(),
            n: 200,
            kind: SketchKind::Accumulation { m: 1 },
            d: 12,
            lambda: 1e-3,
            bandwidth: 0.0,
            seed: 4,
            adaptive: Some(AdaptiveOptions {
                m_max: 16,
                rel_tol: 0.05,
                ..Default::default()
            }),
            precision: Precision::F64,
            sampling: SamplingSpec::Uniform,
            data: None,
        };
        let meta = store.train(&req).unwrap();
        let rep = *meta.model.report();
        assert!(rep.m >= 1 && rep.m <= 16, "{rep:?}");
        assert!(rep.rounds >= 1);
        assert_eq!(meta.sketch, format!("adaptive_m{}", rep.m));
        assert!(meta.train_mse.is_finite());
    }

    #[test]
    fn leverage_sampling_trains_and_reports_d_stat() {
        let store = ModelStore::new();
        let req = TrainRequest {
            name: "lev".into(),
            dataset: "bimodal".into(),
            n: 200,
            kind: SketchKind::Accumulation { m: 4 },
            d: 12,
            lambda: 1e-3,
            bandwidth: 0.0,
            seed: 5,
            adaptive: None,
            precision: Precision::F64,
            sampling: SamplingSpec::Leverage,
            data: None,
        };
        let meta = store.train(&req).unwrap();
        assert_eq!(meta.sketch, "accum_m4_lev");
        assert_eq!(meta.sampling, "leverage");
        // n = 200 ≤ 512 → exact ridge-leverage profile; its stat dim is
        // positive and bounded by n
        assert!(meta.d_stat > 0.0 && meta.d_stat <= 200.0, "{}", meta.d_stat);
        assert!(meta.train_mse.is_finite());
    }

    #[test]
    fn poisson_sampling_trains_via_nystrom() {
        let store = ModelStore::new();
        let req = TrainRequest {
            name: "poi".into(),
            dataset: "bimodal".into(),
            n: 150,
            kind: SketchKind::Nystrom,
            d: 10,
            lambda: 1e-3,
            bandwidth: 0.0,
            seed: 6,
            adaptive: None,
            precision: Precision::F64,
            sampling: SamplingSpec::Poisson,
            data: None,
        };
        let meta = store.train(&req).unwrap();
        assert_eq!(meta.sketch, "poisson");
        assert_eq!(meta.sampling, "poisson");
        assert!(meta.d_stat > 0.0);
        assert!(meta.train_mse.is_finite());
    }

    #[test]
    fn incompatible_sampling_combinations_rejected() {
        use crate::util::ErrorKind;
        let store = ModelStore::new();
        let base = TrainRequest {
            name: "x".into(),
            dataset: "bimodal".into(),
            n: 80,
            kind: SketchKind::Nystrom,
            d: 8,
            lambda: 1e-3,
            bandwidth: 0.0,
            seed: 1,
            adaptive: None,
            precision: Precision::F64,
            sampling: SamplingSpec::Poisson,
            data: None,
        };
        let cases = [
            // poisson cannot grow adaptively
            TrainRequest {
                adaptive: Some(AdaptiveOptions::default()),
                ..base.clone()
            },
            // poisson needs the nystrom shape
            TrainRequest {
                kind: SketchKind::Accumulation { m: 4 },
                ..base.clone()
            },
            // leverage weights don't steer dense projections
            TrainRequest {
                kind: SketchKind::Gaussian,
                sampling: SamplingSpec::Leverage,
                ..base.clone()
            },
        ];
        for req in cases {
            let err = store.train(&req).unwrap_err();
            assert_eq!(err.kind, ErrorKind::InvalidInput, "{req:?}: {err}");
        }
        assert!(store.train(&base).is_ok());
    }

    #[test]
    fn adaptive_leverage_with_refinement_reports_profile() {
        let store = ModelStore::new();
        let req = TrainRequest {
            name: "adlev".into(),
            dataset: "bimodal".into(),
            n: 200,
            kind: SketchKind::Accumulation { m: 1 },
            d: 12,
            lambda: 1e-3,
            bandwidth: 0.0,
            seed: 7,
            adaptive: Some(AdaptiveOptions {
                m_max: 8,
                rel_tol: 0.05,
                refine_after_m: 1,
                ..Default::default()
            }),
            precision: Precision::F64,
            sampling: SamplingSpec::Uniform,
            data: None,
        };
        let meta = store.train(&req).unwrap();
        let rep = *meta.model.report();
        // started uniform, refined between terms (unless the rule fired
        // after a single term — rel_tol 0.05 with m_max 8 never does)
        assert!(rep.refine_round > 0, "{rep:?}");
        assert!(meta.d_stat > 0.0);
        assert_eq!(meta.sampling, "uniform");
        assert!(meta.sketch.starts_with("adaptive_m"), "{}", meta.sketch);
    }

    #[test]
    fn sampling_spec_parse_roundtrip() {
        for s in [SamplingSpec::Uniform, SamplingSpec::Leverage, SamplingSpec::Poisson] {
            assert_eq!(SamplingSpec::parse(s.name()), Ok(s));
        }
        assert!(SamplingSpec::parse("lev").is_err());
        assert_eq!(SamplingSpec::default(), SamplingSpec::Uniform);
    }

    #[test]
    fn sketch_spec_parsing_shared_by_cli_and_server() {
        let (k, a) = parse_sketch_spec("accum", 6, 64, 1e-3).unwrap();
        assert_eq!(k, SketchKind::Accumulation { m: 6 });
        assert!(a.is_none());
        let (k, a) = parse_sketch_spec("adaptive", 4, 32, 0.01).unwrap();
        assert_eq!(k, SketchKind::Accumulation { m: 1 });
        let a = a.unwrap();
        assert_eq!(a.m_max, 32);
        assert!((a.rel_tol - 0.01).abs() < 1e-15);
        assert!(parse_sketch_spec("nope", 1, 1, 0.0).is_err());
    }

    #[test]
    fn unknown_dataset_rejected() {
        let store = ModelStore::new();
        let req = TrainRequest {
            name: "x".into(),
            dataset: "nope".into(),
            n: 50,
            kind: SketchKind::Nystrom,
            d: 5,
            lambda: 1e-2,
            bandwidth: 0.0,
            seed: 1,
            adaptive: None,
            precision: Precision::F64,
            sampling: SamplingSpec::Uniform,
            data: None,
        };
        let err = store.train(&req).unwrap_err();
        assert_eq!(err.kind, crate::util::ErrorKind::InvalidInput);
    }

    /// Every malformed train request classifies as `invalid_input` —
    /// the taxonomy contract for the serving boundary.
    #[test]
    fn malformed_train_requests_classify_as_invalid_input() {
        use crate::util::ErrorKind;
        let store = ModelStore::new();
        let base = TrainRequest {
            name: "x".into(),
            dataset: "bimodal".into(),
            n: 50,
            kind: SketchKind::Nystrom,
            d: 5,
            lambda: 1e-2,
            bandwidth: 0.0,
            seed: 1,
            adaptive: None,
            precision: Precision::F64,
            sampling: SamplingSpec::Uniform,
            data: None,
        };
        let cases = [
            TrainRequest { name: "".into(), ..base.clone() },
            TrainRequest { n: 0, ..base.clone() },
            TrainRequest { lambda: f64::NAN, ..base.clone() },
            TrainRequest { lambda: -1.0, ..base.clone() },
            TrainRequest { bandwidth: f64::INFINITY, ..base.clone() },
            TrainRequest { d: 5000, ..base.clone() }, // d > n
        ];
        for req in cases {
            let err = store.train(&req).unwrap_err();
            assert_eq!(err.kind, ErrorKind::InvalidInput, "{req:?}: {err}");
        }
        // the base request itself is fine — the cases fail for the
        // mutated field, not something latent in the fixture
        assert!(store.train(&base).is_ok());
    }

    #[test]
    fn quarantine_blocks_until_retrain_heals() {
        let store = ModelStore::new();
        let req = TrainRequest {
            name: "q".into(),
            dataset: "bimodal".into(),
            n: 60,
            kind: SketchKind::Nystrom,
            d: 6,
            lambda: 1e-2,
            bandwidth: 0.0,
            seed: 1,
            adaptive: None,
            precision: Precision::F64,
            sampling: SamplingSpec::Uniform,
            data: None,
        };
        store.train(&req).unwrap();
        assert!(!store.is_quarantined("q"));
        store.quarantine("q");
        assert!(store.is_quarantined("q"));
        assert!(!store.is_quarantined("other"), "quarantine is per-name");
        // a successful retrain stores a fresh model and lifts the flag
        store.train(&req).unwrap();
        assert!(!store.is_quarantined("q"));
    }

    #[test]
    fn paper_schedules_match_formulas() {
        // RQA: dX = 4 → d = ⌊1.5·n^{4/11}⌋, λ = 0.9·n^{−7/11}
        assert_eq!(paper_d(15000, 4), (1.5f64 * 15000f64.powf(4.0 / 11.0)) as usize);
        let lam = paper_lambda(15000, 4);
        assert!((lam - 0.9 * 15000f64.powf(-7.0 / 11.0)).abs() < 1e-12);
    }

    #[test]
    fn cluster_job_blobs_end_to_end() {
        let req = ClusterRequest {
            dataset: "blobs".into(),
            n: 90,
            k: 3,
            seed: 7,
            ..Default::default()
        };
        let j = run_cluster_job(&req).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("k").and_then(|v| v.as_usize()), Some(3));
        let labels = j.get("labels").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(labels.len(), 90);
        let sizes = j.get("sizes").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(sizes.len(), 3);
        // well-separated blobs → near-perfect recovery
        let ari = j.get("ari_vs_truth").and_then(|v| v.as_f64()).unwrap();
        assert!(ari >= 0.95, "ARI {ari}");
        assert_eq!(
            j.get("eigenvalues").and_then(|v| v.as_arr()).unwrap().len(),
            3
        );
    }

    #[test]
    fn cluster_job_k_sweep_picks_true_k_by_eigengap() {
        let req = ClusterRequest {
            dataset: "blobs".into(),
            n: 90,
            k: 3,
            k_max: 5,
            seed: 8,
            ..Default::default()
        };
        let j = run_cluster_job(&req).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        // three well-separated blobs → the eigengap sits at k = 3
        assert_eq!(j.get("k").and_then(|v| v.as_usize()), Some(3));
        let sweep = j.get("sweep").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(sweep.len(), 4); // k = 2..=5
        for row in sweep {
            assert!(row.get("inertia").and_then(|v| v.as_f64()).unwrap() >= 0.0);
        }
        // the embedding was wide enough for the gap at k_max
        assert_eq!(
            j.get("eigenvalues").and_then(|v| v.as_arr()).unwrap().len(),
            6
        );
    }

    #[test]
    fn cluster_job_adaptive_reports_chosen_m() {
        let req = ClusterRequest {
            dataset: "blobs".into(),
            n: 90,
            k: 3,
            method: "adaptive".into(),
            m_max: 8,
            seed: 9,
            ..Default::default()
        };
        let j = run_cluster_job(&req).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{j}");
        let m = j.get("chosen_m").and_then(|v| v.as_usize()).unwrap();
        assert!((1..=8).contains(&m), "chosen m {m}");
    }

    #[test]
    fn cluster_method_and_dataset_validation() {
        assert!(parse_cluster_method("nope", 8, 1, 1, 0.1).is_err());
        assert!(parse_cluster_method("operator", 8, 1, 1, 0.1).is_ok());
        let req = ClusterRequest {
            dataset: "no_such_data".into(),
            ..Default::default()
        };
        assert!(run_cluster_job(&req).is_err());
        // oversized k / k_max surface as protocol errors, not panics
        // that would kill a server connection thread
        let req = ClusterRequest {
            dataset: "blobs".into(),
            n: 10,
            k: 30,
            ..Default::default()
        };
        assert!(run_cluster_job(&req).is_err());
        let req = ClusterRequest {
            dataset: "blobs".into(),
            n: 10,
            k: 3,
            k_max: 50,
            ..Default::default()
        };
        assert!(run_cluster_job(&req).is_err());
        // regression datasets are accepted features-only (no ARI field)
        let req = ClusterRequest {
            dataset: "bimodal".into(),
            n: 80,
            k: 2,
            ..Default::default()
        };
        let j = run_cluster_job(&req).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert!(j.get("ari_vs_truth").is_none());
    }

    #[test]
    fn sharded_store_lists_all_models_sorted() {
        let store = ModelStore::new();
        let mut rng = Pcg64::seed(2);
        let x = Matrix::from_fn(20, 2, |_, _| rng.uniform());
        let y: Vec<f64> = (0..20).map(|i| x[(i, 0)]).collect();
        let s = SketchBuilder::new(SketchKind::Nystrom).build(20, 5, &mut rng);
        let m = SketchedKrr::fit(Kernel::gaussian(0.5), &x, &y, &s, 1e-3, None).unwrap();
        let m = Arc::new(m);
        // enough names to land in several different shards
        let names: Vec<String> = (0..40).map(|i| format!("model-{i:02}")).collect();
        for name in &names {
            store.put(
                name,
                StoredModel {
                    model: m.clone(),
                    n_train: 20,
                    train_secs: 0.0,
                    sketch: "nystrom".into(),
                    train_mse: 0.0,
                    sampling: "uniform".into(),
                    d_stat: 0.0,
                },
            );
        }
        for name in &names {
            assert!(store.get(name).is_some(), "missing {name}");
        }
        assert!(store.get("model-99").is_none());
        let listed = store.list();
        assert_eq!(listed.len(), names.len());
        let listed_names: Vec<&str> = listed.iter().map(|t| t.0.as_str()).collect();
        let mut want: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        want.sort();
        assert_eq!(listed_names, want);
        // overwrite goes to the same shard slot, not a duplicate
        store.put(
            &names[0],
            StoredModel {
                model: m.clone(),
                n_train: 21,
                train_secs: 0.0,
                sketch: "nystrom".into(),
                train_mse: 0.0,
                sampling: "uniform".into(),
                d_stat: 0.0,
            },
        );
        assert_eq!(store.get(&names[0]).unwrap().n_train, 21);
        assert_eq!(store.list().len(), names.len());
    }

    #[test]
    fn parse_data_spec_reads_and_rejects() {
        let j = Json::parse(
            r#"{"data":{"kind":"file","path":"/tmp/x.bin","dim":4,"y":"/tmp/y.bin"}}"#,
        )
        .unwrap();
        let spec = parse_data_spec(&j).unwrap().unwrap();
        assert_eq!(spec.kind, "file");
        assert_eq!(spec.path, "/tmp/x.bin");
        assert_eq!(spec.dim, 4);
        assert_eq!(spec.y_path.as_deref(), Some("/tmp/y.bin"));
        // absent field → no spec; missing kind/path → protocol error
        assert_eq!(parse_data_spec(&Json::parse("{}").unwrap()).unwrap(), None);
        assert!(parse_data_spec(&Json::parse(r#"{"data":{"path":"p"}}"#).unwrap()).is_err());
        assert!(parse_data_spec(&Json::parse(r#"{"data":{"kind":"file"}}"#).unwrap()).is_err());
    }

    #[test]
    fn file_backed_train_matches_in_memory_bitwise() {
        use crate::data::{write_f64_file, write_f64_vec};
        let mut drng = Pcg64::seed(0x0dc1);
        let n = 80;
        let x = Matrix::from_fn(n, 3, |_, _| drng.normal());
        let y: Vec<f64> = (0..n).map(|i| (x[(i, 0)] + x[(i, 1)]).sin()).collect();
        let xp = std::env::temp_dir().join("accumkrr_state_train_x.bin");
        let yp = std::env::temp_dir().join("accumkrr_state_train_y.bin");
        write_f64_file(xp.to_str().unwrap(), &x).unwrap();
        write_f64_vec(yp.to_str().unwrap(), &y).unwrap();
        let store = ModelStore::new();
        let req = TrainRequest {
            name: "ooc".into(),
            dataset: String::new(),
            n: 0,
            kind: SketchKind::Accumulation { m: 4 },
            d: 10,
            lambda: 1e-3,
            bandwidth: 0.0,
            seed: 11,
            adaptive: None,
            precision: Precision::F64,
            sampling: SamplingSpec::Uniform,
            data: Some(DataSpec {
                kind: "file".into(),
                path: xp.to_string_lossy().into_owned(),
                dim: 3,
                y_path: Some(yp.to_string_lossy().into_owned()),
            }),
        };
        let meta = store.train(&req).unwrap();
        assert_eq!(meta.n_train, n);
        assert!(meta.train_mse.is_finite());
        // replicate the job in memory: the same seed draws the same
        // sketch, and the streamed file route must land on bitwise the
        // same coefficients
        let mut rng = Pcg64::seed(11);
        let sketch = SketchBuilder::new(SketchKind::Accumulation { m: 4 })
            .with_sampling(Sampling::Uniform)
            .build(n, 10, &mut rng);
        let want = SketchedKrr::fit_with(
            Kernel::matern(1.5, 1.0),
            &x,
            &y,
            &sketch,
            1e-3,
            None,
            Precision::F64,
        )
        .unwrap();
        assert_eq!(meta.model.beta(), want.beta());
        std::fs::remove_file(&xp).ok();
        std::fs::remove_file(&yp).ok();
    }

    #[test]
    fn shard_backed_cluster_job_runs_from_disk() {
        use crate::data::write_shards;
        let mut drng = Pcg64::seed(0x0dc2);
        let n = 90;
        let x = Matrix::from_fn(n, 2, |i, _| {
            let c = if i % 2 == 0 { 4.0 } else { -4.0 };
            c + 0.3 * drng.normal()
        });
        let dir = std::env::temp_dir().join("accumkrr_state_cluster_shards");
        write_shards(dir.to_str().unwrap(), &x, 17).unwrap();
        let req = ClusterRequest {
            k: 2,
            data: Some(DataSpec {
                kind: "shards".into(),
                path: dir.to_string_lossy().into_owned(),
                dim: 0,
                y_path: None,
            }),
            ..Default::default()
        };
        let j = run_cluster_job(&req).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("n").and_then(|v| v.as_usize()), Some(n));
        assert_eq!(
            j.get("labels").and_then(|v| v.as_arr()).map(|a| a.len()),
            Some(n)
        );
        assert!(j.get("ari_vs_truth").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn data_spec_errors_are_protocol_errors() {
        use crate::data::{write_f64_file, write_f64_vec};
        use crate::util::ErrorKind;
        // unknown backend kind
        let bad = DataSpec {
            kind: "mmap".into(),
            path: "x".into(),
            dim: 2,
            y_path: None,
        };
        assert_eq!(bad.open().unwrap_err().kind, ErrorKind::InvalidInput);
        // a train data spec must carry targets, of matching length
        let xp = std::env::temp_dir().join("accumkrr_state_noy_x.bin");
        let yp = std::env::temp_dir().join("accumkrr_state_noy_y.bin");
        let x = Matrix::from_fn(6, 2, |i, j| (i * 2 + j) as f64);
        write_f64_file(xp.to_str().unwrap(), &x).unwrap();
        write_f64_vec(yp.to_str().unwrap(), &[0.0; 5]).unwrap();
        let store = ModelStore::new();
        let mut req = TrainRequest {
            name: "noy".into(),
            dataset: String::new(),
            n: 0,
            kind: SketchKind::Nystrom,
            d: 3,
            lambda: 1e-3,
            bandwidth: 0.0,
            seed: 1,
            adaptive: None,
            precision: Precision::F64,
            sampling: SamplingSpec::Uniform,
            data: Some(DataSpec {
                kind: "file".into(),
                path: xp.to_string_lossy().into_owned(),
                dim: 2,
                y_path: None,
            }),
        };
        let err = store.train(&req).unwrap_err();
        assert_eq!(err.kind, ErrorKind::InvalidInput, "{err}");
        req.data.as_mut().unwrap().y_path = Some(yp.to_string_lossy().into_owned());
        let err = store.train(&req).unwrap_err();
        assert_eq!(err.kind, ErrorKind::InvalidInput, "{err}");
        std::fs::remove_file(&xp).ok();
        std::fs::remove_file(&yp).ok();
    }

    #[test]
    fn model_json_roundtrip() {
        let mut rng = Pcg64::seed(9);
        let x = Matrix::from_fn(30, 2, |_, _| rng.uniform());
        let y: Vec<f64> = (0..30).map(|i| x[(i, 0)]).collect();
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 2 }).build(30, 6, &mut rng);
        let m = SketchedKrr::fit(Kernel::gaussian(0.5), &x, &y, &s, 1e-3, None).unwrap();
        let j = model_to_json(&m);
        let m2 = model_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        let q = Matrix::from_fn(5, 2, |_, _| 0.3);
        let p1 = m.predict(&q);
        let p2 = m2.predict(&q);
        for (a, b) in p1.iter().zip(p2.iter()) {
            assert!((a - b).abs() < 1e-9);
        }
    }
}
