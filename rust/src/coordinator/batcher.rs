//! Adaptive micro-batcher: coalesce concurrent predict requests per model.
//!
//! Prediction against a sketched-KRR model is a cross-kernel GEMV per
//! query; batching queries into one cross-kernel GEMM amortises the
//! landmark-matrix traversal. Two things distinguish this from a fixed
//! `max_wait` batcher:
//!
//! * **Adaptive wait** (the control law, DESIGN.md §9): the worker keeps
//!   an EWMA of observed inter-arrival gaps. The time the batch head
//!   waits for co-riders is `min(cap, gap · remaining_slots)` — the
//!   expected time for the rest of the batch to show up. At low arrival
//!   rates (`gap ≥ cap`) the wait collapses to **zero**: a lone request
//!   is served immediately instead of idling out the full `max_wait`
//!   (the fixed-wait pathology this replaces). Under load the gap
//!   shrinks, the wait grows toward the cap, and batches fill. Even at
//!   zero budget the worker drains already-queued requests with a
//!   non-blocking sweep, so queued co-riders always coalesce.
//! * **Flat row buffers end-to-end**: a request carries one `Vec<f64>`
//!   (row-major) from the wire to the GEMM. The flush path concatenates
//!   flat buffers into a single [`Matrix`] with `copy_from_slice` —
//!   no `Vec<Vec<f64>>`, no per-row allocation (test-enforced with a
//!   counting allocator).
//!
//! Completion is callback-based ([`Completion`]) so the reactor can
//! submit without parking a thread; [`Batcher::predict`] is the blocking
//! convenience wrapper the sync dispatch path and tests use.
//!
//! **Determinism:** coalescing never changes an answer. `SketchedKrr::
//! predict` assembles through the row-stable kernel path, so a row's
//! prediction is bitwise identical whether it rides alone or in any
//! batch composition (test-pinned here and in `tests/serving.rs`).

use crate::coordinator::metrics::ServingMetrics;
use crate::coordinator::state::ModelStore;
use crate::linalg::Matrix;
use crate::util::CodedError;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Max queries per flushed batch.
    pub max_batch: usize,
    /// Upper bound on the time the first request in a batch waits for
    /// co-riders (the adaptive wait never exceeds this cap; with
    /// `adaptive` off it is the fixed wait).
    pub max_wait: Duration,
    /// Scale the wait with the observed arrival rate (see module docs).
    /// Off = the classic fixed-deadline batcher.
    pub adaptive: bool,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            adaptive: true,
        }
    }
}

/// Completion callback invoked exactly once with the request's result
/// (on the batcher worker thread).
pub type Completion = Box<dyn FnOnce(Result<Vec<f64>, CodedError>) + Send>;

struct PredictJob {
    model: String,
    /// Row-major `rows × dim` query block.
    flat: Vec<f64>,
    rows: usize,
    dim: usize,
    /// Submission time — measures queue + batch + GEMM latency.
    t0: Instant,
    /// Absolute expiry: past this instant the job is answered
    /// `deadline_exceeded` instead of consuming a GEMM slot, and the
    /// worker flushes early so co-riders land inside it.
    deadline: Option<Instant>,
    done: Completion,
}

/// How far before the oldest queued deadline the worker flushes — slack
/// for the GEMM itself so the reply still lands inside the deadline.
const DEADLINE_FLUSH_MARGIN: Duration = Duration::from_millis(5);

/// EWMA weight for inter-arrival gap observations.
const GAP_ALPHA: f64 = 0.2;

/// The adaptive control law, pure for testability: how long to keep
/// waiting for co-riders given the gap estimate (seconds, `∞` until the
/// first observation), the wait cap, and the remaining batch slots.
/// Returns zero when arrivals are slower than the cap (serve the lone
/// request now), else the expected fill time `gap · remaining`, capped.
pub(crate) fn adaptive_wait(gap_s: f64, cap: Duration, remaining: usize) -> Duration {
    let cap_s = cap.as_secs_f64();
    if !gap_s.is_finite() || gap_s >= cap_s {
        return Duration::ZERO;
    }
    Duration::from_secs_f64((gap_s * remaining as f64).min(cap_s))
}

fn observe_gap(gap_ewma: &mut f64, last_arrival: &mut Option<Instant>, now: Instant) {
    if let Some(prev) = *last_arrival {
        let dt = now.duration_since(prev).as_secs_f64();
        *gap_ewma = if gap_ewma.is_finite() {
            (1.0 - GAP_ALPHA) * *gap_ewma + GAP_ALPHA * dt
        } else {
            dt
        };
    }
    *last_arrival = Some(now);
}

/// Handle to the batching worker.
pub struct Batcher {
    tx: Mutex<Option<Sender<PredictJob>>>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    metrics: Arc<ServingMetrics>,
}

impl Batcher {
    /// Spawn the worker thread over a shared model store.
    pub fn start(store: Arc<ModelStore>, cfg: BatcherConfig) -> Batcher {
        Batcher::start_with(store, cfg, Arc::new(ServingMetrics::new()))
    }

    /// As [`start`](Batcher::start), sharing an externally owned metrics
    /// block (the server threads one block through reactor + batcher).
    pub fn start_with(
        store: Arc<ModelStore>,
        cfg: BatcherConfig,
        metrics: Arc<ServingMetrics>,
    ) -> Batcher {
        let (tx, rx) = channel::<PredictJob>();
        let m2 = metrics.clone();
        let handle = std::thread::spawn(move || worker(store, cfg, rx, m2));
        Batcher {
            tx: Mutex::new(Some(tx)),
            handle: Mutex::new(Some(handle)),
            metrics,
        }
    }

    /// Submit a flat row-major `rows × dim` query block for prediction
    /// against a named model; `done` fires exactly once (possibly before
    /// this returns, for shape errors). `deadline` is the absolute
    /// expiry — expired jobs are answered `deadline_exceeded` without a
    /// GEMM slot.
    pub fn submit(
        &self,
        model: &str,
        flat: Vec<f64>,
        rows: usize,
        dim: usize,
        deadline: Option<Instant>,
        done: Completion,
    ) {
        if rows == 0 || dim == 0 || flat.len() != rows * dim {
            done(Err(CodedError::invalid_input(format!(
                "bad predict shape: {} values for {rows}x{dim}",
                flat.len()
            ))));
            return;
        }
        let job = PredictJob {
            model: model.to_string(),
            flat,
            rows,
            dim,
            t0: Instant::now(),
            deadline,
            done,
        };
        let guard = self.tx.lock().unwrap_or_else(|e| e.into_inner());
        match guard.as_ref() {
            Some(tx) => {
                if let Err(err) = tx.send(job) {
                    let job = err.0;
                    (job.done)(Err(CodedError::internal("batcher worker gone")));
                }
            }
            None => (job.done)(Err(CodedError::internal("batcher stopped"))),
        }
    }

    /// Blocking convenience wrapper: flatten, submit, wait for the batch
    /// containing these rows to be served.
    pub fn predict(&self, model: &str, rows: Vec<Vec<f64>>) -> Result<Vec<f64>, CodedError> {
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        let dim = rows[0].len();
        let mut flat = Vec::with_capacity(rows.len() * dim);
        for row in &rows {
            if row.len() != dim {
                return Err(CodedError::invalid_input("ragged predict rows"));
            }
            flat.extend_from_slice(row);
        }
        let (reply_tx, reply_rx) = channel();
        self.submit(
            model,
            flat,
            rows.len(),
            dim,
            None,
            Box::new(move |r| {
                let _ = reply_tx.send(r);
            }),
        );
        reply_rx
            .recv()
            .map_err(|_| CodedError::internal("batcher dropped reply"))?
    }

    /// Legacy metrics snapshot: (queries, batches).
    pub fn metrics(&self) -> (u64, u64) {
        (
            self.metrics.queries.load(Ordering::Relaxed),
            self.metrics.batches.load(Ordering::Relaxed),
        )
    }

    /// The full serving metrics block (shared with the reactor).
    pub fn serving_metrics(&self) -> Arc<ServingMetrics> {
        self.metrics.clone()
    }

    /// Stop the worker (drains the queue).
    pub fn stop(&self) {
        let tx = self.tx.lock().unwrap_or_else(|e| e.into_inner()).take();
        drop(tx);
        if let Some(h) = self.handle.lock().unwrap_or_else(|e| e.into_inner()).take() {
            let _ = h.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.stop();
    }
}

fn worker(
    store: Arc<ModelStore>,
    cfg: BatcherConfig,
    rx: Receiver<PredictJob>,
    metrics: Arc<ServingMetrics>,
) {
    let mut gap_ewma = f64::INFINITY;
    let mut last_arrival: Option<Instant> = None;
    loop {
        // block for the batch head
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => return, // all senders gone
        };
        observe_gap(&mut gap_ewma, &mut last_arrival, Instant::now());
        let start = Instant::now();
        let mut total = first.rows;
        let mut jobs = vec![first];
        while total < cfg.max_batch {
            let mut budget = if cfg.adaptive {
                adaptive_wait(gap_ewma, cfg.max_wait, cfg.max_batch - total)
            } else {
                cfg.max_wait
            };
            // a queued deadline trumps the batching policy: flush with
            // enough margin that the oldest co-rider's GEMM still lands
            // inside its deadline instead of idling out `max_wait`
            if let Some(dl) = jobs.iter().filter_map(|j| j.deadline).min() {
                budget = budget.min(
                    dl.saturating_duration_since(start)
                        .saturating_sub(DEADLINE_FLUSH_MARGIN),
                );
            }
            let elapsed = start.elapsed();
            if budget <= elapsed {
                // budget exhausted — still sweep anything already queued
                // so waiting co-riders coalesce instead of re-batching
                match rx.try_recv() {
                    Ok(j) => {
                        observe_gap(&mut gap_ewma, &mut last_arrival, Instant::now());
                        total += j.rows;
                        jobs.push(j);
                        continue;
                    }
                    Err(_) => break,
                }
            }
            match rx.recv_timeout(budget - elapsed) {
                Ok(j) => {
                    observe_gap(&mut gap_ewma, &mut last_arrival, Instant::now());
                    total += j.rows;
                    jobs.push(j);
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        flush(&store, jobs, &metrics);
    }
}

/// Serve one coalesced batch, grouping jobs by model via a sorted index
/// vector (no name clones) and concatenating flat buffers straight into
/// the GEMM input. Allocation budget: O(groups + jobs), never O(rows).
///
/// Failure domains, in evaluation order: an injected `batcher.flush`
/// fault fails the whole batch (structured, no quarantine); expired
/// deadlines are answered before any grouping so they never consume a
/// GEMM slot; quarantined models answer `model_unhealthy`; a panic
/// inside `predict` is caught, quarantines the model, and fails only
/// that model's group — co-batched groups for other models still serve.
fn flush(store: &ModelStore, mut jobs: Vec<PredictJob>, metrics: &ServingMetrics) {
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    let total_rows: usize = jobs.iter().map(|j| j.rows).sum();
    metrics.batch_rows.record(total_rows as f64);
    let mut results: Vec<Option<Result<Vec<f64>, CodedError>>> =
        (0..jobs.len()).map(|_| None).collect();
    if crate::util::fault::hit("batcher.flush") {
        for (job, _) in jobs.drain(..).zip(results) {
            metrics.predict_latency.record(job.t0.elapsed().as_secs_f64());
            (job.done)(Err(CodedError::internal("injected fault: batcher.flush")));
        }
        return;
    }
    // expired deadlines answer before grouping — no GEMM slot consumed
    let now = Instant::now();
    for (i, job) in jobs.iter().enumerate() {
        if job.deadline.is_some_and(|dl| dl <= now) {
            metrics.deadline_expired.fetch_add(1, Ordering::Relaxed);
            results[i] = Some(Err(CodedError::deadline_exceeded()));
        }
    }
    let mut order: Vec<usize> = (0..jobs.len()).filter(|&i| results[i].is_none()).collect();
    order.sort_by(|&a, &b| jobs[a].model.cmp(&jobs[b].model));
    let mut g0 = 0;
    while g0 < order.len() {
        let mut g1 = g0 + 1;
        while g1 < order.len() && jobs[order[g1]].model == jobs[order[g0]].model {
            g1 += 1;
        }
        let group = &order[g0..g1];
        let name = &jobs[group[0]].model;
        if store.is_quarantined(name) {
            for &i in group {
                results[i] = Some(Err(CodedError::model_unhealthy(name)));
            }
            g0 = g1;
            continue;
        }
        match store.get(name) {
            None => {
                for &i in group {
                    results[i] =
                        Some(Err(CodedError::invalid_input(format!("unknown model {name:?}"))));
                }
            }
            Some(sm) => {
                let p = sm.model.landmarks().cols();
                if group.iter().any(|&i| jobs[i].dim != p) {
                    for &i in group {
                        results[i] =
                            Some(Err(CodedError::invalid_input(format!("feature dim != {p}"))));
                    }
                } else {
                    let rows: usize = group.iter().map(|&i| jobs[i].rows).sum();
                    let mut xq = Matrix::zeros(rows, p);
                    let dst = xq.data_mut();
                    let mut off = 0;
                    for &i in group {
                        let src = &jobs[i].flat;
                        dst[off..off + src.len()].copy_from_slice(src);
                        off += src.len();
                    }
                    metrics.queries.fetch_add(rows as u64, Ordering::Relaxed);
                    let y = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        if crate::util::fault::hit("worker.panic") {
                            panic!("injected fault: worker.panic");
                        }
                        sm.model.predict(&xq)
                    }));
                    match y {
                        Ok(y) => {
                            let mut yoff = 0;
                            for &i in group {
                                let k = jobs[i].rows;
                                results[i] = Some(Ok(y[yoff..yoff + k].to_vec()));
                                yoff += k;
                            }
                        }
                        Err(_) => {
                            // poisoned model: quarantine so later requests
                            // get model_unhealthy instead of panicking again
                            store.quarantine(name);
                            metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
                            metrics.quarantined.fetch_add(1, Ordering::Relaxed);
                            for &i in group {
                                results[i] = Some(Err(CodedError::internal(format!(
                                    "predict worker panicked; model {name:?} quarantined"
                                ))));
                            }
                        }
                    }
                }
            }
        }
        g0 = g1;
    }
    for (job, res) in jobs.drain(..).zip(results) {
        metrics.predict_latency.record(job.t0.elapsed().as_secs_f64());
        (job.done)(res.unwrap_or_else(|| Err(CodedError::internal("no result"))));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::state::TrainRequest;
    use crate::sketch::SketchKind;

    fn store_with_model() -> Arc<ModelStore> {
        let store = Arc::new(ModelStore::new());
        store
            .train(&TrainRequest {
                name: "m".into(),
                dataset: "bimodal".into(),
                n: 150,
                kind: SketchKind::Accumulation { m: 3 },
                d: 10,
                lambda: 1e-3,
                bandwidth: 0.0,
                seed: 5,
                adaptive: None,
                precision: crate::linalg::Precision::F64,
                sampling: crate::coordinator::SamplingSpec::Uniform,
                data: None,
            })
            .unwrap();
        store
    }

    #[test]
    fn batched_equals_unbatched() {
        let store = store_with_model();
        let sm = store.get("m").unwrap();
        let b = Batcher::start(store.clone(), BatcherConfig::default());
        let rows = vec![vec![0.5, 0.5, 0.5], vec![2.2, 2.2, 2.2]];
        let got = b.predict("m", rows.clone()).unwrap();
        let mut xq = Matrix::zeros(2, 3);
        xq.row_mut(0).copy_from_slice(&rows[0]);
        xq.row_mut(1).copy_from_slice(&rows[1]);
        let want = sm.model.predict(&xq);
        for (a, w) in got.iter().zip(want.iter()) {
            assert!((a - w).abs() < 1e-12);
        }
        let (q, batches) = b.metrics();
        assert_eq!(q, 2);
        assert!(batches >= 1);
    }

    #[test]
    fn concurrent_requests_coalesce() {
        let store = store_with_model();
        // fixed wait here: the property under test is coalescing, which
        // must hold regardless of the control law
        let b = Arc::new(Batcher::start(
            store,
            BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_millis(30),
                adaptive: false,
            },
        ));
        let mut handles = Vec::new();
        for i in 0..8 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                let v = 0.1 * i as f64;
                b.predict("m", vec![vec![v, v, v]]).unwrap()
            }));
        }
        for h in handles {
            let y = h.join().unwrap();
            assert_eq!(y.len(), 1);
            assert!(y[0].is_finite());
        }
        let (q, batches) = b.metrics();
        assert_eq!(q, 8);
        assert!(batches < 8, "requests should coalesce, got {batches} batches");
    }

    #[test]
    fn unknown_model_and_bad_dims_error() {
        use crate::util::ErrorKind;
        let store = store_with_model();
        let b = Batcher::start(store, BatcherConfig::default());
        let e = b.predict("nope", vec![vec![0.0; 3]]).unwrap_err();
        assert_eq!(e.kind, ErrorKind::InvalidInput);
        let e = b.predict("m", vec![vec![0.0; 7]]).unwrap_err();
        assert_eq!(e.kind, ErrorKind::InvalidInput);
    }

    /// A job whose deadline already passed is answered `deadline_exceeded`
    /// before grouping: it consumes no GEMM slot (queries untouched) and
    /// ticks the `deadline_expired` counter. Live jobs in the same batch
    /// still serve.
    #[test]
    fn expired_deadline_skips_gemm_and_ticks_counter() {
        use crate::util::ErrorKind;
        use std::sync::mpsc;
        let store = store_with_model();
        let metrics = ServingMetrics::new();
        let (tx_dead, rx_dead) = mpsc::channel();
        let (tx_live, rx_live) = mpsc::channel();
        let jobs = vec![
            PredictJob {
                model: "m".to_string(),
                flat: vec![0.5, 0.5, 0.5],
                rows: 1,
                dim: 3,
                t0: Instant::now(),
                deadline: Some(Instant::now() - Duration::from_millis(1)),
                done: Box::new(move |r| tx_dead.send(r).unwrap()),
            },
            PredictJob {
                model: "m".to_string(),
                flat: vec![1.0, 1.0, 1.0],
                rows: 1,
                dim: 3,
                t0: Instant::now(),
                deadline: Some(Instant::now() + Duration::from_secs(30)),
                done: Box::new(move |r| tx_live.send(r).unwrap()),
            },
        ];
        flush(&store, jobs, &metrics);
        let dead = rx_dead.recv().unwrap().unwrap_err();
        assert_eq!(dead.kind, ErrorKind::DeadlineExceeded);
        let live = rx_live.recv().unwrap().unwrap();
        assert_eq!(live.len(), 1);
        assert_eq!(metrics.deadline_expired.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.queries.load(Ordering::Relaxed), 1, "expired job must not reach GEMM");
    }

    /// A quarantined model answers `model_unhealthy` without running the
    /// kernel; retraining under the same name heals it and service
    /// resumes.
    #[test]
    fn quarantined_model_rejects_until_retrained() {
        use crate::util::ErrorKind;
        let store = store_with_model();
        let b = Batcher::start(store.clone(), BatcherConfig::default());
        store.quarantine("m");
        let e = b.predict("m", vec![vec![0.5, 0.5, 0.5]]).unwrap_err();
        assert_eq!(e.kind, ErrorKind::ModelUnhealthy);
        // retrain heals
        store
            .train(&TrainRequest {
                name: "m".into(),
                dataset: "bimodal".into(),
                n: 150,
                kind: SketchKind::Accumulation { m: 3 },
                d: 10,
                lambda: 1e-3,
                bandwidth: 0.0,
                seed: 5,
                adaptive: None,
                precision: crate::linalg::Precision::F64,
                sampling: crate::coordinator::SamplingSpec::Uniform,
                data: None,
            })
            .unwrap();
        let y = b.predict("m", vec![vec![0.5, 0.5, 0.5]]).unwrap();
        assert_eq!(y.len(), 1);
    }

    /// The control law: zero wait until the gap estimate exists or when
    /// arrivals are slower than the cap; expected-fill-time otherwise;
    /// always capped; monotone in `remaining`.
    #[test]
    fn adaptive_wait_control_law() {
        let cap = Duration::from_millis(2);
        assert_eq!(adaptive_wait(f64::INFINITY, cap, 63), Duration::ZERO);
        assert_eq!(adaptive_wait(0.01, cap, 63), Duration::ZERO, "gap beyond cap");
        assert_eq!(adaptive_wait(0.002, cap, 63), Duration::ZERO, "gap == cap");
        let w = adaptive_wait(10e-6, cap, 50); // 10 µs gaps, 50 slots left
        assert_eq!(w, Duration::from_secs_f64(500e-6));
        assert_eq!(adaptive_wait(1e-3, cap, 63), cap, "capped");
        let w1 = adaptive_wait(20e-6, cap, 10);
        let w2 = adaptive_wait(20e-6, cap, 40);
        assert!(w2 > w1, "more open slots => willing to wait longer");
    }

    /// A row's prediction is bitwise identical whether it is served
    /// alone or coalesced behind other rows — the batcher must never
    /// change an answer (row-stable assembly underneath).
    #[test]
    fn batch_composition_does_not_change_answers_bitwise() {
        let store = store_with_model();
        let b = Batcher::start(store, BatcherConfig::default());
        let probe = vec![0.37, -1.2, 0.88];
        let alone = b.predict("m", vec![probe.clone()]).unwrap();
        let riding = b
            .predict(
                "m",
                vec![vec![9.0, 9.0, 9.0], probe.clone(), vec![-3.0, 0.0, 3.0]],
            )
            .unwrap();
        assert_eq!(alone[0].to_bits(), riding[1].to_bits());
    }

    /// The flush path allocates O(jobs), not O(rows): doubling the rows
    /// per job must not grow the allocation count by more than the GEMM
    /// panel slack. Pinned to one pool thread so every allocation lands
    /// on this thread's counter.
    #[test]
    fn flush_does_no_per_row_allocations() {
        use crate::util::mem::alloc_count;
        let _guard = crate::pool::TEST_THREADS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let before_threads = crate::pool::num_threads();
        crate::pool::set_num_threads(1);
        let store = store_with_model();
        let metrics = ServingMetrics::new();

        let build_jobs = |rows_per_job: usize| -> Vec<PredictJob> {
            (0..4)
                .map(|j| {
                    let flat: Vec<f64> = (0..rows_per_job * 3)
                        .map(|t| 0.01 * (j * 1000 + t) as f64)
                        .collect();
                    PredictJob {
                        model: "m".to_string(),
                        flat,
                        rows: rows_per_job,
                        dim: 3,
                        t0: Instant::now(),
                        deadline: None,
                        done: Box::new(|r| {
                            assert!(r.is_ok());
                        }),
                    }
                })
                .collect()
        };

        let count_flush = |jobs: Vec<PredictJob>| -> u64 {
            let a0 = alloc_count::on_thread();
            flush(&store, jobs, &metrics);
            alloc_count::on_thread() - a0
        };
        // warm up lazily-initialised state (dispatch detection etc.)
        count_flush(build_jobs(2));
        let small = count_flush(build_jobs(8)); // 32 rows total
        let large = count_flush(build_jobs(64)); // 256 rows total
        crate::pool::set_num_threads(before_threads);
        assert!(
            large <= small + 64,
            "flush allocations scale with rows: {small} for 32 rows vs {large} for 256"
        );
    }
}
