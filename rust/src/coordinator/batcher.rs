//! Dynamic batcher: coalesce concurrent predict requests per model.
//!
//! Prediction against a sketched-KRR model is a cross-kernel GEMV per
//! query; batching queries into one cross-kernel GEMM amortises the
//! landmark-matrix traversal (and, on the PJRT path, fills the fixed-shape
//! predict bucket). Requests wait at most `max_wait` for co-riders; a full
//! batch flushes immediately.

use crate::coordinator::state::ModelStore;
use crate::linalg::Matrix;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Max queries per flushed batch.
    pub max_batch: usize,
    /// Max time the first request in a batch waits for co-riders.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
        }
    }
}

struct Item {
    model: String,
    rows: Vec<Vec<f64>>,
    reply: Sender<Result<Vec<f64>, String>>,
}

/// Counters exported by the `metrics` server op.
#[derive(Debug, Default)]
pub struct BatcherMetrics {
    /// Total queries served.
    pub queries: AtomicU64,
    /// Total flushed batches.
    pub batches: AtomicU64,
}

/// Handle to the batching worker.
pub struct Batcher {
    tx: Mutex<Option<Sender<Item>>>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    metrics: Arc<BatcherMetrics>,
}

impl Batcher {
    /// Spawn the worker thread over a shared model store.
    pub fn start(store: Arc<ModelStore>, cfg: BatcherConfig) -> Batcher {
        let (tx, rx) = channel::<Item>();
        let metrics = Arc::new(BatcherMetrics::default());
        let m2 = metrics.clone();
        let handle = std::thread::spawn(move || worker(store, cfg, rx, m2));
        Batcher {
            tx: Mutex::new(Some(tx)),
            handle: Mutex::new(Some(handle)),
            metrics,
        }
    }

    /// Submit rows for prediction against a named model; blocks until the
    /// batch containing them is served.
    pub fn predict(&self, model: &str, rows: Vec<Vec<f64>>) -> Result<Vec<f64>, String> {
        let (reply_tx, reply_rx) = channel();
        {
            let guard = self.tx.lock().unwrap();
            let tx = guard.as_ref().ok_or("batcher stopped")?;
            tx.send(Item {
                model: model.to_string(),
                rows,
                reply: reply_tx,
            })
            .map_err(|_| "batcher worker gone")?;
        }
        reply_rx.recv().map_err(|_| "batcher dropped reply".to_string())?
    }

    /// Metrics snapshot: (queries, batches).
    pub fn metrics(&self) -> (u64, u64) {
        (
            self.metrics.queries.load(Ordering::Relaxed),
            self.metrics.batches.load(Ordering::Relaxed),
        )
    }

    /// Stop the worker (drains the queue).
    pub fn stop(&self) {
        let tx = self.tx.lock().unwrap().take();
        drop(tx);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.stop();
    }
}

fn worker(
    store: Arc<ModelStore>,
    cfg: BatcherConfig,
    rx: Receiver<Item>,
    metrics: Arc<BatcherMetrics>,
) {
    loop {
        // block for the first item
        let first = match rx.recv() {
            Ok(i) => i,
            Err(_) => return, // all senders gone
        };
        let deadline = std::time::Instant::now() + cfg.max_wait;
        let mut batch = vec![first];
        let mut total_rows = batch[0].rows.len();
        while total_rows < cfg.max_batch {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(i) => {
                    total_rows += i.rows.len();
                    batch.push(i);
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        flush(&store, batch, &metrics);
    }
}

/// Serve one coalesced batch, grouping items by model.
fn flush(store: &ModelStore, batch: Vec<Item>, metrics: &BatcherMetrics) {
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    // group indices by model name
    let mut by_model: std::collections::HashMap<String, Vec<usize>> = Default::default();
    for (i, item) in batch.iter().enumerate() {
        by_model.entry(item.model.clone()).or_default().push(i);
    }
    let mut replies: Vec<Option<Result<Vec<f64>, String>>> = (0..batch.len()).map(|_| None).collect();
    for (model_name, idxs) in by_model {
        let stored = store.get(&model_name);
        match stored {
            None => {
                for &i in &idxs {
                    replies[i] = Some(Err(format!("unknown model {model_name:?}")));
                }
            }
            Some(sm) => {
                // build one matrix over all items for this model
                let p = sm.model.landmarks().cols();
                let rows: usize = idxs.iter().map(|&i| batch[i].rows.len()).sum();
                let mut ok = true;
                let mut xq = Matrix::zeros(rows, p);
                let mut r = 0;
                for &i in &idxs {
                    for row in &batch[i].rows {
                        if row.len() != p {
                            ok = false;
                            break;
                        }
                        xq.row_mut(r).copy_from_slice(row);
                        r += 1;
                    }
                }
                if !ok {
                    for &i in &idxs {
                        replies[i] = Some(Err(format!("feature dim != {p}")));
                    }
                    continue;
                }
                metrics.queries.fetch_add(rows as u64, Ordering::Relaxed);
                let y = sm.model.predict(&xq);
                let mut off = 0;
                for &i in &idxs {
                    let k = batch[i].rows.len();
                    replies[i] = Some(Ok(y[off..off + k].to_vec()));
                    off += k;
                }
            }
        }
    }
    for (item, reply) in batch.into_iter().zip(replies.into_iter()) {
        let _ = item.reply.send(reply.unwrap_or_else(|| Err("internal: no reply".into())));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::state::{StoredModel, TrainRequest};
    use crate::sketch::SketchKind;

    fn store_with_model() -> Arc<ModelStore> {
        let store = Arc::new(ModelStore::new());
        store
            .train(&TrainRequest {
                name: "m".into(),
                dataset: "bimodal".into(),
                n: 150,
                kind: SketchKind::Accumulation { m: 3 },
                d: 10,
                lambda: 1e-3,
                bandwidth: 0.0,
                seed: 5,
                adaptive: None,
                precision: crate::linalg::Precision::F64,
            })
            .unwrap();
        store
    }

    #[test]
    fn batched_equals_unbatched() {
        let store = store_with_model();
        let sm = store.get("m").unwrap();
        let b = Batcher::start(store.clone(), BatcherConfig::default());
        let rows = vec![vec![0.5, 0.5, 0.5], vec![2.2, 2.2, 2.2]];
        let got = b.predict("m", rows.clone()).unwrap();
        let mut xq = Matrix::zeros(2, 3);
        xq.row_mut(0).copy_from_slice(&rows[0]);
        xq.row_mut(1).copy_from_slice(&rows[1]);
        let want = sm.model.predict(&xq);
        for (a, w) in got.iter().zip(want.iter()) {
            assert!((a - w).abs() < 1e-12);
        }
        let (q, batches) = b.metrics();
        assert_eq!(q, 2);
        assert!(batches >= 1);
    }

    #[test]
    fn concurrent_requests_coalesce() {
        let store = store_with_model();
        let b = Arc::new(Batcher::start(
            store,
            BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_millis(30),
            },
        ));
        let mut handles = Vec::new();
        for i in 0..8 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                let v = 0.1 * i as f64;
                b.predict("m", vec![vec![v, v, v]]).unwrap()
            }));
        }
        for h in handles {
            let y = h.join().unwrap();
            assert_eq!(y.len(), 1);
            assert!(y[0].is_finite());
        }
        let (q, batches) = b.metrics();
        assert_eq!(q, 8);
        assert!(batches < 8, "requests should coalesce, got {batches} batches");
    }

    #[test]
    fn unknown_model_and_bad_dims_error() {
        let store = store_with_model();
        let b = Batcher::start(store, BatcherConfig::default());
        assert!(b.predict("nope", vec![vec![0.0; 3]]).is_err());
        assert!(b.predict("m", vec![vec![0.0; 7]]).is_err());
    }
}
