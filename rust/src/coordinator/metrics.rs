//! Serving-plane metrics: lock-free counters plus fixed-bucket
//! histograms (no deps, no allocation after construction).
//!
//! The histograms use **fixed log-spaced bucket bounds** chosen at
//! construction, with one `AtomicU64` per bucket — `record` is a single
//! linear scan + one relaxed fetch-add, cheap enough to sit on the
//! per-request hot path. Quantiles are reconstructed by a cumulative
//! walk with linear interpolation inside the winning bucket, which makes
//! `quantile(q)` monotone in `q` by construction.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Fixed-bucket histogram. Bounds are upper edges; the last bucket is
/// unbounded (`> bounds.last()`).
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<AtomicU64>,
}

impl Histogram {
    /// Histogram over explicit upper bucket edges (must be ascending).
    pub fn new(bounds: Vec<f64>) -> Histogram {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]));
        let counts = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Histogram { bounds, counts }
    }

    /// Log-spaced bounds for second-scale latencies: 10 µs up to ~100 s
    /// with ratio 1.6 (~2 buckets per octave, ~35 buckets total).
    pub fn log_time() -> Histogram {
        let mut bounds = Vec::new();
        let mut b = 1e-5;
        while b < 100.0 {
            bounds.push(b);
            b *= 1.6;
        }
        Histogram::new(bounds)
    }

    /// Power-of-two bounds for batch-size distributions: 1, 2, 4, … 4096.
    pub fn pow2() -> Histogram {
        Histogram::new((0..13).map(|i| (1u64 << i) as f64).collect())
    }

    /// Record one observation.
    pub fn record(&self, v: f64) {
        let idx = self.bounds.partition_point(|&b| b < v);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Approximate `q`-quantile (`0 ≤ q ≤ 1`), linearly interpolated
    /// inside the winning bucket; `0.0` when empty. For the unbounded
    /// last bucket the lower edge is returned (a deliberate lower
    /// bound). Monotone in `q`.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).max(1.0);
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            let n = c.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            if (cum + n) as f64 >= target {
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                if i == self.bounds.len() {
                    return lo;
                }
                let hi = self.bounds[i];
                let frac = (target - cum as f64) / n as f64;
                return lo + (hi - lo) * frac.clamp(0.0, 1.0);
            }
            cum += n;
        }
        *self.bounds.last().unwrap_or(&0.0)
    }

    /// Mean of recorded values approximated by bucket midpoints (lower
    /// edge for the unbounded tail); `0.0` when empty.
    pub fn approx_mean(&self) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let mut sum = 0.0;
        for (i, c) in self.counts.iter().enumerate() {
            let n = c.load(Ordering::Relaxed) as f64;
            if n == 0.0 {
                continue;
            }
            let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
            let mid = if i == self.bounds.len() { lo } else { (lo + self.bounds[i]) / 2.0 };
            sum += mid * n;
        }
        sum / total as f64
    }

    /// Serialize as `{count, p50, p90, p99, mean}` (values in the
    /// recorded unit).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::from(self.count() as f64)),
            ("p50", Json::from(self.quantile(0.50))),
            ("p90", Json::from(self.quantile(0.90))),
            ("p99", Json::from(self.quantile(0.99))),
            ("mean", Json::from(self.approx_mean())),
        ])
    }
}

/// All serving-plane counters, shared by the reactor, the batcher and
/// the `metrics` op. Everything is atomic; the struct lives in an `Arc`.
pub struct ServingMetrics {
    /// Rows predicted (legacy name: `queries`).
    pub queries: AtomicU64,
    /// Batches flushed by the micro-batcher.
    pub batches: AtomicU64,
    /// Requests shed by backpressure (`overloaded` replies).
    pub shed: AtomicU64,
    /// Malformed / oversized / unparseable frames and lines.
    pub frame_errors: AtomicU64,
    /// Worker panics caught (train or batched predict).
    pub worker_panics: AtomicU64,
    /// Models put into quarantine after a worker panic.
    pub quarantined: AtomicU64,
    /// Requests answered `deadline_exceeded` without consuming compute.
    pub deadline_expired: AtomicU64,
    /// Error replies per taxonomy code, indexed like
    /// [`crate::util::error::ALL`].
    pub err_codes: [AtomicU64; crate::util::error::ALL.len()],
    /// End-to-end predict latency in seconds (submit → reply encoded).
    pub predict_latency: Histogram,
    /// Rows per flushed batch.
    pub batch_rows: Histogram,
}

impl ServingMetrics {
    /// Fresh zeroed metrics.
    pub fn new() -> ServingMetrics {
        ServingMetrics {
            queries: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            frame_errors: AtomicU64::new(0),
            worker_panics: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
            deadline_expired: AtomicU64::new(0),
            err_codes: std::array::from_fn(|_| AtomicU64::new(0)),
            predict_latency: Histogram::log_time(),
            batch_rows: Histogram::pow2(),
        }
    }

    /// Count one error reply under its taxonomy code. Unknown codes (a
    /// reply hand-built without the taxonomy) land on `internal`.
    pub fn tick_err_code(&self, code: &str) {
        use crate::util::error::{ErrorKind, ALL};
        let kind = ErrorKind::from_code(code).unwrap_or(ErrorKind::Internal);
        let idx = ALL.iter().position(|k| *k == kind).unwrap_or(ALL.len() - 1);
        self.err_codes[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot for the `metrics` op. Latency quantiles are reported in
    /// **milliseconds**. `faults_injected` is the process-wide
    /// [`crate::util::fault::fired_total`] — chaos tests read their
    /// injection accounting here next to the counters the faults moved.
    pub fn to_json(&self) -> Json {
        let lat = &self.predict_latency;
        let ms = 1e3;
        let codes: Vec<(&str, Json)> = crate::util::error::ALL
            .iter()
            .zip(self.err_codes.iter())
            .map(|(k, c)| (k.code(), Json::from(c.load(Ordering::Relaxed) as f64)))
            .collect();
        Json::obj(vec![
            ("queries", Json::from(self.queries.load(Ordering::Relaxed) as f64)),
            ("batches", Json::from(self.batches.load(Ordering::Relaxed) as f64)),
            ("shed", Json::from(self.shed.load(Ordering::Relaxed) as f64)),
            ("frame_errors", Json::from(self.frame_errors.load(Ordering::Relaxed) as f64)),
            (
                "worker_panics",
                Json::from(self.worker_panics.load(Ordering::Relaxed) as f64),
            ),
            ("quarantined", Json::from(self.quarantined.load(Ordering::Relaxed) as f64)),
            (
                "deadline_expired",
                Json::from(self.deadline_expired.load(Ordering::Relaxed) as f64),
            ),
            ("faults_injected", Json::from(crate::util::fault::fired_total() as f64)),
            ("err_codes", Json::obj(codes)),
            (
                "predict_latency_ms",
                Json::obj(vec![
                    ("count", Json::from(lat.count() as f64)),
                    ("p50", Json::from(lat.quantile(0.50) * ms)),
                    ("p90", Json::from(lat.quantile(0.90) * ms)),
                    ("p99", Json::from(lat.quantile(0.99) * ms)),
                    ("mean", Json::from(lat.approx_mean() * ms)),
                ]),
            ),
            ("batch_rows", self.batch_rows.to_json()),
        ])
    }
}

impl Default for ServingMetrics {
    fn default() -> ServingMetrics {
        ServingMetrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_order_and_interpolate() {
        let h = Histogram::log_time();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-4); // 0.1 ms .. 100 ms
        }
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        assert!(p50 > 1e-3 && p50 < 1e-1, "p50={p50}");
        assert!(p99 > p50, "p99={p99} should exceed p50={p50}");
        assert!(p99 < 0.2, "p99={p99}");
    }

    #[test]
    fn quantile_is_monotone_in_q() {
        let h = Histogram::pow2();
        for v in [1.0, 1.0, 3.0, 5.0, 17.0, 200.0, 5000.0, 9000.0] {
            h.record(v);
        }
        let mut last = -1.0;
        for i in 0..=20 {
            let q = i as f64 / 20.0;
            let v = h.quantile(q);
            assert!(v >= last, "quantile not monotone at q={q}: {v} < {last}");
            last = v;
        }
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = Histogram::log_time();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.approx_mean(), 0.0);
    }

    #[test]
    fn metrics_serialize_cleanly_and_counters_are_monotone() {
        let m = ServingMetrics::new();
        m.queries.fetch_add(3, Ordering::Relaxed);
        m.batches.fetch_add(1, Ordering::Relaxed);
        m.predict_latency.record(2e-3);
        let before = m.to_json().to_string();
        let parsed = Json::parse(&before).expect("metrics JSON must parse");
        assert_eq!(parsed.get("queries").and_then(Json::as_f64), Some(3.0));
        assert_eq!(parsed.get("shed").and_then(Json::as_f64), Some(0.0));
        // Monotone: more activity never decreases any counter.
        m.queries.fetch_add(2, Ordering::Relaxed);
        m.shed.fetch_add(1, Ordering::Relaxed);
        let after = Json::parse(&m.to_json().to_string()).unwrap();
        assert_eq!(after.get("queries").and_then(Json::as_f64), Some(5.0));
        assert_eq!(after.get("shed").and_then(Json::as_f64), Some(1.0));
        let lat = after.get("predict_latency_ms").expect("latency block");
        assert!(lat.get("p99").and_then(Json::as_f64).unwrap() >= 0.0);
    }

    #[test]
    fn err_code_table_is_exhaustive_and_tallies() {
        let m = ServingMetrics::new();
        m.tick_err_code("deadline_exceeded");
        m.tick_err_code("deadline_exceeded");
        m.tick_err_code("model_unhealthy");
        m.tick_err_code("not-a-real-code"); // lands on internal
        let j = Json::parse(&m.to_json().to_string()).unwrap();
        let codes = j.get("err_codes").expect("err_codes block");
        for k in crate::util::error::ALL {
            assert!(codes.get(k.code()).is_some(), "missing code {}", k.code());
        }
        assert_eq!(codes.get("deadline_exceeded").and_then(Json::as_f64), Some(2.0));
        assert_eq!(codes.get("model_unhealthy").and_then(Json::as_f64), Some(1.0));
        assert_eq!(codes.get("internal").and_then(Json::as_f64), Some(1.0));
        assert_eq!(codes.get("overloaded").and_then(Json::as_f64), Some(0.0));
        // the robustness counters serialize alongside
        for key in ["worker_panics", "quarantined", "deadline_expired", "faults_injected"] {
            assert!(j.get(key).and_then(Json::as_f64).is_some(), "missing {key}");
        }
    }
}
