//! Threaded TCP server speaking newline-delimited JSON.
//!
//! Operations:
//!
//! | op        | request fields                                         | reply |
//! |-----------|--------------------------------------------------------|-------|
//! | `ping`    | —                                                      | `{"ok":true,"pong":true}` |
//! | `train`   | `name,dataset,n,sketch,m,d,lambda,bandwidth,seed` (+ `m_max,rel_tol` for `sketch:"adaptive"`, + optional `precision:"f32"\|"f64"` for one-shot fits) | training metadata (+ `adaptive_m,rounds,rank_updates,refactors` telemetry for adaptive fits) |
//! | `predict` | `model, x: [[f64,…],…]`                                | `{"ok":true,"y":[…]}` |
//! | `cluster` | `dataset,n,k,method,d,m,m_max,rel_tol,bandwidth,seed,k_max` | labels + spectral telemetry (see `coordinator` module docs for the full schema) |
//! | `models`  | —                                                      | list of stored models |
//! | `metrics` | —                                                      | batcher counters |
//! | `shutdown`| —                                                      | stops the listener |
//!
//! One thread per connection (requests within a connection are pipelined
//! line-by-line); predictions flow through the [`Batcher`] so concurrent
//! clients coalesce.

use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::state::{
    parse_sketch_spec, run_cluster_job, ClusterRequest, ModelStore, TrainRequest,
};
use crate::linalg::Precision;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address, e.g. `127.0.0.1:7878` (port 0 → ephemeral).
    pub addr: String,
    /// Batching policy.
    pub batcher: BatcherConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7878".into(),
            batcher: BatcherConfig::default(),
        }
    }
}

/// Start serving; returns the bound local address and a shutdown closure is
/// not needed — send `{"op":"shutdown"}`. Blocks until shutdown when
/// `block` is true; otherwise serves on a background thread.
pub fn serve(
    store: Arc<ModelStore>,
    cfg: ServerConfig,
    block: bool,
) -> std::io::Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let batcher = Arc::new(Batcher::start(store.clone(), cfg.batcher));
    let stop = Arc::new(AtomicBool::new(false));
    let accept_loop = {
        let stop = stop.clone();
        move || {
            for stream in listener.incoming() {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let store = store.clone();
                        let batcher = batcher.clone();
                        let stop = stop.clone();
                        std::thread::spawn(move || {
                            let _ = handle_conn(s, &store, &batcher, &stop);
                        });
                    }
                    Err(_) => break,
                }
            }
        }
    };
    if block {
        accept_loop();
    } else {
        std::thread::spawn(accept_loop);
    }
    Ok(addr)
}

fn handle_conn(
    stream: TcpStream,
    store: &ModelStore,
    batcher: &Batcher,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    // small request/reply lines: Nagle + delayed-ACK would add ~40-90ms
    // per round trip (measured in EXPERIMENTS.md §Perf)
    stream.set_nodelay(true)?;
    let peer_addr = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = dispatch(&line, store, batcher, stop);
        writeln!(writer, "{reply}")?;
        if stop.load(Ordering::Relaxed) {
            // poke the listener so the accept loop observes the flag
            let _ = TcpStream::connect(peer_addr.ip().to_string() + ":0");
            break;
        }
    }
    Ok(())
}

fn err(msg: impl Into<String>) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))])
}

/// Decode one request line, execute, encode the reply. Public so tests can
/// exercise the protocol without sockets.
pub fn dispatch(line: &str, store: &ModelStore, batcher: &Batcher, stop: &AtomicBool) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return err(format!("bad json: {e}")),
    };
    match req.get("op").and_then(|o| o.as_str()) {
        Some("ping") => Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))]),
        Some("train") => op_train(&req, store),
        Some("predict") => op_predict(&req, batcher),
        Some("cluster") => op_cluster(&req),
        Some("models") => {
            let list = store
                .list()
                .into_iter()
                .map(|(name, n, secs, sketch)| {
                    Json::obj(vec![
                        ("name", Json::Str(name)),
                        ("n_train", Json::from(n)),
                        ("train_secs", Json::Num(secs)),
                        ("sketch", Json::Str(sketch)),
                    ])
                })
                .collect();
            Json::obj(vec![("ok", Json::Bool(true)), ("models", Json::Arr(list))])
        }
        Some("metrics") => {
            let (q, b) = batcher.metrics();
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("queries", Json::from(q as usize)),
                ("batches", Json::from(b as usize)),
            ])
        }
        Some("shutdown") => {
            stop.store(true, Ordering::Relaxed);
            Json::obj(vec![("ok", Json::Bool(true)), ("stopping", Json::Bool(true))])
        }
        Some(other) => err(format!("unknown op {other:?}")),
        None => err("missing op"),
    }
}

fn op_train(req: &Json, store: &ModelStore) -> Json {
    let s = |k: &str, d: &str| -> String {
        req.get(k).and_then(|v| v.as_str()).unwrap_or(d).to_string()
    };
    let u = |k: &str, d: usize| req.get(k).and_then(|v| v.as_usize()).unwrap_or(d);
    let f = |k: &str, d: f64| req.get(k).and_then(|v| v.as_f64()).unwrap_or(d);
    let (kind, adaptive) = match parse_sketch_spec(
        &s("sketch", "accum"),
        u("m", 4),
        u("m_max", 64),
        f("rel_tol", 1e-3),
    ) {
        Ok(spec) => spec,
        Err(e) => return err(e),
    };
    // optional "precision": "f64" (default) | "f32" — Gram accumulation
    // precision for one-shot fits; d×d solves are always f64
    let precision = match Precision::parse(&s("precision", "f64")) {
        Ok(p) => p,
        Err(e) => return err(e),
    };
    let treq = TrainRequest {
        name: s("name", "default"),
        dataset: s("dataset", "bimodal"),
        n: u("n", 1000),
        kind,
        d: u("d", 0),
        lambda: f("lambda", 0.0),
        bandwidth: f("bandwidth", 0.0),
        seed: u("seed", 1) as u64,
        adaptive,
        precision,
    };
    match store.train(&treq) {
        Ok(meta) => {
            let rep = *meta.model.report();
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("name", Json::Str(treq.name)),
                ("n_train", Json::from(meta.n_train)),
                ("train_secs", Json::Num(meta.train_secs)),
                ("train_mse", Json::Num(meta.train_mse)),
                ("landmarks", Json::from(meta.model.num_landmarks())),
                ("sketch", Json::Str(meta.sketch)),
            ];
            if rep.rounds > 0 {
                fields.push(("adaptive_m", Json::from(rep.m)));
                fields.push(("rounds", Json::from(rep.rounds)));
                fields.push(("rank_updates", Json::from(rep.rank_updates as usize)));
                fields.push(("refactors", Json::from(rep.refactors as usize)));
            }
            Json::obj(fields)
        }
        Err(e) => err(e),
    }
}

fn op_cluster(req: &Json) -> Json {
    let defaults = ClusterRequest::default();
    let s = |k: &str, d: &str| -> String {
        req.get(k).and_then(|v| v.as_str()).unwrap_or(d).to_string()
    };
    let u = |k: &str, d: usize| req.get(k).and_then(|v| v.as_usize()).unwrap_or(d);
    let f = |k: &str, d: f64| req.get(k).and_then(|v| v.as_f64()).unwrap_or(d);
    let creq = ClusterRequest {
        dataset: s("dataset", &defaults.dataset),
        n: u("n", defaults.n),
        k: u("k", defaults.k),
        k_max: u("k_max", defaults.k_max),
        method: s("method", &defaults.method),
        d: u("d", defaults.d),
        m: u("m", defaults.m),
        m_max: u("m_max", defaults.m_max),
        rel_tol: f("rel_tol", defaults.rel_tol),
        bandwidth: f("bandwidth", defaults.bandwidth),
        seed: u("seed", defaults.seed as usize) as u64,
    };
    match run_cluster_job(&creq) {
        Ok(reply) => reply,
        Err(e) => err(e),
    }
}

fn op_predict(req: &Json, batcher: &Batcher) -> Json {
    let model = match req.get("model").and_then(|v| v.as_str()) {
        Some(m) => m.to_string(),
        None => return err("missing model"),
    };
    let rows: Option<Vec<Vec<f64>>> = req.get("x").and_then(|v| v.as_arr()).map(|rows| {
        rows.iter()
            .filter_map(|r| {
                r.as_arr()
                    .map(|vals| vals.iter().filter_map(|v| v.as_f64()).collect())
            })
            .collect()
    });
    let rows = match rows {
        Some(r) if !r.is_empty() => r,
        _ => return err("missing/empty x"),
    };
    match batcher.predict(&model, rows) {
        Ok(y) => Json::obj(vec![("ok", Json::Bool(true)), ("y", Json::nums(&y))]),
        Err(e) => err(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;

    fn setup() -> (Arc<ModelStore>, Batcher, AtomicBool) {
        let store = Arc::new(ModelStore::new());
        let b = Batcher::start(store.clone(), BatcherConfig::default());
        (store, b, AtomicBool::new(false))
    }

    #[test]
    fn ping_and_unknown() {
        let (store, b, stop) = setup();
        let r = dispatch(r#"{"op":"ping"}"#, &store, &b, &stop);
        assert_eq!(r.get("pong"), Some(&Json::Bool(true)));
        let r = dispatch(r#"{"op":"wat"}"#, &store, &b, &stop);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        let r = dispatch("not json", &store, &b, &stop);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn train_then_predict_roundtrip() {
        let (store, b, stop) = setup();
        let r = dispatch(
            r#"{"op":"train","name":"m1","dataset":"bimodal","n":150,"sketch":"accum","m":3,"d":10,"lambda":0.001,"seed":5}"#,
            &store,
            &b,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        let r = dispatch(
            r#"{"op":"predict","model":"m1","x":[[0.5,0.5,0.5],[2.2,2.2,2.2]]}"#,
            &store,
            &b,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(r.get("y").unwrap().as_arr().unwrap().len(), 2);
        let r = dispatch(r#"{"op":"models"}"#, &store, &b, &stop);
        assert_eq!(r.get("models").unwrap().as_arr().unwrap().len(), 1);
        let r = dispatch(r#"{"op":"metrics"}"#, &store, &b, &stop);
        assert_eq!(r.get("queries").and_then(|q| q.as_usize()), Some(2));
    }

    #[test]
    fn adaptive_train_surfaces_telemetry() {
        let (store, b, stop) = setup();
        let r = dispatch(
            r#"{"op":"train","name":"ad","dataset":"bimodal","n":150,"sketch":"adaptive","m_max":16,"rel_tol":0.05,"d":10,"lambda":0.001,"seed":6}"#,
            &store,
            &b,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        let m = r.get("adaptive_m").and_then(|v| v.as_usize()).unwrap();
        assert!((1..=16).contains(&m), "chosen m = {m}");
        assert!(r.get("rounds").and_then(|v| v.as_usize()).unwrap() >= 1);
        assert!(r.get("sketch").and_then(|v| v.as_str()).unwrap().starts_with("adaptive_m"));
        // the stored model predicts through the batcher like any other
        let r = dispatch(
            r#"{"op":"predict","model":"ad","x":[[0.1,0.2,0.3]]}"#,
            &store,
            &b,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    }

    #[test]
    fn cluster_op_returns_labels_and_ari() {
        let (store, b, stop) = setup();
        let r = dispatch(
            r#"{"op":"cluster","dataset":"blobs","n":90,"k":3,"method":"operator","seed":11}"#,
            &store,
            &b,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(r.get("k").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(r.get("labels").and_then(|v| v.as_arr()).unwrap().len(), 90);
        let ari = r.get("ari_vs_truth").and_then(|v| v.as_f64()).unwrap();
        assert!(ari >= 0.95, "ARI {ari}");
        // bad method surfaces as a protocol error, not a panic
        let r = dispatch(
            r#"{"op":"cluster","dataset":"blobs","n":60,"k":2,"method":"nope"}"#,
            &store,
            &b,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn shutdown_sets_flag() {
        let (store, b, stop) = setup();
        dispatch(r#"{"op":"shutdown"}"#, &store, &b, &stop);
        assert!(stop.load(Ordering::Relaxed));
    }

    #[test]
    fn tcp_end_to_end() {
        let store = Arc::new(ModelStore::new());
        let addr = serve(
            store,
            ServerConfig {
                addr: "127.0.0.1:0".into(),
                batcher: BatcherConfig::default(),
            },
            false,
        )
        .unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        writeln!(conn, r#"{{"op":"ping"}}"#).unwrap();
        let mut line = String::new();
        BufReader::new(conn.try_clone().unwrap()).read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("pong"), Some(&Json::Bool(true)));
        writeln!(conn, r#"{{"op":"shutdown"}}"#).unwrap();
    }
}
