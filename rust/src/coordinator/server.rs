//! Reactor-backed TCP server speaking framed (v2) and legacy newline
//! (v1) JSON.
//!
//! Operations (same schema on both protocols; framed requests use
//! `method`, with `op` accepted as an alias — legacy uses `op`):
//!
//! | op        | request fields                                         | reply |
//! |-----------|--------------------------------------------------------|-------|
//! | `ping`    | —                                                      | `{"ok":true,"pong":true}` |
//! | `train`   | `name,dataset,n,sketch,m,d,lambda,bandwidth,seed` (+ `m_max,rel_tol,refine_after_m` for `sketch:"adaptive"`, + optional `precision:"f32"\|"f64"` for one-shot fits, + optional `sampling:"uniform"\|"leverage"\|"poisson"`) | training metadata (+ `adaptive_m,rounds,rank_updates,refactors` telemetry for adaptive fits; + `sampling,d_stat,refine_round` when informed sampling / refinement was active) |
//! | `predict` | `model, x: [[f64,…],…]` (rectangular)                  | `{"ok":true,"y":[…]}` |
//! | `cluster` | `dataset,n,k,method,d,m,m_max,rel_tol,bandwidth,seed,k_max` | labels + spectral telemetry (see `coordinator` module docs for the full schema) |
//! | `models`  | —                                                      | list of stored models |
//! | `metrics` | —                                                      | serving counters + latency/batch histograms |
//! | `shutdown`| —                                                      | stops the server |
//!
//! All connections are driven by one [`reactor`](crate::coordinator::
//! reactor) thread (non-blocking sockets, per-connection write queues,
//! load shedding past the backpressure limits). Fast ops answer inline
//! on the reactor; `predict` flows through the adaptive [`Batcher`]
//! (callback completion, no thread parked); `train`/`cluster` run on a
//! small [`TaskPool`] so a long fit never stalls the event loop or
//! predictions against already-stored models. Framed replies carry the
//! guaranteed `id`/`method`/`ok` envelope (see `coordinator` module
//! docs for the wire schema); legacy replies are byte-compatible with
//! the v1 server.

use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::metrics::ServingMetrics;
use crate::coordinator::reactor::{self, Done, ReactorConfig, ReplySink, Router};
use crate::coordinator::state::{
    parse_data_spec, parse_sketch_spec, run_cluster_job, ClusterRequest, ModelStore, SamplingSpec,
    TrainRequest,
};
use crate::linalg::Precision;
use crate::pool::TaskPool;
use crate::util::json::Json;
use crate::util::{CodedError, ErrorKind};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::Instant;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address, e.g. `127.0.0.1:7878` (port 0 → ephemeral).
    pub addr: String,
    /// Batching policy.
    pub batcher: BatcherConfig,
    /// Backpressure: max requests in flight per connection before the
    /// server sheds with `{"ok":false,"err":"overloaded"}`.
    pub max_inflight: usize,
    /// Backpressure: max unread reply bytes queued per connection
    /// before new requests on it are shed.
    pub high_water_bytes: usize,
    /// Worker threads for slow ops (`train`, `cluster`).
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7878".into(),
            batcher: BatcherConfig::default(),
            max_inflight: 256,
            high_water_bytes: 1 << 20,
            workers: 2,
        }
    }
}

/// Routes parsed requests from the reactor to handlers (fast ops
/// inline, predicts to the batcher, slow ops to the task pool).
struct CoordRouter {
    store: Arc<ModelStore>,
    batcher: Arc<Batcher>,
    tasks: TaskPool,
    stop: Arc<AtomicBool>,
    metrics: Arc<ServingMetrics>,
}

/// Deliver a reply through the sink, tallying its `err_code` (if any)
/// into the serving metrics so shed vs deadline vs fault rejections are
/// distinguishable in the `metrics` op.
fn send_counted(metrics: &ServingMetrics, sink: ReplySink, reply: Json) {
    if let Some(code) = reply.get("err_code").and_then(|c| c.as_str()) {
        metrics.tick_err_code(code);
    }
    sink.send(reply);
}

impl CoordRouter {
    fn route_predict(&self, req: &Json, sink: ReplySink) {
        let metrics = self.metrics.clone();
        match parse_predict(req) {
            Ok((model, flat, rows, dim)) => {
                // serving-boundary rejections: a quarantined model or a
                // wrong feature width never consumes a batch slot
                if self.store.is_quarantined(&model) {
                    let e = CodedError::model_unhealthy(&model);
                    send_counted(&metrics, sink, coded(&e));
                    return;
                }
                if let Some(sm) = self.store.get(&model) {
                    let p = sm.model.landmarks().cols();
                    if dim != p {
                        let e = CodedError::invalid_input(format!("feature dim != {p}"));
                        send_counted(&metrics, sink, coded(&e));
                        return;
                    }
                }
                let deadline = sink.deadline();
                self.batcher.submit(
                    &model,
                    flat,
                    rows,
                    dim,
                    deadline,
                    Box::new(move |r| {
                        let reply = match r {
                            Ok(y) => ok_y(&y),
                            Err(e) => coded(&e),
                        };
                        send_counted(&metrics, sink, reply);
                    }),
                );
            }
            Err(e) => send_counted(&metrics, sink, coded(&e)),
        }
    }
}

impl Router for CoordRouter {
    fn route(&self, req: Json, sink: ReplySink) {
        let op = req
            .get("method")
            .or_else(|| req.get("op"))
            .and_then(|o| o.as_str())
            .unwrap_or("")
            .to_string();
        match op.as_str() {
            "predict" => self.route_predict(&req, sink),
            "train" | "cluster" => {
                let store = self.store.clone();
                let metrics = self.metrics.clone();
                let deadline = sink.deadline();
                // off the reactor thread: a fit can take seconds, and
                // predictions against stored models must keep flowing
                self.tasks.submit(move || {
                    if deadline.is_some_and(|dl| dl <= Instant::now()) {
                        metrics.deadline_expired.fetch_add(1, Ordering::Relaxed);
                        let e = CodedError::deadline_exceeded();
                        send_counted(&metrics, sink, coded(&e));
                        return;
                    }
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        if crate::util::fault::hit("worker.panic") {
                            panic!("injected fault: worker.panic");
                        }
                        if op == "train" {
                            op_train(&req, &store)
                        } else {
                            op_cluster(&req)
                        }
                    }));
                    let reply = result.unwrap_or_else(|_| {
                        // a panicked train leaves the named model
                        // quarantined until a later train heals it
                        metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
                        if op == "train" {
                            if let Some(name) = req.get("name").and_then(|v| v.as_str()) {
                                store.quarantine(name);
                                metrics.quarantined.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        err(ErrorKind::Internal, "internal error: handler panicked")
                    });
                    send_counted(&metrics, sink, reply);
                });
            }
            _ => {
                let reply = dispatch_value(&req, &self.store, &self.batcher, &self.stop);
                send_counted(&self.metrics, sink, reply);
            }
        }
    }

    fn stop_flag(&self) -> &AtomicBool {
        &self.stop
    }

    fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }
}

/// Running server: reactor thread + batcher + task pool. Dropping the
/// handle shuts the server down (unless [`detach`](ServerHandle::detach)ed).
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    wake: Sender<Done>,
    reactor: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<ServingMetrics>,
    detached: bool,
}

impl ServerHandle {
    /// Bind and start serving on the reactor thread; returns immediately.
    pub fn start(store: Arc<ModelStore>, cfg: ServerConfig) -> std::io::Result<ServerHandle> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let metrics = Arc::new(ServingMetrics::new());
        let batcher = Arc::new(Batcher::start_with(
            store.clone(),
            cfg.batcher,
            metrics.clone(),
        ));
        let stop = Arc::new(AtomicBool::new(false));
        let router = Arc::new(CoordRouter {
            store,
            batcher,
            tasks: TaskPool::new(cfg.workers),
            stop: stop.clone(),
            metrics: metrics.clone(),
        });
        let (wake, handle) = reactor::spawn(
            listener,
            router,
            ReactorConfig {
                max_inflight: cfg.max_inflight,
                high_water_bytes: cfg.high_water_bytes,
            },
        )?;
        Ok(ServerHandle {
            addr,
            stop,
            wake,
            reactor: Some(handle),
            metrics,
            detached: false,
        })
    }

    /// Bound local address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shared serving counters (same block the `metrics` op reports).
    pub fn metrics(&self) -> Arc<ServingMetrics> {
        self.metrics.clone()
    }

    /// Request shutdown (sets the flag and wakes the reactor). Returns
    /// immediately; pair with [`join`](ServerHandle::join) to wait.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.wake.send(Done::Wake);
    }

    /// Block until the reactor exits (e.g. a client sent `shutdown`).
    pub fn join(mut self) {
        if let Some(h) = self.reactor.take() {
            let _ = h.join();
        }
        self.detached = true;
    }

    /// Shut down and wait for the reactor to exit.
    pub fn stop(self) {
        self.shutdown();
        self.join();
    }

    /// Leave the server running for the life of the process and drop
    /// the handle.
    pub fn detach(mut self) -> SocketAddr {
        self.detached = true;
        self.addr
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        if self.detached {
            return;
        }
        self.shutdown();
        if let Some(h) = self.reactor.take() {
            let _ = h.join();
        }
    }
}

/// Start serving; returns the bound local address. Send
/// `{"op":"shutdown"}` (or call [`ServerHandle::shutdown`] via
/// [`ServerHandle::start`]) to stop. Blocks until shutdown when `block`
/// is true; otherwise serves detached on the reactor thread.
pub fn serve(
    store: Arc<ModelStore>,
    cfg: ServerConfig,
    block: bool,
) -> std::io::Result<std::net::SocketAddr> {
    let handle = ServerHandle::start(store, cfg)?;
    let addr = handle.addr();
    if block {
        handle.join();
    } else {
        handle.detach();
    }
    Ok(addr)
}

fn err(kind: ErrorKind, msg: impl Into<String>) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("err_code", Json::Str(kind.code().to_string())),
        ("error", Json::Str(msg.into())),
    ])
}

fn coded(e: &CodedError) -> Json {
    err(e.kind, e.msg.clone())
}

fn ok_y(y: &[f64]) -> Json {
    Json::obj(vec![("ok", Json::Bool(true)), ("y", Json::nums(y))])
}

fn parse_predict(req: &Json) -> Result<(String, Vec<f64>, usize, usize), CodedError> {
    let model = req
        .get("model")
        .and_then(|v| v.as_str())
        .ok_or_else(|| CodedError::invalid_input("missing model"))?
        .to_string();
    let (flat, rows, dim) = req
        .get("x")
        .and_then(|x| x.as_flat_rows())
        .ok_or_else(|| {
            CodedError::invalid_input("missing/empty x (need rectangular numeric rows)")
        })?;
    // reject NaN/Inf at the boundary: a non-finite feature would poison
    // the whole coalesced GEMM batch, not just this request
    if let Some(bad) = flat.iter().position(|v| !v.is_finite()) {
        return Err(CodedError::invalid_input(format!(
            "x[{}][{}] is not finite",
            bad / dim,
            bad % dim
        )));
    }
    Ok((model, flat, rows, dim))
}

/// Decode one request line, execute, encode the reply. Public so tests
/// can exercise the protocol without sockets. This is the synchronous
/// path — the reactor uses the same handlers but completes predict /
/// train / cluster asynchronously.
pub fn dispatch(line: &str, store: &ModelStore, batcher: &Batcher, stop: &AtomicBool) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return err(ErrorKind::InvalidInput, format!("bad json: {e}")),
    };
    dispatch_value(&req, store, batcher, stop)
}

/// Execute one parsed request synchronously.
fn dispatch_value(req: &Json, store: &ModelStore, batcher: &Batcher, stop: &AtomicBool) -> Json {
    match req
        .get("method")
        .or_else(|| req.get("op"))
        .and_then(|o| o.as_str())
    {
        Some("ping") => Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))]),
        Some("train") => op_train(req, store),
        Some("predict") => op_predict(req, batcher),
        Some("cluster") => op_cluster(req),
        Some("models") => {
            let list = store
                .list()
                .into_iter()
                .map(|(name, n, secs, sketch)| {
                    Json::obj(vec![
                        ("name", Json::Str(name)),
                        ("n_train", Json::from(n)),
                        ("train_secs", Json::Num(secs)),
                        ("sketch", Json::Str(sketch)),
                    ])
                })
                .collect();
            Json::obj(vec![("ok", Json::Bool(true)), ("models", Json::Arr(list))])
        }
        Some("metrics") => {
            let mut j = batcher.serving_metrics().to_json();
            if let Json::Obj(m) = &mut j {
                m.insert("ok".into(), Json::Bool(true));
            }
            j
        }
        Some("shutdown") => {
            stop.store(true, Ordering::SeqCst);
            Json::obj(vec![("ok", Json::Bool(true)), ("stopping", Json::Bool(true))])
        }
        Some(other) => err(ErrorKind::InvalidInput, format!("unknown op {other:?}")),
        None => err(ErrorKind::InvalidInput, "missing op"),
    }
}

fn op_train(req: &Json, store: &ModelStore) -> Json {
    let s = |k: &str, d: &str| -> String {
        req.get(k).and_then(|v| v.as_str()).unwrap_or(d).to_string()
    };
    let u = |k: &str, d: usize| req.get(k).and_then(|v| v.as_usize()).unwrap_or(d);
    let f = |k: &str, d: f64| req.get(k).and_then(|v| v.as_f64()).unwrap_or(d);
    let (kind, mut adaptive) = match parse_sketch_spec(
        &s("sketch", "accum"),
        u("m", 4),
        u("m_max", 64),
        f("rel_tol", 1e-3),
    ) {
        Ok(spec) => spec,
        Err(e) => return err(ErrorKind::InvalidInput, e),
    };
    // optional "rank_update_limit": admission cap for the incremental
    // Cholesky path in adaptive fits (chaos tests raise it to force
    // every round through the downdate seam)
    if let Some(limit) = req.get("rank_update_limit").and_then(|v| v.as_usize()) {
        if let Some(a) = adaptive.as_mut() {
            a.rank_update_limit = Some(limit);
        }
    }
    // optional "refine_after_m": between-term probability refinement for
    // adaptive fits — once the sketch holds that many terms, leverage is
    // estimated from the cached support columns and later terms draw
    // from it (0, the default, disables and keeps the draw stream
    // bit-identical)
    if let Some(r) = req.get("refine_after_m").and_then(|v| v.as_usize()) {
        if let Some(a) = adaptive.as_mut() {
            a.refine_after_m = r;
        }
    }
    // optional "precision": "f64" (default) | "f32" — Gram accumulation
    // precision for one-shot fits; d×d solves are always f64
    let precision = match Precision::parse(&s("precision", "f64")) {
        Ok(p) => p,
        Err(e) => return err(ErrorKind::InvalidInput, e),
    };
    // optional "sampling": "uniform" (default) | "leverage" | "poisson"
    let sampling = match SamplingSpec::parse(&s("sampling", "uniform")) {
        Ok(sp) => sp,
        Err(e) => return err(ErrorKind::InvalidInput, e),
    };
    // optional "data": out-of-core source spec — train streams X off
    // disk instead of generating the named dataset (DESIGN.md §12)
    let data = match parse_data_spec(req) {
        Ok(d) => d,
        Err(e) => return err(ErrorKind::InvalidInput, e),
    };
    let treq = TrainRequest {
        name: s("name", "default"),
        dataset: s("dataset", "bimodal"),
        n: u("n", 1000),
        kind,
        d: u("d", 0),
        lambda: f("lambda", 0.0),
        bandwidth: f("bandwidth", 0.0),
        seed: u("seed", 1) as u64,
        adaptive,
        precision,
        sampling,
        data,
    };
    match store.train(&treq) {
        Ok(meta) => {
            let rep = *meta.model.report();
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("name", Json::Str(treq.name)),
                ("n_train", Json::from(meta.n_train)),
                ("train_secs", Json::Num(meta.train_secs)),
                ("train_mse", Json::Num(meta.train_mse)),
                ("landmarks", Json::from(meta.model.num_landmarks())),
                ("sketch", Json::Str(meta.sketch)),
            ];
            if rep.rounds > 0 {
                fields.push(("adaptive_m", Json::from(rep.m)));
                fields.push(("rounds", Json::from(rep.rounds)));
                fields.push(("rank_updates", Json::from(rep.rank_updates as usize)));
                fields.push(("refactors", Json::from(rep.refactors as usize)));
            }
            // sampling telemetry is conditional — uniform, unrefined
            // replies stay byte-identical to the pre-knob protocol
            if meta.sampling != "uniform" {
                fields.push(("sampling", Json::Str(meta.sampling)));
            }
            if rep.refine_round > 0 {
                fields.push(("refine_round", Json::from(rep.refine_round)));
            }
            if meta.d_stat > 0.0 {
                fields.push(("d_stat", Json::Num(meta.d_stat)));
            }
            // only reported when the factorization needed rescuing, so
            // healthy train replies stay byte-identical
            if rep.jitter_bumps > 0 {
                fields.push(("jitter_bumps", Json::from(rep.jitter_bumps as usize)));
            }
            Json::obj(fields)
        }
        Err(e) => coded(&e),
    }
}

fn op_cluster(req: &Json) -> Json {
    let defaults = ClusterRequest::default();
    let s = |k: &str, d: &str| -> String {
        req.get(k).and_then(|v| v.as_str()).unwrap_or(d).to_string()
    };
    let u = |k: &str, d: usize| req.get(k).and_then(|v| v.as_usize()).unwrap_or(d);
    let f = |k: &str, d: f64| req.get(k).and_then(|v| v.as_f64()).unwrap_or(d);
    let data = match parse_data_spec(req) {
        Ok(d) => d,
        Err(e) => return err(ErrorKind::InvalidInput, e),
    };
    let creq = ClusterRequest {
        dataset: s("dataset", &defaults.dataset),
        n: u("n", defaults.n),
        k: u("k", defaults.k),
        k_max: u("k_max", defaults.k_max),
        method: s("method", &defaults.method),
        d: u("d", defaults.d),
        m: u("m", defaults.m),
        m_max: u("m_max", defaults.m_max),
        rel_tol: f("rel_tol", defaults.rel_tol),
        bandwidth: f("bandwidth", defaults.bandwidth),
        seed: u("seed", defaults.seed as usize) as u64,
        data,
    };
    match run_cluster_job(&creq) {
        Ok(reply) => reply,
        Err(e) => coded(&e),
    }
}

fn op_predict(req: &Json, batcher: &Batcher) -> Json {
    match parse_predict(req) {
        Ok((model, flat, rows, dim)) => {
            let (tx, rx) = channel();
            batcher.submit(
                &model,
                flat,
                rows,
                dim,
                None,
                Box::new(move |r| {
                    let _ = tx.send(r);
                }),
            );
            match rx.recv() {
                Ok(Ok(y)) => ok_y(&y),
                Ok(Err(e)) => coded(&e),
                Err(_) => err(ErrorKind::Internal, "batcher dropped reply"),
            }
        }
        Err(e) => coded(&e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    fn setup() -> (Arc<ModelStore>, Batcher, AtomicBool) {
        let store = Arc::new(ModelStore::new());
        let b = Batcher::start(store.clone(), BatcherConfig::default());
        (store, b, AtomicBool::new(false))
    }

    #[test]
    fn ping_and_unknown() {
        let (store, b, stop) = setup();
        let r = dispatch(r#"{"op":"ping"}"#, &store, &b, &stop);
        assert_eq!(r.get("pong"), Some(&Json::Bool(true)));
        let r = dispatch(r#"{"op":"wat"}"#, &store, &b, &stop);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        let r = dispatch("not json", &store, &b, &stop);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        // "method" is accepted everywhere "op" is
        let r = dispatch(r#"{"method":"ping"}"#, &store, &b, &stop);
        assert_eq!(r.get("pong"), Some(&Json::Bool(true)));
    }

    #[test]
    fn train_then_predict_roundtrip() {
        let (store, b, stop) = setup();
        let r = dispatch(
            r#"{"op":"train","name":"m1","dataset":"bimodal","n":150,"sketch":"accum","m":3,"d":10,"lambda":0.001,"seed":5}"#,
            &store,
            &b,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        let r = dispatch(
            r#"{"op":"predict","model":"m1","x":[[0.5,0.5,0.5],[2.2,2.2,2.2]]}"#,
            &store,
            &b,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(r.get("y").unwrap().as_arr().unwrap().len(), 2);
        let r = dispatch(r#"{"op":"models"}"#, &store, &b, &stop);
        assert_eq!(r.get("models").unwrap().as_arr().unwrap().len(), 1);
        let r = dispatch(r#"{"op":"metrics"}"#, &store, &b, &stop);
        assert_eq!(r.get("queries").and_then(|q| q.as_usize()), Some(2));
        // upgraded metrics block: latency + batch histograms serialize
        assert!(r.get("predict_latency_ms").is_some(), "{r}");
        assert!(r.get("batch_rows").is_some(), "{r}");
        assert_eq!(r.get("shed").and_then(|v| v.as_usize()), Some(0));
    }

    #[test]
    fn adaptive_train_surfaces_telemetry() {
        let (store, b, stop) = setup();
        let r = dispatch(
            r#"{"op":"train","name":"ad","dataset":"bimodal","n":150,"sketch":"adaptive","m_max":16,"rel_tol":0.05,"d":10,"lambda":0.001,"seed":6}"#,
            &store,
            &b,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        let m = r.get("adaptive_m").and_then(|v| v.as_usize()).unwrap();
        assert!((1..=16).contains(&m), "chosen m = {m}");
        assert!(r.get("rounds").and_then(|v| v.as_usize()).unwrap() >= 1);
        assert!(r.get("sketch").and_then(|v| v.as_str()).unwrap().starts_with("adaptive_m"));
        // the stored model predicts through the batcher like any other
        let r = dispatch(
            r#"{"op":"predict","model":"ad","x":[[0.1,0.2,0.3]]}"#,
            &store,
            &b,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    }

    #[test]
    fn cluster_op_returns_labels_and_ari() {
        let (store, b, stop) = setup();
        let r = dispatch(
            r#"{"op":"cluster","dataset":"blobs","n":90,"k":3,"method":"operator","seed":11}"#,
            &store,
            &b,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(r.get("k").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(r.get("labels").and_then(|v| v.as_arr()).unwrap().len(), 90);
        let ari = r.get("ari_vs_truth").and_then(|v| v.as_f64()).unwrap();
        assert!(ari >= 0.95, "ARI {ari}");
        // bad method surfaces as a protocol error, not a panic
        let r = dispatch(
            r#"{"op":"cluster","dataset":"blobs","n":60,"k":2,"method":"nope"}"#,
            &store,
            &b,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn shutdown_sets_flag() {
        let (store, b, stop) = setup();
        dispatch(r#"{"op":"shutdown"}"#, &store, &b, &stop);
        assert!(stop.load(Ordering::Relaxed));
    }

    #[test]
    fn tcp_end_to_end() {
        let store = Arc::new(ModelStore::new());
        let addr = serve(
            store,
            ServerConfig {
                addr: "127.0.0.1:0".into(),
                ..Default::default()
            },
            false,
        )
        .unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        writeln!(conn, r#"{{"op":"ping"}}"#).unwrap();
        let mut line = String::new();
        BufReader::new(conn.try_clone().unwrap()).read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("pong"), Some(&Json::Bool(true)));
        writeln!(conn, r#"{{"op":"shutdown"}}"#).unwrap();
    }
}
