//! L3 coordinator — the service layer a team would deploy around the
//! library:
//!
//! * [`jobs`] — experiment job scheduler: parameter sweeps × replicates run
//!   on a worker pool with per-job RNG streams (drives every bench figure).
//! * [`state`] — model store: named trained models behind an `RwLock`, with
//!   JSON persistence (landmarks + β round-trip).
//! * [`batcher`] — dynamic batcher: concurrent predict requests are
//!   coalesced (per model) up to a batch cap / deadline before hitting the
//!   compute path — the same discipline a serving system applies in front
//!   of fixed-shape accelerators.
//! * [`server`] — threaded TCP server speaking newline-delimited JSON
//!   (`train` / `predict` / `models` / `metrics` / `ping`).

pub mod batcher;
pub mod jobs;
pub mod server;
pub mod state;

pub use batcher::{Batcher, BatcherConfig};
pub use jobs::{JobScheduler, SweepPoint};
pub use server::{serve, ServerConfig};
pub use state::{ModelStore, StoredModel, TrainRequest};
