//! L3 coordinator — the service layer a team would deploy around the
//! library:
//!
//! * [`jobs`] — experiment job scheduler: parameter sweeps × replicates run
//!   on a worker pool with per-job RNG streams (drives every bench figure
//!   and the `cluster` job's per-k model-selection sweep).
//! * [`state`] — model store: named trained models behind an `RwLock`, with
//!   JSON persistence (landmarks + β round-trip); also hosts the stateless
//!   job runners shared by the TCP server and the CLI
//!   ([`state::run_cluster_job`], [`state::parse_sketch_spec`]).
//! * [`batcher`] — dynamic batcher: concurrent predict requests are
//!   coalesced (per model) up to a batch cap / deadline before hitting the
//!   compute path — the same discipline a serving system applies in front
//!   of fixed-shape accelerators.
//! * [`server`] — threaded TCP server speaking newline-delimited JSON
//!   (`train` / `predict` / `cluster` / `models` / `metrics` / `ping`).
//!   `train` accepts an optional `"precision":"f32"` field to route
//!   one-shot fits through single-precision Gram assembly (the
//!   [`Precision`](crate::linalg::Precision) knob; `d×d` solves stay
//!   f64, adaptive fits ignore it).
//!
//! # The `cluster` job kind
//!
//! The spectral-clustering workload ([`crate::cluster`]) as a stateless
//! job: generate (or load) a dataset, embed through the streamed
//! Laplacian operator, cluster, reply with the labels. Request fields
//! (defaults in parentheses):
//!
//! ```text
//! {"op":"cluster",
//!  "dataset":"blobs",          // blobs | moons | rings (labelled) or any
//!                              // train dataset / CSV path (features only)
//!  "n":600, "k":2,             // points, clusters
//!  "method":"operator",        // operator | sketched | adaptive
//!  "d":0,                      // sketch width (0 → max(4k, 32))
//!  "m":4,                      // terms for method:"sketched"
//!  "m_max":16, "rel_tol":0.05, // adaptive-m growth bounds
//!  "bandwidth":0.0,            // kernel bandwidth (0 → dataset default)
//!  "seed":1,
//!  "k_max":0}                  // ≥2 → embed at k_max+1, sweep k∈2..=k_max
//!                              //      (JobScheduler), pick k by eigengap
//! ```
//!
//! Reply: `{"ok":true, "k", "labels":[…], "sizes":[…],
//! "eigenvalues":[…]` (bottom Laplacian spectrum, ascending)`,
//! "inertia", "secs"` plus `"chosen_m"` for sketched/adaptive embeddings,
//! `"ari_vs_truth"` for the labelled generators, and `"sweep":[{"k",
//! "inertia", "eigengap"}…]` when `k_max` triggered model selection.

pub mod batcher;
pub mod jobs;
pub mod server;
pub mod state;

pub use batcher::{Batcher, BatcherConfig};
pub use jobs::{JobScheduler, SweepPoint};
pub use server::{serve, ServerConfig};
pub use state::{ClusterRequest, ModelStore, StoredModel, TrainRequest};
