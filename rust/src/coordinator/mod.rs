//! L3 coordinator — the service layer a team would deploy around the
//! library:
//!
//! * [`jobs`] — experiment job scheduler: parameter sweeps × replicates run
//!   on a worker pool with per-job RNG streams (drives every bench figure
//!   and the `cluster` job's per-k model-selection sweep).
//! * [`state`] — model store: named trained models behind sharded
//!   `RwLock`s (name-hashed, so serving-path reads don't contend with a
//!   concurrent `train`), with JSON persistence (landmarks + β
//!   round-trip); also hosts the stateless job runners shared by the TCP
//!   server and the CLI ([`state::run_cluster_job`],
//!   [`state::parse_sketch_spec`]).
//! * [`batcher`] — adaptive micro-batcher: concurrent predict requests
//!   are coalesced (per model) into one cross-kernel GEMM. The wait for
//!   co-riders scales with the observed arrival rate — zero at low load
//!   (lone requests are served immediately), growing toward the cap as
//!   the queue heats up (DESIGN.md §9).
//! * [`frame`] — the v2 wire format: 4-byte big-endian length-prefixed
//!   JSON frames, plus the incremental [`frame::Decoder`] both protocols
//!   share.
//! * [`client`] — the retrying client: bounded attempts, exponential
//!   backoff with seeded jitter, reconnect on transport errors,
//!   idempotent-only resends, and an `err_code` tally for observability.
//! * [`metrics`] — lock-free serving counters and fixed-bucket
//!   log-spaced histograms (latency quantiles, batch-size distribution)
//!   behind the `metrics` op.
//! * `reactor` (crate-private) — the single-threaded readiness loop
//!   driving every connection: non-blocking sockets, per-connection
//!   bounded write queues, load shedding, `mpsc`-based completion/wake.
//! * [`server`] — the TCP serving front end tying the above together
//!   (`train` / `predict` / `cluster` / `models` / `metrics` / `ping` /
//!   `shutdown`). `train` accepts an optional `"precision":"f32"` field
//!   to route one-shot fits through single-precision Gram assembly (the
//!   [`Precision`](crate::linalg::Precision) knob; `d×d` solves stay
//!   f64, adaptive fits ignore it).
//!
//! # Wire protocols
//!
//! Every connection speaks one of two protocols, auto-detected from its
//! first byte and fixed for the connection's lifetime:
//!
//! **v1 (legacy)** — newline-delimited JSON, one request per line, one
//! reply per line, replies in request order. First byte `{` (or
//! whitespace). Byte-compatible with every pre-v2 client.
//!
//! **v2 (framed)** — each message is a 4-byte big-endian length header
//! followed by that many bytes of UTF-8 JSON. The frame cap is 8 MiB
//! ([`frame::MAX_FRAME`]), so a header's first byte is always `0x00` —
//! that is the sniff. Requests carry `method` (the operation; `op` is
//! accepted as an alias), optionally `id` (any JSON value), and
//! optionally `deadline_ms` (integer): a budget after which the server
//! answers `deadline_exceeded` instead of spending compute on a reply
//! nobody is waiting for. Replies are multiplexed: they arrive as their
//! handlers finish, **not** necessarily in request order, and every
//! reply envelope guarantees
//!
//! ```text
//! {"id": <echoed id, if the request had one>,
//!  "method": "<echoed method>",
//!  "ok": true|false,
//!  "err"/"error": "<message, mirrored under both keys when present>",
//!  "err_code": "<stable failure class, present whenever ok is false>",
//!  ...op-specific fields}
//! ```
//!
//! `err_code` is the machine contract ([`crate::util::ErrorKind`]):
//! `invalid_input` | `overloaded` | `deadline_exceeded` |
//! `model_unhealthy` | `numeric_failure` | `internal`. Messages may be
//! reworded; codes never. Legacy (v1) replies predate the taxonomy and
//! stay byte-identical — the reactor strips `err_code` before newline
//! encoding (DESIGN.md §10).
//!
//! Pipelining is unlimited up to the backpressure bounds: a connection
//! with more than `max_inflight` outstanding requests, or more than
//! `high_water_bytes` of unread reply bytes, gets
//! `{"ok":false,"err":"overloaded"}` immediately (and a `shed` metrics
//! tick) instead of queueing without bound. Malformed JSON gets a
//! structured `bad json` error; an oversized frame is answered then the
//! connection closes (the stream cannot be resynchronised).
//!
//! # The `cluster` job kind
//!
//! The spectral-clustering workload ([`crate::cluster`]) as a stateless
//! job: generate (or load) a dataset, embed through the streamed
//! Laplacian operator, cluster, reply with the labels. Request fields
//! (defaults in parentheses):
//!
//! ```text
//! {"op":"cluster",
//!  "dataset":"blobs",          // blobs | moons | rings (labelled) or any
//!                              // train dataset / CSV path (features only)
//!  "n":600, "k":2,             // points, clusters
//!  "method":"operator",        // operator | sketched | adaptive
//!  "d":0,                      // sketch width (0 → max(4k, 32))
//!  "m":4,                      // terms for method:"sketched"
//!  "m_max":16, "rel_tol":0.05, // adaptive-m growth bounds
//!  "bandwidth":0.0,            // kernel bandwidth (0 → dataset default)
//!  "seed":1,
//!  "k_max":0}                  // ≥2 → embed at k_max+1, sweep k∈2..=k_max
//!                              //      (JobScheduler), pick k by eigengap
//! ```
//!
//! # Out-of-core data specs
//!
//! `train` and `cluster` both accept an optional `"data"` object
//! pointing the job at feature rows already on disk instead of a named
//! generator ([`state::DataSpec`], DESIGN.md §12). The whole job then
//! streams row tiles through the [`crate::data::TileSource`] — `X` is
//! never fully resident — and produces results bitwise identical to the
//! same rows processed in memory:
//!
//! ```text
//! {"op":"train", "name":"m",
//!  "data":{"kind":"file",      // file | shards
//!          "path":"x.bin",     // f64 LE row-major file / shard dir
//!          "dim":8,            // features per row (file kind only;
//!                              //  shards read it from manifest.json)
//!          "y":"y.bin"},       // targets, f64 LE, length n (train only)
//!  ...}
//! ```
//!
//! When `"data"` is present, `dataset`/`n` are ignored (the file's row
//! count is authoritative), rows are consumed as stored (writers
//! pre-normalize), and the kernel is Matérn-3/2 (`train`) or Gaussian
//! (`cluster`) at the requested bandwidth.
//!
//! Reply: `{"ok":true, "k", "labels":[…], "sizes":[…],
//! "eigenvalues":[…]` (bottom Laplacian spectrum, ascending)`,
//! "inertia", "secs"` plus `"chosen_m"` for sketched/adaptive embeddings,
//! `"ari_vs_truth"` for the labelled generators, and `"sweep":[{"k",
//! "inertia", "eigengap"}…]` when `k_max` triggered model selection.

pub mod batcher;
pub mod client;
pub mod frame;
pub mod jobs;
pub mod metrics;
pub(crate) mod reactor;
pub mod server;
pub mod state;

pub use batcher::{Batcher, BatcherConfig, Completion};
pub use client::{Client, ClientConfig};
pub use jobs::{JobScheduler, SweepPoint};
pub use metrics::{Histogram, ServingMetrics};
pub use server::{serve, ServerConfig, ServerHandle};
pub use state::{
    parse_data_spec, ClusterRequest, DataSpec, ModelStore, SamplingSpec, StoredModel, TrainRequest,
};
