//! Synthetic data generators.
//!
//! **Regression** — the paper's bimodal distribution (§4.1, §D.1, §D.2):
//! with probability `n/(n+n^γ)` a point is `Unif[0,1]³`; with probability
//! `n^γ/(n+n^γ)` each coordinate has pdf `4·(5−2x)` on `[2, 2.5]` (the
//! normalised version of the paper's `∏(5−2x_j)`). The minority cluster is
//! dense and far from the majority — this is precisely the high-incoherence
//! regime where plain Nyström fails (paper §3.2). The regression target is
//! `f*(x) = g(‖x‖/3)` with
//! `g(t) = 1.6|(t−0.4)(t−0.6)| − t(t−1)(t−2) − 0.5`, plus `N(0, 0.25)`
//! noise.
//!
//! **Clustering** — labelled 2-D generators for the spectral-clustering
//! workload ([`crate::cluster`], EXPERIMENTS.md §Clustering): [`blobs`]
//! (isotropic Gaussians on a circle — the well-separated sanity case),
//! [`two_moons`] (interleaved half-circles — linearly inseparable, the
//! classic spectral/kernel success case), and [`rings`] (concentric
//! annuli). All three assign point `i` to cluster `i % k`, so cluster
//! sizes and the truth labels are deterministic given `n` — only the
//! within-cluster jitter consumes RNG draws.

use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// Configuration of the bimodal generator.
#[derive(Clone, Copy, Debug)]
pub struct BimodalConfig {
    /// Sample size n.
    pub n: usize,
    /// Cluster-imbalance exponent γ (0.5 in Fig. 1, 0.6 in Fig. 2).
    pub gamma: f64,
    /// Noise standard deviation (paper: 0.5, i.e. variance 0.25).
    pub noise_std: f64,
    /// Input dimension (paper: 3).
    pub dim: usize,
}

impl Default for BimodalConfig {
    fn default() -> Self {
        BimodalConfig {
            n: 1000,
            gamma: 0.6,
            noise_std: 0.5,
            dim: 3,
        }
    }
}

/// The paper's univariate shape function `g`.
fn g(t: f64) -> f64 {
    1.6 * ((t - 0.4) * (t - 0.6)).abs() - t * (t - 1.0) * (t - 2.0) - 0.5
}

/// True regression function `f*(x) = g(‖x‖/3)`.
pub fn f_star(x: &[f64]) -> f64 {
    let norm = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    g(norm / 3.0)
}

/// Draw one coordinate of the dense minority cluster: pdf `4(5−2x)` on
/// `[2, 2.5]`, by inverse CDF (`x = (5 − √(1−u))/2`).
fn minority_coord(rng: &mut Pcg64) -> f64 {
    let u = rng.uniform();
    (5.0 - (1.0 - u).sqrt()) / 2.0
}

/// Generate `(X, y, f*(X))`. The third return value (noiseless truth) lets
/// experiments compute estimation error against `f*` exactly as Figure 2's
/// reference line does.
pub fn bimodal(cfg: &BimodalConfig, rng: &mut Pcg64) -> (Matrix, Vec<f64>, Vec<f64>) {
    let n = cfg.n;
    let p_minor = (n as f64).powf(cfg.gamma) / (n as f64 + (n as f64).powf(cfg.gamma));
    let mut x = Matrix::zeros(n, cfg.dim);
    for i in 0..n {
        let minor = rng.uniform() < p_minor;
        for j in 0..cfg.dim {
            x[(i, j)] = if minor {
                minority_coord(rng)
            } else {
                rng.uniform()
            };
        }
    }
    let truth: Vec<f64> = (0..n).map(|i| f_star(x.row(i))).collect();
    let y: Vec<f64> = truth
        .iter()
        .map(|t| t + cfg.noise_std * rng.normal())
        .collect();
    (x, y, truth)
}

/// `k` isotropic Gaussian blobs (std `noise`) centred on a circle of
/// radius `sep`, `n` points total, labels `i % k`. With `sep ≫ noise`
/// the clusters are well separated — the regime the clustering
/// acceptance tests (`ARI ≥ 0.95`) and the `BENCH_cluster` comparison
/// use.
pub fn blobs(n: usize, k: usize, sep: f64, noise: f64, rng: &mut Pcg64) -> (Matrix, Vec<usize>) {
    assert!(k >= 1, "blobs: k >= 1");
    let mut x = Matrix::zeros(n, 2);
    let mut labels = vec![0usize; n];
    for i in 0..n {
        let c = i % k;
        labels[i] = c;
        let a = std::f64::consts::TAU * c as f64 / k as f64;
        x[(i, 0)] = sep * a.cos() + noise * rng.normal();
        x[(i, 1)] = sep * a.sin() + noise * rng.normal();
    }
    (x, labels)
}

/// Two interleaved half-moons (the scikit-learn construction): cluster 0
/// is the upper half of the unit circle, cluster 1 the lower half shifted
/// to `(1, 0.5) − (cos t, sin t)`, plus isotropic `N(0, noise²)` jitter.
/// Labels are `i % 2`. Linearly inseparable but separable by a kernel
/// spectral embedding with a bandwidth below the inter-moon gap (≈ 0.3).
pub fn two_moons(n: usize, noise: f64, rng: &mut Pcg64) -> (Matrix, Vec<usize>) {
    let mut x = Matrix::zeros(n, 2);
    let mut labels = vec![0usize; n];
    for i in 0..n {
        let c = i % 2;
        labels[i] = c;
        // even positions sweep each moon uniformly in angle
        let t = std::f64::consts::PI * ((i / 2) as f64 + 0.5) / (n / 2).max(1) as f64;
        let (mx, my) = if c == 0 {
            (t.cos(), t.sin())
        } else {
            (1.0 - t.cos(), 0.5 - t.sin())
        };
        x[(i, 0)] = mx + noise * rng.normal();
        x[(i, 1)] = my + noise * rng.normal();
    }
    (x, labels)
}

/// Concentric rings: ring `c` has radius `radii[c]`, points get uniform
/// angles plus radial `N(0, noise²)` jitter; labels are `i % radii.len()`.
/// Euclidean k-means cannot split them; a kernel spectral embedding can.
pub fn rings(n: usize, radii: &[f64], noise: f64, rng: &mut Pcg64) -> (Matrix, Vec<usize>) {
    let k = radii.len();
    assert!(k >= 1, "rings: at least one radius");
    let mut x = Matrix::zeros(n, 2);
    let mut labels = vec![0usize; n];
    for i in 0..n {
        let c = i % k;
        labels[i] = c;
        let a = rng.uniform() * std::f64::consts::TAU;
        let r = radii[c] + noise * rng.normal();
        x[(i, 0)] = r * a.cos();
        x[(i, 1)] = r * a.sin();
    }
    (x, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_noise() {
        let mut rng = Pcg64::seed(151);
        let cfg = BimodalConfig {
            n: 500,
            ..Default::default()
        };
        let (x, y, truth) = bimodal(&cfg, &mut rng);
        assert_eq!((x.rows(), x.cols()), (500, 3));
        assert_eq!(y.len(), 500);
        // noise has roughly the configured std
        let resid: Vec<f64> = y.iter().zip(truth.iter()).map(|(a, b)| a - b).collect();
        let var = resid.iter().map(|r| r * r).sum::<f64>() / 500.0;
        assert!((var - 0.25).abs() < 0.08, "noise var {var}");
    }

    #[test]
    fn clusters_land_in_expected_boxes() {
        let mut rng = Pcg64::seed(152);
        let cfg = BimodalConfig {
            n: 2000,
            gamma: 0.6,
            ..Default::default()
        };
        let (x, _, _) = bimodal(&cfg, &mut rng);
        let mut minor = 0usize;
        for i in 0..2000 {
            let first = x[(i, 0)];
            if first >= 2.0 {
                // whole row must be in the minority box
                for j in 0..3 {
                    assert!((2.0..=2.5).contains(&x[(i, j)]));
                }
                minor += 1;
            } else {
                for j in 0..3 {
                    assert!((0.0..=1.0).contains(&x[(i, j)]));
                }
            }
        }
        // expected minority fraction = n^γ/(n+n^γ) ≈ 0.0465 for n=2000, γ=0.6
        let frac = minor as f64 / 2000.0;
        assert!((frac - 0.0465).abs() < 0.02, "minority fraction {frac}");
    }

    #[test]
    fn minority_coordinate_density_decreasing() {
        // pdf 4(5−2x) decreases on [2,2.5]: F(2.25) = 4(5·2.25 − 2.25² − 6)
        // = 0.75, so the left half holds 3/4 of the mass.
        let mut rng = Pcg64::seed(153);
        let left = (0..20_000)
            .filter(|_| minority_coord(&mut rng) < 2.25)
            .count() as f64
            / 20_000.0;
        assert!((left - 0.75).abs() < 0.015, "left mass {left}");
    }

    #[test]
    fn cluster_generators_shapes_and_labels() {
        let mut rng = Pcg64::seed(154);
        let (x, l) = blobs(91, 3, 6.0, 0.5, &mut rng);
        assert_eq!((x.rows(), x.cols()), (91, 2));
        assert_eq!(l.len(), 91);
        // deterministic label pattern i % k and near-even sizes
        for (i, &li) in l.iter().enumerate() {
            assert_eq!(li, i % 3);
        }
        let (xm, lm) = two_moons(80, 0.05, &mut rng);
        assert_eq!((xm.rows(), xm.cols()), (80, 2));
        assert!(lm.iter().all(|&c| c < 2));
        let (xr, lr) = rings(60, &[0.4, 2.0], 0.02, &mut rng);
        assert_eq!(xr.rows(), 60);
        assert!(lr.iter().all(|&c| c < 2));
        // ring radii are respected within noise
        for i in 0..60 {
            let r = (xr[(i, 0)].powi(2) + xr[(i, 1)].powi(2)).sqrt();
            let want = [0.4, 2.0][lr[i]];
            assert!((r - want).abs() < 0.2, "ring {i}: radius {r} vs {want}");
        }
    }

    #[test]
    fn blobs_are_well_separated_at_large_sep() {
        let mut rng = Pcg64::seed(155);
        let (x, l) = blobs(120, 3, 6.0, 0.4, &mut rng);
        // every point is far closer to its own centre than to the others
        for i in 0..120 {
            let c = l[i];
            let a = std::f64::consts::TAU * c as f64 / 3.0;
            let (cx, cy) = (6.0 * a.cos(), 6.0 * a.sin());
            let d_own = ((x[(i, 0)] - cx).powi(2) + (x[(i, 1)] - cy).powi(2)).sqrt();
            assert!(d_own < 3.0, "point {i} strayed {d_own} from its blob");
        }
    }

    #[test]
    fn f_star_matches_g_formula() {
        // at x = 0: g(0) = 1.6·|0.24| − 0 − 0.5 = −0.116
        let v = f_star(&[0.0, 0.0, 0.0]);
        assert!((v - (1.6 * 0.24 - 0.5)).abs() < 1e-12);
    }
}
