//! The paper's synthetic bimodal distribution (§4.1, §D.1, §D.2).
//!
//! With probability `n/(n+n^γ)` a point is `Unif[0,1]³`; with probability
//! `n^γ/(n+n^γ)` each coordinate has pdf `4·(5−2x)` on `[2, 2.5]` (the
//! normalised version of the paper's `∏(5−2x_j)`). The minority cluster is
//! dense and far from the majority — this is precisely the high-incoherence
//! regime where plain Nyström fails (paper §3.2).
//!
//! The regression target is `f*(x) = g(‖x‖/3)` with
//! `g(t) = 1.6|(t−0.4)(t−0.6)| − t(t−1)(t−2) − 0.5`, plus `N(0, 0.25)`
//! noise.

use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// Configuration of the bimodal generator.
#[derive(Clone, Copy, Debug)]
pub struct BimodalConfig {
    /// Sample size n.
    pub n: usize,
    /// Cluster-imbalance exponent γ (0.5 in Fig. 1, 0.6 in Fig. 2).
    pub gamma: f64,
    /// Noise standard deviation (paper: 0.5, i.e. variance 0.25).
    pub noise_std: f64,
    /// Input dimension (paper: 3).
    pub dim: usize,
}

impl Default for BimodalConfig {
    fn default() -> Self {
        BimodalConfig {
            n: 1000,
            gamma: 0.6,
            noise_std: 0.5,
            dim: 3,
        }
    }
}

/// The paper's univariate shape function `g`.
fn g(t: f64) -> f64 {
    1.6 * ((t - 0.4) * (t - 0.6)).abs() - t * (t - 1.0) * (t - 2.0) - 0.5
}

/// True regression function `f*(x) = g(‖x‖/3)`.
pub fn f_star(x: &[f64]) -> f64 {
    let norm = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    g(norm / 3.0)
}

/// Draw one coordinate of the dense minority cluster: pdf `4(5−2x)` on
/// `[2, 2.5]`, by inverse CDF (`x = (5 − √(1−u))/2`).
fn minority_coord(rng: &mut Pcg64) -> f64 {
    let u = rng.uniform();
    (5.0 - (1.0 - u).sqrt()) / 2.0
}

/// Generate `(X, y, f*(X))`. The third return value (noiseless truth) lets
/// experiments compute estimation error against `f*` exactly as Figure 2's
/// reference line does.
pub fn bimodal(cfg: &BimodalConfig, rng: &mut Pcg64) -> (Matrix, Vec<f64>, Vec<f64>) {
    let n = cfg.n;
    let p_minor = (n as f64).powf(cfg.gamma) / (n as f64 + (n as f64).powf(cfg.gamma));
    let mut x = Matrix::zeros(n, cfg.dim);
    for i in 0..n {
        let minor = rng.uniform() < p_minor;
        for j in 0..cfg.dim {
            x[(i, j)] = if minor {
                minority_coord(rng)
            } else {
                rng.uniform()
            };
        }
    }
    let truth: Vec<f64> = (0..n).map(|i| f_star(x.row(i))).collect();
    let y: Vec<f64> = truth
        .iter()
        .map(|t| t + cfg.noise_std * rng.normal())
        .collect();
    (x, y, truth)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_noise() {
        let mut rng = Pcg64::seed(151);
        let cfg = BimodalConfig {
            n: 500,
            ..Default::default()
        };
        let (x, y, truth) = bimodal(&cfg, &mut rng);
        assert_eq!((x.rows(), x.cols()), (500, 3));
        assert_eq!(y.len(), 500);
        // noise has roughly the configured std
        let resid: Vec<f64> = y.iter().zip(truth.iter()).map(|(a, b)| a - b).collect();
        let var = resid.iter().map(|r| r * r).sum::<f64>() / 500.0;
        assert!((var - 0.25).abs() < 0.08, "noise var {var}");
    }

    #[test]
    fn clusters_land_in_expected_boxes() {
        let mut rng = Pcg64::seed(152);
        let cfg = BimodalConfig {
            n: 2000,
            gamma: 0.6,
            ..Default::default()
        };
        let (x, _, _) = bimodal(&cfg, &mut rng);
        let mut minor = 0usize;
        for i in 0..2000 {
            let first = x[(i, 0)];
            if first >= 2.0 {
                // whole row must be in the minority box
                for j in 0..3 {
                    assert!((2.0..=2.5).contains(&x[(i, j)]));
                }
                minor += 1;
            } else {
                for j in 0..3 {
                    assert!((0.0..=1.0).contains(&x[(i, j)]));
                }
            }
        }
        // expected minority fraction = n^γ/(n+n^γ) ≈ 0.0465 for n=2000, γ=0.6
        let frac = minor as f64 / 2000.0;
        assert!((frac - 0.0465).abs() < 0.02, "minority fraction {frac}");
    }

    #[test]
    fn minority_coordinate_density_decreasing() {
        // pdf 4(5−2x) decreases on [2,2.5]: F(2.25) = 4(5·2.25 − 2.25² − 6)
        // = 0.75, so the left half holds 3/4 of the mass.
        let mut rng = Pcg64::seed(153);
        let left = (0..20_000)
            .filter(|_| minority_coord(&mut rng) < 2.25)
            .count() as f64
            / 20_000.0;
        assert!((left - 0.75).abs() < 0.015, "left mass {left}");
    }

    #[test]
    fn f_star_matches_g_formula() {
        // at x = 0: g(0) = 1.6·|0.24| − 0 − 0.5 = −0.116
        let v = f_star(&[0.0, 0.0, 0.0]);
        assert!((v - (1.6 * 0.24 - 0.5)).abs() < 1e-12);
    }
}
