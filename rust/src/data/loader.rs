//! Dataset container, CSV ingestion, preprocessing, splits.

use crate::linalg::Matrix;
use crate::rng::Pcg64;
use crate::util::csv;

/// A regression dataset ready for KRR.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Feature rows.
    pub x: Matrix,
    /// Responses.
    pub y: Vec<f64>,
}

impl Dataset {
    /// Number of rows.
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    /// Take the first `n` rows (after an external shuffle).
    pub fn head(&self, n: usize) -> Dataset {
        let n = n.min(self.n());
        Dataset {
            x: self.x.slice(0, n, 0, self.x.cols()),
            y: self.y[..n].to_vec(),
        }
    }

    /// Shuffle rows in place.
    pub fn shuffle(&mut self, rng: &mut Pcg64) {
        let n = self.n();
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut x = Matrix::zeros(n, self.x.cols());
        let mut y = vec![0.0; n];
        for (dst, &src) in order.iter().enumerate() {
            x.row_mut(dst).copy_from_slice(self.x.row(src));
            y[dst] = self.y[src];
        }
        self.x = x;
        self.y = y;
    }
}

/// Load a numeric CSV whose **last column is the response** (the layout of
/// the UCI RQA/CASP/GAS files after their header row). Streams the file
/// line by line (`BufRead` into a reused buffer) instead of slurping it
/// with `read_to_string`, so ingestion cost is one parsed copy of the
/// values — never text + values — matching the out-of-core story
/// (DESIGN.md §12).
pub fn load_csv_dataset(path: &str, skip_header: bool) -> Result<Dataset, String> {
    let file = std::fs::File::open(path).map_err(|e| format!("{path}: {e}"))?;
    let m = csv::parse_numeric_reader(std::io::BufReader::new(file), skip_header)?;
    if m.cols() < 2 {
        return Err("dataset needs ≥ 1 feature + response".into());
    }
    let p = m.cols() - 1;
    let x = m.slice(0, m.rows(), 0, p);
    let y = (0..m.rows()).map(|i| m[(i, p)]).collect();
    Ok(Dataset { x, y })
}

/// Normalise every feature to unit variance (paper §4.2: "normalizing the
/// features to have variance 1"). Returns the per-feature scales applied.
pub fn normalize_features(x: &mut Matrix) -> Vec<f64> {
    let (n, p) = (x.rows(), x.cols());
    let mut scales = vec![1.0; p];
    if n == 0 {
        return scales;
    }
    for j in 0..p {
        let mean: f64 = (0..n).map(|i| x[(i, j)]).sum::<f64>() / n as f64;
        let var: f64 = (0..n).map(|i| (x[(i, j)] - mean).powi(2)).sum::<f64>() / n as f64;
        let sd = var.sqrt();
        if sd > 1e-12 {
            scales[j] = 1.0 / sd;
            for i in 0..n {
                x[(i, j)] *= scales[j];
            }
        }
    }
    scales
}

/// Random train/test split with the given test fraction (paper: 20%).
pub fn train_test_split(ds: &Dataset, test_frac: f64, rng: &mut Pcg64) -> (Dataset, Dataset) {
    let n = ds.n();
    let n_test = ((n as f64 * test_frac).round() as usize).min(n);
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let (test_idx, train_idx) = order.split_at(n_test);
    let take = |idx: &[usize]| -> Dataset {
        let mut x = Matrix::zeros(idx.len(), ds.x.cols());
        let mut y = vec![0.0; idx.len()];
        for (dst, &src) in idx.iter().enumerate() {
            x.row_mut(dst).copy_from_slice(ds.x.row(src));
            y[dst] = ds.y[src];
        }
        Dataset { x, y }
    };
    (take(train_idx), take(test_idx))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset {
            x: Matrix::from_fn(10, 2, |i, j| (i * 2 + j) as f64),
            y: (0..10).map(|i| i as f64).collect(),
        }
    }

    #[test]
    fn split_partitions_rows() {
        let ds = toy();
        let mut rng = Pcg64::seed(171);
        let (train, test) = train_test_split(&ds, 0.2, &mut rng);
        assert_eq!(train.n(), 8);
        assert_eq!(test.n(), 2);
        // every y value appears exactly once across the split
        let mut all: Vec<f64> = train.y.iter().chain(test.y.iter()).cloned().collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(all, (0..10).map(|i| i as f64).collect::<Vec<_>>());
    }

    #[test]
    fn normalize_gives_unit_variance() {
        let mut x = Matrix::from_fn(50, 2, |i, j| (i as f64) * (j as f64 + 0.5) * 3.0);
        normalize_features(&mut x);
        for j in 0..2 {
            let mean: f64 = (0..50).map(|i| x[(i, j)]).sum::<f64>() / 50.0;
            let var: f64 = (0..50).map(|i| (x[(i, j)] - mean).powi(2)).sum::<f64>() / 50.0;
            assert!((var - 1.0).abs() < 1e-9, "var={var}");
        }
    }

    #[test]
    fn constant_feature_untouched() {
        let mut x = Matrix::from_fn(10, 1, |_, _| 3.0);
        let scales = normalize_features(&mut x);
        assert_eq!(scales[0], 1.0);
        assert_eq!(x[(0, 0)], 3.0);
    }

    #[test]
    fn csv_roundtrip_via_tempfile() {
        let path = std::env::temp_dir().join("accumkrr_loader_test.csv");
        std::fs::write(&path, "a,b,y\n1,2,3\n4,5,6\n").unwrap();
        let ds = load_csv_dataset(path.to_str().unwrap(), true).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.x.cols(), 2);
        assert_eq!(ds.y, vec![3.0, 6.0]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn multi_megabyte_csv_streams_with_unchanged_behavior() {
        // Regression for the read_to_string → BufRead switch: a CSV well
        // past any internal buffer size must round-trip with identical
        // shape, values, and error context to the in-memory parser.
        use std::io::Write;
        let path = std::env::temp_dir().join("accumkrr_loader_big.csv");
        let (n, p) = (40_000usize, 7usize);
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
        writeln!(f, "{},y", (0..p - 1).map(|j| format!("f{j}")).collect::<Vec<_>>().join(",")).unwrap();
        for i in 0..n {
            let row: Vec<String> =
                (0..p).map(|j| format!("{:.6}", ((i * p + j) as f64).sin())).collect();
            writeln!(f, "{}", row.join(",")).unwrap();
        }
        drop(f);
        assert!(std::fs::metadata(&path).unwrap().len() > 2_000_000, "fixture must be multi-MB");
        let ds = load_csv_dataset(path.to_str().unwrap(), true).unwrap();
        assert_eq!((ds.n(), ds.x.cols()), (n, p - 1));
        for &i in &[0usize, 1, 12_345, n - 1] {
            for j in 0..p - 1 {
                assert_eq!(ds.x[(i, j)], format!("{:.6}", ((i * p + j) as f64).sin()).parse::<f64>().unwrap());
            }
            assert_eq!(ds.y[i], format!("{:.6}", ((i * p + p - 1) as f64).sin()).parse::<f64>().unwrap());
        }
        // error context is unchanged: corrupt one field deep in the file
        // and expect the same line/col message the in-memory parser gives
        let text = std::fs::read_to_string(&path).unwrap();
        let needle = format!("{:.6}", ((12_345 * p + 3) as f64).sin());
        let bad = text.replacen(&needle, "not_a_number", 1);
        assert_ne!(text, bad, "corruption target must exist");
        drop(text);
        std::fs::write(&path, &bad).unwrap();
        let stream_err = load_csv_dataset(path.to_str().unwrap(), true).unwrap_err();
        let mem_err = crate::util::csv::parse_numeric(&bad, true).unwrap_err();
        assert_eq!(stream_err, mem_err);
        assert!(stream_err.contains("not a number"), "{stream_err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn head_and_shuffle_preserve_multiset() {
        let mut ds = toy();
        let mut rng = Pcg64::seed(172);
        ds.shuffle(&mut rng);
        let mut y = ds.y.clone();
        y.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(y, (0..10).map(|i| i as f64).collect::<Vec<_>>());
        assert_eq!(ds.head(4).n(), 4);
    }
}
