//! Simulated surrogates for the paper's three UCI datasets.
//!
//! The offline image does not bundle the UCI files, so Figures 3–5 run on
//! synthetic datasets matched to the originals in (rows, features), feature
//! normalisation, noise level and — the property the experiments actually
//! stress — *nontrivial incoherence* (cluster imbalance / heavy tails).
//! Drop the real CSVs into `data/` and the loader path reproduces the
//! figures on the originals instead.

use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// A simulated regression dataset.
#[derive(Clone, Debug)]
pub struct UciSim {
    /// Short name used in bench output (`rqa`, `casp`, `gas`).
    pub name: &'static str,
    /// Feature matrix (already feature-normalised downstream).
    pub x: Matrix,
    /// Response vector.
    pub y: Vec<f64>,
    /// Number of features d_X (drives the paper's λ(n), d(n) schedules).
    pub dx: usize,
}

/// RadiusQueriesAggregation surrogate: 4 features (query center x/y,
/// radius, selectivity-style), smooth multiplicative response with a
/// minority cluster of "far" queries for incoherence.
pub fn rqa_sim(n: usize, rng: &mut Pcg64) -> UciSim {
    let dx = 4;
    let mut x = Matrix::zeros(n, dx);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let minority = rng.uniform() < 0.04;
        let (cx, cy) = if minority {
            (6.0 + 0.2 * rng.uniform(), 6.0 + 0.2 * rng.uniform())
        } else {
            (rng.uniform() * 2.0, rng.uniform() * 2.0)
        };
        let radius = 0.1 + rng.uniform();
        let sel = rng.uniform();
        x[(i, 0)] = cx;
        x[(i, 1)] = cy;
        x[(i, 2)] = radius;
        x[(i, 3)] = sel;
        // aggregate count ∝ area × local density with smooth falloff
        let density = (-0.3 * (cx * cx + cy * cy).sqrt()).exp() + 0.2 * sel;
        y[i] = radius * radius * std::f64::consts::PI * density * 10.0 + 0.3 * rng.normal();
    }
    UciSim {
        name: "rqa",
        x,
        y,
        dx,
    }
}

/// CASP (protein tertiary structure) surrogate: 9 heavy-tailed
/// physicochemical-style features, additive nonlinear response (RMSD-like,
/// nonnegative).
pub fn casp_sim(n: usize, rng: &mut Pcg64) -> UciSim {
    let dx = 9;
    let mut x = Matrix::zeros(n, dx);
    let mut y = vec![0.0; n];
    for i in 0..n {
        // heavy tails: |t|^1.5-distorted normals, correlated pairs
        let base: Vec<f64> = (0..dx).map(|_| rng.normal()).collect();
        for j in 0..dx {
            let corr = if j > 0 { 0.4 * base[j - 1] } else { 0.0 };
            let t = base[j] + corr;
            x[(i, j)] = t.signum() * t.abs().powf(1.3);
        }
        let r = x.row(i);
        let nonlinear = (r[0] - r[1]).tanh() + 0.5 * (r[2] * r[3]).sin()
            + 0.3 * r[4].abs().sqrt()
            + 0.2 * (r[5] + r[6]).cos()
            + 0.1 * r[7] * r[8];
        y[i] = (5.0 + 3.0 * nonlinear + 0.8 * rng.normal()).max(0.0);
    }
    UciSim {
        name: "casp",
        x,
        y,
        dx,
    }
}

/// PPGasEmission surrogate: 10 correlated sensor features with a seasonal
/// drift component; response is a NOx-like emission level.
pub fn gas_sim(n: usize, rng: &mut Pcg64) -> UciSim {
    let dx = 10;
    let mut x = Matrix::zeros(n, dx);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let season = (i as f64 / n as f64) * std::f64::consts::TAU;
        let ambient = 15.0 + 10.0 * season.sin() + 2.0 * rng.normal();
        let load = 50.0 + 30.0 * rng.uniform() + 5.0 * season.cos();
        for j in 0..dx {
            // sensors: mixtures of ambient, load, and idiosyncratic noise
            let a = 0.3 + 0.05 * j as f64;
            x[(i, j)] = a * ambient + (1.0 - a) * load / 10.0 + 0.5 * rng.normal();
        }
        let r = x.row(i);
        y[i] = 60.0 + 0.8 * r[0] - 0.5 * r[3] + 0.02 * (r[5] * r[7])
            + 4.0 * (r[2] / 10.0).sin()
            + 1.5 * rng.normal();
    }
    UciSim {
        name: "gas",
        x,
        y,
        dx,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_paper_feature_counts() {
        let mut rng = Pcg64::seed(161);
        let r = rqa_sim(100, &mut rng);
        assert_eq!((r.x.rows(), r.x.cols(), r.dx), (100, 4, 4));
        let c = casp_sim(80, &mut rng);
        assert_eq!((c.x.cols(), c.dx), (9, 9));
        let g = gas_sim(80, &mut rng);
        assert_eq!((g.x.cols(), g.dx), (10, 10));
    }

    #[test]
    fn responses_have_signal() {
        // fitting the mean should be beatable: response variance must
        // substantially exceed the injected noise floor
        let mut rng = Pcg64::seed(162);
        for sim in [rqa_sim(400, &mut rng), casp_sim(400, &mut rng), gas_sim(400, &mut rng)] {
            let mean = sim.y.iter().sum::<f64>() / 400.0;
            let var = sim.y.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / 400.0;
            assert!(var > 0.5, "{}: var={var}", sim.name);
        }
    }

    #[test]
    fn rqa_has_minority_cluster() {
        let mut rng = Pcg64::seed(163);
        let r = rqa_sim(2000, &mut rng);
        let far = (0..2000).filter(|&i| r.x[(i, 0)] > 5.0).count();
        let frac = far as f64 / 2000.0;
        assert!((frac - 0.04).abs() < 0.02, "minority fraction {frac}");
    }

    #[test]
    fn casp_heavy_tails() {
        let mut rng = Pcg64::seed(164);
        let c = casp_sim(3000, &mut rng);
        // kurtosis of first feature should exceed the Gaussian value 3
        let col: Vec<f64> = (0..3000).map(|i| c.x[(i, 0)]).collect();
        let mean = col.iter().sum::<f64>() / 3000.0;
        let m2 = col.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / 3000.0;
        let m4 = col.iter().map(|v| (v - mean).powi(4)).sum::<f64>() / 3000.0;
        let kurt = m4 / (m2 * m2);
        assert!(kurt > 3.5, "kurtosis {kurt}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::seed(7);
        let mut b = Pcg64::seed(7);
        let ra = rqa_sim(50, &mut a);
        let rb = rqa_sim(50, &mut b);
        assert_eq!(ra.x.data(), rb.x.data());
        assert_eq!(ra.y, rb.y);
    }
}
