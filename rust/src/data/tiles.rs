//! Out-of-core tile sources — the storage layer under every row tile.
//!
//! `GramOperator` freed the pipeline from the `O(n²)` kernel matrix by
//! streaming `K[tile, :]`; the last residency wall was `X` itself. A
//! [`TileSource`] abstracts "the rows of X" behind one operation —
//! [`fill_tile`](TileSource::fill_tile) copies rows `r0..r1` into a
//! caller-owned buffer — so every consumer (fit, adaptive, KPCA,
//! leverage, ksat, clustering) runs at `O(tile·p + n·d)` resident with
//! the dataset on disk.
//!
//! Three backends:
//!
//! * **in-memory** — [`Matrix`] itself implements the trait (row copies
//!   out of the resident buffer; [`as_matrix`](TileSource::as_matrix)
//!   exposes the zero-copy fast path), so every existing `&Matrix` call
//!   site coerces to `&dyn TileSource` unchanged;
//! * [`F64File`] — one headerless little-endian f64 row-major file, read
//!   with positioned `pread`s (`std::os::unix::fs::FileExt::read_at`).
//!   No mmap crate: `pread` keeps the zero-registry-deps invariant, never
//!   takes a SIGBUS on a truncated file, and makes every byte that enters
//!   the address space an explicit, fault-injectable read;
//! * [`ShardedFile`] — a directory of row-range shards listed by a tiny
//!   JSON manifest ([`MANIFEST`]); tiles may straddle any number of shard
//!   boundaries, including a ragged final shard.
//!
//! # The equivalence contract
//!
//! Backends supply **exact bytes**: `fill_tile` must reproduce the f64
//! bit patterns of the in-memory rows, so the assembly schedule above it
//! (fixed column blocks through the row-stable GEMM — see
//! `kernels::operator`) makes every downstream result bitwise identical
//! across backends, tile sizes and thread counts. `tests/tiles.rs` pins
//! that end to end.
//!
//! File reads are wired into the `util::fault` `io.read` seam: an armed
//! fault surfaces as a [`CodedError`] from `fill_tile` and propagates up
//! the fallible (`try_*`) operator entry points — no panic, no partially
//! filled cache entry (DESIGN.md §12).

use crate::linalg::Matrix;
use crate::util::fault;
use crate::util::json::Json;
use crate::util::CodedError;
use std::collections::HashMap;
use std::fs::File;
use std::io::Write as _;
use std::os::unix::fs::FileExt;
use std::path::Path;

/// Name of the shard-directory manifest file.
pub const MANIFEST: &str = "manifest.json";

/// A random-access source of dataset rows. `fill_tile` is the single
/// primitive every streamed consumer is built on; implementations must
/// reproduce the exact f64 bit patterns of the logical matrix (see the
/// module docs for why that makes the storage backend invisible).
///
/// `Sync` because row tiles are pulled from inside pool-parallel
/// assembly loops; `Debug` so operators holding a `&dyn TileSource`
/// can keep deriving `Debug`.
pub trait TileSource: Sync + std::fmt::Debug {
    /// Number of rows `n` in the logical matrix.
    fn rows(&self) -> usize;

    /// Number of columns `p` (the feature dimension).
    fn dim(&self) -> usize;

    /// Copy rows `r0..r1` (row-major, `(r1-r0)·dim` values) into `out`.
    /// Callers pass `r0 ≤ r1 ≤ rows()` and a correctly sized buffer;
    /// violations are programmer errors (panic), while I/O failures —
    /// real or injected through the `io.read` fault seam — come back as
    /// a [`CodedError`].
    fn fill_tile(&self, r0: usize, r1: usize, out: &mut [f64]) -> Result<(), CodedError>;

    /// The resident matrix, if this source is the in-memory backend —
    /// the zero-copy fast path for consumers that genuinely need all of
    /// `X` (dense-sketch application, `SymOp::materialize`). Disk
    /// backends return `None` and those consumers fall back to
    /// [`load_all`].
    fn as_matrix(&self) -> Option<&Matrix> {
        None
    }
}

/// The in-memory backend: the matrix itself. `fill_tile` is a straight
/// row-range copy, `as_matrix` the zero-copy escape hatch — which is
/// what makes `&Matrix` coerce to `&dyn TileSource` at every call site
/// that predates the out-of-core layer.
impl TileSource for Matrix {
    fn rows(&self) -> usize {
        Matrix::rows(self)
    }

    fn dim(&self) -> usize {
        self.cols()
    }

    fn fill_tile(&self, r0: usize, r1: usize, out: &mut [f64]) -> Result<(), CodedError> {
        let p = self.cols();
        out.copy_from_slice(&self.data()[r0 * p..r1 * p]);
        Ok(())
    }

    fn as_matrix(&self) -> Option<&Matrix> {
        Some(self)
    }
}

/// Copy rows `r0..r1` of a source into a fresh tile matrix.
pub fn load_rows(src: &dyn TileSource, r0: usize, r1: usize) -> Result<Matrix, CodedError> {
    let mut t = Matrix::zeros(r1 - r0, src.dim());
    src.fill_tile(r0, r1, t.data_mut())?;
    Ok(t)
}

/// Materialise the whole source as one resident matrix — the documented
/// *exit* from the out-of-core memory model (dense-sketch application
/// and `SymOp::materialize` fallbacks only). The in-memory backend
/// short-circuits to a clone of itself.
pub fn load_all(src: &dyn TileSource) -> Result<Matrix, CodedError> {
    if let Some(m) = src.as_matrix() {
        return Ok(m.clone());
    }
    load_rows(src, 0, src.rows())
}

/// Gather selected rows (duplicates allowed, any order) into a new
/// matrix — the source-routed analogue of `kernels::gather_rows`, used
/// for landmark / support panels. One `fill_tile` per requested row.
pub fn gather_rows_source(src: &dyn TileSource, idx: &[usize]) -> Result<Matrix, CodedError> {
    let p = src.dim();
    let mut out = Matrix::zeros(idx.len(), p);
    for (r, &i) in idx.iter().enumerate() {
        let dst = &mut out.data_mut()[r * p..(r + 1) * p];
        src.fill_tile(i, i + 1, dst)?;
    }
    Ok(out)
}

/// The armed-`io.read` error every file backend returns: one stable
/// message shape so chaos tests and logs can attribute the failure to
/// the storage layer.
fn injected_read_error(path: &str) -> CodedError {
    CodedError::internal(format!("tile source {path}: injected io.read fault"))
}

fn read_error(path: &str, e: std::io::Error) -> CodedError {
    CodedError::internal(format!("tile source {path}: read failed: {e}"))
}

/// Decode a little-endian f64 byte buffer into `out`.
fn decode_le_f64(bytes: &[u8], out: &mut [f64]) {
    for (dst, chunk) in out.iter_mut().zip(bytes.chunks_exact(8)) {
        *dst = f64::from_le_bytes(chunk.try_into().expect("8-byte chunk"));
    }
}

/// One headerless little-endian f64 row-major file. The row count is
/// derived from the file length (which must divide evenly into
/// `8·dim`-byte rows), so the on-disk format is exactly
/// `Matrix::data()`'s buffer — [`write_f64_file`] round-trips bitwise.
#[derive(Debug)]
pub struct F64File {
    file: File,
    path: String,
    rows: usize,
    dim: usize,
}

impl F64File {
    /// Open `path` as an `n×dim` f64 matrix. Length mismatches (or
    /// `dim == 0`) are `invalid_input` — malformed data specs must
    /// surface as protocol errors, never a panic mid-fit.
    pub fn open(path: &str, dim: usize) -> Result<F64File, CodedError> {
        if dim == 0 {
            return Err(CodedError::invalid_input(format!(
                "tile source {path}: dim must be >= 1"
            )));
        }
        let file = File::open(path)
            .map_err(|e| CodedError::invalid_input(format!("tile source {path}: {e}")))?;
        let len = file
            .metadata()
            .map_err(|e| CodedError::invalid_input(format!("tile source {path}: {e}")))?
            .len() as usize;
        let row_bytes = 8 * dim;
        if len % row_bytes != 0 {
            return Err(CodedError::invalid_input(format!(
                "tile source {path}: {len} bytes is not a whole number of {dim}-column f64 rows"
            )));
        }
        Ok(F64File {
            file,
            path: path.to_string(),
            rows: len / row_bytes,
            dim,
        })
    }
}

impl TileSource for F64File {
    fn rows(&self) -> usize {
        self.rows
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn fill_tile(&self, r0: usize, r1: usize, out: &mut [f64]) -> Result<(), CodedError> {
        assert!(r0 <= r1 && r1 <= self.rows, "fill_tile: row range");
        assert_eq!(out.len(), (r1 - r0) * self.dim, "fill_tile: buffer size");
        if r0 == r1 {
            return Ok(());
        }
        if fault::hit("io.read") {
            return Err(injected_read_error(&self.path));
        }
        let mut bytes = vec![0u8; out.len() * 8];
        self.file
            .read_exact_at(&mut bytes, (r0 * self.dim * 8) as u64)
            .map_err(|e| read_error(&self.path, e))?;
        decode_le_f64(&bytes, out);
        Ok(())
    }
}

/// One shard of a [`ShardedFile`]: an open handle plus the global row
/// range it covers.
#[derive(Debug)]
struct Shard {
    file: File,
    path: String,
    start: usize,
    rows: usize,
}

/// A directory of fixed-format row shards described by a
/// [`MANIFEST`] JSON file:
///
/// ```text
/// {"dim": 4,
///  "shards": [{"file": "shard-00000.bin", "rows": 1000},
///             {"file": "shard-00001.bin", "rows": 1000},
///             {"file": "shard-00002.bin", "rows": 613}]}
/// ```
///
/// Each shard is the same headerless little-endian f64 row-major format
/// as [`F64File`]; the final shard may be ragged. `fill_tile` maps a
/// global row span onto however many shards it straddles and issues one
/// positioned read per shard segment.
#[derive(Debug)]
pub struct ShardedFile {
    dir: String,
    dim: usize,
    rows: usize,
    shards: Vec<Shard>,
}

impl ShardedFile {
    /// Open a shard directory by reading and validating its manifest:
    /// every listed shard must exist with exactly `8·dim·rows` bytes, so
    /// format drift is caught at open time, not as a short read mid-fit.
    pub fn open(dir: &str) -> Result<ShardedFile, CodedError> {
        let mpath = Path::new(dir).join(MANIFEST);
        let text = std::fs::read_to_string(&mpath).map_err(|e| {
            CodedError::invalid_input(format!("tile source {}: {e}", mpath.display()))
        })?;
        let j = Json::parse(&text).map_err(|e| {
            CodedError::invalid_input(format!("tile source {}: bad manifest: {e}", mpath.display()))
        })?;
        let bad = |what: &str| {
            CodedError::invalid_input(format!(
                "tile source {}: manifest missing {what}",
                mpath.display()
            ))
        };
        let dim = j
            .get("dim")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| bad("dim"))?;
        if dim == 0 {
            return Err(CodedError::invalid_input(format!(
                "tile source {}: dim must be >= 1",
                mpath.display()
            )));
        }
        let entries = j
            .get("shards")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| bad("shards"))?;
        let mut shards = Vec::with_capacity(entries.len());
        let mut start = 0usize;
        for e in entries {
            let name = e
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| bad("shard file"))?;
            let rows = e
                .get("rows")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| bad("shard rows"))?;
            let spath = Path::new(dir).join(name);
            let file = File::open(&spath).map_err(|e| {
                CodedError::invalid_input(format!("tile source {}: {e}", spath.display()))
            })?;
            let len = file
                .metadata()
                .map_err(|e| {
                    CodedError::invalid_input(format!("tile source {}: {e}", spath.display()))
                })?
                .len() as usize;
            if len != rows * dim * 8 {
                return Err(CodedError::invalid_input(format!(
                    "tile source {}: {len} bytes, manifest says {rows} rows x {dim} cols",
                    spath.display()
                )));
            }
            shards.push(Shard {
                file,
                path: spath.display().to_string(),
                start,
                rows,
            });
            start += rows;
        }
        Ok(ShardedFile {
            dir: dir.to_string(),
            dim,
            rows: start,
            shards,
        })
    }
}

impl TileSource for ShardedFile {
    fn rows(&self) -> usize {
        self.rows
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn fill_tile(&self, r0: usize, r1: usize, out: &mut [f64]) -> Result<(), CodedError> {
        assert!(r0 <= r1 && r1 <= self.rows, "fill_tile: row range");
        assert_eq!(out.len(), (r1 - r0) * self.dim, "fill_tile: buffer size");
        if r0 == r1 {
            return Ok(());
        }
        // one fault-point evaluation per tile (not per straddled shard),
        // so nth/every trigger counts line up with fill_tile calls
        if fault::hit("io.read") {
            return Err(injected_read_error(&self.dir));
        }
        // first shard containing r0 (starts are ascending)
        let mut s = match self
            .shards
            .binary_search_by(|sh| sh.start.cmp(&r0))
        {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        let mut row = r0;
        while row < r1 {
            let sh = &self.shards[s];
            let lo = row - sh.start; // local start row within the shard
            let hi = (r1 - sh.start).min(sh.rows); // local end row
            let seg = &mut out[(row - r0) * self.dim..(sh.start + hi - r0) * self.dim];
            let mut bytes = vec![0u8; seg.len() * 8];
            sh.file
                .read_exact_at(&mut bytes, (lo * self.dim * 8) as u64)
                .map_err(|e| read_error(&sh.path, e))?;
            decode_le_f64(&bytes, seg);
            row = sh.start + hi;
            s += 1;
        }
        Ok(())
    }
}

/// Environment knob for the [`TileCache`] byte budget, in megabytes.
pub const CACHE_BUDGET_ENV: &str = "ACCUMKRR_TILE_CACHE_MB";

/// Default [`TileCache`] budget when [`CACHE_BUDGET_ENV`] is unset.
const DEFAULT_CACHE_MB: usize = 256;

#[derive(Clone, Debug)]
struct CacheSlot {
    row: usize,
    col: Vec<f64>,
    pinned: bool,
    /// Second-chance bit; a `Cell` so reads (`get`) can mark recency
    /// without `&mut`.
    referenced: std::cell::Cell<bool>,
}

/// Byte-budgeted working set of f64 columns keyed by row index — the
/// explicit form of `IncrementalGram`'s support-column cache
/// (DESIGN.md §12).
///
/// * **Pinned** entries (the accumulated sketch's support columns — the
///   solver's live working set) are exempt from eviction and may carry
///   the cache past its budget; the budget then bounds only the
///   *opportunistic* population (seeded landmark panels, refinement
///   leftovers).
/// * Unpinned entries are evicted by a deterministic **clock**
///   (second-chance) sweep: a hand walks the slot ring, clears one
///   referenced bit per pass, and frees the first unreferenced unpinned
///   slot. Slot positions are assigned from an explicit free list (never
///   compacted), so the ring order — and therefore every eviction
///   decision — is a pure function of the operation sequence: no
///   hashing, clocks, or addresses involved, keeping cache behavior
///   bit-reproducible across runs, backends, and thread counts.
/// * Entries are inserted whole (a column is computed, *then* cached),
///   so a failed source read can never leave a partially filled column
///   behind — the chaos suite pins that.
///
/// Budget accounting covers column payload bytes (`8·len`); the default
/// comes from [`CACHE_BUDGET_ENV`] (megabytes, default 256). Tests use
/// [`set_budget`](TileCache::set_budget) rather than the env var to
/// avoid cross-test races.
#[derive(Clone, Debug)]
pub struct TileCache {
    slots: Vec<Option<CacheSlot>>,
    free: Vec<usize>,
    index: HashMap<usize, usize>,
    hand: usize,
    budget: usize,
    bytes: usize,
}

impl TileCache {
    /// Empty cache with an explicit byte budget.
    pub fn new(budget_bytes: usize) -> TileCache {
        TileCache {
            slots: Vec::new(),
            free: Vec::new(),
            index: HashMap::new(),
            hand: 0,
            budget: budget_bytes,
            bytes: 0,
        }
    }

    /// Empty cache budgeted from [`CACHE_BUDGET_ENV`] (megabytes; default
    /// 256 MB when unset or unparsable).
    pub fn from_env() -> TileCache {
        let mb = std::env::var(CACHE_BUDGET_ENV)
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .unwrap_or(DEFAULT_CACHE_MB);
        TileCache::new(mb.saturating_mul(1024 * 1024))
    }

    /// Byte budget for unpinned residency.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Payload bytes currently cached (pinned + unpinned).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Number of cached columns.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// Re-budget (the test hook) and evict down to the new budget.
    pub fn set_budget(&mut self, budget_bytes: usize) {
        self.budget = budget_bytes;
        self.evict_to_budget();
    }

    /// Is this row's column cached?
    pub fn contains(&self, row: usize) -> bool {
        self.index.contains_key(&row)
    }

    /// Fetch a cached column, marking it recently used.
    pub fn get(&self, row: usize) -> Option<&[f64]> {
        let &i = self.index.get(&row)?;
        let s = self.slots[i].as_ref().expect("indexed slot is occupied");
        s.referenced.set(true);
        Some(&s.col)
    }

    /// Pin an already-cached row into the working set (no-op if absent);
    /// returns whether the row was present.
    pub fn pin(&mut self, row: usize) -> bool {
        match self.index.get(&row) {
            Some(&i) => {
                self.slots[i].as_mut().expect("indexed slot is occupied").pinned = true;
                true
            }
            None => false,
        }
    }

    /// Insert a complete column. If the row is already cached the
    /// existing column is kept (columns are immutable for a fixed
    /// dataset) and only upgraded to `pinned` if requested. New entries
    /// may trigger clock eviction of unpinned columns down to the
    /// budget.
    pub fn insert(&mut self, row: usize, col: Vec<f64>, pinned: bool) {
        if let Some(&i) = self.index.get(&row) {
            let s = self.slots[i].as_mut().expect("indexed slot is occupied");
            s.pinned |= pinned;
            s.referenced.set(true);
            return;
        }
        self.bytes += col.len() * 8;
        let slot = CacheSlot {
            row,
            col,
            pinned,
            referenced: std::cell::Cell::new(true),
        };
        let i = match self.free.pop() {
            Some(i) => {
                self.slots[i] = Some(slot);
                i
            }
            None => {
                self.slots.push(Some(slot));
                self.slots.len() - 1
            }
        };
        self.index.insert(row, i);
        self.evict_to_budget();
    }

    /// Rows currently cached, sorted ascending.
    pub fn cached_rows(&self) -> Vec<usize> {
        let mut rows: Vec<usize> = self.index.keys().copied().collect();
        rows.sort_unstable();
        rows
    }

    /// Clock sweep: while over budget, advance the hand over the slot
    /// ring, give referenced slots a second chance, and evict the first
    /// unreferenced unpinned slot. Gives up after two full revolutions
    /// without an eviction (everything left is pinned).
    fn evict_to_budget(&mut self) {
        if self.slots.is_empty() {
            return;
        }
        let mut idle_steps = 0usize;
        while self.bytes > self.budget && idle_steps < 2 * self.slots.len() {
            if self.hand >= self.slots.len() {
                self.hand = 0;
            }
            let i = self.hand;
            self.hand = (self.hand + 1) % self.slots.len();
            let evict = match &self.slots[i] {
                Some(s) if !s.pinned => {
                    if s.referenced.get() {
                        s.referenced.set(false);
                        false
                    } else {
                        true
                    }
                }
                _ => false,
            };
            if evict {
                let s = self.slots[i].take().expect("slot checked occupied");
                self.index.remove(&s.row);
                self.bytes -= s.col.len() * 8;
                self.free.push(i);
                idle_steps = 0;
            } else {
                idle_steps += 1;
            }
        }
    }
}

/// Write a matrix as one headerless little-endian f64 row-major file —
/// the [`F64File`] on-disk format. Round-trips bitwise.
pub fn write_f64_file(path: &str, x: &Matrix) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(File::create(path)?);
    for v in x.data() {
        f.write_all(&v.to_le_bytes())?;
    }
    f.flush()
}

/// Write a matrix as a [`ShardedFile`] directory: `shard_rows` rows per
/// shard (the final shard ragged), plus the [`MANIFEST`].
pub fn write_shards(dir: &str, x: &Matrix, shard_rows: usize) -> std::io::Result<()> {
    assert!(shard_rows >= 1, "write_shards: shard_rows >= 1");
    std::fs::create_dir_all(dir)?;
    let p = x.cols();
    let mut entries = Vec::new();
    let mut r0 = 0usize;
    let mut idx = 0usize;
    while r0 < Matrix::rows(x) || (Matrix::rows(x) == 0 && idx == 0) {
        let r1 = (r0 + shard_rows).min(Matrix::rows(x));
        let name = format!("shard-{idx:05}.bin");
        let mut f = std::io::BufWriter::new(File::create(Path::new(dir).join(&name))?);
        for v in &x.data()[r0 * p..r1 * p] {
            f.write_all(&v.to_le_bytes())?;
        }
        f.flush()?;
        entries.push(Json::obj(vec![
            ("file", Json::Str(name)),
            ("rows", Json::from(r1 - r0)),
        ]));
        r0 = r1;
        idx += 1;
        if Matrix::rows(x) == 0 {
            break;
        }
    }
    let manifest = Json::obj(vec![
        ("dim", Json::from(p)),
        ("shards", Json::Arr(entries)),
    ]);
    std::fs::write(Path::new(dir).join(MANIFEST), manifest.to_string())
}

/// Write a vector as a headerless little-endian f64 file (targets /
/// labels riding next to a feature file).
pub fn write_f64_vec(path: &str, v: &[f64]) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(File::create(path)?);
    for x in v {
        f.write_all(&x.to_le_bytes())?;
    }
    f.flush()
}

/// Read a whole little-endian f64 vector file (the `y` side of a
/// file-backed train job — `O(n)` resident by design).
pub fn read_f64_vec(path: &str) -> Result<Vec<f64>, CodedError> {
    let bytes = std::fs::read(path)
        .map_err(|e| CodedError::invalid_input(format!("tile source {path}: {e}")))?;
    if bytes.len() % 8 != 0 {
        return Err(CodedError::invalid_input(format!(
            "tile source {path}: {} bytes is not a whole number of f64 values",
            bytes.len()
        )));
    }
    let mut out = vec![0.0f64; bytes.len() / 8];
    decode_le_f64(&bytes, &mut out);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn tmp(name: &str) -> String {
        std::env::temp_dir()
            .join(format!("accumkrr_tiles_{name}"))
            .to_string_lossy()
            .into_owned()
    }

    fn randm(seed: u64, n: usize, p: usize) -> Matrix {
        let mut r = Pcg64::seed(seed);
        Matrix::from_fn(n, p, |_, _| r.normal())
    }

    fn tile_of(src: &dyn TileSource, r0: usize, r1: usize) -> Vec<f64> {
        let mut out = vec![0.0; (r1 - r0) * src.dim()];
        src.fill_tile(r0, r1, &mut out).unwrap();
        out
    }

    #[test]
    fn matrix_backend_is_identity() {
        let x = randm(1, 13, 4);
        let src: &dyn TileSource = &x;
        assert_eq!((src.rows(), src.dim()), (13, 4));
        assert_eq!(tile_of(src, 0, 13), x.data());
        assert_eq!(tile_of(src, 5, 9), &x.data()[5 * 4..9 * 4]);
        assert!(std::ptr::eq(src.as_matrix().unwrap(), &x));
    }

    #[test]
    fn f64_file_roundtrips_bitwise() {
        let x = randm(2, 57, 3);
        let path = tmp("roundtrip.bin");
        write_f64_file(&path, &x).unwrap();
        let f = F64File::open(&path, 3).unwrap();
        assert_eq!((TileSource::rows(&f), f.dim()), (57, 3));
        assert_eq!(tile_of(&f, 0, 57), x.data());
        for &(a, b) in &[(0usize, 1usize), (10, 11), (3, 40), (56, 57), (8, 8)] {
            assert_eq!(tile_of(&f, a, b), &x.data()[a * 3..b * 3], "span {a}..{b}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn f64_file_rejects_bad_shapes() {
        let path = tmp("badlen.bin");
        std::fs::write(&path, [0u8; 20]).unwrap(); // not a multiple of 8·dim
        assert!(F64File::open(&path, 3).is_err());
        assert!(F64File::open(&path, 0).is_err());
        assert!(F64File::open(&tmp("nonexistent.bin"), 2).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sharded_roundtrips_across_boundaries() {
        let x = randm(3, 47, 5);
        let dir = tmp("shards_roundtrip");
        write_shards(&dir, &x, 10).unwrap(); // 4 full shards + ragged 7
        let s = ShardedFile::open(&dir).unwrap();
        assert_eq!((TileSource::rows(&s), s.dim()), (47, 5));
        assert_eq!(tile_of(&s, 0, 47), x.data());
        // spans inside one shard, straddling one boundary, straddling
        // several, and touching the ragged tail
        for &(a, b) in &[(2usize, 7usize), (8, 13), (5, 38), (39, 47), (46, 47)] {
            assert_eq!(tile_of(&s, a, b), &x.data()[a * 5..b * 5], "span {a}..{b}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sharded_rejects_manifest_drift() {
        let x = randm(4, 12, 2);
        let dir = tmp("shards_drift");
        write_shards(&dir, &x, 5).unwrap();
        // truncate a shard behind the manifest's back
        let victim = Path::new(&dir).join("shard-00001.bin");
        std::fs::write(&victim, [0u8; 8]).unwrap();
        assert!(ShardedFile::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
        assert!(ShardedFile::open(&tmp("no_such_dir")).is_err());
    }

    #[test]
    fn vec_file_roundtrips() {
        let v: Vec<f64> = (0..19).map(|i| (i as f64).sin()).collect();
        let path = tmp("vec.bin");
        write_f64_vec(&path, &v).unwrap();
        assert_eq!(read_f64_vec(&path).unwrap(), v);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn tile_cache_evicts_unpinned_by_clock_and_respects_pins() {
        let col = |v: f64| vec![v; 4]; // 32 bytes each
        let mut c = TileCache::new(96); // room for 3 columns
        c.insert(10, col(1.0), true); // pinned — never evicted
        c.insert(11, col(2.0), false);
        c.insert(12, col(3.0), false);
        assert_eq!((c.len(), c.bytes()), (3, 96));
        // over budget: the clock clears second-chance bits on pass one,
        // then evicts the first unpinned slot in ring order (row 11)
        c.insert(13, col(4.0), false);
        assert_eq!(c.bytes(), 96);
        assert!(c.contains(10) && !c.contains(11), "rows: {:?}", c.cached_rows());
        assert_eq!(c.cached_rows(), vec![10, 12, 13]);
        // a get() renews row 12's second chance, so the next eviction
        // passes it over and takes row 13
        assert_eq!(c.get(12).unwrap(), &[3.0; 4]);
        c.insert(14, col(5.0), false);
        assert_eq!(c.cached_rows(), vec![10, 12, 14]);
        // pins win over the budget: pinning everything lets inserts
        // exceed it rather than evict the working set
        c.pin(12);
        c.pin(14);
        c.insert(15, col(6.0), true);
        assert!(c.bytes() > c.budget());
        assert_eq!(c.cached_rows(), vec![10, 12, 14, 15]);
        // re-inserting an existing row keeps one copy and can upgrade it
        c.insert(15, col(9.0), false);
        assert_eq!(c.get(15).unwrap(), &[6.0; 4]);
        assert_eq!(c.len(), 4);
        // shrinking the budget only sheds what is unpinned (nothing here)
        c.set_budget(0);
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn tile_cache_eviction_order_is_deterministic() {
        let run = || {
            let mut c = TileCache::new(256); // 4 × 64-byte columns
            for r in 0..12usize {
                c.insert(r, vec![r as f64; 8], r % 5 == 0);
                if r % 3 == 0 {
                    c.get(r / 2);
                }
            }
            c.cached_rows()
        };
        let first = run();
        for _ in 0..5 {
            assert_eq!(run(), first);
        }
    }

    #[test]
    fn injected_read_fault_surfaces_as_coded_error_and_heals() {
        use crate::util::ErrorKind;
        let x = randm(5, 20, 3);
        let fpath = tmp("faulty.bin");
        let dir = tmp("faulty_shards");
        write_f64_file(&fpath, &x).unwrap();
        write_shards(&dir, &x, 6).unwrap();
        let f = F64File::open(&fpath, 3).unwrap();
        let s = ShardedFile::open(&dir).unwrap();
        {
            let _g = fault::scoped("io.read=every:1");
            let mut out = vec![0.0; 12];
            let ef = f.fill_tile(0, 4, &mut out).unwrap_err();
            assert_eq!(ef.kind, ErrorKind::Internal);
            let es = s.fill_tile(4, 8, &mut out).unwrap_err();
            assert_eq!(es.kind, ErrorKind::Internal);
        }
        // guard dropped: the same sources serve clean tiles again
        assert_eq!(tile_of(&f, 0, 20), x.data());
        assert_eq!(tile_of(&s, 0, 20), x.data());
        std::fs::remove_file(&fpath).ok();
        std::fs::remove_dir_all(&dir).ok();
    }
}
