//! Datasets: the paper's synthetic bimodal generator, simulated UCI
//! surrogates (see `data::ucisim` for the substitutions), a CSV loader for the real
//! files, and preprocessing (normalisation, train/test splits).

mod loader;
mod synthetic;
mod ucisim;

pub use loader::{load_csv_dataset, normalize_features, train_test_split, Dataset};
pub use synthetic::{bimodal, blobs, f_star, rings, two_moons, BimodalConfig};
pub use ucisim::{casp_sim, gas_sim, rqa_sim, UciSim};
