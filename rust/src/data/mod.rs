//! Datasets: the paper's synthetic bimodal generator, simulated UCI
//! surrogates (see `data::ucisim` for the substitutions), a CSV loader for the real
//! files, preprocessing (normalisation, train/test splits), and the
//! out-of-core [`TileSource`] storage backends (DESIGN.md §12).

mod loader;
mod synthetic;
pub mod tiles;
mod ucisim;

pub use loader::{load_csv_dataset, normalize_features, train_test_split, Dataset};
pub use synthetic::{bimodal, blobs, f_star, rings, two_moons, BimodalConfig};
pub use tiles::{
    gather_rows_source, load_all, load_rows, read_f64_vec, write_f64_file, write_f64_vec,
    write_shards, F64File, ShardedFile, TileCache, TileSource, CACHE_BUDGET_ENV,
};
pub use ucisim::{casp_sim, gas_sim, rqa_sim, UciSim};
