//! §3.3 cost-model ablation: measured wall-clock of forming `KS` / `SᵀKS`
//! as m grows, against (a) the dense-Gaussian `O(n²d)` path and (b) the
//! "vanilla scheme": a plain Nyström sketch of width `m·d` — the paper
//! argues the vanilla scheme's `SᵀK²S` bottleneck costs ≈ m² more than the
//! accumulation method at equal statistical budget.

use super::common::{BenchOpts, Row};
use crate::data::{bimodal, BimodalConfig};
use crate::kernels::Kernel;
use crate::rng::Pcg64;
use crate::sketch::{sketch_gram, SketchBuilder, SketchKind, SketchOps};
use crate::util::timer::{timed, timing_stats};

/// Run the cost ablation.
pub fn run_cost(opts: &BenchOpts) -> Vec<Row> {
    let n = opts.n_max;
    let cfg = BimodalConfig {
        n,
        gamma: 0.5,
        ..Default::default()
    };
    let mut rng = Pcg64::seed(opts.seed ^ 0xc0);
    let (x, _, _) = bimodal(&cfg, &mut rng);
    let kern = Kernel::gaussian(0.5);
    let d = ((1.5 * (n as f64).powf(3.0 / 7.0)) as usize).max(4);
    let reps = opts.replicates.max(3);

    let mut rows = Vec::new();
    let mut bench = |label: &str, m_label: f64, d_used: usize, kind: SketchKind| {
        let mut secs = Vec::with_capacity(reps);
        let mut evals = 0usize;
        let mut nnz = 0usize;
        for _ in 0..reps {
            let s = SketchBuilder::new(kind.clone()).build(n, d_used, &mut rng);
            nnz = s.nnz();
            let (g, t) = timed(|| sketch_gram(&kern, &x, &s, None));
            evals = g.kernel_evals;
            secs.push(t);
        }
        let st = timing_stats(&secs);
        rows.push(Row::new(
            &[("fig", "cost"), ("scheme", label)],
            &[
                ("n", n as f64),
                ("d", d_used as f64),
                ("m", m_label),
                ("nnz", nnz as f64),
                ("kernel_evals", evals as f64),
                ("gram_secs", st.median),
            ],
        ));
    };

    for &m in &[1usize, 2, 4, 8, 16] {
        bench("accum", m as f64, d, SketchKind::Accumulation { m });
        // vanilla scheme: Nyström of width m·d (same sample budget)
        bench("vanilla_md", m as f64, m * d, SketchKind::Nystrom);
    }
    bench("gaussian", f64::INFINITY, d, SketchKind::Gaussian);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulation_cheaper_than_dense_and_vanilla_grows_faster() {
        let opts = BenchOpts {
            replicates: 3,
            n_max: 600,
            ..Default::default()
        };
        let rows = run_cost(&opts);
        let get = |scheme: &str, m: f64, col: &str| {
            rows.iter()
                .find(|r| r.key("scheme") == Some(scheme) && r.val("m") == Some(m))
                .unwrap()
                .val(col)
                .unwrap()
        };
        // accumulation at m=8 is far cheaper than the dense-Gaussian path
        assert!(
            get("accum", 8.0, "gram_secs") < get("gaussian", f64::INFINITY, "gram_secs"),
            "accum {} vs gaussian {}",
            get("accum", 8.0, "gram_secs"),
            get("gaussian", f64::INFINITY, "gram_secs")
        );
        // kernel evaluations scale with support (≤ m·d columns), far below n²
        assert!(get("accum", 8.0, "kernel_evals") < (600.0 * 600.0));
        // the vanilla m·d-wide scheme pays more kernel evals than accum at
        // the same m (equal sample budget but no column reuse in SᵀK²S)
        assert!(
            get("vanilla_md", 8.0, "kernel_evals") >= get("accum", 8.0, "kernel_evals")
        );
    }
}
