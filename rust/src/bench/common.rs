//! Shared bench plumbing: options, result rows, table printing, CSV dump.

use crate::util::csv::write_csv;

/// Harness options (CLI flags map onto these).
#[derive(Clone, Debug)]
pub struct BenchOpts {
    /// Replicates per setting (paper: 30; default kept small for the
    /// 1-core CI box — crank with `--replicates`).
    pub replicates: usize,
    /// Largest sample size in sweeps.
    pub n_max: usize,
    /// Root seed.
    pub seed: u64,
    /// Optional CSV output path.
    pub csv: Option<String>,
    /// Run at full paper scale (overrides n_max upwards).
    pub full: bool,
    /// Streamed-assembly mode (`--streamed`): sketched fits never receive
    /// a shared precomputed `K` — every Gram goes through the row-tiled
    /// `GramOperator`, so sketch-side peak memory is `O(tile·n + n·d)`.
    /// Exact-KRR reference fits still assemble `K` where a figure needs
    /// the dense baseline (that cost is the baseline's, not the method's).
    pub streamed: bool,
    /// CI smoke mode (`--smoke`): shrink wall-clock-bound benches (the
    /// `serve` load generator) to seconds while still emitting their
    /// JSON artifacts.
    pub smoke: bool,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            replicates: 5,
            n_max: 2000,
            seed: 20210217,
            csv: None,
            full: false,
            streamed: false,
            smoke: false,
        }
    }
}

impl BenchOpts {
    /// Sweep of sample sizes: doubling from 500 (paper: from 1000) capped
    /// at `n_max` (paper: 8k/15k/16k — use `--full`).
    pub fn n_sweep(&self) -> Vec<usize> {
        let cap = if self.full { 16000 } else { self.n_max };
        let mut ns = Vec::new();
        let mut n = 500;
        while n <= cap {
            ns.push(n);
            n *= 2;
        }
        ns
    }
}

/// One result row: string key columns + named numeric columns.
#[derive(Clone, Debug)]
pub struct Row {
    /// Key columns (figure id, dataset, method, …).
    pub keys: Vec<(String, String)>,
    /// Numeric columns (n, d, error, secs, …).
    pub vals: Vec<(String, f64)>,
}

impl Row {
    /// Build from slices.
    pub fn new(keys: &[(&str, &str)], vals: &[(&str, f64)]) -> Row {
        Row {
            keys: keys.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
            vals: vals.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        }
    }

    /// Numeric column by name.
    pub fn val(&self, name: &str) -> Option<f64> {
        self.vals.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }

    /// Key column by name.
    pub fn key(&self, name: &str) -> Option<&str> {
        self.keys.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }
}

/// Print rows as an aligned table and optionally dump CSV.
pub fn print_table(title: &str, rows: &[Row], csv: &Option<String>) {
    println!("\n== {title} ==");
    if rows.is_empty() {
        println!("(no rows)");
        return;
    }
    let key_names: Vec<&str> = rows[0].keys.iter().map(|(k, _)| k.as_str()).collect();
    let val_names: Vec<&str> = rows[0].vals.iter().map(|(k, _)| k.as_str()).collect();
    let mut header = String::new();
    for k in &key_names {
        header.push_str(&format!("{k:>12} "));
    }
    for v in &val_names {
        header.push_str(&format!("{v:>14} "));
    }
    println!("{header}");
    for r in rows {
        let mut line = String::new();
        for (_, v) in &r.keys {
            line.push_str(&format!("{v:>12} "));
        }
        for (_, v) in &r.vals {
            if v.abs() >= 1e-3 && v.abs() < 1e6 {
                line.push_str(&format!("{v:>14.6} "));
            } else {
                line.push_str(&format!("{v:>14.3e} "));
            }
        }
        println!("{line}");
    }
    if let Some(path) = csv {
        let mut header: Vec<&str> = key_names.clone();
        header.extend(val_names.iter());
        // CSV wants uniform numeric rows; encode keys as their own columns
        let mut out_rows: Vec<Vec<f64>> = Vec::new();
        let mut text = String::new();
        text.push_str(&header.join(","));
        text.push('\n');
        for r in rows {
            let mut fields: Vec<String> = r.keys.iter().map(|(_, v)| v.clone()).collect();
            fields.extend(r.vals.iter().map(|(_, v)| format!("{v}")));
            text.push_str(&fields.join(","));
            text.push('\n');
        }
        let _ = out_rows.pop();
        let _ = write_csv; // numeric-only writer unused here; we wrote text
        if let Err(e) = std::fs::write(path, text) {
            eprintln!("csv write failed: {e}");
        } else {
            println!("(csv written to {path})");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_accessors() {
        let r = Row::new(&[("method", "accum")], &[("err", 0.5), ("secs", 1.25)]);
        assert_eq!(r.key("method"), Some("accum"));
        assert_eq!(r.val("err"), Some(0.5));
        assert_eq!(r.val("missing"), None);
    }

    #[test]
    fn n_sweep_caps() {
        let o = BenchOpts {
            n_max: 2100,
            ..Default::default()
        };
        assert_eq!(o.n_sweep(), vec![500, 1000, 2000]);
    }

    #[test]
    fn csv_dump_roundtrips() {
        let path = std::env::temp_dir().join("accumkrr_bench_csv_test.csv");
        let rows = vec![Row::new(&[("m", "x")], &[("v", 1.0)])];
        print_table("t", &rows, &Some(path.to_string_lossy().to_string()));
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("m,v"));
        std::fs::remove_file(&path).ok();
    }
}
