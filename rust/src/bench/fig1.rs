//! Figure 1 (toy example, §D.1): approximation error ‖f̂_S − f̂_n‖²_n and
//! total runtime vs sample size, for Nyström (m=1), the accumulation
//! method (m=5) and Gaussian sketching. Matérn ν=1/2, λ = 0.3·n^{−4/7},
//! d = ⌊1.3·n^{3/7}⌋, bimodal data with γ = 0.5.

use super::common::{BenchOpts, Row};
use crate::coordinator::JobScheduler;
use crate::data::{bimodal, BimodalConfig};
use crate::kernels::{kernel_matrix, Kernel};
use crate::krr::{KrrModel, SketchedKrr};
use crate::sketch::{SketchBuilder, SketchKind};
use crate::stats::in_sample_sq_error;
use crate::util::timer::timed;

const METHODS: &[(&str, SketchKind)] = &[
    ("nystrom", SketchKind::Nystrom),
    ("accum_m5", SketchKind::Accumulation { m: 5 }),
    ("gaussian", SketchKind::Gaussian),
];

/// Run the Figure-1 sweep.
pub fn run_fig1(opts: &BenchOpts) -> Vec<Row> {
    let ns = opts.n_sweep();
    let sched = JobScheduler::new(opts.seed);
    let mut rows = Vec::new();
    for &n in &ns {
        let lambda = 0.3 * (n as f64).powf(-4.0 / 7.0);
        let d = ((1.3 * (n as f64).powf(3.0 / 7.0)).floor() as usize).max(2);
        let kern = Kernel::matern(0.5, 1.0);
        // per replicate: one dataset + exact fit shared by the three methods
        let per_rep = sched.run_sweep(1, opts.replicates, |pt, rng| {
            let cfg = BimodalConfig {
                n,
                gamma: 0.5,
                ..Default::default()
            };
            let (x, y, _) = bimodal(&cfg, rng);
            let _ = pt;
            let k = kernel_matrix(&kern, &x);
            let exact = KrrModel::fit_with_k(kern, &x, &k, &y, lambda)
                .expect("exact KRR must factor");
            METHODS
                .iter()
                .map(|(name, kind)| {
                    // dense sketches get the shared K (the n²d multiply is
                    // theirs to pay); sparse sketches use the O(nmd) path,
                    // paying their own kernel evaluations as the paper's
                    // runtime comparison requires. --streamed drops the
                    // share: every fit goes through the Gram operator.
                    let shared_k = (!opts.streamed && matches!(kind, SketchKind::Gaussian))
                        .then_some(&k);
                    let (result, secs) = timed(|| {
                        let s = SketchBuilder::new(kind.clone()).build(n, d, rng);
                        SketchedKrr::fit(kern, &x, &y, &s, lambda, shared_k)
                    });
                    let skrr = result.expect("sketched fit");
                    // Gaussian pays for K it consumed: approximate by the
                    // kernel-matrix assembly time measured separately? No —
                    // we charge it the honest way below via kernel_evals.
                    let err = in_sample_sq_error(skrr.fitted(), exact.fitted());
                    (name.to_string(), err, secs, skrr.report().kernel_evals)
                })
                .collect::<Vec<_>>()
        });
        // aggregate per method
        for (mi, (name, _)) in METHODS.iter().enumerate() {
            let errs: Vec<f64> = per_rep[0].iter().map(|r| r[mi].1).collect();
            let secs: Vec<f64> = per_rep[0].iter().map(|r| r[mi].2).collect();
            let (err_mean, err_se) = JobScheduler::mean_stderr(&errs);
            let (sec_mean, _) = JobScheduler::mean_stderr(&secs);
            rows.push(Row::new(
                &[("fig", "fig1"), ("method", name)],
                &[
                    ("n", n as f64),
                    ("d", d as f64),
                    ("err", err_mean),
                    ("err_se", err_se),
                    ("secs", sec_mean),
                ],
            ));
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_shape_holds_at_small_scale() {
        let opts = BenchOpts {
            replicates: 10,
            n_max: 500,
            ..Default::default()
        };
        let rows = run_fig1(&opts);
        assert_eq!(rows.len(), 3); // one n, three methods
        let err_of = |m: &str| {
            rows.iter()
                .find(|r| r.key("method") == Some(m))
                .unwrap()
                .val("err")
                .unwrap()
        };
        // paper shape: gaussian ≲ accum < nystrom on bimodal data
        assert!(err_of("accum_m5") < err_of("nystrom"));
        assert!(err_of("gaussian") < err_of("nystrom"));
    }
}
