//! Figure 5 (§D.3): the Figure-3/4 trade-off with every sketch solved
//! through Falkon (preconditioned CG + early stopping) instead of the
//! direct d×d solve. The paper's conclusion — the accumulation sketch
//! keeps the best accuracy/efficiency trade-off — must survive the solver
//! swap.

use super::common::{BenchOpts, Row};
use super::fig3::METHODS;
use crate::coordinator::state::{dataset_for, paper_d, paper_lambda};
use crate::coordinator::JobScheduler;
use crate::data::{normalize_features, train_test_split};
use crate::krr::{falkon, FalkonOptions};
use crate::leverage::bless;
use crate::sketch::{Sampling, Sketch, SketchBuilder, SketchKind};
use crate::stats::test_error;
use crate::util::timer::Timer;

/// Run the Figure-5 sweep.
pub fn run_fig5(opts: &BenchOpts, datasets: &[&str]) -> Vec<Row> {
    let ns = opts.n_sweep();
    let sched = JobScheduler::new(opts.seed ^ 5);
    let mut rows = Vec::new();
    for &ds_name in datasets {
        for &n in &ns {
            let results = sched.run_sweep(METHODS.len(), opts.replicates, |pt, rng| {
                let method = METHODS[pt.setting];
                let total = n + n / 4;
                let (mut ds, dx, kern) = dataset_for(ds_name, total, 0.0, rng).expect("dataset");
                normalize_features(&mut ds.x);
                let (train, test) = train_test_split(&ds, 0.2, rng);
                let train = train.head(n);
                let n_train = train.n();
                let d = paper_d(n, dx);
                let lambda = paper_lambda(n, dx);
                let t = Timer::start();
                let sketch: Sketch = match method {
                    "gaussian" => SketchBuilder::new(SketchKind::Gaussian).build(n_train, d, rng),
                    "verysparse" => SketchBuilder::new(SketchKind::VerySparse { sparsity: None })
                        .build(n_train, d, rng),
                    "accum_m4" => {
                        SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(n_train, d, rng)
                    }
                    "bless" => {
                        let bl = bless(&kern, &train.x, lambda, 2 * d, 1.5, rng);
                        SketchBuilder::new(SketchKind::Nystrom)
                            .with_sampling(Sampling::Weighted(bl.sampling_table()))
                            .build(n_train, d, rng)
                    }
                    other => panic!("unknown method {other}"),
                };
                let fk = falkon(
                    kern,
                    &train.x,
                    &train.y,
                    &sketch,
                    lambda,
                    FalkonOptions::default(),
                    None,
                )
                .expect("falkon fit");
                let secs = t.secs();
                let pred = fk.predict(&kern, &test.x);
                (test_error(&pred, &test.y), secs, fk.iters as f64)
            });
            for (mi, &method) in METHODS.iter().enumerate() {
                let errs: Vec<f64> = results[mi].iter().map(|r| r.0).collect();
                let secs: Vec<f64> = results[mi].iter().map(|r| r.1).collect();
                let iters: Vec<f64> = results[mi].iter().map(|r| r.2).collect();
                let (err, err_se) = JobScheduler::mean_stderr(&errs);
                let (sec, _) = JobScheduler::mean_stderr(&secs);
                let (it, _) = JobScheduler::mean_stderr(&iters);
                rows.push(Row::new(
                    &[("fig", "fig5"), ("dataset", ds_name), ("method", method)],
                    &[
                        ("n", n as f64),
                        ("test_err", err),
                        ("err_se", err_se),
                        ("secs", sec),
                        ("cg_iters", it),
                    ],
                ));
            }
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_falkon_preserves_tradeoff_small_scale() {
        let opts = BenchOpts {
            replicates: 3,
            n_max: 500,
            ..Default::default()
        };
        let rows = run_fig5(&opts, &["rqa"]);
        assert_eq!(rows.len(), METHODS.len());
        let get = |m: &str, col: &str| {
            rows.iter()
                .find(|r| r.key("method") == Some(m))
                .unwrap()
                .val(col)
                .unwrap()
        };
        assert!(get("accum_m4", "secs") < get("gaussian", "secs"));
        for m in METHODS {
            assert!(get(m, "test_err").is_finite());
            assert!(get(m, "cg_iters") >= 1.0);
        }
    }
}
