//! Serving-plane load generator: offered vs sustained QPS against the
//! reactor server, adaptive micro-batching vs batching disabled.
//!
//! The generator is a paced closed loop: `C` client threads each hold
//! one framed connection and send single-row `predict` requests at a
//! target per-client rate (unpaced for the capacity probe), reading
//! each reply before the next send. Offered load is swept as fractions
//! of the measured capacity, so the bench is self-scaling across
//! machines; the *saturation knee* is the largest offered rate the
//! server still sustains within 10%. Client-side latencies give the
//! p50/p99 columns, the server's shared [`ServingMetrics`] the shed
//! counts and mean batch occupancy per point.
//!
//! Emits `BENCH_serve.json` with both configurations and the headline
//! `uplift` (adaptive capacity / no-batch capacity). `--smoke` shrinks
//! clients, durations and the sweep for the CI box.

use super::common::{BenchOpts, Row};
use crate::coordinator::frame::{read_frame, write_frame};
use crate::coordinator::metrics::ServingMetrics;
use crate::coordinator::state::{ModelStore, TrainRequest};
use crate::coordinator::{BatcherConfig, ServerConfig, ServerHandle};
use crate::linalg::Precision;
use crate::rng::Pcg64;
use crate::sketch::SketchKind;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One measured load point.
struct Point {
    offered: f64,
    sustained: f64,
    p50_ms: f64,
    p99_ms: f64,
    errors: u64,
    shed: u64,
    mean_batch_rows: f64,
    /// Failed replies by `err_code` ("unknown" for codeless failures),
    /// so shed vs deadline vs fault rejections stay distinguishable.
    err_codes: BTreeMap<String, u64>,
}

struct LoadParams {
    clients: usize,
    duration: Duration,
    fractions: &'static [f64],
}

/// Run the serving bench, dumping `BENCH_serve.json` into the working
/// directory.
pub fn run_serve(opts: &BenchOpts) -> Vec<Row> {
    run_serve_to(opts, "BENCH_serve.json")
}

/// Same as [`run_serve`] with an explicit JSON output path (tests point
/// it at a temp file).
pub fn run_serve_to(opts: &BenchOpts, json_path: &str) -> Vec<Row> {
    let p = if opts.smoke {
        LoadParams {
            clients: 4,
            duration: Duration::from_millis(200),
            fractions: &[0.5, 1.0],
        }
    } else {
        LoadParams {
            clients: 8,
            duration: Duration::from_millis(1500),
            fractions: &[0.25, 0.5, 0.75, 1.0, 1.25],
        }
    };
    let n_train = if opts.smoke { 150 } else { 1000 };

    let configs: [(&str, BatcherConfig); 2] = [
        ("adaptive", BatcherConfig::default()),
        (
            "nobatch",
            BatcherConfig {
                max_batch: 1,
                ..Default::default()
            },
        ),
    ];
    let mut rows = Vec::new();
    let mut cfg_objs = Vec::new();
    let mut capacities = Vec::new();
    for (name, bcfg) in configs {
        let (capacity, knee, points) = bench_config(bcfg, n_train, opts.seed, &p);
        capacities.push(capacity);
        let mut point_objs = Vec::new();
        for pt in &points {
            rows.push(Row::new(
                &[("bench", "serve"), ("config", name)],
                &[
                    ("offered_qps", pt.offered),
                    ("sustained_qps", pt.sustained),
                    ("p50_ms", pt.p50_ms),
                    ("p99_ms", pt.p99_ms),
                    ("mean_batch_rows", pt.mean_batch_rows),
                    ("shed", pt.shed as f64),
                ],
            ));
            let codes: Vec<(&str, Json)> = pt
                .err_codes
                .iter()
                .map(|(code, n)| (code.as_str(), Json::from(*n as usize)))
                .collect();
            point_objs.push(Json::obj(vec![
                ("offered_qps", Json::Num(pt.offered)),
                ("sustained_qps", Json::Num(pt.sustained)),
                ("p50_ms", Json::Num(pt.p50_ms)),
                ("p99_ms", Json::Num(pt.p99_ms)),
                ("errors", Json::from(pt.errors as usize)),
                ("err_codes", Json::obj(codes)),
                ("shed", Json::from(pt.shed as usize)),
                ("mean_batch_rows", Json::Num(pt.mean_batch_rows)),
            ]));
        }
        cfg_objs.push(Json::obj(vec![
            ("config", Json::from(name)),
            ("capacity_qps", Json::Num(capacity)),
            ("knee_qps", Json::Num(knee)),
            ("points", Json::Arr(point_objs)),
        ]));
    }
    let uplift = capacities[0] / capacities[1].max(1e-9);
    rows.push(Row::new(
        &[("bench", "serve"), ("config", "uplift")],
        &[
            ("offered_qps", 0.0),
            ("sustained_qps", uplift),
            ("p50_ms", 0.0),
            ("p99_ms", 0.0),
            ("mean_batch_rows", 0.0),
            ("shed", 0.0),
        ],
    ));
    let j = Json::obj(vec![
        ("bench", Json::from("serve")),
        ("clients", Json::from(p.clients)),
        ("duration_secs", Json::Num(p.duration.as_secs_f64())),
        ("n_train", Json::from(n_train)),
        ("smoke", Json::Bool(opts.smoke)),
        ("adaptive_capacity_qps", Json::Num(capacities[0])),
        ("nobatch_capacity_qps", Json::Num(capacities[1])),
        ("uplift", Json::Num(uplift)),
        ("configs", Json::Arr(cfg_objs)),
    ]);
    if let Err(e) = std::fs::write(json_path, j.to_string()) {
        eprintln!("serve bench: writing {json_path} failed: {e}");
    } else {
        println!("(serving comparison written to {json_path})");
    }
    rows
}

/// Stand a server up with one trained model, probe capacity (unpaced),
/// then sweep paced fractions of it. Returns (capacity, knee, points).
fn bench_config(
    bcfg: BatcherConfig,
    n_train: usize,
    seed: u64,
    p: &LoadParams,
) -> (f64, f64, Vec<Point>) {
    let store = Arc::new(ModelStore::new());
    store
        .train(&TrainRequest {
            name: "bench".into(),
            dataset: "bimodal".into(),
            n: n_train,
            kind: SketchKind::Accumulation { m: 3 },
            d: 0,
            lambda: 0.0,
            bandwidth: 0.0,
            seed,
            adaptive: None,
            precision: Precision::F64,
            sampling: crate::coordinator::SamplingSpec::Uniform,
            data: None,
        })
        .expect("serve bench: train");
    let handle = ServerHandle::start(
        store,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            batcher: bcfg,
            ..Default::default()
        },
    )
    .expect("serve bench: bind");
    let addr = handle.addr();
    let metrics = handle.metrics();

    // capacity probe: closed loop, no pacing
    let (cap_pt, _) = measure(addr, &metrics, p.clients, None, p.duration, seed);
    let capacity = cap_pt.sustained.max(1.0);

    let mut points = vec![cap_pt];
    let mut knee = 0.0f64;
    for &f in p.fractions {
        let offered = capacity * f;
        let per_client = offered / p.clients as f64;
        let interval = Duration::from_secs_f64(1.0 / per_client.max(1.0));
        let (pt, _) = measure(addr, &metrics, p.clients, Some(interval), p.duration, seed);
        if pt.sustained >= 0.9 * pt.offered && pt.offered > knee {
            knee = pt.offered;
        }
        points.push(pt);
    }
    handle.stop();
    (capacity, knee, points)
}

/// Drive one load point: `clients` framed connections sending paced
/// single-row predicts for `duration`. Returns the point plus the raw
/// completion count.
fn measure(
    addr: SocketAddr,
    metrics: &Arc<ServingMetrics>,
    clients: usize,
    interval: Option<Duration>,
    duration: Duration,
    seed: u64,
) -> (Point, u64) {
    let q0 = metrics.queries.load(Ordering::Relaxed);
    let b0 = metrics.batches.load(Ordering::Relaxed);
    let shed0 = metrics.shed.load(Ordering::Relaxed);
    let wall = Instant::now();
    let stop_at = wall + duration;
    let mut handles = Vec::with_capacity(clients);
    for c in 0..clients {
        handles.push(std::thread::spawn(move || {
            client_loop(addr, interval, stop_at, seed ^ (c as u64 + 1))
        }));
    }
    let mut lat_ms: Vec<f64> = Vec::new();
    let mut completed = 0u64;
    let mut errors = 0u64;
    let mut err_codes: BTreeMap<String, u64> = BTreeMap::new();
    for h in handles {
        let (lat, done, errs, codes) = h.join().expect("load client panicked");
        lat_ms.extend(lat);
        completed += done;
        errors += errs;
        for (code, n) in codes {
            *err_codes.entry(code).or_insert(0) += n;
        }
    }
    let elapsed = wall.elapsed().as_secs_f64();
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let dq = metrics.queries.load(Ordering::Relaxed) - q0;
    let db = metrics.batches.load(Ordering::Relaxed) - b0;
    let pt = Point {
        offered: match interval {
            Some(iv) => clients as f64 / iv.as_secs_f64(),
            None => completed as f64 / elapsed,
        },
        sustained: completed as f64 / elapsed,
        p50_ms: pct(&lat_ms, 0.50),
        p99_ms: pct(&lat_ms, 0.99),
        errors,
        shed: metrics.shed.load(Ordering::Relaxed) - shed0,
        mean_batch_rows: if db > 0 { dq as f64 / db as f64 } else { 0.0 },
        err_codes,
    };
    (pt, completed)
}

/// One client: framed connection, paced send → blocking read, latency
/// per completed request in milliseconds, plus an `err_code` tally of
/// the failed replies.
fn client_loop(
    addr: SocketAddr,
    interval: Option<Duration>,
    stop_at: Instant,
    seed: u64,
) -> (Vec<f64>, u64, u64, BTreeMap<String, u64>) {
    let mut err_codes: BTreeMap<String, u64> = BTreeMap::new();
    let mut conn = match TcpStream::connect(addr) {
        Ok(c) => c,
        Err(_) => return (Vec::new(), 0, 1, err_codes),
    };
    let _ = conn.set_nodelay(true);
    let mut rng = Pcg64::seed(seed);
    let mut lat = Vec::new();
    let mut errors = 0u64;
    let mut sent = 0u64;
    let t0 = Instant::now();
    loop {
        if let Some(iv) = interval {
            let next = t0 + iv.mul_f64(sent as f64);
            if next >= stop_at {
                break;
            }
            let now = Instant::now();
            if next > now {
                std::thread::sleep(next - now);
            }
        }
        if Instant::now() >= stop_at {
            break;
        }
        let row = [
            rng.uniform() * 4.0 - 2.0,
            rng.uniform() * 4.0 - 2.0,
            rng.uniform() * 4.0 - 2.0,
        ];
        let req = Json::obj(vec![
            ("method", Json::from("predict")),
            ("model", Json::from("bench")),
            ("x", Json::Arr(vec![Json::nums(&row)])),
        ]);
        let s = Instant::now();
        if write_frame(&mut conn, &req).is_err() {
            errors += 1;
            break;
        }
        match read_frame(&mut conn) {
            Ok(reply) => {
                lat.push(s.elapsed().as_secs_f64() * 1e3);
                if reply.get("ok") != Some(&Json::Bool(true)) {
                    errors += 1;
                    let code = reply
                        .get("err_code")
                        .and_then(|c| c.as_str())
                        .unwrap_or("unknown")
                        .to_string();
                    *err_codes.entry(code).or_insert(0) += 1;
                }
            }
            Err(_) => {
                errors += 1;
                break;
            }
        }
        sent += 1;
    }
    (lat, sent, errors, err_codes)
}

/// Percentile of an ascending-sorted sample (nearest-rank).
fn pct(sorted_ms: &[f64], q: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((q * sorted_ms.len() as f64).ceil() as usize).clamp(1, sorted_ms.len()) - 1;
    sorted_ms[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_bench_smoke_emits_rows_and_json() {
        let tmp = std::env::temp_dir().join("accumkrr_bench_serve_test.json");
        let opts = BenchOpts {
            smoke: true,
            ..Default::default()
        };
        let rows = run_serve_to(&opts, &tmp.to_string_lossy());
        // capacity + 2 fractions per config, plus the uplift row
        assert_eq!(rows.len(), 2 * 3 + 1);
        for r in &rows {
            if r.key("config") != Some("uplift") {
                assert!(r.val("sustained_qps").unwrap() > 0.0, "{r:?}");
            }
        }
        let text = std::fs::read_to_string(&tmp).unwrap();
        let j = Json::parse(&text).unwrap();
        assert!(j.get("uplift").and_then(|v| v.as_f64()).unwrap() > 0.0);
        let cfgs = j.get("configs").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(cfgs.len(), 2);
        for c in cfgs {
            assert!(c.get("capacity_qps").and_then(|v| v.as_f64()).unwrap() > 0.0);
            let pts = c.get("points").and_then(|v| v.as_arr()).unwrap();
            assert_eq!(pts.len(), 3);
            for p in pts {
                assert_eq!(p.get("errors").and_then(|v| v.as_usize()), Some(0), "{p}");
                // the distribution is always present; healthy runs empty
                let codes = p.get("err_codes").unwrap();
                assert_eq!(codes, &Json::obj(vec![]), "{p}");
            }
        }
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn pct_nearest_rank() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(pct(&v, 0.5), 2.0);
        assert_eq!(pct(&v, 0.99), 4.0);
        assert_eq!(pct(&[], 0.5), 0.0);
    }
}
