//! Adaptive-m bench: the incremental accumulation engine versus a sweep of
//! independent fixed-m refits over the *same* m schedule.
//!
//! The comparison isolates exactly what the engine saves: a fixed-m refit
//! at each schedule point re-evaluates every kernel column, re-folds `KS`,
//! re-runs the `O(n·d²)` SYRK and re-factorises the d×d system, while the
//! adaptive fit pays kernel evaluations only at new support points and
//! folds each appended term into the existing Grams. Results (wall-clock
//! and the deterministic kernel-eval counts) are emitted to
//! `BENCH_adaptive.json` for the acceptance gate: total adaptive fit time
//! must undercut the summed refits.

use super::common::{BenchOpts, Row};
use crate::data::{bimodal, BimodalConfig};
use crate::kernels::Kernel;
use crate::krr::{AdaptiveOptions, SketchedKrr};
use crate::rng::Pcg64;
use crate::sketch::{SketchBuilder, SketchKind};
use crate::util::json::Json;
use crate::util::timer::Timer;

/// Run the adaptive-vs-refit comparison, dumping `BENCH_adaptive.json`
/// into the working directory.
pub fn run_adaptive(opts: &BenchOpts) -> Vec<Row> {
    run_adaptive_to(opts, "BENCH_adaptive.json")
}

/// Same as [`run_adaptive`] with an explicit JSON output path (tests point
/// it at a temp file).
pub fn run_adaptive_to(opts: &BenchOpts, json_path: &str) -> Vec<Row> {
    let n = opts.n_max;
    let cfg = BimodalConfig {
        n,
        gamma: 0.5,
        ..Default::default()
    };
    let mut data_rng = Pcg64::seed(opts.seed ^ 0xad);
    let (x, y, _) = bimodal(&cfg, &mut data_rng);
    let lambda = 0.5 * (n as f64).powf(-4.0 / 7.0);
    let kern = Kernel::gaussian(1.5 * (n as f64).powf(-1.0 / 7.0));
    let d = ((1.5 * (n as f64).powf(3.0 / 7.0)) as usize).max(4);
    let m_max = if opts.full { 64 } else { 32 };
    let builder = SketchBuilder::new(SketchKind::Accumulation { m: 1 });

    // 1. adaptive fit across the full schedule (stopping rule disabled) —
    //    the incremental path the refits are compared against
    let sweep_opts = AdaptiveOptions {
        m_max,
        rel_tol: -1.0,
        ..Default::default()
    };
    let mut rng = Pcg64::seed(opts.seed ^ 0xada);
    let t = Timer::start();
    let (sweep_model, trace) =
        SketchedKrr::fit_adaptive(kern, &x, &y, &builder, d, lambda, &sweep_opts, &mut rng)
            .expect("adaptive sweep fit");
    let adaptive_total = t.secs();

    // 2. independent fixed-m refits over the same schedule, same seed (the
    //    grown and rebuilt sketches bit-match at every point)
    let mut refit_secs = Vec::with_capacity(trace.len());
    let mut refit_evals = 0usize;
    for round in &trace {
        let mut rng = Pcg64::seed(opts.seed ^ 0xada);
        let t = Timer::start();
        let s = SketchBuilder::new(SketchKind::Accumulation { m: round.m }).build(n, d, &mut rng);
        let model = SketchedKrr::fit(kern, &x, &y, &s, lambda, None).expect("fixed-m fit");
        refit_secs.push(t.secs());
        refit_evals += model.report().kernel_evals;
    }
    let refit_total: f64 = refit_secs.iter().sum();

    // 3. what the stopping rule actually picks on this data
    let run_opts = AdaptiveOptions {
        m_max,
        ..Default::default()
    };
    let mut rng = Pcg64::seed(opts.seed ^ 0xada);
    let (chosen_model, _) =
        SketchedKrr::fit_adaptive(kern, &x, &y, &builder, d, lambda, &run_opts, &mut rng)
            .expect("adaptive fit");
    let chosen = *chosen_model.report();

    let mut rows = Vec::new();
    for (round, &rs) in trace.iter().zip(refit_secs.iter()) {
        rows.push(Row::new(
            &[("fig", "adaptive"), ("phase", "round")],
            &[
                ("m", round.m as f64),
                ("adaptive_secs", round.secs),
                ("refit_secs", rs),
                ("rel_change", if round.rel_change.is_finite() { round.rel_change } else { -1.0 }),
            ],
        ));
    }
    rows.push(Row::new(
        &[("fig", "adaptive"), ("phase", "total")],
        &[
            ("m", m_max as f64),
            ("adaptive_secs", adaptive_total),
            ("refit_secs", refit_total),
            ("rel_change", 0.0),
        ],
    ));

    let round_objs: Vec<Json> = trace
        .iter()
        .zip(refit_secs.iter())
        .map(|(r, &rs)| {
            Json::obj(vec![
                ("m", Json::from(r.m)),
                ("adaptive_secs", Json::Num(r.secs)),
                ("refit_secs", Json::Num(rs)),
                (
                    "rel_change",
                    Json::Num(if r.rel_change.is_finite() { r.rel_change } else { -1.0 }),
                ),
                ("refactored", Json::Bool(r.refactored)),
            ])
        })
        .collect();
    let j = Json::obj(vec![
        ("bench", Json::from("adaptive")),
        ("n", Json::from(n)),
        ("d", Json::from(d)),
        ("lambda", Json::Num(lambda)),
        ("m_max", Json::from(m_max)),
        ("adaptive_total_secs", Json::Num(adaptive_total)),
        ("refit_total_secs", Json::Num(refit_total)),
        (
            "speedup",
            Json::Num(refit_total / adaptive_total.max(1e-12)),
        ),
        (
            "adaptive_kernel_evals",
            Json::from(sweep_model.report().kernel_evals),
        ),
        ("refit_kernel_evals", Json::from(refit_evals)),
        ("chosen_m", Json::from(chosen.m)),
        ("chosen_rounds", Json::from(chosen.rounds)),
        ("rounds", Json::Arr(round_objs)),
    ]);
    if let Err(e) = std::fs::write(json_path, j.to_string()) {
        eprintln!("adaptive bench: writing {json_path} failed: {e}");
    } else {
        println!("(adaptive comparison written to {json_path})");
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_bench_rows_json_and_eval_savings() {
        let tmp = std::env::temp_dir().join("accumkrr_bench_adaptive_test.json");
        let opts = BenchOpts {
            replicates: 1,
            n_max: 400,
            ..Default::default()
        };
        let rows = run_adaptive_to(&opts, &tmp.to_string_lossy());
        // schedule 1,2,4,8,16,32 plus the totals row
        assert_eq!(rows.len(), 7);
        let total = rows.last().unwrap();
        assert_eq!(total.key("phase"), Some("total"));
        assert!(total.val("adaptive_secs").unwrap() > 0.0);
        let text = std::fs::read_to_string(&tmp).unwrap();
        let j = Json::parse(&text).unwrap();
        // deterministic core of the speedup: incremental growth pays
        // strictly fewer kernel evaluations than the summed refits
        let a = j
            .get("adaptive_kernel_evals")
            .and_then(|v| v.as_usize())
            .unwrap();
        let r = j.get("refit_kernel_evals").and_then(|v| v.as_usize()).unwrap();
        assert!(a < r, "incremental evals {a} must undercut refit sum {r}");
        let chosen = j.get("chosen_m").and_then(|v| v.as_usize()).unwrap();
        assert!((1..=32).contains(&chosen));
        assert_eq!(j.get("rounds").and_then(|v| v.as_arr()).unwrap().len(), 6);
        std::fs::remove_file(&tmp).ok();
    }
}
