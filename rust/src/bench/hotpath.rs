//! Micro-benchmarks of the L3 hot paths: GEMM, kernel-matrix assembly,
//! sparse sketch application, Cholesky, Falkon iteration. Hand-rolled
//! harness (criterion is unavailable in the offline image): warmup + N
//! timed reps, median/IQR reported. This is the §Perf measurement tool —
//! before/after numbers in EXPERIMENTS.md come from here.

use crate::data::{bimodal, BimodalConfig};
use crate::kernels::{kernel_matrix, Kernel};
use crate::linalg::{chol_factor, matmul, Matrix};
use crate::rng::Pcg64;
use crate::sketch::{sketch_gram, SketchBuilder, SketchKind};
use crate::util::timer::{timed, timing_stats, TimingStats};

/// One benchmark case.
struct Case {
    name: &'static str,
    /// flop estimate for the throughput column (0 = skip).
    flops: f64,
    run: Box<dyn FnMut()>,
}

fn report(name: &str, flops: f64, stats: TimingStats) {
    let gflops = if flops > 0.0 && stats.median > 0.0 {
        flops / stats.median / 1e9
    } else {
        0.0
    };
    println!(
        "{name:>28}  median {:>9.3} ms  iqr [{:>8.3}, {:>8.3}]  {:>7.2} gflop/s  (n={})",
        stats.median * 1e3,
        stats.p25 * 1e3,
        stats.p75 * 1e3,
        gflops,
        stats.n
    );
}

/// Entry point for `cargo bench --bench hotpath`.
pub fn hotpath_main() {
    let reps = std::env::var("ACCUMKRR_BENCH_REPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7usize);
    let mut rng = Pcg64::seed(0xb5);

    // shared inputs
    let n = 1500;
    let p = 3;
    let d = 40;
    let cfg = BimodalConfig {
        n,
        gamma: 0.5,
        ..Default::default()
    };
    let (x, y, _) = bimodal(&cfg, &mut rng);
    let kern = Kernel::gaussian(0.5);
    let k = kernel_matrix(&kern, &x);
    let a = Matrix::from_fn(512, 512, |_, _| rng.normal());
    let b = Matrix::from_fn(512, 512, |_, _| rng.normal());
    let mut spd = crate::linalg::syrk_at_a(&Matrix::from_fn(300, 256, |_, _| rng.normal()));
    spd.add_diag(1.0);
    let accum_sketch = SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(n, d, &mut rng);
    let gauss_sketch = SketchBuilder::new(SketchKind::Gaussian).build(n, d, &mut rng);
    let lam = 1e-3;

    let mut cases: Vec<Case> = vec![
        Case {
            name: "gemm 512^3",
            flops: 2.0 * 512f64.powi(3),
            run: Box::new({
                let (a, b) = (a.clone(), b.clone());
                move || {
                    std::hint::black_box(matmul(&a, &b));
                }
            }),
        },
        Case {
            name: "kernel_matrix n=1500 p=3",
            flops: (n * n) as f64 * (2.0 * p as f64 + 8.0),
            run: Box::new({
                let x = x.clone();
                move || {
                    std::hint::black_box(kernel_matrix(&kern, &x));
                }
            }),
        },
        Case {
            name: "sketch_gram accum m=4",
            flops: 0.0,
            run: Box::new({
                let x = x.clone();
                let s = accum_sketch.clone();
                move || {
                    std::hint::black_box(sketch_gram(&kern, &x, &s, None));
                }
            }),
        },
        Case {
            name: "sketch_gram gaussian (K given)",
            flops: 2.0 * (n * n * d) as f64,
            run: Box::new({
                let x = x.clone();
                let k = k.clone();
                let s = gauss_sketch.clone();
                move || {
                    std::hint::black_box(sketch_gram(&kern, &x, &s, Some(&k)));
                }
            }),
        },
        Case {
            name: "cholesky 256",
            flops: 256f64.powi(3) / 3.0,
            run: Box::new({
                let spd = spd.clone();
                move || {
                    std::hint::black_box(chol_factor(&spd).unwrap());
                }
            }),
        },
        Case {
            name: "sketched fit end-to-end",
            flops: 0.0,
            run: Box::new({
                let x = x.clone();
                let y = y.clone();
                let s = accum_sketch.clone();
                move || {
                    std::hint::black_box(
                        crate::krr::SketchedKrr::fit(kern, &x, &y, &s, lam, None).unwrap(),
                    );
                }
            }),
        },
        Case {
            name: "falkon fit end-to-end",
            flops: 0.0,
            run: Box::new({
                let x = x.clone();
                let y = y.clone();
                let s = accum_sketch.clone();
                move || {
                    std::hint::black_box(
                        crate::krr::falkon(
                            kern,
                            &x,
                            &y,
                            &s,
                            lam,
                            crate::krr::FalkonOptions::default(),
                            None,
                        )
                        .unwrap(),
                    );
                }
            }),
        },
    ];

    println!("hotpath micro-benchmarks (reps={reps}, 1 warmup)");
    for case in cases.iter_mut() {
        (case.run)(); // warmup
        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps {
            let ((), t) = timed(|| (case.run)());
            samples.push(t);
        }
        report(case.name, case.flops, timing_stats(&samples));
    }
}
