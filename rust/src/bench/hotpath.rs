//! Micro-benchmarks of the L3 hot paths: GEMM (all four packed variants'
//! driver), radial kernel-matrix assembly, the partial eigensolver, sparse
//! sketch application, Cholesky, end-to-end fits. Hand-rolled harness
//! (criterion is unavailable in the offline image): warmup + N timed reps,
//! median/IQR reported — and dumped machine-readably to
//! `BENCH_hotpath.json` so the repo's perf trajectory accumulates across
//! PRs. This is the §Perf measurement tool — before/after numbers in
//! EXPERIMENTS.md come from here.
//!
//! Knobs: `ACCUMKRR_BENCH_REPS` (timed reps, default 7),
//! `ACCUMKRR_BENCH_QUICK` (any value but "0": toy shapes — the unit-test
//! plumbing mode; CI deliberately runs the *full* paper-sweep shapes at
//! 1 rep so the uploaded artifact carries the real cases),
//! `ACCUMKRR_BENCH_STREAMED_ONLY` (any value but "0": run *only* the
//! streamed Gram-operator case, allocating no dense `K` anywhere in the
//! harness — the mode the EXPERIMENTS.md peak-RSS sublinearity check
//! needs, since `VmHWM` is a process-wide high-water mark),
//! `ACCUMKRR_THREADS` (pin the pool for stable timings),
//! `ACCUMKRR_FORCE_SCALAR=1` (pin the whole run to the scalar micro-kernel
//! — the `linalg::simd` dispatch knob; when it is *not* set and the host
//! dispatch is vectorized, the dispatch-sensitive cases are additionally
//! re-timed under a pinned scalar dispatch and report the
//! SIMD-over-scalar uplift).

use crate::data::{bimodal, BimodalConfig};
use crate::kernels::{cross_kernel_f32, kernel_cols, kernel_matrix, GramOperator, Kernel};
use crate::linalg::{
    chol_factor, matmul, matmul_at_b, partial_eigh, simd, with_kernel, KernelImpl, Matrix,
};
use crate::rng::Pcg64;
use crate::sketch::{sketch_gram, SketchBuilder, SketchKind};
use crate::util::json::Json;
use crate::util::mem::peak_rss_bytes;
use crate::util::timer::{timed, timing_stats, TimingStats};

/// One benchmark case.
struct Case {
    name: String,
    /// flop estimate for the throughput column (0 = skip).
    flops: f64,
    /// Dispatch-sensitive: also time the case under a pinned scalar
    /// dispatch (when the ambient one is vectorized) and report the
    /// SIMD-over-scalar uplift. Set on the GEMM variants and the
    /// kernel-map cases — the paths the `linalg::simd` micro-kernels
    /// accelerate.
    dual: bool,
    run: Box<dyn FnMut()>,
}

struct CaseResult {
    name: String,
    flops: f64,
    /// Dispatch the timed run used (`"scalar"` / `"avx2"` / `"neon"`).
    kernel: &'static str,
    stats: TimingStats,
    gflops: f64,
    /// Same case re-timed under `with_kernel(Scalar)` — only for `dual`
    /// cases when the ambient dispatch is vectorized.
    scalar_stats: Option<TimingStats>,
    /// Process peak RSS (MB) sampled right after the case's reps — a
    /// monotone high-water mark (see `util::mem::peak_rss_bytes`), so the
    /// interesting signal is whether the *streamed* cases move it versus
    /// the dense-assembly cases that precede them. 0 when unavailable.
    peak_rss_mb: f64,
}

impl CaseResult {
    /// SIMD-over-scalar speedup (scalar median / vector median); 0 when no
    /// scalar comparison ran.
    fn uplift(&self) -> f64 {
        match &self.scalar_stats {
            Some(s) if self.stats.median > 0.0 => s.median / self.stats.median,
            _ => 0.0,
        }
    }
}

fn report(r: &CaseResult) {
    let uplift = match r.uplift() {
        u if u > 0.0 => format!("  {u:>5.2}x vs scalar"),
        _ => String::new(),
    };
    println!(
        "{:>36}  median {:>9.3} ms  iqr [{:>8.3}, {:>8.3}]  {:>7.2} gflop/s  rss {:>7.1} MB  (n={}){}",
        r.name,
        r.stats.median * 1e3,
        r.stats.p25 * 1e3,
        r.stats.p75 * 1e3,
        r.gflops,
        r.peak_rss_mb,
        r.stats.n,
        uplift
    );
}

/// Entry point for `cargo bench --bench hotpath`.
pub fn hotpath_main() {
    let reps = std::env::var("ACCUMKRR_BENCH_REPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7usize);
    let quick = std::env::var("ACCUMKRR_BENCH_QUICK")
        .map(|v| v != "0")
        .unwrap_or(false);
    run_hotpath_to("BENCH_hotpath.json", reps, quick);
}

/// The full paper-sweep-shaped case set (`quick = false`) or a miniature
/// set exercising the same code paths (`quick = true`, used by the unit
/// test so debug builds stay fast). `streamed_only` emits just the
/// Gram-operator case and allocates **no** dense `K` in the harness, so
/// the process peak RSS reflects the streamed path alone.
fn build_cases(quick: bool, streamed_only: bool, rng: &mut Pcg64) -> Vec<Case> {
    // shapes from the paper's sweeps: n = 1500 bimodal points in p = 3,
    // sketch width d = 40; 512³ as the canonical square-GEMM point
    let (gemm_n, n, d, chol_n, eig_k, nys_u) = if quick {
        (48usize, 96usize, 8usize, 32usize, 4usize, 12usize)
    } else {
        (512, 1500, 40, 256, 10, 160)
    };
    let p = 3usize;
    let cfg = BimodalConfig {
        n,
        gamma: 0.5,
        ..Default::default()
    };
    let (x, y, _) = bimodal(&cfg, rng);
    let kern = Kernel::gaussian(0.5);
    let b_thin = Matrix::from_fn(n, d, |_, _| rng.normal());
    // streamed-assembly case: K·B through the row-tiled Gram operator —
    // the memory-model flagship (O(tile·n + n·d) peak instead of the n²
    // a dense assemble-then-GEMM pays); the RSS column tracks it across
    // PRs, and the dense comparator is the `matmul K·B dense` case plus
    // the `kernel_matrix` assembly it would also pay
    let gram_case = Case {
        name: format!("gram_op K·B streamed n={n} d={d}"),
        flops: (n * n) as f64 * (2.0 * p as f64 + 8.0) + 2.0 * (n * n * d) as f64,
        dual: true,
        run: Box::new({
            let x = x.clone();
            let b = b_thin.clone();
            move || {
                std::hint::black_box(GramOperator::new(kern, &x).matmul(&b));
            }
        }),
    };
    if streamed_only {
        return vec![gram_case];
    }
    let k = kernel_matrix(&kern, &x);
    let mut kn = k.clone();
    kn.scale(1.0 / n as f64);
    kn.symmetrize();
    let a = Matrix::from_fn(gemm_n, gemm_n, |_, _| rng.normal());
    let b = Matrix::from_fn(gemm_n, gemm_n, |_, _| rng.normal());
    let ks_like = Matrix::from_fn(n, d, |_, _| rng.normal());
    let mut spd = crate::linalg::syrk_at_a(&Matrix::from_fn(chol_n + 44, chol_n, |_, _| {
        rng.normal()
    }));
    spd.add_diag(1.0);
    let accum_sketch = SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(n, d, rng);
    let gauss_sketch = SketchBuilder::new(SketchKind::Gaussian).build(n, d, rng);
    let landmark_idx: Vec<usize> = (0..nys_u).map(|i| (i * 7) % n).collect();
    let lam = 1e-3;
    // kernel-map case: the vectorized exp over a realistic squared-distance
    // range (what `kernel_matrix` spends its non-GEMM half on)
    let map_len = if quick { 4096 } else { 1 << 20 };
    let map_src: Vec<f64> = (0..map_len).map(|_| rng.uniform() * 40.0).collect();

    let mut cases: Vec<Case> = vec![
        Case {
            name: format!("matmul {gemm_n}^3"),
            flops: 2.0 * (gemm_n as f64).powi(3),
            dual: true,
            run: Box::new({
                let (a, b) = (a.clone(), b.clone());
                move || {
                    std::hint::black_box(matmul(&a, &b));
                }
            }),
        },
        Case {
            name: format!("matmul_at_b (KS)ᵀ(KS) {n}x{d}"),
            flops: 2.0 * (n * d * d) as f64,
            dual: true,
            run: Box::new({
                let ks = ks_like.clone();
                move || {
                    std::hint::black_box(matmul_at_b(&ks, &ks));
                }
            }),
        },
        Case {
            name: format!("syrk_at_a {n}x{d}"),
            flops: (n * d * d) as f64,
            dual: true,
            run: Box::new({
                let ks = ks_like.clone();
                move || {
                    std::hint::black_box(crate::linalg::syrk_at_a(&ks));
                }
            }),
        },
        Case {
            name: format!("kernel_matrix n={n} p={p}"),
            flops: (n * n) as f64 * (2.0 * p as f64 + 8.0),
            dual: true,
            run: Box::new({
                let x = x.clone();
                move || {
                    std::hint::black_box(kernel_matrix(&kern, &x));
                }
            }),
        },
        Case {
            name: format!("kernel_cols n={n} u={nys_u}"),
            flops: (n * nys_u) as f64 * (2.0 * p as f64 + 8.0),
            dual: true,
            run: Box::new({
                let x = x.clone();
                let idx = landmark_idx.clone();
                move || {
                    std::hint::black_box(kernel_cols(&kern, &x, &idx));
                }
            }),
        },
        Case {
            // 1 mul + 1 exp per lane; exp_fast is counted at 8 flops like
            // the assembly cases' estimate
            name: format!("map_sq_dist gaussian len={map_len}"),
            flops: 9.0 * map_len as f64,
            dual: true,
            run: Box::new({
                let src = map_src.clone();
                let mut buf = vec![0.0f64; map_len];
                move || {
                    buf.copy_from_slice(&src);
                    kern.map_sq_dist(&mut buf);
                    std::hint::black_box(&buf);
                }
            }),
        },
        Case {
            // the mixed-precision comparator for the f64 `kernel_matrix`
            // case above: same assembly through the f32 panel path
            // (`Precision::F32` inside `GramOperator`), widened on output
            name: format!("kernel_matrix f32 n={n} p={p}"),
            flops: (n * n) as f64 * (2.0 * p as f64 + 8.0),
            dual: false,
            run: Box::new({
                let x = x.clone();
                move || {
                    std::hint::black_box(cross_kernel_f32(&kern, &x, &x));
                }
            }),
        },
        Case {
            name: format!("partial_eigh n={n} k={eig_k}"),
            flops: 0.0,
            dual: false,
            run: Box::new({
                let kn = kn.clone();
                move || {
                    std::hint::black_box(partial_eigh(&kn, eig_k));
                }
            }),
        },
        Case {
            name: format!("cholesky {chol_n}"),
            flops: (chol_n as f64).powi(3) / 3.0,
            dual: false,
            run: Box::new({
                let spd = spd.clone();
                move || {
                    std::hint::black_box(chol_factor(&spd).unwrap());
                }
            }),
        },
        gram_case,
        Case {
            // the streamed case's dense comparator: same K·B product off
            // the prebuilt K (EXPERIMENTS.md's throughput gate sums this
            // with the kernel_matrix assembly case for the full dense
            // route's cost)
            name: format!("matmul K·B dense n={n} d={d}"),
            flops: 2.0 * (n * n * d) as f64,
            dual: true,
            run: Box::new({
                let k = k.clone();
                let b = b_thin.clone();
                move || {
                    std::hint::black_box(matmul(&k, &b));
                }
            }),
        },
        Case {
            name: "sketch_gram accum m=4".to_string(),
            flops: 0.0,
            dual: false,
            run: Box::new({
                let x = x.clone();
                let s = accum_sketch.clone();
                move || {
                    std::hint::black_box(sketch_gram(&kern, &x, &s, None));
                }
            }),
        },
        Case {
            name: "sketch_gram gaussian (K given)".to_string(),
            flops: 2.0 * (n * n * d) as f64,
            dual: false,
            run: Box::new({
                let x = x.clone();
                let k = k.clone();
                let s = gauss_sketch.clone();
                move || {
                    std::hint::black_box(sketch_gram(&kern, &x, &s, Some(&k)));
                }
            }),
        },
    ];
    if !quick {
        cases.push(Case {
            name: "sketched fit end-to-end".to_string(),
            flops: 0.0,
            dual: false,
            run: Box::new({
                let x = x.clone();
                let y = y.clone();
                let s = accum_sketch.clone();
                move || {
                    std::hint::black_box(
                        crate::krr::SketchedKrr::fit(kern, &x, &y, &s, lam, None).unwrap(),
                    );
                }
            }),
        });
        cases.push(Case {
            name: "falkon fit end-to-end".to_string(),
            flops: 0.0,
            dual: false,
            run: Box::new({
                let x = x.clone();
                let y = y.clone();
                let s = accum_sketch.clone();
                move || {
                    std::hint::black_box(
                        crate::krr::falkon(
                            kern,
                            &x,
                            &y,
                            &s,
                            lam,
                            crate::krr::FalkonOptions::default(),
                            None,
                        )
                        .unwrap(),
                    );
                }
            }),
        });
    }
    cases
}

/// Run the harness, print the table, and write the machine-readable dump
/// (per-case median/IQR/gflops) to `json_path`. Returns the JSON document
/// so tests can assert on it without re-reading the file.
pub fn run_hotpath_to(json_path: &str, reps: usize, quick: bool) -> Json {
    let reps = reps.max(1);
    let streamed_only = std::env::var("ACCUMKRR_BENCH_STREAMED_ONLY")
        .map(|v| v != "0")
        .unwrap_or(false);
    let mut rng = Pcg64::seed(0xb5);
    let mut cases = build_cases(quick, streamed_only, &mut rng);
    // Sample the ambient dispatch once — every case below is timed under
    // it, and `dual` cases get a second pinned-scalar run for the uplift
    // column when it is vectorized.
    let ambient = simd::active();
    println!(
        "hotpath micro-benchmarks (reps={reps}, 1 warmup, {} mode{}, kernel={})",
        if quick { "quick" } else { "full" },
        if streamed_only { ", streamed-only" } else { "" },
        ambient.name()
    );
    let mut results = Vec::with_capacity(cases.len());
    for case in cases.iter_mut() {
        let time_reps = |run: &mut dyn FnMut()| {
            run(); // warmup
            let mut samples = Vec::with_capacity(reps);
            for _ in 0..reps {
                let ((), t) = timed(|| run());
                samples.push(t);
            }
            timing_stats(&samples)
        };
        let stats = time_reps(&mut *case.run);
        let scalar_stats = if case.dual && ambient != KernelImpl::Scalar {
            // `with_kernel` pins the calling thread's dispatch; the hot
            // entry points sample it here and hand it to their pool
            // workers, so the whole run is scalar end to end.
            Some(with_kernel(KernelImpl::Scalar, || time_reps(&mut *case.run)))
        } else {
            None
        };
        let gflops = if case.flops > 0.0 && stats.median > 0.0 {
            case.flops / stats.median / 1e9
        } else {
            0.0
        };
        let peak_rss_mb = peak_rss_bytes().map_or(0.0, |b| b as f64 / (1024.0 * 1024.0));
        let r = CaseResult {
            name: case.name.clone(),
            flops: case.flops,
            kernel: ambient.name(),
            stats,
            gflops,
            scalar_stats,
            peak_rss_mb,
        };
        report(&r);
        results.push(r);
    }

    let case_objs: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut fields = vec![
                ("name", Json::from(r.name.as_str())),
                ("flops", Json::Num(r.flops)),
                ("kernel", Json::from(r.kernel)),
                ("median_secs", Json::Num(r.stats.median)),
                ("p25_secs", Json::Num(r.stats.p25)),
                ("p75_secs", Json::Num(r.stats.p75)),
                ("min_secs", Json::Num(r.stats.min)),
                ("max_secs", Json::Num(r.stats.max)),
                ("gflops", Json::Num(r.gflops)),
                ("peak_rss_mb", Json::Num(r.peak_rss_mb)),
                ("reps", Json::from(r.stats.n)),
            ];
            if let Some(s) = &r.scalar_stats {
                // the forced-scalar rerun of the same case — EXPERIMENTS.md
                // §Mixed-precision's uplift gate reads these two fields
                fields.push(("scalar_median_secs", Json::Num(s.median)));
                fields.push(("uplift", Json::Num(r.uplift())));
            }
            Json::obj(fields)
        })
        .collect();
    let final_rss = peak_rss_bytes().map_or(0.0, |b| b as f64 / (1024.0 * 1024.0));
    let j = Json::obj(vec![
        ("bench", Json::from("hotpath")),
        ("mode", Json::from(if quick { "quick" } else { "full" })),
        ("streamed_only", Json::Bool(streamed_only)),
        ("reps", Json::from(reps)),
        ("threads", Json::from(crate::pool::num_threads())),
        // host provenance: which dispatch produced the numbers, and what
        // the hardware offered (mirrors `runtime::HostStamp`)
        ("arch", Json::from(std::env::consts::ARCH)),
        ("kernel", Json::from(ambient.name())),
        ("cpu_features", Json::from(crate::linalg::detected_features().as_str())),
        ("peak_rss_mb", Json::Num(final_rss)),
        ("cases", Json::Arr(case_objs)),
    ]);
    if let Err(e) = std::fs::write(json_path, j.to_string()) {
        eprintln!("hotpath bench: writing {json_path} failed: {e}");
    } else {
        println!("(hotpath results written to {json_path})");
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The quick mode exercises the same code paths at toy shapes and the
    /// JSON dump round-trips with every per-case field present.
    #[test]
    fn quick_mode_emits_parseable_json() {
        let tmp = std::env::temp_dir().join("accumkrr_bench_hotpath_test.json");
        let j = run_hotpath_to(&tmp.to_string_lossy(), 1, true);
        let text = std::fs::read_to_string(&tmp).unwrap();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed, j);
        assert_eq!(j.get("bench").and_then(|v| v.as_str()), Some("hotpath"));
        assert_eq!(j.get("mode").and_then(|v| v.as_str()), Some("quick"));
        let cases = j.get("cases").and_then(|v| v.as_arr()).unwrap();
        assert!(cases.len() >= 8, "expected the full quick case set");
        let ambient_name = simd::active().name();
        for c in cases {
            assert!(c.get("name").and_then(|v| v.as_str()).is_some());
            assert_eq!(c.get("kernel").and_then(|v| v.as_str()), Some(ambient_name));
            for field in ["median_secs", "p25_secs", "p75_secs", "gflops", "peak_rss_mb"] {
                let v = c.get(field).and_then(|v| v.as_f64()).unwrap();
                assert!(v >= 0.0, "{field} must be present and non-negative");
            }
            assert!(c.get("median_secs").unwrap().as_f64().unwrap() > 0.0);
            assert_eq!(c.get("reps").and_then(|v| v.as_usize()), Some(1));
            // the uplift pair travels together and only on dual-run rows
            match (c.get("scalar_median_secs"), c.get("uplift")) {
                (Some(s), Some(u)) => {
                    assert!(s.as_f64().unwrap() > 0.0);
                    assert!(u.as_f64().unwrap() > 0.0);
                    assert_ne!(ambient_name, "scalar", "no scalar rerun under scalar dispatch");
                }
                (None, None) => {}
                _ => panic!("scalar_median_secs and uplift must appear together"),
            }
        }
        // the tentpole cases are present by name
        let names: Vec<&str> = cases
            .iter()
            .filter_map(|c| c.get("name").and_then(|v| v.as_str()))
            .collect();
        assert!(names.iter().any(|n| n.starts_with("matmul ")));
        assert!(names.iter().any(|n| n.starts_with("kernel_matrix")));
        assert!(names.iter().any(|n| n.starts_with("kernel_matrix f32")));
        assert!(names.iter().any(|n| n.starts_with("map_sq_dist")));
        assert!(names.iter().any(|n| n.starts_with("partial_eigh")));
        assert!(names.iter().any(|n| n.starts_with("gram_op K·B streamed")));
        assert!(names.iter().any(|n| n.starts_with("matmul K·B dense")));
        // a vectorized host emits the uplift pair on the GEMM and
        // kernel-map rows (the acceptance gate's inputs)
        if ambient_name != "scalar" {
            for prefix in ["matmul ", "map_sq_dist"] {
                let i = names.iter().position(|n| n.starts_with(prefix)).unwrap();
                let u = cases[i].get("uplift").and_then(|v| v.as_f64()).unwrap();
                assert!(u > 0.0, "{prefix} case should report an uplift");
            }
        }
        assert!(j.get("peak_rss_mb").and_then(|v| v.as_f64()).is_some());
        // host provenance travels at the top level
        assert_eq!(j.get("kernel").and_then(|v| v.as_str()), Some(ambient_name));
        assert_eq!(
            j.get("arch").and_then(|v| v.as_str()),
            Some(std::env::consts::ARCH)
        );
        assert!(j
            .get("cpu_features")
            .and_then(|v| v.as_str())
            .is_some_and(|s| !s.is_empty()));
        std::fs::remove_file(&tmp).ok();
    }

    /// Streamed-only mode emits exactly the Gram-operator case — the
    /// harness allocates no dense K, so its peak RSS is the streamed
    /// path's (EXPERIMENTS.md's sublinearity protocol relies on this).
    #[test]
    fn streamed_only_case_set_is_just_the_operator() {
        let mut rng = Pcg64::seed(0xb6);
        let cases = build_cases(true, true, &mut rng);
        assert_eq!(cases.len(), 1);
        assert!(cases[0].name.starts_with("gram_op K·B streamed"));
    }
}
