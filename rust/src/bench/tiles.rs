//! Out-of-core tile-source bench: file-backed vs resident training.
//!
//! The acceptance comparison for the `data::TileSource` backends
//! (EXPERIMENTS.md §Out-of-core): one dataset, one sketched-KRR job,
//! three routes over identical bytes —
//!
//! 1. **file** — X in a single little-endian f64 row-major file
//!    ([`crate::data::F64File`]), streamed tile by tile via pread;
//! 2. **shards** — the same rows split across a shard directory
//!    ([`crate::data::ShardedFile`]), tiles straddling shard boundaries;
//! 3. **resident** — X as an in-memory [`Matrix`] with the full `n×n`
//!    kernel matrix materialised and shared across the fit (the dense
//!    `O(n²)`-memory comparator).
//!
//! The file-backed routes run **first**: the process peak-RSS samples
//! taken after them reflect the streamed paths alone (`VmHWM` is a
//! monotone high-water mark — see `util::mem::peak_rss_bytes`); the
//! resident comparator then necessarily drags the mark up with its
//! `n×n` allocation. Both streamed routes must land on coefficients
//! bitwise identical to the resident fit without the shared `K` — the
//! cross-backend invariance the `tiles` integration suite pins.
//! Results go to `BENCH_tiles.json`: per-route median seconds over the
//! replicates and `peak_rss_mb`, plus the bitwise-equality verdict.

use super::common::{BenchOpts, Row};
use crate::data::{write_f64_file, write_shards, F64File, ShardedFile, TileSource};
use crate::kernels::{kernel_matrix, Kernel};
use crate::krr::SketchedKrr;
use crate::linalg::{Matrix, Precision};
use crate::rng::Pcg64;
use crate::sketch::{SketchBuilder, SketchKind};
use crate::util::json::Json;
use crate::util::mem::peak_rss_bytes;
use crate::util::timer::Timer;

/// Run the out-of-core comparison (`--smoke` shrinks it to CI scale,
/// `--full` raises it to 8192 rows), dumping `BENCH_tiles.json` into
/// the working directory.
pub fn run_tiles(opts: &BenchOpts) -> Vec<Row> {
    run_tiles_to(opts, "BENCH_tiles.json")
}

/// Median of the (short) replicate timings.
fn median(xs: &mut [f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// Same as [`run_tiles`] with an explicit JSON output path (tests point
/// it at a temp file and a small `n_max`).
pub fn run_tiles_to(opts: &BenchOpts, json_path: &str) -> Vec<Row> {
    let n = if opts.full {
        8192
    } else if opts.smoke {
        opts.n_max.min(600)
    } else {
        opts.n_max
    };
    let p = 6usize;
    let d = 24usize.min(n);
    let lambda = 1e-3;
    let reps = opts.replicates.max(1);
    let kern = Kernel::matern(1.5, 1.0);
    let mut rng = Pcg64::seed(opts.seed ^ 0x7175);
    let x = Matrix::from_fn(n, p, |_, _| rng.normal());
    let y: Vec<f64> = (0..n).map(|i| (x[(i, 0)] - x[(i, 1)]).sin()).collect();
    // one sketch shared by every route: the comparison isolates the data
    // path, not the draw
    let sketch = SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(n, d, &mut rng);
    let rss_mb = || peak_rss_bytes().map(|b| b as f64 / (1024.0 * 1024.0)).unwrap_or(0.0);

    let file_path = std::env::temp_dir().join("accumkrr_bench_tiles_x.bin");
    let shard_dir = std::env::temp_dir().join("accumkrr_bench_tiles_shards");
    write_f64_file(&file_path.to_string_lossy(), &x).expect("tiles bench: write f64 file");
    // shard rows chosen so tiles straddle boundaries (not a divisor of n)
    write_shards(&shard_dir.to_string_lossy(), &x, (n / 7).max(1))
        .expect("tiles bench: write shards");

    let fit_streamed = |src: &dyn TileSource| -> (SketchedKrr, f64) {
        let mut secs: Vec<f64> = Vec::with_capacity(reps);
        let mut model = None;
        for _ in 0..reps {
            let t = Timer::start();
            let m = SketchedKrr::fit_with(kern, src, &y, &sketch, lambda, None, Precision::F64)
                .expect("tiles bench: streamed fit");
            secs.push(t.secs());
            model = Some(m);
        }
        (model.expect("reps >= 1"), median(&mut secs))
    };

    // 1–2. file-backed routes FIRST (monotone-RSS ordering, see the
    //      module docs)
    let file_src = F64File::open(&file_path.to_string_lossy(), p).expect("tiles bench: open file");
    let (file_model, file_secs) = fit_streamed(&file_src);
    let file_rss = rss_mb();
    let shard_src = ShardedFile::open(&shard_dir.to_string_lossy()).expect("tiles bench: shards");
    let (shard_model, shard_secs) = fit_streamed(&shard_src);
    let shard_rss = rss_mb();

    // 3. resident comparator: X in memory, full K materialised and
    //    shared across the fit
    let mut res_secs: Vec<f64> = Vec::with_capacity(reps);
    let mut res_model = None;
    for _ in 0..reps {
        let t = Timer::start();
        let k_full = kernel_matrix(&kern, &x);
        let m = SketchedKrr::fit_with(kern, &x, &y, &sketch, lambda, Some(&k_full), Precision::F64)
            .expect("tiles bench: resident fit");
        res_secs.push(t.secs());
        res_model = Some(m);
    }
    let resident_secs = median(&mut res_secs);
    let resident_rss = rss_mb();
    let res_model = res_model.expect("reps >= 1");

    // invariance verdict: the streamed routes agree bitwise with each
    // other; the shared-K comparator agrees numerically (different
    // summation schedule, same system)
    let bitwise = file_model.beta() == shard_model.beta();
    let max_dev = file_model
        .beta()
        .iter()
        .zip(res_model.beta())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);

    let rows = vec![
        Row::new(
            &[("fig", "tiles"), ("route", "file")],
            &[("n", n as f64), ("secs", file_secs), ("peak_rss_mb", file_rss)],
        ),
        Row::new(
            &[("fig", "tiles"), ("route", "shards")],
            &[("n", n as f64), ("secs", shard_secs), ("peak_rss_mb", shard_rss)],
        ),
        Row::new(
            &[("fig", "tiles"), ("route", "resident")],
            &[("n", n as f64), ("secs", resident_secs), ("peak_rss_mb", resident_rss)],
        ),
    ];

    let j = Json::obj(vec![
        ("bench", Json::from("tiles")),
        ("n", Json::from(n)),
        ("p", Json::from(p)),
        ("d", Json::from(d)),
        ("replicates", Json::from(reps)),
        (
            "file",
            Json::obj(vec![
                ("secs_median", Json::Num(file_secs)),
                ("peak_rss_mb", Json::Num(file_rss)),
            ]),
        ),
        (
            "shards",
            Json::obj(vec![
                ("secs_median", Json::Num(shard_secs)),
                ("peak_rss_mb", Json::Num(shard_rss)),
            ]),
        ),
        (
            "resident",
            Json::obj(vec![
                ("secs_median", Json::Num(resident_secs)),
                ("peak_rss_mb", Json::Num(resident_rss)),
            ]),
        ),
        ("streamed_bitwise_equal", Json::Bool(bitwise)),
        ("beta_dev_vs_resident", Json::Num(max_dev)),
    ]);
    if let Err(e) = std::fs::write(json_path, j.to_string()) {
        eprintln!("tiles bench: writing {json_path} failed: {e}");
    } else {
        println!("(out-of-core comparison written to {json_path})");
    }
    std::fs::remove_file(&file_path).ok();
    std::fs::remove_dir_all(&shard_dir).ok();
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The deterministic core of the out-of-core acceptance gate at a
    /// debug-friendly shape: both streamed backends agree bitwise, the
    /// file-backed peak-RSS samples (taken before the resident `n×n`
    /// allocation) stay strictly below the resident one, and the JSON
    /// artifact carries every field EXPERIMENTS.md names.
    #[test]
    fn tiles_bench_rows_json_and_rss_ordering() {
        let tmp = std::env::temp_dir().join("accumkrr_bench_tiles_test.json");
        let opts = BenchOpts {
            n_max: 700,
            replicates: 1,
            ..Default::default()
        };
        let rows = run_tiles_to(&opts, &tmp.to_string_lossy());
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].key("route"), Some("file"));
        let text = std::fs::read_to_string(&tmp).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("streamed_bitwise_equal"), Some(&Json::Bool(true)));
        let dev = j.get("beta_dev_vs_resident").and_then(|v| v.as_f64()).unwrap();
        assert!(dev.is_finite());
        let rss = |route: &str| {
            j.get(route)
                .and_then(|v| v.get("peak_rss_mb"))
                .and_then(|v| v.as_f64())
                .unwrap()
        };
        // VmHWM is monotone and process-wide: the streamed samples are
        // taken first, so they can never exceed the resident one. The
        // *strict* gap is asserted by the single-process `bench tiles`
        // CI run, not here — concurrent tests in this process can have
        // pushed the high-water mark arbitrarily high already.
        assert!(
            rss("file") <= rss("shards") && rss("shards") <= rss("resident"),
            "rss ordering: file {} shards {} resident {}",
            rss("file"),
            rss("shards"),
            rss("resident")
        );
        std::fs::remove_file(&tmp).ok();
    }
}
