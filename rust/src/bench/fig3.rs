//! Figures 3 & 4 (§4.2/§D.3): accuracy-vs-efficiency trade-off on the
//! (simulated) UCI datasets. Methods: Gaussian sketching, very sparse
//! random projection, Nyström with BLESS leverage scores, and the
//! accumulation method (m = 4). Matérn ν = 3/2,
//! λ = 0.9·n^{−(3+dX)/(3+2dX)}, d = ⌊1.5·n^{dX/(3+2dX)}⌋, 20% held-out
//! test split, features normalised to unit variance.

use super::common::{BenchOpts, Row};
use crate::coordinator::state::{dataset_for, paper_d, paper_lambda};
use crate::coordinator::JobScheduler;
use crate::data::{normalize_features, train_test_split};
use crate::krr::SketchedKrr;
use crate::leverage::bless;
use crate::rng::Pcg64;
use crate::sketch::{Sampling, Sketch, SketchBuilder, SketchKind};
use crate::stats::test_error;
use crate::util::timer::{timed, Timer};

/// The four candidate methods of Figure 3.
pub const METHODS: &[&str] = &["gaussian", "verysparse", "bless", "accum_m4"];

/// Train one method; returns (test_error, train_secs).
pub fn run_method(
    method: &str,
    kern: crate::kernels::Kernel,
    train_x: &crate::linalg::Matrix,
    train_y: &[f64],
    test_x: &crate::linalg::Matrix,
    test_y: &[f64],
    d: usize,
    lambda: f64,
    rng: &mut Pcg64,
) -> (f64, f64) {
    let n = train_x.rows();
    let t = Timer::start();
    let sketch: Sketch = match method {
        "gaussian" => SketchBuilder::new(SketchKind::Gaussian).build(n, d, rng),
        "verysparse" => SketchBuilder::new(SketchKind::VerySparse { sparsity: None })
            .build(n, d, rng),
        "accum_m4" => SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(n, d, rng),
        "bless" => {
            // leverage-score Nyström: BLESS estimates the scores (paper uses
            // ⌊3·n^{dX/(3+2dX)}⌋ sub-samples; we match via d target)
            let bl = bless(&kern, train_x, lambda, 2 * d, 1.5, rng);
            SketchBuilder::new(SketchKind::Nystrom)
                .with_sampling(Sampling::Weighted(bl.sampling_table()))
                .build(n, d, rng)
        }
        other => panic!("unknown method {other}"),
    };
    let (fit, fit_secs) = timed(|| SketchedKrr::fit(kern, train_x, train_y, &sketch, lambda, None));
    let model = fit.expect("fit");
    let secs = t.secs().max(fit_secs);
    let pred = model.predict(test_x);
    (test_error(&pred, test_y), secs)
}

/// Run the Figure-3/4 sweep over the given datasets.
pub fn run_fig3(opts: &BenchOpts, datasets: &[&str]) -> Vec<Row> {
    let ns = opts.n_sweep();
    let sched = JobScheduler::new(opts.seed ^ 3);
    let mut rows = Vec::new();
    for &ds_name in datasets {
        for &n in &ns {
            // draw n training + 20% test rows
            let results = sched.run_sweep(METHODS.len(), opts.replicates, |pt, rng| {
                let method = METHODS[pt.setting];
                let total = n + n / 4;
                let (mut ds, dx, kern) =
                    dataset_for(ds_name, total, 0.0, rng).expect("dataset");
                normalize_features(&mut ds.x);
                let (train, test) = train_test_split(&ds, 0.2, rng);
                let train = train.head(n);
                let d = paper_d(n, dx);
                let lambda = paper_lambda(n, dx);
                run_method(
                    method, kern, &train.x, &train.y, &test.x, &test.y, d, lambda, rng,
                )
            });
            for (mi, &method) in METHODS.iter().enumerate() {
                let errs: Vec<f64> = results[mi].iter().map(|r| r.0).collect();
                let secs: Vec<f64> = results[mi].iter().map(|r| r.1).collect();
                let (err, err_se) = JobScheduler::mean_stderr(&errs);
                let (sec, _) = JobScheduler::mean_stderr(&secs);
                rows.push(Row::new(
                    &[("fig", "fig3"), ("dataset", ds_name), ("method", method)],
                    &[
                        ("n", n as f64),
                        ("test_err", err),
                        ("err_se", err_se),
                        ("secs", sec),
                    ],
                ));
            }
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_tradeoff_shape_small_scale() {
        let opts = BenchOpts {
            replicates: 3,
            n_max: 500,
            ..Default::default()
        };
        let rows = run_fig3(&opts, &["rqa"]);
        assert_eq!(rows.len(), METHODS.len());
        let get = |m: &str, col: &str| {
            rows.iter()
                .find(|r| r.key("method") == Some(m))
                .unwrap()
                .val(col)
                .unwrap()
        };
        // runtime shape: accumulation ≪ gaussian (the O(nmd) vs O(n²d) gap)
        assert!(
            get("accum_m4", "secs") < get("gaussian", "secs"),
            "accum {} vs gaussian {}",
            get("accum_m4", "secs"),
            get("gaussian", "secs")
        );
        // every method produces finite errors
        for m in METHODS {
            assert!(get(m, "test_err").is_finite());
        }
    }
}
