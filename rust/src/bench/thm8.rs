//! Theorem-8 ablation: empirical K-satisfiability and incoherence vs
//! (d, m) on the paper's §3.2 failure construction — two clusters, a tiny
//! *dense, far* minority carrying an eigendirection almost entirely on a
//! few coordinates, so uniform sub-sampling has incoherence `M = Θ(n)`.
//! Demonstrates the theorem's two conditions in action: `d ≳ d_δ log²`
//! fixes the intrinsic dimension, `m·d ≳ M log³` fixes the incoherence —
//! raising m at fixed (adequate) d rescues uniform sub-sampling.

use super::common::{BenchOpts, Row};
use crate::coordinator::JobScheduler;
use crate::kernels::{kernel_matrix, Kernel};
use crate::linalg::Matrix;
use crate::sketch::{SketchBuilder, SketchKind};
use crate::stats::{incoherence, k_satisfiability, SpectralView};

/// Run the ablation at `n = min(opts.n_max, 600)` (the diagnostics are
/// eigendecomposition-bound).
pub fn run_thm8(opts: &BenchOpts) -> Vec<Row> {
    let n = opts.n_max.min(600);
    let sched = JobScheduler::new(opts.seed ^ 8);
    // §3.2 construction: diffuse majority + tiny tight far minority
    let n_small = (n / 150).max(2);
    let n_big = n - n_small;
    let mut rng0 = sched.rng_for(crate::coordinator::SweepPoint {
        setting: 0,
        replicate: 0,
    });
    let x = Matrix::from_fn(n, 2, |i, _| {
        if i < n_big {
            2.0 * rng0.uniform()
        } else {
            30.0 + 0.05 * rng0.uniform()
        }
    });
    let kern = Kernel::gaussian(1.0);
    let k = kernel_matrix(&kern, &x);
    let view = SpectralView::new(&k);
    // δ just below the minority eigenvalue σ ≈ n_small/n, so the minority
    // direction sits inside the top space U₁
    let delta = 0.8 * n_small as f64 / n as f64;
    let d_delta = view.d_delta(delta);
    let m_uniform = incoherence(&view, &vec![1.0 / n as f64; n], delta);

    let ms = [1usize, 2, 4, 8, 16];
    let base = d_delta.max(2);
    let ds = [base, 4 * base, 12 * base];
    let mut settings = Vec::new();
    for &d in &ds {
        for &m in &ms {
            settings.push((d, m));
        }
    }
    let results = sched.run_sweep(settings.len(), opts.replicates, |pt, rng| {
        let (d, m) = settings[pt.setting];
        let s = SketchBuilder::new(SketchKind::Accumulation { m }).build(n, d, rng);
        let rep = k_satisfiability(&view, &s, delta);
        (
            rep.top_distortion,
            rep.tail_norm / rep.sqrt_delta,
            rep.satisfied() as usize as f64,
        )
    });

    let mut rows = Vec::new();
    for (si, &(d, m)) in settings.iter().enumerate() {
        let dist: Vec<f64> = results[si].iter().map(|r| r.0).collect();
        let tail: Vec<f64> = results[si].iter().map(|r| r.1).collect();
        let sat: Vec<f64> = results[si].iter().map(|r| r.2).collect();
        let (dmean, _) = JobScheduler::mean_stderr(&dist);
        let (tmean, _) = JobScheduler::mean_stderr(&tail);
        let (smean, _) = JobScheduler::mean_stderr(&sat);
        rows.push(Row::new(
            &[("fig", "thm8")],
            &[
                ("n", n as f64),
                ("d", d as f64),
                ("m", m as f64),
                ("d_delta", d_delta as f64),
                ("M_incoh", m_uniform),
                ("top_distortion", dmean),
                ("tail_ratio", tmean),
                ("ksat_rate", smean),
            ],
        ));
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raising_m_reduces_distortion_at_adequate_d() {
        let opts = BenchOpts {
            replicates: 6,
            n_max: 300,
            ..Default::default()
        };
        let rows = run_thm8(&opts);
        // incoherence of the construction is Θ(n), dwarfing d_stat
        let m_incoh = rows[0].val("M_incoh").unwrap();
        let d_delta = rows[0].val("d_delta").unwrap();
        assert!(
            m_incoh > 5.0 * d_delta,
            "construction should be high-incoherence: M={m_incoh}, d_δ={d_delta}"
        );
        // at the largest (adequate) d, distortion at m=16 beats m=1
        // (Theorem 8: m·d ≳ M log³ is what uniform m=1 cannot meet)
        let dmax = rows
            .iter()
            .map(|r| r.val("d").unwrap() as u64)
            .max()
            .unwrap() as f64;
        let get_m = |m: f64| {
            rows.iter()
                .find(|r| r.val("d") == Some(dmax) && r.val("m") == Some(m))
                .unwrap()
                .val("top_distortion")
                .unwrap()
        };
        assert!(
            get_m(16.0) < get_m(1.0),
            "d={dmax}: m=16 {} should beat m=1 {}",
            get_m(16.0),
            get_m(1.0)
        );
    }
}
