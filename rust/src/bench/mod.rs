//! Figure-regeneration harness: one entry per table/figure in the paper
//! (see DESIGN.md §3 for the experiment index). Each harness returns
//! structured rows and prints the same series the paper plots; `cargo
//! bench --bench figures` runs quick versions, the CLI (`accumkrr bench
//! <id>`) exposes full-scale knobs.

mod adaptive;
mod cluster;
mod common;
mod cost;
mod ext;
mod fig1;
mod fig2;
mod fig3;
mod fig5;
mod hotpath;
mod sampling;
mod serve;
mod thm8;
mod tiles;

pub use adaptive::{run_adaptive, run_adaptive_to};
pub use cluster::{run_cluster, run_cluster_to};
pub use common::{print_table, BenchOpts, Row};
pub use ext::{run_ext_amm, run_ext_kpca, run_ext_sketches};
pub use hotpath::{hotpath_main, run_hotpath_to};
pub use cost::run_cost;
pub use fig1::run_fig1;
pub use fig2::run_fig2;
pub use fig3::run_fig3;
pub use fig5::run_fig5;
pub use sampling::{run_sampling, run_sampling_to};
pub use serve::{run_serve, run_serve_to};
pub use thm8::run_thm8;
pub use tiles::{run_tiles, run_tiles_to};

/// Dispatch a bench by id (`fig1`, `fig2`, `fig3`, `fig4`, `fig5`, `thm8`,
/// `cost`, `adaptive`, `sampling`, `cluster`, `serve`). `fig4` is `fig3`
/// over all three datasets; `adaptive` compares the incremental
/// accumulation engine against fixed-m refits and emits
/// `BENCH_adaptive.json`; `sampling` compares uniform vs leverage-fed vs
/// Poisson draws (error-vs-m, time-to-target) and emits
/// `BENCH_sampling.json`; `cluster` compares streamed vs dense Laplacian
/// spectral clustering and emits `BENCH_cluster.json`; `serve` load-tests
/// the reactor serving plane (adaptive batching vs none) and emits
/// `BENCH_serve.json`; `tiles` compares file-backed (out-of-core) vs
/// resident training over the `TileSource` backends and emits
/// `BENCH_tiles.json`.
pub fn run(id: &str, opts: &BenchOpts) -> Result<Vec<Row>, String> {
    match id {
        "fig1" => Ok(run_fig1(opts)),
        "fig2" => Ok(run_fig2(opts)),
        "fig3" => Ok(run_fig3(opts, &["rqa"])),
        "fig4" => Ok(run_fig3(opts, &["rqa", "casp", "gas"])),
        "fig5" => Ok(run_fig5(opts, &["rqa", "casp", "gas"])),
        "thm8" => Ok(run_thm8(opts)),
        "cost" => Ok(run_cost(opts)),
        "adaptive" => Ok(run_adaptive(opts)),
        "sampling" => Ok(run_sampling(opts)),
        "cluster" => Ok(run_cluster(opts)),
        "serve" => Ok(run_serve(opts)),
        "tiles" => Ok(run_tiles(opts)),
        "ext-sketches" => Ok(run_ext_sketches(opts)),
        "ext-amm" => Ok(run_ext_amm(opts)),
        "ext-kpca" => Ok(run_ext_kpca(opts)),
        other => Err(format!(
            "unknown bench id {other:?} (try fig1|fig2|fig3|fig4|fig5|thm8|cost|adaptive|sampling|cluster|serve|tiles|ext-sketches|ext-amm|ext-kpca)"
        )),
    }
}
