//! Clustering bench: streamed vs dense Laplacian spectral clustering.
//!
//! The acceptance comparison for the `cluster::` workload
//! (EXPERIMENTS.md §Clustering): one dataset, three routes —
//!
//! 1. **streamed** — `cluster::SpectralClustering::fit` through the
//!    Laplacian operator (peak memory `O(tile·n + n·k)`);
//! 2. **adaptive** — the accumulation-sketched pencil with runtime-chosen
//!    `m`;
//! 3. **dense** — materialise `K`, build `2I − L_sym` densely, same
//!    partial eigensolver, same deterministic k-means (the `O(n²)`-memory
//!    comparator).
//!
//! The streamed fit runs **first** so the process peak-RSS sample taken
//! after it reflects the streamed path alone (`VmHWM` is a monotone
//! high-water mark — see `util::mem::peak_rss_bytes`); the dense
//! comparator then necessarily drags the mark up by its two `n×n`
//! matrices. Results go to `BENCH_cluster.json`: per-route seconds and
//! `peak_rss_mb`, ARI of each route against the generator's ground
//! truth, the streamed-vs-dense label agreement (ARI) and embedding
//! subspace angle — the "same answer, `O(n)` memory" acceptance pair.

use super::common::{BenchOpts, Row};
use crate::cluster::{
    adjusted_rand_index, dense_shifted_laplacian, lloyd_kmeans, max_principal_sine,
    row_normalize, EmbedMethod, SpectralClustering, SpectralOptions, LAPLACIAN_SHIFT,
};
use crate::data::blobs;
use crate::kernels::{kernel_matrix, Kernel};
use crate::linalg::partial_eigh;
use crate::rng::Pcg64;
use crate::util::json::Json;
use crate::util::mem::peak_rss_bytes;
use crate::util::timer::Timer;

/// Run the clustering comparison at the acceptance shape (`--n-max 4096`
/// reproduces the gate; `--full` doubles it), dumping
/// `BENCH_cluster.json` into the working directory.
pub fn run_cluster(opts: &BenchOpts) -> Vec<Row> {
    run_cluster_to(opts, "BENCH_cluster.json")
}

/// Same as [`run_cluster`] with an explicit JSON output path (tests
/// point it at a temp file and a small `n_max`).
pub fn run_cluster_to(opts: &BenchOpts, json_path: &str) -> Vec<Row> {
    let n = if opts.full { 8192 } else { opts.n_max };
    let k = 3usize;
    let mut rng = Pcg64::seed(opts.seed ^ 0xc1);
    let (x, truth) = blobs(n, k, 6.0, 0.3, &mut rng);
    let kern = Kernel::gaussian(1.5);
    let rss_mb =
        || peak_rss_bytes().map(|b| b as f64 / (1024.0 * 1024.0)).unwrap_or(0.0);

    // 1. streamed operator route FIRST (monotone-RSS ordering, see the
    //    module docs)
    let t = Timer::start();
    let streamed = SpectralClustering::fit(
        kern,
        &x,
        &SpectralOptions {
            k,
            ..Default::default()
        },
        &mut rng,
    )
    .expect("streamed spectral fit");
    let streamed_secs = t.secs();
    let streamed_rss = rss_mb();
    let streamed_ari = adjusted_rand_index(&streamed.labels, &truth);

    // 2. adaptive sketched pencil (sparse accumulation sketch, runtime m)
    let d = crate::cluster::default_sketch_width(k, k, n);
    let t = Timer::start();
    let adaptive = SpectralClustering::fit(
        kern,
        &x,
        &SpectralOptions {
            k,
            method: EmbedMethod::Adaptive {
                d,
                m_max: 16,
                rel_tol: 5e-2,
            },
            ..Default::default()
        },
        &mut rng,
    )
    .expect("adaptive spectral fit");
    let adaptive_secs = t.secs();
    let adaptive_rss = rss_mb();
    let adaptive_ari = adjusted_rand_index(&adaptive.labels, &truth);
    let chosen_m = adaptive.chosen_m.unwrap_or(0);

    // 3. dense comparator: the same pipeline with K materialised
    let t = Timer::start();
    let kd = kernel_matrix(&kern, &x);
    let (shifted, _deg) = dense_shifted_laplacian(&kd, LAPLACIAN_SHIFT);
    let pe = partial_eigh(&shifted, k);
    let pts = row_normalize(&pe.v, k);
    let km = lloyd_kmeans(&pts, k, 100);
    let dense_secs = t.secs();
    let dense_rss = rss_mb();
    let dense_ari = adjusted_rand_index(&km.labels, &truth);

    // agreement between the streamed and dense routes
    let cross_ari = adjusted_rand_index(&streamed.labels, &km.labels);
    let subspace_sin = max_principal_sine(&streamed.embedding, &pe.v);

    let rows = vec![
        Row::new(
            &[("fig", "cluster"), ("route", "streamed")],
            &[
                ("n", n as f64),
                ("secs", streamed_secs),
                ("peak_rss_mb", streamed_rss),
                ("ari", streamed_ari),
            ],
        ),
        Row::new(
            &[("fig", "cluster"), ("route", "adaptive")],
            &[
                ("n", n as f64),
                ("secs", adaptive_secs),
                ("peak_rss_mb", adaptive_rss),
                ("ari", adaptive_ari),
            ],
        ),
        Row::new(
            &[("fig", "cluster"), ("route", "dense")],
            &[
                ("n", n as f64),
                ("secs", dense_secs),
                ("peak_rss_mb", dense_rss),
                ("ari", dense_ari),
            ],
        ),
    ];

    let j = Json::obj(vec![
        ("bench", Json::from("cluster")),
        ("n", Json::from(n)),
        ("k", Json::from(k)),
        ("d", Json::from(d)),
        (
            "streamed",
            Json::obj(vec![
                ("secs", Json::Num(streamed_secs)),
                ("peak_rss_mb", Json::Num(streamed_rss)),
                ("ari_vs_truth", Json::Num(streamed_ari)),
            ]),
        ),
        (
            "adaptive",
            Json::obj(vec![
                ("secs", Json::Num(adaptive_secs)),
                ("peak_rss_mb", Json::Num(adaptive_rss)),
                ("ari_vs_truth", Json::Num(adaptive_ari)),
                ("chosen_m", Json::from(chosen_m)),
            ]),
        ),
        (
            "dense",
            Json::obj(vec![
                ("secs", Json::Num(dense_secs)),
                ("peak_rss_mb", Json::Num(dense_rss)),
                ("ari_vs_truth", Json::Num(dense_ari)),
            ]),
        ),
        ("ari_streamed_vs_dense", Json::Num(cross_ari)),
        ("subspace_sin_max", Json::Num(subspace_sin)),
    ]);
    if let Err(e) = std::fs::write(json_path, j.to_string()) {
        eprintln!("cluster bench: writing {json_path} failed: {e}");
    } else {
        println!("(cluster comparison written to {json_path})");
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The deterministic core of the acceptance gate at a debug-friendly
    /// shape: streamed and dense routes agree (labels + subspace), the
    /// streamed peak-RSS sample (taken before the dense `n×n`
    /// allocations) does not exceed the dense one, and the JSON artifact
    /// carries every field EXPERIMENTS.md names.
    #[test]
    fn cluster_bench_rows_json_and_agreement() {
        let tmp = std::env::temp_dir().join("accumkrr_bench_cluster_test.json");
        let opts = BenchOpts {
            n_max: 240,
            ..Default::default()
        };
        let rows = run_cluster_to(&opts, &tmp.to_string_lossy());
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].key("route"), Some("streamed"));
        let text = std::fs::read_to_string(&tmp).unwrap();
        let j = Json::parse(&text).unwrap();
        let cross = j
            .get("ari_streamed_vs_dense")
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(cross >= 0.95, "streamed vs dense ARI {cross}");
        let sine = j.get("subspace_sin_max").and_then(|v| v.as_f64()).unwrap();
        assert!(sine < 1e-6, "subspace sin {sine}");
        let s_rss = j
            .get("streamed")
            .and_then(|v| v.get("peak_rss_mb"))
            .and_then(|v| v.as_f64())
            .unwrap();
        let d_rss = j
            .get("dense")
            .and_then(|v| v.get("peak_rss_mb"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(
            s_rss <= d_rss,
            "streamed RSS {s_rss} must not exceed dense RSS {d_rss}"
        );
        let m = j
            .get("adaptive")
            .and_then(|v| v.get("chosen_m"))
            .and_then(|v| v.as_usize())
            .unwrap();
        assert!(m >= 1, "chosen m {m}");
        std::fs::remove_file(&tmp).ok();
    }
}
