//! Extension benches beyond the paper's figures:
//!
//! * `ext-sketches` — all seven sketch families in the crate on one
//!   high-incoherence KRR task (err + time at equal d).
//! * `ext-amm` — approximate matrix multiplication error vs d (paper §5
//!   future work).
//! * `ext-kpca` — sketched kernel PCA: top-spectrum mass recovered per
//!   sketch family (paper §5 future work).

use super::common::{BenchOpts, Row};
use crate::coordinator::JobScheduler;
use crate::data::{bimodal, BimodalConfig};
use crate::kernels::{kernel_matrix, Kernel, RffKrr};
use crate::krr::{sketched_kpca, KrrModel, SketchedKrr};
use crate::linalg::Matrix;
use crate::sketch::{countsketch, srht, Sketch, SketchBuilder, SketchKind};
use crate::stats::{in_sample_sq_error, top_sigma};
use crate::util::timer::Timer;

fn build_named(name: &str, n: usize, d: usize, rng: &mut crate::rng::Pcg64) -> Sketch {
    match name {
        "nystrom" => SketchBuilder::new(SketchKind::Nystrom).build(n, d, rng),
        "accum_m4" => SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(n, d, rng),
        "accum_m16" => SketchBuilder::new(SketchKind::Accumulation { m: 16 }).build(n, d, rng),
        "gaussian" => SketchBuilder::new(SketchKind::Gaussian).build(n, d, rng),
        "rademacher" => SketchBuilder::new(SketchKind::Rademacher).build(n, d, rng),
        "verysparse" => {
            SketchBuilder::new(SketchKind::VerySparse { sparsity: None }).build(n, d, rng)
        }
        "srht" => srht(n, d, rng),
        "countsketch" => countsketch(n, d, rng),
        other => panic!("unknown sketch {other}"),
    }
}

const FAMILIES: &[&str] = &[
    "nystrom",
    "accum_m4",
    "accum_m16",
    "gaussian",
    "rademacher",
    "verysparse",
    "srht",
    "countsketch",
];

/// All sketch families + the RFF baseline on one sketched-KRR task.
pub fn run_ext_sketches(opts: &BenchOpts) -> Vec<Row> {
    let n = opts.n_max.min(1500);
    let sched = JobScheduler::new(opts.seed ^ 0xe1);
    let cfg = BimodalConfig {
        n,
        gamma: 0.5,
        ..Default::default()
    };
    let kern = Kernel::gaussian(1.5 * (n as f64).powf(-1.0 / 7.0));
    let lambda = 0.5 * (n as f64).powf(-4.0 / 7.0);
    let d = ((1.5 * (n as f64).powf(3.0 / 7.0)) as usize).max(4);

    let n_settings = FAMILIES.len() + 1; // + rff
    let results = sched.run_sweep(n_settings, opts.replicates, |pt, rng| {
        let (x, y, _) = bimodal(&cfg, rng);
        let k = kernel_matrix(&kern, &x);
        let exact = KrrModel::fit_with_k(kern, &x, &k, &y, lambda).expect("exact");
        let t = Timer::start();
        if pt.setting < FAMILIES.len() {
            let name = FAMILIES[pt.setting];
            let s = build_named(name, n, d, rng);
            let shared_k = matches!(s, Sketch::Dense(_)).then_some(&k);
            let model = SketchedKrr::fit(kern, &x, &y, &s, lambda, shared_k).expect("fit");
            let secs = t.secs();
            (in_sample_sq_error(model.fitted(), exact.fitted()), secs)
        } else {
            // RFF baseline with D = 4·d features
            let model = RffKrr::fit(&kern, &x, &y, 4 * d, lambda, rng).expect("rff fit");
            let secs = t.secs();
            (in_sample_sq_error(model.fitted(), exact.fitted()), secs)
        }
    });

    let mut rows = Vec::new();
    for (si, res) in results.iter().enumerate() {
        let name = if si < FAMILIES.len() { FAMILIES[si] } else { "rff_4d" };
        let errs: Vec<f64> = res.iter().map(|r| r.0).collect();
        let secs: Vec<f64> = res.iter().map(|r| r.1).collect();
        let (err, err_se) = JobScheduler::mean_stderr(&errs);
        let (sec, _) = JobScheduler::mean_stderr(&secs);
        rows.push(Row::new(
            &[("fig", "ext-sketches"), ("method", name)],
            &[
                ("n", n as f64),
                ("d", d as f64),
                ("approx_err", err),
                ("err_se", err_se),
                ("secs", sec),
            ],
        ));
    }
    rows
}

/// AMM error vs d for accumulation sketches (paper §5).
pub fn run_ext_amm(opts: &BenchOpts) -> Vec<Row> {
    let n = opts.n_max.min(800);
    let sched = JobScheduler::new(opts.seed ^ 0xe2);
    let ds = [8usize, 16, 32, 64, 128];
    let results = sched.run_sweep(ds.len(), opts.replicates.max(5), |pt, rng| {
        let d = ds[pt.setting];
        let a = Matrix::from_fn(16, n, |_, _| rng.normal());
        let b = Matrix::from_fn(n, 16, |_, _| rng.normal());
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(n, d, rng);
        crate::sketch::amm_rel_error(&a, &b, &s)
    });
    let mut rows = Vec::new();
    for (si, &d) in ds.iter().enumerate() {
        let (err, se) = JobScheduler::mean_stderr(&results[si]);
        rows.push(Row::new(
            &[("fig", "ext-amm")],
            &[("n", n as f64), ("d", d as f64), ("rel_err", err), ("err_se", se)],
        ));
    }
    rows
}

/// KPCA spectrum recovery per sketch family (paper §5).
pub fn run_ext_kpca(opts: &BenchOpts) -> Vec<Row> {
    let n = opts.n_max.min(400);
    let sched = JobScheduler::new(opts.seed ^ 0xe3);
    let cfg = BimodalConfig {
        n,
        gamma: 0.5,
        ..Default::default()
    };
    let kern = Kernel::gaussian(0.7);
    let d = ((2.0 * (n as f64).powf(3.0 / 7.0)) as usize).max(8);
    let r = 6;
    let families = ["nystrom", "accum_m4", "accum_m16", "gaussian"];
    let results = sched.run_sweep(families.len(), opts.replicates, |pt, rng| {
        let (x, _, _) = bimodal(&cfg, rng);
        let k = kernel_matrix(&kern, &x);
        // only the top-r spectral mass is consumed → partial eigensolver
        let exact_mass: f64 = top_sigma(&k, r).iter().sum();
        let s = build_named(families[pt.setting], n, d, rng);
        let got = sketched_kpca(&kern, &x, &s, r)
            .map(|res| res.eigenvalues.iter().sum::<f64>())
            .unwrap_or(0.0);
        got / exact_mass
    });
    let mut rows = Vec::new();
    for (si, &name) in families.iter().enumerate() {
        let (frac, se) = JobScheduler::mean_stderr(&results[si]);
        rows.push(Row::new(
            &[("fig", "ext-kpca"), ("method", name)],
            &[
                ("n", n as f64),
                ("d", d as f64),
                ("r", r as f64),
                ("spectrum_frac", frac),
                ("err_se", se),
            ],
        ));
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ext_sketches_all_finite_and_accum_competitive() {
        let opts = BenchOpts {
            replicates: 3,
            n_max: 400,
            ..Default::default()
        };
        let rows = run_ext_sketches(&opts);
        assert_eq!(rows.len(), FAMILIES.len() + 1);
        for r in &rows {
            assert!(r.val("approx_err").unwrap().is_finite(), "{:?}", r.key("method"));
        }
        let err = |m: &str| {
            rows.iter()
                .find(|r| r.key("method") == Some(m))
                .unwrap()
                .val("approx_err")
                .unwrap()
        };
        // accumulation m=16 should be within a small factor of gaussian
        assert!(err("accum_m16") < 20.0 * err("gaussian") + 1e-9);
    }

    #[test]
    fn ext_amm_error_monotone_in_d() {
        let opts = BenchOpts {
            replicates: 6,
            n_max: 300,
            ..Default::default()
        };
        let rows = run_ext_amm(&opts);
        let first = rows.first().unwrap().val("rel_err").unwrap();
        let last = rows.last().unwrap().val("rel_err").unwrap();
        assert!(last < first, "rel err should fall with d: {first} → {last}");
    }

    #[test]
    fn ext_kpca_gaussian_and_accum_recover_more_than_nystrom() {
        let opts = BenchOpts {
            replicates: 4,
            n_max: 250,
            ..Default::default()
        };
        let rows = run_ext_kpca(&opts);
        let frac = |m: &str| {
            rows.iter()
                .find(|r| r.key("method") == Some(m))
                .unwrap()
                .val("spectrum_frac")
                .unwrap()
        };
        assert!(frac("accum_m16") >= frac("nystrom") * 0.95);
        assert!(frac("gaussian") > 0.5);
    }
}
