//! Figure 2 (§4.1): approximation error ‖f̂_S − f̂_n‖²_n vs projection
//! dimension d, one curve per accumulation level m ∈ {1, 2, 4, 8, 16, 32}
//! plus Gaussian (m = ∞) and the exact-KRR estimation error ‖f̂_n − f*‖²_n
//! as the reference line. Gaussian kernel bw = 1.5·n^{−1/7},
//! λ = 0.5·n^{−4/7}, bimodal γ = 0.6, d from ⌊0.3·n^{3/7}⌋ to ⌊3·n^{3/7}⌋.

use super::common::{BenchOpts, Row};
use crate::coordinator::JobScheduler;
use crate::data::{bimodal, BimodalConfig};
use crate::kernels::{kernel_matrix, Kernel};
use crate::krr::{KrrModel, SketchedKrr};
use crate::sketch::{SketchBuilder, SketchKind};
use crate::stats::in_sample_sq_error;

/// m-levels plotted by the paper (0 encodes Gaussian / m = ∞).
pub const M_LEVELS: &[usize] = &[1, 2, 4, 8, 16, 32, 0];

/// Run the Figure-2 sweep at `n = opts.n_max` (the paper varies n from 1k
/// to 8k; each n is a separate invocation).
pub fn run_fig2(opts: &BenchOpts) -> Vec<Row> {
    let n = opts.n_max;
    let lambda = 0.5 * (n as f64).powf(-4.0 / 7.0);
    let bw = 1.5 * (n as f64).powf(-1.0 / 7.0);
    let kern = Kernel::gaussian(bw);
    let base_d = (n as f64).powf(3.0 / 7.0);
    let d_factors = [0.3, 0.75, 1.5, 3.0];
    let sched = JobScheduler::new(opts.seed ^ 2);

    // settings = (d, m) grid
    let mut settings = Vec::new();
    for &f in &d_factors {
        let d = ((f * base_d).floor() as usize).max(2);
        for &m in M_LEVELS {
            settings.push((d, m));
        }
    }

    let results = sched.run_sweep(settings.len(), opts.replicates, |pt, rng| {
        let (d, m) = settings[pt.setting];
        let cfg = BimodalConfig {
            n,
            gamma: 0.6,
            ..Default::default()
        };
        let (x, y, truth) = bimodal(&cfg, rng);
        let k = kernel_matrix(&kern, &x);
        let exact = KrrModel::fit_with_k(kern, &x, &k, &y, lambda).expect("exact KRR");
        let kind = if m == 0 {
            SketchKind::Gaussian
        } else {
            SketchKind::Accumulation { m }
        };
        // --streamed: no shared K — dense sketches stream K·S through the
        // Gram operator instead of borrowing the baseline's assembly
        let shared_k = (!opts.streamed && matches!(kind, SketchKind::Gaussian)).then_some(&k);
        let s = SketchBuilder::new(kind).build(n, d, rng);
        let skrr = SketchedKrr::fit(kern, &x, &y, &s, lambda, shared_k).expect("sketched fit");
        let approx_err = in_sample_sq_error(skrr.fitted(), exact.fitted());
        let est_err = in_sample_sq_error(exact.fitted(), &truth);
        (approx_err, est_err)
    });

    let mut rows = Vec::new();
    for (si, &(d, m)) in settings.iter().enumerate() {
        let errs: Vec<f64> = results[si].iter().map(|r| r.0).collect();
        let refs: Vec<f64> = results[si].iter().map(|r| r.1).collect();
        let (err, err_se) = JobScheduler::mean_stderr(&errs);
        let (est, _) = JobScheduler::mean_stderr(&refs);
        let label = if m == 0 { "inf".to_string() } else { m.to_string() };
        rows.push(Row::new(
            &[("fig", "fig2"), ("m", &label)],
            &[
                ("n", n as f64),
                ("d", d as f64),
                ("approx_err", err),
                ("err_se", err_se),
                ("krr_est_err", est),
            ],
        ));
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deflaked (was `#[ignore]`d): a single fixed-seed mean can invert
    /// adjacent m-curves at this miniature scale, but the *median over
    /// independent seeds* of the m=1 vs m=16 vs Gaussian ordering is
    /// stable — an outlier seed ends up in the tail, not the middle.
    /// Scale is kept small (n = 400, 4 replicates, 3 seeds) so the test
    /// stays within tier-1 runtime.
    #[test]
    fn fig2_error_monotone_in_m_at_small_scale() {
        let errs_at_dmax = |seed: u64| {
            let opts = BenchOpts {
                replicates: 4,
                n_max: 400,
                seed,
                ..Default::default()
            };
            let rows = run_fig2(&opts);
            // largest d: where accumulation separates the curves most
            let dmax = rows.iter().map(|r| r.val("d").unwrap()).fold(0.0f64, f64::max);
            let err_of = |m: &str| {
                rows.iter()
                    .find(|r| r.key("m") == Some(m) && r.val("d") == Some(dmax))
                    .unwrap()
                    .val("approx_err")
                    .unwrap()
            };
            (err_of("1"), err_of("16"), err_of("inf"))
        };
        let (mut e1, mut e16, mut einf) = (Vec::new(), Vec::new(), Vec::new());
        for seed in [2u64, 12, 22] {
            let (a, b, c) = errs_at_dmax(seed);
            e1.push(a);
            e16.push(b);
            einf.push(c);
        }
        let median = |vals: &mut Vec<f64>| {
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals[vals.len() / 2]
        };
        let (m1, m16, minf) = (median(&mut e1), median(&mut e16), median(&mut einf));
        assert!(m16 < m1, "median m=16 ({m16}) should beat m=1 ({m1})");
        assert!(minf < m1, "median gaussian ({minf}) should beat m=1 ({m1})");
    }
}
