//! Informed-sampling bench: uniform vs leverage-weighted accumulation vs
//! Poisson inclusion, on a common error-vs-m axis.
//!
//! All three schemes target the same exact-KRR reference on a bimodal
//! dataset whose imbalanced clusters give a genuinely non-uniform ridge
//! leverage profile. The bench emits `BENCH_sampling.json` with:
//!
//! * error-vs-m curves (median relative fitted-value error over seeds)
//!   per scheme, plus per-point fit seconds;
//! * a self-calibrated target error — uniform's median error at the top
//!   of the m grid — and each scheme's `best_m` (smallest m at or under
//!   the target) and `secs_at_best` (time-to-target);
//! * the adaptive comparison: final m chosen by the stopping rule with
//!   refinement off vs `refine_after_m = 1`, at equal `rel_tol`;
//! * a raw-feature [`sketched_ols`](crate::krr::sketched_ols) mini-curve
//!   (uniform vs [`feature_leverage`](crate::krr::feature_leverage)-fed
//!   draws).

use super::common::{BenchOpts, Row};
use crate::data::{bimodal, BimodalConfig};
use crate::kernels::{kernel_matrix, Kernel};
use crate::krr::{
    feature_leverage, ridge_exact, sketched_ols, AdaptiveOptions, KrrModel, SketchedKrr,
};
use crate::leverage::{exact_scores, stat_dim_from_scores};
use crate::rng::{AliasTable, Pcg64};
use crate::sketch::{Sampling, SketchBuilder, SketchKind};
use crate::util::json::Json;
use crate::util::timer::Timer;

/// The m grid every scheme is swept over. Poisson has no terms — its
/// grid point `m` is a Nyström-shaped draw at `d_target = d·m`, matching
/// the accumulation schemes' expected sample budget.
const M_GRID: [usize; 5] = [1, 2, 4, 8, 16];

/// Relative ℓ₂ error between two fitted-value vectors.
fn rel_err(got: &[f64], want: &[f64]) -> f64 {
    let num: f64 = got
        .iter()
        .zip(want.iter())
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    let den: f64 = want.iter().map(|b| b * b).sum::<f64>().sqrt();
    num / den.max(1e-300)
}

fn median(v: &mut [f64]) -> f64 {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

/// One (scheme, m) sweep point: median error + median fit seconds.
struct Point {
    m: usize,
    err: f64,
    secs: f64,
}

/// Run the informed-sampling comparison, dumping `BENCH_sampling.json`
/// into the working directory.
pub fn run_sampling(opts: &BenchOpts) -> Vec<Row> {
    run_sampling_to(opts, "BENCH_sampling.json")
}

/// Same as [`run_sampling`] with an explicit JSON output path (tests
/// point it at a temp file).
pub fn run_sampling_to(opts: &BenchOpts, json_path: &str) -> Vec<Row> {
    // the exact-KRR reference is O(n³): keep n modest even in full runs
    let n = if opts.smoke { 240 } else { opts.n_max.min(600) };
    let cfg = BimodalConfig {
        n,
        gamma: 0.5,
        ..Default::default()
    };
    let mut data_rng = Pcg64::seed(opts.seed ^ 0x5a);
    let (x, y, _) = bimodal(&cfg, &mut data_rng);
    let lambda = 0.5 * (n as f64).powf(-4.0 / 7.0);
    let kern = Kernel::gaussian(1.5 * (n as f64).powf(-1.0 / 7.0));
    let d = ((1.5 * (n as f64).powf(3.0 / 7.0)) as usize).max(6);
    let seeds: Vec<u64> = (0..opts.replicates.max(3) as u64)
        .map(|i| opts.seed ^ (0x5a17 + i * 0x9e37))
        .collect();

    let exact = KrrModel::fit(kern, &x, &y, lambda).expect("exact KRR reference");
    let reference = exact.fitted();

    // the informed profile every non-uniform scheme draws from: exact
    // ridge leverage at the training λ (n is small enough here; the
    // serving path switches to BLESS past n = 512)
    let scores = exact_scores(&kernel_matrix(&kern, &x), lambda);
    let d_stat = stat_dim_from_scores(&scores);

    let sweep = |scheme: &str| -> Vec<Point> {
        M_GRID
            .iter()
            .map(|&m| {
                let mut errs = Vec::new();
                let mut secs = Vec::new();
                for &seed in &seeds {
                    let mut rng = Pcg64::seed(seed);
                    let t = Timer::start();
                    let sketch = match scheme {
                        "uniform" => SketchBuilder::new(SketchKind::Accumulation { m })
                            .build(n, d, &mut rng),
                        "leverage" => SketchBuilder::new(SketchKind::Accumulation { m })
                            .with_sampling(Sampling::Weighted(AliasTable::new(&scores)))
                            .build(n, d, &mut rng),
                        "poisson" => SketchBuilder::new(SketchKind::Nystrom)
                            .with_sampling(Sampling::Poisson(AliasTable::new(&scores)))
                            .build(n, (d * m).min(n), &mut rng),
                        other => unreachable!("scheme {other}"),
                    };
                    let model = SketchedKrr::fit(kern, &x, &y, &sketch, lambda, None)
                        .expect("sketched fit");
                    secs.push(t.secs());
                    errs.push(rel_err(model.fitted(), reference));
                }
                Point {
                    m,
                    err: median(&mut errs),
                    secs: median(&mut secs),
                }
            })
            .collect()
    };

    let curves: Vec<(&str, Vec<Point>)> = ["uniform", "leverage", "poisson"]
        .iter()
        .map(|&s| (s, sweep(s)))
        .collect();

    // self-calibrating target: whatever uniform achieves at the top of
    // the grid — `best_m` is then the smallest m reaching that quality
    let target = curves[0].1.last().expect("grid non-empty").err;
    let best = |pts: &[Point]| -> (usize, f64) {
        pts.iter()
            .find(|p| p.err <= target)
            .map(|p| (p.m, p.secs))
            .unwrap_or_else(|| {
                let l = pts.last().expect("grid non-empty");
                (l.m, l.secs)
            })
    };

    // adaptive stopping: refinement off vs on, equal tolerance and seed
    let rel_tol = 0.05;
    let adaptive_m = |refine: usize| -> (usize, usize, f64) {
        let aopts = AdaptiveOptions {
            m_max: *M_GRID.last().expect("grid non-empty"),
            rel_tol,
            refine_after_m: refine,
            ..Default::default()
        };
        let builder = SketchBuilder::new(SketchKind::Accumulation { m: 1 });
        let mut rng = Pcg64::seed(opts.seed ^ 0xada5);
        let (model, _) =
            SketchedKrr::fit_adaptive(kern, &x, &y, &builder, d, lambda, &aopts, &mut rng)
                .expect("adaptive fit");
        let rep = *model.report();
        (rep.m, rep.refine_round, rep.d_stat)
    };
    let (m_unrefined, _, _) = adaptive_m(0);
    let (m_refined, refine_round, refined_d_stat) = adaptive_m(1);

    // raw-feature mini-curve: sketched OLS on the design matrix itself,
    // uniform vs feature-leverage-informed draws at d = 2·p columns' worth
    let ols_exact = ridge_exact(&x, &y, lambda).expect("exact ridge");
    let ols_scores = feature_leverage(&x, lambda);
    let ols_d = (2 * x.cols()).max(6);
    let ols_curve = |sampling: &Sampling| -> Vec<(usize, f64)> {
        [1usize, 4, 16]
            .iter()
            .map(|&m| {
                let mut errs: Vec<f64> = seeds
                    .iter()
                    .map(|&seed| {
                        let mut rng = Pcg64::seed(seed ^ 0x015);
                        let s = SketchBuilder::new(SketchKind::Accumulation { m })
                            .with_sampling(sampling.clone())
                            .build(n, ols_d, &mut rng);
                        let fit = sketched_ols(&x, &y, &s, lambda).expect("sketched ols");
                        rel_err(fit.beta(), &ols_exact)
                    })
                    .collect();
                (m, median(&mut errs))
            })
            .collect()
    };
    let ols_uniform = ols_curve(&Sampling::Uniform);
    let ols_informed = ols_curve(&Sampling::Weighted(AliasTable::new(&ols_scores)));

    let mut rows = Vec::new();
    for (scheme, pts) in &curves {
        for p in pts {
            rows.push(Row::new(
                &[("fig", "sampling"), ("scheme", *scheme)],
                &[("m", p.m as f64), ("rel_err", p.err), ("secs", p.secs)],
            ));
        }
    }
    let curve_json = |pts: &[Point]| -> Json {
        Json::Arr(
            pts.iter()
                .map(|p| {
                    Json::obj(vec![
                        ("m", Json::from(p.m)),
                        ("rel_err", Json::Num(p.err)),
                        ("secs", Json::Num(p.secs)),
                    ])
                })
                .collect(),
        )
    };
    let ols_json = |pts: &[(usize, f64)]| -> Json {
        Json::Arr(
            pts.iter()
                .map(|(m, e)| {
                    Json::obj(vec![("m", Json::from(*m)), ("rel_err", Json::Num(*e))])
                })
                .collect(),
        )
    };
    let mut fields = vec![
        ("bench", Json::from("sampling")),
        ("n", Json::from(n)),
        ("d", Json::from(d)),
        ("lambda", Json::Num(lambda)),
        ("d_stat", Json::Num(d_stat)),
        ("target_rel_err", Json::Num(target)),
        ("adaptive_rel_tol", Json::Num(rel_tol)),
        ("adaptive_m_unrefined", Json::from(m_unrefined)),
        ("adaptive_m_refined", Json::from(m_refined)),
        ("refine_round", Json::from(refine_round)),
        ("refined_d_stat", Json::Num(refined_d_stat)),
        ("ols_uniform", ols_json(&ols_uniform)),
        ("ols_leverage", ols_json(&ols_informed)),
    ];
    for (scheme, pts) in &curves {
        let (bm, bs) = best(pts);
        fields.push((
            *scheme,
            Json::obj(vec![
                ("curve", curve_json(pts)),
                ("best_m", Json::from(bm)),
                ("secs_at_best", Json::Num(bs)),
            ]),
        ));
    }
    let j = Json::obj(fields);
    if let Err(e) = std::fs::write(json_path, j.to_string()) {
        eprintln!("sampling bench: writing {json_path} failed: {e}");
    } else {
        println!("(sampling comparison written to {json_path})");
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_bench_informed_schemes_reach_target_no_later() {
        let tmp = std::env::temp_dir().join("accumkrr_bench_sampling_test.json");
        let opts = BenchOpts {
            replicates: 3,
            smoke: true,
            ..Default::default()
        };
        let rows = run_sampling_to(&opts, &tmp.to_string_lossy());
        // 3 schemes × 5 grid points
        assert_eq!(rows.len(), 3 * M_GRID.len());
        let text = std::fs::read_to_string(&tmp).unwrap();
        let j = Json::parse(&text).unwrap();
        let best = |s: &str| -> usize {
            j.get(s)
                .and_then(|v| v.get("best_m"))
                .and_then(|v| v.as_usize())
                .unwrap()
        };
        let (uni, lev, poi) = (best("uniform"), best("leverage"), best("poisson"));
        // informed draws must reach uniform's top-of-grid error no later
        // on the m axis (the JSON records the actual — typically strict —
        // improvement for the acceptance gate)
        assert!(lev <= uni, "leverage best_m {lev} vs uniform {uni}");
        assert!(poi <= uni, "poisson best_m {poi} vs uniform {uni}");
        // refinement can only tighten the stopping point at equal rel_tol
        let m0 = j.get("adaptive_m_unrefined").and_then(|v| v.as_usize()).unwrap();
        let m1 = j.get("adaptive_m_refined").and_then(|v| v.as_usize()).unwrap();
        assert!(m1 <= m0, "refined m {m1} vs unrefined {m0}");
        assert!(j.get("refine_round").and_then(|v| v.as_usize()).unwrap() >= 1);
        // the informed OLS curve is at least as good at the top m
        let tail = |k: &str| -> f64 {
            j.get(k)
                .and_then(|v| v.as_arr())
                .and_then(|a| a.last())
                .and_then(|p| p.get("rel_err"))
                .and_then(|v| v.as_f64())
                .unwrap()
        };
        assert!(tail("ols_uniform").is_finite() && tail("ols_leverage").is_finite());
        std::fs::remove_file(&tmp).ok();
    }
}
