//! Kernel functions, empirical kernel-matrix assembly, and the row-tiled
//! implicit Gram operator.
//!
//! The paper's experiments use the Gaussian (RBF) kernel (Figure 2) and the
//! Matérn family with ν ∈ {1/2, 3/2} (Figures 1, 3–5); Laplacian,
//! polynomial and linear kernels round out the library for downstream use.
//! Kernel-matrix assembly ([`kernel_matrix`], [`cross_kernel`]) is tiled
//! and runs on the thread pool — it is one of the two L3 hot paths (the
//! other is sketch application). Square self-assembly exploits symmetry
//! (upper tiles + mirror, ~2× cheaper).
//!
//! [`GramOperator`] is the streamed alternative to materialising `K`: it
//! assembles `K[tile, :]` on the fly and exposes `K·B`, gathered columns,
//! `diag(K)` and the sketched Grams with `O(tile·n + n·d)` peak memory —
//! the memory model every training/diagnostic path routes through (see
//! DESIGN.md §5). [`assembly_guard`] instruments the "never allocates
//! `n×n`" contract for tests.

mod functions;
mod matrix;
mod operator;
mod rff;

pub use functions::{Kernel, KernelKind};
pub(crate) use matrix::{cross_kernel_f32, cross_kernel_rows_f32};
pub use matrix::{
    assembly_guard, cross_kernel, cross_kernel_rowstable, gather_rows, kernel_cols, kernel_diag,
    kernel_matrix,
};
pub use operator::{GramOperator, COL_TILE, DEFAULT_TILE, ROW_TILE_ENV};
pub use rff::{RandomFourierFeatures, RffKrr};
