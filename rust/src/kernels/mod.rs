//! Kernel functions and empirical kernel-matrix assembly.
//!
//! The paper's experiments use the Gaussian (RBF) kernel (Figure 2) and the
//! Matérn family with ν ∈ {1/2, 3/2} (Figures 1, 3–5); Laplacian,
//! polynomial and linear kernels round out the library for downstream use.
//! Kernel-matrix assembly ([`kernel_matrix`], [`cross_kernel`]) is tiled
//! and runs on the thread pool — it is one of the two L3 hot paths (the
//! other is sketch application).

mod functions;
mod matrix;
mod rff;

pub use functions::{Kernel, KernelKind};
pub use matrix::{cross_kernel, gather_rows, kernel_cols, kernel_diag, kernel_matrix};
pub use rff::{RandomFourierFeatures, RffKrr};
