//! Kernel function definitions.

/// Which positive semi-definite kernel to use.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelKind {
    /// Gaussian / RBF: `exp(−‖x−y‖² / (2σ²))`.
    Gaussian,
    /// Matérn ν = 1/2 (a.k.a. exponential): `exp(−r/σ)`.
    Matern12,
    /// Matérn ν = 3/2: `(1 + √3 r/σ) exp(−√3 r/σ)`.
    Matern32,
    /// Matérn ν = 5/2: `(1 + √5 r/σ + 5r²/(3σ²)) exp(−√5 r/σ)`.
    Matern52,
    /// Laplacian over L1 distance: `exp(−‖x−y‖₁/σ)`.
    Laplacian,
    /// Polynomial `(xᵀy/σ + 1)^p` (degree in [`Kernel::degree`]).
    Polynomial,
    /// Linear `xᵀy`.
    Linear,
}

/// A configured kernel: kind + bandwidth (+ degree for polynomial).
#[derive(Clone, Copy, Debug)]
pub struct Kernel {
    /// Kernel family.
    pub kind: KernelKind,
    /// Length-scale σ (ignored by `Linear`).
    pub bandwidth: f64,
    /// Polynomial degree (ignored elsewhere).
    pub degree: u32,
}

impl Kernel {
    /// Gaussian kernel with bandwidth σ.
    pub fn gaussian(bandwidth: f64) -> Kernel {
        Kernel {
            kind: KernelKind::Gaussian,
            bandwidth,
            degree: 0,
        }
    }

    /// Matérn kernel; `nu` must be one of 0.5, 1.5, 2.5.
    pub fn matern(nu: f64, bandwidth: f64) -> Kernel {
        let kind = if nu == 0.5 {
            KernelKind::Matern12
        } else if nu == 1.5 {
            KernelKind::Matern32
        } else if nu == 2.5 {
            KernelKind::Matern52
        } else {
            panic!("matern: nu must be 0.5 / 1.5 / 2.5, got {nu}")
        };
        Kernel {
            kind,
            bandwidth,
            degree: 0,
        }
    }

    /// Laplacian kernel.
    pub fn laplacian(bandwidth: f64) -> Kernel {
        Kernel {
            kind: KernelKind::Laplacian,
            bandwidth,
            degree: 0,
        }
    }

    /// Polynomial kernel `(xᵀy/σ + 1)^degree`.
    pub fn polynomial(bandwidth: f64, degree: u32) -> Kernel {
        Kernel {
            kind: KernelKind::Polynomial,
            bandwidth,
            degree,
        }
    }

    /// Linear kernel.
    pub fn linear() -> Kernel {
        Kernel {
            kind: KernelKind::Linear,
            bandwidth: 1.0,
            degree: 0,
        }
    }

    /// Evaluate `k(x, y)` for feature slices.
    #[inline]
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        match self.kind {
            KernelKind::Gaussian => {
                let d2 = sq_dist(x, y);
                (-d2 / (2.0 * self.bandwidth * self.bandwidth)).exp()
            }
            KernelKind::Matern12 => {
                let r = sq_dist(x, y).sqrt();
                (-r / self.bandwidth).exp()
            }
            KernelKind::Matern32 => {
                let r = sq_dist(x, y).sqrt();
                let a = 3f64.sqrt() * r / self.bandwidth;
                (1.0 + a) * (-a).exp()
            }
            KernelKind::Matern52 => {
                let r2 = sq_dist(x, y);
                let r = r2.sqrt();
                let a = 5f64.sqrt() * r / self.bandwidth;
                (1.0 + a + 5.0 * r2 / (3.0 * self.bandwidth * self.bandwidth)) * (-a).exp()
            }
            KernelKind::Laplacian => {
                let l1: f64 = x.iter().zip(y).map(|(a, b)| (a - b).abs()).sum();
                (-l1 / self.bandwidth).exp()
            }
            KernelKind::Polynomial => {
                let ip = dot(x, y);
                (ip / self.bandwidth + 1.0).powi(self.degree as i32)
            }
            KernelKind::Linear => dot(x, y),
        }
    }

    /// Evaluate from a precomputed squared distance (used by the tiled
    /// assembly path, which gets ‖x−y‖² from the GEMM-shaped expansion).
    /// Only valid for translation-invariant kernels.
    #[inline]
    pub fn eval_sq_dist(&self, d2: f64) -> f64 {
        let d2 = d2.max(0.0); // guard round-off negatives from the expansion
        match self.kind {
            KernelKind::Gaussian => (-d2 / (2.0 * self.bandwidth * self.bandwidth)).exp(),
            KernelKind::Matern12 => (-d2.sqrt() / self.bandwidth).exp(),
            KernelKind::Matern32 => {
                let a = 3f64.sqrt() * d2.sqrt() / self.bandwidth;
                (1.0 + a) * (-a).exp()
            }
            KernelKind::Matern52 => {
                let a = 5f64.sqrt() * d2.sqrt() / self.bandwidth;
                (1.0 + a + 5.0 * d2 / (3.0 * self.bandwidth * self.bandwidth)) * (-a).exp()
            }
            _ => panic!("eval_sq_dist: {:?} is not translation-invariant-over-L2", self.kind),
        }
    }

    /// Apply the kernel map to a row of squared distances **in place** —
    /// the batched form of [`eval_sq_dist`] used by the tiled assembly
    /// path. The kernel kind is matched once per row, and the
    /// transcendental goes through [`exp_fast`] (Cody–Waite reduction +
    /// degree-12 Horner, no libm call), so the loop body is branch-free
    /// and vectorises; values agree with [`eval`]/libm to a few ulp —
    /// far inside every tolerance in the repo.
    pub fn map_sq_dist(&self, d2: &mut [f64]) {
        match self.kind {
            KernelKind::Gaussian => {
                let c = -1.0 / (2.0 * self.bandwidth * self.bandwidth);
                for v in d2.iter_mut() {
                    *v = exp_fast((*v).max(0.0) * c);
                }
            }
            KernelKind::Matern12 => {
                let c = -1.0 / self.bandwidth;
                for v in d2.iter_mut() {
                    *v = exp_fast((*v).max(0.0).sqrt() * c);
                }
            }
            KernelKind::Matern32 => {
                let c = 3f64.sqrt() / self.bandwidth;
                for v in d2.iter_mut() {
                    let a = c * (*v).max(0.0).sqrt();
                    *v = (1.0 + a) * exp_fast(-a);
                }
            }
            KernelKind::Matern52 => {
                let c = 5f64.sqrt() / self.bandwidth;
                let q = 5.0 / (3.0 * self.bandwidth * self.bandwidth);
                for v in d2.iter_mut() {
                    let x = (*v).max(0.0);
                    let a = c * x.sqrt();
                    *v = (1.0 + a + q * x) * exp_fast(-a);
                }
            }
            _ => {
                for v in d2.iter_mut() {
                    *v = self.eval_sq_dist(*v);
                }
            }
        }
    }

    /// True when `eval_sq_dist` applies (the fast tiled assembly path).
    pub fn is_radial(&self) -> bool {
        matches!(
            self.kind,
            KernelKind::Gaussian | KernelKind::Matern12 | KernelKind::Matern32 | KernelKind::Matern52
        )
    }

    /// `k(x,x)` (1 for all radial kernels; data-dependent otherwise).
    pub fn diag_value(&self, x: &[f64]) -> f64 {
        match self.kind {
            KernelKind::Linear => dot(x, x),
            KernelKind::Polynomial => (dot(x, x) / self.bandwidth + 1.0).powi(self.degree as i32),
            _ => 1.0,
        }
    }

    /// Stable name used in artifact manifests and bench output.
    pub fn name(&self) -> &'static str {
        match self.kind {
            KernelKind::Gaussian => "gaussian",
            KernelKind::Matern12 => "matern12",
            KernelKind::Matern32 => "matern32",
            KernelKind::Matern52 => "matern52",
            KernelKind::Laplacian => "laplacian",
            KernelKind::Polynomial => "polynomial",
            KernelKind::Linear => "linear",
        }
    }
}

#[inline]
fn dot(x: &[f64], y: &[f64]) -> f64 {
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

#[inline]
fn sq_dist(x: &[f64], y: &[f64]) -> f64 {
    x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum()
}

/// Branch-light `exp` for the batched kernel map: Cody–Waite range
/// reduction (`x = n·ln2 + r`, `|r| ≤ ln2/2`) followed by a degree-12
/// Taylor–Horner polynomial and an exact power-of-two scale via exponent
/// bits. No division and no libm call, so the per-row kernel-map loop can
/// vectorise. Accurate to a few ulp for `x ∈ [−708, 709]` (the truncation
/// tail `r¹³/13!` is below 2e-16 relative); saturates to `0`/`∞` outside.
#[inline]
fn exp_fast(x: f64) -> f64 {
    if x < -708.0 {
        return 0.0;
    }
    if x > 709.0 {
        return f64::INFINITY;
    }
    const LN2_HI: f64 = 6.931_471_803_691_238_164_90e-1;
    const LN2_LO: f64 = 1.908_214_929_270_587_700_02e-10;
    let n = (x * std::f64::consts::LOG2_E).round();
    let r = (x - n * LN2_HI) - n * LN2_LO;
    let mut p = 1.0 / 479_001_600.0; // 1/12!
    p = p * r + 1.0 / 39_916_800.0; // 1/11!
    p = p * r + 1.0 / 3_628_800.0; // 1/10!
    p = p * r + 1.0 / 362_880.0; // 1/9!
    p = p * r + 1.0 / 40_320.0; // 1/8!
    p = p * r + 1.0 / 5_040.0; // 1/7!
    p = p * r + 1.0 / 720.0; // 1/6!
    p = p * r + 1.0 / 120.0; // 1/5!
    p = p * r + 1.0 / 24.0; // 1/4!
    p = p * r + 1.0 / 6.0; // 1/3!
    p = p * r + 0.5; // 1/2!
    p = p * r + 1.0; // 1/1!
    p = p * r + 1.0; // 1/0!
    // 2ⁿ exactly, through the exponent field (n ∈ [−1022, 1023] here)
    let scale = f64::from_bits(((n as i64 + 1023) as u64) << 52);
    p * scale
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_basic_values() {
        let k = Kernel::gaussian(1.0);
        assert!((k.eval(&[0.0], &[0.0]) - 1.0).abs() < 1e-12);
        assert!((k.eval(&[0.0], &[1.0]) - (-0.5f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn matern12_is_exponential() {
        let k = Kernel::matern(0.5, 2.0);
        assert!((k.eval(&[0.0, 0.0], &[3.0, 4.0]) - (-2.5f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn matern_orders_decrease_with_distance() {
        for nu in [0.5, 1.5, 2.5] {
            let k = Kernel::matern(nu, 1.0);
            let near = k.eval(&[0.0], &[0.1]);
            let far = k.eval(&[0.0], &[2.0]);
            assert!(near > far, "nu={nu}");
            assert!((k.eval(&[0.3], &[0.3]) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn eval_sq_dist_consistent() {
        for kern in [
            Kernel::gaussian(1.3),
            Kernel::matern(0.5, 0.9),
            Kernel::matern(1.5, 1.1),
            Kernel::matern(2.5, 2.0),
        ] {
            let (x, y) = ([0.2, -1.0, 3.0], [1.0, 0.5, 2.0]);
            let d2: f64 = x.iter().zip(y.iter()).map(|(a, b)| (a - b) * (a - b)).sum();
            assert!(
                (kern.eval(&x, &y) - kern.eval_sq_dist(d2)).abs() < 1e-12,
                "{:?}",
                kern.kind
            );
        }
    }

    #[test]
    fn polynomial_and_linear() {
        let k = Kernel::polynomial(1.0, 2);
        assert!((k.eval(&[1.0, 2.0], &[3.0, 4.0]) - 144.0).abs() < 1e-9); // (11+1)^2
        let l = Kernel::linear();
        assert!((l.eval(&[1.0, 2.0], &[3.0, 4.0]) - 11.0).abs() < 1e-12);
    }

    #[test]
    fn symmetry() {
        let kerns = [
            Kernel::gaussian(0.7),
            Kernel::matern(1.5, 0.7),
            Kernel::laplacian(0.7),
            Kernel::polynomial(2.0, 3),
        ];
        let (x, y) = ([0.1, 0.9], [-0.4, 2.0]);
        for k in kerns {
            assert_eq!(k.eval(&x, &y), k.eval(&y, &x));
        }
    }

    #[test]
    #[should_panic]
    fn bad_matern_nu_panics() {
        let _ = Kernel::matern(2.0, 1.0);
    }

    #[test]
    fn exp_fast_matches_libm() {
        let mut worst = 0.0f64;
        let mut x = -700.0;
        while x < 30.0 {
            let fast = exp_fast(x);
            let lib = x.exp();
            worst = worst.max(((fast - lib) / lib.max(1e-300)).abs());
            x += 0.37;
        }
        assert!(worst < 1e-13, "relative error {worst}");
        assert_eq!(exp_fast(0.0), 1.0);
        assert_eq!(exp_fast(-1000.0), 0.0);
        assert_eq!(exp_fast(1000.0), f64::INFINITY);
    }

    #[test]
    fn map_sq_dist_matches_scalar_eval() {
        let kerns = [
            Kernel::gaussian(1.3),
            Kernel::matern(0.5, 0.9),
            Kernel::matern(1.5, 1.1),
            Kernel::matern(2.5, 2.0),
        ];
        let d2s: Vec<f64> = vec![0.0, 1e-14, 0.3, 1.0, 4.0, 25.0, 900.0, -1e-13];
        for kern in kerns {
            let mut row = d2s.clone();
            kern.map_sq_dist(&mut row);
            for (got, &d2) in row.iter().zip(d2s.iter()) {
                let want = kern.eval_sq_dist(d2);
                assert!(
                    (got - want).abs() < 1e-12 * (1.0 + want),
                    "{:?} d2={d2}: {got} vs {want}",
                    kern.kind
                );
            }
        }
    }
}
