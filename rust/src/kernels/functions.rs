//! Kernel function definitions.
//!
//! The batched kernel map ([`Kernel::map_sq_dist`]) is the transcendental
//! hot path of tiled assembly; it routes through the
//! [`crate::linalg::simd`] dispatch so AVX2 hosts run a 4-lane `exp`
//! (NEON hosts and `ACCUMKRR_FORCE_SCALAR=1` fall back to the scalar
//! [`exp_fast`], which the lane kernels agree with to ≲1e-12 relative).

use crate::linalg::simd::{self, exp_fast, KernelImpl};

/// Which positive semi-definite kernel to use.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelKind {
    /// Gaussian / RBF: `exp(−‖x−y‖² / (2σ²))`.
    Gaussian,
    /// Matérn ν = 1/2 (a.k.a. exponential): `exp(−r/σ)`.
    Matern12,
    /// Matérn ν = 3/2: `(1 + √3 r/σ) exp(−√3 r/σ)`.
    Matern32,
    /// Matérn ν = 5/2: `(1 + √5 r/σ + 5r²/(3σ²)) exp(−√5 r/σ)`.
    Matern52,
    /// Laplacian over L1 distance: `exp(−‖x−y‖₁/σ)`.
    Laplacian,
    /// Polynomial `(xᵀy/σ + 1)^p` (degree in [`Kernel::degree`]).
    Polynomial,
    /// Linear `xᵀy`.
    Linear,
}

/// A configured kernel: kind + bandwidth (+ degree for polynomial).
#[derive(Clone, Copy, Debug)]
pub struct Kernel {
    /// Kernel family.
    pub kind: KernelKind,
    /// Length-scale σ (ignored by `Linear`).
    pub bandwidth: f64,
    /// Polynomial degree (ignored elsewhere).
    pub degree: u32,
}

impl Kernel {
    /// Gaussian kernel with bandwidth σ.
    pub fn gaussian(bandwidth: f64) -> Kernel {
        Kernel {
            kind: KernelKind::Gaussian,
            bandwidth,
            degree: 0,
        }
    }

    /// Matérn kernel; `nu` must be one of 0.5, 1.5, 2.5.
    pub fn matern(nu: f64, bandwidth: f64) -> Kernel {
        let kind = if nu == 0.5 {
            KernelKind::Matern12
        } else if nu == 1.5 {
            KernelKind::Matern32
        } else if nu == 2.5 {
            KernelKind::Matern52
        } else {
            panic!("matern: nu must be 0.5 / 1.5 / 2.5, got {nu}")
        };
        Kernel {
            kind,
            bandwidth,
            degree: 0,
        }
    }

    /// Laplacian kernel.
    pub fn laplacian(bandwidth: f64) -> Kernel {
        Kernel {
            kind: KernelKind::Laplacian,
            bandwidth,
            degree: 0,
        }
    }

    /// Polynomial kernel `(xᵀy/σ + 1)^degree`.
    pub fn polynomial(bandwidth: f64, degree: u32) -> Kernel {
        Kernel {
            kind: KernelKind::Polynomial,
            bandwidth,
            degree,
        }
    }

    /// Linear kernel.
    pub fn linear() -> Kernel {
        Kernel {
            kind: KernelKind::Linear,
            bandwidth: 1.0,
            degree: 0,
        }
    }

    /// Evaluate `k(x, y)` for feature slices.
    #[inline]
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        match self.kind {
            KernelKind::Gaussian => {
                let d2 = sq_dist(x, y);
                (-d2 / (2.0 * self.bandwidth * self.bandwidth)).exp()
            }
            KernelKind::Matern12 => {
                let r = sq_dist(x, y).sqrt();
                (-r / self.bandwidth).exp()
            }
            KernelKind::Matern32 => {
                let r = sq_dist(x, y).sqrt();
                let a = 3f64.sqrt() * r / self.bandwidth;
                (1.0 + a) * (-a).exp()
            }
            KernelKind::Matern52 => {
                let r2 = sq_dist(x, y);
                let r = r2.sqrt();
                let a = 5f64.sqrt() * r / self.bandwidth;
                (1.0 + a + 5.0 * r2 / (3.0 * self.bandwidth * self.bandwidth)) * (-a).exp()
            }
            KernelKind::Laplacian => {
                let l1: f64 = x.iter().zip(y).map(|(a, b)| (a - b).abs()).sum();
                (-l1 / self.bandwidth).exp()
            }
            KernelKind::Polynomial => {
                let ip = dot(x, y);
                (ip / self.bandwidth + 1.0).powi(self.degree as i32)
            }
            KernelKind::Linear => dot(x, y),
        }
    }

    /// Evaluate from a precomputed squared distance (used by the tiled
    /// assembly path, which gets ‖x−y‖² from the GEMM-shaped expansion).
    /// Only valid for translation-invariant kernels.
    #[inline]
    pub fn eval_sq_dist(&self, d2: f64) -> f64 {
        let d2 = d2.max(0.0); // guard round-off negatives from the expansion
        match self.kind {
            KernelKind::Gaussian => (-d2 / (2.0 * self.bandwidth * self.bandwidth)).exp(),
            KernelKind::Matern12 => (-d2.sqrt() / self.bandwidth).exp(),
            KernelKind::Matern32 => {
                let a = 3f64.sqrt() * d2.sqrt() / self.bandwidth;
                (1.0 + a) * (-a).exp()
            }
            KernelKind::Matern52 => {
                let a = 5f64.sqrt() * d2.sqrt() / self.bandwidth;
                (1.0 + a + 5.0 * d2 / (3.0 * self.bandwidth * self.bandwidth)) * (-a).exp()
            }
            _ => panic!("eval_sq_dist: {:?} is not translation-invariant-over-L2", self.kind),
        }
    }

    /// Apply the kernel map to a row of squared distances **in place** —
    /// the batched form of [`eval_sq_dist`] used by the tiled assembly
    /// path. Samples the micro-kernel dispatch and delegates to
    /// [`Kernel::map_sq_dist_with`]; parallel assembly loops should
    /// instead sample `simd::active()` once on the calling thread and
    /// call `map_sq_dist_with` from their workers, so scoped dispatch
    /// overrides propagate into the pool.
    pub fn map_sq_dist(&self, d2: &mut [f64]) {
        self.map_sq_dist_with(simd::active(), d2);
    }

    /// [`Kernel::map_sq_dist`] with the dispatch pinned by the caller.
    /// The kernel kind is matched once per row; the transcendental runs
    /// lane-parallel on AVX2 (`simd::map_exp`, a 4-wide Cody–Waite +
    /// degree-12 Horner `exp`) and through the scalar [`exp_fast`]
    /// otherwise — identical reduction/polynomial, so the two dispatch
    /// modes agree to ≲1e-12 relative and each is position-independent
    /// (any slice ordering gives bitwise-identical values per element,
    /// which the symmetric-assembly mirror relies on).
    pub(crate) fn map_sq_dist_with(&self, imp: KernelImpl, d2: &mut [f64]) {
        match self.kind {
            KernelKind::Gaussian => {
                let c = -1.0 / (2.0 * self.bandwidth * self.bandwidth);
                for v in d2.iter_mut() {
                    *v = (*v).max(0.0) * c;
                }
                simd::map_exp(imp, d2);
            }
            KernelKind::Matern12 => {
                let c = -1.0 / self.bandwidth;
                for v in d2.iter_mut() {
                    *v = (*v).max(0.0).sqrt() * c;
                }
                simd::map_exp(imp, d2);
            }
            KernelKind::Matern32 => {
                // the (1 + a) prefactor needs a alongside exp(−a), so this
                // family stays on the scalar exp (dispatch-independent)
                let c = 3f64.sqrt() / self.bandwidth;
                for v in d2.iter_mut() {
                    let a = c * (*v).max(0.0).sqrt();
                    *v = (1.0 + a) * exp_fast(-a);
                }
            }
            KernelKind::Matern52 => {
                let c = 5f64.sqrt() / self.bandwidth;
                let q = 5.0 / (3.0 * self.bandwidth * self.bandwidth);
                for v in d2.iter_mut() {
                    let x = (*v).max(0.0);
                    let a = c * x.sqrt();
                    *v = (1.0 + a + q * x) * exp_fast(-a);
                }
            }
            _ => {
                for v in d2.iter_mut() {
                    *v = self.eval_sq_dist(*v);
                }
            }
        }
    }

    /// Single-precision kernel map for the opt-in f32 assembly path
    /// (`Precision::F32`): same shapes as [`Kernel::map_sq_dist_with`]
    /// but on f32 squared distances, with an 8-lane AVX2 `exp` under
    /// SIMD dispatch and the scalar `exp_fast_f32` otherwise. Radial
    /// kernels only — callers gate on [`Kernel::is_radial`].
    pub(crate) fn map_sq_dist_f32(&self, imp: KernelImpl, d2: &mut [f32]) {
        match self.kind {
            KernelKind::Gaussian => {
                let c = (-1.0 / (2.0 * self.bandwidth * self.bandwidth)) as f32;
                for v in d2.iter_mut() {
                    *v = (*v).max(0.0) * c;
                }
                simd::map_exp_f32(imp, d2);
            }
            KernelKind::Matern12 => {
                let c = (-1.0 / self.bandwidth) as f32;
                for v in d2.iter_mut() {
                    *v = (*v).max(0.0).sqrt() * c;
                }
                simd::map_exp_f32(imp, d2);
            }
            KernelKind::Matern32 => {
                let c = (3f64.sqrt() / self.bandwidth) as f32;
                for v in d2.iter_mut() {
                    let a = c * (*v).max(0.0).sqrt();
                    *v = (1.0 + a) * simd::exp_fast_f32(-a);
                }
            }
            KernelKind::Matern52 => {
                let c = (5f64.sqrt() / self.bandwidth) as f32;
                let q = (5.0 / (3.0 * self.bandwidth * self.bandwidth)) as f32;
                for v in d2.iter_mut() {
                    let x = (*v).max(0.0);
                    let a = c * x.sqrt();
                    *v = (1.0 + a + q * x) * simd::exp_fast_f32(-a);
                }
            }
            _ => panic!(
                "map_sq_dist_f32: {:?} is not radial (gate on is_radial)",
                self.kind
            ),
        }
    }

    /// True when `eval_sq_dist` applies (the fast tiled assembly path).
    pub fn is_radial(&self) -> bool {
        matches!(
            self.kind,
            KernelKind::Gaussian | KernelKind::Matern12 | KernelKind::Matern32 | KernelKind::Matern52
        )
    }

    /// `k(x,x)` (1 for all radial kernels; data-dependent otherwise).
    pub fn diag_value(&self, x: &[f64]) -> f64 {
        match self.kind {
            KernelKind::Linear => dot(x, x),
            KernelKind::Polynomial => (dot(x, x) / self.bandwidth + 1.0).powi(self.degree as i32),
            _ => 1.0,
        }
    }

    /// Stable name used in artifact manifests and bench output.
    pub fn name(&self) -> &'static str {
        match self.kind {
            KernelKind::Gaussian => "gaussian",
            KernelKind::Matern12 => "matern12",
            KernelKind::Matern32 => "matern32",
            KernelKind::Matern52 => "matern52",
            KernelKind::Laplacian => "laplacian",
            KernelKind::Polynomial => "polynomial",
            KernelKind::Linear => "linear",
        }
    }
}

#[inline]
fn dot(x: &[f64], y: &[f64]) -> f64 {
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

#[inline]
fn sq_dist(x: &[f64], y: &[f64]) -> f64 {
    x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_basic_values() {
        let k = Kernel::gaussian(1.0);
        assert!((k.eval(&[0.0], &[0.0]) - 1.0).abs() < 1e-12);
        assert!((k.eval(&[0.0], &[1.0]) - (-0.5f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn matern12_is_exponential() {
        let k = Kernel::matern(0.5, 2.0);
        assert!((k.eval(&[0.0, 0.0], &[3.0, 4.0]) - (-2.5f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn matern_orders_decrease_with_distance() {
        for nu in [0.5, 1.5, 2.5] {
            let k = Kernel::matern(nu, 1.0);
            let near = k.eval(&[0.0], &[0.1]);
            let far = k.eval(&[0.0], &[2.0]);
            assert!(near > far, "nu={nu}");
            assert!((k.eval(&[0.3], &[0.3]) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn eval_sq_dist_consistent() {
        for kern in [
            Kernel::gaussian(1.3),
            Kernel::matern(0.5, 0.9),
            Kernel::matern(1.5, 1.1),
            Kernel::matern(2.5, 2.0),
        ] {
            let (x, y) = ([0.2, -1.0, 3.0], [1.0, 0.5, 2.0]);
            let d2: f64 = x.iter().zip(y.iter()).map(|(a, b)| (a - b) * (a - b)).sum();
            assert!(
                (kern.eval(&x, &y) - kern.eval_sq_dist(d2)).abs() < 1e-12,
                "{:?}",
                kern.kind
            );
        }
    }

    #[test]
    fn polynomial_and_linear() {
        let k = Kernel::polynomial(1.0, 2);
        assert!((k.eval(&[1.0, 2.0], &[3.0, 4.0]) - 144.0).abs() < 1e-9); // (11+1)^2
        let l = Kernel::linear();
        assert!((l.eval(&[1.0, 2.0], &[3.0, 4.0]) - 11.0).abs() < 1e-12);
    }

    #[test]
    fn symmetry() {
        let kerns = [
            Kernel::gaussian(0.7),
            Kernel::matern(1.5, 0.7),
            Kernel::laplacian(0.7),
            Kernel::polynomial(2.0, 3),
        ];
        let (x, y) = ([0.1, 0.9], [-0.4, 2.0]);
        for k in kerns {
            assert_eq!(k.eval(&x, &y), k.eval(&y, &x));
        }
    }

    #[test]
    #[should_panic]
    fn bad_matern_nu_panics() {
        let _ = Kernel::matern(2.0, 1.0);
    }

    #[test]
    fn exp_fast_matches_libm() {
        let mut worst = 0.0f64;
        let mut x = -700.0;
        while x < 30.0 {
            let fast = exp_fast(x);
            let lib = x.exp();
            worst = worst.max(((fast - lib) / lib.max(1e-300)).abs());
            x += 0.37;
        }
        assert!(worst < 1e-13, "relative error {worst}");
        assert_eq!(exp_fast(0.0), 1.0);
        assert_eq!(exp_fast(-1000.0), 0.0);
        assert_eq!(exp_fast(1000.0), f64::INFINITY);
    }

    /// The f32 map agrees with the f64 map to single-precision accuracy
    /// on every radial family, under forced-scalar and detected dispatch.
    #[test]
    fn map_sq_dist_f32_matches_f64_map() {
        let kerns = [
            Kernel::gaussian(1.3),
            Kernel::matern(0.5, 0.9),
            Kernel::matern(1.5, 1.1),
            Kernel::matern(2.5, 2.0),
        ];
        let d2s: Vec<f64> = vec![0.0, 1e-6, 0.3, 1.0, 4.0, 25.0, 60.0, -1e-13];
        for imp in [KernelImpl::Scalar, simd::active()] {
            for kern in kerns {
                let mut want = d2s.clone();
                kern.map_sq_dist_with(imp, &mut want);
                let mut got: Vec<f32> = d2s.iter().map(|&v| v as f32).collect();
                kern.map_sq_dist_f32(imp, &mut got);
                for ((g, w), &d2) in got.iter().zip(want.iter()).zip(d2s.iter()) {
                    let rel = (*g as f64 - w).abs() / (1.0 + w.abs());
                    assert!(rel < 1e-5, "{:?} {imp:?} d2={d2}: {g} vs {w}", kern.kind);
                }
            }
        }
    }

    #[test]
    #[should_panic]
    fn map_sq_dist_f32_rejects_non_radial() {
        let mut row = [1.0f32];
        Kernel::linear().map_sq_dist_f32(KernelImpl::Scalar, &mut row);
    }

    #[test]
    fn map_sq_dist_matches_scalar_eval() {
        let kerns = [
            Kernel::gaussian(1.3),
            Kernel::matern(0.5, 0.9),
            Kernel::matern(1.5, 1.1),
            Kernel::matern(2.5, 2.0),
        ];
        let d2s: Vec<f64> = vec![0.0, 1e-14, 0.3, 1.0, 4.0, 25.0, 900.0, -1e-13];
        for kern in kerns {
            let mut row = d2s.clone();
            kern.map_sq_dist(&mut row);
            for (got, &d2) in row.iter().zip(d2s.iter()) {
                let want = kern.eval_sq_dist(d2);
                assert!(
                    (got - want).abs() < 1e-12 * (1.0 + want),
                    "{:?} d2={d2}: {got} vs {want}",
                    kern.kind
                );
            }
        }
    }
}
