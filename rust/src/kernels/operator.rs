//! Row-tiled implicit Gram operator — the streamed heart of the pipeline.
//!
//! The paper's whole argument is that accumulation makes the *effective*
//! problem `d×d`; the one thing that must never happen on the way there is
//! materialising the `n×n` kernel matrix. [`GramOperator`] assembles
//! `K[tile, :]` on the fly over a [`TileSource`] — the rows of `X`
//! in memory, in one f64 file, or across a shard directory
//! (DESIGN.md §12) — and exposes the products the rest of the system
//! actually consumes:
//!
//! * `K·B` / `Kᵀ·B` ([`matmul`](GramOperator::matmul) — identical for the
//!   symmetric Gram) for dense-sketch application and subspace iteration,
//! * gathered column blocks `K[:, idx]` ([`columns`](GramOperator::columns))
//!   for Nyström / landmark / BLESS paths,
//! * `K·S`, `SᵀKS`, `SᵀK²S` against a [`Sketch`]
//!   ([`ks`](GramOperator::ks), [`stks`](GramOperator::stks),
//!   [`stk2s`](GramOperator::stk2s)) — the sketched-KRR Grams,
//! * `diag(K)` ([`diag`](GramOperator::diag)),
//! * the [`SymOp`] impl, which feeds
//!   [`partial_eigh_op`](crate::linalg::partial_eigh_op) so top-k spectral
//!   consumers (KPCA pencil, K-satisfiability) iterate `K/n` implicitly.
//!
//! Peak memory is `O(tile·n + n·d)` — the tile panel plus the thin
//! factors — instead of `O(n²)`; with a file-backed source, `X` itself
//! drops out of residency too and the footprint becomes
//! `O(tile·p + n·d)`. That is what flips the system's scaling ceiling
//! from RAM to arithmetic (and, out of core, to I/O bandwidth).
//!
//! # Determinism rule
//!
//! Results are **bitwise independent of the tile size, the thread count,
//! and the storage backend**. Three disciplines buy that (same spirit as
//! the GEMM core's fixed row panels, DESIGN.md §5):
//!
//! 1. every backend feeds the assembly the exact f64 bytes of `X`'s rows
//!    (the [`TileSource`] contract), and every backend — the in-memory
//!    one included — goes through the same `fill_tile` → scratch-panel
//!    path, so there is literally one code path to be invariant;
//! 2. panels are assembled through the row-stable GEMM entry
//!    ([`cross_kernel_rowstable`]) over a **fixed [`COL_TILE`]-wide
//!    column-block schedule**: block boundaries sit at multiples of
//!    `COL_TILE` whatever the row-tile height, so each `K[i, c0..c1]`
//!    block is produced by an identical GEMM + norm-fold + kernel-map
//!    call however rows are tiled (the row-stable entry never takes the
//!    small-flops shortcut, whose accumulation order would otherwise
//!    depend on the tile height);
//! 3. every output row of a product has exactly one owner, and its
//!    accumulation order is fixed: `out[i, :] = Σⱼ K[i,j]·B[j, :]` with
//!    `j` strictly ascending, regardless of how rows are grouped into
//!    tiles or distributed over workers.
//!
//! The streamed products therefore differ from the dense
//! `kernel_matrix` + packed-GEMM route only by floating-point grouping;
//! equality tests pin both routes together, and `tests/tiles.rs` pins
//! whole-pipeline outputs bitwise across all three backends.
//!
//! # Fallibility
//!
//! Disk reads can fail (and the `io.read` fault seam injects failures on
//! purpose), so every product has a fallible `try_*` core returning
//! [`CodedError`]; the original infallible names are thin wrappers that
//! panic on error — the right behavior for in-memory sources (which
//! cannot fail) and for consumers behind the coordinator's worker-panic
//! containment. Fit paths route through the `try_*` entries so an
//! injected read failure surfaces as a coded error, not a panic.

use super::functions::Kernel;
use super::matrix::{
    cross_kernel_f32, cross_kernel_rows_f32, cross_kernel_rowstable, kernel_diag, kernel_matrix,
};
use crate::data::{gather_rows_source, load_all, load_rows, TileSource};
use crate::linalg::{syrk_at_a, Matrix, Precision, SymOp};
use crate::pool;
use crate::sketch::{Sketch, SketchOps, SparseSketch};
use crate::util::CodedError;
use std::collections::HashMap;

/// Default row-tile height: matches the assembly tile in
/// `kernels::matrix` (L2-resident working set at the paper's widths).
pub const DEFAULT_TILE: usize = 128;

/// Env var overriding the row-tile height every new operator starts
/// with (`ACCUMKRR_ROW_TILE`). A memory/performance knob like
/// [`with_tile`](GramOperator::with_tile) — results are bitwise
/// unaffected, which is exactly why `tests/tiles.rs` uses it to drive
/// *whole fits* across tile heights without any API plumbing.
pub const ROW_TILE_ENV: &str = "ACCUMKRR_ROW_TILE";

/// The starting tile height: [`ROW_TILE_ENV`] when set to a positive
/// integer, [`DEFAULT_TILE`] otherwise.
fn initial_tile() -> usize {
    std::env::var(ROW_TILE_ENV)
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&t| t >= 1)
        .unwrap_or(DEFAULT_TILE)
}

/// Fixed column-block width of the panel assembly schedule. Not a tuning
/// knob: the determinism contract (see the module docs) is defined in
/// terms of these block boundaries, so the value is part of the bitwise
/// behavior. 512 keeps a block of `B` rows L2-resident next to the tile
/// and is a multiple of every SIMD lane width in use, so only the final
/// ragged block ever runs map tails.
pub const COL_TILE: usize = 512;

/// Row-tiled implicit Gram matrix `α·K` over the rows of a tile source
/// (`n×p`). Cheap to copy — it owns only the kernel, a source reference,
/// and the schedule knobs.
#[derive(Clone, Copy, Debug)]
pub struct GramOperator<'a> {
    kernel: Kernel,
    src: &'a dyn TileSource,
    tile: usize,
    scale: f64,
    precision: Precision,
}

impl<'a> GramOperator<'a> {
    /// Operator for the un-scaled Gram `K` of a source under `kernel`.
    /// `&Matrix` coerces to the source trait object, so in-memory call
    /// sites are unchanged: `GramOperator::new(kern, &x)`.
    pub fn new(kernel: Kernel, src: &'a dyn TileSource) -> GramOperator<'a> {
        GramOperator {
            kernel,
            src,
            tile: initial_tile(),
            scale: 1.0,
            precision: Precision::F64,
        }
    }

    /// Override the tile height (results are bitwise unaffected — this is
    /// a memory/performance knob and a test axis, not a semantic one).
    pub fn with_tile(mut self, tile: usize) -> GramOperator<'a> {
        assert!(tile >= 1, "gram operator: tile >= 1");
        self.tile = tile;
        self
    }

    /// Opt into single-precision assembly + accumulation
    /// ([`Precision::F32`]): tile panels are assembled in f32 (8-lane
    /// `exp` under AVX2), `K·B` accumulates in f32, and each output entry
    /// is widened to f64 exactly once. Radial kernels only — non-radial
    /// kernels silently stay on the f64 path. All `d×d` solves downstream
    /// remain f64 regardless. Determinism contracts (bitwise tile-,
    /// thread- and backend-invariance) hold for the f32 path too; only
    /// the precision of the values changes (bounds: EXPERIMENTS.md
    /// §Mixed-precision).
    pub fn with_precision(mut self, precision: Precision) -> GramOperator<'a> {
        self.precision = precision;
        self
    }

    /// The accumulation precision in effect.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// The same operator representing `alpha·(current)` — e.g.
    /// `op.scaled(1.0 / n as f64)` is the `K/n` every spectral diagnostic
    /// decomposes.
    pub fn scaled(mut self, alpha: f64) -> GramOperator<'a> {
        self.scale *= alpha;
        self
    }

    /// Number of data points `n` (the operator is `n×n`).
    pub fn n(&self) -> usize {
        self.src.rows()
    }

    /// Kernel behind the operator.
    pub fn kernel(&self) -> &Kernel {
        &self.kernel
    }

    /// The tile source the Gram is implicit over.
    pub fn source(&self) -> &'a dyn TileSource {
        self.src
    }

    /// `diag(α·K)` — `O(n)` evaluations, streamed one row tile at a time.
    pub fn try_diag(&self) -> Result<Vec<f64>, CodedError> {
        let n = self.n();
        let mut d = Vec::with_capacity(n);
        let mut r0 = 0usize;
        while r0 < n {
            let r1 = (r0 + self.tile).min(n);
            let xt = load_rows(self.src, r0, r1)?;
            d.extend_from_slice(&kernel_diag(&self.kernel, &xt));
            r0 = r1;
        }
        if self.scale != 1.0 {
            for v in d.iter_mut() {
                *v *= self.scale;
            }
        }
        Ok(d)
    }

    /// Infallible [`GramOperator::try_diag`] — panics on a source read
    /// failure (in-memory sources cannot fail).
    pub fn diag(&self) -> Vec<f64> {
        self.try_diag().expect("gram operator: tile source read failed")
    }

    /// Gathered column block `α·K[:, idx]` (`n × |idx|`) — the Nyström /
    /// landmark fast path, `O(n·|idx|)` evaluations and memory. The
    /// landmark rows are gathered once; the `n`-side streams row tiles
    /// through the row-stable assembly, so the result is bitwise
    /// tile/thread/backend-invariant.
    pub fn try_columns(&self, idx: &[usize]) -> Result<Matrix, CodedError> {
        let n = self.n();
        let landmarks = gather_rows_source(self.src, idx)?;
        let mut c = Matrix::zeros(n, idx.len());
        if idx.is_empty() {
            return Ok(c);
        }
        let mut r0 = 0usize;
        while r0 < n {
            let r1 = (r0 + self.tile).min(n);
            let xt = load_rows(self.src, r0, r1)?;
            let kb = if self.use_f32() {
                cross_kernel_f32(&self.kernel, &xt, &landmarks)
            } else {
                cross_kernel_rowstable(&self.kernel, &xt, &landmarks)
            };
            for li in 0..r1 - r0 {
                c.row_mut(r0 + li).copy_from_slice(kb.row(li));
            }
            r0 = r1;
        }
        if self.scale != 1.0 {
            c.scale(self.scale);
        }
        Ok(c)
    }

    /// Infallible [`GramOperator::try_columns`].
    pub fn columns(&self, idx: &[usize]) -> Matrix {
        self.try_columns(idx)
            .expect("gram operator: tile source read failed")
    }

    /// F32 requested *and* applicable (radial kernel).
    fn use_f32(&self) -> bool {
        self.precision == Precision::F32 && self.kernel.is_radial()
    }

    /// Assemble the un-scaled panel `K[r0..r1, :]` through the fixed
    /// [`COL_TILE`] column-block schedule — the only routine in the crate
    /// that produces streamed panel values, so the determinism argument
    /// lives in one place. Each block is one row-stable `cross_kernel`
    /// over scratch tiles pulled from the source.
    fn try_panel(&self, r0: usize, r1: usize) -> Result<Matrix, CodedError> {
        let n = self.n();
        let a = load_rows(self.src, r0, r1)?;
        let mut kt = Matrix::zeros(r1 - r0, n);
        let mut c0 = 0usize;
        while c0 < n {
            let c1 = (c0 + COL_TILE).min(n);
            let blk = load_rows(self.src, c0, c1)?;
            let kb = cross_kernel_rowstable(&self.kernel, &a, &blk);
            for li in 0..r1 - r0 {
                kt.row_mut(li)[c0..c1].copy_from_slice(kb.row(li));
            }
            c0 = c1;
        }
        Ok(kt)
    }

    /// The f32 panel: same fixed column-block schedule, per-element
    /// scalar dots + vectorized f32 kernel map (`cross_kernel_rows_f32`),
    /// row-major `(r1-r0)×n`.
    fn try_panel_f32(&self, r0: usize, r1: usize) -> Result<Vec<f32>, CodedError> {
        let n = self.n();
        let a = load_rows(self.src, r0, r1)?;
        let th = r1 - r0;
        let mut kt = vec![0.0f32; th * n];
        let mut c0 = 0usize;
        while c0 < n {
            let c1 = (c0 + COL_TILE).min(n);
            let blk = load_rows(self.src, c0, c1)?;
            let kb = cross_kernel_rows_f32(&self.kernel, &a, &blk);
            let w = c1 - c0;
            for li in 0..th {
                kt[li * n + c0..li * n + c1].copy_from_slice(&kb[li * w..(li + 1) * w]);
            }
            c0 = c1;
        }
        Ok(kt)
    }

    /// Streamed `α·K·B` for a tall `n×c` block, never holding more than
    /// one `tile×n` panel of `K` and two scratch row tiles of `X`. Since
    /// the Gram is symmetric this is also `Kᵀ·B`. See the module docs for
    /// the fixed assembly + accumulation schedule that makes the result
    /// bitwise tile-, thread- and backend-invariant.
    ///
    /// The tile product is a hand-rolled per-row axpy sweep rather than a
    /// call into the packed GEMM **on purpose**: the GEMM's small-flops
    /// cutoff and `KC` grouping make its per-element accumulation order
    /// depend on the tile height once `n > KC`, which would break the
    /// tile-size-invariance contract. The sweep vectorises over `B`'s
    /// contiguous rows, and for radial kernels at the paper's `p` the
    /// panel *assembly* (transcendental-bound) dominates the product
    /// anyway — see the `gram_op` vs dense `K·B` hotpath cases.
    pub fn try_matmul(&self, b: &Matrix) -> Result<Matrix, CodedError> {
        let n = self.n();
        assert_eq!(b.rows(), n, "gram operator: K·B row mismatch");
        let c = b.cols();
        let mut out = Matrix::zeros(n, c);
        if c == 0 || n == 0 {
            return Ok(out);
        }
        if self.use_f32() {
            self.try_matmul_f32_into(b, &mut out)?;
            return Ok(out);
        }
        let bd = b.data();
        let scale = self.scale;
        let mut r0 = 0usize;
        while r0 < n {
            let r1 = (r0 + self.tile).min(n);
            // assemble K[r0..r1, :] — the only K storage that ever exists
            let kt = self.try_panel(r0, r1)?;
            let out_chunk = &mut out.data_mut()[r0 * c..r1 * c];
            // one owner per output row; j ascending inside a row
            pool::scope_chunks(out_chunk, c, |li, orow| {
                let krow = kt.row(li);
                for (j, &kv) in krow.iter().enumerate() {
                    let brow = &bd[j * c..(j + 1) * c];
                    for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                        *o += kv * bv;
                    }
                }
                if scale != 1.0 {
                    for o in orow.iter_mut() {
                        *o *= scale;
                    }
                }
            });
            r0 = r1;
        }
        Ok(out)
    }

    /// Infallible [`GramOperator::try_matmul`].
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        self.try_matmul(b)
            .expect("gram operator: tile source read failed")
    }

    /// The [`Precision::F32`] body of [`GramOperator::try_matmul`]: f32
    /// tile panels, f32 row accumulation with the same
    /// one-owner-per-row / j-ascending schedule as the f64 path, a single
    /// f32→f64 widen per output entry, and the scale applied in f64.
    /// Bitwise tile/thread/backend-invariant for the same reasons.
    fn try_matmul_f32_into(&self, b: &Matrix, out: &mut Matrix) -> Result<(), CodedError> {
        let n = self.n();
        let c = b.cols();
        let bf: Vec<f32> = b.data().iter().map(|&v| v as f32).collect();
        let scale = self.scale;
        let mut r0 = 0usize;
        while r0 < n {
            let r1 = (r0 + self.tile).min(n);
            let kt = self.try_panel_f32(r0, r1)?;
            let out_chunk = &mut out.data_mut()[r0 * c..r1 * c];
            let (bf, kt) = (&bf, &kt);
            pool::scope_chunks(out_chunk, c, |li, orow| {
                let krow = &kt[li * n..(li + 1) * n];
                let mut acc = vec![0.0f32; c];
                for (j, &kv) in krow.iter().enumerate() {
                    let brow = &bf[j * c..(j + 1) * c];
                    for (a, &bv) in acc.iter_mut().zip(brow.iter()) {
                        *a += kv * bv;
                    }
                }
                for (o, &a) in orow.iter_mut().zip(acc.iter()) {
                    *o = a as f64 * scale;
                }
            });
            r0 = r1;
        }
        Ok(())
    }

    /// Streamed `α·K·v` matrix–vector product.
    pub fn try_matvec(&self, v: &[f64]) -> Result<Vec<f64>, CodedError> {
        let kv = self.try_matmul(&Matrix::col_vec(v))?;
        Ok(kv.data().to_vec())
    }

    /// Infallible [`GramOperator::try_matvec`].
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        self.try_matvec(v)
            .expect("gram operator: tile source read failed")
    }

    /// `α·K·S` plus the kernel-evaluation count. Sparse sketches take the
    /// support-column path (`O(n·|U|)` evaluations, the paper's §3.3
    /// argument); dense sketches stream row tiles (`O(n²)` evaluations —
    /// unavoidable — but `O(tile·n)` memory instead of the dense `O(n²)`).
    pub fn try_ks(&self, sketch: &Sketch) -> Result<(Matrix, usize), CodedError> {
        match sketch {
            Sketch::Sparse(sp) => self.try_ks_sparse(sp),
            Sketch::Dense(s) => Ok((self.try_matmul(s)?, self.n() * self.n())),
        }
    }

    /// Infallible [`GramOperator::try_ks`].
    pub fn ks(&self, sketch: &Sketch) -> (Matrix, usize) {
        self.try_ks(sketch)
            .expect("gram operator: tile source read failed")
    }

    /// `Sᵀ·(α·K)·S` from a previously computed `ks`, symmetrised.
    pub fn stks(&self, sketch: &Sketch, ks: &Matrix) -> Matrix {
        let mut m = sketch.st_mat(ks);
        m.symmetrize();
        m
    }

    /// `Sᵀ·(α·K)²·S = (KS)ᵀ(KS)` from a previously computed `ks`.
    pub fn stk2s(&self, ks: &Matrix) -> Matrix {
        syrk_at_a(ks)
    }

    /// Support-column `K·S` for a sparse sketch: column `j` of `KS` is
    /// `Σ_{(i,w)∈col j} w · K[:, i]` over the gathered support block.
    /// (Crate-visible so `sketch::sketch_kernel_cols` can delegate.)
    pub(crate) fn try_ks_sparse(&self, sp: &SparseSketch) -> Result<(Matrix, usize), CodedError> {
        let n = self.n();
        assert_eq!(SketchOps::n(sp), n, "gram operator: sketch n mismatch");
        let support = sp.support();
        let kcols = self.try_columns(&support)?; // n × |U|
        let mut pos = HashMap::with_capacity(support.len());
        for (p, &i) in support.iter().enumerate() {
            pos.insert(i, p);
        }
        let mut ks = Matrix::zeros(n, sp.d());
        for j in 0..sp.d() {
            for &(i, w) in sp.col(j) {
                let src = pos[&i];
                for r in 0..n {
                    ks[(r, j)] += w * kcols[(r, src)];
                }
            }
        }
        Ok((ks, n * support.len()))
    }
}

/// Feeds [`partial_eigh_op`](crate::linalg::partial_eigh_op): subspace
/// iteration sees `α·K` through tile-streamed products;
/// [`materialize`](SymOp::materialize) (small-n / stalled-iteration
/// fallbacks only) is the one route back to a dense assembly — and, for
/// a disk-backed source, the one route that loads all of `X` (the
/// documented exit from the out-of-core model).
impl SymOp for GramOperator<'_> {
    fn dim(&self) -> usize {
        self.n()
    }

    fn apply(&self, b: &Matrix) -> Matrix {
        self.matmul(b)
    }

    fn materialize(&self) -> Matrix {
        let mut k = match self.src.as_matrix() {
            Some(x) => kernel_matrix(&self.kernel, x),
            None => {
                let x = load_all(self.src).expect("gram operator: tile source read failed");
                kernel_matrix(&self.kernel, &x)
            }
        };
        if self.scale != 1.0 {
            k.scale(self.scale);
        }
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::assembly_guard;
    use crate::linalg::{matmul, matmul_at_b, partial_eigh_op};
    use crate::rng::Pcg64;
    use crate::sketch::{SketchBuilder, SketchKind};

    fn setup(n: usize, seed: u64) -> (Kernel, Matrix, Pcg64) {
        let mut rng = Pcg64::seed(seed);
        let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
        (Kernel::gaussian(0.8), x, rng)
    }

    fn assert_close(a: &Matrix, b: &Matrix, tol: f64, what: &str) {
        assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "{what}: shape");
        for i in 0..a.rows() {
            for j in 0..a.cols() {
                assert!(
                    (a[(i, j)] - b[(i, j)]).abs() < tol,
                    "{what} ({i},{j}): {} vs {}",
                    a[(i, j)],
                    b[(i, j)]
                );
            }
        }
    }

    /// Streamed `K·B` equals the dense assemble-then-GEMM route. The two
    /// differ only by FP grouping, so the tolerance is tight.
    #[test]
    fn streamed_matmul_matches_dense() {
        for &n in &[35usize, 220, 300] {
            let (kern, x, mut rng) = setup(n, 0x0901);
            let b = Matrix::from_fn(n, 7, |_, _| rng.normal());
            let k = kernel_matrix(&kern, &x);
            let dense = matmul(&k, &b);
            let streamed = GramOperator::new(kern, &x).matmul(&b);
            assert_close(&streamed, &dense, 1e-10 * n as f64, &format!("K·B n={n}"));
        }
    }

    /// The determinism rule: bitwise identical output across tile sizes
    /// {1 row, odd, default, n} and thread counts {1, 4}. n > COL_TILE so
    /// the column-block schedule (boundary + ragged tail) is exercised.
    #[test]
    fn bitwise_invariant_across_tile_sizes_and_threads() {
        let _guard = pool::TEST_THREADS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let n = COL_TILE + 89;
        let (kern, x, mut rng) = setup(n, 0x0902);
        let b = Matrix::from_fn(n, 5, |_, _| rng.normal());
        let before = pool::num_threads();
        pool::set_num_threads(1);
        let reference = GramOperator::new(kern, &x).matmul(&b);
        for &tile in &[1usize, 37, DEFAULT_TILE, n] {
            for &threads in &[1usize, 4] {
                pool::set_num_threads(threads);
                let got = GramOperator::new(kern, &x).with_tile(tile).matmul(&b);
                assert_eq!(
                    got.data(),
                    reference.data(),
                    "tile={tile} threads={threads}"
                );
            }
        }
        pool::set_num_threads(before);
    }

    /// The file and shard backends reproduce the in-memory operator
    /// products bitwise — the unit-level face of the cross-backend
    /// equivalence harness in `tests/tiles.rs`.
    #[test]
    fn file_backends_match_in_memory_bitwise() {
        let (kern, x, mut rng) = setup(90, 0x0909);
        let b = Matrix::from_fn(90, 4, |_, _| rng.normal());
        let dir = std::env::temp_dir().join("accumkrr_op_backends");
        std::fs::create_dir_all(&dir).unwrap();
        let fpath = dir.join("x.bin");
        let sdir = dir.join("shards");
        crate::data::write_f64_file(fpath.to_str().unwrap(), &x).unwrap();
        crate::data::write_shards(sdir.to_str().unwrap(), &x, 17).unwrap();
        let f = crate::data::F64File::open(fpath.to_str().unwrap(), 3).unwrap();
        let s = crate::data::ShardedFile::open(sdir.to_str().unwrap()).unwrap();
        let mem = GramOperator::new(kern, &x);
        let (want_mm, want_cols, want_diag) =
            (mem.matmul(&b), mem.columns(&[3, 40, 40, 71]), mem.diag());
        for src in [&f as &dyn crate::data::TileSource, &s] {
            for &tile in &[1usize, 23, DEFAULT_TILE] {
                let op = GramOperator::new(kern, src).with_tile(tile);
                assert_eq!(op.matmul(&b).data(), want_mm.data(), "matmul tile={tile}");
                assert_eq!(
                    op.columns(&[3, 40, 40, 71]).data(),
                    want_cols.data(),
                    "columns tile={tile}"
                );
                assert_eq!(op.diag(), want_diag, "diag tile={tile}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The f32 streamed product tracks the f64 one to single-precision
    /// accumulation accuracy, stays bitwise tile/thread-invariant, and
    /// non-radial kernels silently keep the f64 path.
    #[test]
    fn f32_precision_matmul_tracks_f64_and_stays_invariant() {
        use crate::linalg::Precision;
        let _guard = pool::TEST_THREADS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let (kern, x, mut rng) = setup(260, 0x0907);
        let b = Matrix::from_fn(260, 6, |_, _| rng.normal());
        let f64_out = GramOperator::new(kern, &x).matmul(&b);
        let op32 = GramOperator::new(kern, &x).with_precision(Precision::F32);
        let f32_out = op32.matmul(&b);
        assert_close(&f32_out, &f64_out, 1e-5 * 260.0, "f32 K·B vs f64");
        let before = pool::num_threads();
        for &tile in &[1usize, 37, DEFAULT_TILE, 260] {
            for &threads in &[1usize, 4] {
                pool::set_num_threads(threads);
                let got = op32.with_tile(tile).matmul(&b);
                assert_eq!(got.data(), f32_out.data(), "tile={tile} t={threads}");
            }
        }
        pool::set_num_threads(before);
        // non-radial: F32 request is a no-op, bitwise the f64 path
        let lin = Kernel::linear();
        let a = GramOperator::new(lin, &x).matmul(&b);
        let b32 = GramOperator::new(lin, &x)
            .with_precision(Precision::F32)
            .matmul(&b);
        assert_eq!(a.data(), b32.data());
    }

    /// The streamed determinism contract holds under **both** dispatch
    /// modes: forced-scalar and host-detected kernels each give bitwise
    /// tile/thread-invariant products (the two modes differ from each
    /// other only by FMA grouping, so cross-mode equality is not, and
    /// must not be, asserted bitwise).
    #[test]
    fn streamed_invariance_holds_under_both_dispatch_modes() {
        use crate::linalg::{with_kernel, KernelImpl};
        let _guard = pool::TEST_THREADS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let (kern, x, mut rng) = setup(301, 0x0908);
        let b = Matrix::from_fn(301, 5, |_, _| rng.normal());
        for imp in [KernelImpl::Scalar, crate::linalg::simd::active()] {
            with_kernel(imp, || {
                let before = pool::num_threads();
                pool::set_num_threads(1);
                let reference = GramOperator::new(kern, &x).matmul(&b);
                for &tile in &[37usize, DEFAULT_TILE, 301] {
                    for &threads in &[1usize, 4] {
                        pool::set_num_threads(threads);
                        let got = GramOperator::new(kern, &x).with_tile(tile).matmul(&b);
                        assert_eq!(got.data(), reference.data(), "{imp:?} tile={tile}");
                    }
                }
                pool::set_num_threads(before);
            });
        }
    }

    /// Sketched Grams through the operator equal the dense-K reference for
    /// sparse and dense sketch kinds alike.
    #[test]
    fn sketched_products_match_dense_reference() {
        let (kern, x, mut rng) = setup(60, 0x0903);
        let k = kernel_matrix(&kern, &x);
        let op = GramOperator::new(kern, &x);
        for kind in [
            SketchKind::Nystrom,
            SketchKind::Accumulation { m: 4 },
            SketchKind::Gaussian,
        ] {
            let s = SketchBuilder::new(kind.clone()).build(60, 9, &mut rng);
            let (ks, evals) = op.ks(&s);
            let sd = s.to_dense();
            let ks_ref = matmul(&k, &sd);
            assert_close(&ks, &ks_ref, 1e-9, &format!("KS {}", kind.name()));
            let stks = op.stks(&s, &ks);
            let stks_ref = matmul_at_b(&sd, &ks_ref);
            assert_close(&stks, &stks_ref, 1e-9, "StKS");
            let stk2s = op.stk2s(&ks);
            let stk2s_ref = matmul_at_b(&ks_ref, &ks_ref);
            assert_close(&stk2s, &stk2s_ref, 1e-8, "StK2S");
            match kind {
                SketchKind::Gaussian => assert_eq!(evals, 60 * 60),
                _ => assert!(evals <= 60 * s.nnz()),
            }
        }
    }

    /// `diag` and `columns` agree with the assembled matrix; `scaled`
    /// composes into every product.
    #[test]
    fn diag_columns_and_scaling() {
        let (kern, x, mut rng) = setup(40, 0x0904);
        let k = kernel_matrix(&kern, &x);
        let op = GramOperator::new(kern, &x).scaled(1.0 / 40.0);
        let d = op.diag();
        let cols = op.columns(&[3, 17, 17, 29]);
        for i in 0..40 {
            assert!((d[i] - k[(i, i)] / 40.0).abs() < 1e-14);
            for (c, &j) in [3usize, 17, 17, 29].iter().enumerate() {
                assert!((cols[(i, c)] - k[(i, j)] / 40.0).abs() < 1e-14);
            }
        }
        let v: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let kv = op.matvec(&v);
        let mut kn = k.clone();
        kn.scale(1.0 / 40.0);
        let want = kn.matvec(&v);
        for (a, b) in kv.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-11, "{a} vs {b}");
        }
    }

    /// `partial_eigh_op` over the streamed `K/n` matches the dense top
    /// spectrum — the route KPCA and top-k K-satisfiability take.
    #[test]
    fn partial_eigh_over_operator_matches_dense_spectrum() {
        let (_, x, _) = setup(150, 0x0905);
        // wide bandwidth → fast spectral decay, so the subspace iteration
        // converges well inside its budget and never falls back to dense
        let kern = Kernel::gaussian(1.5);
        let k = kernel_matrix(&kern, &x);
        let view = crate::stats::SpectralView::new(&k);
        let op = GramOperator::new(kern, &x).scaled(1.0 / 150.0);
        assembly_guard::reset();
        let pe = partial_eigh_op(&op, 6);
        assert!(
            assembly_guard::max_square() < 150,
            "streamed eigensolve must not assemble K (saw {})",
            assembly_guard::max_square()
        );
        for j in 0..6 {
            assert!(
                (pe.w[j] - view.sigma[j]).abs() < 1e-8 * (1.0 + view.sigma[j]),
                "σ{j}: {} vs {}",
                pe.w[j],
                view.sigma[j]
            );
        }
    }

    /// Acceptance gate for the whole pipeline: every streamed consumer —
    /// one-shot sketched fits (sparse *and* dense sketches), the adaptive
    /// fit, KPCA, kernel k-means, BLESS, top-k K-satisfiability, and the
    /// spectral-clustering subsystem (Laplacian operator iteration *and*
    /// the sketched Laplacian pencil) — runs without a single full `n×n`
    /// assembly (the guard tracks square self-assemblies on this thread;
    /// sub-blocks like BLESS's `K_JJ` stay far below `n`).
    #[test]
    fn streamed_consumers_never_assemble_full_k() {
        let n = 120;
        let (_, x, mut rng) = setup(n, 0x0906);
        // wide bandwidth keeps the K-sat partial eigensolve comfortably in
        // its streamed regime (σ₁₆ ≪ δ at the first block size)
        let kern = Kernel::gaussian(1.5);
        let y: Vec<f64> = (0..n).map(|i| (x[(i, 0)] + 0.1 * (i as f64)).sin()).collect();
        let lam = 1e-3;
        assembly_guard::reset();

        let sp = SketchBuilder::new(SketchKind::Accumulation { m: 3 }).build(n, 8, &mut rng);
        let _ = crate::krr::SketchedKrr::fit(kern, &x, &y, &sp, lam, None).unwrap();
        let dn = SketchBuilder::new(SketchKind::Gaussian).build(n, 8, &mut rng);
        let _ = crate::krr::SketchedKrr::fit(kern, &x, &y, &dn, lam, None).unwrap();

        let builder = SketchBuilder::new(SketchKind::Accumulation { m: 1 });
        let opts = crate::krr::AdaptiveOptions {
            m_max: 4,
            rel_tol: -1.0,
            ..Default::default()
        };
        let _ =
            crate::krr::SketchedKrr::fit_adaptive(kern, &x, &y, &builder, 8, lam, &opts, &mut rng)
                .unwrap();

        let _ = crate::krr::sketched_kpca(&kern, &x, &sp, 4).unwrap();
        let _ = crate::krr::kernel_kmeans(&kern, &x, &sp, 2, 4, 10, &mut rng).unwrap();
        let _ = crate::leverage::bless(&kern, &x, lam, 10, 2.0, &mut rng);

        let op = GramOperator::new(kern, &x);
        let _ = crate::stats::k_satisfiability_topk_streamed(&op, &sp, 0.05);
        let _ = crate::stats::top_sigma_streamed(&op, 4);

        // the clustering workload, on its own well-separated data (sized
        // above n so any fallback assembly would trip the assert below):
        // operator-iterated embedding and the sketched Laplacian pencil
        let (cx, _) = crate::data::blobs(150, 3, 6.0, 0.3, &mut rng);
        let ckern = Kernel::gaussian(1.5);
        for method in [
            crate::cluster::EmbedMethod::Operator,
            crate::cluster::EmbedMethod::Adaptive {
                d: 20,
                m_max: 4,
                rel_tol: 1e-2,
            },
        ] {
            let opts = crate::cluster::SpectralOptions {
                k: 3,
                method,
                ..Default::default()
            };
            let _ = crate::cluster::SpectralClustering::fit(ckern, &cx, &opts, &mut rng)
                .unwrap();
        }

        assert!(
            assembly_guard::max_square() < n,
            "streamed pipeline assembled a square of size {} (n = {n})",
            assembly_guard::max_square()
        );
    }
}
