//! Row-tiled implicit Gram operator — the streamed heart of the pipeline.
//!
//! The paper's whole argument is that accumulation makes the *effective*
//! problem `d×d`; the one thing that must never happen on the way there is
//! materialising the `n×n` kernel matrix. [`GramOperator`] assembles
//! `K[tile, :]` on the fly (one row tile at a time, through the same
//! GEMM-routed [`cross_kernel`] that dense assembly uses) and exposes the
//! products the rest of the system actually consumes:
//!
//! * `K·B` / `Kᵀ·B` ([`matmul`](GramOperator::matmul) — identical for the
//!   symmetric Gram) for dense-sketch application and subspace iteration,
//! * gathered column blocks `K[:, idx]` ([`columns`](GramOperator::columns))
//!   for Nyström / landmark / BLESS paths,
//! * `K·S`, `SᵀKS`, `SᵀK²S` against a [`Sketch`]
//!   ([`ks`](GramOperator::ks), [`stks`](GramOperator::stks),
//!   [`stk2s`](GramOperator::stk2s)) — the sketched-KRR Grams,
//! * `diag(K)` ([`diag`](GramOperator::diag)),
//! * the [`SymOp`] impl, which feeds
//!   [`partial_eigh_op`](crate::linalg::partial_eigh_op) so top-k spectral
//!   consumers (KPCA pencil, K-satisfiability) iterate `K/n` implicitly.
//!
//! Peak memory is `O(tile·n + n·d)` — the tile panel plus the thin
//! factors — instead of `O(n²)`, which is what flips the system's scaling
//! ceiling from RAM to arithmetic.
//!
//! # Determinism rule
//!
//! Results are **bitwise independent of the tile size and the thread
//! count**. Two disciplines buy that (same spirit as the GEMM core's
//! fixed row panels, DESIGN.md §5):
//!
//! 1. tile assembly is per-row independent: each row of `K[tile, :]` is
//!    produced by the same GEMM + norm-fold + kernel-map sequence whatever
//!    tile it lands in (the packed GEMM's per-element accumulation order
//!    depends only on the inner dimension, and `p ≤ KC` always holds for
//!    feature matrices);
//! 2. every output row of a product has exactly one owner, and its
//!    accumulation order is fixed: `out[i, :] = Σⱼ K[i,j]·B[j, :]` with
//!    `j` strictly ascending, regardless of how rows are grouped into
//!    tiles or distributed over workers.
//!
//! The streamed products therefore differ from the dense
//! `kernel_matrix` + packed-GEMM route only by floating-point grouping
//! (and not at all for `n ≤ KC`); equality tests pin both routes together.

use super::functions::Kernel;
use super::matrix::{
    cross_kernel, cross_kernel_f32, cross_kernel_rows_f32, gather_rows, kernel_diag, kernel_matrix,
};
use crate::linalg::{syrk_at_a, Matrix, Precision, SymOp};
use crate::pool;
use crate::sketch::{Sketch, SketchOps, SparseSketch};
use std::collections::HashMap;

/// Default row-tile height: matches the assembly tile in
/// `kernels::matrix` (L2-resident working set at the paper's widths).
pub const DEFAULT_TILE: usize = 128;

/// Row-tiled implicit Gram matrix `α·K` over the rows of `x` (`n×p`).
/// Cheap to copy — it owns only the kernel, a data reference, and the
/// schedule knobs.
#[derive(Clone, Copy, Debug)]
pub struct GramOperator<'a> {
    kernel: Kernel,
    x: &'a Matrix,
    tile: usize,
    scale: f64,
    precision: Precision,
}

impl<'a> GramOperator<'a> {
    /// Operator for the un-scaled Gram `K` of `x` under `kernel`.
    pub fn new(kernel: Kernel, x: &'a Matrix) -> GramOperator<'a> {
        GramOperator {
            kernel,
            x,
            tile: DEFAULT_TILE,
            scale: 1.0,
            precision: Precision::F64,
        }
    }

    /// Override the tile height (results are bitwise unaffected — this is
    /// a memory/performance knob and a test axis, not a semantic one).
    pub fn with_tile(mut self, tile: usize) -> GramOperator<'a> {
        assert!(tile >= 1, "gram operator: tile >= 1");
        self.tile = tile;
        self
    }

    /// Opt into single-precision assembly + accumulation
    /// ([`Precision::F32`]): tile panels are assembled in f32 (8-lane
    /// `exp` under AVX2), `K·B` accumulates in f32, and each output entry
    /// is widened to f64 exactly once. Radial kernels only — non-radial
    /// kernels silently stay on the f64 path. All `d×d` solves downstream
    /// remain f64 regardless. Determinism contracts (bitwise tile- and
    /// thread-invariance) hold for the f32 path too; only the precision
    /// of the values changes (bounds: EXPERIMENTS.md §Mixed-precision).
    pub fn with_precision(mut self, precision: Precision) -> GramOperator<'a> {
        self.precision = precision;
        self
    }

    /// The accumulation precision in effect.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// The same operator representing `alpha·(current)` — e.g.
    /// `op.scaled(1.0 / n as f64)` is the `K/n` every spectral diagnostic
    /// decomposes.
    pub fn scaled(mut self, alpha: f64) -> GramOperator<'a> {
        self.scale *= alpha;
        self
    }

    /// Number of data points `n` (the operator is `n×n`).
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    /// Kernel behind the operator.
    pub fn kernel(&self) -> &Kernel {
        &self.kernel
    }

    /// Data matrix the Gram is implicit over.
    pub fn data(&self) -> &Matrix {
        self.x
    }

    /// `diag(α·K)` — `O(n)` evaluations, no assembly.
    pub fn diag(&self) -> Vec<f64> {
        let mut d = kernel_diag(&self.kernel, self.x);
        if self.scale != 1.0 {
            for v in d.iter_mut() {
                *v *= self.scale;
            }
        }
        d
    }

    /// Gathered column block `α·K[:, idx]` (`n × |idx|`) — the Nyström /
    /// landmark fast path, `O(n·|idx|)` evaluations and memory.
    pub fn columns(&self, idx: &[usize]) -> Matrix {
        let landmarks = gather_rows(self.x, idx);
        let mut c = if self.use_f32() {
            cross_kernel_f32(&self.kernel, self.x, &landmarks)
        } else {
            cross_kernel(&self.kernel, self.x, &landmarks)
        };
        if self.scale != 1.0 {
            c.scale(self.scale);
        }
        c
    }

    /// F32 requested *and* applicable (radial kernel).
    fn use_f32(&self) -> bool {
        self.precision == Precision::F32 && self.kernel.is_radial()
    }

    /// Streamed `α·K·B` for a tall `n×c` block, never holding more than
    /// one `tile×n` panel of `K`. Since the Gram is symmetric this is also
    /// `Kᵀ·B`. See the module docs for the fixed accumulation schedule
    /// that makes the result bitwise tile- and thread-invariant.
    ///
    /// The tile product is a hand-rolled per-row axpy sweep rather than a
    /// call into the packed GEMM **on purpose**: the GEMM's small-flops
    /// cutoff and `KC` grouping make its per-element accumulation order
    /// depend on the tile height once `n > KC`, which would break the
    /// tile-size-invariance contract. The sweep vectorises over `B`'s
    /// contiguous rows, and for radial kernels at the paper's `p` the
    /// panel *assembly* (transcendental-bound) dominates the product
    /// anyway — see the `gram_op` vs dense `K·B` hotpath cases.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        let n = self.n();
        assert_eq!(b.rows(), n, "gram operator: K·B row mismatch");
        let c = b.cols();
        let mut out = Matrix::zeros(n, c);
        if c == 0 || n == 0 {
            return out;
        }
        if self.use_f32() {
            self.matmul_f32_into(b, &mut out);
            return out;
        }
        let bd = b.data();
        let scale = self.scale;
        let mut r0 = 0usize;
        while r0 < n {
            let r1 = (r0 + self.tile).min(n);
            // assemble K[r0..r1, :] — the only K storage that ever exists
            let xt = self.x.slice(r0, r1, 0, self.x.cols());
            let kt = cross_kernel(&self.kernel, &xt, self.x);
            let out_chunk = &mut out.data_mut()[r0 * c..r1 * c];
            // one owner per output row; j ascending inside a row
            pool::scope_chunks(out_chunk, c, |li, orow| {
                let krow = kt.row(li);
                for (j, &kv) in krow.iter().enumerate() {
                    let brow = &bd[j * c..(j + 1) * c];
                    for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                        *o += kv * bv;
                    }
                }
                if scale != 1.0 {
                    for o in orow.iter_mut() {
                        *o *= scale;
                    }
                }
            });
            r0 = r1;
        }
        out
    }

    /// The [`Precision::F32`] body of [`GramOperator::matmul`]: f32 tile
    /// panels (`cross_kernel_rows_f32`), f32 row accumulation with the
    /// same one-owner-per-row / j-ascending schedule as the f64 path, a
    /// single f32→f64 widen per output entry, and the scale applied in
    /// f64. Bitwise tile- and thread-invariant for the same reasons.
    fn matmul_f32_into(&self, b: &Matrix, out: &mut Matrix) {
        let n = self.n();
        let c = b.cols();
        let bf: Vec<f32> = b.data().iter().map(|&v| v as f32).collect();
        let scale = self.scale;
        let mut r0 = 0usize;
        while r0 < n {
            let r1 = (r0 + self.tile).min(n);
            let xt = self.x.slice(r0, r1, 0, self.x.cols());
            let kt = cross_kernel_rows_f32(&self.kernel, &xt, self.x);
            let out_chunk = &mut out.data_mut()[r0 * c..r1 * c];
            let (bf, kt) = (&bf, &kt);
            pool::scope_chunks(out_chunk, c, |li, orow| {
                let krow = &kt[li * n..(li + 1) * n];
                let mut acc = vec![0.0f32; c];
                for (j, &kv) in krow.iter().enumerate() {
                    let brow = &bf[j * c..(j + 1) * c];
                    for (a, &bv) in acc.iter_mut().zip(brow.iter()) {
                        *a += kv * bv;
                    }
                }
                for (o, &a) in orow.iter_mut().zip(acc.iter()) {
                    *o = a as f64 * scale;
                }
            });
            r0 = r1;
        }
    }

    /// Streamed `α·K·v` matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let kv = self.matmul(&Matrix::col_vec(v));
        kv.data().to_vec()
    }

    /// `α·K·S` plus the kernel-evaluation count. Sparse sketches take the
    /// support-column path (`O(n·|U|)` evaluations, the paper's §3.3
    /// argument); dense sketches stream row tiles (`O(n²)` evaluations —
    /// unavoidable — but `O(tile·n)` memory instead of the dense `O(n²)`).
    pub fn ks(&self, sketch: &Sketch) -> (Matrix, usize) {
        match sketch {
            Sketch::Sparse(sp) => self.ks_sparse(sp),
            Sketch::Dense(s) => (self.matmul(s), self.n() * self.n()),
        }
    }

    /// `Sᵀ·(α·K)·S` from a previously computed `ks`, symmetrised.
    pub fn stks(&self, sketch: &Sketch, ks: &Matrix) -> Matrix {
        let mut m = sketch.st_mat(ks);
        m.symmetrize();
        m
    }

    /// `Sᵀ·(α·K)²·S = (KS)ᵀ(KS)` from a previously computed `ks`.
    pub fn stk2s(&self, ks: &Matrix) -> Matrix {
        syrk_at_a(ks)
    }

    /// Support-column `K·S` for a sparse sketch: column `j` of `KS` is
    /// `Σ_{(i,w)∈col j} w · K[:, i]` over the gathered support block.
    /// (Crate-visible so `sketch::sketch_kernel_cols` can delegate.)
    pub(crate) fn ks_sparse(&self, sp: &SparseSketch) -> (Matrix, usize) {
        let n = self.n();
        assert_eq!(SketchOps::n(sp), n, "gram operator: sketch n mismatch");
        let support = sp.support();
        let kcols = self.columns(&support); // n × |U|
        let mut pos = HashMap::with_capacity(support.len());
        for (p, &i) in support.iter().enumerate() {
            pos.insert(i, p);
        }
        let mut ks = Matrix::zeros(n, sp.d());
        for j in 0..sp.d() {
            for &(i, w) in sp.col(j) {
                let src = pos[&i];
                for r in 0..n {
                    ks[(r, j)] += w * kcols[(r, src)];
                }
            }
        }
        (ks, n * support.len())
    }
}

/// Feeds [`partial_eigh_op`](crate::linalg::partial_eigh_op): subspace
/// iteration sees `α·K` through tile-streamed products;
/// [`materialize`](SymOp::materialize) (small-n / stalled-iteration
/// fallbacks only) is the one route back to a dense assembly.
impl SymOp for GramOperator<'_> {
    fn dim(&self) -> usize {
        self.n()
    }

    fn apply(&self, b: &Matrix) -> Matrix {
        self.matmul(b)
    }

    fn materialize(&self) -> Matrix {
        let mut k = kernel_matrix(&self.kernel, self.x);
        if self.scale != 1.0 {
            k.scale(self.scale);
        }
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::assembly_guard;
    use crate::linalg::{matmul, matmul_at_b, partial_eigh_op};
    use crate::rng::Pcg64;
    use crate::sketch::{SketchBuilder, SketchKind};

    fn setup(n: usize, seed: u64) -> (Kernel, Matrix, Pcg64) {
        let mut rng = Pcg64::seed(seed);
        let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
        (Kernel::gaussian(0.8), x, rng)
    }

    fn assert_close(a: &Matrix, b: &Matrix, tol: f64, what: &str) {
        assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "{what}: shape");
        for i in 0..a.rows() {
            for j in 0..a.cols() {
                assert!(
                    (a[(i, j)] - b[(i, j)]).abs() < tol,
                    "{what} ({i},{j}): {} vs {}",
                    a[(i, j)],
                    b[(i, j)]
                );
            }
        }
    }

    /// Streamed `K·B` equals the dense assemble-then-GEMM route. The two
    /// differ only by FP grouping (not at all while `n ≤ KC`), so the
    /// tolerance is tight.
    #[test]
    fn streamed_matmul_matches_dense() {
        for &n in &[35usize, 220, 300] {
            let (kern, x, mut rng) = setup(n, 0x0901);
            let b = Matrix::from_fn(n, 7, |_, _| rng.normal());
            let k = kernel_matrix(&kern, &x);
            let dense = matmul(&k, &b);
            let streamed = GramOperator::new(kern, &x).matmul(&b);
            assert_close(&streamed, &dense, 1e-10 * n as f64, &format!("K·B n={n}"));
        }
    }

    /// The determinism rule: bitwise identical output across tile sizes
    /// {1 row, odd, default, n} and thread counts {1, 4}.
    #[test]
    fn bitwise_invariant_across_tile_sizes_and_threads() {
        let _guard = pool::TEST_THREADS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let (kern, x, mut rng) = setup(301, 0x0902);
        let b = Matrix::from_fn(301, 5, |_, _| rng.normal());
        let before = pool::num_threads();
        pool::set_num_threads(1);
        let reference = GramOperator::new(kern, &x).matmul(&b);
        for &tile in &[1usize, 37, DEFAULT_TILE, 301] {
            for &threads in &[1usize, 4] {
                pool::set_num_threads(threads);
                let got = GramOperator::new(kern, &x).with_tile(tile).matmul(&b);
                assert_eq!(
                    got.data(),
                    reference.data(),
                    "tile={tile} threads={threads}"
                );
            }
        }
        pool::set_num_threads(before);
    }

    /// The f32 streamed product tracks the f64 one to single-precision
    /// accumulation accuracy, stays bitwise tile/thread-invariant, and
    /// non-radial kernels silently keep the f64 path.
    #[test]
    fn f32_precision_matmul_tracks_f64_and_stays_invariant() {
        use crate::linalg::Precision;
        let _guard = pool::TEST_THREADS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let (kern, x, mut rng) = setup(260, 0x0907);
        let b = Matrix::from_fn(260, 6, |_, _| rng.normal());
        let f64_out = GramOperator::new(kern, &x).matmul(&b);
        let op32 = GramOperator::new(kern, &x).with_precision(Precision::F32);
        let f32_out = op32.matmul(&b);
        assert_close(&f32_out, &f64_out, 1e-5 * 260.0, "f32 K·B vs f64");
        let before = pool::num_threads();
        for &tile in &[1usize, 37, DEFAULT_TILE, 260] {
            for &threads in &[1usize, 4] {
                pool::set_num_threads(threads);
                let got = op32.with_tile(tile).matmul(&b);
                assert_eq!(got.data(), f32_out.data(), "tile={tile} t={threads}");
            }
        }
        pool::set_num_threads(before);
        // non-radial: F32 request is a no-op, bitwise the f64 path
        let lin = Kernel::linear();
        let a = GramOperator::new(lin, &x).matmul(&b);
        let b32 = GramOperator::new(lin, &x)
            .with_precision(Precision::F32)
            .matmul(&b);
        assert_eq!(a.data(), b32.data());
    }

    /// The streamed determinism contract holds under **both** dispatch
    /// modes: forced-scalar and host-detected kernels each give bitwise
    /// tile/thread-invariant products (the two modes differ from each
    /// other only by FMA grouping, so cross-mode equality is not, and
    /// must not be, asserted bitwise).
    #[test]
    fn streamed_invariance_holds_under_both_dispatch_modes() {
        use crate::linalg::{with_kernel, KernelImpl};
        let _guard = pool::TEST_THREADS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let (kern, x, mut rng) = setup(301, 0x0908);
        let b = Matrix::from_fn(301, 5, |_, _| rng.normal());
        for imp in [KernelImpl::Scalar, crate::linalg::simd::active()] {
            with_kernel(imp, || {
                let before = pool::num_threads();
                pool::set_num_threads(1);
                let reference = GramOperator::new(kern, &x).matmul(&b);
                for &tile in &[37usize, DEFAULT_TILE, 301] {
                    for &threads in &[1usize, 4] {
                        pool::set_num_threads(threads);
                        let got = GramOperator::new(kern, &x).with_tile(tile).matmul(&b);
                        assert_eq!(got.data(), reference.data(), "{imp:?} tile={tile}");
                    }
                }
                pool::set_num_threads(before);
            });
        }
    }

    /// Sketched Grams through the operator equal the dense-K reference for
    /// sparse and dense sketch kinds alike.
    #[test]
    fn sketched_products_match_dense_reference() {
        let (kern, x, mut rng) = setup(60, 0x0903);
        let k = kernel_matrix(&kern, &x);
        let op = GramOperator::new(kern, &x);
        for kind in [
            SketchKind::Nystrom,
            SketchKind::Accumulation { m: 4 },
            SketchKind::Gaussian,
        ] {
            let s = SketchBuilder::new(kind.clone()).build(60, 9, &mut rng);
            let (ks, evals) = op.ks(&s);
            let sd = s.to_dense();
            let ks_ref = matmul(&k, &sd);
            assert_close(&ks, &ks_ref, 1e-9, &format!("KS {}", kind.name()));
            let stks = op.stks(&s, &ks);
            let stks_ref = matmul_at_b(&sd, &ks_ref);
            assert_close(&stks, &stks_ref, 1e-9, "StKS");
            let stk2s = op.stk2s(&ks);
            let stk2s_ref = matmul_at_b(&ks_ref, &ks_ref);
            assert_close(&stk2s, &stk2s_ref, 1e-8, "StK2S");
            match kind {
                SketchKind::Gaussian => assert_eq!(evals, 60 * 60),
                _ => assert!(evals <= 60 * s.nnz()),
            }
        }
    }

    /// `diag` and `columns` agree with the assembled matrix; `scaled`
    /// composes into every product.
    #[test]
    fn diag_columns_and_scaling() {
        let (kern, x, mut rng) = setup(40, 0x0904);
        let k = kernel_matrix(&kern, &x);
        let op = GramOperator::new(kern, &x).scaled(1.0 / 40.0);
        let d = op.diag();
        let cols = op.columns(&[3, 17, 17, 29]);
        for i in 0..40 {
            assert!((d[i] - k[(i, i)] / 40.0).abs() < 1e-14);
            for (c, &j) in [3usize, 17, 17, 29].iter().enumerate() {
                assert!((cols[(i, c)] - k[(i, j)] / 40.0).abs() < 1e-14);
            }
        }
        let v: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let kv = op.matvec(&v);
        let mut kn = k.clone();
        kn.scale(1.0 / 40.0);
        let want = kn.matvec(&v);
        for (a, b) in kv.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-11, "{a} vs {b}");
        }
    }

    /// `partial_eigh_op` over the streamed `K/n` matches the dense top
    /// spectrum — the route KPCA and top-k K-satisfiability take.
    #[test]
    fn partial_eigh_over_operator_matches_dense_spectrum() {
        let (_, x, _) = setup(150, 0x0905);
        // wide bandwidth → fast spectral decay, so the subspace iteration
        // converges well inside its budget and never falls back to dense
        let kern = Kernel::gaussian(1.5);
        let k = kernel_matrix(&kern, &x);
        let view = crate::stats::SpectralView::new(&k);
        let op = GramOperator::new(kern, &x).scaled(1.0 / 150.0);
        assembly_guard::reset();
        let pe = partial_eigh_op(&op, 6);
        assert!(
            assembly_guard::max_square() < 150,
            "streamed eigensolve must not assemble K (saw {})",
            assembly_guard::max_square()
        );
        for j in 0..6 {
            assert!(
                (pe.w[j] - view.sigma[j]).abs() < 1e-8 * (1.0 + view.sigma[j]),
                "σ{j}: {} vs {}",
                pe.w[j],
                view.sigma[j]
            );
        }
    }

    /// Acceptance gate for the whole pipeline: every streamed consumer —
    /// one-shot sketched fits (sparse *and* dense sketches), the adaptive
    /// fit, KPCA, kernel k-means, BLESS, top-k K-satisfiability, and the
    /// spectral-clustering subsystem (Laplacian operator iteration *and*
    /// the sketched Laplacian pencil) — runs without a single full `n×n`
    /// assembly (the guard tracks square self-assemblies on this thread;
    /// sub-blocks like BLESS's `K_JJ` stay far below `n`).
    #[test]
    fn streamed_consumers_never_assemble_full_k() {
        let n = 120;
        let (_, x, mut rng) = setup(n, 0x0906);
        // wide bandwidth keeps the K-sat partial eigensolve comfortably in
        // its streamed regime (σ₁₆ ≪ δ at the first block size)
        let kern = Kernel::gaussian(1.5);
        let y: Vec<f64> = (0..n).map(|i| (x[(i, 0)] + 0.1 * (i as f64)).sin()).collect();
        let lam = 1e-3;
        assembly_guard::reset();

        let sp = SketchBuilder::new(SketchKind::Accumulation { m: 3 }).build(n, 8, &mut rng);
        let _ = crate::krr::SketchedKrr::fit(kern, &x, &y, &sp, lam, None).unwrap();
        let dn = SketchBuilder::new(SketchKind::Gaussian).build(n, 8, &mut rng);
        let _ = crate::krr::SketchedKrr::fit(kern, &x, &y, &dn, lam, None).unwrap();

        let builder = SketchBuilder::new(SketchKind::Accumulation { m: 1 });
        let opts = crate::krr::AdaptiveOptions {
            m_max: 4,
            rel_tol: -1.0,
            ..Default::default()
        };
        let _ =
            crate::krr::SketchedKrr::fit_adaptive(kern, &x, &y, &builder, 8, lam, &opts, &mut rng)
                .unwrap();

        let _ = crate::krr::sketched_kpca(&kern, &x, &sp, 4).unwrap();
        let _ = crate::krr::kernel_kmeans(&kern, &x, &sp, 2, 4, 10, &mut rng).unwrap();
        let _ = crate::leverage::bless(&kern, &x, lam, 10, 2.0, &mut rng);

        let op = GramOperator::new(kern, &x);
        let _ = crate::stats::k_satisfiability_topk_streamed(&op, &sp, 0.05);
        let _ = crate::stats::top_sigma_streamed(&op, 4);

        // the clustering workload, on its own well-separated data (sized
        // above n so any fallback assembly would trip the assert below):
        // operator-iterated embedding and the sketched Laplacian pencil
        let (cx, _) = crate::data::blobs(150, 3, 6.0, 0.3, &mut rng);
        let ckern = Kernel::gaussian(1.5);
        for method in [
            crate::cluster::EmbedMethod::Operator,
            crate::cluster::EmbedMethod::Adaptive {
                d: 20,
                m_max: 4,
                rel_tol: 1e-2,
            },
        ] {
            let opts = crate::cluster::SpectralOptions {
                k: 3,
                method,
                ..Default::default()
            };
            let _ = crate::cluster::SpectralClustering::fit(ckern, &cx, &opts, &mut rng)
                .unwrap();
        }

        assert!(
            assembly_guard::max_square() < n,
            "streamed pipeline assembled a square of size {} (n = {n})",
            assembly_guard::max_square()
        );
    }
}
