//! Random Fourier Features (Rahimi & Recht, 2008) — the other major
//! kernel-approximation family the paper's introduction cites. Included so
//! downstream users can compare feature-space against sketch-space
//! approximation in one framework.
//!
//! For a shift-invariant kernel with spectral density `p(ω)`,
//! `k(x, y) ≈ z(x)ᵀ z(y)` with `z(x) = √(2/D)·[cos(ωᵢᵀx + bᵢ)]ᵢ`,
//! `ωᵢ ~ p`, `bᵢ ~ Unif[0, 2π)`. Gaussian kernel → ω ~ N(0, I/σ²);
//! Matérn ν → ω ~ multivariate-t with 2ν dof (componentwise scaled).

use super::functions::{Kernel, KernelKind};
use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// A sampled random-feature map.
#[derive(Clone, Debug)]
pub struct RandomFourierFeatures {
    /// Frequencies, one row per feature (D × p).
    omega: Matrix,
    /// Phases (D).
    phase: Vec<f64>,
    /// √(2/D).
    scale: f64,
}

impl RandomFourierFeatures {
    /// Sample `n_features` random features for the given radial kernel.
    pub fn sample(kernel: &Kernel, input_dim: usize, n_features: usize, rng: &mut Pcg64) -> Self {
        assert!(kernel.is_radial(), "RFF needs a shift-invariant kernel");
        let bw = kernel.bandwidth;
        let omega = Matrix::from_fn(n_features, input_dim, |_, _| match kernel.kind {
            KernelKind::Gaussian => rng.normal() / bw,
            // Matérn ν: ω ∼ t_{2ν}/bw componentwise via N/√(χ²_{2ν}/2ν).
            KernelKind::Matern12 | KernelKind::Matern32 | KernelKind::Matern52 => {
                let nu = match kernel.kind {
                    KernelKind::Matern12 => 0.5,
                    KernelKind::Matern32 => 1.5,
                    _ => 2.5,
                };
                let dof = 2.0 * nu;
                // χ²_k as sum of k standard-normal squares (k = 1, 3, 5)
                let chi2: f64 = (0..dof as usize * 2)
                    .map(|_| {
                        let g = rng.normal();
                        g * g * 0.5
                    })
                    .sum();
                rng.normal() / bw / (chi2 / dof).max(1e-12).sqrt()
            }
            _ => unreachable!(),
        });
        let phase: Vec<f64> = (0..n_features)
            .map(|_| rng.uniform() * std::f64::consts::TAU)
            .collect();
        RandomFourierFeatures {
            omega,
            phase,
            scale: (2.0 / n_features as f64).sqrt(),
        }
    }

    /// Number of random features D.
    pub fn dim(&self) -> usize {
        self.omega.rows()
    }

    /// Map data rows to feature space: (n × D).
    pub fn transform(&self, x: &Matrix) -> Matrix {
        let n = x.rows();
        let d = self.dim();
        let mut z = Matrix::zeros(n, d);
        for i in 0..n {
            let xi = x.row(i);
            let zrow = z.row_mut(i);
            for j in 0..d {
                let w = self.omega.row(j);
                let mut ip = self.phase[j];
                for (a, b) in w.iter().zip(xi.iter()) {
                    ip += a * b;
                }
                zrow[j] = self.scale * ip.cos();
            }
        }
        z
    }

    /// Approximate kernel matrix `Z Zᵀ` (diagnostic).
    pub fn approx_kernel(&self, x: &Matrix) -> Matrix {
        let z = self.transform(x);
        crate::linalg::matmul_a_bt(&z, &z)
    }
}

/// Ridge regression in RFF space: `w = (ZᵀZ + nλI)⁻¹ Zᵀ y` — the RFF-KRR
/// baseline (`O(n·D²)`).
#[derive(Clone, Debug)]
pub struct RffKrr {
    features: RandomFourierFeatures,
    weights: Vec<f64>,
    fitted: Vec<f64>,
}

impl RffKrr {
    /// Fit the RFF ridge model.
    pub fn fit(
        kernel: &Kernel,
        x: &Matrix,
        y: &[f64],
        n_features: usize,
        lambda: f64,
        rng: &mut Pcg64,
    ) -> Option<RffKrr> {
        let n = x.rows();
        let features = RandomFourierFeatures::sample(kernel, x.cols(), n_features, rng);
        let z = features.transform(x);
        let mut a = crate::linalg::syrk_at_a(&z);
        a.add_diag(n as f64 * lambda);
        let rhs = z.matvec_t(y);
        let w = crate::linalg::chol_solve(&a, &rhs)?;
        let fitted = z.matvec(&w);
        Some(RffKrr {
            features,
            weights: w,
            fitted,
        })
    }

    /// In-sample fitted values.
    pub fn fitted(&self) -> &[f64] {
        &self.fitted
    }

    /// Predict at query rows.
    pub fn predict(&self, xq: &Matrix) -> Vec<f64> {
        self.features.transform(xq).matvec(&self.weights)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rff_approximates_gaussian_kernel() {
        let mut rng = Pcg64::seed(0xff1);
        let x = Matrix::from_fn(20, 2, |_, _| rng.uniform());
        let kern = Kernel::gaussian(0.8);
        let rff = RandomFourierFeatures::sample(&kern, 2, 4000, &mut rng);
        let approx = rff.approx_kernel(&x);
        let exact = crate::kernels::kernel_matrix(&kern, &x);
        let mut max_err = 0.0f64;
        for i in 0..20 {
            for j in 0..20 {
                max_err = max_err.max((approx[(i, j)] - exact[(i, j)]).abs());
            }
        }
        assert!(max_err < 0.08, "max |K̂ − K| = {max_err}");
    }

    #[test]
    fn rff_matern_diag_is_one() {
        let mut rng = Pcg64::seed(0xff2);
        let x = Matrix::from_fn(10, 3, |_, _| rng.normal());
        let kern = Kernel::matern(1.5, 1.0);
        let rff = RandomFourierFeatures::sample(&kern, 3, 3000, &mut rng);
        let approx = rff.approx_kernel(&x);
        for i in 0..10 {
            assert!((approx[(i, i)] - 1.0).abs() < 0.06, "{}", approx[(i, i)]);
        }
    }

    #[test]
    fn rff_krr_learns_smooth_function() {
        let mut rng = Pcg64::seed(0xff3);
        let n = 150;
        let x = Matrix::from_fn(n, 1, |_, _| rng.uniform() * 2.0);
        let y: Vec<f64> = (0..n).map(|i| (2.0 * x[(i, 0)]).sin() + 0.05 * rng.normal()).collect();
        let model = RffKrr::fit(&Kernel::gaussian(0.5), &x, &y, 200, 1e-4, &mut rng).unwrap();
        let mse = crate::stats::mse(model.fitted(), &y);
        assert!(mse < 0.02, "train mse {mse}");
        // predict at train points ≈ fitted
        let p = model.predict(&x);
        for (a, b) in p.iter().zip(model.fitted().iter()) {
            assert!((a - b).abs() < 1e-10);
        }
    }
}
